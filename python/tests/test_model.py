"""Layer-2 correctness: the JAX graphs that get AOT-lowered.

Checks the numerical semantics of each graph against numpy references
and the shape contract recorded in the manifest (``aot.graph_catalog``).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import aot, model
from compile.kernels import ref


def test_catalog_shapes_consistent():
    """Every graph in the catalog must abstract-eval to the declared
    output shapes (this is what the Rust manifest consumer relies on)."""
    cat = aot.graph_catalog()
    assert len(cat) >= 20
    for name, (fn, specs, _params) in cat.items():
        outs = jax.eval_shape(fn, *specs)
        assert len(outs) >= 1, name
        for o in outs:
            assert o.dtype == jnp.float32, f"{name}: non-f32 output"


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_lsq_grad_matches_numpy(seed):
    rng = np.random.default_rng(seed)
    s, d = 64, 10
    a = rng.standard_normal((s, d)).astype(np.float32)
    w = rng.standard_normal(d).astype(np.float32)
    b = rng.standard_normal(s).astype(np.float32)
    (g,) = model.lsq_grad(a, w, b)
    expect = 2.0 / s * a.T @ (a @ w - b)
    np.testing.assert_allclose(np.asarray(g), expect, rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_power_update_matches_numpy(seed):
    rng = np.random.default_rng(seed)
    s, d = 48, 12
    x = rng.standard_normal((s, d)).astype(np.float32)
    v = rng.standard_normal(d).astype(np.float32)
    (u,) = model.power_update(x, v)
    np.testing.assert_allclose(
        np.asarray(u), x.T @ (x @ v), rtol=2e-4, atol=2e-4
    )


def test_mlp_grad_matches_finite_differences():
    rng = np.random.default_rng(3)
    b_, f, h, c = 8, 5, 6, 3
    fn = jax.jit(model.mlp_grad_graph(h, c))
    xb = rng.standard_normal((b_, f)).astype(np.float32)
    labels = rng.integers(0, c, b_)
    yb = np.eye(c, dtype=np.float32)[labels]
    w1 = (rng.standard_normal((f, h)) * 0.3).astype(np.float32)
    b1 = np.zeros(h, np.float32)
    w2 = (rng.standard_normal((h, c)) * 0.3).astype(np.float32)
    b2 = np.zeros(c, np.float32)
    loss, gw1, _gb1, gw2, _gb2 = fn(xb, yb, w1, b1, w2, b2)
    eps = 1e-3
    for (param, grad, idx) in [(w1, gw1, (2, 3)), (w2, gw2, (4, 1))]:
        p_plus = param.copy()
        p_plus[idx] += eps
        p_minus = param.copy()
        p_minus[idx] -= eps
        if param is w1:
            lp = fn(xb, yb, p_plus, b1, w2, b2)[0]
            lm = fn(xb, yb, p_minus, b1, w2, b2)[0]
        else:
            lp = fn(xb, yb, w1, b1, p_plus, b2)[0]
            lm = fn(xb, yb, w1, b1, p_minus, b2)[0]
        fd = (float(lp[0]) - float(lm[0])) / (2 * eps)
        assert abs(fd - float(np.asarray(grad)[idx])) < 5e-3
    assert float(loss[0]) > 0


def test_me_round_graph_semantics():
    """The fused leader round must equal: decode each color against the
    leader's vector, average with the leader input, re-encode."""
    rng = np.random.default_rng(5)
    n, d, q, s = 3, 16, 16, 0.5
    fn = jax.jit(model.mean_estimate_round_graph(q, n))
    offset = rng.uniform(-s / 2, s / 2, d).astype(np.float32)
    x_leader = rng.standard_normal(d).astype(np.float32) * 0.2 + 7.0
    workers = [
        (x_leader + rng.uniform(-1, 1, d) * 0.4).astype(np.float32)
        for _ in range(n)
    ]
    colors = np.stack(
        [
            np.asarray(ref.lattice_encode_ref(wv, offset, s, q)[0])
            for wv in workers
        ]
    ).astype(np.float32)
    mu_color, mu_hat = fn(colors, x_leader, offset, np.array([s], np.float32))
    decoded = [
        np.asarray(ref.lattice_decode_ref(c, x_leader, offset, s, q))
        for c in colors
    ]
    expect_mu = (np.sum(decoded, axis=0) + x_leader) / (n + 1)
    np.testing.assert_allclose(np.asarray(mu_hat), expect_mu, atol=1e-5)
    expect_color = np.asarray(ref.lattice_encode_ref(expect_mu, offset, s, q)[0])
    np.testing.assert_array_equal(np.asarray(mu_color), expect_color)


def test_rotate_encode_pipeline_consistent():
    rng = np.random.default_rng(6)
    d, q, s = 128, 8, 0.3
    fn = jax.jit(model.rotate_encode_graph(q))
    x = rng.standard_normal(d).astype(np.float32) + 40.0
    sign = rng.choice([-1.0, 1.0], d).astype(np.float32)
    offset = rng.uniform(-s / 2, s / 2, d).astype(np.float32)
    color, rx = fn(x, sign, offset, np.array([s], np.float32))
    rx_ref = np.asarray(ref.rotate_fwd_ref(x, sign))
    np.testing.assert_allclose(np.asarray(rx), rx_ref, atol=1e-4)
    c_ref = np.asarray(ref.lattice_encode_ref(np.asarray(rx), offset, s, q)[0])
    np.testing.assert_array_equal(np.asarray(color), c_ref)


def test_encode_decode_roundtrip_helper():
    rng = np.random.default_rng(7)
    d, q, s = 64, 16, 0.4
    x = rng.standard_normal(d).astype(np.float32) * 3
    xv = (x + rng.uniform(-1, 1, d).astype(np.float32)).astype(np.float32)
    offset = rng.uniform(-s / 2, s / 2, d).astype(np.float32)
    z = model.encode_decode_roundtrip(
        x, xv, offset, np.array([s], np.float32), q=q
    )
    assert np.max(np.abs(np.asarray(z) - x)) <= s / 2 + 1e-5
