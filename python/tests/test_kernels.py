"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps dimensions, quantization levels, scales and offsets;
every property the Rust layer relies on is pinned here:

* encode/decode match ``ref.py`` exactly (same rounding mode),
* round-trip recovers the encoder's lattice point within the success
  radius (Lemma 15 / §9.1),
* FWHT is an orthonormal involution and matches the direct Hadamard
  definition.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lattice, ref

DIMS = st.sampled_from([4, 16, 60, 128, 256])
POW2_DIMS = st.sampled_from([4, 16, 64, 128, 512])
QS = st.sampled_from([2, 4, 8, 16, 64, 200])


def vec(rng, d, scale=10.0, center=0.0):
    return (center + scale * rng.standard_normal(d)).astype(np.float32)


@settings(max_examples=30, deadline=None)
@given(d=DIMS, q=QS, seed=st.integers(0, 2**32 - 1))
def test_encode_matches_ref(d, q, seed):
    rng = np.random.default_rng(seed)
    s = float(rng.uniform(0.05, 2.0))
    x = vec(rng, d, center=float(rng.uniform(-100, 100)))
    offset = (rng.uniform(-s / 2, s / 2, d)).astype(np.float32)
    c, k = lattice.lattice_encode(x, offset, np.array([s], np.float32), q=q)
    cr, kr = ref.lattice_encode_ref(x, offset, s, q)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(k), np.asarray(kr))
    # colors in range
    assert np.all(np.asarray(c) >= 0) and np.all(np.asarray(c) < q)


@settings(max_examples=30, deadline=None)
@given(d=DIMS, q=QS, seed=st.integers(0, 2**32 - 1))
def test_decode_matches_ref(d, q, seed):
    rng = np.random.default_rng(seed)
    s = float(rng.uniform(0.05, 2.0))
    x = vec(rng, d)
    xv = (x + rng.uniform(-s, s, d)).astype(np.float32)
    offset = (rng.uniform(-s / 2, s / 2, d)).astype(np.float32)
    sarr = np.array([s], np.float32)
    c, _ = lattice.lattice_encode(x, offset, sarr, q=q)
    z = lattice.lattice_decode(c, xv, offset, sarr, q=q)
    zr = ref.lattice_decode_ref(np.asarray(c), xv, offset, s, q)
    # f32 op-ordering differences between the Pallas kernel and the ref
    # (fma vs mul+add) leave ~1 ulp of noise; the decoded *lattice index*
    # must still agree exactly.
    np.testing.assert_allclose(np.asarray(z), np.asarray(zr), rtol=1e-6, atol=1e-5)
    k_kernel = np.round((np.asarray(z) - offset) / s)
    k_ref = np.round((np.asarray(zr) - offset) / s)
    np.testing.assert_array_equal(k_kernel, k_ref)


@settings(max_examples=25, deadline=None)
@given(d=DIMS, q=st.sampled_from([8, 16, 64]), seed=st.integers(0, 2**32 - 1))
def test_roundtrip_within_success_radius(d, q, seed):
    """Lemma 15 (practical form §9.1): if ‖x−xv‖∞ ≤ (q−1)s/2 the decoder
    recovers exactly the encoder's lattice point."""
    rng = np.random.default_rng(seed)
    s = float(rng.uniform(0.1, 1.0))
    radius = (q - 1) * s / 2.0
    x = vec(rng, d, center=float(rng.uniform(-50, 50)))
    xv = (x + rng.uniform(-radius, radius, d) * 0.999).astype(np.float32)
    offset = (rng.uniform(-s / 2, s / 2, d)).astype(np.float32)
    sarr = np.array([s], np.float32)
    c, k = lattice.lattice_encode(x, offset, sarr, q=q)
    z = lattice.lattice_decode(c, xv, offset, sarr, q=q)
    expected = offset + np.asarray(k) * s
    np.testing.assert_allclose(np.asarray(z), expected, atol=1e-5)
    # quantization error bounded by s/2 (+ f32 slack)
    assert np.max(np.abs(np.asarray(z) - x)) <= s / 2 + 1e-4


@settings(max_examples=20, deadline=None)
@given(d=POW2_DIMS, seed=st.integers(0, 2**32 - 1))
def test_fwht_involution_and_isometry(d, seed):
    rng = np.random.default_rng(seed)
    x = vec(rng, d)
    y = np.asarray(lattice.fwht(x))
    z = np.asarray(lattice.fwht(y))
    np.testing.assert_allclose(z, x, atol=1e-3)
    np.testing.assert_allclose(
        np.linalg.norm(y), np.linalg.norm(x), rtol=1e-5
    )


@settings(max_examples=20, deadline=None)
@given(d=POW2_DIMS, seed=st.integers(0, 2**32 - 1))
def test_rotate_fwd_inv_roundtrip(d, seed):
    rng = np.random.default_rng(seed)
    x = vec(rng, d, center=25.0)
    sign = rng.choice([-1.0, 1.0], d).astype(np.float32)
    y = lattice.rotate_fwd(x, sign)
    z = np.asarray(lattice.rotate_inv(y, sign))
    np.testing.assert_allclose(z, x, atol=1e-3)
    yr = np.asarray(ref.rotate_fwd_ref(x, sign))
    np.testing.assert_allclose(np.asarray(y), yr, atol=1e-4)


def test_fwht_matches_direct_hadamard():
    d = 8
    x = np.arange(d, dtype=np.float32)
    y = np.asarray(lattice.fwht(x))
    H = np.array(
        [[(-1) ** bin(i & j).count("1") for j in range(d)] for i in range(d)],
        np.float32,
    ) / np.sqrt(d)
    np.testing.assert_allclose(y, H @ x, atol=1e-5)


def test_fwht_rejects_non_power_of_two():
    with pytest.raises(AssertionError):
        lattice.fwht(np.zeros(12, np.float32))


def test_blocked_grid_path_matches_single_block():
    """d = 256 exercises the multi-block BlockSpec path of the encode
    kernel; it must agree with the oracle exactly."""
    rng = np.random.default_rng(0)
    d, q, s = 256, 16, 0.25
    x = vec(rng, d)
    offset = rng.uniform(-s / 2, s / 2, d).astype(np.float32)
    c, k = lattice.lattice_encode(x, offset, np.array([s], np.float32), q=q)
    cr, kr = ref.lattice_encode_ref(x, offset, s, q)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(k), np.asarray(kr))
