"""AOT pipeline: lowering to HLO text and manifest integrity."""

import json
import os
import subprocess
import sys

import jax

from compile import aot


def test_to_hlo_text_produces_parseable_module():
    cat = aot.graph_catalog()
    fn, specs, _ = cat["lattice_encode_d128_q8"]
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple=True ⇒ tuple-typed root
    assert "(f32[128]" in text.replace(" ", "")[:20000] or "tuple" in text


def test_catalog_covers_experiment_shapes():
    cat = aot.graph_catalog()
    required = [
        "lattice_encode_d128_q16",
        "lattice_decode_d128_q16",
        "rotate_d128",
        "unrotate_d128",
        "lsq_grad_s4096_d100",
        "power_update_s4096_d128",
        "mlp_grad_b128_f32_h64_c10",
        "me_round_n7_d128_q16",
    ]
    for name in required:
        assert name in cat, f"missing artifact spec {name}"


def test_existing_manifest_matches_catalog():
    """If `make artifacts` has run, the manifest on disk must agree with
    the current catalog (names, shapes)."""
    root = os.path.join(os.path.dirname(__file__), "..", "..")
    path = os.path.join(root, "artifacts", "manifest.json")
    if not os.path.exists(path):
        import pytest

        pytest.skip("artifacts not built")
    with open(path) as fh:
        manifest = json.load(fh)
    cat = aot.graph_catalog()
    by_name = {g["name"]: g for g in manifest["graphs"]}
    for name, (fn, specs, _params) in cat.items():
        assert name in by_name, f"{name} missing from manifest (re-run make artifacts)"
        g = by_name[name]
        assert g["inputs"] == [list(s.shape) for s in specs], name
        hlo = os.path.join(root, "artifacts", g["file"])
        assert os.path.exists(hlo), hlo


def test_aot_cli_subset(tmp_path):
    """Run the aot module end to end for one graph into a temp dir."""
    env = dict(os.environ)
    cwd = os.path.join(os.path.dirname(__file__), "..")
    out = tmp_path / "arts"
    res = subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--only",
            "lattice_encode_d128_q8",
        ],
        cwd=cwd,
        env=env,
        capture_output=True,
        text=True,
        timeout=300,
    )
    assert res.returncode == 0, res.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["graphs"][0]["name"] == "lattice_encode_d128_q8"
    assert (out / "lattice_encode_d128_q8.hlo.txt").exists()
