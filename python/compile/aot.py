"""AOT lowering: JAX (L2) + Pallas (L1) graphs -> HLO text artifacts.

Run once at build time (``make artifacts``); Python never appears on the
request path. Each graph in ``GRAPHS`` is jitted, lowered to stablehlo,
converted to an XlaComputation and dumped as **HLO text** plus a
``manifest.json`` entry describing its shapes and static parameters.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids, which xla_extension
0.5.1 (the version the Rust ``xla`` crate binds) rejects. The text parser
reassigns ids and round-trips cleanly.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), jnp.float32)


def graph_catalog():
    """The full artifact set, keyed by name.

    Returns {name: (fn, input_specs, params)}. Shapes are the ones used by
    the experiment drivers and examples (see DESIGN.md experiment index).
    """
    g = {}

    # LQSGD encode/decode, specialized per (d, q) used by the experiments.
    for d, q in [(128, 8), (128, 16), (128, 64), (256, 8), (1024, 16)]:
        g[f"lattice_encode_d{d}_q{q}"] = (
            model.encode_graph(q),
            [f32(d), f32(d), f32(1)],
            {"d": d, "q": q},
        )
        g[f"lattice_decode_d{d}_q{q}"] = (
            model.decode_graph(q),
            [f32(d), f32(d), f32(d), f32(1)],
            {"d": d, "q": q},
        )

    # RLQSGD rotation (standalone and fused pipelines).
    for d in [128, 256, 1024]:
        g[f"rotate_d{d}"] = (model.rotate_graph(), [f32(d), f32(d)], {"d": d})
        g[f"unrotate_d{d}"] = (model.unrotate_graph(), [f32(d), f32(d)], {"d": d})
    g["rotate_encode_d128_q8"] = (
        model.rotate_encode_graph(8),
        [f32(128), f32(128), f32(128), f32(1)],
        {"d": 128, "q": 8},
    )
    g["decode_unrotate_d128_q8"] = (
        model.decode_unrotate_graph(8),
        [f32(128), f32(128), f32(128), f32(128), f32(1)],
        {"d": 128, "q": 8},
    )

    # Least-squares batch gradients (Experiments 1-5).
    for s, d in [(4096, 100), (1024, 12), (512, 100)]:
        g[f"lsq_grad_s{s}_d{d}"] = (
            model.lsq_grad_graph(),
            [f32(s, d), f32(d), f32(s)],
            {"s": s, "d": d},
        )

    # Power iteration partial updates (Experiment 8).
    for s, d in [(4096, 128), (1024, 128)]:
        g[f"power_update_s{s}_d{d}"] = (
            model.power_update_graph(),
            [f32(s, d), f32(d)],
            {"s": s, "d": d},
        )

    # MLP training-step gradients (Experiment 7 analogue).
    b, f, h, c = 128, 32, 64, 10
    g["mlp_grad_b128_f32_h64_c10"] = (
        model.mlp_grad_graph(h, c),
        [f32(b, f), f32(b, c), f32(f, h), f32(h), f32(h, c), f32(c)],
        {"batch": b, "features": f, "hidden": h, "classes": c},
    )

    # Fused leader round for the star topology (Algorithm 3).
    g["me_round_n7_d128_q16"] = (
        model.mean_estimate_round_graph(16, 7),
        [f32(7, 128), f32(128), f32(128), f32(1)],
        {"n": 7, "d": 128, "q": 16},
    )

    return g


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated graph names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    catalog = graph_catalog()
    names = args.only.split(",") if args.only else sorted(catalog)

    manifest = {"format": "hlo-text", "graphs": []}
    for name in names:
        fn, specs, params = catalog[name]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as fh:
            fh.write(text)

        # Output shapes from an abstract evaluation.
        outs = jax.eval_shape(fn, *specs)
        out_shapes = [list(o.shape) for o in outs]
        manifest["graphs"].append(
            {
                "name": name,
                "file": fname,
                "inputs": [list(s.shape) for s in specs],
                "outputs": out_shapes,
                "params": params,
            }
        )
        print(f"  lowered {name}: {len(text)} chars")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
    print(f"wrote {len(manifest['graphs'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
