"""Layer-2 JAX compute graphs, calling the Layer-1 Pallas kernels.

Each function here is a complete graph that ``aot.py`` lowers to HLO text
for the Rust coordinator. Graphs are shape-specialized (PJRT AOT requires
static shapes); the specializations used by the experiments are listed in
``aot.py::GRAPHS`` and recorded in ``artifacts/manifest.json``.

Every graph takes and returns float32 arrays only (colors are small
integers carried as f32) so the Rust side needs a single literal type.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import lattice


def encode_graph(q):
    """(x[d], offset[d], s[1]) -> (color[d], k[d]) — LQSGD encode."""

    def f(x, offset, s):
        color, k = lattice.lattice_encode(x, offset, s, q=q)
        return (color, k)

    return f


def decode_graph(q):
    """(color[d], xv[d], offset[d], s[1]) -> (z[d]) — LQSGD decode."""

    def f(color, xv, offset, s):
        return (lattice.lattice_decode(color, xv, offset, s, q=q),)

    return f


def rotate_encode_graph(q):
    """RLQSGD fused pipeline: rotate by HD, then lattice-encode.

    (x[d], sign[d], offset[d], s[1]) -> (color[d], rx[d])
    ``rx`` (the rotated input) is returned so the caller can maintain its
    y_R estimate exactly as in Section 9.1.
    """

    def f(x, sign, offset, s):
        rx = lattice.rotate_fwd(x, sign)
        color, _k = lattice.lattice_encode(rx, offset, s, q=q)
        return (color, rx)

    return f


def decode_unrotate_graph(q):
    """RLQSGD fused decode: lattice-decode in rotated space, rotate back.

    (color[d], rxv[d], sign[d], offset[d], s[1]) -> (z[d], rz[d])
    ``rxv`` is the decoder's own vector already in rotated space.
    """

    def f(color, rxv, sign, offset, s):
        rz = lattice.lattice_decode(color, rxv, offset, s, q=q)
        z = lattice.rotate_inv(rz, sign)
        return (z, rz)

    return f


def rotate_graph():
    """(x[d], sign[d]) -> (H D x,) — standalone rotation."""

    def f(x, sign):
        return (lattice.rotate_fwd(x, sign),)

    return f


def unrotate_graph():
    """(y[d], sign[d]) -> (D^-1 H y,) — standalone inverse rotation."""

    def f(y, sign):
        return (lattice.rotate_inv(y, sign),)

    return f


def lsq_grad_graph():
    """(A[S,d], w[d], b[S]) -> (grad[d],) — least-squares batch gradient.

    The workhorse of experiments 1-5 (Section 9.2)."""

    def f(a, w, b):
        r = a @ w - b
        return ((2.0 / a.shape[0]) * (a.T @ r),)

    return f


def power_update_graph():
    """(X[S,d], v[d]) -> (u[d],) — power-iteration partial update (Exp 8)."""

    def f(x, v):
        return (x.T @ (x @ v),)

    return f


def mlp_grad_graph(hidden, classes):
    """Two-layer MLP grads for the NN-training experiment (Exp 7 analogue).

    (X[B,f], Y[B] one-hot as f32[B,C], W1[f,h], b1[h], W2[h,C], b2[C])
    -> (loss[1], gW1, gb1, gW2, gb2)   (softmax cross-entropy)
    """

    def loss_fn(params, xb, yb):
        w1, b1, w2, b2 = params
        z1 = jnp.tanh(xb @ w1 + b1)
        logits = z1 @ w2 + b2
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.sum(yb * logp, axis=1))

    def f(xb, yb, w1, b1, w2, b2):
        params = (w1, b1, w2, b2)
        loss, grads = jax.value_and_grad(loss_fn)(params, xb, yb)
        gw1, gb1, gw2, gb2 = grads
        return (loss.reshape(1), gw1, gb1, gw2, gb2)

    return f


def mean_estimate_round_graph(q, n):
    """Fused star-topology round at the leader (Algorithm 3, inner step).

    Decodes n worker colors against the leader's vector, averages with the
    leader's own input, and re-encodes the average for broadcast.

    (colors[n,d], x_leader[d], offset[d], s[1])
      -> (mu_color[d], mu_hat[d])
    """

    def f(colors, x_leader, offset, s):
        def dec(c):
            return lattice.lattice_decode(c, x_leader, offset, s, q=q)

        decoded = jax.vmap(dec)(colors)  # [n, d]
        mu_hat = (jnp.sum(decoded, axis=0) + x_leader) / jnp.float32(n + 1)
        mu_color, _ = lattice.lattice_encode(mu_hat, offset, s, q=q)
        return (mu_color, mu_hat)

    return f


# Convenience: jitted versions for the python test-suite.
lsq_grad = jax.jit(lsq_grad_graph())
power_update = jax.jit(power_update_graph())


@functools.partial(jax.jit, static_argnames=("q",))
def encode_decode_roundtrip(x, xv, offset, s, *, q):
    """encode at u, decode at v — used by tests for the Theorem-1 guarantee."""
    color, _ = lattice.lattice_encode(x, offset, s, q=q)
    return lattice.lattice_decode(color, xv, offset, s, q=q)
