"""Layer-1 Pallas kernels: the quantization hot-spot.

The paper's practical algorithm (Section 9.1) quantizes a d-dimensional
vector onto a randomly offset cubic lattice and transmits only the
coordinate-wise lattice index mod q. Encode, decode, and the RLQSGD
Walsh-Hadamard rotation are implemented here as Pallas kernels so that the
Layer-2 JAX graphs lower them into the same HLO module that the Rust
runtime executes.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper
evaluates on CPU/GPU clusters where quantization is bandwidth-bound. On
TPU the same structure applies — these kernels are elementwise/VPU work
tiled into VMEM blocks (``BLOCK`` lanes per grid step), with the FWHT
expressed as log2(d) in-VMEM butterfly stages instead of the
shared-memory butterflies a CUDA port would use. ``interpret=True``
everywhere: the CPU PJRT plugin cannot run Mosaic custom-calls, so the
kernels are lowered through the interpreter for correctness, and TPU
performance is estimated analytically from the BlockSpec (DESIGN.md
§Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM-friendly lane tile. 128 matches the TPU lane width; for the small
# experiment dimensions a single block is used (grid collapses to 1).
BLOCK = 128


def _num_blocks(d):
    return max(1, (d + BLOCK - 1) // BLOCK)


def _block_len(d):
    return min(d, BLOCK) if d % BLOCK == 0 or d < BLOCK else BLOCK


# ---------------------------------------------------------------------------
# Encode: color = round((x - offset)/s) mod q  (+ raw index k)
# ---------------------------------------------------------------------------


def _encode_kernel(x_ref, off_ref, s_ref, color_ref, k_ref, *, q):
    s = s_ref[0]
    t = (x_ref[...] - off_ref[...]) / s
    k = jnp.round(t)
    color_ref[...] = jnp.mod(k, jnp.float32(q)).astype(jnp.float32)
    k_ref[...] = k.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("q",))
def lattice_encode(x, offset, s, *, q):
    """Pallas cubic-lattice encode. x, offset: f32[d]; s: f32[1].

    Returns (color f32[d], k f32[d]). The color is the transmitted message
    (d * log2(q) bits); k is kept for diagnostics / variance accounting.
    """
    d = x.shape[0]
    if d % BLOCK == 0 and d > BLOCK:
        grid = (d // BLOCK,)
        spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    else:
        grid = (1,)
        spec = pl.BlockSpec((d,), lambda i: (0,))
    sspec = pl.BlockSpec((1,), lambda i: (0,))
    out_shape = [
        jax.ShapeDtypeStruct((d,), jnp.float32),
        jax.ShapeDtypeStruct((d,), jnp.float32),
    ]
    return pl.pallas_call(
        functools.partial(_encode_kernel, q=q),
        grid=grid,
        in_specs=[spec, spec, sspec],
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=True,
    )(x, offset, s)


# ---------------------------------------------------------------------------
# Decode: nearest lattice point to xv whose index ≡ color (mod q)
# ---------------------------------------------------------------------------


def _decode_kernel(color_ref, xv_ref, off_ref, s_ref, z_ref, *, q):
    s = s_ref[0]
    t = (xv_ref[...] - off_ref[...]) / s
    c = color_ref[...]
    m = jnp.round((t - c) / jnp.float32(q))
    k = c + jnp.float32(q) * m
    z_ref[...] = (off_ref[...] + k * s).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("q",))
def lattice_decode(color, xv, offset, s, *, q):
    """Pallas cubic-lattice decode. Returns f32[d] decoded vector."""
    d = xv.shape[0]
    if d % BLOCK == 0 and d > BLOCK:
        grid = (d // BLOCK,)
        spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    else:
        grid = (1,)
        spec = pl.BlockSpec((d,), lambda i: (0,))
    sspec = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        functools.partial(_decode_kernel, q=q),
        grid=grid,
        in_specs=[spec, spec, spec, sspec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,
    )(color, xv, offset, s)


# ---------------------------------------------------------------------------
# FWHT rotation (RLQSGD): one whole-vector block, log2(d) butterfly stages
# ---------------------------------------------------------------------------


def _fwht_kernel(x_ref, o_ref, *, d):
    y = x_ref[...]
    h = 1
    while h < d:
        y = y.reshape(d // (2 * h), 2, h)
        a = y[:, 0, :]
        b = y[:, 1, :]
        y = jnp.stack([a + b, a - b], axis=1)
        h *= 2
    o_ref[...] = (y.reshape(d) / jnp.sqrt(jnp.float32(d))).astype(jnp.float32)


def _rotate_fwd_kernel(x_ref, sign_ref, o_ref, *, d):
    tmp = x_ref[...] * sign_ref[...]
    y = tmp
    h = 1
    while h < d:
        y = y.reshape(d // (2 * h), 2, h)
        a = y[:, 0, :]
        b = y[:, 1, :]
        y = jnp.stack([a + b, a - b], axis=1)
        h *= 2
    o_ref[...] = (y.reshape(d) / jnp.sqrt(jnp.float32(d))).astype(jnp.float32)


def _rotate_inv_kernel(y_ref, sign_ref, o_ref, *, d):
    y = y_ref[...]
    h = 1
    while h < d:
        y = y.reshape(d // (2 * h), 2, h)
        a = y[:, 0, :]
        b = y[:, 1, :]
        y = jnp.stack([a + b, a - b], axis=1)
        h *= 2
    o_ref[...] = (y.reshape(d) / jnp.sqrt(jnp.float32(d)) * sign_ref[...]).astype(
        jnp.float32
    )


def _whole_vec_call(kernel, d, n_in):
    spec = pl.BlockSpec((d,), lambda: (0,))
    return pl.pallas_call(
        functools.partial(kernel, d=d),
        in_specs=[spec] * n_in,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,
    )


@jax.jit
def fwht(x):
    """Normalized Walsh-Hadamard transform (Pallas). d must be a power of 2."""
    d = x.shape[0]
    assert d & (d - 1) == 0, "FWHT requires power-of-two dimension"
    return _whole_vec_call(_fwht_kernel, d, 1)(x)


@jax.jit
def rotate_fwd(x, sign):
    """RLQSGD rotation H @ (sign * x) as a single fused Pallas kernel."""
    d = x.shape[0]
    assert d & (d - 1) == 0
    return _whole_vec_call(_rotate_fwd_kernel, d, 2)(x, sign)


@jax.jit
def rotate_inv(y, sign):
    """Inverse rotation sign * (H @ y) as a single fused Pallas kernel."""
    d = y.shape[0]
    assert d & (d - 1) == 0
    return _whole_vec_call(_rotate_inv_kernel, d, 2)(y, sign)
