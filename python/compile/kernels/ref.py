"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground-truth implementations of the paper's cubic-lattice
quantization primitives (Davies et al., ICLR 2021, Section 9.1) and the
structured random rotation (Section 6). The Pallas kernels in
``lattice.py`` must match these bit-for-bit under ``interpret=True``;
``python/tests`` enforces that with hypothesis sweeps.

Conventions shared with the Rust layer (``rust/src/quant``):

* The cubic lattice has side length ``s`` and a shared-randomness offset
  ``offset`` (one uniform draw per coordinate in ``[-s/2, s/2)``).
* ``encode`` rounds to the nearest lattice point with round-half-to-even
  (matching ``jnp.round`` and Rust's ``round_ties_even``), then sends the
  coordinate-wise lattice index mod ``q`` — the *color*.
* ``decode`` recovers, among lattice points of that color, the one closest
  to the decoder's own vector.
"""

import jax.numpy as jnp


def lattice_encode_ref(x, offset, s, q):
    """Cubic-lattice encode: returns (color, k) as float32.

    ``k``     — per-coordinate lattice index, k = round((x - offset)/s)
    ``color`` — k mod q, the d*log2(q)-bit message actually transmitted.
    """
    t = (x - offset) / s
    k = jnp.round(t)
    color = jnp.mod(k, q)
    return color.astype(jnp.float32), k.astype(jnp.float32)


def lattice_decode_ref(color, xv, offset, s, q):
    """Cubic-lattice decode: nearest lattice point to ``xv`` with ``color``.

    Among k ≡ color (mod q), the closest to t = (xv-offset)/s is
    k = color + q * round((t - color)/q).
    """
    t = (xv - offset) / s
    m = jnp.round((t - color) / q)
    k = color + q * m
    return (offset + k * s).astype(jnp.float32)


def fwht_ref(x):
    """Normalized fast Walsh-Hadamard transform (d must be a power of two)."""
    d = x.shape[-1]
    h = 1
    y = x.astype(jnp.float32).reshape(1, d)
    while h < d:
        y = y.reshape(-1, d // (2 * h), 2, h)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.stack([a + b, a - b], axis=2)
        h *= 2
    y = y.reshape(x.shape)
    return y / jnp.sqrt(jnp.float32(d))


def rotate_fwd_ref(x, sign):
    """RLQSGD forward rotation: H @ (sign * x)."""
    return fwht_ref(x * sign)


def rotate_inv_ref(y, sign):
    """RLQSGD inverse rotation: sign * (H @ y) (H is an involution)."""
    return sign * fwht_ref(y)


def qsgd_encode_ref(x, norm, levels, u):
    """QSGD stochastic quantization oracle (baseline, Alistarh et al. 2017).

    Quantizes x/norm onto the grid {0, 1/levels, ..., 1} with stochastic
    rounding driven by pre-drawn uniforms ``u``; returns the reconstructed
    vector (sign * norm * level / levels).
    """
    scaled = jnp.abs(x) / norm * levels
    low = jnp.floor(scaled)
    prob = scaled - low
    level = low + (u < prob).astype(jnp.float32)
    return jnp.sign(x) * norm * level / levels


def lsq_grad_ref(a, w, b):
    """Least-squares batch gradient: (2/S) A^T (A w - b)."""
    r = a @ w - b
    return (2.0 / a.shape[0]) * (a.T @ r)


def power_update_ref(x_rows, v):
    """Distributed power-iteration partial update: u_i = X_i^T (X_i v)."""
    return x_rows.T @ (x_rows @ v)
