"""Layer-1 Pallas kernels (``lattice``) and their pure-jnp oracle (``ref``)."""
