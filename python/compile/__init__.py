"""Build-time compile path: L1 Pallas kernels + L2 JAX graphs + AOT lowering.

Never imported at runtime — ``make artifacts`` runs ``compile.aot`` once
and the Rust binary consumes only ``artifacts/*.hlo.txt``.
"""
