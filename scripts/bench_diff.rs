//! Diff two `BENCH_<name>.json` summaries (schema v1, emitted by every
//! bench target via `dme::bench::Bencher::write_json` — see
//! `rust/benches/README.md`): per-case old vs new median ns/op and the
//! relative delta, plus cases added or removed between the runs. This is
//! how the perf trajectory across PRs gets populated — CI uploads the
//! smoke-run JSONs as artifacts, so any two runs are one command apart:
//!
//! ```text
//! cargo bench-diff old/BENCH_quant_bench.json BENCH_quant_bench.json
//! cargo bench-diff --fail-above 10 old.json new.json   # CI gate form
//! ```
//!
//! `--fail-above <pct>` exits non-zero if any case regressed by more
//! than `<pct>` percent (median ns/op). Without it the diff is purely
//! informational. Smoke-run JSONs (`iters = 1`) carry meaningless
//! timings — diff them only to check the case inventory.

use dme::config::Json;
use std::collections::BTreeMap;
use std::process::exit;

/// name → median ns/op for every case of one summary file.
fn load(path: &str) -> BTreeMap<String, f64> {
    let src = match std::fs::read_to_string(path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("bench_diff: cannot read {path}: {e}");
            exit(2);
        }
    };
    let json = match Json::parse(&src) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("bench_diff: {path} is not valid JSON: {e:?}");
            exit(2);
        }
    };
    let Some(cases) = json.get("cases").and_then(|c| c.as_arr()) else {
        eprintln!("bench_diff: {path} has no `cases` array (schema v1 expected)");
        exit(2);
    };
    let mut out = BTreeMap::new();
    for case in cases {
        let (Some(name), Some(median)) = (
            case.get("name").and_then(|n| n.as_str()),
            case.get("median_ns").and_then(|m| m.as_f64()),
        ) else {
            eprintln!("bench_diff: {path}: case without name/median_ns");
            exit(2);
        };
        out.insert(name.to_string(), median);
    }
    out
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let mut fail_above: Option<f64> = None;
    if let Some(pos) = args.iter().position(|a| a == "--fail-above") {
        if pos + 1 >= args.len() {
            eprintln!("bench_diff: --fail-above needs a percentage");
            exit(2);
        }
        fail_above = args[pos + 1].parse().ok();
        if fail_above.is_none() {
            eprintln!("bench_diff: bad --fail-above value {:?}", args[pos + 1]);
            exit(2);
        }
        args.drain(pos..=pos + 1);
    }
    let [old_path, new_path] = args.as_slice() else {
        eprintln!("usage: bench_diff [--fail-above <pct>] <old.json> <new.json>");
        exit(2);
    };
    let old = load(old_path);
    let new = load(new_path);

    println!("# bench diff: {old_path} → {new_path}\n");
    println!("{:<46} {:>12} {:>12} {:>9}", "case", "old", "new", "delta");
    let mut worst: f64 = f64::NEG_INFINITY;
    for (name, new_ns) in &new {
        match old.get(name) {
            Some(old_ns) => {
                let pct = (new_ns - old_ns) / old_ns * 100.0;
                worst = worst.max(pct);
                println!(
                    "{:<46} {:>12} {:>12} {:>+8.1}%",
                    name,
                    fmt_ns(*old_ns),
                    fmt_ns(*new_ns),
                    pct
                );
            }
            None => println!("{:<46} {:>12} {:>12}    (new)", name, "-", fmt_ns(*new_ns)),
        }
    }
    for name in old.keys().filter(|n| !new.contains_key(*n)) {
        println!("{name:<46} (removed)");
    }
    let matched = new.keys().filter(|n| old.contains_key(*n)).count();
    println!(
        "\n{} matched, {} new, {} removed{}",
        matched,
        new.len() - matched,
        old.len() - matched,
        if matched > 0 && worst.is_finite() {
            format!("; worst regression {worst:+.1}%")
        } else {
            String::new()
        }
    );
    if let Some(limit) = fail_above {
        if worst.is_finite() && worst > limit {
            eprintln!("bench_diff: regression {worst:+.1}% exceeds --fail-above {limit}%");
            exit(1);
        }
    }
}
