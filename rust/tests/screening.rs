//! Property tests over the report-screening contract
//! ([`dme::net::screen`] + [`dme::net::cohort`]).
//!
//! The pinned guarantees, exercised here under seeded adversarial bit
//! patterns (same harness idiom as `tests/prop.rs` — the offline
//! toolchain has no `proptest`, so failures print a `CASE_SEED`):
//!
//! - **no decode path panics or folds a non-finite value**: for every
//!   stateless codec, a correctly-sized frame of arbitrary bytes is
//!   either folded to all-finite values or quarantined — never a panic,
//!   never NaN/Inf in the accumulator;
//! - **quarantine is bit-invisible**: a quarantined report leaves the
//!   round's estimate bit-identical to a run where it never arrived,
//!   and leaves a durable table's WAL byte-for-byte untouched;
//! - **short frames shed before decode** and a shed first report rolls
//!   the freshly-opened round back (no empty open rounds to pin).

use dme::coordinator::CodecSpec;
use dme::net::cohort::{
    client_encoder_rng, cohort_codec, CohortKey, CohortSpec, CohortTable, Submit,
};
use dme::net::screen::{RoundScreen, ScreenMode};
use dme::quant::Message;
use dme::rng::{hash2, Rng};
use dme::store::DurabilityOpts;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Run `prop` over `cases` generated cases; panics with the case seed.
fn check(name: &str, cases: u64, prop: impl Fn(&mut Rng)) {
    let base = std::env::var("CASE_SEED")
        .ok()
        .and_then(|s| s.parse().ok());
    match base {
        Some(seed) => {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        }
        None => {
            for case in 0..cases {
                let seed = hash2(0x5C4E, case);
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let mut rng = Rng::new(seed);
                    prop(&mut rng);
                }));
                if let Err(e) = result {
                    panic!("property '{name}' failed at CASE_SEED={seed}: {e:?}");
                }
            }
        }
    }
}

/// Every codec a stateless cohort can serve (the screen's domain).
fn stateless_codecs() -> [CodecSpec; 10] {
    [
        CodecSpec::Lq { q: 64 },
        CodecSpec::Rlq { q: 16 },
        CodecSpec::LqHull { q: 8 },
        CodecSpec::D4 { q: 16 },
        CodecSpec::QsgdL2 { q: 16 },
        CodecSpec::QsgdLinf { q: 16 },
        CodecSpec::Hadamard { q: 16 },
        CodecSpec::Vqsgd { reps: 6 },
        CodecSpec::TernGrad,
        CodecSpec::Full,
    ]
}

fn spec(codec: CodecSpec, d: usize) -> CohortSpec {
    CohortSpec {
        n: 2,
        d,
        spec: codec,
        y: 8.0,
        seed: 5,
    }
}

fn encode(cs: &CohortSpec, round: u64, client: usize, x: &[f64]) -> Message {
    let mut codec = cohort_codec(cs, round);
    let mut rng = client_encoder_rng(cs.seed, round, client);
    codec.encode(x, &mut rng)
}

fn rand_bytes(rng: &mut Rng, len: usize) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(len + 8);
    while bytes.len() < len {
        bytes.extend_from_slice(&rng.next_u64().to_le_bytes());
    }
    bytes.truncate(len);
    bytes
}

fn rand_input(rng: &mut Rng, d: usize, y: f64) -> Vec<f64> {
    (0..d).map(|_| rng.uniform(-y / 2.0, y / 2.0)).collect()
}

/// Hostile `Full`-codec payload at the exact probe size: `d` raw f32s.
fn f32_payload(d: usize, v: f32) -> Message {
    let mut bytes = Vec::new();
    for _ in 0..d {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    Message { bits: 32 * d as u64, bytes }
}

/// Fresh per-test scratch dir (no `Date::now` — counter + pid).
fn temp_dir(tag: &str) -> PathBuf {
    static N: AtomicUsize = AtomicUsize::new(0);
    let n = N.fetch_add(1, Ordering::Relaxed);
    let dir = std::env::temp_dir().join(format!("dme-screen-{}-{tag}-{n}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// For every stateless codec: a frame of adversarial bytes at the exact
/// probe size either folds to finite values or is quarantined. No panic
/// reaches the caller, and the accumulator never goes non-finite — the
/// leader survives arbitrary hostile payloads.
#[test]
fn prop_adversarial_bit_patterns_never_panic_or_fold_nonfinite() {
    for codec in stateless_codecs() {
        let name = format!("adversarial_bits[{}]", codec.label());
        check(&name, 40, |rng| {
            let cs = spec(codec, 16);
            let key = CohortKey {
                cohort: 1,
                round: rng.next_below(4),
            };
            let probe = RoundScreen::probe(&cs, key.round);
            let hostile = Message {
                bytes: rand_bytes(rng, probe.expect_len),
                bits: probe.expect_bits,
            };
            let mut table = CohortTable::new();
            table.set_screen(ScreenMode::Basic);
            let accepted = match table.submit(key, &cs, 0, &hostile, 0, 100) {
                Submit::Pending { received, expected } => {
                    assert_eq!((received, expected), (1, 2));
                    true
                }
                Submit::Quarantined(why) => {
                    assert!(why.contains("quarantined"), "unexpected reason: {why}");
                    false
                }
                other => panic!("{}: unexpected {other:?}", cs.spec.label()),
            };
            // The honest report still lands; the closed round's estimate
            // must be all-finite whether the hostile bytes folded or not.
            let honest = encode(&cs, key.round, 1, &rand_input(rng, cs.d, cs.y));
            let result = match table.submit(key, &cs, 1, &honest, 0, 100) {
                Submit::Complete(r) => {
                    assert!(accepted, "round completed without the hostile fold");
                    r
                }
                Submit::Pending { received, .. } => {
                    assert!(!accepted);
                    assert_eq!(received, 1);
                    let closed = table.expire(1_000);
                    assert_eq!(closed.len(), 1);
                    closed.into_iter().next().expect("one round closed").1
                }
                other => panic!("{}: unexpected {other:?}", cs.spec.label()),
            };
            assert_eq!(result.estimate.len(), cs.d);
            for &v in &result.estimate {
                assert!(v.is_finite(), "{}: non-finite fold {v}", cs.spec.label());
            }
            assert_eq!(table.open_rounds(), 0);
        });
    }
}

/// A quarantined report is bit-invisible: the attacked round's estimate
/// equals, bit for bit, the estimate of a round the poison never
/// reached. Poison is injected at a random position relative to the
/// honest reports.
#[test]
fn prop_quarantined_reports_are_bit_invisible_to_the_estimate() {
    check("quarantine_bit_invisible", 120, |rng| {
        let d = [1, 3, 8, 16, 33][rng.next_below(5) as usize];
        let cs = spec(CodecSpec::Full, d);
        let key = CohortKey { cohort: 2, round: 1 };
        let honest: Vec<Message> = (0..2)
            .map(|c| encode(&cs, key.round, c, &rand_input(rng, d, cs.y)))
            .collect();
        // Hostile payload at the exact probe size: raw f32 fields, NaN
        // or far-but-finite (caught by Basic resp. Distance).
        let poison = f32_payload(d, if rng.next_bool() { f32::NAN } else { 1.0e30 });
        let inject_first = rng.next_bool();

        let mut reference = CohortTable::new();
        reference.set_screen(ScreenMode::Distance);
        let mut attacked = CohortTable::new();
        attacked.set_screen(ScreenMode::Distance);

        let complete = |table: &mut CohortTable, poisoned: bool| {
            if poisoned && inject_first {
                assert!(matches!(
                    table.submit(key, &cs, 1, &poison, 0, 100),
                    Submit::Quarantined(_)
                ));
            }
            assert!(matches!(
                table.submit(key, &cs, 0, &honest[0], 0, 100),
                Submit::Pending { .. }
            ));
            if poisoned && !inject_first {
                assert!(matches!(
                    table.submit(key, &cs, 1, &poison, 0, 100),
                    Submit::Quarantined(_)
                ));
            }
            match table.submit(key, &cs, 1, &honest[1], 0, 100) {
                Submit::Complete(r) => r,
                other => panic!("expected Complete, got {other:?}"),
            }
        };
        let want = complete(&mut reference, false);
        let got = complete(&mut attacked, true);
        let want_bits: Vec<u64> = want.estimate.iter().map(|v| v.to_bits()).collect();
        let got_bits: Vec<u64> = got.estimate.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got_bits, want_bits, "quarantine perturbed the fold");
        assert_eq!((got.received, got.expected, got.partial), (2, 2, false));
        let s = attacked.stats()[0];
        assert_eq!((s.quarantined, s.shed), (1, 0));
    });
}

/// Quarantined and shed reports never touch a durable table's WAL: the
/// log stays byte-for-byte identical across the hostile submissions,
/// and the recovered estimate matches a clean in-RAM reference.
#[test]
fn quarantined_and_shed_reports_leave_the_wal_untouched() {
    let dir = temp_dir("wal");
    let cs = spec(CodecSpec::Full, 8);
    let key = CohortKey { cohort: 3, round: 0 };
    let honest: Vec<Message> = (0..2)
        .map(|c| encode(&cs, key.round, c, &[0.5 + c as f64; 8]))
        .collect();
    let (mut table, _) = CohortTable::durable(&DurabilityOpts::new(&dir)).expect("durable table");
    table.set_screen(ScreenMode::Distance);
    assert!(matches!(
        table.submit(key, &cs, 0, &honest[0], 0, 1000),
        Submit::Pending { .. }
    ));
    let wal_before = table.wal_bytes().expect("durable table logs a WAL");
    // NaN poison (quarantined after decode) and a truncated frame (shed
    // before decode): neither may grow the log.
    let poison = f32_payload(8, f32::NAN);
    assert!(matches!(
        table.submit(key, &cs, 1, &poison, 0, 1000),
        Submit::Quarantined(_)
    ));
    let mut short = honest[1].clone();
    short.bytes.pop();
    short.bits = 8 * short.bytes.len() as u64;
    assert!(matches!(
        table.submit(key, &cs, 1, &short, 0, 1000),
        Submit::Shed { .. }
    ));
    assert_eq!(
        table.wal_bytes().expect("durable table logs a WAL"),
        wal_before,
        "hostile reports reached the WAL"
    );
    // The honest completion still matches a clean in-RAM reference.
    let got = match table.submit(key, &cs, 1, &honest[1], 0, 1000) {
        Submit::Complete(r) => r,
        other => panic!("expected Complete, got {other:?}"),
    };
    let mut clean = CohortTable::new();
    assert!(matches!(
        clean.submit(key, &cs, 0, &honest[0], 0, 1000),
        Submit::Pending { .. }
    ));
    let want = match clean.submit(key, &cs, 1, &honest[1], 0, 1000) {
        Submit::Complete(r) => r,
        other => panic!("expected Complete, got {other:?}"),
    };
    assert_eq!(got.estimate, want.estimate);
    let s = table.stats()[0];
    assert_eq!((s.reports, s.quarantined, s.shed), (2, 1, 1));
    let _ = std::fs::remove_dir_all(&dir);
}

/// For every stateless codec: a frame truncated by 1–3 bytes is shed
/// before any decode, and a shed *first* report rolls the fresh round
/// back — hostile traffic cannot pin empty open rounds.
#[test]
fn prop_short_frames_shed_before_decode_and_roll_back_fresh_rounds() {
    for codec in stateless_codecs() {
        let name = format!("short_frames[{}]", codec.label());
        check(&name, 20, |rng| {
            let cs = spec(codec, 16);
            let key = CohortKey { cohort: 4, round: 0 };
            let mut short = encode(&cs, key.round, 0, &rand_input(rng, cs.d, cs.y));
            let cut = (1 + rng.next_below(3) as usize).min(short.bytes.len());
            short.bytes.truncate(short.bytes.len() - cut);
            short.bits = 8 * short.bytes.len() as u64;
            let mut table = CohortTable::new();
            table.set_screen(ScreenMode::Basic);
            match table.submit(key, &cs, 0, &short, 0, 1000) {
                Submit::Shed { reason, retry_after_ms } => {
                    assert!(reason.contains("screened"), "unexpected reason: {reason}");
                    assert!(retry_after_ms > 0);
                }
                other => panic!("{}: expected Shed, got {other:?}", cs.spec.label()),
            }
            assert_eq!(table.open_rounds(), 0, "{}: empty round pinned", cs.spec.label());
            let s = table.stats()[0];
            assert_eq!((s.shed, s.open_rounds), (1, 0));
            // Honest traffic afterwards is unaffected.
            let m0 = encode(&cs, key.round, 0, &rand_input(rng, cs.d, cs.y));
            let m1 = encode(&cs, key.round, 1, &rand_input(rng, cs.d, cs.y));
            assert!(matches!(
                table.submit(key, &cs, 0, &m0, 0, 1000),
                Submit::Pending { .. }
            ));
            assert!(matches!(
                table.submit(key, &cs, 1, &m1, 0, 1000),
                Submit::Complete(_)
            ));
        });
    }
}
