//! Transport-layer integration suite.
//!
//! The load-bearing claims pinned here:
//!
//! 1. **Loopback-TCP parity** — `star_round_over` / `vr_round_over` run
//!    over a real `127.0.0.1` mesh produce bit-identical estimates,
//!    leader diagnostics *and per-machine metered traffic* to the same
//!    code over the in-process channel reference, and to the
//!    `DmeSession` in-process round at the same `(seed, round, y)`.
//! 2. **Service correctness** — a partial k-of-n round renormalizes by
//!    `1/k` and matches a hand-computed decode-and-average reference
//!    exactly; malformed bytes get a typed error response, never a
//!    panic or a desynchronized accept loop.
//! 3. **Scale** — one service process multiplexes 256 concurrent open
//!    cohort rounds, closing dropout cohorts at their deadline with the
//!    renormalized partial mean and full cohorts with the k = n mean.

use dme::coordinator::{
    star_round_over, star_round_partial_over, vr_round_over, CodecSpec, DmeBuilder,
    PartialRoundReport, StarRoundReport, StragglerPolicy,
};
use dme::net::cohort::{client_encoder_rng, cohort_codec, CohortSpec};
use dme::net::faulty::{FaultPlan, FaultyTransport};
use dme::net::service::{fetch_stats, report_round, serve, EstimateOut, ServeOpts};
use dme::net::tcp::{LoopbackMesh, TcpOpts};
use dme::net::wire::{read_response, write_request, Request, Response};
use dme::net::{Traffic, Transport};
use dme::rng::Rng;
use dme::sim::Cluster;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::thread;
use std::time::{Duration, Instant};

fn gen_inputs(n: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..d).map(|_| 5.0 + rng.uniform(-0.4, 0.4)).collect())
        .collect()
}

/// Drive `rounds` star (or VR, when `sigma_alpha` is set) rounds over
/// every endpoint of a transport, one thread per machine — the exact
/// same protocol code regardless of transport. Returns per-machine
/// round reports and final traffic snapshots, in machine order.
#[allow(clippy::too_many_arguments)]
fn run_rounds<T>(
    transport: &mut T,
    spec: CodecSpec,
    seed: u64,
    y: f64,
    rounds: u64,
    inputs: &[Vec<f64>],
    collect: bool,
    sigma_alpha: Option<(f64, f64)>,
) -> (Vec<Vec<StarRoundReport>>, Vec<Traffic>)
where
    T: Transport,
    T::Endpoint: 'static,
{
    let eps = transport.open().expect("open transport");
    let handles: Vec<_> = eps
        .into_iter()
        .zip(inputs.to_vec())
        .map(|(mut ep, x)| {
            thread::spawn(move || {
                let reports: Vec<StarRoundReport> = (0..rounds)
                    .map(|r| match sigma_alpha {
                        None => star_round_over(&mut ep, spec, seed, r, y, &x, collect)
                            .expect("star round"),
                        Some((sigma, alpha)) => {
                            vr_round_over(&mut ep, spec, seed, r, sigma, alpha, &x, collect)
                                .expect("vr round")
                        }
                    })
                    .collect();
                let t = ep.traffic();
                (reports, t)
            })
        })
        .collect();
    let mut reports = Vec::new();
    let mut traffic = Vec::new();
    for h in handles {
        let (r, t) = h.join().expect("machine thread");
        reports.push(r);
        traffic.push(t);
    }
    (reports, traffic)
}

fn assert_reports_identical(a: &[Vec<StarRoundReport>], b: &[Vec<StarRoundReport>]) {
    assert_eq!(a.len(), b.len());
    for (m, (ra, rb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ra.len(), rb.len());
        for (r, (x, y)) in ra.iter().zip(rb).enumerate() {
            assert_eq!(x.leader, y.leader, "machine {m} round {r}: leader");
            assert_eq!(x.output, y.output, "machine {m} round {r}: estimate");
            assert_eq!(x.spread, y.spread, "machine {m} round {r}: spread");
            assert_eq!(
                x.decoded_at_leader, y.decoded_at_leader,
                "machine {m} round {r}: leader diagnostics"
            );
        }
    }
}

/// Tentpole parity: the identical protocol body over in-process channels
/// and over a loopback TCP mesh — estimates, diagnostics and metered
/// bits all bit-identical, and both equal to the in-process session.
#[test]
fn loopback_tcp_star_round_matches_in_process_bit_for_bit() {
    let (n, d, seed, y) = (5, 48, 41, 1.0);
    let spec = CodecSpec::Lq { q: 32 };
    let inputs = gen_inputs(n, d, 7);

    let mut cluster = Cluster::new(n);
    let (sim_reports, sim_traffic) =
        run_rounds(&mut cluster, spec, seed, y, 3, &inputs, true, None);

    let mut mesh = LoopbackMesh::new(n, &TcpOpts::default()).expect("mesh up");
    let (tcp_reports, tcp_traffic) = run_rounds(&mut mesh, spec, seed, y, 3, &inputs, true, None);

    assert_reports_identical(&sim_reports, &tcp_reports);
    assert_eq!(sim_traffic, tcp_traffic, "metered per-machine traffic");
    // Transport::traffic agrees with what the endpoints reported.
    assert_eq!(cluster.traffic(), sim_traffic);
    assert_eq!(mesh.traffic(), tcp_traffic);
    // All machines agree within a round, and the leader collected n
    // decoded vectors (collect=true).
    for round in 0..3 {
        let est = &sim_reports[0][round].output;
        for m in 1..n {
            assert_eq!(&sim_reports[m][round].output, est);
        }
        let leader = sim_reports[0][round].leader;
        assert_eq!(sim_reports[leader][round].decoded_at_leader.len(), n);
        assert!(sim_reports[leader][round].spread.is_some());
    }

    // The extracted public round equals the session's in-process round.
    let mut sess = DmeBuilder::new(n, d).codec(spec).seed(seed).build();
    let out = sess.round_with_y(&inputs, y);
    assert_eq!(
        out.estimate, sim_reports[0][0].output,
        "star_round_over must reproduce the session round"
    );
}

#[test]
fn loopback_tcp_vr_round_matches_in_process_bit_for_bit() {
    let (n, d, seed) = (4, 32, 99);
    let spec = CodecSpec::Lq { q: 64 };
    let (sigma, alpha) = (0.5, 4.0);
    let inputs = gen_inputs(n, d, 13);

    let mut cluster = Cluster::new(n);
    let (sim_reports, sim_traffic) =
        run_rounds(&mut cluster, spec, seed, 0.0, 2, &inputs, false, Some((sigma, alpha)));

    let mut mesh = LoopbackMesh::new(n, &TcpOpts::default()).expect("mesh up");
    let (tcp_reports, tcp_traffic) =
        run_rounds(&mut mesh, spec, seed, 0.0, 2, &inputs, false, Some((sigma, alpha)));

    assert_reports_identical(&sim_reports, &tcp_reports);
    assert_eq!(sim_traffic, tcp_traffic, "metered per-machine traffic");
}

fn spawn_server(opts: ServeOpts) -> (String, thread::JoinHandle<dme::net::service::ServeSummary>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
    let addr = listener.local_addr().expect("local addr").to_string();
    let h = thread::spawn(move || serve(listener, opts).expect("serve"));
    (addr, h)
}

/// Decode-and-average reference for a cohort round, built from the same
/// shared convention the clients and server use. Fold order is the
/// submission order; for k = 2 the sum is order-independent exactly
/// (two-term IEEE addition is commutative).
fn reference_mean(cs: &CohortSpec, round: u64, reports: &[(usize, &[f64])]) -> Vec<f64> {
    let codec = cohort_codec(cs, round);
    let zeros = vec![0.0; cs.d];
    let mut acc = vec![0.0; cs.d];
    for &(client, x) in reports {
        let mut rng = client_encoder_rng(cs.seed, round, client);
        let mut enc = cohort_codec(cs, round);
        let msg = enc.encode(x, &mut rng);
        codec.decode_accumulate_into(&msg, &zeros, 1.0, &mut acc);
    }
    let inv_k = 1.0 / reports.len() as f64;
    acc.iter().map(|&a| inv_k * a).collect()
}

/// Satellite: k-of-n partial participation over real TCP — 2 of 4
/// clients report, the deadline closes the round, and the delivered
/// estimate equals the hand-computed renormalized reference exactly.
#[test]
fn service_partial_round_matches_hand_computed_reference() {
    let (addr, server) = spawn_server(ServeOpts {
        max_rounds: Some(1),
        ..ServeOpts::default()
    });
    let cs = CohortSpec {
        n: 4,
        d: 12,
        spec: CodecSpec::Lq { q: 64 },
        y: 8.0,
        seed: 3,
    };
    let x0 = vec![3.5; 12];
    let x2 = vec![-1.5; 12];
    let reporters: Vec<_> = [(0usize, x0.clone()), (2usize, x2.clone())]
        .into_iter()
        .map(|(client, x)| {
            let addr = addr.clone();
            thread::spawn(move || {
                report_round(
                    &addr,
                    8,
                    1,
                    client,
                    &CohortSpec {
                        n: 4,
                        d: 12,
                        spec: CodecSpec::Lq { q: 64 },
                        y: 8.0,
                        seed: 3,
                    },
                    &x,
                    300,
                    Duration::from_secs(20),
                )
                .expect("report")
            })
        })
        .collect();
    let outs: Vec<EstimateOut> = reporters.into_iter().map(|h| h.join().unwrap()).collect();
    let summary = server.join().unwrap();

    let want = reference_mean(&cs, 1, &[(0, &x0), (2, &x2)]);
    for out in &outs {
        assert_eq!(out.received, 2);
        assert_eq!(out.expected, 4);
        assert!(out.partial);
        assert_eq!(out.estimate, want, "renormalized k-of-n mean, exactly");
    }
    // The k=2 mean of 3.5 and -1.5 per coordinate is 1.0.
    for &v in &outs[0].estimate {
        assert!((v - 1.0).abs() < 0.3, "partial mean {v} far from 1.0");
    }
    assert_eq!(summary.rounds_partial, 1);
    // Paper accounting: 2 reports in, 2 estimate deliveries of 64·d out.
    assert_eq!(summary.traffic.recv_msgs, 2);
    assert_eq!(summary.traffic.sent_bits, 2u64 * 64 * 12);
}

/// Satellite: corrupt/truncated bytes are answered with a typed error
/// (or dropped), never a panic — and the service keeps serving after.
#[test]
fn service_rejects_garbage_and_truncated_requests() {
    let (addr, server) = spawn_server(ServeOpts {
        max_rounds: Some(1),
        ..ServeOpts::default()
    });
    // Garbage magic.
    {
        let mut s = TcpStream::connect(&addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(&[0xFF; 32]).unwrap();
        match read_response(&mut s).expect("error response") {
            Response::Error(reason) => assert!(reason.contains("magic"), "got: {reason}"),
            other => panic!("expected Error, got {other:?}"),
        }
    }
    // A report truncated mid-payload (short read after write-side close).
    {
        let mut wire = Vec::new();
        write_request(
            &mut wire,
            &Request::Report {
                cohort: 1,
                round: 0,
                client: 0,
                spec: CohortSpec {
                    n: 2,
                    d: 8,
                    spec: CodecSpec::Lq { q: 16 },
                    y: 4.0,
                    seed: 0,
                },
                deadline_ms: 0,
                msg: dme::quant::Message {
                    bytes: vec![7; 40],
                    bits: 320,
                },
            },
        )
        .unwrap();
        wire.truncate(wire.len() - 10);
        let mut s = TcpStream::connect(&addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(&wire).unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        match read_response(&mut s).expect("error response") {
            Response::Error(reason) => {
                assert!(reason.contains("short read"), "got: {reason}")
            }
            other => panic!("expected Error, got {other:?}"),
        }
    }
    // The service is still healthy: a real round completes.
    let cs = CohortSpec {
        n: 1,
        d: 4,
        spec: CodecSpec::Lq { q: 16 },
        y: 4.0,
        seed: 0,
    };
    let out = report_round(&addr, 2, 0, 0, &cs, &[1.0; 4], 0, Duration::from_secs(10))
        .expect("round after garbage");
    assert_eq!(out.received, 1);
    assert!(!out.partial);
    server.join().unwrap();
}

/// Acceptance: ≥ 256 concurrent cohorts multiplexed by one process.
/// Phase 1 opens all 256 rounds (client 0 of every cohort reports and
/// parks); a health probe confirms 256 rounds are simultaneously open;
/// phase 2 completes 224 cohorts (client 1 reports) while the other 32
/// are dropout cohorts whose deadline closes them with the k=1
/// renormalized partial mean.
#[test]
fn service_multiplexes_256_cohorts_with_deadline_dropout() {
    const COHORTS: u64 = 256;
    const DROPOUT_EVERY: u64 = 8; // cohorts 0, 8, 16, … lose client 1
    let (addr, server) = spawn_server(ServeOpts {
        max_rounds: Some(COHORTS),
        default_deadline_ms: 60_000,
        ..ServeOpts::default()
    });
    let cs = |seed: u64| CohortSpec {
        n: 2,
        d: 8,
        spec: CodecSpec::Lq { q: 64 },
        y: 8.0,
        seed,
    };
    let spawn_reporter = |cohort: u64, client: usize, deadline_ms: u32| {
        let addr = addr.clone();
        thread::Builder::new()
            .stack_size(128 * 1024)
            .spawn(move || {
                let x = vec![cohort as f64 * 0.01 + client as f64; 8];
                report_round(
                    &addr,
                    cohort,
                    0,
                    client,
                    &cs(cohort),
                    &x,
                    deadline_ms,
                    Duration::from_secs(60),
                )
                .expect("report")
            })
            .expect("spawn reporter")
    };

    // Phase 1: every cohort's client 0 reports. Dropout cohorts carry a
    // short deadline; the rest effectively never expire on their own.
    let phase1: Vec<_> = (0..COHORTS)
        .map(|c| {
            let deadline = if c % DROPOUT_EVERY == 0 { 3_000 } else { 0 };
            spawn_reporter(c, 0, deadline)
        })
        .collect();

    // All 256 rounds must be open *concurrently* before anything closes
    // (if a dropout deadline fired early, `open` could never reach 256
    // and the loop would time out).
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let stats = fetch_stats(&addr, Duration::from_secs(5)).expect("health");
        let open: u64 = stats.iter().map(|s| u64::from(s.open_rounds)).sum();
        if open == COHORTS {
            assert_eq!(stats.len() as u64, COHORTS);
            break;
        }
        assert!(Instant::now() < deadline, "only {open}/{COHORTS} rounds open");
        thread::sleep(Duration::from_millis(10));
    }

    // Phase 2: client 1 reports everywhere except the dropout cohorts.
    let phase2: Vec<_> = (0..COHORTS)
        .filter(|c| c % DROPOUT_EVERY != 0)
        .map(|c| spawn_reporter(c, 1, 0))
        .collect();

    let outs1: Vec<EstimateOut> = phase1.into_iter().map(|h| h.join().unwrap()).collect();
    let outs2: Vec<EstimateOut> = phase2.into_iter().map(|h| h.join().unwrap()).collect();
    let summary = server.join().unwrap();

    let mut full = 0u64;
    let mut partial = 0u64;
    for (c, out) in (0..COHORTS).zip(&outs1) {
        if c % DROPOUT_EVERY == 0 {
            // Dropout: deadline-closed, renormalized over k=1 — exactly
            // the decode of client 0's lone report.
            assert!(out.partial, "cohort {c} should be partial");
            assert_eq!(out.received, 1);
            let x = vec![c as f64 * 0.01; 8];
            let want = reference_mean(&cs(c), 0, &[(0, &x)]);
            assert_eq!(out.estimate, want, "cohort {c} k=1 partial mean");
            partial += 1;
        } else {
            // Full: both reports in, mean over k = n = 2 — exact against
            // the ordered (client 0 first, it opened the round) fold.
            assert!(!out.partial, "cohort {c} should be full");
            assert_eq!(out.received, 2);
            let x0 = vec![c as f64 * 0.01; 8];
            let x1 = vec![c as f64 * 0.01 + 1.0; 8];
            let want = reference_mean(&cs(c), 0, &[(0, &x0), (1, &x1)]);
            assert_eq!(out.estimate, want, "cohort {c} full mean");
            full += 1;
        }
    }
    assert_eq!((full, partial), (COHORTS - COHORTS / DROPOUT_EVERY, COHORTS / DROPOUT_EVERY));
    // Phase-2 reporters see the same estimates their cohort's phase-1
    // reporter saw.
    for out in &outs2 {
        assert_eq!(out.received, 2);
        assert!(!out.partial);
    }
    assert_eq!(summary.rounds_completed, COHORTS);
    assert_eq!(summary.cohorts, COHORTS as usize);
    assert_eq!(summary.rounds_partial, COHORTS / DROPOUT_EVERY);
    // Every accepted report was metered inbound; every delivered
    // estimate charged 64·d outbound (2 recipients for full cohorts, 1
    // for dropouts).
    let reports = COHORTS + (COHORTS - COHORTS / DROPOUT_EVERY);
    assert_eq!(summary.traffic.recv_msgs, reports);
    assert_eq!(summary.traffic.sent_bits, reports * 64 * 8);
}

/// Drive `rounds` k-of-n partial star rounds over every endpoint of a
/// fault-wrapped transport, one thread per machine. The wrapper's round
/// counter is advanced before each call — exactly like the session's
/// worker loop — so the plan's deterministic fault schedule applies
/// identically on any transport.
fn run_partial_rounds<T>(
    transport: &mut FaultyTransport<T>,
    spec: CodecSpec,
    seed: u64,
    y: f64,
    rounds: u64,
    inputs: &[Vec<f64>],
) -> Vec<Vec<PartialRoundReport>>
where
    T: Transport,
    T::Endpoint: 'static,
{
    let eps = transport.open().expect("open transport");
    let handles: Vec<_> = eps
        .into_iter()
        .zip(inputs.to_vec())
        .map(|(mut ep, x)| {
            thread::spawn(move || {
                let policy = StragglerPolicy::deterministic(Duration::from_millis(800), 1, 5);
                (0..rounds)
                    .map(|r| {
                        ep.set_round(r);
                        star_round_partial_over(&mut ep, spec, seed, r, y, &policy, &x)
                            .expect("partial round")
                    })
                    .collect::<Vec<PartialRoundReport>>()
            })
        })
        .collect();
    handles
        .into_iter()
        .map(|h| h.join().expect("machine thread"))
        .collect()
}

/// Satellite: the same seeded fault plan wrapped around the loopback-TCP
/// mesh and the in-process channels — k-of-n partial rounds with dropped
/// reports yield identical leaders, quorum sizes, arrival records and
/// renormalized estimates on both transports, and the leader's arrival
/// record is exactly the plan's survivor set. (Retry tallies are the one
/// field deliberately not compared: backoff windows expire on wall-clock
/// time, which real sockets do not reproduce.)
#[test]
fn faulty_tcp_partial_round_matches_sim() {
    let (n, d, seed, y) = (5, 24, 23, 1.0);
    let spec = CodecSpec::Lq { q: 32 };
    let rounds = 3u64;
    let inputs = gen_inputs(n, d, 17);
    let plan = FaultPlan::dropout(0xD10_0F, 0.4);

    let mut sim = FaultyTransport::new(Cluster::new(n), plan.clone());
    let sim_reports = run_partial_rounds(&mut sim, spec, seed, y, rounds, &inputs);

    let mesh = LoopbackMesh::new(n, &TcpOpts::default()).expect("mesh up");
    let mut tcp = FaultyTransport::new(mesh, plan.clone());
    let tcp_reports = run_partial_rounds(&mut tcp, spec, seed, y, rounds, &inputs);

    let mut saw_partial = false;
    for r in 0..rounds as usize {
        for m in 0..n {
            let (a, b) = (&sim_reports[m][r], &tcp_reports[m][r]);
            assert_eq!(a.leader, b.leader, "machine {m} round {r}: leader");
            assert_eq!(a.k, b.k, "machine {m} round {r}: quorum size");
            assert_eq!(a.arrived, b.arrived, "machine {m} round {r}: arrival record");
            assert_eq!(a.output, b.output, "machine {m} round {r}: estimate");
        }
        // The leader's arrival record is exactly the plan's survivor set
        // (its own report never crosses the wire, so it always counts).
        let leader = sim_reports[0][r].leader;
        let survivors = plan.survivors(n, r as u64);
        let arrived = &sim_reports[leader][r].arrived;
        assert_eq!(arrived.len(), n, "round {r}: leader arrival record");
        for v in 0..n {
            let want = v == leader || survivors.contains(&v);
            assert_eq!(arrived[v], want, "round {r} machine {v} arrival");
        }
        let k_want = 1 + survivors.iter().filter(|&&v| v != leader).count();
        assert_eq!(sim_reports[leader][r].k, k_want, "round {r}: quorum size");
        saw_partial |= sim_reports[leader][r].k < n;
    }
    assert!(saw_partial, "rate-0.4 dropout never dropped a report; pick a new plan seed");
}
