//! Property-based tests over the quantization + coordinator invariants.
//!
//! The offline toolchain has no `proptest` crate (DESIGN.md §6), so this
//! file carries a small seeded-case harness: each property runs over a
//! few hundred generated cases; on failure the offending case's seed is
//! printed, making reproduction one `CASE_SEED=… cargo test` away.

use dme::coordinator::{
    mean_estimation_star, mean_estimation_tree, robust_variance_reduction, CodecSpec, DmeBuilder,
};
use dme::linalg::{axpy, dist_inf, mean_vecs};
use dme::quant::baselines::{EfSignSgd, Qsgd, QsgdNorm, SureshHadamard, TernGrad, TopK};
use dme::quant::{LatticeQuantizer, Message, PacketArena, RotatedLatticeQuantizer, VectorCodec};
use dme::rng::{hash2, Rng};

/// Run `prop` over `cases` generated cases; panics with the case seed.
fn check(name: &str, cases: u64, prop: impl Fn(&mut Rng)) {
    let base = std::env::var("CASE_SEED")
        .ok()
        .and_then(|s| s.parse().ok());
    match base {
        Some(seed) => {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        }
        None => {
            for case in 0..cases {
                let seed = hash2(0xBEEF, case);
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let mut rng = Rng::new(seed);
                    prop(&mut rng);
                }));
                if let Err(e) = result {
                    panic!("property '{name}' failed at CASE_SEED={seed}: {e:?}");
                }
            }
        }
    }
}

fn rand_dim(rng: &mut Rng) -> usize {
    [1, 2, 3, 7, 16, 33, 100, 128][rng.next_below(8) as usize]
}

fn rand_q(rng: &mut Rng) -> u32 {
    [2, 3, 4, 8, 16, 64, 255][rng.next_below(7) as usize]
}

fn rand_vec(rng: &mut Rng, d: usize, center: f64, spread: f64) -> Vec<f64> {
    (0..d)
        .map(|_| center + rng.uniform(-spread, spread))
        .collect()
}

/// Theorem 1 / Lemma 15 (practical §9.1 form): within the success radius
/// the decode recovers exactly the encoded lattice point, for any d, q,
/// center, scale.
#[test]
fn prop_lattice_roundtrip_exact_within_radius() {
    check("lattice_roundtrip", 300, |rng| {
        let d = rand_dim(rng);
        let q = rand_q(rng);
        let y = 10f64.powf(rng.uniform(-3.0, 3.0));
        let center = rng.uniform(-1e4, 1e4);
        let mut shared = rng.fork(1);
        let mut codec = LatticeQuantizer::from_y(d, q, y, &mut shared);
        let x = rand_vec(rng, d, center, y);
        let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(-y, y) * 0.999).collect();
        let (msg, point) = codec.encode_with_point(&x);
        assert_eq!(msg.bits, codec.message_bits());
        let z = codec.decode(&msg, &xv);
        let tol = codec.lattice.s * 1e-9 + 1e-12;
        for (zi, pi) in z.iter().zip(&point) {
            assert!((zi - pi).abs() <= tol, "decode != encoded point");
        }
        let _ = msg;
    });
}

/// Error is always ≤ s/2 per coordinate regardless of input magnitude.
#[test]
fn prop_quantization_error_independent_of_norm() {
    check("error_vs_norm", 200, |rng| {
        let d = rand_dim(rng);
        let q = rand_q(rng);
        let y = 1.0;
        let center = 10f64.powf(rng.uniform(0.0, 6.0)); // up to 1e6
        let mut shared = rng.fork(2);
        let codec = LatticeQuantizer::from_y(d, q, y, &mut shared);
        let x = rand_vec(rng, d, center, y);
        let (_, point) = codec.encode_with_point(&x);
        assert!(
            dist_inf(&point, &x) <= codec.lattice.s / 2.0 + center * 1e-12,
            "error grew with norm"
        );
    });
}

/// RLQ: rotate→quantize→decode→unrotate stays within the ℓ2 envelope
/// s/2·√dp for inputs at any center.
#[test]
fn prop_rlq_l2_error_envelope() {
    check("rlq_envelope", 120, |rng| {
        let d = rand_dim(rng);
        let q = 16;
        let center = rng.uniform(-1e3, 1e3);
        let x = rand_vec(rng, d, center, 0.5);
        // Probe the rotated distance with the same shared stream the codec
        // will draw, then build with a matching y_rot.
        let mut shared_probe = rng.fork(3);
        let probe = RotatedLatticeQuantizer::from_y_rot(d, q, 1.0, &mut shared_probe);
        let rx = probe.rotation.forward(&x);
        let r_ref = probe.rotation.forward(&x);
        let _ = r_ref;
        let y_rot = dme::linalg::norm_inf(&rx).max(1e-9); // self-decode: distance 0
        let mut shared = rng.fork(3);
        let mut codec = RotatedLatticeQuantizer::from_y_rot(d, q, y_rot, &mut shared);
        let mut enc_rng = rng.fork(4);
        let msg = codec.encode(&x, &mut enc_rng);
        let z = codec.decode(&msg, &x);
        let dp = codec.rotation.padded_dim() as f64;
        let bound = codec.inner.lattice.s / 2.0 * dp.sqrt() + 1e-9 + center.abs() * 1e-9;
        assert!(
            dme::linalg::dist2(&z, &x) <= bound,
            "ℓ2 err {} > bound {}",
            dme::linalg::dist2(&z, &x),
            bound
        );
    });
}

/// Star topology: agreement (all outputs identical) and accuracy
/// (‖EST−μ‖∞ ≤ 1.5·s) for every n, d, q within the y contract.
#[test]
fn prop_star_agreement_and_accuracy() {
    check("star_agreement", 120, |rng| {
        let n = 1 + rng.next_below(9) as usize;
        let d = rand_dim(rng);
        let q = [8u32, 16, 64][rng.next_below(3) as usize];
        let y: f64 = 1.0;
        let center = rng.uniform(-1e3, 1e3);
        let inputs: Vec<Vec<f64>> = (0..n)
            .map(|_| rand_vec(rng, d, center, y / 2.0 * 0.98))
            .collect();
        let out = mean_estimation_star(&inputs, &CodecSpec::Lq { q }, y, rng.next_u64(), 0);
        for o in &out.outputs {
            assert_eq!(o, &out.outputs[0], "agreement violated");
        }
        let mu = mean_vecs(&inputs);
        let s = 2.0 * y / (q as f64 - 1.0);
        assert!(
            dist_inf(out.estimate(), &mu) <= 1.5 * s + 1e-9,
            "err {} > 1.5s {}",
            dist_inf(out.estimate(), &mu),
            1.5 * s
        );
    });
}

/// Star traffic invariant: workers pay exactly d·⌈log₂q⌉ each way; the
/// leader pays (n−1) times that each way.
#[test]
fn prop_star_traffic_exact() {
    check("star_traffic", 80, |rng| {
        let n = 2 + rng.next_below(8) as usize;
        let d = rand_dim(rng);
        let q = rand_q(rng);
        let inputs: Vec<Vec<f64>> = (0..n).map(|_| rand_vec(rng, d, 0.0, 0.4)).collect();
        let out = mean_estimation_star(&inputs, &CodecSpec::Lq { q }, 1.0, rng.next_u64(), 1);
        let w = dme::quant::bits::width_for(q as u64) as u64;
        let msg = d as u64 * w;
        for (v, t) in out.traffic.iter().enumerate() {
            if v == out.leader {
                assert_eq!(t.sent_bits, (n as u64 - 1) * msg);
                assert_eq!(t.recv_bits, (n as u64 - 1) * msg);
            } else {
                assert_eq!(t.sent_bits, msg);
                assert_eq!(t.recv_bits, msg);
            }
        }
    });
}

/// Tree topology: agreement for any machine count, and worst-case traffic
/// bounded by O(1) roles × message size for every machine.
#[test]
fn prop_tree_agreement_and_bounded_traffic() {
    check("tree_bounds", 60, |rng| {
        let n = 2 + rng.next_below(15) as usize;
        let d = rand_dim(rng);
        let y = 1.0;
        let inputs: Vec<Vec<f64>> = (0..n).map(|_| rand_vec(rng, d, 50.0, y / 2.0)).collect();
        let out = mean_estimation_tree(&inputs, n, y, rng.next_u64(), 0);
        for o in &out.outputs {
            assert_eq!(o, &out.outputs[0]);
        }
        let w = dme::quant::bits::width_for(out.q_used as u64) as u64;
        let cap = 8 * d as u64 * w;
        for t in &out.traffic {
            assert!(t.sent_bits <= cap && t.recv_bits <= cap);
        }
    });
}

/// Robust VR: decoding never silently corrupts — the output is always
/// within the worst-case averaging envelope of the true mean, even with
/// adversarially far inputs (escalation must absorb them).
#[test]
fn prop_robust_vr_never_corrupts() {
    check("robust_vr", 60, |rng| {
        let n = 2 + rng.next_below(6) as usize;
        let d = [4usize, 16, 33][rng.next_below(3) as usize];
        let sigma = 10f64.powf(rng.uniform(-2.0, 1.0));
        let center = rng.uniform(-100.0, 100.0);
        let mut inputs: Vec<Vec<f64>> = (0..n)
            .map(|_| rand_vec(rng, d, center, sigma))
            .collect();
        // With probability 1/2, make one input wildly far.
        if rng.next_bool() {
            let k = rng.next_below(n as u64) as usize;
            let shift = rng.uniform(10.0, 1e4) * sigma;
            for v in inputs[k].iter_mut() {
                *v += shift;
            }
        }
        let out = robust_variance_reduction(&inputs, sigma, 8, rng.next_u64(), 0);
        let mu = mean_vecs(&inputs);
        // Output = mean of per-input estimates, each within s/2 of its
        // input (s = 2σ/(q−1) at the final escalation level ≤ initial s).
        let s0 = 2.0 * sigma / 7.0;
        assert!(
            dist_inf(&out.estimate, &mu) <= s0 + 1e-9,
            "robust VR output {} off the mean envelope {}",
            dist_inf(&out.estimate, &mu),
            s0
        );
    });
}

/// The streaming-fold contract: for *every* registered codec,
/// `decode_accumulate_into(msg, ref, w, acc)` must equal `decode_into`
/// followed by a weighted axpy — bit for bit, with random weights and a
/// stale (non-zero) accumulator. This is what lets the coordinator swap
/// decode-then-sum for the fused fold without moving a single estimate
/// bit.
#[test]
fn prop_decode_accumulate_equals_decode_plus_axpy_all_codecs() {
    check("decode_accumulate", 40, |rng| {
        let d = 16; // multiple of 4 (D4) and power of two (PowerSGD grid)
        let y = 10f64.powf(rng.uniform(-1.0, 1.0));
        let seed = rng.next_u64();
        let round = rng.next_below(4);
        let specs = [
            CodecSpec::Lq { q: 16 },
            CodecSpec::Rlq { q: 16 },
            CodecSpec::LqHull { q: 8 },
            CodecSpec::D4 { q: 16 },
            CodecSpec::QsgdL2 { q: 16 },
            CodecSpec::QsgdLinf { q: 16 },
            CodecSpec::Hadamard { q: 16 },
            CodecSpec::Vqsgd { reps: 4 },
            CodecSpec::EfSign,
            CodecSpec::PowerSgd { rank: 2 },
            CodecSpec::TernGrad,
            CodecSpec::TopK { k: 5 },
            CodecSpec::Full,
        ];
        for spec in specs {
            let mut codec = spec.build(d, y, seed, round);
            let center = rng.uniform(-100.0, 100.0);
            let x = rand_vec(rng, d, center, y);
            let reference: Vec<f64> = x.iter().map(|v| v + rng.uniform(-y, y) * 0.5).collect();
            let mut enc_rng = rng.fork(7);
            let msg = codec.encode(&x, &mut enc_rng);
            let weight = rng.uniform(-3.0, 3.0);
            let stale = rand_vec(rng, d, 0.0, 5.0);
            // Reference path: materialize the decode, then weighted add.
            let mut expect = stale.clone();
            let mut z = vec![0.0; d];
            codec.decode_into(&msg, &reference, &mut z);
            axpy(&mut expect, weight, &z);
            // Fused path.
            let mut acc = stale.clone();
            codec.decode_accumulate_into(&msg, &reference, weight, &mut acc);
            assert_eq!(acc, expect, "fused fold diverged for {}", spec.label());
            // Range variant on an aligned interior chunk.
            let align = codec.fold_chunk_align();
            let lo = align;
            let hi = d - align;
            let mut acc_r = stale[lo..hi].to_vec();
            codec.decode_accumulate_range(&msg, &reference, weight, lo, &mut acc_r);
            assert_eq!(
                acc_r,
                expect[lo..hi],
                "range fold diverged for {}",
                spec.label()
            );
        }
    });
}

/// The block kernel underneath the lattice decodes: `read_block` must
/// equal repeated `read` for every width 1..=32, any count, any
/// (misaligned) starting offset.
#[test]
fn prop_read_block_equals_repeated_read() {
    check("read_block", 150, |rng| {
        let width = 1 + rng.next_below(32) as u32;
        let prefix = rng.next_below(64) as u32; // misaligns the stream
        let n = 1 + rng.next_below(300) as usize;
        let mask = (1u64 << width) - 1;
        let vals: Vec<u64> = (0..n).map(|_| rng.next_u64() & mask).collect();
        let mut w = dme::quant::bits::BitWriter::new();
        let pv = if prefix == 0 {
            0
        } else {
            rng.next_u64() & ((1u64 << prefix) - 1)
        };
        w.push(pv, prefix);
        for &v in &vals {
            w.push(v, width);
        }
        let (bytes, _) = w.finish();
        // Scalar reference.
        let mut r1 = dme::quant::bits::BitReader::new(&bytes);
        r1.seek(prefix as u64);
        let scalar: Vec<u64> = (0..n).map(|_| r1.read(width)).collect();
        assert_eq!(scalar, vals);
        // Block kernel, in randomly sized sub-blocks.
        let mut r2 = dme::quant::bits::BitReader::new(&bytes);
        r2.seek(prefix as u64);
        let mut block = vec![0u64; n];
        let mut done = 0;
        while done < n {
            let take = (1 + rng.next_below(50) as usize).min(n - done);
            r2.read_block(width, &mut block[done..done + take]);
            done += take;
        }
        assert_eq!(block, vals);
        assert_eq!(r1.bits_consumed(), r2.bits_consumed());
    });
}

/// Session-level invariant: the streaming-fold leader (diagnostics off)
/// and the collecting leader (diagnostics on) produce identical
/// estimates and traffic for the same (seed, round).
#[test]
fn prop_streaming_and_collecting_leaders_agree() {
    check("fold_vs_collect", 30, |rng| {
        let n = 2 + rng.next_below(7) as usize;
        let d = rand_dim(rng);
        let q = [8u32, 16, 64][rng.next_below(3) as usize];
        let seed = rng.next_u64();
        let inputs: Vec<Vec<f64>> = (0..n).map(|_| rand_vec(rng, d, 10.0, 0.45)).collect();
        let mk = |diag: bool| {
            dme::coordinator::DmeBuilder::new(n, d)
                .codec(CodecSpec::Lq { q })
                .seed(seed)
                .diagnostics(diag)
                .build()
        };
        let mut streaming = mk(false);
        let mut collecting = mk(true);
        for _ in 0..3 {
            let s = streaming.round_with_y(&inputs, 1.0);
            let c = collecting.round_with_y(&inputs, 1.0);
            assert_eq!(s.estimate, c.estimate);
            assert_eq!(s.round_traffic, c.round_traffic);
            assert!(s.decoded_at_leader.is_empty());
            assert_eq!(c.decoded_at_leader.len(), n);
        }
    });
}

/// The write-side block kernel: `push_block` must produce the identical
/// byte stream and bit count as repeated `push`, for every width 0..=64
/// (width 0 fields carry no bits at all), any count, and any misaligned
/// starting offset — and the stream must round-trip through `read_block`,
/// non-word-aligned tail included.
#[test]
fn prop_push_block_equals_repeated_push() {
    check("push_block", 150, |rng| {
        let width = rng.next_below(65) as u32; // 0..=64
        let prefix = rng.next_below(64) as u32; // misaligns the stream
        let n = 1 + rng.next_below(300) as usize;
        let mask = if width == 0 {
            0
        } else if width == 64 {
            u64::MAX
        } else {
            (1u64 << width) - 1
        };
        let vals: Vec<u64> = (0..n).map(|_| rng.next_u64() & mask).collect();
        let pv = if prefix == 0 {
            0
        } else {
            rng.next_u64() & ((1u64 << prefix) - 1)
        };
        // Scalar reference stream.
        let mut ws = dme::quant::bits::BitWriter::new();
        ws.push(pv, prefix);
        for &v in &vals {
            ws.push(v, width);
        }
        // Block stream, in randomly sized sub-blocks.
        let mut wb = dme::quant::bits::BitWriter::new();
        wb.push(pv, prefix);
        let mut done = 0;
        while done < n {
            let take = (1 + rng.next_below(50) as usize).min(n - done);
            wb.push_block(&vals[done..done + take], width);
            done += take;
        }
        assert_eq!(wb.bit_len(), ws.bit_len());
        let (bytes, bits) = ws.finish();
        assert_eq!(wb.finish(), (bytes.clone(), bits));
        // And the written fields round-trip through the read-side twin.
        let mut r = dme::quant::bits::BitReader::new(&bytes);
        r.seek(prefix as u64);
        let mut out = vec![u64::MAX; n];
        r.read_block(width, &mut out);
        assert_eq!(out, vals);
    });
}

/// The seed's scalar per-coordinate LQ encode loop (one `push` per
/// color) — the reference the fused block kernel must match bit for bit.
fn lq_encode_scalar(lq: &LatticeQuantizer, x: &[f64]) -> dme::quant::Message {
    let width = dme::quant::bits::width_for(lq.q as u64);
    let inv = 1.0 / lq.lattice.s;
    let q = lq.q as i64;
    let mut w = dme::quant::bits::BitWriter::new();
    for (xi, off) in x.iter().zip(&lq.lattice.offset) {
        let k = ((xi - off) * inv).round_ties_even() as i64;
        let c = if (lq.q & (lq.q - 1)) == 0 {
            (k & (q - 1)) as u64
        } else {
            k.rem_euclid(q) as u64
        };
        w.push(c, width);
    }
    let (bytes, bits) = w.finish();
    dme::quant::Message { bytes, bits }
}

/// Encode-plane parity: for LQ (power-of-two and general q), RLQ (scalar
/// two-pass rotation + scalar pack) and D4 (scalar per-bucket pushes),
/// the fused block-kernel `encode_into` must reproduce the scalar
/// reference encode bit for bit, stale scratch included.
#[test]
fn prop_encode_block_kernels_match_scalar_reference() {
    check("encode_block", 60, |rng| {
        let y = 10f64.powf(rng.uniform(-1.0, 1.0));
        let center = rng.uniform(-100.0, 100.0);
        let mut stale = dme::quant::Message {
            bytes: vec![0xCD; 5],
            bits: 40,
        };

        // LQ at a random dimension and both q classes.
        let d = rand_dim(rng);
        let q = rand_q(rng);
        let mut shared = rng.fork(11);
        let mut lq = LatticeQuantizer::from_y(d, q, y, &mut shared);
        let x = rand_vec(rng, d, center, y);
        let expect = lq_encode_scalar(&lq, &x);
        let mut enc_rng = rng.fork(12);
        lq.encode_into(&x, &mut enc_rng, &mut stale);
        assert_eq!(stale, expect, "LQ d={d} q={q}");

        // RLQ: scalar reference = sign-multiply → two-pass radix-2 FWHT
        // (the seed rotation) → scalar pack on the inner lattice.
        let mut shared = rng.fork(13);
        let mut rlq = RotatedLatticeQuantizer::from_y_rot(d, 16, y, &mut shared);
        let mut rx = vec![0.0; rlq.rotation.padded_dim()];
        for i in 0..d {
            rx[i] = x[i] * rlq.rotation.sign[i];
        }
        dme::quant::hadamard::fwht_reference(&mut rx);
        let expect = lq_encode_scalar(&rlq.inner, &rx);
        let mut enc_rng = rng.fork(14);
        rlq.encode_into(&x, &mut enc_rng, &mut stale);
        assert_eq!(stale, expect, "RLQ d={d}");

        // D4: scalar reference = per-bucket nearest_d4 + four pushes.
        let d = 4 * (1 + rng.next_below(40) as usize);
        let x = rand_vec(rng, d, center, y);
        let mut shared = rng.fork(15);
        let mut d4 = dme::quant::D4Quantizer::from_y(d, 16, y, &mut shared);
        let width = dme::quant::bits::width_for(d4.q as u64);
        let inv = 1.0 / d4.s;
        let mask = (d4.q - 1) as i64;
        let mut w = dme::quant::bits::BitWriter::new();
        for b in 0..d / 4 {
            let mut t = [0.0f64; 4];
            for (i, ti) in t.iter_mut().enumerate() {
                let j = 4 * b + i;
                *ti = (x[j] - d4.offset[j]) * inv;
            }
            let k = dme::quant::d4::nearest_d4(&t);
            let c: Vec<u64> = k.iter().map(|&ki| (ki & mask) as u64).collect();
            w.push(c[0], width);
            w.push(c[1], width);
            w.push(c[2], width);
            w.push(c[3] >> 1, width - 1);
        }
        let (bytes, bits) = w.finish();
        let expect = dme::quant::Message { bytes, bits };
        let mut enc_rng = rng.fork(16);
        d4.encode_into(&x, &mut enc_rng, &mut stale);
        assert_eq!(stale, expect, "D4 d={d}");
    });
}

/// Chunk-parallel encode: for every range-encoding codec, any chunk
/// size, and ragged dimensions, `encode_chunked` must equal the
/// sequential `encode_into` stream bit for bit — sharding may only ever
/// change wall-clock.
#[test]
fn prop_encode_chunked_matches_sequential() {
    check("encode_chunked", 60, |rng| {
        let y = 1.0;
        let chunk = 1 + rng.next_below(200) as usize;
        let mut stale = dme::quant::Message {
            bytes: vec![0xAB; 3],
            bits: 24,
        };

        let d = rand_dim(rng);
        let q = rand_q(rng);
        let mut shared = rng.fork(21);
        let mut lq = LatticeQuantizer::from_y(d, q, y, &mut shared);
        let center = rng.uniform(-50.0, 50.0);
        let x = rand_vec(rng, d, center, y);
        let mut enc_rng = rng.fork(22);
        let chunk_rng = enc_rng.clone();
        let expect = dme::quant::VectorCodec::encode(&mut lq, &x, &mut enc_rng);
        dme::quant::encode_chunked(&mut lq, &x, &mut chunk_rng.clone(), &mut stale, chunk);
        assert_eq!(stale, expect, "LQ d={d} q={q} chunk={chunk}");

        let d = 4 * (1 + rng.next_below(64) as usize);
        let x = rand_vec(rng, d, 0.0, y);
        let mut shared = rng.fork(23);
        let mut d4 = dme::quant::D4Quantizer::from_y(d, 16, y, &mut shared);
        let chunk_rng = enc_rng.clone();
        let expect = dme::quant::VectorCodec::encode(&mut d4, &x, &mut enc_rng);
        dme::quant::encode_chunked(&mut d4, &x, &mut chunk_rng.clone(), &mut stale, chunk);
        assert_eq!(stale, expect, "D4 d={d} chunk={chunk}");
    });
}

/// The blocked multi-radix one-pass FWHT is bit-identical to the seed's
/// two-pass radix-2 reference at every power-of-two size, including
/// multi-block ones.
#[test]
fn prop_fused_fwht_matches_reference() {
    check("fwht_parity", 40, |rng| {
        let logd = rng.next_below(14) as u32; // 1 .. 8192
        let d = 1usize << logd;
        let x: Vec<f64> = (0..d).map(|_| rng.next_gaussian() * 2.0).collect();
        let mut fused = x.clone();
        dme::quant::hadamard::fwht(&mut fused);
        let mut reference = x;
        dme::quant::hadamard::fwht_reference(&mut reference);
        assert_eq!(fused, reference, "d={d}");
    });
}

/// Bit-packing: pack→unpack round-trips any width/value set (the wire
/// format underneath every lattice message).
#[test]
fn prop_bitpack_roundtrip() {
    check("bitpack", 200, |rng| {
        let width = 1 + rng.next_below(32) as u32;
        let n = 1 + rng.next_below(500) as usize;
        let vals: Vec<u64> = (0..n)
            .map(|_| rng.next_u64() & ((1u64 << width) - 1))
            .collect();
        let (bytes, bits) = dme::quant::bits::pack(&vals, width);
        assert_eq!(bits, n as u64 * width as u64);
        assert_eq!(dme::quant::bits::unpack(&bytes, width, n), vals);
    });
}

/// Message-arena packet framing (the batch round plane's staging buffer,
/// `quant::PacketArena`): length-prefixed packets round-trip exactly —
/// arbitrary byte lengths (misaligned bit tails included), empty
/// packets, and arena reuse across batches with stale capacity.
#[test]
fn prop_packet_arena_framing_roundtrip() {
    check("packet_arena", 200, |rng| {
        let mut arena = PacketArena::new();
        // Several batches through one arena: clear() must drop every
        // stale packet while keeping the allocation.
        for _batch in 0..3 {
            let count = rng.next_below(6) as usize;
            let msgs: Vec<Message> = (0..count)
                .map(|_| {
                    let len = rng.next_below(67) as usize;
                    let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
                    // Bit count anywhere in the last byte (misaligned
                    // packet tails are the common lattice-stream case).
                    let bits = if len == 0 {
                        0
                    } else {
                        len as u64 * 8 - rng.next_below(8)
                    };
                    Message { bytes, bits }
                })
                .collect();
            arena.clear();
            assert!(arena.is_empty());
            for m in &msgs {
                arena.push(m);
            }
            assert_eq!(arena.len(), msgs.len());
            let mut r = arena.reader();
            assert_eq!(r.remaining(), msgs.len());
            for (i, m) in msgs.iter().enumerate() {
                let got = r.next_message().expect("framed packet");
                assert_eq!(&got, m, "packet {i}");
            }
            assert!(r.next_packet().is_none(), "no trailing packet");
        }
    });
}

/// Batch plane vs sequential rounds at random shapes: estimates, leaders
/// and per-machine traffic must be bit-identical slot for slot (the
/// deep per-field pin lives in `session_parity`; this sweeps shapes).
#[test]
fn prop_round_batch_matches_sequential_rounds() {
    check("round_batch_parity", 25, |rng| {
        let n = 2 + rng.next_below(5) as usize;
        let d = 1 + rng.next_below(40) as usize;
        let b_total = 1 + rng.next_below(5) as usize;
        let seed = rng.next_u64();
        let q = [4u32, 8, 16][rng.next_below(3) as usize];
        let slots: Vec<Vec<Vec<f64>>> = (0..b_total)
            .map(|_| (0..n).map(|_| rand_vec(rng, d, 30.0, 0.5)).collect())
            .collect();
        let ys: Vec<f64> = (0..b_total).map(|_| rng.uniform(0.8, 2.0)).collect();
        let mk = || DmeBuilder::new(n, d).codec(CodecSpec::Lq { q }).seed(seed).build();
        let mut batched = mk();
        let mut seq = mk();
        let outs = batched.round_batch_with_y(&slots, &ys);
        for (s, o) in outs.iter().enumerate() {
            let r = seq.round_with_y(&slots[s], ys[s]);
            assert_eq!(o.round, r.round, "slot {s}");
            assert_eq!(o.estimate, r.estimate, "slot {s}");
            assert_eq!(o.leader, r.leader, "slot {s}");
            assert_eq!(o.agreement, r.agreement, "slot {s}");
            assert_eq!(o.round_traffic, r.round_traffic, "slot {s}");
        }
    });
}

// ---------------------------------------------------------------------
// Baseline comparators on the blocked data plane: every fused path must
// reproduce the seed's scalar loops bit for bit — same RNG draw order,
// same IEEE expression order. The scalar references below are verbatim
// copies of the seed implementations (per-coordinate `rng.next_f64()`
// draws, per-field `BitWriter::push`).
// ---------------------------------------------------------------------

/// Seed QSGD-L2: one f64 header, then per coordinate a sign bit and a
/// stochastically rounded level, one RNG draw per coordinate (drawn even
/// for the zero vector).
fn qsgd_l2_encode_scalar(levels: u32, x: &[f64], rng: &mut Rng) -> Message {
    let w_lvl = dme::quant::bits::width_for(levels as u64 + 1);
    let norm = dme::linalg::norm2(x);
    let mut w = dme::quant::bits::BitWriter::new();
    w.push_f64(norm);
    for &v in x {
        let sign = if v < 0.0 { 1u64 } else { 0u64 };
        let scaled = if norm > 0.0 {
            v.abs() / norm * levels as f64
        } else {
            0.0
        };
        let low = scaled.floor();
        let lvl = low as u64 + if rng.next_f64() < scaled - low { 1 } else { 0 };
        w.push(sign, 1);
        w.push(lvl.min(levels as u64), w_lvl);
    }
    let (bytes, bits) = w.finish();
    Message { bytes, bits }
}

/// Seed QSGD-L∞: min/max header, per-coordinate stochastic level.
fn qsgd_linf_encode_scalar(levels: u32, x: &[f64], rng: &mut Rng) -> Message {
    let w_lvl = dme::quant::bits::width_for(levels as u64 + 1);
    let mn = x.iter().cloned().fold(f64::INFINITY, f64::min);
    let mx = x.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let range = (mx - mn).max(0.0);
    let mut w = dme::quant::bits::BitWriter::new();
    w.push_f64(mn);
    w.push_f64(mx);
    for &v in x {
        let scaled = if range > 0.0 {
            (v - mn) / range * levels as f64
        } else {
            0.0
        };
        let low = scaled.floor();
        let lvl = (low as u64 + if rng.next_f64() < scaled - low { 1 } else { 0 })
            .min(levels as u64);
        w.push(lvl, w_lvl);
    }
    let (bytes, bits) = w.finish();
    Message { bytes, bits }
}

/// Seed Suresh–Hadamard: rotate, min/max header over the rotated vector,
/// per-padded-coordinate stochastic level.
fn suresh_encode_scalar(c: &SureshHadamard, x: &[f64], rng: &mut Rng) -> Message {
    let levels = c.levels;
    let w_lvl = dme::quant::bits::width_for(levels as u64 + 1);
    let rx = c.rotation.forward(x);
    let mn = rx.iter().cloned().fold(f64::INFINITY, f64::min);
    let mx = rx.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let range = (mx - mn).max(0.0);
    let mut w = dme::quant::bits::BitWriter::new();
    w.push_f64(mn);
    w.push_f64(mx);
    for &v in &rx {
        let scaled = if range > 0.0 {
            (v - mn) / range * levels as f64
        } else {
            0.0
        };
        let low = scaled.floor();
        let lvl = (low as u64 + if rng.next_f64() < scaled - low { 1 } else { 0 })
            .min(levels as u64);
        w.push(lvl, w_lvl);
    }
    let (bytes, bits) = w.finish();
    Message { bytes, bits }
}

/// Seed TernGrad: ℓ∞ header, per-coordinate trit — note the seed's
/// `m > 0.0 &&` short-circuit, which drew *nothing* for the zero vector.
fn terngrad_encode_scalar(x: &[f64], rng: &mut Rng) -> Message {
    let m = dme::linalg::norm_inf(x);
    let mut w = dme::quant::bits::BitWriter::new();
    w.push_f64(m);
    for &v in x {
        let t = if m > 0.0 && rng.next_f64() < v.abs() / m {
            if v < 0.0 {
                2u64
            } else {
                1u64
            }
        } else {
            0u64
        };
        w.push(t, 2);
    }
    let (bytes, bits) = w.finish();
    Message { bytes, bits }
}

/// Seed EF-SignSGD: scale header + sign bits over `p = x + e`, with the
/// caller-held error memory updated exactly like the seed.
fn efsign_encode_scalar(error: &mut [f64], x: &[f64]) -> Message {
    let d = x.len();
    let p: Vec<f64> = x.iter().zip(error.iter()).map(|(a, e)| a + e).collect();
    let scale = dme::linalg::norm1(&p) / d as f64;
    let mut w = dme::quant::bits::BitWriter::new();
    w.push_f64(scale);
    for &v in &p {
        w.push(u64::from(v < 0.0), 1);
    }
    for (e, &v) in error.iter_mut().zip(&p) {
        let dec = if v < 0.0 { -scale } else { scale };
        *e = v - dec;
    }
    let (bytes, bits) = w.finish();
    Message { bytes, bits }
}

/// Seed Top-K: stable descending sort by |p| (panics on NaN — the
/// reference is only used on finite inputs), truncate to k, ascending
/// index serialization, error feedback.
fn topk_encode_scalar(k: usize, error: &mut [f64], x: &[f64]) -> Message {
    let d = x.len();
    let iw = dme::quant::bits::width_for(d as u64).max(1);
    let p: Vec<f64> = x.iter().zip(error.iter()).map(|(a, e)| a + e).collect();
    let mut idx: Vec<usize> = (0..d).collect();
    idx.sort_by(|&a, &b| p[b].abs().partial_cmp(&p[a].abs()).unwrap());
    idx.truncate(k);
    idx.sort_unstable();
    let mut w = dme::quant::bits::BitWriter::new();
    for &i in &idx {
        w.push(i as u64, iw);
        w.push_f32(p[i] as f32);
    }
    let mut kept = vec![false; d];
    for &i in &idx {
        kept[i] = true;
    }
    for i in 0..d {
        error[i] = if kept[i] {
            p[i] - p[i] as f32 as f64
        } else {
            p[i]
        };
    }
    let (bytes, bits) = w.finish();
    Message { bytes, bits }
}

/// Fused baseline encodes vs the seed scalar references: bit-identical
/// messages AND identical RNG stream positions afterwards, across two
/// successive rounds (exercising EF/Top-K state evolution), at edge dims
/// (d = 1 included in `rand_dim`) and for the all-zero vector (where
/// QSGD still draws d uniforms but TernGrad draws none).
#[test]
fn prop_baseline_fused_encode_matches_seed_scalar() {
    check("baseline_encode_scalar", 30, |rng| {
        let d = rand_dim(rng);
        let q = [2u32, 8, 16, 255][rng.next_below(4) as usize];
        let zero = rng.next_below(5) == 0;
        let center = rng.uniform(-20.0, 20.0);
        // Draw from a coarse grid half the time so Top-K sees magnitude
        // ties (the tie-break parity matters).
        let coarse = rng.next_below(2) == 0;
        let draw = |rng: &mut Rng| -> Vec<f64> {
            if zero {
                vec![0.0; d]
            } else if coarse {
                (0..d).map(|_| (rng.next_below(7) as f64 - 3.0) * 0.5).collect()
            } else {
                rand_vec(rng, d, center, 3.0)
            }
        };

        for norm in [QsgdNorm::L2, QsgdNorm::Linf] {
            let mut c = Qsgd::new(d, q, norm);
            let mut r_ref = rng.fork(1);
            let mut r_fused = r_ref.clone();
            for step in 0..2 {
                let x = draw(rng);
                let expect = match norm {
                    QsgdNorm::L2 => qsgd_l2_encode_scalar(q - 1, &x, &mut r_ref),
                    QsgdNorm::Linf => qsgd_linf_encode_scalar(q - 1, &x, &mut r_ref),
                };
                let got = c.encode(&x, &mut r_fused);
                assert_eq!(got, expect, "QSGD {norm:?} d={d} q={q} step={step}");
            }
            assert_eq!(r_ref.next_u64(), r_fused.next_u64(), "QSGD rng stream");
        }

        let mut shared = rng.fork(2);
        let mut c = SureshHadamard::new(d, q, &mut shared);
        let mut r_ref = rng.fork(3);
        let mut r_fused = r_ref.clone();
        for step in 0..2 {
            let x = draw(rng);
            let expect = suresh_encode_scalar(&c, &x, &mut r_ref);
            let got = c.encode(&x, &mut r_fused);
            assert_eq!(got, expect, "Suresh d={d} q={q} step={step}");
        }
        assert_eq!(r_ref.next_u64(), r_fused.next_u64(), "Suresh rng stream");

        let mut c = TernGrad::new(d);
        let mut r_ref = rng.fork(4);
        let mut r_fused = r_ref.clone();
        for step in 0..2 {
            let x = draw(rng);
            let expect = terngrad_encode_scalar(&x, &mut r_ref);
            let got = c.encode(&x, &mut r_fused);
            assert_eq!(got, expect, "TernGrad d={d} step={step}");
        }
        assert_eq!(r_ref.next_u64(), r_fused.next_u64(), "TernGrad rng stream");

        let mut c = EfSignSgd::new(d);
        let mut err_ref = vec![0.0; d];
        let mut r_fused = rng.fork(5);
        for step in 0..2 {
            let x = draw(rng);
            let expect = efsign_encode_scalar(&mut err_ref, &x);
            let got = c.encode(&x, &mut r_fused);
            assert_eq!(got, expect, "EF-Sign d={d} step={step}");
            assert_eq!(c.error, err_ref, "EF-Sign error memory step={step}");
        }

        let k = 1 + rng.next_below(d as u64) as usize;
        let mut c = TopK::new(d, k);
        let mut err_ref = vec![0.0; d];
        let mut r_fused = rng.fork(6);
        for step in 0..2 {
            let x = draw(rng);
            let expect = topk_encode_scalar(k, &mut err_ref, &x);
            let got = c.encode(&x, &mut r_fused);
            assert_eq!(got, expect, "TopK d={d} k={k} step={step} coarse={coarse}");
        }
    });
}

/// `encode_into` ≡ `encode` (stale scratch included) and `decode_into` ≡
/// `decode`, bit for bit, for every baseline comparator — stateful ones
/// run two rounds on twin instances so error memory evolves identically.
#[test]
fn prop_baseline_encode_into_matches_encode() {
    check("baseline_encode_into", 30, |rng| {
        let d = rand_dim(rng);
        let seed = rng.next_u64();
        let k = 1 + rng.next_below(d as u64) as usize;
        let specs = [
            CodecSpec::QsgdL2 { q: 16 },
            CodecSpec::QsgdLinf { q: 16 },
            CodecSpec::Hadamard { q: 16 },
            CodecSpec::Vqsgd { reps: 3 },
            CodecSpec::EfSign,
            CodecSpec::PowerSgd { rank: 1 },
            CodecSpec::TernGrad,
            CodecSpec::TopK { k },
            CodecSpec::Full,
        ];
        for spec in specs {
            let mut a = spec.build(d, 1.0, seed, 0);
            let mut b = spec.build(d, 1.0, seed, 0);
            let mut ra = rng.fork(41);
            let mut rb = ra.clone();
            let mut scratch = Message {
                bytes: vec![0x5A; 9],
                bits: 72,
            };
            for step in 0..2 {
                let x = rand_vec(rng, d, 5.0, 2.0);
                let m = a.encode(&x, &mut ra);
                b.encode_into(&x, &mut rb, &mut scratch);
                assert_eq!(scratch, m, "{} step={step} d={d}", spec.label());
                let z = a.decode(&m, &x);
                let mut z2 = vec![-9.0; d];
                a.decode_into(&m, &x, &mut z2);
                assert_eq!(z, z2, "{} decode_into step={step}", spec.label());
            }
        }
    });
}

/// Baseline fold kernels at arbitrary dims: `decode_accumulate_into` ≡
/// decode + axpy and `decode_accumulate_range` ≡ the slice of it, bit
/// for bit, with *misaligned* chunk boundaries (every baseline has
/// fold_chunk_align = 1), stale accumulators, the all-zero vector, and
/// d = 1.
#[test]
fn prop_baseline_fold_kernels_bitwise_any_dim() {
    check("baseline_fold", 30, |rng| {
        let d = rand_dim(rng);
        let zero = rng.next_below(6) == 0;
        let seed = rng.next_u64();
        let k = 1 + rng.next_below(d as u64) as usize;
        let specs = [
            CodecSpec::QsgdL2 { q: 16 },
            CodecSpec::QsgdLinf { q: 8 },
            CodecSpec::Hadamard { q: 16 },
            CodecSpec::Vqsgd { reps: 4 },
            CodecSpec::EfSign,
            CodecSpec::PowerSgd { rank: 2 },
            CodecSpec::TernGrad,
            CodecSpec::TopK { k },
            CodecSpec::Full,
        ];
        for spec in specs {
            let mut codec = spec.build(d, 1.0, seed, 1);
            let x = if zero {
                vec![0.0; d]
            } else {
                rand_vec(rng, d, -3.0, 8.0)
            };
            let mut er = rng.fork(7);
            let msg = codec.encode(&x, &mut er);
            let weight = rng.uniform(-2.0, 2.0);
            let stale = rand_vec(rng, d, 0.0, 4.0);
            let mut z = vec![0.0; d];
            codec.decode_into(&msg, &x, &mut z);
            let mut expect = stale.clone();
            axpy(&mut expect, weight, &z);
            let mut acc = stale.clone();
            codec.decode_accumulate_into(&msg, &x, weight, &mut acc);
            assert_eq!(acc, expect, "{} fused d={d} zero={zero}", spec.label());
            let lo = rng.next_below(d as u64) as usize;
            let len = 1 + rng.next_below((d - lo) as u64) as usize;
            let mut acc_r = stale[lo..lo + len].to_vec();
            codec.decode_accumulate_range(&msg, &x, weight, lo, &mut acc_r);
            assert_eq!(
                acc_r,
                expect[lo..lo + len],
                "{} range lo={lo} len={len} d={d}",
                spec.label()
            );
        }
    });
}

/// Chunk-parallel encode for the fixed-width baselines: any chunk size,
/// ragged dims (Suresh pads to a power of two), headers riding the first
/// chunk — bit-identical to the sequential encode, with the RNG stream
/// and (for EF-Sign) error memory replayed from clones.
#[test]
fn prop_baseline_encode_chunked_matches_sequential() {
    fn check_one<C: VectorCodec + Sync + Clone>(
        codec: &mut C,
        x: &[f64],
        rng: &mut Rng,
        chunk: usize,
    ) {
        let pristine = codec.clone();
        let r0 = rng.clone();
        let expect = codec.encode(x, rng);
        let mut c = pristine;
        let mut msg = Message {
            bytes: vec![0xEE; 3],
            bits: 24,
        };
        dme::quant::encode_chunked(&mut c, x, &mut r0.clone(), &mut msg, chunk);
        assert_eq!(msg, expect, "{} chunk={chunk} d={}", c.name(), x.len());
    }

    check("baseline_chunked", 30, |rng| {
        let d = rand_dim(rng);
        let q = [2u32, 8, 16][rng.next_below(3) as usize];
        let chunk = 1 + rng.next_below(100) as usize;
        let x = rand_vec(rng, d, 4.0, 6.0);
        let mut enc_rng = rng.fork(51);
        check_one(&mut Qsgd::new(d, q, QsgdNorm::L2), &x, &mut enc_rng, chunk);
        check_one(&mut Qsgd::new(d, q, QsgdNorm::Linf), &x, &mut enc_rng, chunk);
        let mut shared = rng.fork(52);
        check_one(
            &mut SureshHadamard::new(d, q, &mut shared),
            &x,
            &mut enc_rng,
            chunk,
        );
        check_one(&mut TernGrad::new(d), &x, &mut enc_rng, chunk);
        let mut ef = EfSignSgd::new(d);
        // Warm the error memory so the chunked replay carries state.
        let _ = ef.encode(&x, &mut enc_rng);
        check_one(&mut ef, &x, &mut enc_rng, chunk);
    });
}

/// SIMD lanes ≡ scalar twins, bit for bit: every dispatched f64 kernel
/// in `dme::simd` against its always-compiled scalar reference, across
/// ragged lengths (0, 1, and tails around the 4-lane width), subnormal
/// inputs, negative zero (compared via `to_bits` — `-0.0 == 0.0` under
/// `PartialEq`, which would mask a sign flip), exact ties, and large
/// magnitudes. Without `--features simd` this pins the trivial identity;
/// with it, it pins the AVX2 lanes against the same references.
#[test]
fn prop_simd_float_kernels_bitwise_match_scalar() {
    use dme::simd;
    fn edge(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n)
            .map(|_| match rng.next_below(8) {
                0 => -0.0,
                1 => 0.0,
                2 => f64::from_bits(rng.next_u64() & 0xF_FFFF_FFFF_FFFF), // subnormal
                3 => (rng.next_below(81) as f64 - 40.0) * 0.25,           // exact ties
                4 => rng.uniform(-1e15, 1e15),
                _ => rng.uniform(-10.0, 10.0),
            })
            .collect()
    }
    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }
    check("simd_float_kernels", 60, |rng| {
        let n = [0usize, 1, 2, 3, 4, 5, 7, 8, 15, 33, 64, 65][rng.next_below(12) as usize];
        let a = edge(rng, n);
        let b = edge(rng, n);
        let c = edge(rng, n);
        let e = edge(rng, n);
        let scale = rng.uniform(-3.0, 3.0);

        let (mut l1, mut h1) = (a.clone(), b.clone());
        let (mut l2, mut h2) = (a.clone(), b.clone());
        simd::butterfly2(&mut l1, &mut h1);
        simd::butterfly2_scalar(&mut l2, &mut h2);
        assert_eq!((bits(&l1), bits(&h1)), (bits(&l2), bits(&h2)), "butterfly2 n={n}");

        let (mut l1, mut h1) = (a.clone(), b.clone());
        let (mut l2, mut h2) = (a.clone(), b.clone());
        simd::butterfly2_scaled(&mut l1, &mut h1, scale);
        simd::butterfly2_scaled_scalar(&mut l2, &mut h2, scale);
        assert_eq!((bits(&l1), bits(&h1)), (bits(&l2), bits(&h2)), "scaled n={n}");

        let (mut l1, mut h1) = (a.clone(), b.clone());
        let (mut l2, mut h2) = (a.clone(), b.clone());
        simd::butterfly2_diag(&mut l1, &mut h1, &c, &e);
        simd::butterfly2_diag_scalar(&mut l2, &mut h2, &c, &e);
        assert_eq!((bits(&l1), bits(&h1)), (bits(&l2), bits(&h2)), "diag n={n}");

        let mut q = [a.clone(), b.clone(), c.clone(), e.clone()];
        let mut r = q.clone();
        {
            let [q0, q1, q2, q3] = &mut q;
            simd::butterfly4(q0, q1, q2, q3);
            let [r0, r1, r2, r3] = &mut r;
            simd::butterfly4_scalar(r0, r1, r2, r3);
        }
        for (g, s) in q.iter().zip(&r) {
            assert_eq!(bits(g), bits(s), "butterfly4 n={n}");
        }

        let mut o1 = vec![0.0; n];
        let mut o2 = vec![0.0; n];
        simd::quantize_scaled(&a, &b, scale, &mut o1);
        simd::quantize_scaled_scalar(&a, &b, scale, &mut o2);
        assert_eq!(bits(&o1), bits(&o2), "quantize_scaled n={n}");
        simd::scale_offset(&a, &b, scale, &mut o1);
        simd::scale_offset_scalar(&a, &b, scale, &mut o2);
        assert_eq!(bits(&o1), bits(&o2), "scale_offset n={n}");
        let (isq, iq) = (rng.uniform(0.01, 4.0), rng.uniform(0.01, 1.0));
        simd::fold_decode_indices(&a, &b, &c, isq, iq, &mut o1);
        simd::fold_decode_indices_scalar(&a, &b, &c, isq, iq, &mut o2);
        assert_eq!(bits(&o1), bits(&o2), "fold_decode_indices n={n}");

        let words: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        simd::uniform_from_bits(&words, &mut o1);
        simd::uniform_from_bits_scalar(&words, &mut o2);
        assert_eq!(bits(&o1), bits(&o2), "uniform_from_bits n={n}");
    });
}

/// SIMD field pack/unpack ≡ scalar twins for every width 1–64, every
/// field count that fits a word, and arbitrary base offsets — the exact
/// contracts `BitWriter::push_block` / `BitReader::read_block` dispatch
/// under. (Width 0 never reaches these kernels: both block entry points
/// early-return on it, which `prop_push_block`/`prop_read_block` pin.)
#[test]
fn prop_simd_field_pack_unpack_bitwise_all_widths() {
    use dme::simd;
    check("simd_fields", 120, |rng| {
        let width = 1 + rng.next_below(64) as u32;
        let mask = if width == 64 {
            u64::MAX
        } else {
            (1u64 << width) - 1
        };
        let max_fields = (64 / width) as u64;
        let count = rng.next_below(max_fields + 1) as usize;
        let base_room = 64 - count as u32 * width;
        let base = rng.next_below(base_room as u64 + 1) as u32;
        let vals: Vec<u64> = (0..count).map(|_| rng.next_u64() & mask).collect();
        assert_eq!(
            simd::pack_fields(&vals, width, base),
            simd::pack_fields_scalar(&vals, width, base),
            "pack width={width} count={count} base={base}"
        );
        let w = rng.next_u64();
        let mut o1 = vec![0u64; count];
        let mut o2 = vec![0u64; count];
        simd::unpack_fields(w, width, mask, &mut o1);
        simd::unpack_fields_scalar(w, width, mask, &mut o2);
        assert_eq!(o1, o2, "unpack width={width} count={count}");
    });
}

/// The bulk uniform fill stays stream-identical to repeated `next_f64`
/// across the SIMD staging-block boundary (256 words): same bits, same
/// final generator state, for lengths straddling 0, 1, the block edge,
/// and multiple blocks.
#[test]
fn prop_bulk_uniform_fill_stream_identical_across_chunk_boundary() {
    check("bulk_uniform_chunks", 30, |rng| {
        let n = [0usize, 1, 5, 255, 256, 257, 700, 1024][rng.next_below(8) as usize];
        let seed = rng.next_u64();
        let mut bulk = Rng::new(seed);
        let mut scalar = Rng::new(seed);
        let mut out = vec![0.0; n];
        bulk.fill_uniform(&mut out);
        for (i, &o) in out.iter().enumerate() {
            assert_eq!(o.to_bits(), scalar.next_f64().to_bits(), "i={i} n={n}");
        }
        assert_eq!(bulk.next_u64(), scalar.next_u64(), "state after fill n={n}");
    });
}

/// Pool determinism, write side: the chunk-sharded encode is
/// bit-identical to the sequential encode for pool sizes 1, 2 and 5, for
/// repeated calls on the same pool, and on the shared global pool — the
/// fixed shard→worker assignment and task-order stitching mean
/// scheduling can never reach the wire.
#[test]
fn prop_pool_sharded_encode_bit_identical_across_pool_sizes() {
    use dme::pool::ChunkPool;
    check("pool_encode_determinism", 12, |rng| {
        let d = [64usize, 257, 1024][rng.next_below(3) as usize];
        let q = rand_q(rng);
        let chunk = 1 + rng.next_below(64) as usize;
        let mut shared = rng.fork(3);
        let mut codec = LatticeQuantizer::from_y(d, q, 1.0, &mut shared);
        let x = rand_vec(rng, d, 2.0, 5.0);
        let enc_rng = rng.fork(4);
        let expect = codec.encode(&x, &mut enc_rng.clone());
        for size in [1usize, 2, 5] {
            let pool = ChunkPool::new(size);
            for repeat in 0..2 {
                let mut msg = Message {
                    bytes: vec![0xC3; 5],
                    bits: 40,
                };
                dme::quant::encode_chunked_on(
                    &pool,
                    &mut codec,
                    &x,
                    &mut enc_rng.clone(),
                    &mut msg,
                    chunk,
                );
                assert_eq!(msg, expect, "pool size {size} repeat {repeat}");
            }
        }
        let mut msg = Message {
            bytes: Vec::new(),
            bits: 0,
        };
        dme::quant::encode_chunked(&mut codec, &x, &mut enc_rng.clone(), &mut msg, chunk);
        assert_eq!(msg, expect, "global pool");
    });
}

/// Pool determinism, read side: the chunk-sharded fold is bit-identical
/// to the sequential streaming fold for pool sizes 1, 2 and 5 and on the
/// shared global pool — per coordinate the additions happen in the same
/// pinned part order on every worker layout.
#[test]
fn prop_pool_sharded_fold_bit_identical_across_pool_sizes() {
    use dme::coordinator::{fold_mean, fold_mean_chunked, fold_mean_chunked_on, FoldPart};
    use dme::pool::ChunkPool;
    check("pool_fold_determinism", 12, |rng| {
        let d = [33usize, 257, 600][rng.next_below(3) as usize];
        let n = 2 + rng.next_below(6) as usize;
        let q = rand_q(rng);
        let chunk = 1 + rng.next_below(64) as usize;
        let mut shared = rng.fork(5);
        let mut codec = LatticeQuantizer::from_y(d, q, 1.0, &mut shared);
        let inputs: Vec<Vec<f64>> = (0..n).map(|_| rand_vec(rng, d, 10.0, 0.45)).collect();
        let reference = inputs[0].clone();
        let mut er = rng.fork(6);
        let msgs: Vec<Message> = inputs[1..]
            .iter()
            .map(|x| codec.encode(x, &mut er))
            .collect();
        let mut parts = vec![FoldPart::Own(&inputs[0])];
        parts.extend(msgs.iter().map(FoldPart::Encoded));
        let mut expect = vec![0.0; d];
        fold_mean(&codec, &parts, &reference, &mut expect);
        for size in [1usize, 2, 5] {
            let pool = ChunkPool::new(size);
            let mut out = vec![-7.0; d];
            fold_mean_chunked_on(&pool, &codec, &parts, &reference, &mut out, chunk);
            assert_eq!(out, expect, "pool size {size}");
        }
        let mut out = vec![9.0; d];
        fold_mean_chunked(&codec, &parts, &reference, &mut out, chunk);
        assert_eq!(out, expect, "global pool");
    });
}
