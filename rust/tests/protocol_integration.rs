//! Protocol-level integration tests: the paper's theorems as executable
//! contracts over the full coordinator + sim + quant stack.

use dme::coordinator::{
    mean_estimation_star, mean_estimation_tree, robust_variance_reduction, vr_y_bound, CodecSpec,
};
use dme::linalg::{dist2, mean_vecs};
use dme::rng::Rng;
use dme::sim::summarize;

fn gen_inputs(n: usize, d: usize, center: f64, spread: f64, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            (0..d)
                .map(|_| center + rng.uniform(-spread / 2.0, spread / 2.0))
                .collect()
        })
        .collect()
}

/// Theorem 2 shape: output variance scales as ~1/q² (per-coordinate
/// uniform error), measured end-to-end through the star protocol.
#[test]
fn variance_scales_inverse_q_squared() {
    let n = 8;
    let d = 64;
    let y = 1.0;
    let inputs = gen_inputs(n, d, 500.0, y, 1);
    let mu = mean_vecs(&inputs);
    let measure = |q: u32| {
        let trials = 120;
        let mut acc = 0.0;
        for t in 0..trials {
            let o = mean_estimation_star(&inputs, &CodecSpec::Lq { q }, y, 2, t);
            acc += dist2(o.estimate(), &mu).powi(2);
        }
        acc / trials as f64
    };
    let v8 = measure(8);
    let v32 = measure(32);
    let ratio = v8 / v32;
    // (32/8)² = 16 in the limit; wide tolerance for sampling noise.
    assert!(
        ratio > 6.0 && ratio < 40.0,
        "v8/v32 = {ratio} (expected ~16)"
    );
}

/// Theorem 3 shape: averaging n inputs reduces variance ~n-fold vs one
/// input, through the full quantized pipeline.
#[test]
fn variance_reduction_scales_with_n() {
    let d = 32;
    let sigma_c = 0.2; // per-coordinate input std
    let mut errs = Vec::new();
    for &n in &[2usize, 8, 32] {
        let trials = 60;
        let mut acc = 0.0;
        for t in 0..trials {
            let mut rng = Rng::new(1000 + t);
            let nabla: Vec<f64> = (0..d).map(|_| 100.0 + rng.next_gaussian()).collect();
            let inputs: Vec<Vec<f64>> = (0..n)
                .map(|_| {
                    nabla
                        .iter()
                        .map(|v| v + sigma_c * rng.next_gaussian())
                        .collect()
                })
                .collect();
            // y via the Chebyshev reduction with a fine q so quantization
            // noise is negligible next to sampling noise.
            let y = vr_y_bound(sigma_c * (d as f64).sqrt(), n, 4.0);
            let o = mean_estimation_star(&inputs, &CodecSpec::Lq { q: 4096 }, y, 3, t as u64);
            acc += dist2(o.estimate(), &nabla).powi(2);
        }
        errs.push(acc / trials as f64);
    }
    // err(n=2)/err(n=32) ≈ 16.
    let r = errs[0] / errs[2];
    assert!(r > 6.0, "variance must drop ~n-fold: {errs:?} (ratio {r})");
}

/// Theorem 4 behavior: expected bits stay near the base cost when inputs
/// are concentrated, and only the outlier pair escalates otherwise.
#[test]
fn robust_vr_bits_concentrate() {
    let n = 12;
    let d = 64;
    let sigma = 0.5;
    let inputs = gen_inputs(n, d, 50.0, sigma, 7);
    let out = robust_variance_reduction(&inputs, sigma, 16, 8, 0);
    assert!(out.rounds_stage1.iter().all(|&r| r == 1));
    let s = summarize(&out.traffic);
    // Base cost: d·⌈log2 16⌉ + 32 hash = 288 bits forward per worker.
    let base = (d as u64) * 4 + 32;
    let non_leader_max = out
        .traffic
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != out.leader)
        .map(|(_, t)| t.sent_bits)
        .max()
        .unwrap();
    assert_eq!(non_leader_max, base);
    assert!(s.max_sent >= base * (n as u64 - 1)); // the leader's broadcast
}

/// Agreement holds across every codec family on the star topology
/// (baselines included — they simply ignore the reference).
#[test]
fn star_agreement_for_all_codecs() {
    let n = 5;
    let d = 48;
    let inputs = gen_inputs(n, d, 10.0, 0.5, 11);
    for spec in [
        CodecSpec::Lq { q: 16 },
        CodecSpec::Rlq { q: 16 },
        CodecSpec::LqHull { q: 16 },
        CodecSpec::D4 { q: 16 },
        CodecSpec::QsgdL2 { q: 16 },
        CodecSpec::QsgdLinf { q: 16 },
        CodecSpec::Hadamard { q: 16 },
        CodecSpec::Vqsgd { reps: 8 },
        CodecSpec::TernGrad,
        CodecSpec::Full,
    ] {
        let out = mean_estimation_star(&inputs, &spec, 1.0, 13, 0);
        for o in &out.outputs {
            assert_eq!(o, &out.outputs[0], "agreement violated for {}", spec.label());
        }
    }
}

/// Star and tree topologies agree with each other (both estimate μ) and
/// their traffic profiles differ exactly as the paper describes: star
/// concentrates cost at the leader, tree spreads it.
#[test]
fn star_vs_tree_traffic_profile() {
    let n = 16;
    let d = 64;
    let y = 1.0;
    let inputs = gen_inputs(n, d, 0.0, y, 17);
    let mu = mean_vecs(&inputs);

    let star = mean_estimation_star(&inputs, &CodecSpec::Lq { q: 64 }, y, 19, 0);
    let tree = mean_estimation_tree(&inputs, n, y, 19, 0);
    assert!(dist2(star.estimate(), &mu) < 0.2);
    assert!(dist2(tree.estimate(), &mu) < 0.2);

    let st = summarize(&star.traffic);
    let tt = summarize(&tree.traffic);
    // Star: worst machine (leader) ≈ (n−1)× the mean worker cost.
    assert!(st.max_sent as f64 > 5.0 * st.mean_sent);
    // Tree: the worst machine is within a small constant of the mean.
    assert!((tt.max_sent as f64) < 8.0 * tt.mean_sent.max(1.0));
}

/// End-to-end Experiment-5-like run: star SGD with per-round y broadcast
/// converges on a real-shaped dataset from a far-away init.
#[test]
fn star_sgd_cpusmall_like_converges() {
    use dme::coordinator::YPolicy;
    use dme::opt::dist_gd::{run_distributed_gd, GdAggregation, GdConfig};
    let ds = dme::data::gen_cpusmall_like(1024, 5);
    let cfg = GdConfig {
        n_machines: 8,
        lr: 0.3,
        iters: 80,
        seed: 0,
        y0: 200.0,
        y_policy: YPolicy::LeaderMeasured {
            slack: 3.0,
            period: 1,
        },
        w0: Some(vec![-1000.0; ds.dim()]),
        batch_slots: 1,
    };
    let t = run_distributed_gd(
        &ds,
        &GdAggregation::Star(CodecSpec::Lq { q: 16 }),
        &cfg,
    );
    let first = t.loss[0];
    let last = *t.loss.last().unwrap();
    assert!(
        last < first / 100.0,
        "star SGD must make >100x progress: {first} → {last}"
    );
}
