//! Durability integration tests — the crash-recovery contract of the
//! WAL'd cohort table ([`dme::store`] + [`dme::net::cohort`]).
//!
//! The pinned guarantees:
//!
//! - a leader killed mid-round and restarted over the same data dir
//!   produces **bit-identical** renormalized (partial) means to an
//!   uninterrupted leader;
//! - torn or bit-flipped WAL tails are truncated back to the last valid
//!   record boundary — reported as a typed [`TailTruncation`], never a
//!   panic, and never costing a record *before* the damage;
//! - replay is idempotent (recover twice ≡ recover once) and the result
//!   is invariant to the fold pool size and to spill-to-disk pressure.

use dme::coordinator::{fold_mean_chunked_on, CodecSpec, FoldPart};
use dme::net::cohort::{
    client_encoder_rng, cohort_codec, CohortKey, CohortSpec, CohortTable, Submit,
};
use dme::pool::ChunkPool;
use dme::quant::{LatticeQuantizer, Message};
use dme::rng::{hash2, Rng};
use dme::store::{DurabilityOpts, SyncPolicy, MANIFEST_FILE, WAL_FILE};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Fresh per-test scratch dir (no `Date::now` — counter + pid).
fn temp_dir(tag: &str) -> PathBuf {
    static N: AtomicUsize = AtomicUsize::new(0);
    let n = N.fetch_add(1, Ordering::Relaxed);
    let dir = std::env::temp_dir().join(format!("dme-dur-{}-{tag}-{n}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn opts(dir: &Path, sync: SyncPolicy) -> DurabilityOpts {
    DurabilityOpts {
        sync,
        ..DurabilityOpts::new(dir)
    }
}

fn spec(n: usize, d: usize) -> CohortSpec {
    CohortSpec {
        n,
        d,
        spec: CodecSpec::Lq { q: 64 },
        y: 8.0,
        seed: 42,
    }
}

fn encode(cs: &CohortSpec, round: u64, client: usize, x: &[f64]) -> Message {
    let mut codec = cohort_codec(cs, round);
    let mut rng = client_encoder_rng(cs.seed, round, client);
    codec.encode(x, &mut rng)
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Client inputs with per-coordinate structure so wrong fold orders
/// can't accidentally agree.
fn inputs(cs: &CohortSpec, clients: &[usize]) -> Vec<(usize, Message)> {
    clients
        .iter()
        .map(|&c| {
            let x: Vec<f64> = (0..cs.d)
                .map(|j| 3.0 + 0.7 * c as f64 - 0.05 * j as f64)
                .collect();
            (c, encode(cs, 0, c, &x))
        })
        .collect()
}

/// Feed `reports` to a table; all but the last must stay Pending.
fn submit_all(
    table: &mut CohortTable,
    key: CohortKey,
    cs: &CohortSpec,
    reports: &[(usize, Message)],
) {
    for (c, m) in reports {
        match table.submit(key, cs, *c, m, 0, 1_000) {
            Submit::Pending { .. } | Submit::Complete(_) => {}
            other => panic!("client {c}: unexpected {other:?}"),
        }
    }
}

/// The uninterrupted leader's result for `reports` (closing at the
/// deadline when fewer than `n` report).
fn plain_result(
    key: CohortKey,
    cs: &CohortSpec,
    reports: &[(usize, Message)],
) -> dme::net::cohort::RoundResult {
    let mut table = CohortTable::new();
    for (c, m) in reports {
        if let Submit::Complete(r) = table.submit(key, cs, *c, m, 0, 1_000) {
            return r;
        }
    }
    let mut closed = table.expire(1_000);
    assert_eq!(closed.len(), 1, "exactly one round closes");
    closed.remove(0).1
}

// --- the acceptance pin ----------------------------------------------

/// A leader killed mid-round (k=3 of n=5 reports WAL'd, table dropped
/// without closing) restarts, replays the log, and its deadline-closed
/// partial mean is bit-identical to an uninterrupted leader's.
#[test]
fn killed_leader_recovers_bit_identical_partial_mean() {
    let dir = temp_dir("kill-partial");
    let cs = spec(5, 24);
    let key = CohortKey { cohort: 11, round: 0 };
    let reports = inputs(&cs, &[0, 2, 3]);
    let want = plain_result(key, &cs, &reports);
    assert!(want.partial);
    assert_eq!((want.received, want.expected), (3, 5));
    // Killed leader: every accepted report hit the WAL first.
    {
        let (mut t, rec) = CohortTable::durable(&opts(&dir, SyncPolicy::Always)).expect("open");
        assert_eq!(rec.reports_replayed, 0);
        submit_all(&mut t, key, &cs, &reports);
        // kill -9: dropped here without closing the round.
    }
    let (mut t, rec) = CohortTable::durable(&opts(&dir, SyncPolicy::Always)).expect("recover");
    assert_eq!(rec.reports_replayed, 3);
    assert_eq!(rec.rounds_reopened, 1);
    assert_eq!(rec.warnings, 0);
    assert!(rec.tail.is_none());
    let closed = t.expire(1_000);
    assert_eq!(closed.len(), 1);
    let got = &closed[0].1;
    assert_eq!((got.received, got.expected, got.partial), (3, 5, true));
    assert_eq!(
        bits(&got.estimate),
        bits(&want.estimate),
        "recovered partial mean must be bit-identical to the uninterrupted fold"
    );
    assert_eq!(t.store_errors(), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Recovery resumes (not restarts) an open round: the missing client
/// reports *after* the restart and completes it, bit-identical to a
/// never-interrupted full round.
#[test]
fn recovery_resumes_open_round_and_finishes_it() {
    let dir = temp_dir("resume");
    let cs = spec(3, 16);
    let key = CohortKey { cohort: 1, round: 0 };
    let reports = inputs(&cs, &[0, 1, 2]);
    let want = plain_result(key, &cs, &reports);
    assert!(!want.partial);
    {
        let (mut t, _) = CohortTable::durable(&opts(&dir, SyncPolicy::OnClose)).expect("open");
        submit_all(&mut t, key, &cs, &reports[..2]);
    }
    let (mut t, rec) = CohortTable::durable(&opts(&dir, SyncPolicy::OnClose)).expect("recover");
    assert_eq!((rec.reports_replayed, rec.rounds_reopened), (2, 1));
    let (c, m) = &reports[2];
    let got = match t.submit(key, &cs, *c, m, 0, 1_000) {
        Submit::Complete(r) => r,
        other => panic!("expected Complete, got {other:?}"),
    };
    assert_eq!(bits(&got.estimate), bits(&want.estimate));
    // All rounds closed: the checkpoint truncated the log.
    assert_eq!(t.wal_bytes(), Some(0));
    let _ = std::fs::remove_dir_all(&dir);
}

// --- WAL edge cases --------------------------------------------------

/// An empty (or missing) log recovers to an empty table, twice.
#[test]
fn empty_log_recovers_to_empty_table() {
    let dir = temp_dir("empty");
    for pass in 0..2 {
        let (t, rec) = CohortTable::durable(&opts(&dir, SyncPolicy::Never)).expect("open");
        assert_eq!(rec.reports_replayed, 0, "pass {pass}");
        assert_eq!(rec.rounds_reopened, 0);
        assert_eq!(rec.wal_bytes, 0);
        assert!(rec.tail.is_none());
        assert_eq!(t.open_rounds(), 0);
        assert_eq!(t.wal_bytes(), Some(0));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A final record torn at *every* possible cut point is truncated back
/// to the last valid boundary; the records before it all survive.
#[test]
fn torn_final_record_is_truncated_not_fatal() {
    let dir = temp_dir("torn-src");
    let cs = spec(3, 8);
    let key = CohortKey { cohort: 7, round: 0 };
    let reports = inputs(&cs, &[0, 1]);
    {
        let (mut t, _) = CohortTable::durable(&opts(&dir, SyncPolicy::Never)).expect("open");
        submit_all(&mut t, key, &cs, &reports);
    }
    let wal = std::fs::read(dir.join(WAL_FILE)).expect("read wal");
    let len1 = u32::from_le_bytes(wal[0..4].try_into().expect("4 bytes")) as usize;
    let boundary = 8 + len1;
    assert!(boundary < wal.len(), "two records on disk");
    for cut in boundary + 1..wal.len() {
        let d2 = temp_dir("torn-cut");
        std::fs::create_dir_all(&d2).expect("mkdir");
        std::fs::write(d2.join(WAL_FILE), &wal[..cut]).expect("write torn wal");
        let (t, rec) = CohortTable::durable(&opts(&d2, SyncPolicy::Never)).expect("recover");
        assert_eq!(rec.reports_replayed, 1, "cut at byte {cut}");
        let tail = rec.tail.expect("torn tail reported");
        assert_eq!(tail.offset, boundary as u64, "cut at byte {cut}");
        assert_eq!(tail.dropped_bytes, (cut - boundary) as u64);
        assert!(
            tail.what == "torn record header" || tail.what == "torn record body",
            "cut at byte {cut}: {}",
            tail.what
        );
        // The file itself was truncated back to the valid prefix.
        let disk = std::fs::metadata(d2.join(WAL_FILE)).expect("stat").len();
        assert_eq!(disk, boundary as u64);
        assert_eq!(t.wal_bytes(), Some(boundary as u64));
        let _ = std::fs::remove_dir_all(&d2);
    }
    // One end-to-end check: recover a torn log, re-report the lost
    // client plus the missing one, match the uninterrupted full round.
    let d3 = temp_dir("torn-refill");
    std::fs::create_dir_all(&d3).expect("mkdir");
    std::fs::write(d3.join(WAL_FILE), &wal[..wal.len() - 1]).expect("write torn wal");
    let (mut t, rec) = CohortTable::durable(&opts(&d3, SyncPolicy::Never)).expect("recover");
    assert_eq!(rec.reports_replayed, 1);
    let all = inputs(&cs, &[0, 1, 2]);
    let want = plain_result(key, &cs, &all);
    submit_all(&mut t, key, &cs, &all[1..2]);
    let got = match t.submit(key, &cs, all[2].0, &all[2].1, 0, 1_000) {
        Submit::Complete(r) => r,
        other => panic!("expected Complete, got {other:?}"),
    };
    assert_eq!(bits(&got.estimate), bits(&want.estimate));
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&d3);
}

/// Bit rot anywhere in a record (its CRC field, its body, its length)
/// truncates from that record's boundary — and only from there.
#[test]
fn bit_flipped_records_truncate_from_the_corruption_point() {
    let dir = temp_dir("flip-src");
    let cs = spec(3, 8);
    let key = CohortKey { cohort: 7, round: 0 };
    let reports = inputs(&cs, &[0, 1]);
    {
        let (mut t, _) = CohortTable::durable(&opts(&dir, SyncPolicy::Never)).expect("open");
        submit_all(&mut t, key, &cs, &reports);
    }
    let wal = std::fs::read(dir.join(WAL_FILE)).expect("read wal");
    let len1 = u32::from_le_bytes(wal[0..4].try_into().expect("4 bytes")) as usize;
    let boundary = 8 + len1;
    // (byte to damage, expected valid offset, expected replays, what)
    let cases: [(usize, u64, u64, &str); 3] = [
        // Record 2's first body byte: its CRC no longer matches.
        (boundary + 8, boundary as u64, 1, "record crc mismatch"),
        // Record 1's stored CRC itself: nothing survives.
        (4, 0, 0, "record crc mismatch"),
        // Record 2's length field forced huge (flip below).
        (boundary, boundary as u64, 1, "impossible record length"),
    ];
    for (i, (pos, offset, replays, what)) in cases.iter().enumerate() {
        let d2 = temp_dir("flip-case");
        std::fs::create_dir_all(&d2).expect("mkdir");
        let mut bytes = wal.clone();
        if *what == "impossible record length" {
            bytes[*pos..*pos + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        } else {
            bytes[*pos] ^= 0x40;
        }
        std::fs::write(d2.join(WAL_FILE), &bytes).expect("write damaged wal");
        let (t, rec) = CohortTable::durable(&opts(&d2, SyncPolicy::Never)).expect("recover");
        assert_eq!(rec.reports_replayed, *replays, "case {i}");
        let tail = rec.tail.expect("damage reported");
        assert_eq!(tail.offset, *offset, "case {i}");
        assert_eq!(tail.what, *what, "case {i}");
        assert_eq!(t.wal_bytes(), Some(*offset), "case {i}");
        let _ = std::fs::remove_dir_all(&d2);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Recovering twice produces the same replay and the same bits as
/// recovering once — replay never appends to the log it is reading.
#[test]
fn replay_is_idempotent_recover_twice_equals_once() {
    let dir = temp_dir("idempotent");
    let cs = spec(5, 24);
    let key = CohortKey { cohort: 11, round: 0 };
    let reports = inputs(&cs, &[0, 2, 3]);
    let want = plain_result(key, &cs, &reports);
    {
        let (mut t, _) = CohortTable::durable(&opts(&dir, SyncPolicy::OnClose)).expect("open");
        submit_all(&mut t, key, &cs, &reports);
    }
    let (t1, r1) = CohortTable::durable(&opts(&dir, SyncPolicy::OnClose)).expect("recover 1");
    let wal_after_first = t1.wal_bytes();
    drop(t1);
    let (mut t2, r2) = CohortTable::durable(&opts(&dir, SyncPolicy::OnClose)).expect("recover 2");
    assert_eq!(r1.reports_replayed, r2.reports_replayed);
    assert_eq!(r1.rounds_reopened, r2.rounds_reopened);
    assert_eq!(r1.wal_bytes, r2.wal_bytes);
    assert_eq!(wal_after_first, t2.wal_bytes());
    let closed = t2.expire(1_000);
    assert_eq!(closed.len(), 1);
    assert_eq!(bits(&closed[0].1.estimate), bits(&want.estimate));
    let _ = std::fs::remove_dir_all(&dir);
}

/// The recovered estimate equals the coordinator's pool-sharded fold at
/// every pool size — recovery is invariant to how the service's fold
/// pool happens to be sized after the restart.
#[test]
fn recovered_estimate_is_pool_size_invariant() {
    let dir = temp_dir("pool");
    let cs = spec(5, 33);
    let key = CohortKey { cohort: 4, round: 0 };
    let reports = inputs(&cs, &[0, 2, 3]);
    {
        let (mut t, _) = CohortTable::durable(&opts(&dir, SyncPolicy::OnClose)).expect("open");
        submit_all(&mut t, key, &cs, &reports);
    }
    let (mut t, _) = CohortTable::durable(&opts(&dir, SyncPolicy::OnClose)).expect("recover");
    let closed = t.expire(1_000);
    assert_eq!(closed.len(), 1);
    let got = &closed[0].1.estimate;
    // The same codec the cohort convention builds, as a concrete Sync
    // type the chunked fold can shard.
    let mut shared = Rng::new(hash2(cs.seed, key.round));
    let codec = LatticeQuantizer::from_y(cs.d, 64, cs.y, &mut shared);
    let zeros = vec![0.0; cs.d];
    let parts: Vec<FoldPart> = reports.iter().map(|(_, m)| FoldPart::Encoded(m)).collect();
    for size in [1usize, 2, 5] {
        let pool = ChunkPool::new(size);
        let mut out = vec![0.0; cs.d];
        fold_mean_chunked_on(&pool, &codec, &parts, &zeros, &mut out, 7);
        assert_eq!(bits(&out), bits(got), "pool size {size}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// --- spill-to-disk runs ----------------------------------------------

/// `mem_budget = 0` forces the round through a spill and several
/// LSM-style compactions (32 reports at a compaction fan-in of 8); the
/// completed estimate is bit-identical to the all-in-RAM fold.
#[test]
fn spilled_round_completes_bit_identical_to_all_in_ram() {
    let dir = temp_dir("spill-full");
    let cs = spec(32, 16);
    let key = CohortKey { cohort: 6, round: 0 };
    let reports = inputs(&cs, &(0..32).collect::<Vec<_>>());
    let want = plain_result(key, &cs, &reports);
    let o = DurabilityOpts {
        mem_budget: 0,
        sync: SyncPolicy::Never,
        ..DurabilityOpts::new(&dir)
    };
    let (mut t, _) = CohortTable::durable(&o).expect("open");
    submit_all(&mut t, key, &cs, &reports[..31]);
    assert_eq!(t.spilled_rounds(), 1, "budget 0 must spill the round");
    let (c, m) = &reports[31];
    let got = match t.submit(key, &cs, *c, m, 0, 1_000) {
        Submit::Complete(r) => r,
        other => panic!("expected Complete, got {other:?}"),
    };
    assert_eq!(bits(&got.estimate), bits(&want.estimate));
    assert_eq!(t.store_errors(), 0);
    // The run was dropped at close and the checkpoint emptied the log.
    assert_eq!(t.wal_bytes(), Some(0));
    let leftover = std::fs::read_dir(&dir)
        .expect("read dir")
        .filter_map(|e| e.ok())
        .filter(|e| e.file_name().to_string_lossy().starts_with("run-"))
        .count();
    assert_eq!(leftover, 0, "no run files survive a closed round");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A spilled *partial* round (13 of 32 report) expires bit-identical to
/// RAM, and a crash while spilled recovers from the WAL alone — the
/// stale run files are garbage-collected, not trusted.
#[test]
fn spilled_partial_round_expires_and_recovers_bit_identical() {
    let dir = temp_dir("spill-partial");
    let cs = spec(32, 16);
    let key = CohortKey { cohort: 9, round: 0 };
    let clients: Vec<usize> = (0..13).map(|i| i * 2).collect();
    let reports = inputs(&cs, &clients);
    let want = plain_result(key, &cs, &reports);
    assert!(want.partial);
    let o = DurabilityOpts {
        mem_budget: 0,
        sync: SyncPolicy::Never,
        ..DurabilityOpts::new(&dir)
    };
    // Leg 1: expire while spilled.
    {
        let (mut t, _) = CohortTable::durable(&o).expect("open");
        submit_all(&mut t, key, &cs, &reports);
        assert_eq!(t.spilled_rounds(), 1);
        let closed = t.expire(1_000);
        assert_eq!(closed.len(), 1);
        assert_eq!(bits(&closed[0].1.estimate), bits(&want.estimate));
        assert_eq!(t.store_errors(), 0);
    }
    // Leg 2: crash while spilled (drop without closing), then recover.
    let dir2 = temp_dir("spill-crash");
    let o2 = DurabilityOpts {
        mem_budget: 0,
        sync: SyncPolicy::Never,
        ..DurabilityOpts::new(&dir2)
    };
    {
        let (mut t, _) = CohortTable::durable(&o2).expect("open");
        submit_all(&mut t, key, &cs, &reports);
        assert_eq!(t.spilled_rounds(), 1, "crashing with a live run on disk");
    }
    let (mut t, rec) = CohortTable::durable(&o2).expect("recover");
    assert!(rec.stale_runs_removed >= 1, "the crashed run file is GC'd");
    assert_eq!(rec.reports_replayed, 13);
    let closed = t.expire(1_000);
    assert_eq!(closed.len(), 1);
    assert_eq!(bits(&closed[0].1.estimate), bits(&want.estimate));
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&dir2);
}

// --- manifest, checkpoint, and GC ------------------------------------

/// A corrupt manifest is rebuilt (flagged in the recovery report), and
/// the WAL replay still recovers the round in full.
#[test]
fn corrupt_manifest_is_rebuilt_not_fatal() {
    let dir = temp_dir("manifest");
    let cs = spec(3, 16);
    let key = CohortKey { cohort: 2, round: 0 };
    let reports = inputs(&cs, &[0, 1, 2]);
    let want = plain_result(key, &cs, &reports);
    {
        let (mut t, _) = CohortTable::durable(&opts(&dir, SyncPolicy::Never)).expect("open");
        submit_all(&mut t, key, &cs, &reports[..2]);
    }
    std::fs::write(dir.join(MANIFEST_FILE), b"not a manifest").expect("clobber manifest");
    let (mut t, rec) = CohortTable::durable(&opts(&dir, SyncPolicy::Never)).expect("recover");
    assert!(rec.manifest_rebuilt);
    assert_eq!(rec.reports_replayed, 2);
    let (c, m) = &reports[2];
    let got = match t.submit(key, &cs, *c, m, 0, 1_000) {
        Submit::Complete(r) => r,
        other => panic!("expected Complete, got {other:?}"),
    };
    assert_eq!(bits(&got.estimate), bits(&want.estimate));
    let _ = std::fs::remove_dir_all(&dir);
}

/// A round that closed gracefully before the crash replays into the
/// finished cache: late clients still get the original bits back. An
/// unrelated open round blocks the checkpoint so the history survives.
#[test]
fn graceful_close_replays_and_serves_late_clients() {
    let dir = temp_dir("late");
    let cs = spec(2, 12);
    let key_a = CohortKey { cohort: 1, round: 0 };
    let key_b = CohortKey { cohort: 2, round: 0 };
    let a = inputs(&cs, &[0, 1]);
    let b = inputs(&cs, &[0]);
    let res_a;
    {
        let (mut t, _) = CohortTable::durable(&opts(&dir, SyncPolicy::OnClose)).expect("open");
        // B opens first and stays open, so A's close cannot checkpoint
        // the log away.
        submit_all(&mut t, key_b, &cs, &b);
        submit_all(&mut t, key_a, &cs, &a[..1]);
        res_a = match t.submit(key_a, &cs, a[1].0, &a[1].1, 0, 1_000) {
            Submit::Complete(r) => r,
            other => panic!("expected Complete, got {other:?}"),
        };
        assert!(t.wal_bytes().unwrap() > 0, "open round B blocks the checkpoint");
    }
    let (mut t, rec) = CohortTable::durable(&opts(&dir, SyncPolicy::OnClose)).expect("recover");
    assert_eq!(rec.reports_replayed, 3);
    assert_eq!(rec.rounds_reopened, 1);
    assert_eq!(rec.warnings, 0);
    // A late duplicate for the closed round gets the original bits.
    match t.submit(key_a, &cs, 0, &a[0].1, 5, 1_000) {
        Submit::Late(r) => assert_eq!(bits(&r.estimate), bits(&res_a.estimate)),
        other => panic!("expected Late, got {other:?}"),
    }
    // Finishing B empties the table and checkpoints the log.
    let b1 = inputs(&cs, &[0, 1]);
    match t.submit(key_b, &cs, b1[1].0, &b1[1].1, 0, 1_000) {
        Submit::Complete(_) => {}
        other => panic!("expected Complete, got {other:?}"),
    }
    assert_eq!(t.wal_bytes(), Some(0));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Once every round has closed, the checkpoint truncates the WAL: the
/// next recovery replays nothing.
#[test]
fn checkpoint_truncates_wal_after_all_rounds_close() {
    let dir = temp_dir("checkpoint");
    let cs = spec(2, 8);
    let key = CohortKey { cohort: 3, round: 0 };
    let reports = inputs(&cs, &[0, 1]);
    {
        let (mut t, _) = CohortTable::durable(&opts(&dir, SyncPolicy::OnClose)).expect("open");
        submit_all(&mut t, key, &cs, &reports[..1]);
        match t.submit(key, &cs, reports[1].0, &reports[1].1, 0, 1_000) {
            Submit::Complete(_) => {}
            other => panic!("expected Complete, got {other:?}"),
        }
        assert_eq!(t.wal_bytes(), Some(0));
    }
    assert_eq!(std::fs::metadata(dir.join(WAL_FILE)).expect("stat").len(), 0);
    let (_, rec) = CohortTable::durable(&opts(&dir, SyncPolicy::OnClose)).expect("recover");
    assert_eq!(rec.reports_replayed, 0);
    assert_eq!(rec.rounds_reopened, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Stray `run-*.dat` files from a dead process are deleted at open —
/// recovery only ever trusts the WAL.
#[test]
fn stray_run_files_are_garbage_collected_at_open() {
    let dir = temp_dir("stray");
    std::fs::create_dir_all(&dir).expect("mkdir");
    std::fs::write(dir.join("run-99.dat"), b"stale garbage from a dead process").expect("write");
    let (_, rec) = CohortTable::durable(&opts(&dir, SyncPolicy::Never)).expect("open");
    assert_eq!(rec.stale_runs_removed, 1);
    assert!(!dir.join("run-99.dat").exists(), "stray run deleted");
    let _ = std::fs::remove_dir_all(&dir);
}
