//! Cross-layer integration: the AOT-compiled Pallas/JAX artifacts must
//! agree with the Rust-native implementations on the same inputs.
//!
//! These tests require `make artifacts`; they skip (with a notice) when
//! the artifact directory is absent so `cargo test` works on a fresh
//! checkout.

use dme::quant::{CubicLattice, LatticeQuantizer, VectorCodec};
use dme::rng::Rng;
use dme::runtime::Engine;

fn engine() -> Option<Engine> {
    match Engine::discover() {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping runtime integration: {e}");
            None
        }
    }
}

fn f32v(xs: &[f64]) -> Vec<f32> {
    xs.iter().map(|&v| v as f32).collect()
}

#[test]
fn aot_encode_decode_matches_native() {
    let Some(eng) = engine() else { return };
    let enc = eng.load("lattice_encode_d128_q8").unwrap();
    let dec = eng.load("lattice_decode_d128_q8").unwrap();
    let d = 128;
    let q = 8;
    let mut rng = Rng::new(5);
    for trial in 0..20 {
        let s = 0.05 + 0.1 * trial as f64;
        let offset: Vec<f64> = (0..d).map(|_| rng.uniform(-s / 2.0, s / 2.0)).collect();
        let x: Vec<f64> = (0..d).map(|_| rng.uniform(-50.0, 50.0)).collect();
        let radius = (q as f64 - 1.0) * s / 2.0 * 0.95;
        let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(-radius, radius)).collect();

        let native = LatticeQuantizer::new(CubicLattice::with_offset(s, offset.clone()), q);
        let (msg, _pt) = native.encode_with_point(&x);
        let zn = native.decode(&msg, &xv);

        let s_arr = [s as f32];
        let colors = enc
            .run_f32(&[(&f32v(&x), &[d]), (&f32v(&offset), &[d]), (&s_arr, &[1])])
            .unwrap();
        let za = dec
            .run_f32(&[
                (&colors[0], &[d]),
                (&f32v(&xv), &[d]),
                (&f32v(&offset), &[d]),
                (&s_arr, &[1]),
            ])
            .unwrap();
        for i in 0..d {
            assert!(
                (za[0][i] as f64 - zn[i]).abs() < 1e-3,
                "trial {trial} coord {i}: aot {} native {}",
                za[0][i],
                zn[i]
            );
        }
    }
}

#[test]
fn aot_rotation_matches_native_fwht() {
    let Some(eng) = engine() else { return };
    let rot = eng.load("rotate_d128").unwrap();
    let unrot = eng.load("unrotate_d128").unwrap();
    let d = 128;
    let mut rng = Rng::new(6);
    let x: Vec<f64> = (0..d).map(|_| rng.next_gaussian() * 3.0).collect();
    let sign: Vec<f64> = (0..d).map(|_| rng.next_sign()).collect();

    // Native: H(x·sign)
    let mut native: Vec<f64> = x.iter().zip(&sign).map(|(a, s)| a * s).collect();
    dme::quant::hadamard::fwht(&mut native);

    let y = rot
        .run_f32(&[(&f32v(&x), &[d]), (&f32v(&sign), &[d])])
        .unwrap();
    for i in 0..d {
        assert!((y[0][i] as f64 - native[i]).abs() < 1e-3);
    }
    // And the inverse returns x.
    let back = unrot
        .run_f32(&[(&y[0], &[d]), (&f32v(&sign), &[d])])
        .unwrap();
    for i in 0..d {
        assert!((back[0][i] as f64 - x[i]).abs() < 1e-3);
    }
}

#[test]
fn aot_lsq_grad_matches_native() {
    let Some(eng) = engine() else { return };
    let g = eng.load("lsq_grad_s512_d100").unwrap();
    let ds = dme::data::gen_lsq(512, 100, 9);
    let w: Vec<f64> = (0..100).map(|i| (i as f64) * 0.01 - 0.5).collect();
    let native = ds.full_gradient(&w);
    let out = g
        .run_f32(&[
            (&f32v(&ds.a.data), &[512, 100]),
            (&f32v(&w), &[100]),
            (&f32v(&ds.b), &[512]),
        ])
        .unwrap();
    for i in 0..100 {
        let rel = (out[0][i] as f64 - native[i]).abs() / (1.0 + native[i].abs());
        assert!(rel < 1e-4, "coord {i}: aot {} native {}", out[0][i], native[i]);
    }
}

#[test]
fn aot_me_round_matches_star_semantics() {
    let Some(eng) = engine() else { return };
    let gr = eng.load("me_round_n7_d128_q16").unwrap();
    let d = 128;
    let q = 16u32;
    let s = 0.25f64;
    let n_workers = 7;
    let mut rng = Rng::new(11);
    let offset: Vec<f64> = (0..d).map(|_| rng.uniform(-s / 2.0, s / 2.0)).collect();
    let x_leader: Vec<f64> = (0..d).map(|_| 10.0 + rng.uniform(-0.4, 0.4)).collect();
    let lat = CubicLattice::with_offset(s, offset.clone());
    let native = LatticeQuantizer::new(lat, q);

    // Worker colors + native decoded points.
    let mut colors_flat = Vec::with_capacity(n_workers * d);
    let mut decoded = Vec::with_capacity(n_workers);
    for _ in 0..n_workers {
        let xw: Vec<f64> = x_leader.iter().map(|v| v + rng.uniform(-0.4, 0.4)).collect();
        let (msg, _) = native.encode_with_point(&xw);
        decoded.push(native.decode(&msg, &x_leader));
        let cols = dme::quant::bits::unpack(&msg.bytes, 4, d);
        colors_flat.extend(cols.iter().map(|&c| c as f32));
    }
    let mut mu = vec![0.0; d];
    for z in &decoded {
        dme::linalg::axpy(&mut mu, 1.0, z);
    }
    dme::linalg::axpy(&mut mu, 1.0, &x_leader);
    let mu: Vec<f64> = mu.iter().map(|v| v / (n_workers + 1) as f64).collect();
    let (expect_msg, _) = native.encode_with_point(&mu);
    let expect_colors = dme::quant::bits::unpack(&expect_msg.bytes, 4, d);

    let s_arr = [s as f32];
    let out = gr
        .run_f32(&[
            (&colors_flat, &[n_workers, d]),
            (&f32v(&x_leader), &[d]),
            (&f32v(&offset), &[d]),
            (&s_arr, &[1]),
        ])
        .unwrap();
    let mut color_mismatches = 0;
    for i in 0..d {
        assert!(
            (out[1][i] as f64 - mu[i]).abs() < 1e-3,
            "mu mismatch at {i}: {} vs {}",
            out[1][i],
            mu[i]
        );
        if out[0][i] as u64 != expect_colors[i] {
            color_mismatches += 1;
        }
    }
    // The fused graph re-encodes the f32 average; values landing within
    // ~1 ulp of a rounding boundary may flip — tolerate a handful.
    assert!(
        color_mismatches <= 2,
        "too many re-encode color mismatches: {color_mismatches}"
    );
}

#[test]
fn aot_mlp_grad_runs_and_decreases_loss() {
    let Some(eng) = engine() else { return };
    let g = eng.load("mlp_grad_b128_f32_h64_c10").unwrap();
    let (b, f, h, c) = (128usize, 32usize, 64usize, 10usize);
    let mut rng = Rng::new(13);
    let xb: Vec<f32> = (0..b * f).map(|_| rng.next_gaussian() as f32).collect();
    let labels: Vec<usize> = (0..b).map(|_| rng.next_below(c as u64) as usize).collect();
    let mut yb = vec![0.0f32; b * c];
    for (i, &l) in labels.iter().enumerate() {
        yb[i * c + l] = 1.0;
    }
    let mut w1: Vec<f32> = (0..f * h).map(|_| (rng.next_gaussian() * 0.2) as f32).collect();
    let mut b1 = vec![0.0f32; h];
    let mut w2: Vec<f32> = (0..h * c).map(|_| (rng.next_gaussian() * 0.2) as f32).collect();
    let mut b2 = vec![0.0f32; c];

    let mut losses = Vec::new();
    for _ in 0..30 {
        let out = g
            .run_f32(&[
                (&xb, &[b, f]),
                (&yb, &[b, c]),
                (&w1, &[f, h]),
                (&b1, &[h]),
                (&w2, &[h, c]),
                (&b2, &[c]),
            ])
            .unwrap();
        losses.push(out[0][0]);
        let lr = 0.5f32;
        for (p, gr) in w1.iter_mut().zip(&out[1]) {
            *p -= lr * gr;
        }
        for (p, gr) in b1.iter_mut().zip(&out[2]) {
            *p -= lr * gr;
        }
        for (p, gr) in w2.iter_mut().zip(&out[3]) {
            *p -= lr * gr;
        }
        for (p, gr) in b2.iter_mut().zip(&out[4]) {
            *p -= lr * gr;
        }
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.7),
        "training via AOT grads must reduce loss: {losses:?}"
    );
}
