//! Failure-injection tests: wrong parameters, corrupted wire bytes,
//! adversarial references. The system must degrade *detectably* (robust
//! path) or *boundedly* (plain lattice path) — never silently corrupt
//! beyond its documented envelopes.

use dme::coordinator::{star_round_over, variance_reduction_star, CodecSpec};
use dme::linalg::{dist2, dist_inf, mean_vecs};
use dme::net::TransportError;
use dme::quant::robust::{RobustAgreement, RobustOutcome};
use dme::quant::{LatticeQuantizer, VectorCodec};
use dme::rng::Rng;
use dme::sim::Cluster;

/// Corrupting color bits moves the decode to a *different lattice point*
/// of the same lattice — the error is quantized (a multiple of s), never
/// a garbage float.
#[test]
fn corrupted_message_decodes_to_lattice_point() {
    let d = 32;
    let q = 16u32;
    let mut shared = Rng::new(1);
    let mut codec = LatticeQuantizer::from_y(d, q, 1.0, &mut shared);
    let mut rng = Rng::new(2);
    let x: Vec<f64> = (0..d).map(|_| rng.uniform(-5.0, 5.0)).collect();
    let mut msg = codec.encode(&x, &mut rng);
    // Flip some bits.
    for i in [0usize, 3, 7] {
        msg.bytes[i] ^= 0xA5;
    }
    let z = codec.decode(&msg, &x);
    // Every coordinate still reconstructs as offset + s·k for integer k.
    for (i, zi) in z.iter().enumerate() {
        let k = (zi - codec.lattice.offset[i]) / codec.lattice.s;
        assert!((k - k.round()).abs() < 1e-9, "non-lattice decode at {i}");
    }
}

/// The robust protocol's hash check catches corrupted colors with
/// probability 1 − 2⁻³²: flipping payload bits yields FAR, not a wrong
/// accepted value.
#[test]
fn robust_detects_corrupted_wire_bytes() {
    let d = 48;
    let ra = RobustAgreement::new(d, 16, 1.0, 42);
    let mut rng = Rng::new(3);
    let x: Vec<f64> = (0..d).map(|_| rng.uniform(-10.0, 10.0)).collect();
    let mut detected = 0;
    let trials = 50;
    for t in 0..trials {
        let (mut msg, _) = ra.encode_round(&x, 16);
        let i = (t as usize) % (msg.bytes.len() - 4); // keep inside colors
        msg.bytes[i] ^= 1 << (t % 8);
        match ra.decode_round(&msg, &x, 16) {
            RobustOutcome::Far => detected += 1,
            RobustOutcome::Ok(z) => {
                // Only acceptable if the flip didn't change any decoded
                // index (flip in padding bits).
                let (orig, _) = ra.encode_round(&x, 16);
                assert_ne!(orig.bytes, msg.bytes);
                let _ = z;
            }
        }
    }
    assert!(
        detected >= trials * 9 / 10,
        "only {detected}/{trials} corruptions detected"
    );
}

/// A lying `y` (too small by 100×) breaks decoding *within the documented
/// envelope*: decoded points stay near the reference (same-color class),
/// within q·s of it — no unbounded blowup.
#[test]
fn wrong_y_fails_boundedly() {
    let d = 16;
    let q = 8u32;
    let y_claimed = 0.01;
    let mut shared = Rng::new(4);
    let mut codec = LatticeQuantizer::from_y(d, q, y_claimed, &mut shared);
    let mut rng = Rng::new(5);
    let x: Vec<f64> = (0..d).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(-1.0, 1.0)).collect(); // 100x the claim
    let msg = codec.encode(&x, &mut rng);
    let z = codec.decode(&msg, &xv);
    assert!(dist_inf(&z, &xv) <= q as f64 * codec.lattice.s);
}

/// Theorem-17 wrapper: star VR reduces error vs a single input on
/// well-behaved inputs, and the α parameter controls the budget.
#[test]
fn vr_star_reduction_works() {
    let n = 16;
    let d = 32;
    let sigma_c = 0.1;
    let mut rng = Rng::new(6);
    let nabla: Vec<f64> = (0..d).map(|_| 50.0 + rng.next_gaussian()).collect();
    let inputs: Vec<Vec<f64>> = (0..n)
        .map(|_| {
            nabla
                .iter()
                .map(|v| v + sigma_c * rng.next_gaussian())
                .collect()
        })
        .collect();
    let sigma = sigma_c * (d as f64).sqrt();
    let mut in_err = 0.0;
    let mut out_err = 0.0;
    for round in 0..20 {
        let out = variance_reduction_star(
            &inputs,
            &CodecSpec::Lq { q: 1024 },
            sigma,
            4.0,
            7,
            round,
        );
        in_err += dist2(&inputs[0], &nabla).powi(2);
        out_err += dist2(out.estimate(), &nabla).powi(2);
    }
    // μ itself has variance σ²/n; quantization at q=1024 is negligible.
    let mu = mean_vecs(&inputs);
    assert!(out_err < in_err / 4.0, "in {in_err} out {out_err}");
    let out = variance_reduction_star(&inputs, &CodecSpec::Lq { q: 1024 }, sigma, 4.0, 7, 99);
    assert!(dist2(out.estimate(), &mu) < 0.05);
}

/// A machine dying mid-protocol surfaces as a typed [`TransportError`]
/// on the survivors — the graceful-shutdown path — instead of poisoning
/// the process the way the legacy `expect("peer hung up")` panics did.
#[test]
fn dead_leader_degrades_to_transport_error_not_panic() {
    let n = 4;
    let d = 16;
    let seed = 21;
    let spec = CodecSpec::Lq { q: 16 };
    // Learn round 0's shared-randomness leader from a clean run.
    let probe = vec![1.0f64; d];
    let leader = {
        let p = probe.clone();
        let results = Cluster::new(n).try_run(move |mut ep| {
            star_round_over(&mut ep, spec, seed, 0, 1.0, &p, false)
        });
        results[0].as_ref().expect("clean round").leader
    };
    // Fresh cluster, same round — but the leader's machine drops its
    // endpoint before the round starts (a barrier makes the death
    // happen-before every survivor's first send, so the failure mode is
    // deterministic: try_send to a closed channel).
    let barrier = std::sync::Arc::new(std::sync::Barrier::new(n));
    let results = Cluster::new(n).try_run(move |mut ep| {
        if ep.id == leader {
            drop(ep);
            barrier.wait();
            return Ok(Vec::new());
        }
        barrier.wait();
        star_round_over(&mut ep, spec, seed, 0, 1.0, &probe, false).map(|r| r.output)
    });
    // The dead machine exited cleanly; every survivor observed exactly
    // PeerClosed{leader} — and the process is still alive to assert it.
    for (m, r) in results.iter().enumerate() {
        if m == leader {
            assert_eq!(r.as_ref().unwrap().len(), 0);
        } else {
            assert_eq!(
                r.as_ref().unwrap_err(),
                &TransportError::PeerClosed { peer: leader },
                "machine {m}"
            );
        }
    }
}

/// A panicking machine is reported as `WorkerPanicked` by `try_run`,
/// with every other machine's result still delivered.
#[test]
fn panicking_machine_is_reported_not_propagated() {
    let cluster = Cluster::new(3);
    let results = cluster.try_run(|ep| {
        if ep.id == 1 {
            panic!("injected fault");
        }
        Ok(ep.id)
    });
    assert_eq!(results[0], Ok(0));
    assert_eq!(results[1], Err(TransportError::WorkerPanicked { machine: 1 }));
    assert_eq!(results[2], Ok(2));
}

/// Zero and constant vectors round-trip through every lattice codec.
#[test]
fn degenerate_inputs_roundtrip() {
    let d = 16;
    for spec in [
        CodecSpec::Lq { q: 8 },
        CodecSpec::Rlq { q: 8 },
        CodecSpec::D4 { q: 8 },
    ] {
        for val in [0.0, 1e6, -3.25] {
            let x = vec![val; d];
            let mut codec = spec.build(d, 1.0, 11, 0);
            let mut rng = Rng::new(12);
            let msg = codec.encode(&x, &mut rng);
            let z = codec.decode(&msg, &x);
            let tol = match spec {
                // RLQ error bound is ℓ2 over the padded space.
                CodecSpec::Rlq { .. } => 2.0,
                _ => 1.0,
            } + val.abs() * 1e-9;
            assert!(
                dist_inf(&z, &x) <= tol,
                "{} on constant {val}: err {}",
                spec.label(),
                dist_inf(&z, &x)
            );
        }
    }
}
