//! Failure-injection tests: wrong parameters, corrupted wire bytes,
//! adversarial references. The system must degrade *detectably* (robust
//! path) or *boundedly* (plain lattice path) — never silently corrupt
//! beyond its documented envelopes.

use dme::coordinator::{
    star_round_over, tree_partial_reference, variance_reduction_star, CodecSpec, DmeBuilder,
    RoundOutcome, StragglerPolicy, Topology,
};
use dme::linalg::{dist2, dist_inf, mean_vecs};
use dme::net::faulty::{FaultPlan, FaultyEndpoint};
use dme::net::retry::RetrySchedule;
use dme::net::{TransportEndpoint, TransportError};
use dme::quant::robust::{RobustAgreement, RobustOutcome};
use dme::quant::{LatticeQuantizer, Message, VectorCodec};
use dme::rng::{hash2, Rng};
use dme::sim::Cluster;
use std::time::Duration;

/// Corrupting color bits moves the decode to a *different lattice point*
/// of the same lattice — the error is quantized (a multiple of s), never
/// a garbage float.
#[test]
fn corrupted_message_decodes_to_lattice_point() {
    let d = 32;
    let q = 16u32;
    let mut shared = Rng::new(1);
    let mut codec = LatticeQuantizer::from_y(d, q, 1.0, &mut shared);
    let mut rng = Rng::new(2);
    let x: Vec<f64> = (0..d).map(|_| rng.uniform(-5.0, 5.0)).collect();
    let mut msg = codec.encode(&x, &mut rng);
    // Flip some bits.
    for i in [0usize, 3, 7] {
        msg.bytes[i] ^= 0xA5;
    }
    let z = codec.decode(&msg, &x);
    // Every coordinate still reconstructs as offset + s·k for integer k.
    for (i, zi) in z.iter().enumerate() {
        let k = (zi - codec.lattice.offset[i]) / codec.lattice.s;
        assert!((k - k.round()).abs() < 1e-9, "non-lattice decode at {i}");
    }
}

/// The robust protocol's hash check catches corrupted colors with
/// probability 1 − 2⁻³²: flipping payload bits yields FAR, not a wrong
/// accepted value.
#[test]
fn robust_detects_corrupted_wire_bytes() {
    let d = 48;
    let ra = RobustAgreement::new(d, 16, 1.0, 42);
    let mut rng = Rng::new(3);
    let x: Vec<f64> = (0..d).map(|_| rng.uniform(-10.0, 10.0)).collect();
    let mut detected = 0;
    let trials = 50;
    for t in 0..trials {
        let (mut msg, _) = ra.encode_round(&x, 16);
        let i = (t as usize) % (msg.bytes.len() - 4); // keep inside colors
        msg.bytes[i] ^= 1 << (t % 8);
        match ra.decode_round(&msg, &x, 16) {
            RobustOutcome::Far => detected += 1,
            RobustOutcome::Ok(z) => {
                // Only acceptable if the flip didn't change any decoded
                // index (flip in padding bits).
                let (orig, _) = ra.encode_round(&x, 16);
                assert_ne!(orig.bytes, msg.bytes);
                let _ = z;
            }
        }
    }
    assert!(
        detected >= trials * 9 / 10,
        "only {detected}/{trials} corruptions detected"
    );
}

/// A lying `y` (too small by 100×) breaks decoding *within the documented
/// envelope*: decoded points stay near the reference (same-color class),
/// within q·s of it — no unbounded blowup.
#[test]
fn wrong_y_fails_boundedly() {
    let d = 16;
    let q = 8u32;
    let y_claimed = 0.01;
    let mut shared = Rng::new(4);
    let mut codec = LatticeQuantizer::from_y(d, q, y_claimed, &mut shared);
    let mut rng = Rng::new(5);
    let x: Vec<f64> = (0..d).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(-1.0, 1.0)).collect(); // 100x the claim
    let msg = codec.encode(&x, &mut rng);
    let z = codec.decode(&msg, &xv);
    assert!(dist_inf(&z, &xv) <= q as f64 * codec.lattice.s);
}

/// Theorem-17 wrapper: star VR reduces error vs a single input on
/// well-behaved inputs, and the α parameter controls the budget.
#[test]
fn vr_star_reduction_works() {
    let n = 16;
    let d = 32;
    let sigma_c = 0.1;
    let mut rng = Rng::new(6);
    let nabla: Vec<f64> = (0..d).map(|_| 50.0 + rng.next_gaussian()).collect();
    let inputs: Vec<Vec<f64>> = (0..n)
        .map(|_| {
            nabla
                .iter()
                .map(|v| v + sigma_c * rng.next_gaussian())
                .collect()
        })
        .collect();
    let sigma = sigma_c * (d as f64).sqrt();
    let mut in_err = 0.0;
    let mut out_err = 0.0;
    for round in 0..20 {
        let out = variance_reduction_star(
            &inputs,
            &CodecSpec::Lq { q: 1024 },
            sigma,
            4.0,
            7,
            round,
        );
        in_err += dist2(&inputs[0], &nabla).powi(2);
        out_err += dist2(out.estimate(), &nabla).powi(2);
    }
    // μ itself has variance σ²/n; quantization at q=1024 is negligible.
    let mu = mean_vecs(&inputs);
    assert!(out_err < in_err / 4.0, "in {in_err} out {out_err}");
    let out = variance_reduction_star(&inputs, &CodecSpec::Lq { q: 1024 }, sigma, 4.0, 7, 99);
    assert!(dist2(out.estimate(), &mu) < 0.05);
}

/// A machine dying mid-protocol surfaces as a typed [`TransportError`]
/// on the survivors — the graceful-shutdown path — instead of poisoning
/// the process the way the legacy `expect("peer hung up")` panics did.
#[test]
fn dead_leader_degrades_to_transport_error_not_panic() {
    let n = 4;
    let d = 16;
    let seed = 21;
    let spec = CodecSpec::Lq { q: 16 };
    // Learn round 0's shared-randomness leader from a clean run.
    let probe = vec![1.0f64; d];
    let leader = {
        let p = probe.clone();
        let results = Cluster::new(n).try_run(move |mut ep| {
            star_round_over(&mut ep, spec, seed, 0, 1.0, &p, false)
        });
        results[0].as_ref().expect("clean round").leader
    };
    // Fresh cluster, same round — but the leader's machine drops its
    // endpoint before the round starts (a barrier makes the death
    // happen-before every survivor's first send, so the failure mode is
    // deterministic: try_send to a closed channel).
    let barrier = std::sync::Arc::new(std::sync::Barrier::new(n));
    let results = Cluster::new(n).try_run(move |mut ep| {
        if ep.id == leader {
            drop(ep);
            barrier.wait();
            return Ok(Vec::new());
        }
        barrier.wait();
        star_round_over(&mut ep, spec, seed, 0, 1.0, &probe, false).map(|r| r.output)
    });
    // The dead machine exited cleanly; every survivor observed exactly
    // PeerClosed{leader} — and the process is still alive to assert it.
    for (m, r) in results.iter().enumerate() {
        if m == leader {
            assert_eq!(r.as_ref().unwrap().len(), 0);
        } else {
            assert_eq!(
                r.as_ref().unwrap_err(),
                &TransportError::PeerClosed { peer: leader },
                "machine {m}"
            );
        }
    }
}

/// A panicking machine is reported as `WorkerPanicked` by `try_run`,
/// with every other machine's result still delivered.
#[test]
fn panicking_machine_is_reported_not_propagated() {
    let cluster = Cluster::new(3);
    let results = cluster.try_run(|ep| {
        if ep.id == 1 {
            panic!("injected fault");
        }
        Ok(ep.id)
    });
    assert_eq!(results[0], Ok(0));
    assert_eq!(results[1], Err(TransportError::WorkerPanicked { machine: 1 }));
    assert_eq!(results[2], Ok(2));
}

/// Zero and constant vectors round-trip through every lattice codec.
#[test]
fn degenerate_inputs_roundtrip() {
    let d = 16;
    for spec in [
        CodecSpec::Lq { q: 8 },
        CodecSpec::Rlq { q: 8 },
        CodecSpec::D4 { q: 8 },
    ] {
        for val in [0.0, 1e6, -3.25] {
            let x = vec![val; d];
            let mut codec = spec.build(d, 1.0, 11, 0);
            let mut rng = Rng::new(12);
            let msg = codec.encode(&x, &mut rng);
            let z = codec.decode(&msg, &x);
            let tol = match spec {
                // RLQ error bound is ℓ2 over the padded space.
                CodecSpec::Rlq { .. } => 2.0,
                _ => 1.0,
            } + val.abs() * 1e-9;
            assert!(
                dist_inf(&z, &x) <= tol,
                "{} on constant {val}: err {}",
                spec.label(),
                dist_inf(&z, &x)
            );
        }
    }
}

// ---------------------------------------------------------------------------
// k-of-n partial rounds under seeded fault injection.
// ---------------------------------------------------------------------------

/// Fault seeds matching the CI fault matrix (`DME_FAULT_SEED`): the suite
/// must pass for any seed, so the env var lets CI pin three fixed ones.
fn fault_seed() -> u64 {
    std::env::var("DME_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xFA017)
}

/// Deadline for in-process partial rounds: healthy sends arrive in
/// microseconds, so this only needs to dwarf scheduler jitter.
const DEADLINE: Duration = Duration::from_millis(250);

/// A policy whose *first* backoff window is already wide (≥ 20 ms): a
/// healthy in-process report lands in microseconds, so no window can
/// expire on a loaded CI box before it arrives — `retries_used` counts
/// only genuinely dropped reports, timing-independently.
fn wide_window_policy(k_min: usize) -> StragglerPolicy {
    StragglerPolicy {
        deadline: DEADLINE,
        k_min,
        retry: RetrySchedule::deterministic(
            2,
            Duration::from_millis(40),
            Duration::from_millis(40),
            5,
        ),
    }
}

fn spread_inputs(n: usize, d: usize, y: f64, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..d).map(|_| 80.0 + rng.uniform(-y / 2.0, y / 2.0)).collect())
        .collect()
}

/// Hand-computed star k-of-n reference, replayed from public APIs only:
/// fold the leader's raw input plus the decode of every *surviving*
/// machine's encode (pinned machine order, leader's input as the decode
/// reference), renormalize by `1/k`, re-encode at the leader, decode.
/// Mirrors `OpenRound::close` in `net::service` — the PR-6 semantics the
/// in-session partial round must match bit for bit.
fn star_partial_reference(
    spec: CodecSpec,
    seed: u64,
    round: u64,
    y: f64,
    inputs: &[Vec<f64>],
    plan: &FaultPlan,
    leader: usize,
) -> (Vec<f64>, usize, Vec<usize>) {
    let n = inputs.len();
    let d = inputs[0].len();
    let shared = hash2(seed, round);
    let mut mu = vec![0.0; d];
    let mut k = 0usize;
    let mut dropped = Vec::new();
    for v in 0..n {
        if v == leader {
            // The coordinator always holds its own report.
            for (m, x) in mu.iter_mut().zip(&inputs[leader]) {
                *m += x;
            }
            k += 1;
        } else if plan.silences(v, round) {
            dropped.push(v);
        } else {
            let mut codec = spec.build(d, y, seed, round);
            let mut enc_rng = Rng::new(hash2(shared, v as u64 + 1));
            let msg = codec.encode(&inputs[v], &mut enc_rng);
            let z = codec.decode(&msg, &inputs[leader]);
            for (m, zi) in mu.iter_mut().zip(&z) {
                *m += zi;
            }
            k += 1;
        }
    }
    let inv_k = 1.0 / (k.max(1) as f64);
    for m in mu.iter_mut() {
        *m *= inv_k;
    }
    let mut codec = spec.build(d, y, seed, round);
    let mut enc_rng = Rng::new(hash2(shared, leader as u64 + 1));
    let msg = codec.encode(&mu, &mut enc_rng);
    (codec.decode(&msg, &inputs[leader]), k, dropped)
}

/// Star k-of-n rounds under injected dropout equal the hand-computed
/// `1/k`-renormalized reference *exactly* — estimate, quorum size, and
/// dropped set — across several rounds (so leaders and drop sets vary).
#[test]
fn star_partial_rounds_match_renormalized_reference() {
    let n = 8;
    let d = 32;
    let y = 1.0;
    let seed = 31;
    let spec = CodecSpec::Lq { q: 32 };
    let plan = FaultPlan::dropout(fault_seed(), 0.4);
    let policy = StragglerPolicy::deterministic(DEADLINE, 1, 5);
    let inputs = spread_inputs(n, d, y, 77);
    let mut sess = DmeBuilder::new(n, d)
        .codec(spec)
        .seed(seed)
        .fault_plan(plan.clone())
        .build();
    let mut saw_partial = false;
    for round in 0..4u64 {
        let out = sess.round_partial_with_y(&inputs, y, &policy).expect("quorum of 1");
        let leader = out.leader.expect("star rounds have a leader");
        let (want, k, dropped) =
            star_partial_reference(spec, seed, round, y, &inputs, &plan, leader);
        assert_eq!(out.estimate, want, "round {round}: estimate diverged from reference");
        assert_eq!(out.participants, k, "round {round}");
        assert_eq!(out.dropped, dropped, "round {round}");
        saw_partial |= k < n;
    }
    assert!(saw_partial, "rate 0.4 over 4 rounds should drop someone; weak fault seed?");
}

/// At dropout rate 0 the partial round *is* the full round: same
/// estimate, full participation, zero retries — the k-of-n plane rides
/// the identical codec/leader randomness as `round_with_y`.
#[test]
fn partial_round_without_faults_equals_full_round() {
    let n = 6;
    let d = 24;
    let y = 1.0;
    let seed = 13;
    let spec = CodecSpec::Rlq { q: 16 };
    let inputs = spread_inputs(n, d, y, 33);
    let mut full = DmeBuilder::new(n, d).codec(spec).seed(seed).build();
    let mut partial = DmeBuilder::new(n, d)
        .codec(spec)
        .seed(seed)
        .fault_plan(FaultPlan::dropout(fault_seed(), 0.0))
        .build();
    let policy = wide_window_policy(n);
    for round in 0..3u64 {
        let want = full.round_with_y(&inputs, y);
        let got = partial.round_partial_with_y(&inputs, y, &policy).expect("no faults");
        assert_eq!(got.estimate, want.estimate, "round {round}");
        assert_eq!(got.participants, n);
        assert!(got.dropped.is_empty());
        assert_eq!(got.retries_used, 0, "healthy reports arrive before any window expires");
        assert!(got.agreement);
    }
}

/// Tree k-of-n rounds under injected dropout equal the transport-free
/// [`tree_partial_reference`] oracle exactly: the root's estimate folds
/// only the surviving subtrees, pass-through-unhalved for lone children.
#[test]
fn tree_partial_rounds_match_reference_oracle() {
    let n = 8;
    let d = 16;
    let y = 1.0;
    let seed = 47;
    let plan = FaultPlan::dropout(fault_seed(), 0.3);
    let policy = StragglerPolicy::deterministic(DEADLINE, 1, 5);
    let inputs = spread_inputs(n, d, y, 55);
    let mut sess = DmeBuilder::new(n, d)
        .topology(Topology::Tree { m: n })
        .seed(seed)
        .fault_plan(plan.clone())
        .build();
    for round in 0..3u64 {
        let silenced: Vec<usize> = (0..n).filter(|&v| plan.silences(v, round)).collect();
        let want = tree_partial_reference(n, n, y, seed, round, &inputs, &silenced);
        match sess.round_partial_with_y(&inputs, y, &policy) {
            Ok(out) => {
                assert_eq!(out.participants, want.k, "round {round} ({silenced:?} silenced)");
                assert_eq!(
                    out.estimate,
                    want.estimate.expect("k >= 1 on an Ok round"),
                    "round {round}: tree estimate diverged from oracle"
                );
                assert_eq!(out.dropped, silenced, "round {round}");
            }
            // Silencing can sever the root from *every* leaf report
            // (both of its last-level children lost): the round fails
            // detectably, and the oracle must agree it was empty.
            Err(TransportError::QuorumFailed { got, need }) => {
                assert_eq!(need, 1, "round {round}");
                assert_eq!(got, want.k, "round {round}");
                assert_eq!(want.k, 0, "quorum of 1 only fails when all reports are lost");
            }
            Err(e) => panic!("round {round}: unexpected transport error {e:?}"),
        }
    }
}

/// An under-quorum round fails with the *typed* error — got/need filled
/// in, no panic — and the session stays usable: relaxing `k_min` the
/// next round succeeds on the same (still fully faulted) cluster.
#[test]
fn quorum_failure_is_typed_and_session_survives() {
    let n = 4;
    let d = 16;
    let y = 1.0;
    let inputs = spread_inputs(n, d, y, 11);
    let mut sess = DmeBuilder::new(n, d)
        .codec(CodecSpec::Lq { q: 16 })
        .seed(3)
        .fault_plan(FaultPlan::dropout(fault_seed(), 1.0))
        .build();
    // Every machine's sends are silenced: only the leader's own report
    // exists, so a quorum of 3 cannot form.
    let strict = StragglerPolicy::deterministic(DEADLINE, 3, 5);
    match sess.round_partial_with_y(&inputs, y, &strict) {
        Err(TransportError::QuorumFailed { got, need }) => {
            assert_eq!(got, 1);
            assert_eq!(need, 3);
        }
        other => panic!("expected QuorumFailed, got {other:?}"),
    }
    // Same session, next round, k_min = 1: the leader's own report makes
    // quorum and the round completes.
    let lax = StragglerPolicy::deterministic(DEADLINE, 1, 5);
    let out = sess.round_partial_with_y(&inputs, y, &lax).expect("quorum of 1");
    assert_eq!(out.participants, 1);
    assert_eq!(out.dropped.len(), n - 1);
}

/// One `FaultPlan` seed reproduces byte-identical `RoundOutcome`s across
/// independent runs: the fault schedule is a pure function of
/// `(seed, machine, round)` and the seeded retry windows exhaust well
/// inside the deadline, so even `retries_used` is timing-independent.
#[test]
fn same_fault_seed_reproduces_round_outcomes() {
    let n = 8;
    let d = 16;
    let y = 1.0;
    let inputs = spread_inputs(n, d, y, 21);
    let run = |_tag: u64| -> Vec<RoundOutcome> {
        let mut sess = DmeBuilder::new(n, d)
            .codec(CodecSpec::D4 { q: 16 })
            .seed(9)
            .fault_plan(FaultPlan::dropout(fault_seed(), 0.35))
            .build();
        let policy = wide_window_policy(1);
        (0..3)
            .map(|_| sess.round_partial_with_y(&inputs, y, &policy).expect("quorum of 1"))
            .collect()
    };
    let a = run(0);
    let b = run(1);
    for (oa, ob) in a.iter().zip(&b) {
        assert_eq!(oa.estimate, ob.estimate, "round {}", oa.round);
        assert_eq!(oa.participants, ob.participants, "round {}", oa.round);
        assert_eq!(oa.dropped, ob.dropped, "round {}", oa.round);
        assert_eq!(oa.retries_used, ob.retries_used, "round {}", oa.round);
        assert_eq!(oa.leader, ob.leader, "round {}", oa.round);
    }
}

/// The premise behind timing-independent `retries_used`: the policy's
/// seeded backoff windows replay identically and their total is a small
/// fraction of the deadline, so every wait pattern exhausts the same
/// number of windows no matter how the scheduler jitters.
#[test]
fn straggler_policy_windows_exhaust_inside_the_deadline() {
    let policy = StragglerPolicy::deterministic(DEADLINE, 1, 5);
    let a: Vec<Duration> = policy.retry.windows(42).collect();
    let b: Vec<Duration> = policy.retry.windows(42).collect();
    assert_eq!(a, b, "seeded windows must replay");
    assert_eq!(a.len() as u32, policy.retry.attempts());
    let total: Duration = a.iter().sum();
    assert!(
        total * 2 < DEADLINE,
        "windows ({total:?}) must exhaust well inside the deadline ({DEADLINE:?})"
    );
    // An unseeded schedule with the same shape still respects the bounds
    // (production default: ambient jitter, same envelope).
    let prod = RetrySchedule {
        jitter_seed: None,
        ..policy.retry
    };
    let total: Duration = prod.windows(42).sum();
    assert!(total * 2 < DEADLINE);
}

// ---------------------------------------------------------------------------
// Duplicate and Corrupt faults, end to end through the 17-byte envelope.
// ---------------------------------------------------------------------------

/// Duplicate faults are invisible end to end: every upload and broadcast
/// is delivered twice, the leader's first-copy-per-sender dedup folds
/// each report exactly once, and the stale second copies of round `r`
/// are discarded by round `r+1`'s envelope round-tag check — so the
/// estimate equals the fault-free full round's, bit for bit.
#[test]
fn duplicate_faults_are_deduplicated_end_to_end() {
    let n = 6;
    let d = 24;
    let y = 1.0;
    let seed = 29;
    let spec = CodecSpec::Lq { q: 16 };
    let inputs = spread_inputs(n, d, y, 91);
    let mut clean = DmeBuilder::new(n, d).codec(spec).seed(seed).build();
    let mut dup = DmeBuilder::new(n, d)
        .codec(spec)
        .seed(seed)
        .fault_plan(FaultPlan {
            seed: fault_seed(),
            duplicate_rate: 1.0,
            ..FaultPlan::default()
        })
        .build();
    // k_min = n: losing even one report to a dedup bug fails loudly.
    let policy = wide_window_policy(n);
    for round in 0..3u64 {
        let want = clean.round_with_y(&inputs, y);
        let got = dup.round_partial_with_y(&inputs, y, &policy).expect("full quorum");
        assert_eq!(got.estimate, want.estimate, "round {round}");
        assert_eq!(got.participants, n, "round {round}: duplicates deduped, none lost");
        assert!(got.dropped.is_empty(), "round {round}");
        assert_eq!(got.retries_used, 0, "round {round}: duplicates arrive instantly");
        assert!(got.agreement, "round {round}");
    }
}

/// Corrupt faults degrade deterministically, replayed by a wire-exact
/// oracle: each upload's flipped byte either lands in the codec payload
/// (the envelope passes, the leader folds a wrong-but-valid lattice
/// point) or in the 17-byte `[round][weight][dir]` trailer (the
/// envelope check rejects the packet and the sender is reported
/// dropped). The oracle taps the *actual* `FaultyEndpoint` for each
/// `(machine, round)` cell to observe the corrupted bytes, replays the
/// leader's documented accept rule, and must match the session's
/// estimate, quorum size and dropped set exactly.
#[test]
fn corrupt_faults_fold_bounded_or_reject_detectably() {
    let n = 6;
    let d = 16;
    let y = 1.0;
    let seed = 19;
    // Power-of-two q: any corrupted color bit pattern is still a valid
    // lattice color, so payload corruption can never panic the decoder.
    let spec = CodecSpec::Lq { q: 32 };
    let plan = FaultPlan {
        seed: fault_seed(),
        corrupt_rate: 1.0,
        ..FaultPlan::default()
    };
    let policy = StragglerPolicy::deterministic(DEADLINE, 1, 5);
    let inputs = spread_inputs(n, d, y, 63);
    let mut sess = DmeBuilder::new(n, d)
        .codec(spec)
        .seed(seed)
        .fault_plan(plan.clone())
        .build();
    // Tap cluster: the same plan wrapped around throwaway endpoints
    // reproduces each cell's exact corruption (it is a pure function of
    // `(plan seed, machine, round)` and the payload length).
    let tap_cluster = Cluster::new(n);
    let mut taps: Vec<_> = tap_cluster
        .endpoints()
        .into_iter()
        .map(|ep| FaultyEndpoint::with_plan(ep, plan.clone()))
        .collect();

    let mut saw_folded_corruption = false;
    let mut saw_rejection = false;
    for round in 0..4u64 {
        let out = sess.round_partial_with_y(&inputs, y, &policy).expect("quorum of 1");
        let leader = out.leader.expect("star rounds have a leader");
        let shared = hash2(seed, round);
        let mut codec = spec.build(d, y, seed, round);
        let mut mu = vec![0.0; d];
        let mut k = 0usize;
        let mut dropped = Vec::new();
        for v in 0..n {
            if v == leader {
                // The coordinator always holds its own raw report.
                for (m, x) in mu.iter_mut().zip(&inputs[leader]) {
                    *m += x;
                }
                k += 1;
                continue;
            }
            // v's honest upload: encoded payload plus the documented
            // `[round: u64 LE][weight = 1: u64 LE][dir = up]` trailer.
            let mut enc = spec.build(d, y, seed, round);
            let mut enc_rng = Rng::new(hash2(shared, v as u64 + 1));
            let mut wire = enc.encode(&inputs[v], &mut enc_rng);
            wire.bytes.extend_from_slice(&round.to_le_bytes());
            wire.bytes.extend_from_slice(&1u64.to_le_bytes());
            wire.bytes.push(0);
            wire.bits += 8 * 17;
            let clean = wire.clone();
            taps[v].set_round(round);
            taps[v].send(leader, wire).expect("tap send");
            let mut got = taps[leader].recv().expect("tap recv").msg;
            assert_eq!(got.bytes.len(), clean.bytes.len(), "corruption preserves length");
            let len = got.bytes.len();
            // The leader's accept rule, byte for byte: round tag must
            // match, weight must be plausible, direction must be upward.
            let dir = got.bytes[len - 1];
            let weight = u64::from_le_bytes(got.bytes[len - 9..len - 1].try_into().unwrap());
            let tag = u64::from_le_bytes(got.bytes[len - 17..len - 9].try_into().unwrap());
            if tag == round && weight <= n as u64 && dir == 0 {
                saw_folded_corruption |= got.bytes[..len - 17] != clean.bytes[..len - 17];
                got.bytes.truncate(len - 17);
                got.bits -= 8 * 17;
                codec.decode_accumulate_into(&got, &inputs[leader], 1.0, &mut mu);
                k += 1;
            } else {
                saw_rejection = true;
                dropped.push(v);
            }
        }
        let inv_k = 1.0 / (k.max(1) as f64);
        for m in mu.iter_mut() {
            *m *= inv_k;
        }
        let mut lead_rng = Rng::new(hash2(shared, leader as u64 + 1));
        let msg = codec.encode(&mu, &mut lead_rng);
        let want = codec.decode(&msg, &inputs[leader]);
        assert_eq!(out.estimate, want, "round {round}: estimate diverged from wire oracle");
        assert_eq!(out.participants, k, "round {round}");
        assert_eq!(out.dropped, dropped, "round {round}");
    }
    // With a ~10-byte payload under a 17-byte trailer, 20 corrupted
    // cells over 4 rounds hit both regions for any reasonable seed.
    assert!(saw_folded_corruption, "no flip landed in a payload; weak fault seed?");
    assert!(saw_rejection, "no flip landed in the trailer; weak fault seed?");
}

/// A flip in the trailer's final byte turns the direction marker odd —
/// never again `up` — so the envelope must reject that upload and the
/// leader must report its sender dropped. The plan seed is found by a
/// bounded behavioral search over the real `FaultyEndpoint` (no
/// knowledge of the corruption formula), so the pin survives any
/// reimplementation of the byte choice.
#[test]
fn corrupted_direction_byte_is_rejected_and_sender_dropped() {
    let n = 5;
    let d = 16;
    let y = 1.0;
    let seed = 37;
    let spec = CodecSpec::Lq { q: 32 };
    let policy = StragglerPolicy::deterministic(DEADLINE, 1, 5);
    let inputs = spread_inputs(n, d, y, 41);
    // Learn round 0's leader from a clean probe session.
    let leader = DmeBuilder::new(n, d)
        .codec(spec)
        .seed(seed)
        .build()
        .round_partial_with_y(&inputs, y, &policy)
        .expect("clean round")
        .leader
        .expect("star rounds have a leader");
    // Wire shape of a round-0 upload: encoded payload + 17-byte trailer.
    let mut enc = spec.build(d, y, seed, 0);
    let mut enc_rng = Rng::new(hash2(hash2(seed, 0), 1));
    let probe_shape = enc.encode(&inputs[0], &mut enc_rng);
    let wire_len = probe_shape.bytes.len() + 17;
    let wire_bits = probe_shape.bits + 8 * 17;
    // Search plan seeds until some machine's round-0 flip lands on the
    // last wire byte — observed through the endpoint, not predicted.
    let mut found = None;
    'search: for cand in 0..5000u64 {
        let plan = FaultPlan {
            seed: cand,
            corrupt_rate: 1.0,
            ..FaultPlan::default()
        };
        let tap_cluster = Cluster::new(n);
        let mut taps: Vec<_> = tap_cluster
            .endpoints()
            .into_iter()
            .map(|ep| FaultyEndpoint::with_plan(ep, plan.clone()))
            .collect();
        for v in (0..n).filter(|&v| v != leader) {
            let probe = Message {
                bytes: vec![0u8; wire_len],
                bits: wire_bits,
            };
            taps[v].send(leader, probe).expect("probe send");
            let got = taps[leader].recv().expect("probe recv").msg;
            if got.bytes[wire_len - 1] != 0 {
                found = Some((cand, v));
                break 'search;
            }
        }
    }
    let (cand, victim) = found.expect("no dir-byte flip below seed 5000 — span changed?");
    let mut sess = DmeBuilder::new(n, d)
        .codec(spec)
        .seed(seed)
        .fault_plan(FaultPlan {
            seed: cand,
            corrupt_rate: 1.0,
            ..FaultPlan::default()
        })
        .build();
    let out = sess.round_partial_with_y(&inputs, y, &policy).expect("quorum of 1");
    assert_eq!(out.leader, Some(leader), "leader schedule is plan-independent");
    assert!(
        out.dropped.contains(&victim),
        "machine {victim}'s dir-corrupted upload must be rejected (dropped: {:?})",
        out.dropped
    );
    assert!(out.participants < n, "at least the victim is missing from the fold");
}
