//! Session ↔ legacy parity: `DmeSession` (and the one-shot wrappers now
//! built on it) must be **bit-identical** — estimates, per-machine
//! outputs, and exact traffic — to the original one-shot protocol
//! implementations for the same `(seed, round)`.
//!
//! The originals are preserved *here*, as independent reference
//! implementations written against the public sim/quant/rng APIs, so the
//! parity check stays meaningful now that the library's free functions
//! are thin wrappers over one-round sessions.

use dme::coordinator::{CodecSpec, DmeBuilder, Topology};
use dme::quant::robust::RobustAgreement;
use dme::quant::{CubicLattice, LatticeQuantizer, VectorCodec};
use dme::rng::{hash2, Rng};
use dme::sim::{Cluster, Traffic};
use std::sync::Arc;

fn gen_inputs(n: usize, d: usize, center: f64, spread: f64, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            (0..d)
                .map(|_| center + rng.uniform(-spread, spread))
                .collect()
        })
        .collect()
}

// ----------------------------------------------------------------------
// Reference Algorithm 3 (star) — the seed's original implementation.
// ----------------------------------------------------------------------

struct RefStar {
    outputs: Vec<Vec<f64>>,
    decoded_at_leader: Vec<Vec<f64>>,
    traffic: Vec<Traffic>,
    leader: usize,
}

fn reference_star(
    inputs: &[Vec<f64>],
    spec: &CodecSpec,
    y: f64,
    seed: u64,
    round: u64,
) -> RefStar {
    let n = inputs.len();
    let d = inputs[0].len();
    let leader = Rng::new(hash2(seed, round ^ 0x1EAD)).next_below(n as u64) as usize;
    assert!(n >= 2, "reference covers the threaded path");

    let cluster = Cluster::new(n);
    let inputs = Arc::new(inputs.to_vec());
    let spec = *spec;

    struct MachineOut {
        output: Vec<f64>,
        decoded: Vec<Vec<f64>>, // leader only
    }

    let results = cluster.run(move |mut ep| {
        let id = ep.id;
        let x = &inputs[id];
        let mut stash = Vec::new();
        let mut enc_rng = Rng::new(hash2(hash2(seed, round), id as u64 + 1));
        let mut codec = spec.build(d, y, seed, round);

        if id == leader {
            let mut decoded: Vec<Vec<f64>> = vec![Vec::new(); n];
            decoded[id] = x.clone();
            for _ in 0..n - 1 {
                let p = ep.recv();
                decoded[p.from] = codec.decode(&p.msg, x);
            }
            let mut mu = vec![0.0; d];
            for v in &decoded {
                dme::linalg::axpy(&mut mu, 1.0, v);
            }
            let mu = dme::linalg::scale(&mu, 1.0 / n as f64);
            let bmsg = codec.encode(&mu, &mut enc_rng);
            ep.broadcast(&bmsg);
            let output = codec.decode(&bmsg, x);
            MachineOut { output, decoded }
        } else {
            let msg = codec.encode(x, &mut enc_rng);
            ep.send(leader, msg);
            let p = ep.recv_from(leader, &mut stash);
            let output = codec.decode(&p.msg, x);
            MachineOut {
                output,
                decoded: Vec::new(),
            }
        }
    });

    let traffic = cluster.traffic();
    let mut outputs = Vec::with_capacity(n);
    let mut decoded_at_leader = Vec::new();
    for (i, r) in results.into_iter().enumerate() {
        if i == leader {
            decoded_at_leader = r.decoded;
        }
        outputs.push(r.output);
    }
    RefStar {
        outputs,
        decoded_at_leader,
        traffic,
        leader,
    }
}

// ----------------------------------------------------------------------
// Reference Algorithm 4 (tree) — the seed's original sequential driver.
// ----------------------------------------------------------------------

struct RefTree {
    outputs: Vec<Vec<f64>>,
    traffic: Vec<Traffic>,
    leaves: Vec<usize>,
    q_used: u32,
}

fn tree_params(m: usize, y: f64) -> (f64, u32) {
    let m = m.max(2) as f64;
    let side = 2.0 * y / (m * m);
    let q = (m * m * m).min((1u64 << 20) as f64) as u32;
    (side.max(f64::MIN_POSITIVE), q.max(4))
}

fn reference_tree(inputs: &[Vec<f64>], m: usize, y: f64, seed: u64, round: u64) -> RefTree {
    let n = inputs.len();
    let d = inputs[0].len();
    let mut shared = Rng::new(hash2(seed, round ^ 0x7EEE));
    let m_eff = m.min(n).next_power_of_two().min(n.next_power_of_two());
    let leaves: Vec<usize> = if m_eff >= n {
        (0..n).collect()
    } else {
        shared.sample_indices(n, m_eff)
    };
    let (side, q) = tree_params(m.max(2), y);

    let make_codec = || {
        let mut sr = Rng::new(hash2(seed, round));
        LatticeQuantizer::new(CubicLattice::random_offset(d, side, &mut sr), q)
    };

    assert!(n >= 2, "reference covers the threaded path");
    let cluster = Cluster::new(n);
    let mut eps = cluster.endpoints();

    let role_of = |level: usize, j: usize| -> usize { (j * 2 + level * 3) % n };
    let mut estimates: Vec<Vec<f64>> = leaves.iter().map(|&v| inputs[v].clone()).collect();
    let mut owners: Vec<usize> = leaves.clone();
    let mut level = 0usize;
    while estimates.len() > 1 {
        level += 1;
        let mut next_est = Vec::with_capacity(estimates.len() / 2);
        let mut next_own = Vec::with_capacity(estimates.len() / 2);
        for j in 0..estimates.len() / 2 {
            let parent = role_of(level, j);
            let mut decoded = Vec::with_capacity(2);
            for c in 0..2 {
                let child_idx = 2 * j + c;
                let child = owners[child_idx];
                let codec = make_codec();
                let (msg, _pt) = codec.encode_with_point(&estimates[child_idx]);
                if child != parent {
                    eps[child].send(parent, msg.clone());
                    let p = {
                        let mut stash = Vec::new();
                        eps[parent].recv_from(child, &mut stash)
                    };
                    decoded.push(codec.decode(&p.msg, &inputs[parent]));
                } else {
                    decoded.push(codec.decode(&msg, &inputs[parent]));
                }
            }
            let avg = dme::linalg::scale(&dme::linalg::add(&decoded[0], &decoded[1]), 0.5);
            next_est.push(avg);
            next_own.push(parent);
        }
        if estimates.len() % 2 == 1 {
            next_est.push(estimates.last().unwrap().clone());
            next_own.push(*owners.last().unwrap());
        }
        estimates = next_est;
        owners = next_own;
    }
    let root_est = estimates.pop().unwrap();
    let root = owners.pop().unwrap();

    let codec = make_codec();
    let (bmsg, _pt) = codec.encode_with_point(&root_est);
    let order: Vec<usize> = (0..n).map(|i| (root + i) % n).collect();
    for pos in 0..n {
        let me = order[pos];
        for c in [2 * pos + 1, 2 * pos + 2] {
            if c < n {
                eps[me].send(order[c], bmsg.clone());
                let mut stash = Vec::new();
                let _ = eps[order[c]].recv_from(me, &mut stash);
            }
        }
    }
    let outputs: Vec<Vec<f64>> = (0..n).map(|v| codec.decode(&bmsg, &inputs[v])).collect();

    RefTree {
        outputs,
        traffic: cluster.traffic(),
        leaves,
        q_used: q,
    }
}

// ----------------------------------------------------------------------
// Reference Algorithm 6 (robust VR) — the seed's original driver.
// ----------------------------------------------------------------------

struct RefRobustVr {
    estimate: Vec<f64>,
    traffic: Vec<Traffic>,
    leader: usize,
    rounds_stage1: Vec<u32>,
}

fn reference_robust_vr(
    inputs: &[Vec<f64>],
    sigma: f64,
    q0: u32,
    seed: u64,
    round: u64,
) -> RefRobustVr {
    let n = inputs.len();
    let d = inputs[0].len();
    let leader = Rng::new(hash2(seed, round ^ 0x10BD)).next_below(n as u64) as usize;
    let mut traffic = vec![Traffic::default(); n];
    let mut rounds_stage1 = Vec::new();

    let mut estimates: Vec<Vec<f64>> = Vec::with_capacity(n);
    for u in 0..n {
        if u == leader {
            estimates.push(inputs[leader].clone());
            continue;
        }
        let ra = RobustAgreement::new(
            d,
            q0,
            sigma.max(1e-12),
            hash2(seed, round * 1000 + u as u64),
        );
        let t = ra.run(&inputs[u], &inputs[leader]);
        traffic[u].sent_bits += t.bits_forward;
        traffic[leader].recv_bits += t.bits_forward;
        traffic[leader].sent_bits += t.bits_backward;
        traffic[u].recv_bits += t.bits_backward;
        traffic[u].sent_msgs += t.rounds as u64;
        rounds_stage1.push(t.rounds);
        estimates.push(t.estimate.expect("robust agreement exhausted"));
    }

    let nabla_hat = dme::linalg::mean_vecs(&estimates);

    let ra_bcast = RobustAgreement::new(
        d,
        q0,
        sigma.max(1e-12),
        hash2(seed, round * 1000 + 0xBCA5),
    );
    let mut estimate = nabla_hat.clone();
    for (u, input) in inputs.iter().enumerate() {
        if u == leader {
            continue;
        }
        let t = ra_bcast.run(&nabla_hat, input);
        traffic[leader].sent_bits += t.bits_forward;
        traffic[u].recv_bits += t.bits_forward;
        traffic[u].sent_bits += t.bits_backward;
        traffic[leader].recv_bits += t.bits_backward;
        estimate = t.estimate.expect("broadcast agreement exhausted");
    }

    RefRobustVr {
        estimate,
        traffic,
        leader,
        rounds_stage1,
    }
}

// ----------------------------------------------------------------------
// Scalar encode-plane reference: the session's codecs now run blocked /
// fused / chunk-parallel encode kernels (`BitWriter::push_block`,
// `encode_fold`, the one-pass multi-radix rotation, `encode_chunked`);
// this reference re-runs the star protocol with the seed's fully scalar
// wire loops — one `push`/`read` per color — so any wire bit moved by
// the vectorized encode plane fails these asserts.
// ----------------------------------------------------------------------

fn scalar_lq_encode(lq: &LatticeQuantizer, x: &[f64]) -> dme::quant::Message {
    let width = dme::quant::bits::width_for(lq.q as u64);
    let inv = 1.0 / lq.lattice.s;
    let mask = (lq.q - 1) as i64; // q is a power of two in these tests
    let mut w = dme::quant::bits::BitWriter::new();
    for (xi, off) in x.iter().zip(&lq.lattice.offset) {
        let k = ((xi - off) * inv).round_ties_even() as i64;
        w.push((k & mask) as u64, width);
    }
    let (bytes, bits) = w.finish();
    dme::quant::Message { bytes, bits }
}

fn scalar_lq_decode(
    lq: &LatticeQuantizer,
    msg: &dme::quant::Message,
    reference: &[f64],
) -> Vec<f64> {
    let d = lq.lattice.dim();
    let width = dme::quant::bits::width_for(lq.q as u64);
    let s = lq.lattice.s;
    let inv_sq = 1.0 / (s * lq.q as f64);
    let inv_q = 1.0 / lq.q as f64;
    let qi = lq.q as i64;
    let mut r = dme::quant::bits::BitReader::new(&msg.bytes);
    (0..d)
        .map(|i| {
            let c = r.read(width) as i64;
            let m = ((reference[i] - lq.lattice.offset[i]) * inv_sq - c as f64 * inv_q)
                .round_ties_even() as i64;
            let k = c + qi * m;
            lq.lattice.offset[i] + s * k as f64
        })
        .collect()
}

/// One star round computed entirely with the scalar wire loops: encode
/// every machine, fold the decoded vectors at the leader in pinned
/// machine order, re-encode the mean, decode everywhere.
fn scalar_star_round(
    inputs: &[Vec<f64>],
    q: u32,
    y: f64,
    seed: u64,
    round: u64,
) -> (Vec<f64>, Vec<Vec<f64>>, usize) {
    let n = inputs.len();
    let d = inputs[0].len();
    let leader = Rng::new(hash2(seed, round ^ 0x1EAD)).next_below(n as u64) as usize;
    let lq = LatticeQuantizer::from_y(d, q, y, &mut Rng::new(hash2(seed, round)));
    let mut mu = vec![0.0; d];
    for (v, input) in inputs.iter().enumerate() {
        if v == leader {
            dme::linalg::axpy(&mut mu, 1.0, input);
        } else {
            let msg = scalar_lq_encode(&lq, input);
            let z = scalar_lq_decode(&lq, &msg, &inputs[leader]);
            dme::linalg::axpy(&mut mu, 1.0, &z);
        }
    }
    let inv_n = 1.0 / n as f64;
    for m in mu.iter_mut() {
        *m = inv_n * *m;
    }
    let bmsg = scalar_lq_encode(&lq, &mu);
    let outputs: Vec<Vec<f64>> = inputs
        .iter()
        .map(|x| scalar_lq_decode(&lq, &bmsg, x))
        .collect();
    (outputs[0].clone(), outputs, leader)
}

#[test]
fn session_block_encode_plane_bit_identical_to_scalar_encode() {
    for (n, d, q) in [(2usize, 16usize, 8u32), (6, 33, 16), (9, 128, 64)] {
        let seed = 6000 + n as u64;
        let y = 1.0;
        let inputs = gen_inputs(n, d, 100.0, y / 2.0, seed);
        let mut streaming = DmeBuilder::new(n, d)
            .codec(CodecSpec::Lq { q })
            .seed(seed)
            .build();
        let mut collecting = DmeBuilder::new(n, d)
            .codec(CodecSpec::Lq { q })
            .seed(seed)
            .diagnostics(true)
            .build();
        for round in 0..4 {
            let (estimate, outputs, leader) = scalar_star_round(&inputs, q, y, seed, round);
            let s = streaming.round_with_y(&inputs, y);
            let c = collecting.round_with_y(&inputs, y);
            assert_eq!(s.leader, Some(leader), "n={n} round={round}");
            assert_eq!(s.estimate, estimate, "n={n} round={round} streaming");
            assert_eq!(c.outputs, outputs, "n={n} round={round} outputs");
        }
    }
}

// ----------------------------------------------------------------------
// Parity tests
// ----------------------------------------------------------------------

#[test]
fn star_session_bit_identical_to_reference_across_rounds() {
    for (n, d, q) in [(2usize, 16usize, 8u32), (6, 32, 16), (9, 33, 64)] {
        let seed = 1000 + n as u64;
        let y = 1.0;
        let inputs = gen_inputs(n, d, 100.0, y / 2.0, seed);
        let spec = CodecSpec::Lq { q };
        let mut sess = DmeBuilder::new(n, d)
            .codec(spec)
            .seed(seed)
            .diagnostics(true)
            .build();
        for round in 0..5 {
            let r = reference_star(&inputs, &spec, y, seed, round);
            let s = sess.round_with_y(&inputs, y);
            assert!(s.agreement, "n={n} round={round}");
            assert_eq!(s.leader, Some(r.leader), "n={n} round={round}");
            assert_eq!(s.estimate, r.outputs[0], "n={n} round={round} estimate");
            assert_eq!(s.outputs, r.outputs, "n={n} round={round} outputs");
            assert_eq!(
                s.decoded_at_leader, r.decoded_at_leader,
                "n={n} round={round} decoded"
            );
            assert_eq!(
                s.round_traffic, r.traffic,
                "n={n} round={round} traffic"
            );
        }
    }
}

#[test]
fn streaming_fold_bit_identical_to_reference_decode_then_sum() {
    // With diagnostics off the leader never materializes the n decoded
    // vectors — each packet is folded straight into the O(d) accumulator
    // (quant::VectorCodec::decode_accumulate_into) in pinned machine
    // order. The estimate and metering must still be bit-identical to
    // the original decode-all-then-sum implementation.
    for (n, d, q) in [(2usize, 16usize, 8u32), (6, 32, 16), (9, 33, 64), (16, 128, 16)] {
        let seed = 4000 + n as u64;
        let y = 1.0;
        let inputs = gen_inputs(n, d, 100.0, y / 2.0, seed);
        let spec = CodecSpec::Lq { q };
        let mut sess = DmeBuilder::new(n, d).codec(spec).seed(seed).build();
        for round in 0..5 {
            let r = reference_star(&inputs, &spec, y, seed, round);
            let s = sess.round_with_y(&inputs, y);
            assert!(s.agreement, "n={n} round={round}");
            assert_eq!(s.estimate, r.outputs[0], "n={n} round={round} estimate");
            assert_eq!(s.round_traffic, r.traffic, "n={n} round={round} traffic");
            assert!(
                s.decoded_at_leader.is_empty(),
                "streaming leader must not ship decoded vectors"
            );
        }
    }
    // Same contract for the fused RLQ / D4 / full-precision overrides.
    let n = 5;
    let d = 32;
    let inputs = gen_inputs(n, d, 10.0, 0.4, 99);
    for spec in [
        CodecSpec::Rlq { q: 16 },
        CodecSpec::D4 { q: 16 },
        CodecSpec::Full,
    ] {
        let mut sess = DmeBuilder::new(n, d).codec(spec).seed(17).build();
        let r = reference_star(&inputs, &spec, 1.0, 17, 0);
        let s = sess.round_with_y(&inputs, 1.0);
        assert_eq!(s.estimate, r.outputs[0], "{}", spec.label());
        assert_eq!(s.round_traffic, r.traffic, "{}", spec.label());
    }
}

#[test]
fn star_session_parity_for_baseline_codecs() {
    // The session must replicate the protocol for reference-free codecs
    // too (gather + broadcast degenerate form).
    let n = 5;
    let d = 24;
    let inputs = gen_inputs(n, d, 10.0, 0.5, 77);
    for spec in [
        CodecSpec::QsgdL2 { q: 16 },
        CodecSpec::Hadamard { q: 16 },
        CodecSpec::Full,
    ] {
        let mut sess = DmeBuilder::new(n, d)
            .codec(spec)
            .seed(5)
            .diagnostics(true)
            .build();
        let r = reference_star(&inputs, &spec, 1.0, 5, 0);
        let s = sess.round_with_y(&inputs, 1.0);
        assert_eq!(s.outputs, r.outputs, "{}", spec.label());
        assert_eq!(s.round_traffic, r.traffic, "{}", spec.label());
    }
}

#[test]
fn tree_session_bit_identical_to_reference_across_rounds() {
    // Full participation, subsampled, and odd machine counts.
    for (n, m) in [(2usize, 2usize), (8, 8), (16, 4), (7, 7), (9, 4)] {
        let seed = 2000 + n as u64 + m as u64;
        let y = 1.5;
        let inputs = gen_inputs(n, 8, 50.0, y / 2.0, seed);
        let mut sess = DmeBuilder::new(n, 8)
            .topology(Topology::Tree { m })
            .seed(seed)
            .diagnostics(true)
            .build();
        for round in 0..4 {
            let r = reference_tree(&inputs, m, y, seed, round);
            let s = sess.round_with_y(&inputs, y);
            assert!(s.agreement, "n={n} m={m} round={round}");
            assert_eq!(s.leaves, r.leaves, "n={n} m={m} round={round} leaves");
            assert_eq!(s.q_used, Some(r.q_used), "n={n} m={m} round={round}");
            assert_eq!(s.outputs, r.outputs, "n={n} m={m} round={round} outputs");
            assert_eq!(
                s.round_traffic, r.traffic,
                "n={n} m={m} round={round} traffic"
            );
        }
    }
}

#[test]
fn legacy_wrappers_match_references() {
    // The public one-shot functions (now session wrappers) must still be
    // bit-identical to the original implementations.
    let n = 6;
    let d = 20;
    let y = 1.0;
    let inputs = gen_inputs(n, d, 5.0, y / 2.0, 300);
    let spec = CodecSpec::Lq { q: 16 };

    let r = reference_star(&inputs, &spec, y, 9, 3);
    let w = dme::coordinator::mean_estimation_star(&inputs, &spec, y, 9, 3);
    assert_eq!(w.outputs, r.outputs);
    assert_eq!(w.decoded_at_leader, r.decoded_at_leader);
    assert_eq!(w.traffic, r.traffic);
    assert_eq!(w.leader, r.leader);

    let rt = reference_tree(&inputs, n, y, 10, 2);
    let wt = dme::coordinator::mean_estimation_tree(&inputs, n, y, 10, 2);
    assert_eq!(wt.outputs, rt.outputs);
    assert_eq!(wt.traffic, rt.traffic);
    assert_eq!(wt.leaves, rt.leaves);
    assert_eq!(wt.q_used, rt.q_used);
}

#[test]
fn robust_vr_session_matches_reference() {
    let n = 8;
    let d = 16;
    let sigma = 0.3;
    let inputs = gen_inputs(n, d, 0.0, sigma, 400);
    let r = reference_robust_vr(&inputs, sigma, 8, 11, 4);
    let mut sess = DmeBuilder::new(n, d).robust(8).seed(11).build();
    sess.set_round(4);
    let s = sess.round_vr(&inputs, sigma);
    assert_eq!(s.estimate, r.estimate);
    assert_eq!(s.leader, Some(r.leader));
    assert_eq!(s.rounds_stage1, r.rounds_stage1);
    assert_eq!(s.round_traffic, r.traffic);
}

// ----------------------------------------------------------------------
// Batch-plane parity: `round_batch` is a pure scheduling change — slot b
// of a batch starting at round r must be bit-identical (estimate,
// outputs, diagnostics, per-machine traffic, cumulative summary) to a
// sequential round at index r + b.
// ----------------------------------------------------------------------

fn assert_slot_eq(
    o: &dme::coordinator::RoundOutcome,
    r: &dme::coordinator::RoundOutcome,
    ctx: &str,
) {
    assert_eq!(o.round, r.round, "{ctx} round");
    assert_eq!(o.estimate, r.estimate, "{ctx} estimate");
    assert_eq!(o.agreement, r.agreement, "{ctx} agreement");
    assert_eq!(o.y_used, r.y_used, "{ctx} y_used");
    assert_eq!(o.leader, r.leader, "{ctx} leader");
    assert_eq!(o.leaves, r.leaves, "{ctx} leaves");
    assert_eq!(o.q_used, r.q_used, "{ctx} q_used");
    assert_eq!(o.outputs, r.outputs, "{ctx} outputs");
    assert_eq!(o.decoded_at_leader, r.decoded_at_leader, "{ctx} decoded");
    assert_eq!(o.round_traffic, r.round_traffic, "{ctx} round_traffic");
    assert_eq!(o.traffic, r.traffic, "{ctx} cumulative traffic");
}

#[test]
fn round_batch_slot_by_slot_bit_identical_to_sequential_rounds_star() {
    let n = 6;
    let d = 24;
    for b_total in [1usize, 2, 7] {
        let seed = 7000 + b_total as u64;
        // Distinct inputs and a distinct explicit y per slot.
        let slots: Vec<Vec<Vec<f64>>> = (0..b_total)
            .map(|s| gen_inputs(n, d, 50.0, 0.4, seed * 10 + s as u64))
            .collect();
        let ys: Vec<f64> = (0..b_total).map(|s| 1.0 + 0.1 * s as f64).collect();
        for diagnostics in [false, true] {
            let mk = || {
                DmeBuilder::new(n, d)
                    .codec(CodecSpec::Lq { q: 16 })
                    .seed(seed)
                    .diagnostics(diagnostics)
                    .build()
            };
            let mut batched = mk();
            let mut seq = mk();
            let outs = batched.round_batch_with_y(&slots, &ys);
            assert_eq!(outs.len(), b_total);
            for (s, o) in outs.iter().enumerate() {
                let r = seq.round_with_y(&slots[s], ys[s]);
                assert_slot_eq(o, &r, &format!("B={b_total} diag={diagnostics} slot={s}"));
            }
            // The sessions stay interchangeable after the batch: the next
            // sequential round continues the same window on both.
            let o = batched.round_with_y(&slots[0], 1.0);
            let r = seq.round_with_y(&slots[0], 1.0);
            assert_slot_eq(&o, &r, &format!("B={b_total} diag={diagnostics} post-batch"));
        }
    }
}

#[test]
fn round_batch_slot_by_slot_bit_identical_to_sequential_rounds_tree() {
    for (n, m) in [(8usize, 8usize), (7, 4)] {
        for b_total in [1usize, 2, 7] {
            let seed = 8000 + n as u64 + b_total as u64;
            let slots: Vec<Vec<Vec<f64>>> = (0..b_total)
                .map(|s| gen_inputs(n, 12, 20.0, 0.5, seed * 10 + s as u64))
                .collect();
            let ys: Vec<f64> = (0..b_total).map(|s| 1.5 + 0.2 * s as f64).collect();
            let mk = || {
                DmeBuilder::new(n, 12)
                    .topology(Topology::Tree { m })
                    .seed(seed)
                    .build()
            };
            let mut batched = mk();
            let mut seq = mk();
            let outs = batched.round_batch_with_y(&slots, &ys);
            for (s, o) in outs.iter().enumerate() {
                let r = seq.round_with_y(&slots[s], ys[s]);
                assert_slot_eq(o, &r, &format!("tree n={n} m={m} B={b_total} slot={s}"));
            }
        }
    }
}

#[test]
fn round_batch_parity_for_fused_codecs() {
    // The RLQ / D4 / full-precision fused paths ride the batch plane
    // identically.
    let n = 5;
    let d = 32;
    let slots: Vec<Vec<Vec<f64>>> = (0..2).map(|s| gen_inputs(n, d, 10.0, 0.4, 9000 + s)).collect();
    let ys = [1.0, 1.1];
    for spec in [
        CodecSpec::Rlq { q: 16 },
        CodecSpec::D4 { q: 16 },
        CodecSpec::Full,
    ] {
        let mut batched = DmeBuilder::new(n, d).codec(spec).seed(19).build();
        let mut seq = DmeBuilder::new(n, d).codec(spec).seed(19).build();
        let outs = batched.round_batch_with_y(&slots, &ys);
        for (s, o) in outs.iter().enumerate() {
            let r = seq.round_with_y(&slots[s], ys[s]);
            assert_slot_eq(o, &r, &format!("{} slot={s}", spec.label()));
        }
    }
}

#[test]
fn round_batch_mixed_dim_slots_match_per_dimension_sessions() {
    // Variable-width slots (the per-layer use): slot s of the batch must
    // equal round s of a session built at that slot's dimension.
    let n = 4;
    let dims = [16usize, 5, 33];
    let seed = 555;
    let spec = CodecSpec::Lq { q: 16 };
    let slots: Vec<Vec<Vec<f64>>> = dims
        .iter()
        .enumerate()
        .map(|(s, &d_s)| gen_inputs(n, d_s, 100.0, 0.45, seed + s as u64))
        .collect();
    let ys = [1.0, 0.7, 1.3];
    let mut batched = DmeBuilder::new(n, 33).codec(spec).seed(seed).build();
    let outs = batched.round_batch_with_y(&slots, &ys);
    for (s, o) in outs.iter().enumerate() {
        let mut seq = DmeBuilder::new(n, dims[s]).codec(spec).seed(seed).build();
        seq.set_round(s as u64);
        let r = seq.round_with_y(&slots[s], ys[s]);
        // Everything per-slot must match; the cumulative summary is the
        // one field that cannot (the per-dim reference session never ran
        // the batch's earlier slots).
        assert_eq!(o.round, r.round, "mixed-dim slot={s} round");
        assert_eq!(o.estimate, r.estimate, "mixed-dim slot={s} estimate");
        assert_eq!(o.agreement, r.agreement, "mixed-dim slot={s} agreement");
        assert_eq!(o.leader, r.leader, "mixed-dim slot={s} leader");
        assert_eq!(o.round_traffic, r.round_traffic, "mixed-dim slot={s} traffic");
    }
}

#[test]
fn session_round_counter_reproduces_any_round() {
    // set_round pins the shared randomness: round r of a fresh session
    // equals round r reached by iteration.
    let n = 4;
    let d = 12;
    let inputs = gen_inputs(n, d, 1.0, 0.4, 500);
    let spec = CodecSpec::Lq { q: 16 };
    let mut iterated = DmeBuilder::new(n, d).codec(spec).seed(13).build();
    let mut last = None;
    for _ in 0..6 {
        last = Some(iterated.round_with_y(&inputs, 1.0));
    }
    let mut jumped = DmeBuilder::new(n, d).codec(spec).seed(13).build();
    jumped.set_round(5);
    let direct = jumped.round_with_y(&inputs, 1.0);
    let last = last.unwrap();
    assert_eq!(last.round, direct.round);
    assert_eq!(last.estimate, direct.estimate);
    assert_eq!(last.leader, direct.leader);
    assert_eq!(last.round_traffic, direct.round_traffic);
}
