//! Micro-benchmarks for the quantization hot path (criterion-lite).
//!
//! These are the §Perf L3 numbers recorded in EXPERIMENTS.md: encode /
//! decode / FWHT throughput per codec at the experiment dimensions.
//! Quantization is memory-bound (see DESIGN.md §4), so the target is
//! element throughput, not flops.
//!
//! The `encode_bench` section isolates the vectorized encode data plane
//! against its scalar ancestors, each pair bit-identical by the parity
//! tests: per-field `BitWriter::push` vs the word-granular `push_block`,
//! the seed's two-pass radix-2 FWHT (`fwht_reference`) vs the fused
//! blocked multi-radix rotation, and sequential `encode_into` vs the
//! chunk-parallel `encode_chunked`, at d ∈ {128, 4096, 65536}.

use dme::bench::Bencher;
use dme::coordinator::CodecSpec;
use dme::quant::bits::BitWriter;
use dme::quant::hadamard::{fwht, fwht_reference, Rotation};
use dme::quant::{encode_chunked, D4Quantizer, LatticeQuantizer, Message, VectorCodec};
use dme::rng::Rng;

/// The seed's scalar encode loop (per-coordinate push), kept inline as
/// the baseline the fused block kernel is measured against.
fn lq_encode_scalar(lq: &LatticeQuantizer, x: &[f64], out: &mut Message) {
    let s = lq.lattice.s;
    let inv = 1.0 / s;
    let mask = (lq.q - 1) as i64;
    let width = dme::quant::bits::width_for(lq.q as u64);
    let mut w = BitWriter::reusing(std::mem::take(&mut out.bytes));
    for (xi, off) in x.iter().zip(&lq.lattice.offset) {
        let k = ((xi - off) * inv).round_ties_even() as i64;
        w.push((k & mask) as u64, width);
    }
    let (bytes, bits) = w.finish();
    out.bytes = bytes;
    out.bits = bits;
}

fn encode_bench(b: &mut Bencher) {
    println!("# encode_bench — scalar vs block/fused/parallel encode plane\n");
    for d in [128usize, 4096, 65536] {
        let mut rng = Rng::new(21);
        let x: Vec<f64> = (0..d).map(|_| 100.0 + rng.uniform(-0.5, 0.5)).collect();

        // (a) Bit packing: one push per field vs one store per
        // ⌊64/width⌋ fields (width 5 keeps every store misaligned).
        let vals: Vec<u64> = (0..d).map(|_| rng.next_u64() & 31).collect();
        let mut buf = Vec::new();
        b.bench(&format!("pack w=5 scalar-push   d={d}"), Some(d as u64), || {
            let mut w = BitWriter::reusing(std::mem::take(&mut buf));
            for &v in &vals {
                w.push(v, 5);
            }
            let (bytes, bits) = w.finish();
            buf = bytes;
            bits
        });
        b.bench(&format!("pack w=5 push_block    d={d}"), Some(d as u64), || {
            let mut w = BitWriter::reusing(std::mem::take(&mut buf));
            w.push_block(&vals, 5);
            let (bytes, bits) = w.finish();
            buf = bytes;
            bits
        });

        // (b) Rotation: the seed's two-pass radix-2 FWHT vs the fused
        // cache-blocked multi-radix kernel (bit-identical outputs), plus
        // the one-pass rotation with sign/norm fused into the butterflies.
        let mut fbuf = x.clone();
        b.bench(&format!("fwht two-pass radix-2  d={d}"), Some(d as u64), || {
            fwht_reference(&mut fbuf);
            fbuf[0]
        });
        b.bench(&format!("fwht fused multiradix  d={d}"), Some(d as u64), || {
            fwht(&mut fbuf);
            fbuf[0]
        });
        let mut shared = Rng::new(3);
        let rot = Rotation::new(d, &mut shared);
        let mut rbuf = Vec::new();
        b.bench(&format!("rotation forward_into  d={d}"), Some(d as u64), || {
            rot.forward_into(&x, &mut rbuf);
            rbuf[0]
        });

        // (c) Lattice encode: scalar per-coordinate loop vs the fused
        // block kernel behind encode_into vs the chunk-parallel encode.
        let mut shared = Rng::new(4);
        let mut lq = LatticeQuantizer::from_y(d, 16, 1.0, &mut shared);
        let mut msg = Message::empty();
        b.bench(&format!("lq q=16 encode scalar  d={d}"), Some(d as u64), || {
            lq_encode_scalar(&lq, &x, &mut msg);
            msg.bits
        });
        b.bench(&format!("lq q=16 encode_into    d={d}"), Some(d as u64), || {
            lq.encode_into(&x, &mut rng, &mut msg);
            msg.bits
        });
        b.bench(&format!("lq q=16 encode_chunked d={d}"), Some(d as u64), || {
            encode_chunked(&lq, &x, &mut msg, 4096);
            msg.bits
        });
        let mut d4 = D4Quantizer::from_y(d, 16, 1.0, &mut shared);
        b.bench(&format!("d4 q=16 encode_into    d={d}"), Some(d as u64), || {
            d4.encode_into(&x, &mut rng, &mut msg);
            msg.bits
        });
        b.bench(&format!("d4 q=16 encode_chunked d={d}"), Some(d as u64), || {
            encode_chunked(&d4, &x, &mut msg, 4096);
            msg.bits
        });
        println!();
    }
}

fn main() {
    let mut b = Bencher::from_env();
    println!("# quant_bench — codec encode/decode throughput\n");

    for d in [128usize, 1024, 16384] {
        let mut rng = Rng::new(1);
        let x: Vec<f64> = (0..d).map(|_| 100.0 + rng.uniform(-0.5, 0.5)).collect();
        let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(-0.2, 0.2)).collect();

        // LQSGD
        let mut shared = Rng::new(2);
        let mut lq = LatticeQuantizer::from_y(d, 16, 1.0, &mut shared);
        let msg = lq.encode(&x, &mut rng);
        b.bench(&format!("lq_encode d={d} q=16"), Some(d as u64), || {
            lq.encode(&x, &mut rng)
        });
        b.bench(&format!("lq_decode d={d} q=16"), Some(d as u64), || {
            lq.decode(&msg, &xv)
        });

        // FWHT
        let mut buf = x.clone();
        b.bench(&format!("fwht d={d}"), Some(d as u64), || {
            dme::quant::hadamard::fwht(&mut buf);
            buf[0]
        });

        // Baselines at the same dimension.
        for spec in [
            CodecSpec::Rlq { q: 16 },
            CodecSpec::QsgdL2 { q: 16 },
            CodecSpec::Hadamard { q: 16 },
            CodecSpec::EfSign,
        ] {
            let mut c = spec.build(d, 1.0, 3, 0);
            let m = c.encode(&x, &mut rng);
            b.bench(
                &format!("{} encode d={d}", spec.label()),
                Some(d as u64),
                || c.encode(&x, &mut rng),
            );
            b.bench(
                &format!("{} decode d={d}", spec.label()),
                Some(d as u64),
                || c.decode(&m, &xv),
            );
        }
        println!();
    }

    encode_bench(&mut b);

    b.write_json("quant_bench").expect("write bench json");
}
