//! Micro-benchmarks for the quantization hot path (criterion-lite).
//!
//! These are the §Perf L3 numbers recorded in EXPERIMENTS.md: encode /
//! decode / FWHT throughput per codec at the experiment dimensions.
//! Quantization is memory-bound (see DESIGN.md §4), so the target is
//! element throughput, not flops.

use dme::bench::Bencher;
use dme::coordinator::CodecSpec;
use dme::quant::{LatticeQuantizer, VectorCodec};
use dme::rng::Rng;

fn main() {
    let mut b = Bencher::from_env();
    println!("# quant_bench — codec encode/decode throughput\n");

    for d in [128usize, 1024, 16384] {
        let mut rng = Rng::new(1);
        let x: Vec<f64> = (0..d).map(|_| 100.0 + rng.uniform(-0.5, 0.5)).collect();
        let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(-0.2, 0.2)).collect();

        // LQSGD
        let mut shared = Rng::new(2);
        let mut lq = LatticeQuantizer::from_y(d, 16, 1.0, &mut shared);
        let msg = lq.encode(&x, &mut rng);
        b.bench(&format!("lq_encode d={d} q=16"), Some(d as u64), || {
            lq.encode(&x, &mut rng)
        });
        b.bench(&format!("lq_decode d={d} q=16"), Some(d as u64), || {
            lq.decode(&msg, &xv)
        });

        // FWHT
        let mut buf = x.clone();
        b.bench(&format!("fwht d={d}"), Some(d as u64), || {
            dme::quant::hadamard::fwht(&mut buf);
            buf[0]
        });

        // Baselines at the same dimension.
        for spec in [
            CodecSpec::Rlq { q: 16 },
            CodecSpec::QsgdL2 { q: 16 },
            CodecSpec::Hadamard { q: 16 },
            CodecSpec::EfSign,
        ] {
            let mut c = spec.build(d, 1.0, 3, 0);
            let m = c.encode(&x, &mut rng);
            b.bench(
                &format!("{} encode d={d}", spec.label()),
                Some(d as u64),
                || c.encode(&x, &mut rng),
            );
            b.bench(
                &format!("{} decode d={d}", spec.label()),
                Some(d as u64),
                || c.decode(&m, &xv),
            );
        }
        println!();
    }
}
