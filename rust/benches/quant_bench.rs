//! Micro-benchmarks for the quantization hot path (criterion-lite).
//!
//! These are the §Perf L3 numbers recorded in EXPERIMENTS.md: encode /
//! decode / FWHT throughput per codec at the experiment dimensions.
//! Quantization is memory-bound (see DESIGN.md §4), so the target is
//! element throughput, not flops.
//!
//! The `encode_bench` section isolates the vectorized encode data plane
//! against its scalar ancestors, each pair bit-identical by the parity
//! tests: per-field `BitWriter::push` vs the word-granular `push_block`,
//! the seed's two-pass radix-2 FWHT (`fwht_reference`) vs the fused
//! blocked multi-radix rotation, and sequential `encode_into` vs the
//! chunk-parallel `encode_chunked`, at d ∈ {128, 4096, 65536}.
//!
//! The `baseline_bench` section does the same for the comparator suite
//! (QSGD both norms, Suresh–Hadamard, TernGrad, EF-Sign, Top-K): seed
//! scalar encode vs fused `encode_into` vs chunk-parallel
//! `encode_chunked`, and decode+axpy vs the fused (sparse, for Top-K)
//! `decode_accumulate_into`, at the same dimensions.
//!
//! The `simd_pool_bench` section isolates this PR's two wall-clock
//! levers, again with every pair bit-identical: the explicit-lane
//! kernels of `dme::simd` against their always-compiled scalar twins
//! (run with and without `--features simd` to see the lanes move — the
//! section header prints which dispatch is live), and the persistent
//! `ChunkPool` chunk-parallel encode against a per-call scoped-spawn
//! copy of the same sharding (the pre-pool shape), at d ∈
//! {128, 4096, 65536}.

use dme::bench::Bencher;
use dme::coordinator::CodecSpec;
use dme::quant::bits::BitWriter;
use dme::quant::hadamard::{fwht, fwht_reference, Rotation};
use dme::quant::{encode_chunked, D4Quantizer, LatticeQuantizer, Message, VectorCodec};
use dme::rng::Rng;

/// The seed's scalar encode loop (per-coordinate push), kept inline as
/// the baseline the fused block kernel is measured against.
fn lq_encode_scalar(lq: &LatticeQuantizer, x: &[f64], out: &mut Message) {
    let s = lq.lattice.s;
    let inv = 1.0 / s;
    let mask = (lq.q - 1) as i64;
    let width = dme::quant::bits::width_for(lq.q as u64);
    let mut w = BitWriter::reusing(std::mem::take(&mut out.bytes));
    for (xi, off) in x.iter().zip(&lq.lattice.offset) {
        let k = ((xi - off) * inv).round_ties_even() as i64;
        w.push((k & mask) as u64, width);
    }
    let (bytes, bits) = w.finish();
    out.bytes = bytes;
    out.bits = bits;
}

fn encode_bench(b: &mut Bencher) {
    println!("# encode_bench — scalar vs block/fused/parallel encode plane\n");
    for d in [128usize, 4096, 65536] {
        let mut rng = Rng::new(21);
        let x: Vec<f64> = (0..d).map(|_| 100.0 + rng.uniform(-0.5, 0.5)).collect();

        // (a) Bit packing: one push per field vs one store per
        // ⌊64/width⌋ fields (width 5 keeps every store misaligned).
        let vals: Vec<u64> = (0..d).map(|_| rng.next_u64() & 31).collect();
        let mut buf = Vec::new();
        b.bench(&format!("pack w=5 scalar-push   d={d}"), Some(d as u64), || {
            let mut w = BitWriter::reusing(std::mem::take(&mut buf));
            for &v in &vals {
                w.push(v, 5);
            }
            let (bytes, bits) = w.finish();
            buf = bytes;
            bits
        });
        b.bench(&format!("pack w=5 push_block    d={d}"), Some(d as u64), || {
            let mut w = BitWriter::reusing(std::mem::take(&mut buf));
            w.push_block(&vals, 5);
            let (bytes, bits) = w.finish();
            buf = bytes;
            bits
        });

        // (b) Rotation: the seed's two-pass radix-2 FWHT vs the fused
        // cache-blocked multi-radix kernel (bit-identical outputs), plus
        // the one-pass rotation with sign/norm fused into the butterflies.
        let mut fbuf = x.clone();
        b.bench(&format!("fwht two-pass radix-2  d={d}"), Some(d as u64), || {
            fwht_reference(&mut fbuf);
            fbuf[0]
        });
        b.bench(&format!("fwht fused multiradix  d={d}"), Some(d as u64), || {
            fwht(&mut fbuf);
            fbuf[0]
        });
        let mut shared = Rng::new(3);
        let rot = Rotation::new(d, &mut shared);
        let mut rbuf = Vec::new();
        b.bench(&format!("rotation forward_into  d={d}"), Some(d as u64), || {
            rot.forward_into(&x, &mut rbuf);
            rbuf[0]
        });

        // (c) Lattice encode: scalar per-coordinate loop vs the fused
        // block kernel behind encode_into vs the chunk-parallel encode.
        let mut shared = Rng::new(4);
        let mut lq = LatticeQuantizer::from_y(d, 16, 1.0, &mut shared);
        let mut msg = Message::empty();
        b.bench(&format!("lq q=16 encode scalar  d={d}"), Some(d as u64), || {
            lq_encode_scalar(&lq, &x, &mut msg);
            msg.bits
        });
        b.bench(&format!("lq q=16 encode_into    d={d}"), Some(d as u64), || {
            lq.encode_into(&x, &mut rng, &mut msg);
            msg.bits
        });
        b.bench(&format!("lq q=16 encode_chunked d={d}"), Some(d as u64), || {
            encode_chunked(&mut lq, &x, &mut rng, &mut msg, 4096);
            msg.bits
        });
        let mut d4 = D4Quantizer::from_y(d, 16, 1.0, &mut shared);
        b.bench(&format!("d4 q=16 encode_into    d={d}"), Some(d as u64), || {
            d4.encode_into(&x, &mut rng, &mut msg);
            msg.bits
        });
        b.bench(&format!("d4 q=16 encode_chunked d={d}"), Some(d as u64), || {
            encode_chunked(&mut d4, &x, &mut rng, &mut msg, 4096);
            msg.bits
        });
        println!();
    }
}

/// The seed's scalar per-coordinate baseline encodes (one `next_f64` +
/// one `push` per coordinate) — the references `baseline_bench` measures
/// the fused kernels against. `baseline_bench` asserts these copies are
/// still bit-identical to the fused library paths before timing a single
/// row (the `baseline_*` prop tests pin the library against the test
/// file's own copies), so the rows compare wall-clock only.
mod baseline_scalar {
    use dme::quant::bits::{width_for, BitWriter};
    use dme::quant::baselines::{Qsgd, QsgdNorm, SureshHadamard};
    use dme::quant::Message;
    use dme::rng::Rng;

    pub fn qsgd(c: &Qsgd, x: &[f64], rng: &mut Rng, out: &mut Message) {
        let levels = c.levels;
        let w_lvl = width_for(levels as u64 + 1);
        let mut w = BitWriter::reusing(std::mem::take(&mut out.bytes));
        match c.norm {
            QsgdNorm::L2 => {
                let norm = dme::linalg::norm2(x);
                w.push_f64(norm);
                for &v in x {
                    let sign = if v < 0.0 { 1u64 } else { 0u64 };
                    let scaled = if norm > 0.0 {
                        v.abs() / norm * levels as f64
                    } else {
                        0.0
                    };
                    let low = scaled.floor();
                    let lvl = low as u64 + u64::from(rng.next_f64() < scaled - low);
                    w.push(sign, 1);
                    w.push(lvl.min(levels as u64), w_lvl);
                }
            }
            QsgdNorm::Linf => {
                let mn = x.iter().cloned().fold(f64::INFINITY, f64::min);
                let mx = x.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let range = (mx - mn).max(0.0);
                w.push_f64(mn);
                w.push_f64(mx);
                for &v in x {
                    let scaled = if range > 0.0 {
                        (v - mn) / range * levels as f64
                    } else {
                        0.0
                    };
                    let low = scaled.floor();
                    let lvl =
                        (low as u64 + u64::from(rng.next_f64() < scaled - low)).min(levels as u64);
                    w.push(lvl, w_lvl);
                }
            }
        }
        let (bytes, bits) = w.finish();
        out.bytes = bytes;
        out.bits = bits;
    }

    pub fn suresh(c: &SureshHadamard, x: &[f64], rng: &mut Rng, out: &mut Message) {
        let levels = c.levels;
        let w_lvl = width_for(levels as u64 + 1);
        let rx = c.rotation.forward(x); // allocating two-pass seed shape
        let mn = rx.iter().cloned().fold(f64::INFINITY, f64::min);
        let mx = rx.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let range = (mx - mn).max(0.0);
        let mut w = BitWriter::reusing(std::mem::take(&mut out.bytes));
        w.push_f64(mn);
        w.push_f64(mx);
        for &v in &rx {
            let scaled = if range > 0.0 {
                (v - mn) / range * levels as f64
            } else {
                0.0
            };
            let low = scaled.floor();
            let lvl = (low as u64 + u64::from(rng.next_f64() < scaled - low)).min(levels as u64);
            w.push(lvl, w_lvl);
        }
        let (bytes, bits) = w.finish();
        out.bytes = bytes;
        out.bits = bits;
    }

    pub fn terngrad(x: &[f64], rng: &mut Rng, out: &mut Message) {
        let m = dme::linalg::norm_inf(x);
        let mut w = BitWriter::reusing(std::mem::take(&mut out.bytes));
        w.push_f64(m);
        for &v in x {
            let t = if m > 0.0 && rng.next_f64() < v.abs() / m {
                if v < 0.0 { 2u64 } else { 1u64 }
            } else {
                0u64
            };
            w.push(t, 2);
        }
        let (bytes, bits) = w.finish();
        out.bytes = bytes;
        out.bits = bits;
    }

    pub fn efsign(error: &mut [f64], x: &[f64], out: &mut Message) {
        let d = x.len();
        let p: Vec<f64> = x.iter().zip(error.iter()).map(|(a, e)| a + e).collect();
        let scale = dme::linalg::norm1(&p) / d as f64;
        let mut w = BitWriter::reusing(std::mem::take(&mut out.bytes));
        w.push_f64(scale);
        for &v in &p {
            w.push(u64::from(v < 0.0), 1);
        }
        for (e, &v) in error.iter_mut().zip(&p) {
            let dec = if v < 0.0 { -scale } else { scale };
            *e = v - dec;
        }
        let (bytes, bits) = w.finish();
        out.bytes = bytes;
        out.bits = bits;
    }

    /// Seed Top-K ranking: full stable sort (the fused path uses an O(d)
    /// partition instead).
    pub fn topk_sort(d: usize, k: usize, error: &mut [f64], x: &[f64], out: &mut Message) {
        let iw = width_for(d as u64).max(1);
        let p: Vec<f64> = x.iter().zip(error.iter()).map(|(a, e)| a + e).collect();
        let mut idx: Vec<usize> = (0..d).collect();
        idx.sort_by(|&a, &b| p[b].abs().partial_cmp(&p[a].abs()).unwrap());
        idx.truncate(k);
        idx.sort_unstable();
        let mut w = BitWriter::reusing(std::mem::take(&mut out.bytes));
        for &i in &idx {
            w.push(i as u64, iw);
            w.push_f32(p[i] as f32);
        }
        let mut kept = vec![false; d];
        for &i in &idx {
            kept[i] = true;
        }
        for i in 0..d {
            error[i] = if kept[i] {
                p[i] - p[i] as f32 as f64
            } else {
                p[i]
            };
        }
        let (bytes, bits) = w.finish();
        out.bytes = bytes;
        out.bits = bits;
    }
}

/// Comparator codecs on the blocked data plane: per codec at d ∈
/// {128, 4096, 65536}, the seed scalar encode vs the fused block-kernel
/// `encode_into` vs the chunk-parallel `encode_chunked`, and the
/// decode-then-axpy fold vs the fused `decode_accumulate_into` (sparse
/// for Top-K). Every pair is bit-identical; the rows measure wall-clock
/// only — this is the experiment harness's comparator cost, which
/// `experiments_bench` picks up end to end.
fn baseline_bench(b: &mut Bencher) {
    use dme::quant::baselines::{EfSignSgd, Qsgd, QsgdNorm, SureshHadamard, TernGrad, TopK};

    println!("# baseline_bench — comparator suite: scalar vs fused vs chunk-parallel\n");

    // One-time parity gate before any timing: the scalar references
    // above must still be bit-identical to the fused library paths (the
    // prop tests pin the library against *their own* scalar copies; this
    // pins the bench's copies, so a drifted reference can't silently
    // turn the scalar-vs-fused rows into fiction).
    {
        let d = 257; // awkward non-power-of-two, pads for Suresh
        let mut prng = Rng::new(91);
        let x: Vec<f64> = (0..d).map(|_| prng.uniform(-3.0, 3.0)).collect();
        let mut msg = Message::empty();
        for norm in [QsgdNorm::L2, QsgdNorm::Linf] {
            let mut c = Qsgd::new(d, 16, norm);
            let mut ra = prng.clone();
            baseline_scalar::qsgd(&c, &x, &mut prng, &mut msg);
            assert_eq!(c.encode(&x, &mut ra), msg, "qsgd scalar reference drifted");
        }
        let mut shared = Rng::new(92);
        let mut c = SureshHadamard::new(d, 16, &mut shared);
        let mut ra = prng.clone();
        baseline_scalar::suresh(&c, &x, &mut prng, &mut msg);
        assert_eq!(c.encode(&x, &mut ra), msg, "suresh scalar reference drifted");
        let mut c = TernGrad::new(d);
        let mut ra = prng.clone();
        baseline_scalar::terngrad(&x, &mut prng, &mut msg);
        assert_eq!(c.encode(&x, &mut ra), msg, "terngrad scalar reference drifted");
        let mut c = EfSignSgd::new(d);
        let mut err = vec![0.0; d];
        for step in 0..2 {
            baseline_scalar::efsign(&mut err, &x, &mut msg);
            let got = c.encode(&x, &mut prng);
            assert_eq!(got, msg, "ef-sign scalar reference drifted (step {step})");
        }
        let k = 9;
        let mut c = TopK::new(d, k);
        let mut err = vec![0.0; d];
        for step in 0..2 {
            baseline_scalar::topk_sort(d, k, &mut err, &x, &mut msg);
            let got = c.encode(&x, &mut prng);
            assert_eq!(got, msg, "topk scalar reference drifted (step {step})");
        }
    }
    for d in [128usize, 4096, 65536] {
        let mut rng = Rng::new(31);
        let x: Vec<f64> = (0..d).map(|_| rng.uniform(-3.0, 3.0)).collect();
        let mut msg = Message::empty();
        let weight = 1.0 / 16.0;

        // QSGD (both norms).
        for norm in [QsgdNorm::L2, QsgdNorm::Linf] {
            let tag = if norm == QsgdNorm::L2 { "l2 " } else { "linf" };
            let mut c = Qsgd::new(d, 16, norm);
            b.bench(&format!("qsgd-{tag} encode scalar  d={d}"), Some(d as u64), || {
                baseline_scalar::qsgd(&c, &x, &mut rng, &mut msg);
                msg.bits
            });
            b.bench(&format!("qsgd-{tag} encode fused   d={d}"), Some(d as u64), || {
                c.encode_into(&x, &mut rng, &mut msg);
                msg.bits
            });
            b.bench(&format!("qsgd-{tag} encode chunked d={d}"), Some(d as u64), || {
                encode_chunked(&mut c, &x, &mut rng, &mut msg, 4096);
                msg.bits
            });
            let m = c.encode(&x, &mut rng);
            let mut acc = vec![0.0; d];
            b.bench(&format!("qsgd-{tag} fold decode+axpy d={d}"), Some(d as u64), || {
                let z = c.decode(&m, &x);
                dme::linalg::axpy(&mut acc, weight, &z);
                acc[0]
            });
            b.bench(&format!("qsgd-{tag} fold fused       d={d}"), Some(d as u64), || {
                c.decode_accumulate_into(&m, &x, weight, &mut acc);
                acc[0]
            });
        }

        // Suresh–Hadamard.
        let mut shared = Rng::new(32);
        let mut c = SureshHadamard::new(d, 16, &mut shared);
        b.bench(&format!("hadamard encode scalar  d={d}"), Some(d as u64), || {
            baseline_scalar::suresh(&c, &x, &mut rng, &mut msg);
            msg.bits
        });
        b.bench(&format!("hadamard encode fused   d={d}"), Some(d as u64), || {
            c.encode_into(&x, &mut rng, &mut msg);
            msg.bits
        });
        b.bench(&format!("hadamard encode chunked d={d}"), Some(d as u64), || {
            encode_chunked(&mut c, &x, &mut rng, &mut msg, 4096);
            msg.bits
        });
        let m = c.encode(&x, &mut rng);
        let mut acc = vec![0.0; d];
        b.bench(&format!("hadamard fold decode+axpy d={d}"), Some(d as u64), || {
            let z = c.decode(&m, &x);
            dme::linalg::axpy(&mut acc, weight, &z);
            acc[0]
        });
        b.bench(&format!("hadamard fold fused       d={d}"), Some(d as u64), || {
            c.decode_accumulate_into(&m, &x, weight, &mut acc);
            acc[0]
        });

        // TernGrad.
        let mut c = TernGrad::new(d);
        b.bench(&format!("terngrad encode scalar  d={d}"), Some(d as u64), || {
            baseline_scalar::terngrad(&x, &mut rng, &mut msg);
            msg.bits
        });
        b.bench(&format!("terngrad encode fused   d={d}"), Some(d as u64), || {
            c.encode_into(&x, &mut rng, &mut msg);
            msg.bits
        });
        b.bench(&format!("terngrad encode chunked d={d}"), Some(d as u64), || {
            encode_chunked(&mut c, &x, &mut rng, &mut msg, 4096);
            msg.bits
        });
        let m = c.encode(&x, &mut rng);
        let mut acc = vec![0.0; d];
        b.bench(&format!("terngrad fold decode+axpy d={d}"), Some(d as u64), || {
            let z = c.decode(&m, &x);
            dme::linalg::axpy(&mut acc, weight, &z);
            acc[0]
        });
        b.bench(&format!("terngrad fold fused       d={d}"), Some(d as u64), || {
            c.decode_accumulate_into(&m, &x, weight, &mut acc);
            acc[0]
        });

        // EF-SignSGD (stateful: scalar and fused keep separate memories).
        let mut err = vec![0.0; d];
        let mut c = EfSignSgd::new(d);
        b.bench(&format!("ef-sign encode scalar  d={d}"), Some(d as u64), || {
            baseline_scalar::efsign(&mut err, &x, &mut msg);
            msg.bits
        });
        b.bench(&format!("ef-sign encode fused   d={d}"), Some(d as u64), || {
            c.encode_into(&x, &mut rng, &mut msg);
            msg.bits
        });
        b.bench(&format!("ef-sign encode chunked d={d}"), Some(d as u64), || {
            encode_chunked(&mut c, &x, &mut rng, &mut msg, 4096);
            msg.bits
        });
        let m = c.encode(&x, &mut rng);
        let mut acc = vec![0.0; d];
        b.bench(&format!("ef-sign fold decode+axpy d={d}"), Some(d as u64), || {
            let z = c.decode(&m, &x);
            dme::linalg::axpy(&mut acc, weight, &z);
            acc[0]
        });
        b.bench(&format!("ef-sign fold fused       d={d}"), Some(d as u64), || {
            c.decode_accumulate_into(&m, &x, weight, &mut acc);
            acc[0]
        });

        // Top-K: O(d log d) sort vs O(d) partition ranking, dense vs
        // sparse fold.
        let k = (d / 64).max(1);
        let mut err = vec![0.0; d];
        let mut c = TopK::new(d, k);
        b.bench(&format!("topk(k={k}) encode sort   d={d}"), Some(d as u64), || {
            baseline_scalar::topk_sort(d, k, &mut err, &x, &mut msg);
            msg.bits
        });
        b.bench(&format!("topk(k={k}) encode select d={d}"), Some(d as u64), || {
            c.encode_into(&x, &mut rng, &mut msg);
            msg.bits
        });
        let m = c.encode(&x, &mut rng);
        let mut acc = vec![0.0; d];
        b.bench(&format!("topk(k={k}) fold dense    d={d}"), Some(d as u64), || {
            let z = c.decode(&m, &x);
            dme::linalg::axpy(&mut acc, weight, &z);
            acc[0]
        });
        b.bench(&format!("topk(k={k}) fold sparse   d={d}"), Some(d as u64), || {
            c.decode_accumulate_into(&m, &x, weight, &mut acc);
            acc[0]
        });
        println!();
    }
}

/// The pre-pool shape of the chunk-parallel encode: scoped threads
/// spawned, joined and torn down on every call, with the identical
/// sharding math — the baseline the persistent-pool rows are measured
/// against. Output is bit-identical to `encode_chunked` (same shards,
/// same task-order concatenation); only the thread lifecycle differs.
fn encode_chunked_spawning<C: VectorCodec + Sync>(
    codec: &mut C,
    x: &[f64],
    rng: &mut Rng,
    out: &mut Message,
    chunk: usize,
) {
    codec.encode_prepare(x, rng);
    let codec: &C = codec;
    let d = codec.wire_fields();
    let align = codec.encode_chunk_align().max(1);
    let chunk = chunk.max(1).div_ceil(align) * align;
    let threads = dme::pool::threads();
    let n_chunks = d.div_ceil(chunk).max(1);
    let group = n_chunks.div_ceil(threads) * chunk;
    out.bytes.clear();
    out.bits = 0;
    if d <= group {
        let mut w = BitWriter::reusing(std::mem::take(&mut out.bytes));
        codec.encode_range(x, 0, d, &mut w);
        let (bytes, bits) = w.finish();
        out.bytes = bytes;
        out.bits = bits;
        return;
    }
    let runs: Vec<(usize, usize)> = (0..d.div_ceil(group))
        .map(|gi| (gi * group, group.min(d - gi * group)))
        .collect();
    let parts: Vec<(Vec<u8>, u64)> = std::thread::scope(|s| {
        let handles: Vec<_> = runs
            .iter()
            .map(|&(lo, len)| {
                s.spawn(move || {
                    let mut w = BitWriter::new();
                    codec.encode_range(x, lo, len, &mut w);
                    w.finish()
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("encode shard"))
            .collect()
    });
    for (pb, pbits) in &parts {
        out.bytes.extend_from_slice(pb);
        out.bits += pbits;
    }
}

/// Explicit SIMD lanes vs scalar twins (bit-identical by
/// `prop_simd_*`), and the persistent worker pool vs per-call scoped
/// spawns. Without `--features simd` (or off x86_64/AVX2) the two rows
/// of each lane pair time the same scalar kernel — the header says
/// which dispatch is live, so a diff across feature builds is honest.
fn simd_pool_bench(b: &mut Bencher) {
    use dme::simd;
    println!(
        "# simd_pool_bench — scalar twins vs dispatched lanes (live: {}), pool vs spawn\n",
        simd::lanes()
    );
    for d in [128usize, 4096, 65536] {
        let mut rng = Rng::new(41);
        let a: Vec<f64> = (0..d).map(|_| rng.uniform(-3.0, 3.0)).collect();
        let off: Vec<f64> = (0..d).map(|_| rng.uniform(-0.5, 0.5)).collect();

        // (a) FWHT butterfly layer over d/2-length halves.
        let (mut lo, mut hi) = (a.clone(), off.clone());
        b.bench(&format!("butterfly2 scalar       d={d}"), Some(d as u64), || {
            simd::butterfly2_scalar(&mut lo, &mut hi);
            lo[0]
        });
        b.bench(&format!("butterfly2 dispatched   d={d}"), Some(d as u64), || {
            simd::butterfly2(&mut lo, &mut hi);
            lo[0]
        });

        // (b) Stochastic-rounding quantize: offset, scale, round-even.
        let mut qout = vec![0.0; d];
        b.bench(&format!("quantize scalar         d={d}"), Some(d as u64), || {
            simd::quantize_scaled_scalar(&a, &off, 4.0, &mut qout);
            qout[0]
        });
        b.bench(&format!("quantize dispatched     d={d}"), Some(d as u64), || {
            simd::quantize_scaled(&a, &off, 4.0, &mut qout);
            qout[0]
        });

        // (c) Bulk uniform conversion (the vector stage of fill_uniform).
        let words: Vec<u64> = (0..d).map(|_| rng.next_u64()).collect();
        let mut uout = vec![0.0; d];
        b.bench(&format!("u64→uniform scalar      d={d}"), Some(d as u64), || {
            simd::uniform_from_bits_scalar(&words, &mut uout);
            uout[0]
        });
        b.bench(&format!("u64→uniform dispatched  d={d}"), Some(d as u64), || {
            simd::uniform_from_bits(&words, &mut uout);
            uout[0]
        });

        // (d) Field packing at width 5 (⌊64/5⌋ = 12 fields per word —
        // the push_block inner kernel).
        let vals: Vec<u64> = (0..d).map(|_| rng.next_u64() & 31).collect();
        b.bench(&format!("pack w=5 scalar fields  d={d}"), Some(d as u64), || {
            let mut acc = 0u64;
            for c in vals.chunks(12) {
                acc ^= simd::pack_fields_scalar(c, 5, 0);
            }
            acc
        });
        b.bench(&format!("pack w=5 lane fields    d={d}"), Some(d as u64), || {
            let mut acc = 0u64;
            for c in vals.chunks(12) {
                acc ^= simd::pack_fields(c, 5, 0);
            }
            acc
        });
        println!();
    }

    // (e) Persistent pool vs per-call scoped spawns for the chunk-
    // parallel encode. d=128 inlines on both paths (one run — no thread
    // to amortize), so its rows pin the small-d overhead floor; the
    // larger dims measure spawn/join+teardown vs parked-worker handoff.
    for d in [128usize, 4096, 65536] {
        let mut rng = Rng::new(42);
        let x: Vec<f64> = (0..d).map(|_| 100.0 + rng.uniform(-0.5, 0.5)).collect();
        let mut shared = Rng::new(43);
        let mut lq = LatticeQuantizer::from_y(d, 16, 1.0, &mut shared);
        let mut msg = Message::empty();
        b.bench(&format!("lq encode spawn-per-call d={d}"), Some(d as u64), || {
            encode_chunked_spawning(&mut lq, &x, &mut rng, &mut msg, 1024);
            msg.bits
        });
        b.bench(&format!("lq encode parked pool    d={d}"), Some(d as u64), || {
            encode_chunked(&mut lq, &x, &mut rng, &mut msg, 1024);
            msg.bits
        });
    }
    println!();
}

fn main() {
    let mut b = Bencher::from_env();
    println!("# quant_bench — codec encode/decode throughput\n");

    for d in [128usize, 1024, 16384] {
        let mut rng = Rng::new(1);
        let x: Vec<f64> = (0..d).map(|_| 100.0 + rng.uniform(-0.5, 0.5)).collect();
        let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(-0.2, 0.2)).collect();

        // LQSGD
        let mut shared = Rng::new(2);
        let mut lq = LatticeQuantizer::from_y(d, 16, 1.0, &mut shared);
        let msg = lq.encode(&x, &mut rng);
        b.bench(&format!("lq_encode d={d} q=16"), Some(d as u64), || {
            lq.encode(&x, &mut rng)
        });
        b.bench(&format!("lq_decode d={d} q=16"), Some(d as u64), || {
            lq.decode(&msg, &xv)
        });

        // FWHT
        let mut buf = x.clone();
        b.bench(&format!("fwht d={d}"), Some(d as u64), || {
            dme::quant::hadamard::fwht(&mut buf);
            buf[0]
        });

        // Baselines at the same dimension.
        for spec in [
            CodecSpec::Rlq { q: 16 },
            CodecSpec::QsgdL2 { q: 16 },
            CodecSpec::Hadamard { q: 16 },
            CodecSpec::EfSign,
        ] {
            let mut c = spec.build(d, 1.0, 3, 0);
            let m = c.encode(&x, &mut rng);
            b.bench(
                &format!("{} encode d={d}", spec.label()),
                Some(d as u64),
                || c.encode(&x, &mut rng),
            );
            b.bench(
                &format!("{} decode d={d}", spec.label()),
                Some(d as u64),
                || c.decode(&m, &xv),
            );
        }
        println!();
    }

    encode_bench(&mut b);
    baseline_bench(&mut b);
    simd_pool_bench(&mut b);

    b.write_json("quant_bench").expect("write bench json");
}
