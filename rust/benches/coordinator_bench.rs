//! End-to-end coordinator benchmarks: full MeanEstimation rounds over the
//! simulated cluster (threads + channels + bit metering included), plus
//! the robust VR protocol — the paper's Theorem 2/3/4 operations as
//! deployed. One row per (topology, n, d).
//!
//! The `session_bench` section isolates the §Perf claims behind the
//! `DmeBuilder`/`DmeSession` redesign: spawn-per-round vs a persistent
//! session (thread amortization) and `encode`/`decode` vs
//! `encode_into`/`decode_into` (allocation amortization) at d ∈ {128,
//! 4096}.
//!
//! The `fold_bench` section isolates the streaming-fold data plane:
//! decode-then-sum (legacy leader, O(n·d) buffers + two passes) vs the
//! fused block-kernel streaming fold (`decode_accumulate_into`, one pass,
//! O(d)) vs the chunk-sharded parallel fold — on the persistent
//! `ChunkPool` and against a per-call scoped-spawn copy of the same
//! sharding (the pre-pool shape, bit-identical output) — at
//! n ∈ {16, 256} and d ∈ {128, 4096}.
//!
//! The `encode_plane_bench` section is the fold section's write-side
//! twin: per-machine round encode through the fused block kernels
//! (`encode_into`) vs the chunk-parallel `encode_chunked` — the paper's
//! deployment has every one of n machines encoding each round, so this
//! is the plane that dominates round latency at scale.
//!
//! The `batch_bench` section measures the batched round *control plane*:
//! B sequential `round_with_y` calls vs one `round_batch_with_y` of B
//! slots (bit-identical per slot — pinned by `session_parity`), at
//! B ∈ {1, 8, 64}, d ∈ {128, 4096}, star and tree. The gap is the
//! per-round crossing + staging cost the batch amortizes.
//!
//! The `transport_bench` section prices the pluggable transport layer:
//! the same star round over the in-process channel cluster vs the
//! loopback-TCP mesh (bit-identical estimates and meters — pinned by
//! `tests/transport.rs`; the gap is the OS socket hop), and the
//! multi-cohort service front-end driven end-to-end over TCP at
//! cohorts ∈ {1, 16, 256}, n ∈ {4, 16}, d ∈ {128, 4096}. Its
//! durability rows re-run one service config with the write-ahead log
//! off / fsync-on-close / fsync-always and with a zero memory budget
//! (every accumulator folded through an on-disk spill run), pricing
//! crash durability against the in-RAM round.
//!
//! The `screen_bench` section prices report screening on the leader's
//! submit path: the identical pre-encoded round folded with the screen
//! off / basic / distance — bit-identical estimates (pinned by
//! `tests/screening.rs`), so the row gaps are the probe check plus the
//! screened decode-then-axpy fold vs the fused unscreened fold.

use dme::bench::Bencher;
use dme::coordinator::{
    fold_mean, fold_mean_chunked, mean_estimation_star, mean_estimation_tree,
    robust_variance_reduction, star_round_over, CodecSpec, DmeBuilder, FoldPart,
};
use dme::net::cohort::CohortSpec;
use dme::net::service::{report_round, request_shutdown, serve, ServeOpts};
use dme::net::tcp::{LoopbackMesh, TcpOpts};
use dme::quant::{encode_chunked, D4Quantizer, LatticeQuantizer, Message, VectorCodec};
use dme::rng::Rng;
use dme::sim::Cluster;
use dme::store::{DurabilityOpts, SyncPolicy};
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

fn inputs(n: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..d).map(|_| 50.0 + rng.uniform(-0.5, 0.5)).collect())
        .collect()
}

fn main() {
    let mut b = Bencher::from_env();
    println!("# coordinator_bench — full protocol rounds\n");

    for (n, d) in [(4usize, 128usize), (8, 128), (8, 1024), (16, 1024)] {
        let xs = inputs(n, d, 7);
        let mut round = 0u64;
        b.bench(
            &format!("star  n={n} d={d} q=16 (threads)"),
            Some((n * d) as u64),
            || {
                round += 1;
                mean_estimation_star(&xs, &CodecSpec::Lq { q: 16 }, 1.0, 3, round)
            },
        );
        // §Perf: same protocol on a persistent session (spawn amortized).
        let mut sess = dme::coordinator::StarSession::new(n, d, CodecSpec::Lq { q: 16 }, 3);
        b.bench(
            &format!("star  n={n} d={d} q=16 (session)"),
            Some((n * d) as u64),
            || sess.round(&xs, 1.0),
        );
        let mut round = 0u64;
        b.bench(
            &format!("tree  n={n} d={d} (m=n)"),
            Some((n * d) as u64),
            || {
                round += 1;
                mean_estimation_tree(&xs, n, 1.0, 3, round)
            },
        );
        let mut round = 0u64;
        b.bench(
            &format!("robust-vr n={n} d={d} q0=16"),
            Some((n * d) as u64),
            || {
                round += 1;
                robust_variance_reduction(&xs, 0.5, 16, 3, round)
            },
        );
        println!();
    }

    session_bench(&mut b);
    fold_bench(&mut b);
    encode_plane_bench(&mut b);
    batch_bench(&mut b);
    transport_bench(&mut b);
    screen_bench(&mut b);

    b.write_json("coordinator_bench").expect("write bench json");
}

/// Screening overhead on the leader's submit path: the identical n
/// pre-encoded reports folded through a fresh `CohortTable` with the
/// report screen off / basic (frame + NaN hygiene) / distance (adds the
/// ℓ∞ plausibility filter). Estimates are bit-identical across modes
/// (pinned by `tests/screening.rs` and the cohort unit tests); the row
/// gaps price the probe check and the screen's decode-then-axpy fold
/// against the fused unscreened fold.
fn screen_bench(b: &mut Bencher) {
    use dme::net::cohort::{client_encoder_rng, cohort_codec, CohortKey, CohortTable, Submit};
    use dme::net::screen::ScreenMode;
    println!("# screen_bench — report screening overhead on the submit path\n");
    let n = 8;
    for d in [128usize, 4096] {
        let cs = CohortSpec {
            n,
            d,
            spec: CodecSpec::Lq { q: 16 },
            y: 64.0,
            seed: 41,
        };
        let key = CohortKey { cohort: 1, round: 0 };
        let xs = inputs(n, d, 43);
        let msgs: Vec<Message> = xs
            .iter()
            .enumerate()
            .map(|(c, x)| {
                let mut codec = cohort_codec(&cs, key.round);
                let mut rng = client_encoder_rng(cs.seed, key.round, c);
                codec.encode(x, &mut rng)
            })
            .collect();
        for mode in [ScreenMode::Off, ScreenMode::Basic, ScreenMode::Distance] {
            let tag = mode.label();
            b.bench(
                &format!("submit n={n} d={d} screen={tag}"),
                Some((n * d) as u64),
                || {
                    let mut table = CohortTable::new();
                    table.set_screen(mode);
                    for (c, m) in msgs.iter().enumerate() {
                        match table.submit(key, &cs, c, m, 0, 60_000) {
                            Submit::Pending { .. } => {}
                            Submit::Complete(r) => return r.estimate[0],
                            other => panic!("unexpected {other:?}"),
                        }
                    }
                    unreachable!("n reports complete the round")
                },
            );
        }
        println!();
    }
}

/// A persistent cluster of worker threads, one per endpoint of a
/// [`Transport`](dme::net::Transport), each running one
/// [`star_round_over`] per command. The same driver runs the channel
/// cluster and the TCP mesh, so the two rows differ only in the wire.
struct MeshDriver {
    cmd: Vec<mpsc::Sender<u64>>,
    res: mpsc::Receiver<f64>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl MeshDriver {
    /// Run one full round across all machines; returns the sum of every
    /// machine's first output coordinate (black-box fodder).
    fn round(&mut self, round: u64) -> f64 {
        for tx in &self.cmd {
            tx.send(round).expect("mesh worker alive");
        }
        let mut acc = 0.0;
        for _ in 0..self.cmd.len() {
            acc += self.res.recv().expect("mesh round result");
        }
        acc
    }

    fn finish(self) {
        drop(self.cmd);
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn mesh_driver<T>(
    transport: &mut T,
    spec: CodecSpec,
    seed: u64,
    y: f64,
    xs: &[Vec<f64>],
) -> MeshDriver
where
    T: dme::net::Transport,
    T::Endpoint: 'static,
{
    let (res_tx, res) = mpsc::channel();
    let mut cmd = Vec::new();
    let mut handles = Vec::new();
    for (i, mut ep) in transport.open().expect("open transport").into_iter().enumerate() {
        let (tx, rx) = mpsc::channel::<u64>();
        cmd.push(tx);
        let input = xs[i].clone();
        let res_tx = res_tx.clone();
        handles.push(thread::spawn(move || {
            for round in rx {
                let r = star_round_over(&mut ep, spec, seed, round, y, &input, false)
                    .expect("bench round");
                let _ = res_tx.send(r.output[0]);
            }
        }));
    }
    MeshDriver { cmd, res, handles }
}

/// In-process channels vs loopback TCP for the identical star round,
/// then the cohort service driven end-to-end (connect + report + fold +
/// estimate broadcast) at increasing multiplexing width.
fn transport_bench(b: &mut Bencher) {
    println!("# transport_bench — in-process vs loopback-TCP vs cohort service\n");
    let spec = CodecSpec::Lq { q: 16 };
    let seed = 23;
    let y = 64.0; // must bound the 50.0 ± 0.5 inputs in ℓ∞
    for (n, d) in [(4usize, 128usize), (4, 4096), (16, 128), (16, 4096)] {
        let xs = inputs(n, d, 29);
        let mut chan = mesh_driver(&mut Cluster::new(n), spec, seed, y, &xs);
        let mut round = 0u64;
        b.bench(
            &format!("star n={n} d={d} in-process"),
            Some((n * d) as u64),
            || {
                round += 1;
                chan.round(round)
            },
        );
        chan.finish();

        let mut mesh = LoopbackMesh::new(n, &TcpOpts::default()).expect("loopback mesh");
        let mut tcp = mesh_driver(&mut mesh, spec, seed, y, &xs);
        let mut round = 0u64;
        b.bench(
            &format!("star n={n} d={d} loopback-tcp"),
            Some((n * d) as u64),
            || {
                round += 1;
                tcp.round(round)
            },
        );
        tcp.finish();
        println!();
    }
    service_throughput_bench(b);
}

/// Service throughput: one `dme serve` loop multiplexing `cohorts`
/// independent client groups per measured iteration. n lock-step
/// reporter threads each play client j for every cohort in order, so
/// every round sees all n reports and closes full — the measured unit
/// is `cohorts` complete TCP rounds (connect, report, fold, estimate).
fn service_throughput_bench(b: &mut Bencher) {
    println!("# transport_bench — service throughput (full rounds over TCP)\n");
    for (cohorts, n, d) in [
        (1usize, 4usize, 128usize),
        (16, 4, 128),
        (256, 4, 128),
        (256, 16, 128),
        (1, 16, 4096),
        (16, 16, 4096),
    ] {
        let label = format!("service cohorts={cohorts} n={n} d={d}");
        service_round_rows(b, &label, cohorts, n, d, None);
    }
    println!();
    durability_overhead_bench(b);
}

/// Durability overhead on the identical service round: WAL off, WAL
/// fsync'd once per round close, WAL fsync'd on every append, and a
/// zero memory budget so every accumulator folds through an on-disk
/// spill run. Same driver, same wire, bit-identical estimates (pinned
/// by `tests/durability.rs`) — the row gaps price the write-ahead log
/// and the spill path.
fn durability_overhead_bench(b: &mut Bencher) {
    println!("# transport_bench — durability overhead (WAL + spill on the service round)\n");
    let (cohorts, n, d) = (16usize, 4usize, 128usize);
    let dir = std::env::temp_dir().join(format!("dme-bench-dur-{}", std::process::id()));
    let always = DurabilityOpts {
        sync: SyncPolicy::Always,
        ..DurabilityOpts::new(&dir)
    };
    let spill = DurabilityOpts {
        mem_budget: 0,
        ..DurabilityOpts::new(&dir)
    };
    let modes = [
        ("wal=off", None),
        ("wal=close", Some(DurabilityOpts::new(&dir))),
        ("wal=always", Some(always)),
        ("wal=close mem=0 (spill)", Some(spill)),
    ];
    for (tag, durability) in modes {
        // Fresh data dir per mode: no replay of the previous mode's log.
        let _ = std::fs::remove_dir_all(&dir);
        let label = format!("service cohorts={cohorts} n={n} d={d} {tag}");
        service_round_rows(b, &label, cohorts, n, d, durability);
    }
    let _ = std::fs::remove_dir_all(&dir);
    println!();
}

/// One service row: spawn a `serve` loop with the given durability
/// mode and drive `cohorts` complete rounds per measured iteration
/// with n lock-step reporter threads.
fn service_round_rows(
    b: &mut Bencher,
    label: &str,
    cohorts: usize,
    n: usize,
    d: usize,
    durability: Option<DurabilityOpts>,
) {
    let listener = std::net::TcpListener::bind(("127.0.0.1", 0)).expect("bind service");
    let addr = listener.local_addr().expect("service addr").to_string();
    let opts = ServeOpts {
        // Generous deadline: lock-step reporters skew by at most one
        // round-trip, and a partial close would corrupt the
        // throughput measurement.
        default_deadline_ms: 120_000,
        max_rounds: None,
        read_timeout: Duration::from_secs(60),
        durability,
        ..ServeOpts::default()
    };
    let server = thread::spawn(move || serve(listener, opts));
    let cs = CohortSpec {
        n,
        d,
        spec: CodecSpec::Lq { q: 16 },
        y: 64.0,
        seed: 31,
    };
    let xs = inputs(n, d, 37);
    let (done_tx, done_rx) = mpsc::channel();
    let mut gos = Vec::new();
    let mut workers = Vec::new();
    for (j, input) in xs.iter().enumerate() {
        let (go_tx, go_rx) = mpsc::channel::<u64>();
        gos.push(go_tx);
        let addr = addr.clone();
        let input = input.clone();
        let done_tx = done_tx.clone();
        workers.push(thread::spawn(move || {
            for round in go_rx {
                for c in 0..cohorts as u64 {
                    report_round(&addr, c, round, j, &cs, &input, 0, Duration::from_secs(120))
                        .expect("service round");
                }
                let _ = done_tx.send(());
            }
        }));
    }
    let mut round = 0u64;
    b.bench(label, Some((cohorts * n * d) as u64), || {
        round += 1;
        for go in &gos {
            go.send(round).expect("reporter alive");
        }
        for _ in 0..n {
            done_rx.recv().expect("reporter done");
        }
        round
    });
    drop(gos);
    for w in workers {
        let _ = w.join();
    }
    request_shutdown(&addr, Duration::from_secs(5)).expect("service shutdown");
    server.join().expect("server thread").expect("serve exits cleanly");
}

/// Control-plane amortization: B sequential rounds vs one batched call
/// of B slots over the same persistent session. Throughput denominators
/// are B·n·d, so the rows are directly comparable per element.
fn batch_bench(b: &mut Bencher) {
    println!("# batch_bench — sequential rounds vs round_batch\n");
    let n = 8;
    for topology in [dme::coordinator::Topology::Star, dme::coordinator::Topology::Tree { m: n }] {
        for d in [128usize, 4096] {
            let xs = inputs(n, d, 17);
            for bsz in [1usize, 8, 64] {
                let label = topology.label();
                let mut seq = DmeBuilder::new(n, d).topology(topology).seed(9).build();
                b.bench(
                    &format!("{label} d={d} B={bsz} sequential"),
                    Some((bsz * n * d) as u64),
                    || {
                        let mut last = 0.0;
                        for _ in 0..bsz {
                            last = seq.round_with_y(&xs, 1.0).estimate[0];
                        }
                        last
                    },
                );
                let slots = vec![xs.clone(); bsz];
                let ys = vec![1.0; bsz];
                let mut batched = DmeBuilder::new(n, d).topology(topology).seed(9).build();
                let mut outcomes = Vec::new();
                b.bench(
                    &format!("{label} d={d} B={bsz} round_batch"),
                    Some((bsz * n * d) as u64),
                    || {
                        batched.round_batch_into(&slots, &ys, &mut outcomes);
                        outcomes[0].estimate[0]
                    },
                );
            }
            println!();
        }
    }
}

/// Write-side twin of `fold_bench`: one machine's per-round encode at
/// gradient scale, sequential fused block kernel vs chunk-parallel
/// sharding (both bit-identical to the scalar encode — pinned by the
/// prop/parity tests; the rows measure wall-clock only).
fn encode_plane_bench(b: &mut Bencher) {
    println!("# encode_plane_bench — sequential vs chunk-parallel encode\n");
    for d in [4096usize, 65536] {
        let mut rng = Rng::new(19);
        let x: Vec<f64> = (0..d).map(|_| 50.0 + rng.uniform(-0.5, 0.5)).collect();
        let mut shared = Rng::new(20);
        let mut lq = LatticeQuantizer::from_y(d, 16, 1.0, &mut shared);
        let mut d4 = D4Quantizer::from_y(d, 16, 1.0, &mut shared);
        let mut msg = Message::empty();
        b.bench(&format!("encode lq d={d} sequential"), Some(d as u64), || {
            lq.encode_into(&x, &mut rng, &mut msg);
            msg.bits
        });
        b.bench(&format!("encode lq d={d} chunk-parallel"), Some(d as u64), || {
            encode_chunked(&mut lq, &x, &mut rng, &mut msg, 8192);
            msg.bits
        });
        b.bench(&format!("encode d4 d={d} sequential"), Some(d as u64), || {
            d4.encode_into(&x, &mut rng, &mut msg);
            msg.bits
        });
        b.bench(&format!("encode d4 d={d} chunk-parallel"), Some(d as u64), || {
            encode_chunked(&mut d4, &x, &mut rng, &mut msg, 8192);
            msg.bits
        });
        println!();
    }
}

/// The pre-pool shape of the chunk-sharded fold: scoped threads spawned,
/// joined and torn down on every call, identical sharding math — the
/// baseline the persistent-`ChunkPool` row is measured against.
/// Bit-identical output (each shard depends only on its coordinate
/// range); only the thread lifecycle differs.
fn fold_mean_chunked_spawning<C: VectorCodec + Sync>(
    codec: &C,
    parts: &[FoldPart],
    reference: &[f64],
    out: &mut [f64],
    chunk: usize,
) {
    let align = codec.fold_chunk_align().max(1);
    let chunk = chunk.max(1).div_ceil(align) * align;
    let threads = dme::pool::threads();
    let n_chunks = out.len().div_ceil(chunk).max(1);
    let group = n_chunks.div_ceil(threads) * chunk;
    let inv_n = 1.0 / parts.len() as f64;
    thread::scope(|s| {
        for (gi, run) in out.chunks_mut(group).enumerate() {
            s.spawn(move || {
                for (ci, shard) in run.chunks_mut(chunk).enumerate() {
                    let lo = gi * group + ci * chunk;
                    for o in shard.iter_mut() {
                        *o = 0.0;
                    }
                    for part in parts {
                        match part {
                            FoldPart::Own(x) => {
                                dme::linalg::axpy(shard, 1.0, &x[lo..lo + shard.len()])
                            }
                            FoldPart::Encoded(msg) => {
                                codec.decode_accumulate_range(msg, reference, 1.0, lo, shard)
                            }
                        }
                    }
                    for o in shard.iter_mut() {
                        *o = inv_n * *o;
                    }
                }
            });
        }
    });
}

/// Leader aggregation data plane: legacy decode-then-sum vs the fused
/// streaming fold vs the chunk-sharded parallel fold. All variants
/// produce bit-identical estimates (pinned by `coordinator::fold` tests
/// and the pool-determinism prop tests); the rows measure the cost of
/// materializing n decoded vectors vs folding the bitstreams directly,
/// and — between the last two rows — spawn-per-call threads vs the
/// parked workers of the persistent pool.
fn fold_bench(b: &mut Bencher) {
    println!("# fold_bench — decode-then-sum vs streaming fold vs chunk-sharded fold (spawn vs pool)\n");
    for n in [16usize, 256] {
        for d in [128usize, 4096] {
            let xs = inputs(n, d, 13);
            let reference = xs[0].clone();
            let mut shared = Rng::new(4);
            let mut lq = LatticeQuantizer::from_y(d, 16, 1.0, &mut shared);
            let mut rng = Rng::new(5);
            let msgs: Vec<Message> = xs[1..].iter().map(|x| lq.encode(x, &mut rng)).collect();
            let mut parts: Vec<FoldPart> = vec![FoldPart::Own(&xs[0])];
            parts.extend(msgs.iter().map(FoldPart::Encoded));

            // (a) Legacy leader: decode every message into its own
            // (pre-allocated) buffer, then a second pass sums them.
            let mut decoded = vec![vec![0.0; d]; n];
            let mut mu = vec![0.0; d];
            b.bench(
                &format!("fold n={n} d={d} decode-then-sum"),
                Some((n * d) as u64),
                || {
                    decoded[0].copy_from_slice(&xs[0]);
                    for (z, msg) in decoded[1..].iter_mut().zip(&msgs) {
                        lq.decode_into(msg, &reference, z);
                    }
                    for m in mu.iter_mut() {
                        *m = 0.0;
                    }
                    for z in &decoded {
                        dme::linalg::axpy(&mut mu, 1.0, z);
                    }
                    let inv_n = 1.0 / n as f64;
                    for m in mu.iter_mut() {
                        *m = inv_n * *m;
                    }
                    mu[0]
                },
            );

            // (b) Fused block-kernel streaming fold: one pass per
            // bitstream straight into the O(d) accumulator.
            b.bench(
                &format!("fold n={n} d={d} streaming-fused"),
                Some((n * d) as u64),
                || {
                    fold_mean(&lq, &parts, &reference, &mut mu);
                    mu[0]
                },
            );

            // (c) Chunk-sharded fold, scoped threads spawned per call
            // (the pre-pool shape — 1024-coordinate shards).
            b.bench(
                &format!("fold n={n} d={d} chunk spawn-per-call"),
                Some((n * d) as u64),
                || {
                    fold_mean_chunked_spawning(&lq, &parts, &reference, &mut mu, 1024);
                    mu[0]
                },
            );

            // (d) Same shards on the persistent worker pool.
            b.bench(
                &format!("fold n={n} d={d} chunk parked pool"),
                Some((n * d) as u64),
                || {
                    fold_mean_chunked(&lq, &parts, &reference, &mut mu, 1024);
                    mu[0]
                },
            );
            println!();
        }
    }
}

/// Spawn-per-round vs persistent session vs zero-realloc codec calls.
fn session_bench(b: &mut Bencher) {
    println!("# session_bench — persistent sessions + encode_into/decode_into\n");
    let n = 8;
    for d in [128usize, 4096] {
        let xs = inputs(n, d, 11);
        let spec = CodecSpec::Lq { q: 16 };

        // (a) Legacy deployment: a fresh cluster per round — n thread
        // spawns and O(n·d) fresh vectors every round. Built directly
        // (diagnostics off) so the comparison isolates spawn + alloc
        // cost, not the legacy wrapper's diagnostics copies.
        let mut round = 0u64;
        b.bench(
            &format!("round n={n} d={d} spawn-per-round"),
            Some((n * d) as u64),
            || {
                round += 1;
                let mut one = DmeBuilder::new(n, d).codec(spec).seed(5).build();
                one.set_round(round);
                one.round_with_y(&xs, 1.0)
            },
        );

        // (b) Persistent session: threads spawned once, buffers recycled,
        // codecs write through encode_into/decode_into scratch space.
        let mut sess = DmeBuilder::new(n, d).codec(spec).seed(5).build();
        b.bench(
            &format!("round n={n} d={d} persistent-session"),
            Some((n * d) as u64),
            || sess.round_with_y(&xs, 1.0),
        );
        // Both topologies stay persistent now.
        let mut tree = DmeBuilder::new(n, d)
            .topology(dme::coordinator::Topology::Tree { m: n })
            .seed(5)
            .build();
        b.bench(
            &format!("round n={n} d={d} persistent-tree"),
            Some((n * d) as u64),
            || tree.round_with_y(&xs, 1.0),
        );

        // (c) Codec level: allocating vs buffer-reusing encode/decode.
        let mut shared = Rng::new(2);
        let mut lq = LatticeQuantizer::from_y(d, 16, 1.0, &mut shared);
        let x = &xs[0];
        let xv = &xs[1];
        let mut rng = Rng::new(3);
        b.bench(&format!("lq encode (alloc)   d={d}"), Some(d as u64), || {
            lq.encode(x, &mut rng)
        });
        let mut msg = Message::empty();
        b.bench(&format!("lq encode_into      d={d}"), Some(d as u64), || {
            lq.encode_into(x, &mut rng, &mut msg);
            msg.bits
        });
        let wire = lq.encode(x, &mut rng);
        b.bench(&format!("lq decode (alloc)   d={d}"), Some(d as u64), || {
            lq.decode(&wire, xv)
        });
        let mut out = vec![0.0; d];
        b.bench(&format!("lq decode_into      d={d}"), Some(d as u64), || {
            lq.decode_into(&wire, xv, &mut out);
            out[0]
        });
        println!();
    }
}
