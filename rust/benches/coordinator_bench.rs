//! End-to-end coordinator benchmarks: full MeanEstimation rounds over the
//! simulated cluster (threads + channels + bit metering included), plus
//! the robust VR protocol — the paper's Theorem 2/3/4 operations as
//! deployed. One row per (topology, n, d).

use dme::bench::Bencher;
use dme::coordinator::{
    mean_estimation_star, mean_estimation_tree, robust_variance_reduction, CodecSpec,
};
use dme::rng::Rng;

fn inputs(n: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..d).map(|_| 50.0 + rng.uniform(-0.5, 0.5)).collect())
        .collect()
}

fn main() {
    let mut b = Bencher::from_env();
    println!("# coordinator_bench — full protocol rounds\n");

    for (n, d) in [(4usize, 128usize), (8, 128), (8, 1024), (16, 1024)] {
        let xs = inputs(n, d, 7);
        let mut round = 0u64;
        b.bench(
            &format!("star  n={n} d={d} q=16 (threads)"),
            Some((n * d) as u64),
            || {
                round += 1;
                mean_estimation_star(&xs, &CodecSpec::Lq { q: 16 }, 1.0, 3, round)
            },
        );
        // §Perf: same protocol on a persistent session (spawn amortized).
        let mut sess = dme::coordinator::StarSession::new(n, d, CodecSpec::Lq { q: 16 }, 3);
        b.bench(
            &format!("star  n={n} d={d} q=16 (session)"),
            Some((n * d) as u64),
            || sess.round(&xs, 1.0),
        );
        let mut round = 0u64;
        b.bench(
            &format!("tree  n={n} d={d} (m=n)"),
            Some((n * d) as u64),
            || {
                round += 1;
                mean_estimation_tree(&xs, n, 1.0, 3, round)
            },
        );
        let mut round = 0u64;
        b.bench(
            &format!("robust-vr n={n} d={d} q0=16"),
            Some((n * d) as u64),
            || {
                round += 1;
                robust_variance_reduction(&xs, 0.5, 16, 3, round)
            },
        );
        println!();
    }
}
