//! Experiment-harness benchmark: times a reduced-scale regeneration of
//! every paper figure/table (E1–E8, tradeoff, ablation, dropout) to
//! prove the full harness
//! runs end to end under `cargo bench` and to track its cost.
//!
//! For the full-scale reports use `dme exp all` (see EXPERIMENTS.md).

use dme::bench::Bencher;
use dme::exp::{self, ExpOpts};
use std::time::Duration;

fn main() {
    let mut b = Bencher::from_env();
    // Figure regeneration is seconds-scale: one timed sample is enough.
    b.warmup = Duration::from_millis(0);
    b.measure = Duration::from_millis(1);
    b.min_samples = 1;
    println!("# experiments_bench — reduced-scale figure regeneration\n");

    let opts = ExpOpts {
        scale: 0.08,
        seeds: 1,
        out_dir: None,
        batch: 1,
        addr: None,
    };
    for id in exp::ALL_IDS {
        b.bench(&format!("exp {id} (scale=0.08)"), None, || {
            let r = exp::run(id, &opts).expect("known id");
            assert!(!r.is_empty());
            r.len()
        });
    }

    b.write_json("experiments_bench").expect("write bench json");
}
