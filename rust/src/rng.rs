//! Deterministic pseudo-random number generation.
//!
//! The paper's algorithms rely on *shared randomness* between encoder and
//! decoder (the lattice offset `θ`, the Hadamard sign diagonal `D`, random
//! colorings). We therefore need a small, fully deterministic, seedable PRNG
//! that both sides of a protocol can instantiate from a common seed — and the
//! offline build has no `rand` crate, so we carry our own.
//!
//! The generator is xoshiro256++ seeded via splitmix64, the standard
//! construction recommended by Blackman & Vigna. It is *not* cryptographic;
//! it is used only for unbiased rounding, offsets and experiment workloads.

/// splitmix64 step — used for seeding and for cheap stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Hash a pair of u64s into one (for deriving per-round / per-machine seeds).
#[inline]
pub fn hash2(a: u64, b: u64) -> u64 {
    let mut s = a ^ 0xA0761D6478BD642F;
    let h1 = splitmix64(&mut s);
    let mut s2 = h1 ^ b;
    splitmix64(&mut s2)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a sub-component (e.g. machine id).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(hash2(self.next_u64(), tag))
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` (n > 0) via Lemire's method.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Fair coin.
    #[inline]
    pub fn next_bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Random sign in `{-1.0, +1.0}`.
    #[inline]
    pub fn next_sign(&mut self) -> f64 {
        if self.next_bool() {
            1.0
        } else {
            -1.0
        }
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// adequate for workload generation).
    pub fn next_gaussian(&mut self) -> f64 {
        // Avoid log(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of standard normals.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.next_gaussian()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (reservoir for small k).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher-Yates: first k entries become the sample
        for i in 0..k {
            let j = i + self.next_below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs = r.gaussian_vec(n);
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(11);
        let s = r.sample_indices(100, 20);
        assert_eq!(s.len(), 20);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
