//! Deterministic pseudo-random number generation.
//!
//! The paper's algorithms rely on *shared randomness* between encoder and
//! decoder (the lattice offset `θ`, the Hadamard sign diagonal `D`, random
//! colorings). We therefore need a small, fully deterministic, seedable PRNG
//! that both sides of a protocol can instantiate from a common seed — and the
//! offline build has no `rand` crate, so we carry our own.
//!
//! The generator is xoshiro256++ seeded via splitmix64, the standard
//! construction recommended by Blackman & Vigna. It is *not* cryptographic;
//! it is used only for unbiased rounding, offsets and experiment workloads.

/// splitmix64 step — used for seeding and for cheap stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Hash a pair of u64s into one (for deriving per-round / per-machine seeds).
#[inline]
pub fn hash2(a: u64, b: u64) -> u64 {
    let mut s = a ^ 0xA0761D6478BD642F;
    let h1 = splitmix64(&mut s);
    let mut s2 = h1 ^ b;
    splitmix64(&mut s2)
}

/// Per-round shared-randomness seeds for a batched round window
/// `[first_round, first_round + count)` — the one-fan-out-per-batch form
/// of the per-round `hash2(seed, round)` reseeding the sequential round
/// loop performs. Each element equals the sequential derivation exactly,
/// so batching the derivation is a pure scheduling change: codecs,
/// dither offsets and rotation signs built from these seeds are
/// bit-identical to the per-round path.
pub fn fork_round_seeds(seed: u64, first_round: u64, count: usize) -> Vec<u64> {
    (0..count as u64)
        .map(|b| hash2(seed, first_round + b))
        .collect()
}

/// One xoshiro256++ state step — the single copy of the generator
/// algorithm. [`Rng::next_u64`] runs it on `self.s` directly; the bulk
/// fills ([`Rng::fill_u64`], [`Rng::fill_uniform`]) run it on a local
/// copy of the state (registers for the whole fill) and store back once.
#[inline(always)]
fn xoshiro_step(s: &mut [u64; 4]) -> u64 {
    let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
    let t = s[1] << 17;
    s[2] ^= s[0];
    s[3] ^= s[1];
    s[1] ^= s[2];
    s[0] ^= s[3];
    s[2] ^= t;
    s[3] = s[3].rotate_left(45);
    result
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second half of the last Box–Muller draw (see
    /// [`Self::next_gaussian`]).
    spare_gauss: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng {
            s,
            spare_gauss: None,
        }
    }

    /// Derive an independent stream for a sub-component (e.g. machine id).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(hash2(self.next_u64(), tag))
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        xoshiro_step(&mut self.s)
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fill `out` with raw 64-bit draws — the bulk twin of
    /// [`Self::next_u64`], producing the *identical* stream (one
    /// [`xoshiro_step`] per word, in order). The generator state lives
    /// in a local for the whole fill instead of round-tripping through
    /// `self` per draw, which is what the fused stochastic-rounding
    /// encode kernels feed on (§Perf). Pinned by
    /// `bulk_fills_match_scalar_draws`.
    pub fn fill_u64(&mut self, out: &mut [u64]) {
        let mut s = self.s;
        for o in out.iter_mut() {
            *o = xoshiro_step(&mut s);
        }
        self.s = s;
    }

    /// Fill `out` with uniforms in `[0, 1)` — the bulk twin of
    /// [`Self::next_f64`], stream-identical to calling it `out.len()`
    /// times (same draws, same 53-bit conversion, same final state).
    ///
    /// §Perf: the xoshiro recurrence is inherently serial, so the raw
    /// words are drawn scalar into a stack staging block; the 53-bit
    /// shift-and-scale conversion then runs through
    /// [`crate::simd::uniform_from_bits`], whose AVX2 path is exact (see
    /// its docs) — bit-identical output either way, pinned by
    /// `bulk_fills_match_scalar_draws` and
    /// `prop_bulk_uniform_fill_stream_identical_across_chunk_boundary`.
    pub fn fill_uniform(&mut self, out: &mut [f64]) {
        const CHUNK: usize = 256;
        let mut words = [0u64; CHUNK];
        let mut s = self.s;
        for block in out.chunks_mut(CHUNK) {
            for w in words[..block.len()].iter_mut() {
                *w = xoshiro_step(&mut s);
            }
            crate::simd::uniform_from_bits(&words[..block.len()], block);
        }
        self.s = s;
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` (n > 0) via Lemire's method.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Fair coin.
    #[inline]
    pub fn next_bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Random sign in `{-1.0, +1.0}`.
    #[inline]
    pub fn next_sign(&mut self) -> f64 {
        if self.next_bool() {
            1.0
        } else {
            -1.0
        }
    }

    /// Standard normal via Box–Muller. Each uniform pair yields *two*
    /// independent normals (the cosine and sine projections of one
    /// Rayleigh-radius draw); the sine half is cached and returned by the
    /// next call, so a run of draws consumes one uniform per normal
    /// instead of two. The stream is fully deterministic in the seed
    /// (pinned by `gaussian_pairs_come_from_one_box_muller_draw`).
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(z) = self.spare_gauss.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_gauss = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Vector of standard normals.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.next_gaussian()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (reservoir for small k).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher-Yates: first k entries become the sample
        for i in 0..k {
            let j = i + self.next_below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs = r.gaussian_vec(n);
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gaussian_pairs_come_from_one_box_muller_draw() {
        // The spare cache must pin the stream exactly: draws 2k and 2k+1
        // are the cosine and sine halves of one (u1, u2) uniform pair.
        let mut g = Rng::new(123);
        let gs: Vec<f64> = (0..6).map(|_| g.next_gaussian()).collect();
        let mut u = Rng::new(123);
        for pair in gs.chunks(2) {
            let u1 = u.next_f64();
            let u2 = u.next_f64();
            assert!(u1 > 1e-300);
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            assert_eq!(pair[0], r * theta.cos());
            assert_eq!(pair[1], r * theta.sin());
        }
        // Determinism across instances survives the cache.
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_gaussian(), b.next_gaussian());
        }
        // gaussian_vec rides the same cached stream.
        let mut c = Rng::new(7);
        let v = c.gaussian_vec(100);
        let mut d = Rng::new(7);
        for vi in &v {
            assert_eq!(*vi, d.next_gaussian());
        }
    }

    #[test]
    fn bulk_fills_match_scalar_draws() {
        // fill_u64 / fill_uniform must be stream-identical to repeated
        // next_u64 / next_f64 — same values AND same final state, so
        // scalar and bulk consumption can interleave freely. This is the
        // contract the fused baseline encode kernels rely on to stay
        // bit-identical to the seed's one-draw-per-coordinate loops.
        let mut scalar = Rng::new(77);
        let mut bulk = Rng::new(77);
        for &n in &[1usize, 2, 7, 64, 257] {
            let expect_u: Vec<u64> = (0..n).map(|_| scalar.next_u64()).collect();
            let mut got_u = vec![0u64; n];
            bulk.fill_u64(&mut got_u);
            assert_eq!(got_u, expect_u, "fill_u64 n={n}");
            let expect_f: Vec<f64> = (0..n).map(|_| scalar.next_f64()).collect();
            let mut got_f = vec![0.0f64; n];
            bulk.fill_uniform(&mut got_f);
            assert_eq!(got_f, expect_f, "fill_uniform n={n}");
            // Interleave a scalar draw between fills: state must agree.
            assert_eq!(scalar.next_u64(), bulk.next_u64(), "state after fills n={n}");
        }
        // Empty fill is a no-op on the state.
        bulk.fill_uniform(&mut []);
        bulk.fill_u64(&mut []);
        assert_eq!(scalar.next_u64(), bulk.next_u64());
    }

    #[test]
    fn fork_round_seeds_matches_per_round_reseeding() {
        let seeds = fork_round_seeds(42, 1000, 5);
        assert_eq!(seeds.len(), 5);
        for (b, s) in seeds.iter().enumerate() {
            assert_eq!(*s, hash2(42, 1000 + b as u64));
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(11);
        let s = r.sample_indices(100, 20);
        assert_eq!(s.len(), 20);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
