//! Quantized gradient aggregation — the all-to-all exchange used by the
//! two-machine experiments (§9.2 Exp 2–4) and, generalized to n machines,
//! by local SGD, power iteration and the MLP driver.
//!
//! Every machine broadcasts its encoded vector; every machine decodes all
//! messages against **its own** current vector (the lattice scheme's
//! reference) and averages the decoded points. For lattice codecs the
//! decoded point is the encoder's exact lattice point whenever inputs are
//! within the success radius, so all machines agree bit-for-bit; decode
//! disagreements are *detected* (by cross-checking two references) and
//! reported, mirroring the paper's observed ~3% incorrect-decode rate in
//! Exp 7 (tolerated there, surfaced here).

use crate::coordinator::{CodecSpec, YEstimator, YPolicy};
use crate::quant::hadamard::Rotation;
use crate::quant::VectorCodec;
use crate::rng::{hash2, Rng};

/// Per-step aggregation report.
#[derive(Clone, Debug)]
pub struct StepReport {
    /// The common estimate (mean of decoded vectors).
    pub estimate: Vec<f64>,
    /// Decoded quantized point per machine (reference machine's view).
    pub decoded: Vec<Vec<f64>>,
    /// Bits sent per machine this step (incl. side info and y updates).
    pub bits_sent: Vec<u64>,
    /// Bits received per machine this step.
    pub bits_recv: Vec<u64>,
    /// Number of messages whose decode disagreed across references.
    pub decode_mismatches: usize,
    /// y used this round (lattice codecs), rotated-space for RLQ.
    pub y_used: f64,
}

/// Stateful aggregator: owns per-machine codecs (for EF/PowerSGD-style
/// state) and the y estimator (for lattice codecs).
pub struct Aggregator {
    pub spec: CodecSpec,
    pub n: usize,
    pub d: usize,
    pub seed: u64,
    pub y_est: YEstimator,
    round: u64,
    /// Persistent per-machine codecs for stateful specs.
    codecs: Vec<Box<dyn VectorCodec>>,
}

impl Aggregator {
    pub fn new(spec: CodecSpec, n: usize, d: usize, y0: f64, policy: YPolicy, seed: u64) -> Self {
        let codecs = if spec.is_stateful() {
            (0..n).map(|_| spec.build(d, y0, seed, 0)).collect()
        } else {
            Vec::new()
        };
        Aggregator {
            spec,
            n,
            d,
            seed,
            y_est: YEstimator::new(policy, y0),
            round: 0,
            codecs,
        }
    }

    /// The rotation RLQ uses this round (shared-randomness reconstruction;
    /// must consume the same draws as `CodecSpec::Rlq.build`).
    fn rlq_rotation(&self, round: u64) -> Rotation {
        let mut shared = Rng::new(hash2(self.seed, round));
        Rotation::new(self.d, &mut shared)
    }

    /// Run one aggregation over the machines' vectors.
    pub fn step(&mut self, vectors: &[Vec<f64>]) -> StepReport {
        assert_eq!(vectors.len(), self.n);
        let n = self.n;
        let round = self.round;
        self.round += 1;
        let y = self.y_est.y;

        // Build / reuse codecs.
        let mut fresh: Vec<Box<dyn VectorCodec>>;
        let codecs: &mut [Box<dyn VectorCodec>] = if self.spec.is_stateful() {
            &mut self.codecs
        } else {
            fresh = (0..n)
                .map(|_| self.spec.build(self.d, y, self.seed, round))
                .collect();
            &mut fresh
        };

        // Encode at every machine.
        let mut msgs = Vec::with_capacity(n);
        for (i, v) in vectors.iter().enumerate() {
            let mut rng = Rng::new(hash2(hash2(self.seed, round), 0x5E11D ^ i as u64));
            msgs.push(codecs[i].encode(v, &mut rng));
        }

        // Traffic: all-to-all broadcast.
        let mut bits_sent = vec![0u64; n];
        let mut bits_recv = vec![0u64; n];
        for i in 0..n {
            bits_sent[i] += msgs[i].bits * (n as u64 - 1);
            for j in 0..n {
                if j != i {
                    bits_recv[i] += msgs[j].bits;
                }
            }
        }

        // Decode everything against machine (i+1)%n's reference and
        // cross-check against a second reference to detect disagreement.
        let mut decoded = Vec::with_capacity(n);
        let mut mismatches = 0;
        for (i, msg) in msgs.iter().enumerate() {
            let ref_a = &vectors[(i + 1) % n];
            let z = codecs[i].decode(msg, ref_a);
            if n > 2 {
                let ref_b = &vectors[(i + 2) % n];
                let z2 = codecs[i].decode(msg, ref_b);
                if codecs[i].needs_reference() && z != z2 {
                    mismatches += 1;
                }
            } else if n == 2 && codecs[i].needs_reference() {
                // Cross-check against the encoder's own vector.
                let z2 = codecs[i].decode(msg, &vectors[i]);
                if z != z2 {
                    mismatches += 1;
                }
            }
            decoded.push(z);
        }

        let estimate = crate::linalg::mean_vecs(&decoded);

        // Maintain y. For RLQ the policy tracks rotated-space distances.
        let side_bits = match self.spec {
            CodecSpec::Rlq { .. } => {
                let rot = self.rlq_rotation(round);
                let rotated: Vec<Vec<f64>> = decoded.iter().map(|p| rot.forward(p)).collect();
                self.y_est.update(&rotated, n)
            }
            CodecSpec::Lq { .. } | CodecSpec::LqHull { .. } => self.y_est.update(&decoded, n),
            _ => 0,
        };
        if side_bits > 0 {
            // Charged to machine 0 (the measuring leader) as sender.
            bits_sent[0] += side_bits;
            let per = side_bits / (n as u64 - 1).max(1);
            for b in bits_recv.iter_mut().skip(1) {
                *b += per;
            }
        }

        StepReport {
            estimate,
            decoded,
            bits_sent,
            bits_recv,
            decode_mismatches: mismatches,
            y_used: y,
        }
    }

    /// Batched stepping — the all-to-all path's `batch` knob: process
    /// `slots[b]` as round `rounds() + b` in one call, collecting every
    /// per-slot report. The aggregator is in-process (there is no worker
    /// channel crossing to amortize, unlike
    /// [`crate::coordinator::DmeSession::round_batch`]), so each slot is
    /// bit-identical to a sequential [`Aggregator::step`] call — the knob
    /// buys the batched *calling convention* (multi-vector steps, e.g.
    /// per-layer gradients of equal width or coordinate chunks) without
    /// changing a single wire bit (pinned by a test).
    pub fn step_batch(&mut self, slots: &[Vec<Vec<f64>>]) -> Vec<StepReport> {
        slots.iter().map(|s| self.step(s)).collect()
    }

    pub fn rounds(&self) -> u64 {
        self.round
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dist2, mean_vecs};

    fn two_grads(center: f64, spread: f64, d: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..2)
            .map(|_| {
                (0..d)
                    .map(|_| center + rng.uniform(-spread, spread))
                    .collect()
            })
            .collect()
    }

    #[test]
    fn lq_estimate_unbiased_and_tight() {
        let d = 64;
        let grads = two_grads(500.0, 0.05, d, 1);
        let mu = mean_vecs(&grads);
        let mut agg = Aggregator::new(
            CodecSpec::Lq { q: 8 },
            2,
            d,
            0.2,
            YPolicy::FromQuantized { slack: 1.5 },
            7,
        );
        let rep = agg.step(&grads);
        assert_eq!(rep.decode_mismatches, 0);
        let s = 2.0 * 0.2 / 7.0;
        assert!(dist2(&rep.estimate, &mu) <= s * (d as f64).sqrt());
    }

    #[test]
    fn y_adapts_from_quantized_points() {
        let d = 16;
        let mut agg = Aggregator::new(
            CodecSpec::Lq { q: 16 },
            2,
            d,
            10.0, // deliberately loose start
            YPolicy::FromQuantized { slack: 1.5 },
            9,
        );
        let grads = two_grads(0.0, 0.01, d, 2);
        agg.step(&grads);
        let y1 = agg.y_est.y;
        assert!(y1 < 10.0, "y should tighten: {y1}");
        agg.step(&grads);
        assert!(agg.y_est.y <= y1 * 1.5 + 1e-9);
    }

    #[test]
    fn bits_accounting_all_to_all() {
        let d = 32;
        let n = 4;
        let mut rng = Rng::new(3);
        let grads: Vec<Vec<f64>> = (0..n).map(|_| rng.gaussian_vec(d)).collect();
        let mut agg = Aggregator::new(CodecSpec::Lq { q: 16 }, n, d, 10.0, YPolicy::Fixed, 11);
        let rep = agg.step(&grads);
        let msg = d as u64 * 4;
        for i in 0..n {
            assert_eq!(rep.bits_sent[i], msg * (n as u64 - 1));
            assert_eq!(rep.bits_recv[i], msg * (n as u64 - 1));
        }
    }

    #[test]
    fn step_batch_bit_identical_to_sequential_steps() {
        let d = 24;
        let n = 3;
        let mut rng = Rng::new(5);
        let slots: Vec<Vec<Vec<f64>>> = (0..4)
            .map(|_| (0..n).map(|_| rng.gaussian_vec(d)).collect())
            .collect();
        let mk = || {
            Aggregator::new(
                CodecSpec::Lq { q: 16 },
                n,
                d,
                5.0,
                YPolicy::FromQuantized { slack: 1.5 },
                23,
            )
        };
        let mut batched = mk();
        let mut seq = mk();
        let reps = batched.step_batch(&slots);
        assert_eq!(reps.len(), 4);
        assert_eq!(batched.rounds(), 4);
        for (b, rep) in reps.iter().enumerate() {
            let s = seq.step(&slots[b]);
            assert_eq!(rep.estimate, s.estimate, "slot {b}");
            assert_eq!(rep.bits_sent, s.bits_sent, "slot {b}");
            assert_eq!(rep.y_used, s.y_used, "slot {b}");
        }
    }

    #[test]
    fn stateful_codec_persists_across_steps() {
        let d = 8;
        let mut agg = Aggregator::new(CodecSpec::EfSign, 2, d, 1.0, YPolicy::Fixed, 13);
        let grads = vec![vec![1.0, 0.1, 0.0, -0.2, 0.5, -0.9, 0.3, 0.0]; 2];
        let r1 = agg.step(&grads);
        let r2 = agg.step(&grads);
        // With error feedback, the second step's decoded output differs
        // from the first (residual flushed), proving state persisted.
        assert_ne!(r1.decoded[0], r2.decoded[0]);
    }

    #[test]
    fn rlq_handles_nonzero_center() {
        let d = 48;
        let grads = two_grads(100.0, 0.02, d, 4);
        let mu = mean_vecs(&grads);
        let mut agg = Aggregator::new(
            CodecSpec::Rlq { q: 16 },
            2,
            d,
            0.1, // y_R bootstrap
            YPolicy::FromQuantized { slack: 2.0 },
            17,
        );
        // First step may be off if y_R was mis-set; step twice to adapt.
        agg.step(&grads);
        let rep = agg.step(&grads);
        assert!(
            dist2(&rep.estimate, &mu) < 1.0,
            "err {}",
            dist2(&rep.estimate, &mu)
        );
    }
}
