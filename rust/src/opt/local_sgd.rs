//! Local SGD with compressed model averaging — Experiment 6 (§9.3).
//!
//! Each worker takes `local_steps` SGD steps on its own shard, then the
//! workers average their models. Following the paper, what is compressed
//! is the **model delta** `Δ_i = w_i − w_global` accumulated since the
//! last averaging step (neither models nor deltas are origin-centered,
//! which is why RLQSGD is the natural fit).

use super::allreduce::Aggregator;
use super::{chunk_count, chunk_slots, concat_chunk_outcomes, BatchYDriver};
use crate::coordinator::{CodecSpec, RoundOutcome, Topology, YPolicy};
use crate::data::Regression;
use crate::linalg::dist2;
use crate::rng::{hash2, Rng};

#[derive(Clone, Debug)]
pub struct LocalSgdConfig {
    pub n_machines: usize,
    pub lr: f64,
    /// Local steps between averaging rounds (paper: 10).
    pub local_steps: usize,
    /// Number of averaging rounds.
    pub rounds: usize,
    pub batch: usize,
    pub seed: u64,
    pub y0: f64,
    pub y_policy: YPolicy,
    /// `None` (default): the historical all-to-all exchange. `Some(t)`:
    /// aggregate the deltas through a persistent [`crate::coordinator::DmeBuilder`] session
    /// over topology `t` (tree sessions pin `y` at `y0` — the tree has
    /// no leader to measure it). Session aggregation runs the streaming
    /// fold: the leader (star) and every inner node (tree) fold incoming
    /// bitstreams straight into an O(d) accumulator.
    pub topology: Option<Topology>,
    /// Batched-round knob (session aggregation only): ship each
    /// averaging round's delta as this many coordinate-chunk slots of
    /// one `round_batch_with_y` call — one worker crossing per round.
    /// 1 (default) keeps the sequential round; > 1 maintains `y` per
    /// chunk at the driver (star: the configured policy; tree: fixed).
    pub batch_slots: usize,
}

impl Default for LocalSgdConfig {
    fn default() -> Self {
        LocalSgdConfig {
            n_machines: 2,
            lr: 0.05,
            local_steps: 10,
            rounds: 40,
            batch: 256,
            seed: 0,
            y0: 1.0,
            y_policy: YPolicy::FromQuantized { slack: 2.0 },
            topology: None,
            batch_slots: 1,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct LocalSgdTrace {
    /// Global-model loss after each averaging round.
    pub loss: Vec<f64>,
    /// Quantization error ‖mean(Δ̂) − mean(Δ)‖₂ per round.
    pub quant_err: Vec<f64>,
    pub max_bits_sent: Vec<u64>,
    pub w: Vec<f64>,
}

/// Run Local SGD; `spec = None` is the uncompressed baseline.
pub fn run_local_sgd(
    ds: &Regression,
    spec: Option<CodecSpec>,
    cfg: &LocalSgdConfig,
) -> LocalSgdTrace {
    let d = ds.dim();
    let n = cfg.n_machines;
    let mut w_global = vec![0.0; d];
    let mut trace = LocalSgdTrace::default();
    // Compressed averaging backend: a persistent session over the
    // configured topology, or the historical all-to-all aggregator.
    assert!(
        cfg.topology.is_none() || spec.is_some(),
        "cfg.topology requires a codec (spec = None is the uncompressed baseline)"
    );
    let mut sess = match (cfg.topology, spec) {
        (Some(topology), Some(s)) => Some(super::topology_session(
            n,
            d,
            topology,
            s,
            cfg.seed,
            cfg.y0,
            cfg.y_policy,
        )),
        _ => None,
    };
    let mut agg = match (&sess, spec) {
        (None, Some(s)) => Some(Aggregator::new(s, n, d, cfg.y0, cfg.y_policy, cfg.seed)),
        _ => None,
    };
    // Batched session rounds (batch_slots > 1): per-chunk y at the
    // driver — tree sessions pin y (no leader to measure it).
    let mut batch_y = match (cfg.topology, spec) {
        (Some(topology), Some(s)) if cfg.batch_slots > 1 => Some(BatchYDriver::new(
            chunk_count(d, cfg.batch_slots),
            match topology {
                Topology::Star => cfg.y_policy,
                Topology::Tree { .. } => YPolicy::Fixed,
            },
            cfg.y0,
            s,
            cfg.seed,
        )),
        _ => None,
    };
    let mut ys: Vec<f64> = Vec::new();
    let mut outcomes: Vec<RoundOutcome> = Vec::new();
    let mut rng = Rng::new(hash2(cfg.seed, 0x10CA1));

    // Static shard per worker (Local SGD's data-local regime).
    let shards = ds.partition(n, &mut rng);

    for _round in 0..cfg.rounds {
        // Local training.
        let mut deltas = Vec::with_capacity(n);
        for shard in shards.iter() {
            let mut w = w_global.clone();
            for _ in 0..cfg.local_steps {
                let batch: Vec<usize> = (0..cfg.batch)
                    .map(|_| shard[rng.next_below(shard.len() as u64) as usize])
                    .collect();
                let g = ds.batch_gradient(&w, &batch);
                crate::linalg::axpy(&mut w, -cfg.lr, &g);
            }
            deltas.push(crate::linalg::sub(&w, &w_global));
        }
        let true_mean = crate::linalg::mean_vecs(&deltas);

        let (applied, bits) = if let Some(s) = sess.as_mut() {
            if let Some(ydrv) = batch_y.as_mut() {
                // One batched round: the delta's coordinate chunks ride
                // as slots, one worker crossing for the whole exchange.
                let slots = chunk_slots(&deltas, cfg.batch_slots);
                let first_round = s.rounds_run();
                ydrv.fill_ys(&mut ys);
                s.round_batch_into(&slots, &ys, &mut outcomes);
                ydrv.observe(&slots, first_round);
                concat_chunk_outcomes(&outcomes)
            } else {
                let out = s.round(&deltas);
                let mb = out.max_sent_bits();
                (out.estimate, mb)
            }
        } else if let Some(a) = agg.as_mut() {
            let rep = a.step(&deltas);
            let mb = rep.bits_sent.iter().copied().max().unwrap_or(0);
            (rep.estimate, mb)
        } else {
            (true_mean.clone(), 0)
        };
        trace.quant_err.push(dist2(&applied, &true_mean));
        trace.max_bits_sent.push(bits);
        crate::linalg::axpy(&mut w_global, 1.0, &applied);
        trace.loss.push(ds.loss(&w_global));
    }
    trace.w = w_global;
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_lsq;

    #[test]
    fn uncompressed_local_sgd_converges() {
        let ds = gen_lsq(1024, 10, 1);
        let cfg = LocalSgdConfig {
            rounds: 30,
            ..Default::default()
        };
        let t = run_local_sgd(&ds, None, &cfg);
        assert!(t.loss.last().unwrap() < &0.05, "{:?}", t.loss.last());
        assert!(t.quant_err.iter().all(|&e| e == 0.0));
    }

    #[test]
    fn rlq_compressed_tracks_baseline() {
        let ds = gen_lsq(1024, 16, 2);
        let cfg = LocalSgdConfig {
            rounds: 30,
            y0: 0.5,
            ..Default::default()
        };
        let base = run_local_sgd(&ds, None, &cfg);
        let rlq = run_local_sgd(&ds, Some(CodecSpec::Rlq { q: 16 }), &cfg);
        let lb = base.loss.last().unwrap();
        let lr_ = rlq.loss.last().unwrap();
        assert!(lr_ < &(lb * 5.0 + 0.1), "RLQ {lr_} vs base {lb}");
        assert!(rlq.max_bits_sent.iter().any(|&b| b > 0));
    }

    #[test]
    fn star_topology_session_tracks_baseline() {
        let ds = gen_lsq(1024, 16, 4);
        let base_cfg = LocalSgdConfig {
            rounds: 30,
            y0: 0.5,
            ..Default::default()
        };
        let star_cfg = LocalSgdConfig {
            topology: Some(Topology::Star),
            ..base_cfg.clone()
        };
        let base = run_local_sgd(&ds, None, &base_cfg);
        let star = run_local_sgd(&ds, Some(CodecSpec::Lq { q: 64 }), &star_cfg);
        let lb = base.loss.last().unwrap();
        let ls = star.loss.last().unwrap();
        assert!(ls < &(lb * 5.0 + 0.1), "star {ls} vs base {lb}");
        assert!(star.max_bits_sent.iter().any(|&b| b > 0));
    }

    #[test]
    fn batched_session_rounds_track_baseline() {
        // batch_slots > 1 over both topologies: chunked batched rounds
        // must converge like the sequential session path.
        let ds = gen_lsq(1024, 16, 4);
        let base = run_local_sgd(
            &ds,
            None,
            &LocalSgdConfig {
                rounds: 30,
                y0: 0.5,
                ..Default::default()
            },
        );
        let lb = base.loss.last().unwrap();
        for topology in [Topology::Star, Topology::Tree { m: 2 }] {
            let cfg = LocalSgdConfig {
                rounds: 30,
                y0: 0.5,
                topology: Some(topology),
                batch_slots: 4,
                ..Default::default()
            };
            let t = run_local_sgd(&ds, Some(CodecSpec::Lq { q: 64 }), &cfg);
            let lt = t.loss.last().unwrap();
            assert!(
                lt < &(lb * 5.0 + 0.1),
                "{} batched {lt} vs base {lb}",
                topology.label()
            );
            assert!(t.max_bits_sent.iter().any(|&b| b > 0));
        }
    }

    #[test]
    fn quant_error_smaller_with_finer_lattice() {
        let ds = gen_lsq(512, 8, 3);
        let cfg = LocalSgdConfig {
            rounds: 15,
            y0: 0.5,
            ..Default::default()
        };
        let coarse = run_local_sgd(&ds, Some(CodecSpec::Lq { q: 4 }), &cfg);
        let fine = run_local_sgd(&ds, Some(CodecSpec::Lq { q: 64 }), &cfg);
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert!(mean(&fine.quant_err) < mean(&coarse.quant_err));
    }
}
