//! Distributed power iteration — Experiment 8 (§9.5).
//!
//! Rows of X are partitioned across machines; each round every machine
//! computes `u_i = X_iᵀ X_i x`, the partial updates are exchanged
//! (quantized), and everyone updates `x ← Σu_i / ‖Σu_i‖`. The trace
//! records the three panels of Figs 14–16: the relevant norms, the
//! convergence measure `1 − |⟨x, v₁⟩|`, and the per-round quantization
//! error.

use super::allreduce::Aggregator;
use super::{chunk_count, chunk_slots, concat_chunk_outcomes, BatchYDriver};
use crate::coordinator::{CodecSpec, RoundOutcome, Topology, YPolicy};
use crate::linalg::{coord_range, dist2, dist_inf, normalize, Matrix};
use crate::rng::{hash2, Rng};

#[derive(Clone, Debug)]
pub struct PowerConfig {
    pub n_machines: usize,
    pub iters: usize,
    pub seed: u64,
    pub y0: f64,
    pub y_policy: YPolicy,
    /// `None` (default): the historical all-to-all exchange. `Some(t)`:
    /// exchange the partial updates through a persistent
    /// [`crate::coordinator::DmeBuilder`] session over topology `t` (tree sessions pin `y` at `y0`).
    pub topology: Option<Topology>,
    /// Batched-round knob (session exchange only): ship each iteration's
    /// partial update as this many coordinate-chunk slots of one
    /// `round_batch_with_y` call — one worker crossing per iteration.
    /// 1 (default) keeps the sequential round.
    pub batch_slots: usize,
}

impl Default for PowerConfig {
    fn default() -> Self {
        PowerConfig {
            n_machines: 2,
            iters: 50,
            seed: 0,
            y0: 1.0,
            y_policy: YPolicy::FromQuantized { slack: 2.0 },
            topology: None,
            batch_slots: 1,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct PowerTrace {
    /// 1 − |⟨x, v₁⟩| per iteration (angle error to the true eigvec).
    pub angle_err: Vec<f64>,
    /// ‖u₀ − u₁‖∞ per iteration (the lattice-relevant norm).
    pub u_dist_inf: Vec<f64>,
    /// max(u₀) − min(u₀) (QSGD's measure).
    pub u_range: Vec<f64>,
    /// ‖û − u‖₂ quantization error on the summed update.
    pub quant_err: Vec<f64>,
    pub max_bits_sent: Vec<u64>,
    /// Final eigenvector estimate.
    pub x: Vec<f64>,
}

/// Run distributed power iteration; `spec = None` is the full-precision
/// baseline.
pub fn run_power_iteration(
    x_mat: &Matrix,
    v1: &[f64],
    spec: Option<CodecSpec>,
    cfg: &PowerConfig,
) -> PowerTrace {
    let d = x_mat.cols;
    let n = cfg.n_machines;
    assert_eq!(x_mat.rows % n, 0, "rows must split evenly");
    let rows_per = x_mat.rows / n;
    let blocks: Vec<Matrix> = (0..n)
        .map(|i| x_mat.row_block(i * rows_per, (i + 1) * rows_per))
        .collect();

    let mut rng = Rng::new(hash2(cfg.seed, 0x9013E));
    let mut x = normalize(&rng.gaussian_vec(d));
    assert!(
        cfg.topology.is_none() || spec.is_some(),
        "cfg.topology requires a codec (spec = None is the full-precision baseline)"
    );
    let mut sess = match (cfg.topology, spec) {
        (Some(topology), Some(s)) => Some(super::topology_session(
            n,
            d,
            topology,
            s,
            cfg.seed,
            cfg.y0,
            cfg.y_policy,
        )),
        _ => None,
    };
    let mut agg = match (&sess, spec) {
        (None, Some(s)) => Some(Aggregator::new(s, n, d, cfg.y0, cfg.y_policy, cfg.seed)),
        _ => None,
    };
    // Batched session rounds (batch_slots > 1): per-chunk y at the
    // driver — tree sessions pin y (no leader to measure it).
    let mut batch_y = match (cfg.topology, spec) {
        (Some(topology), Some(s)) if cfg.batch_slots > 1 => Some(BatchYDriver::new(
            chunk_count(d, cfg.batch_slots),
            match topology {
                Topology::Star => cfg.y_policy,
                Topology::Tree { .. } => YPolicy::Fixed,
            },
            cfg.y0,
            s,
            cfg.seed,
        )),
        _ => None,
    };
    let mut ys: Vec<f64> = Vec::new();
    let mut outcomes: Vec<RoundOutcome> = Vec::new();
    let mut trace = PowerTrace::default();

    for _ in 0..cfg.iters {
        let us: Vec<Vec<f64>> = blocks.iter().map(|b| b.gram_apply(&x)).collect();
        let true_sum = {
            let m = crate::linalg::mean_vecs(&us);
            crate::linalg::scale(&m, n as f64)
        };
        trace.u_dist_inf.push(dist_inf(&us[0], &us[1 % n]));
        trace.u_range.push(coord_range(&us[0]));

        let (applied, bits) = if let Some(s) = sess.as_mut() {
            if let Some(ydrv) = batch_y.as_mut() {
                // One batched round over the update's coordinate chunks.
                let slots = chunk_slots(&us, cfg.batch_slots);
                let first_round = s.rounds_run();
                ydrv.fill_ys(&mut ys);
                s.round_batch_into(&slots, &ys, &mut outcomes);
                ydrv.observe(&slots, first_round);
                let (est, mb) = concat_chunk_outcomes(&outcomes);
                (crate::linalg::scale(&est, n as f64), mb)
            } else {
                let out = s.round(&us);
                let mb = out.max_sent_bits();
                (crate::linalg::scale(&out.estimate, n as f64), mb)
            }
        } else if let Some(a) = agg.as_mut() {
            let rep = a.step(&us);
            let mb = rep.bits_sent.iter().copied().max().unwrap_or(0);
            (crate::linalg::scale(&rep.estimate, n as f64), mb)
        } else {
            (true_sum.clone(), 0)
        };
        trace.quant_err.push(dist2(&applied, &true_sum));
        trace.max_bits_sent.push(bits);

        x = normalize(&applied);
        let cos = crate::linalg::dot(&x, v1).abs();
        trace.angle_err.push(1.0 - cos);
    }
    trace.x = x;
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_power_matrix;

    #[test]
    fn exact_power_iteration_converges() {
        let (m, v1) = gen_power_matrix(1024, 32, &[10.0, 8.0, 1.0], false, 1);
        let cfg = PowerConfig {
            iters: 100,
            ..Default::default()
        };
        let t = run_power_iteration(&m, &v1, None, &cfg);
        // Finite-sample covariance: the empirical top eigenvector differs
        // from the population one by O(√(d/S)/gap), so allow that floor.
        assert!(
            t.angle_err.last().unwrap() < &5e-3,
            "angle {:?}",
            t.angle_err.last()
        );
    }

    #[test]
    fn lq_power_iteration_close_to_exact() {
        let (m, v1) = gen_power_matrix(1024, 32, &[10.0, 8.0, 1.0], false, 2);
        let cfg = PowerConfig {
            iters: 60,
            y0: 50.0,
            ..Default::default()
        };
        let t = run_power_iteration(&m, &v1, Some(CodecSpec::Lq { q: 64 }), &cfg);
        assert!(
            t.angle_err.last().unwrap() < &0.05,
            "angle {:?}",
            t.angle_err.last()
        );
    }

    #[test]
    fn u_distance_much_smaller_than_range() {
        // §9.5's norm observation on balanced shards.
        let (m, v1) = gen_power_matrix(2048, 64, &[10.0, 8.0, 1.0], true, 3);
        let cfg = PowerConfig {
            iters: 20,
            ..Default::default()
        };
        let t = run_power_iteration(&m, &v1, None, &cfg);
        let md = t.u_dist_inf.iter().sum::<f64>() / 20.0;
        let mr = t.u_range.iter().sum::<f64>() / 20.0;
        assert!(md < mr, "dist {md} range {mr}");
    }

    #[test]
    fn star_topology_session_converges() {
        let (m, v1) = gen_power_matrix(1024, 32, &[10.0, 8.0, 1.0], false, 5);
        let cfg = PowerConfig {
            n_machines: 4,
            iters: 60,
            y0: 50.0,
            topology: Some(Topology::Star),
            ..Default::default()
        };
        let t = run_power_iteration(&m, &v1, Some(CodecSpec::Lq { q: 64 }), &cfg);
        assert!(
            t.angle_err.last().unwrap() < &0.1,
            "angle {:?}",
            t.angle_err.last()
        );
        assert!(t.max_bits_sent.iter().all(|&b| b > 0));
    }

    #[test]
    fn batched_star_session_converges() {
        let (m, v1) = gen_power_matrix(1024, 32, &[10.0, 8.0, 1.0], false, 5);
        let cfg = PowerConfig {
            n_machines: 4,
            iters: 60,
            y0: 50.0,
            topology: Some(Topology::Star),
            batch_slots: 8,
            ..Default::default()
        };
        let t = run_power_iteration(&m, &v1, Some(CodecSpec::Lq { q: 64 }), &cfg);
        assert!(
            t.angle_err.last().unwrap() < &0.1,
            "batched angle {:?}",
            t.angle_err.last()
        );
        assert!(t.max_bits_sent.iter().all(|&b| b > 0));
    }

    #[test]
    fn eight_workers_supported() {
        let (m, v1) = gen_power_matrix(1024, 16, &[5.0, 4.0], false, 4);
        let cfg = PowerConfig {
            n_machines: 8,
            iters: 40,
            y0: 20.0,
            ..Default::default()
        };
        let t = run_power_iteration(&m, &v1, Some(CodecSpec::Lq { q: 64 }), &cfg);
        assert!(t.angle_err.last().unwrap() < &0.1);
    }
}
