//! Pure-Rust MLP + distributed training with per-layer gradient
//! compression — the Experiment 7 analogue (see DESIGN.md §2 for the
//! ResNet→MLP substitution rationale).
//!
//! Architecture: one tanh hidden layer + softmax cross-entropy (the same
//! shape as the `mlp_grad_*` AOT artifact, so the Rust and JAX paths are
//! cross-checkable). Compression is applied *per layer* exactly as the
//! paper does for ResNet20/CIFAR-100 ("quantization is applied at the
//! level of each layer").
//!
//! Aggregation (§Perf): the four per-layer gradients ship as **batch
//! slots** of one persistent [`crate::coordinator::DmeSession`] —
//! `round_batch_with_y` exchanges all layers in a single worker crossing
//! per step, with per-layer `y` bounds maintained driver-side
//! (`super::BatchYDriver`, slack 3.0, the §9.2 zero-communication
//! rule). Stateful codecs (EF-SignSGD, PowerSGD, Top-K) need one error
//! memory *per layer per machine*, which a single session cannot hold,
//! so they keep the historical per-layer all-to-all [`Aggregator`]s.

use super::allreduce::Aggregator;
use super::BatchYDriver;
use crate::coordinator::{CodecSpec, DmeBuilder, RoundOutcome, YPolicy};
use crate::data::Classification;
use crate::rng::{hash2, Rng};

/// A two-layer MLP with parameters stored flat per layer.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub features: usize,
    pub hidden: usize,
    pub classes: usize,
    pub w1: Vec<f64>, // features × hidden
    pub b1: Vec<f64>, // hidden
    pub w2: Vec<f64>, // hidden × classes
    pub b2: Vec<f64>, // classes
}

/// Per-layer gradients in the same layout.
#[derive(Clone, Debug)]
pub struct MlpGrads {
    pub w1: Vec<f64>,
    pub b1: Vec<f64>,
    pub w2: Vec<f64>,
    pub b2: Vec<f64>,
}

impl Mlp {
    pub fn new(features: usize, hidden: usize, classes: usize, rng: &mut Rng) -> Self {
        let xavier1 = (2.0 / (features + hidden) as f64).sqrt();
        let xavier2 = (2.0 / (hidden + classes) as f64).sqrt();
        Mlp {
            features,
            hidden,
            classes,
            w1: (0..features * hidden)
                .map(|_| rng.next_gaussian() * xavier1)
                .collect(),
            b1: vec![0.0; hidden],
            w2: (0..hidden * classes)
                .map(|_| rng.next_gaussian() * xavier2)
                .collect(),
            b2: vec![0.0; classes],
        }
    }

    /// Forward pass for one sample: returns (hidden activations, logits).
    fn forward(&self, x: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let mut h = self.b1.clone();
        for (i, xi) in x.iter().enumerate() {
            if *xi == 0.0 {
                continue;
            }
            let row = &self.w1[i * self.hidden..(i + 1) * self.hidden];
            for (hj, wij) in h.iter_mut().zip(row) {
                *hj += xi * wij;
            }
        }
        for v in h.iter_mut() {
            *v = v.tanh();
        }
        let mut logits = self.b2.clone();
        for (j, hj) in h.iter().enumerate() {
            let row = &self.w2[j * self.classes..(j + 1) * self.classes];
            for (lk, wjk) in logits.iter_mut().zip(row) {
                *lk += hj * wjk;
            }
        }
        (h, logits)
    }

    fn softmax(logits: &[f64]) -> Vec<f64> {
        let m = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = logits.iter().map(|l| (l - m).exp()).collect();
        let z: f64 = exps.iter().sum();
        exps.iter().map(|e| e / z).collect()
    }

    /// Mean CE loss and gradients over the given sample indices.
    pub fn loss_and_grads(&self, data: &Classification, idx: &[usize]) -> (f64, MlpGrads) {
        let mut g = MlpGrads {
            w1: vec![0.0; self.w1.len()],
            b1: vec![0.0; self.b1.len()],
            w2: vec![0.0; self.w2.len()],
            b2: vec![0.0; self.b2.len()],
        };
        let mut loss = 0.0;
        let inv = 1.0 / idx.len().max(1) as f64;
        for &i in idx {
            let x = data.x.row(i);
            let label = data.labels[i];
            let (h, logits) = self.forward(x);
            let p = Self::softmax(&logits);
            loss -= (p[label].max(1e-300)).ln();
            // dL/dlogits = p − onehot
            let mut dl = p;
            dl[label] -= 1.0;
            // layer 2
            for (j, hj) in h.iter().enumerate() {
                let row = &mut g.w2[j * self.classes..(j + 1) * self.classes];
                for (gk, dk) in row.iter_mut().zip(&dl) {
                    *gk += hj * dk * inv;
                }
            }
            for (gb, dk) in g.b2.iter_mut().zip(&dl) {
                *gb += dk * inv;
            }
            // backprop into hidden
            let mut dh = vec![0.0; self.hidden];
            for (j, dhj) in dh.iter_mut().enumerate() {
                let row = &self.w2[j * self.classes..(j + 1) * self.classes];
                *dhj = crate::linalg::dot(row, &dl) * (1.0 - h[j] * h[j]);
            }
            // layer 1
            for (i_f, xi) in x.iter().enumerate() {
                if *xi == 0.0 {
                    continue;
                }
                let row = &mut g.w1[i_f * self.hidden..(i_f + 1) * self.hidden];
                for (gj, dhj) in row.iter_mut().zip(&dh) {
                    *gj += xi * dhj * inv;
                }
            }
            for (gb, dhj) in g.b1.iter_mut().zip(&dh) {
                *gb += dhj * inv;
            }
        }
        (loss * inv, g)
    }

    pub fn apply(&mut self, g: &MlpGrads, lr: f64) {
        crate::linalg::axpy(&mut self.w1, -lr, &g.w1);
        crate::linalg::axpy(&mut self.b1, -lr, &g.b1);
        crate::linalg::axpy(&mut self.w2, -lr, &g.w2);
        crate::linalg::axpy(&mut self.b2, -lr, &g.b2);
    }

    /// Classification accuracy over sample indices.
    pub fn accuracy(&self, data: &Classification, idx: &[usize]) -> f64 {
        let mut correct = 0;
        for &i in idx {
            let (_, logits) = self.forward(data.x.row(i));
            let pred = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == data.labels[i] {
                correct += 1;
            }
        }
        correct as f64 / idx.len().max(1) as f64
    }
}

#[derive(Clone, Debug)]
pub struct MlpTrainConfig {
    pub n_machines: usize,
    pub hidden: usize,
    pub lr: f64,
    pub epochs: usize,
    pub batch_per_machine: usize,
    pub seed: u64,
    pub y0: f64,
}

impl Default for MlpTrainConfig {
    fn default() -> Self {
        MlpTrainConfig {
            n_machines: 4,
            hidden: 64,
            lr: 0.5,
            epochs: 20,
            batch_per_machine: 64,
            seed: 0,
            y0: 0.5,
        }
    }
}

#[derive(Clone, Debug)]
pub struct MlpTrainReport {
    pub train_acc: f64,
    pub val_acc: f64,
    pub train_loss: Vec<f64>,
    /// Sessions (stateless codecs): steps × layers whose round lost the
    /// agreement invariant. Aggregators (stateful codecs): total decode
    /// mismatches observed. Both mirror the paper's ~3% Exp-7 rate.
    pub decode_mismatches: usize,
}

/// Distributed training with per-layer compression; `spec = None` is the
/// uncompressed baseline row of Figures 12–13. Stateless codecs ride a
/// batched session (all four layer slots in one worker crossing per
/// step); stateful codecs keep per-layer aggregators (see module docs).
pub fn train_distributed(
    train: &Classification,
    val: &Classification,
    spec: Option<CodecSpec>,
    cfg: &MlpTrainConfig,
) -> MlpTrainReport {
    let mut rng = Rng::new(hash2(cfg.seed, 0x311D));
    let mut model = Mlp::new(train.x.cols, cfg.hidden, train.classes, &mut rng);
    let n = cfg.n_machines;
    let layer_dims = [
        model.w1.len(),
        model.b1.len(),
        model.w2.len(),
        model.b2.len(),
    ];
    // Batched-session path for stateless codecs: one session whose
    // nominal dimension is the widest layer; each step ships the four
    // layer gradients as variable-width batch slots.
    let session_spec = spec.filter(|s| !s.is_stateful());
    let mut sess = session_spec.map(|s| {
        DmeBuilder::new(n, *layer_dims.iter().max().expect("four layers"))
            .codec(s)
            .seed(cfg.seed)
            .build()
    });
    let mut ydrv = session_spec.map(|s| {
        BatchYDriver::new(
            layer_dims.len(),
            YPolicy::FromQuantized { slack: 3.0 },
            cfg.y0,
            s,
            cfg.seed,
        )
    });
    let mut ys: Vec<f64> = Vec::new();
    let mut outcomes: Vec<RoundOutcome> = Vec::new();
    // Legacy per-layer aggregators for stateful codecs (per-layer error
    // memory).
    let mut aggs: Vec<Option<Aggregator>> = layer_dims
        .iter()
        .map(|&d| {
            spec.filter(|s| s.is_stateful()).map(|s| {
                Aggregator::new(
                    s,
                    n,
                    d,
                    cfg.y0,
                    YPolicy::FromQuantized { slack: 3.0 },
                    cfg.seed,
                )
            })
        })
        .collect();

    let n_train = train.x.rows;
    let steps_per_epoch = (n_train / (n * cfg.batch_per_machine)).max(1);
    let mut train_loss = Vec::new();
    let mut mismatches = 0;

    for _epoch in 0..cfg.epochs {
        let mut epoch_loss = 0.0;
        for _step in 0..steps_per_epoch {
            // Each machine samples its own batch.
            let grads: Vec<(f64, MlpGrads)> = (0..n)
                .map(|_| {
                    let idx: Vec<usize> = (0..cfg.batch_per_machine)
                        .map(|_| rng.next_below(n_train as u64) as usize)
                        .collect();
                    model.loss_and_grads(train, &idx)
                })
                .collect();
            epoch_loss += grads.iter().map(|(l, _)| l).sum::<f64>() / n as f64;

            // Aggregate layer by layer.
            let layers: [fn(&MlpGrads) -> &Vec<f64>; 4] = [
                |g| &g.w1,
                |g| &g.b1,
                |g| &g.w2,
                |g| &g.b2,
            ];
            let mut agg_out: Vec<Vec<f64>> = Vec::with_capacity(4);
            if let Some(sess) = sess.as_mut() {
                // One batched round: layer li is slot li, per-layer y
                // bounds from the driver-side estimators.
                let slots: Vec<Vec<Vec<f64>>> = layers
                    .iter()
                    .map(|get| grads.iter().map(|(_, g)| get(g).clone()).collect())
                    .collect();
                let ydrv = ydrv.as_mut().expect("session path has a y driver");
                let first_round = sess.rounds_run();
                ydrv.fill_ys(&mut ys);
                sess.round_batch_into(&slots, &ys, &mut outcomes);
                ydrv.observe(&slots, first_round);
                for o in &outcomes {
                    if !o.agreement {
                        mismatches += 1;
                    }
                }
                agg_out.extend(outcomes.iter().map(|o| o.estimate.clone()));
            } else {
                for (li, get) in layers.iter().enumerate() {
                    let vecs: Vec<Vec<f64>> = grads.iter().map(|(_, g)| get(g).clone()).collect();
                    match aggs[li].as_mut() {
                        None => agg_out.push(crate::linalg::mean_vecs(&vecs)),
                        Some(a) => {
                            let rep = a.step(&vecs);
                            mismatches += rep.decode_mismatches;
                            agg_out.push(rep.estimate);
                        }
                    }
                }
            }
            let g = MlpGrads {
                w1: agg_out[0].clone(),
                b1: agg_out[1].clone(),
                w2: agg_out[2].clone(),
                b2: agg_out[3].clone(),
            };
            model.apply(&g, cfg.lr);
        }
        train_loss.push(epoch_loss / steps_per_epoch as f64);
    }

    let train_idx: Vec<usize> = (0..n_train).collect();
    let val_idx: Vec<usize> = (0..val.x.rows).collect();
    MlpTrainReport {
        train_acc: model.accuracy(train, &train_idx),
        val_acc: model.accuracy(val, &val_idx),
        train_loss,
        decode_mismatches: mismatches,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_classification;

    #[test]
    fn gradients_match_finite_differences() {
        let data = gen_classification(16, 5, 3, 0.3, 1);
        let mut rng = Rng::new(2);
        let model = Mlp::new(5, 4, 3, &mut rng);
        let idx: Vec<usize> = (0..16).collect();
        let (_, g) = model.loss_and_grads(&data, &idx);
        let eps = 1e-6;
        // Check a few W1 and W2 entries.
        for (which, k) in [(0usize, 3usize), (0, 7), (1, 2), (1, 5)] {
            let mut mp = model.clone();
            let mut mm = model.clone();
            let (gref, param_p, param_m): (f64, &mut Vec<f64>, &mut Vec<f64>) = match which {
                0 => (g.w1[k], &mut mp.w1, &mut mm.w1),
                _ => (g.w2[k], &mut mp.w2, &mut mm.w2),
            };
            param_p[k] += eps;
            param_m[k] -= eps;
            let (lp, _) = mp.loss_and_grads(&data, &idx);
            let (lm, _) = mm.loss_and_grads(&data, &idx);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - gref).abs() < 1e-5,
                "layer {which} idx {k}: fd {fd} vs {gref}"
            );
        }
    }

    #[test]
    fn uncompressed_training_learns() {
        let (train, val) = gen_classification(1000, 8, 4, 0.35, 3).split(800);
        let cfg = MlpTrainConfig {
            epochs: 15,
            ..Default::default()
        };
        let rep = train_distributed(&train, &val, None, &cfg);
        assert!(rep.val_acc > 0.9, "val acc {}", rep.val_acc);
        assert!(rep.train_loss.first().unwrap() > rep.train_loss.last().unwrap());
    }

    #[test]
    fn stateful_codec_keeps_per_layer_aggregators() {
        // EF-SignSGD cannot ride the batched session (per-layer error
        // memory); the legacy per-layer aggregator path must still train.
        let (train, val) = gen_classification(400, 6, 3, 0.3, 9).split(320);
        let cfg = MlpTrainConfig {
            epochs: 3,
            ..Default::default()
        };
        let rep = train_distributed(&train, &val, Some(CodecSpec::EfSign), &cfg);
        assert!(rep.val_acc.is_finite());
        assert!(!rep.train_loss.is_empty());
    }

    #[test]
    fn lq_compressed_training_close_to_baseline() {
        let (train, val) = gen_classification(1000, 8, 4, 0.35, 5).split(800);
        let cfg = MlpTrainConfig {
            epochs: 15,
            ..Default::default()
        };
        let base = train_distributed(&train, &val, None, &cfg);
        let lq = train_distributed(&train, &val, Some(CodecSpec::Lq { q: 16 }), &cfg);
        assert!(
            lq.val_acc > base.val_acc - 0.1,
            "LQ {} vs base {}",
            lq.val_acc,
            base.val_acc
        );
    }
}
