//! Optimization drivers: the applications of Section 9.
//!
//! * [`allreduce`] — quantized gradient exchange (the all-to-all pattern
//!   of Experiments 2–4 and the building block for the others).
//! * [`dist_gd`] — distributed (stochastic) gradient descent on
//!   regression workloads (Experiments 1–5).
//! * [`local_sgd`] — Local SGD with compressed model deltas (Experiment 6).
//! * [`mlp`] — pure-Rust MLP + distributed training with per-layer
//!   gradient compression (Experiment 7 analogue).
//! * [`power_iteration`] — distributed power iteration (Experiment 8).

pub mod allreduce;
pub mod dist_gd;
pub mod local_sgd;
pub mod mlp;
pub mod power_iteration;

use crate::coordinator::{CodecSpec, DmeBuilder, DmeSession, Topology, YPolicy};

/// The persistent aggregation session the optimizer drivers share when
/// configured with an explicit topology: star keeps the caller's `y`
/// policy; tree pins `y` at `y0` (it has no leader to measure it — see
/// [`DmeBuilder::y_policy`]).
pub(crate) fn topology_session(
    n: usize,
    d: usize,
    topology: Topology,
    spec: CodecSpec,
    seed: u64,
    y0: f64,
    y_policy: YPolicy,
) -> DmeSession {
    let policy = match topology {
        Topology::Star => y_policy,
        Topology::Tree { .. } => YPolicy::Fixed,
    };
    DmeBuilder::new(n, d)
        .topology(topology)
        .codec(spec)
        .seed(seed)
        .y0(y0)
        .y_policy(policy)
        .build()
}

pub use allreduce::{Aggregator, StepReport};
pub use dist_gd::{run_distributed_gd, GdConfig, GdTrace};
pub use local_sgd::{run_local_sgd, LocalSgdConfig, LocalSgdTrace};
pub use mlp::{Mlp, MlpTrainConfig, MlpTrainReport};
pub use power_iteration::{run_power_iteration, PowerConfig, PowerTrace};
