//! Optimization drivers: the applications of Section 9.
//!
//! * [`allreduce`] — quantized gradient exchange (the all-to-all pattern
//!   of Experiments 2–4 and the building block for the others).
//! * [`dist_gd`] — distributed (stochastic) gradient descent on
//!   regression workloads (Experiments 1–5).
//! * [`local_sgd`] — Local SGD with compressed model deltas (Experiment 6).
//! * [`mlp`] — pure-Rust MLP + distributed training with per-layer
//!   gradient compression (Experiment 7 analogue).
//! * [`power_iteration`] — distributed power iteration (Experiment 8).

pub mod allreduce;
pub mod dist_gd;
pub mod local_sgd;
pub mod mlp;
pub mod power_iteration;

use crate::coordinator::{
    CodecSpec, DmeBuilder, DmeSession, RoundOutcome, Topology, YEstimator, YPolicy,
};
use crate::quant::hadamard::Rotation;
use crate::rng::{hash2, Rng};

/// The persistent aggregation session the optimizer drivers share when
/// configured with an explicit topology: star keeps the caller's `y`
/// policy; tree pins `y` at `y0` (it has no leader to measure it — see
/// [`DmeBuilder::y_policy`]).
pub(crate) fn topology_session(
    n: usize,
    d: usize,
    topology: Topology,
    spec: CodecSpec,
    seed: u64,
    y0: f64,
    y_policy: YPolicy,
) -> DmeSession {
    let policy = match topology {
        Topology::Star => y_policy,
        Topology::Tree { .. } => YPolicy::Fixed,
    };
    DmeBuilder::new(n, d)
        .topology(topology)
        .codec(spec)
        .seed(seed)
        .y0(y0)
        .y_policy(policy)
        .build()
}

/// Effective slot count a `batch_slots` knob yields at dimension `d`
/// (chunks are `⌈d / B⌉` coordinates, so very large knobs degrade to one
/// coordinate per slot).
pub(crate) fn chunk_count(d: usize, batch_slots: usize) -> usize {
    let b = batch_slots.clamp(1, d.max(1));
    let chunk = d.div_ceil(b).max(1);
    d.div_ceil(chunk).max(1)
}

/// Split one round's per-machine vectors into `batch_slots` contiguous
/// coordinate chunks, slot-major — the optimizer drivers' `batch` knob:
/// the chunks ride [`DmeSession::round_batch_with_y`] as independent
/// slots, so the whole d-dimensional exchange costs one worker crossing
/// however many chunks it is cut into. Chunking is aggregation-exact
/// (the concatenated slot means equal the full-vector mean estimate in
/// distribution; each chunk's ℓ∞ spread is ≤ the full vector's, so a
/// full-vector `y` stays decode-safe for every chunk).
pub(crate) fn chunk_slots(vectors: &[Vec<f64>], batch_slots: usize) -> Vec<Vec<Vec<f64>>> {
    let d = vectors[0].len();
    let chunk = d.div_ceil(chunk_count(d, batch_slots)).max(1);
    (0..d)
        .step_by(chunk)
        .map(|lo| {
            let hi = (lo + chunk).min(d);
            vectors.iter().map(|v| v[lo..hi].to_vec()).collect()
        })
        .collect()
}

/// Stitch a chunked batch's outcomes back together: the concatenated
/// estimate plus the max-over-machines total sent bits (each machine's
/// round cost is the sum of its per-slot costs).
pub(crate) fn concat_chunk_outcomes(outs: &[RoundOutcome]) -> (Vec<f64>, u64) {
    let mut est = Vec::new();
    let n = outs.first().map_or(0, |o| o.round_traffic.len());
    let mut sent = vec![0u64; n];
    for o in outs {
        est.extend_from_slice(&o.estimate);
        for (s, t) in sent.iter_mut().zip(&o.round_traffic) {
            *s += t.sent_bits;
        }
    }
    (est, sent.into_iter().max().unwrap_or(0))
}

/// Max pairwise ℓ∞ spread of one slot's raw inputs, measured in the
/// space the codec's `y` lives in — rotated for RLQSGD (mirroring the
/// rotated-space tracking in [`allreduce::Aggregator`]), plain ℓ∞
/// otherwise. `round` selects RLQ's per-round rotation.
pub(crate) fn slot_spread(spec: CodecSpec, vectors: &[Vec<f64>], seed: u64, round: u64) -> f64 {
    match spec {
        CodecSpec::Rlq { .. } => {
            let rot = Rotation::new(vectors[0].len(), &mut Rng::new(hash2(seed, round)));
            let rotated: Vec<Vec<f64>> = vectors.iter().map(|v| rot.forward(v)).collect();
            YEstimator::max_pairwise_inf(&rotated)
        }
        _ => YEstimator::max_pairwise_inf(vectors),
    }
}

/// Driver-side per-slot `y` maintenance for batched session rounds: one
/// [`YEstimator`] per slot, fed the raw-input spread the driver measures
/// itself (these in-process drivers own every machine's vector) — the
/// zero-communication rule of §9.2, applied before quantization. The
/// batch plane amortizes the leader's between-round measurement away
/// (see [`DmeSession::round_batch`]), so the estimator state lives here
/// and the bounds travel as the `ys` argument of
/// [`DmeSession::round_batch_with_y`].
pub(crate) struct BatchYDriver {
    spec: CodecSpec,
    seed: u64,
    ests: Vec<YEstimator>,
}

impl BatchYDriver {
    pub(crate) fn new(slots: usize, policy: YPolicy, y0: f64, spec: CodecSpec, seed: u64) -> Self {
        BatchYDriver {
            spec,
            seed,
            ests: (0..slots).map(|_| YEstimator::new(policy, y0)).collect(),
        }
    }

    /// Current per-slot bounds, into a recycled buffer.
    pub(crate) fn fill_ys(&self, ys: &mut Vec<f64>) {
        ys.clear();
        ys.extend(self.ests.iter().map(|e| e.y));
    }

    /// Feed one batch's raw slot inputs to the per-slot estimators
    /// (`first_round` anchors RLQ's per-round rotation; measurement only
    /// happens on rounds the policy asks for, per `needs_spread`).
    pub(crate) fn observe(&mut self, slots: &[Vec<Vec<f64>>], first_round: u64) {
        let (spec, seed) = (self.spec, self.seed);
        for (b, (est, slot)) in self.ests.iter_mut().zip(slots).enumerate() {
            let spread = est
                .needs_spread()
                .then(|| slot_spread(spec, slot, seed, first_round + b as u64));
            est.update_spread(spread, slot.len());
        }
    }
}

pub use allreduce::{Aggregator, StepReport};
pub use dist_gd::{run_distributed_gd, GdConfig, GdTrace};
pub use local_sgd::{run_local_sgd, LocalSgdConfig, LocalSgdTrace};
pub use mlp::{Mlp, MlpTrainConfig, MlpTrainReport};
pub use power_iteration::{run_power_iteration, PowerConfig, PowerTrace};
