//! Distributed (stochastic) gradient descent with quantized gradient
//! exchange — the workhorse of Experiments 1, 2, 3 and 5.
//!
//! Each iteration: the dataset rows are randomly re-partitioned across
//! the `n` machines (exactly the paper's §9.2 protocol), every machine
//! computes its batch gradient, the gradients are aggregated with the
//! configured method, and the common estimate is applied. The trace
//! records everything the paper's figures plot: the four §9.2-Exp-1
//! norms, per-iteration output variance vs the true full gradient, loss,
//! and exact bits.

use super::allreduce::Aggregator;
use super::{chunk_count, chunk_slots, concat_chunk_outcomes, BatchYDriver};
use crate::coordinator::{CodecSpec, DmeBuilder, RoundOutcome, YPolicy};
use crate::data::Regression;
use crate::linalg::{coord_range, dist2, dist_inf, norm2};
use crate::rng::{hash2, Rng};

/// How gradients are combined each iteration.
#[derive(Clone, Debug)]
pub enum GdAggregation {
    /// Naive full-precision averaging (the paper's baseline).
    Exact,
    /// All-to-all quantized exchange (Exp 2/3 protocol; n = 2 there).
    AllToAll(CodecSpec),
    /// Star topology through a random leader (Algorithm 3; Exp 5).
    Star(CodecSpec),
}

#[derive(Clone, Debug)]
pub struct GdConfig {
    pub n_machines: usize,
    pub lr: f64,
    pub iters: usize,
    pub seed: u64,
    /// Initial y (ℓ∞ bound; rotated-space for RLQ).
    pub y0: f64,
    pub y_policy: YPolicy,
    /// Initial weights (defaults to zeros).
    pub w0: Option<Vec<f64>>,
    /// Batched-round knob (star aggregation only): cut each iteration's
    /// gradient into this many coordinate chunks and ship them as slots
    /// of one `round_batch_with_y` call — one worker crossing per
    /// iteration however many chunks. 1 (default) keeps the historical
    /// sequential round; > 1 maintains `y` per chunk at the driver
    /// (`BatchYDriver`, raw-gradient spread, the policy's slack).
    pub batch_slots: usize,
}

impl Default for GdConfig {
    fn default() -> Self {
        GdConfig {
            n_machines: 2,
            lr: 0.8,
            iters: 100,
            seed: 0,
            y0: 1.0,
            y_policy: YPolicy::FromQuantized { slack: 1.5 },
            w0: None,
            batch_slots: 1,
        }
    }
}

/// Per-iteration measurements (one entry per iteration).
#[derive(Clone, Debug, Default)]
pub struct GdTrace {
    pub loss: Vec<f64>,
    /// ‖EST − ∇_full‖² — the output variance proxy the paper plots.
    pub output_err2: Vec<f64>,
    /// ‖g₀ − g₁‖₂ (batch gradient distance, Exp 1).
    pub grad_dist_2: Vec<f64>,
    /// ‖g₀ − g₁‖∞.
    pub grad_dist_inf: Vec<f64>,
    /// ‖g₀‖₂ (batch gradient norm).
    pub grad_norm_2: Vec<f64>,
    /// max(g₀) − min(g₀) (QSGD-Linf's measure).
    pub grad_range: Vec<f64>,
    /// Max bits sent by any machine this iteration.
    pub max_bits_sent: Vec<u64>,
    /// y in effect each iteration (lattice methods).
    pub y_used: Vec<f64>,
    /// Total decode mismatches observed.
    pub decode_mismatches: usize,
    /// Final weights.
    pub w: Vec<f64>,
}

/// Run distributed GD on a regression problem.
pub fn run_distributed_gd(ds: &Regression, agg: &GdAggregation, cfg: &GdConfig) -> GdTrace {
    let d = ds.dim();
    let n = cfg.n_machines;
    let mut w = cfg.w0.clone().unwrap_or_else(|| vec![0.0; d]);
    let mut part_rng = Rng::new(hash2(cfg.seed, 0xDA7A));
    let mut trace = GdTrace::default();

    // Aggregator state for the AllToAll path.
    let mut aggregator = match agg {
        GdAggregation::AllToAll(spec) => Some(Aggregator::new(
            *spec,
            n,
            d,
            cfg.y0,
            cfg.y_policy,
            cfg.seed,
        )),
        _ => None,
    };
    // Persistent cluster for the Star path (Exp 5 style): the session
    // owns the y estimator and keeps the machine threads alive across
    // iterations — bit-identical to the historical one-shot-per-iteration
    // protocol, minus the per-round thread spawns. With diagnostics off
    // the leader aggregates by streaming fold (decode_accumulate_into),
    // so its memory stays O(d) however many machines feed it; y-policy
    // measurement rounds ship one spread scalar back, not n vectors.
    let mut star_sess = match agg {
        GdAggregation::Star(spec) => Some(
            DmeBuilder::new(n, d)
                .codec(*spec)
                .seed(cfg.seed)
                .y0(cfg.y0)
                .y_policy(if cfg.batch_slots > 1 {
                    // Batched rounds carry explicit per-slot bounds; the
                    // session's own estimator stays out of the loop.
                    YPolicy::Fixed
                } else {
                    cfg.y_policy
                })
                .build(),
        ),
        _ => None,
    };
    // Batched star path (batch_slots > 1): per-chunk y maintained at the
    // driver, outcomes and bounds recycled across iterations.
    let mut star_y = match agg {
        GdAggregation::Star(spec) if cfg.batch_slots > 1 => Some(BatchYDriver::new(
            chunk_count(d, cfg.batch_slots),
            cfg.y_policy,
            cfg.y0,
            *spec,
            cfg.seed,
        )),
        _ => None,
    };
    let mut ys: Vec<f64> = Vec::new();
    let mut outcomes: Vec<RoundOutcome> = Vec::new();

    for _ in 0..cfg.iters {
        let parts = ds.partition(n, &mut part_rng);
        let grads: Vec<Vec<f64>> = parts.iter().map(|p| ds.batch_gradient(&w, p)).collect();
        let full = ds.full_gradient(&w);

        // Exp-1 norms (always recorded; cheap).
        trace.grad_dist_2.push(dist2(&grads[0], &grads[1 % n]));
        trace.grad_dist_inf.push(dist_inf(&grads[0], &grads[1 % n]));
        trace.grad_norm_2.push(norm2(&grads[0]));
        trace.grad_range.push(coord_range(&grads[0]));

        let (est, max_bits, y_used) = match agg {
            GdAggregation::Exact => (crate::linalg::mean_vecs(&grads), 0u64, 0.0),
            GdAggregation::AllToAll(_) => {
                let a = aggregator.as_mut().unwrap();
                let rep = a.step(&grads);
                trace.decode_mismatches += rep.decode_mismatches;
                let mb = rep.bits_sent.iter().copied().max().unwrap_or(0);
                (rep.estimate, mb, rep.y_used)
            }
            GdAggregation::Star(_) if cfg.batch_slots > 1 => {
                // One batched round: the gradient's coordinate chunks are
                // the slots, so the whole exchange is one worker crossing.
                let sess = star_sess.as_mut().unwrap();
                let ydrv = star_y.as_mut().unwrap();
                let slots = chunk_slots(&grads, cfg.batch_slots);
                let first_round = sess.rounds_run();
                ydrv.fill_ys(&mut ys);
                sess.round_batch_into(&slots, &ys, &mut outcomes);
                ydrv.observe(&slots, first_round);
                let (est, mb) = concat_chunk_outcomes(&outcomes);
                let y_used = ys.iter().cloned().fold(0.0f64, f64::max);
                (est, mb, y_used)
            }
            GdAggregation::Star(_) => {
                let sess = star_sess.as_mut().unwrap();
                let out = sess.round(&grads);
                // Round traffic already folds the y policy's side bits in
                // at the leader.
                let mb = out.max_sent_bits();
                (out.estimate, mb, out.y_used)
            }
        };

        trace.output_err2.push(dist2(&est, &full).powi(2));
        trace.max_bits_sent.push(max_bits);
        trace.y_used.push(y_used);

        crate::linalg::axpy(&mut w, -cfg.lr, &est);
        trace.loss.push(ds.loss(&w));
    }
    trace.w = w;
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_lsq;

    fn small_cfg(iters: usize) -> GdConfig {
        GdConfig {
            n_machines: 2,
            lr: 0.1,
            iters,
            seed: 3,
            y0: 2.0,
            y_policy: YPolicy::FromQuantized { slack: 1.5 },
            w0: None,
            batch_slots: 1,
        }
    }

    #[test]
    fn exact_gd_converges() {
        let ds = gen_lsq(512, 10, 1);
        let t = run_distributed_gd(&ds, &GdAggregation::Exact, &small_cfg(60));
        assert!(t.loss.last().unwrap() < &1e-3, "loss {:?}", t.loss.last());
        assert!(t.loss[0] > *t.loss.last().unwrap());
    }

    #[test]
    fn lq_gd_tracks_exact_closely() {
        let ds = gen_lsq(512, 10, 2);
        let exact = run_distributed_gd(&ds, &GdAggregation::Exact, &small_cfg(50));
        let lq = run_distributed_gd(
            &ds,
            &GdAggregation::AllToAll(CodecSpec::Lq { q: 16 }),
            &small_cfg(50),
        );
        let le = exact.loss.last().unwrap();
        let ll = lq.loss.last().unwrap();
        assert!(ll < &(le + 0.05), "LQ {ll} vs exact {le}");
        // Dynamic y-estimation admits occasional decode misses (the paper
        // reports ~3% in Exp 7 with no convergence impact); bound them.
        assert!(
            lq.decode_mismatches <= 5,
            "too many decode mismatches: {}",
            lq.decode_mismatches
        );
    }

    #[test]
    fn distance_norms_below_input_norms() {
        // Exp 1's claim on this workload: ‖g0−g1‖ ≪ ‖g0‖ away from w*.
        let ds = gen_lsq(2048, 20, 3);
        let t = run_distributed_gd(&ds, &GdAggregation::Exact, &small_cfg(10));
        for i in 0..10 {
            assert!(t.grad_dist_2[i] < 0.5 * t.grad_norm_2[i]);
        }
    }

    #[test]
    fn star_aggregation_converges() {
        let ds = gen_lsq(512, 8, 4);
        let mut cfg = small_cfg(40);
        cfg.n_machines = 4;
        cfg.y_policy = YPolicy::LeaderMeasured {
            slack: 3.0,
            period: 1,
        };
        let t = run_distributed_gd(
            &ds,
            &GdAggregation::Star(CodecSpec::Lq { q: 16 }),
            &cfg,
        );
        assert!(
            t.loss.last().unwrap() < &0.05,
            "star loss {:?}",
            t.loss.last()
        );
        // Star bits: leader pays O(n d log q); others O(d log q).
        assert!(t.max_bits_sent.iter().all(|&b| b > 0));
    }

    #[test]
    fn batched_star_aggregation_converges() {
        // batch_slots > 1: the gradient ships as chunk slots of one
        // batched round per iteration; convergence must match the
        // sequential star path's quality.
        let ds = gen_lsq(512, 8, 4);
        let mut cfg = small_cfg(40);
        cfg.n_machines = 4;
        cfg.batch_slots = 4;
        cfg.y_policy = YPolicy::FromQuantized { slack: 3.0 };
        let t = run_distributed_gd(&ds, &GdAggregation::Star(CodecSpec::Lq { q: 16 }), &cfg);
        assert!(
            t.loss.last().unwrap() < &0.05,
            "batched star loss {:?}",
            t.loss.last()
        );
        assert!(t.max_bits_sent.iter().all(|&b| b > 0));
        assert!(t.y_used.iter().all(|&y| y > 0.0));
    }

    #[test]
    fn variance_decreases_with_more_levels() {
        let ds = gen_lsq(1024, 16, 5);
        let err = |q: u32| {
            let t = run_distributed_gd(
                &ds,
                &GdAggregation::AllToAll(CodecSpec::Lq { q }),
                &small_cfg(20),
            );
            t.output_err2.iter().sum::<f64>() / 20.0
        };
        let e8 = err(8);
        let e64 = err(64);
        assert!(e64 < e8, "q=64 ({e64}) must beat q=8 ({e8})");
    }
}
