//! Experiment 4 (Figures 7–8): sublinear-communication variance.
//!
//! Two machines; u sends its quantized batch gradient to v at 0.5
//! bits/coordinate. Comparators:
//!
//! * sublinear LQSGD — the paper's own methodology: analytic variance
//!   `d·s²/12` with `s = 4y/(2^{b/d} − 1)` and `y` re-measured every 5
//!   iterations (`y = 1.6·‖g₀−g₁‖∞`, shipped as one 64-bit float);
//! * vQSGD cross-polytope with repetition — *measured* variance at the
//!   matching bit budget.
//!
//! Expected shape: sublinear LQ is competitive, winning only at large
//! S relative to d (Fig 8), with visible steps from the periodic y.

use super::{mean_trace, render_series, ExpOpts, Series};
use crate::coordinator::CodecSpec;
use crate::data::gen_lsq;
use crate::linalg::{dist2, dist_inf};
use crate::quant::sublinear::SublinearModel;
use crate::rng::{hash2, Rng};

fn one_run(samples: usize, d: usize, iters: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let ds = gen_lsq(samples, d, seed * 10);
    let bits_per_coord = 0.5;
    let budget = (bits_per_coord * d as f64) as u64;
    let reps = crate::quant::baselines::VqsgdCrossPolytope::reps_for_bits(d, budget + 128);
    let mut w = vec![0.0; d];
    let mut rng = Rng::new(hash2(seed, 0xE4));
    let mut y = 0.0f64;
    let mut lq_var = Vec::with_capacity(iters);
    let mut vq_var = Vec::with_capacity(iters);
    let model = |y: f64| SublinearModel { d, y };
    for it in 0..iters {
        let parts = ds.partition(2, &mut rng);
        let g0 = ds.batch_gradient(&w, &parts[0]);
        let g1 = ds.batch_gradient(&w, &parts[1]);
        // Periodic y update (every 5 iterations, as in the paper).
        if it % 5 == 0 || y == 0.0 {
            y = 1.6 * dist_inf(&g0, &g1).max(1e-12);
        }
        // Analytic sublinear-LQ variance at this y.
        lq_var.push(model(y).variance_for_bits(bits_per_coord));
        // Measured vQSGD variance (E[‖ẑ − g0‖²] over quantizer draws).
        let mut codec = CodecSpec::Vqsgd { reps }.build(d, y, seed, it as u64);
        let trials = 24;
        let mut acc = 0.0;
        for t in 0..trials {
            let mut qrng = Rng::new(hash2(seed * 7919 + it as u64, t));
            let msg = codec.encode(&g0, &mut qrng);
            let z = codec.decode(&msg, &g1);
            acc += dist2(&z, &g0).powi(2);
        }
        vq_var.push(acc / trials as f64);
        // Advance w with the exact mean gradient (the paper measures the
        // quantizers along the uncompressed trajectory here). A small lr
        // keeps gradients macroscopic over the window — the noise-free
        // lsq instance otherwise converges exactly and both variances
        // collapse to numerical dust.
        let est = crate::linalg::mean_vecs(&[g0, g1]);
        crate::linalg::axpy(&mut w, -0.05, &est);
    }
    (lq_var, vq_var)
}

pub fn run(opts: &ExpOpts) -> String {
    let mut out = String::from("# E4 — sublinear quantization variance at 0.5 bits/coord (Figs 7-8)\n\n");
    let mut ratios = Vec::new();
    for (fig, samples, d) in [
        ("Fig 7 (fewer samples)", 8192usize, 128usize),
        ("Fig 8 (more samples)", 32768, 256),
    ] {
        let s = opts.samples(samples);
        let iters = opts.iters(40);
        let mut lq = Vec::new();
        let mut vq = Vec::new();
        for seed in 0..opts.seeds as u64 {
            let (a, b) = one_run(s, d, iters, seed);
            lq.push(a);
            vq.push(b);
        }
        let series = vec![
            Series {
                label: "sublinear-LQ".into(),
                values: mean_trace(&lq),
            },
            Series {
                label: "vQSGD-cp".into(),
                values: mean_trace(&vq),
            },
        ];
        out += &render_series(
            &format!("{fig}: S={s}, d={d}, 0.5 bits/coord, mean of {} seeds", opts.seeds),
            "iter",
            &series,
            12,
        );
        // Geometric-mean ratio across the trajectory (robust to the
        // orders-of-magnitude decay along the descent).
        let ratio = series[0]
            .values
            .iter()
            .zip(&series[1].values)
            .map(|(a, b)| (a.max(1e-300) / b.max(1e-300)).ln())
            .sum::<f64>()
            / series[0].values.len() as f64;
        let ratio = ratio.exp();
        ratios.push(ratio);
        out += &format!(
            "shape check: geomean(sublinear-LQ / vQSGD) = {ratio:.3}\n\n"
        );
    }
    out += &format!(
        "paper shape: the LQ/vQSGD ratio improves with S relative to d — here {:.3} (S=8192,d=128) vs {:.3} (S=32768,d=256)\n",
        ratios[0], ratios[1]
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e4_produces_both_series_and_steps() {
        let opts = ExpOpts {
            scale: 0.1,
            seeds: 1,
            out_dir: None,
            batch: 1,
            addr: None,
        };
        let r = run(&opts);
        assert!(r.contains("sublinear-LQ"));
        assert!(r.contains("vQSGD"));
        // The S/d claim: the large-S/d configuration must have a ratio no
        // worse than the small one (paper: LQ only wins at large S vs d).
        let line = r
            .lines()
            .find(|l| l.starts_with("paper shape"))
            .expect("summary line");
        let nums: Vec<f64> = line
            .split_whitespace()
            .filter_map(|t| t.parse().ok())
            .collect();
        assert!(nums.len() >= 2, "{line}");
        // y updates every 5 iters => the analytic curve is piecewise
        // constant in 5-blocks within a seed (steps in the figure).
        let (lq, _) = one_run(512, 64, 10, 0);
        assert_eq!(lq[0], lq[1]);
        assert_eq!(lq[1], lq[4]);
        assert_ne!(lq[4], lq[5]);
    }
}
