//! Experiment 7 (Figures 12–13): neural-network training with gradient
//! compression — accuracy table.
//!
//! ResNet/ImageNet is substituted by a 2-layer MLP on a gaussian-mixture
//! classification task (DESIGN.md §2): the paper's claim under test is
//! the *relative* accuracy of compressors at ~4 bits/coordinate, with
//! per-layer quantization, which this preserves. Rows: none, QSGD-L∞,
//! QSGD-L2, EF-SignSGD, PowerSGD, LQSGD. Expected shape: LQSGD within a
//! point or two of uncompressed and ≥ the other 4-bit schemes;
//! EF-SignSGD (1 bit) trails.

use super::{render_table, ExpOpts};
use crate::coordinator::CodecSpec;
use crate::data::gen_classification;
use crate::opt::mlp::{train_distributed, MlpTrainConfig};

pub fn run(opts: &ExpOpts) -> String {
    let q = 16; // 4 bits/coordinate
    let mut out = String::from("# E7 — NN training with compressed gradients (Figs 12-13)\n\n");
    let total = opts.samples(4000);
    let n_train = total * 4 / 5;
    let methods: Vec<(String, Option<CodecSpec>)> = vec![
        ("none".into(), None),
        (format!("QSGD-Linf(q={q})"), Some(CodecSpec::QsgdLinf { q })),
        (format!("QSGD-L2(q={q})"), Some(CodecSpec::QsgdL2 { q })),
        ("EF-SignSGD".into(), Some(CodecSpec::EfSign)),
        ("PowerSGD(r=2)".into(), Some(CodecSpec::PowerSgd { rank: 2 })),
        (format!("LQSGD(q={q})"), Some(CodecSpec::Lq { q })),
        (format!("RLQSGD(q={q})"), Some(CodecSpec::Rlq { q })),
    ];
    let mut rows = Vec::new();
    for (label, spec) in &methods {
        let mut tr = 0.0;
        let mut va = 0.0;
        let mut mm = 0usize;
        for seed in 0..opts.seeds.min(2) as u64 {
            // paper: "averaged over 2 runs, since variance is small"
            // Noise high enough that the task is not saturated — the
            // paper's comparison only shows up below the accuracy ceiling.
            let data = gen_classification(total, 16, 10, 1.0, 77 + seed);
            let (train, val) = data.split(n_train);
            let cfg = MlpTrainConfig {
                n_machines: 4,
                hidden: 64,
                lr: 0.4,
                epochs: opts.iters(12),
                batch_per_machine: 64,
                seed,
                y0: 0.5,
            };
            let rep = train_distributed(&train, &val, *spec, &cfg);
            tr += rep.train_acc;
            va += rep.val_acc;
            mm += rep.decode_mismatches;
        }
        let runs = opts.seeds.min(2) as f64;
        rows.push(vec![
            label.clone(),
            format!("{:.1}", 100.0 * tr / runs),
            format!("{:.1}", 100.0 * va / runs),
            format!("{mm}"),
        ]);
    }
    out += &render_table(
        &format!(
            "MLP-16-64-10 on gaussian mixture ({n_train} train / {} val), 4 machines, ~4 bits/coord",
            total - n_train
        ),
        &["compression", "train %", "val %", "decode-miss"],
        &rows,
    );
    out += "paper shape: all ~4-bit methods within a few points of 'none'; EF-SignSGD trails; LQSGD competitive with the best.\n";
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e7_table_shape() {
        let opts = ExpOpts {
            scale: 0.15,
            seeds: 1,
            out_dir: None,
            batch: 1,
            addr: None,
        };
        let r = run(&opts);
        assert!(r.contains("none"));
        assert!(r.contains("LQSGD"));
        // Parse val accuracies; LQSGD should be within 15 points of none
        // and EF-SignSGD should not beat everything.
        let acc = |name: &str| -> f64 {
            r.lines()
                .find(|l| l.trim_start().starts_with(name))
                .map(|l| {
                    l.split_whitespace()
                        .filter_map(|t| t.parse::<f64>().ok())
                        .nth(1)
                        .unwrap_or(0.0)
                })
                .unwrap_or(0.0)
        };
        let none = acc("none");
        let lq = acc(&format!("LQSGD(q=16)"));
        assert!(none > 50.0, "baseline should learn: {none}");
        assert!(lq > none - 20.0, "LQSGD {lq} vs none {none}");
    }
}
