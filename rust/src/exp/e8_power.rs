//! Experiment 8 (Figures 14–16): distributed power iteration.
//!
//! S = 8192, d = 128, q = 64 (6 bits/coordinate); the first two
//! eigenvalues are large and comparable so convergence is slow enough to
//! expose quantization. Three panels per figure: relevant norms (left),
//! convergence 1−|⟨x,v₁⟩| (center), quantization error (right).
//! Fig 14: principal direction e₂; Fig 15: random direction; Fig 16:
//! 8 workers.

use super::{mean_trace, render_series, ExpOpts, Series};
use crate::coordinator::CodecSpec;
use crate::data::gen_power_matrix;
use crate::opt::power_iteration::{run_power_iteration, PowerConfig};

fn panel(
    opts: &ExpOpts,
    title: &str,
    n_machines: usize,
    random_dirs: bool,
) -> String {
    let q = 64;
    // Rows must split evenly across machines.
    let samples = (opts.samples(8192) / n_machines.max(8)) * n_machines.max(8);
    let d = 128;
    let iters = opts.iters(50);
    let methods: Vec<(String, Option<CodecSpec>)> = vec![
        ("baseline".into(), None),
        (format!("LQSGD(q={q})"), Some(CodecSpec::Lq { q })),
        (format!("RLQSGD(q={q})"), Some(CodecSpec::Rlq { q })),
        (format!("QSGD-L2(q={q})"), Some(CodecSpec::QsgdL2 { q })),
        (format!("Hadamard(q={q})"), Some(CodecSpec::Hadamard { q })),
    ];

    let mut out = String::new();
    // Norms panel from the baseline run.
    let mut norm_dist = Vec::new();
    let mut norm_range = Vec::new();
    let mut conv_series = Vec::new();
    let mut err_series = Vec::new();
    for (label, spec) in &methods {
        let mut conv = Vec::new();
        let mut qerr = Vec::new();
        for seed in 0..opts.seeds as u64 {
            let (m, v1) =
                gen_power_matrix(samples, d, &[10.0, 8.5, 2.0], random_dirs, 500 + seed);
            let cfg = PowerConfig {
                n_machines,
                iters,
                seed,
                y0: 2.0 * samples as f64 / n_machines as f64 / 100.0,
                ..Default::default()
            };
            let t = run_power_iteration(&m, &v1, *spec, &cfg);
            if spec.is_none() {
                norm_dist.push(t.u_dist_inf.clone());
                norm_range.push(t.u_range.clone());
            }
            conv.push(t.angle_err);
            qerr.push(t.quant_err);
        }
        conv_series.push(Series {
            label: label.clone(),
            values: mean_trace(&conv),
        });
        if spec.is_some() {
            err_series.push(Series {
                label: label.clone(),
                values: mean_trace(&qerr),
            });
        }
    }
    out += &render_series(
        &format!("{title} — left: norms (baseline trajectory)"),
        "iter",
        &[
            Series {
                label: "|u0-u1|_inf".into(),
                values: mean_trace(&norm_dist),
            },
            Series {
                label: "max-min(u0)".into(),
                values: mean_trace(&norm_range),
            },
        ],
        10,
    );
    out += &render_series(
        &format!("{title} — center: convergence 1-|<x,v1>|"),
        "iter",
        &conv_series,
        10,
    );
    out += &render_series(
        &format!("{title} — right: quantization error"),
        "iter",
        &err_series,
        10,
    );
    let last = |s: &Series| *s.values.last().unwrap();
    out += &format!(
        "shape check (final angle err): baseline {:.3e}, LQSGD {:.3e}, RLQSGD {:.3e}, QSGD-L2 {:.3e}\n\n",
        last(&conv_series[0]),
        last(&conv_series[1]),
        last(&conv_series[2]),
        last(&conv_series[3])
    );
    out
}

pub fn run(opts: &ExpOpts) -> String {
    let mut out = String::from("# E8 — distributed power iteration (Figs 14-16)\n\n");
    out += &panel(opts, "Fig 14: principal = e2, 2 workers", 2, false);
    out += &panel(opts, "Fig 15: principal = random, 2 workers", 2, true);
    out += &panel(opts, "Fig 16: 8 workers", 8, true);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e8_lattice_tracks_baseline() {
        let opts = ExpOpts {
            scale: 0.15,
            seeds: 1,
            out_dir: None,
            batch: 1,
            addr: None,
        };
        let r = panel(&opts, "t", 2, false);
        let line = r
            .lines()
            .find(|l| l.starts_with("shape check"))
            .expect("shape check line");
        let nums: Vec<f64> = line
            .split_whitespace()
            .filter_map(|t| t.trim_end_matches(',').parse().ok())
            .collect();
        let (base, lq, _rlq, qs) = (nums[0], nums[1], nums[2], nums[3]);
        assert!(
            lq < base + 0.2,
            "LQ angle {lq} should be near baseline {base}"
        );
        assert!(lq <= qs * 2.0 + 1e-9, "LQ {lq} should not lose badly to QSGD {qs}");
    }
}
