//! Experiment 6 (Figure 11): Local SGD with compressed model deltas.
//!
//! Workers average every 10 local steps; the transmitted model deltas are
//! compressed with RLQSGD vs QSGD vs Hadamard vs uncompressed. Two
//! panels: convergence (left) and quantization error (right).

use super::{mean_trace, render_series, ExpOpts, Series};
use crate::coordinator::CodecSpec;
use crate::data::gen_lsq;
use crate::opt::local_sgd::{run_local_sgd, LocalSgdConfig};

pub fn run(opts: &ExpOpts) -> String {
    let q = 16;
    let mut out = String::from("# E6 — Local SGD with compressed deltas (Fig 11)\n\n");
    let samples = opts.samples(8192);
    let rounds = opts.iters(40);
    let methods: Vec<(String, Option<CodecSpec>)> = vec![
        ("uncompressed".into(), None),
        (format!("RLQSGD(q={q})"), Some(CodecSpec::Rlq { q })),
        (format!("LQSGD(q={q})"), Some(CodecSpec::Lq { q })),
        (format!("QSGD-L2(q={q})"), Some(CodecSpec::QsgdL2 { q })),
        (format!("Hadamard(q={q})"), Some(CodecSpec::Hadamard { q })),
    ];
    let mut loss_series = Vec::new();
    let mut err_series = Vec::new();
    for (label, spec) in methods {
        let mut losses = Vec::new();
        let mut errs = Vec::new();
        for seed in 0..opts.seeds as u64 {
            let ds = gen_lsq(samples, 100, seed * 10);
            let cfg = LocalSgdConfig {
                n_machines: 2,
                lr: 0.02,
                local_steps: 10,
                rounds,
                batch: 256,
                seed,
                y0: 0.5,
                ..Default::default()
            };
            let t = run_local_sgd(&ds, spec, &cfg);
            losses.push(t.loss);
            errs.push(t.quant_err);
        }
        loss_series.push(Series {
            label: label.clone(),
            values: mean_trace(&losses),
        });
        err_series.push(Series {
            label,
            values: mean_trace(&errs),
        });
    }
    out += &render_series(
        &format!(
            "Fig 11 left: Local SGD loss (S={samples}, d=100, avg every 10 steps, {} seeds)",
            opts.seeds
        ),
        "round",
        &loss_series,
        12,
    );
    out += &render_series(
        "Fig 11 right: quantization error ‖mean Δ̂ − mean Δ‖₂",
        "round",
        &err_series,
        12,
    );
    let tail = |s: &Series| {
        let v = &s.values;
        v[v.len() / 2..].iter().sum::<f64>() / (v.len() - v.len() / 2) as f64
    };
    out += &format!(
        "shape check (quant err, 2nd half): RLQSGD {:.3e}, LQSGD {:.3e}, QSGD-L2 {:.3e}, Hadamard {:.3e}\n\n",
        tail(&err_series[1]),
        tail(&err_series[2]),
        tail(&err_series[3]),
        tail(&err_series[4])
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e6_lattice_quant_error_below_norm_based() {
        let opts = ExpOpts {
            scale: 0.2,
            seeds: 1,
            out_dir: None,
            batch: 1,
            addr: None,
        };
        let r = run(&opts);
        for line in r.lines().filter(|l| l.starts_with("shape check")) {
            let nums: Vec<f64> = line
                .split_whitespace()
                .filter_map(|t| t.trim_end_matches(',').parse().ok())
                .collect();
            let (rlq, lq, qs) = (nums[0], nums[1], nums[2]);
            assert!(
                rlq.min(lq) < qs,
                "lattice err (rlq {rlq}, lq {lq}) must beat QSGD {qs}"
            );
        }
    }
}
