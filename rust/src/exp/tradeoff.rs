//! Bits ↔ variance trade-off — the empirical face of Theorems 2/6.
//!
//! Sweeps q over the star and tree topologies at fixed inputs and
//! reports, per q: exact max bits sent/received by any machine, measured
//! output variance `E‖EST − μ‖²`, the upper-bound model `49·s²·d`
//! (per-coordinate uniform error through two quantization stages), and
//! the lower-bound shape `Ω(y² 2^{−2b/d})` (Theorem 38). Expected shape:
//! measured variance decays ~1/q² per ℓ∞ coordinate (the paper states
//! O(y²/q) after normalizing b = d log q; both bounds bracket the
//! measurement).

use super::{render_table, ExpOpts};
use crate::coordinator::{CodecSpec, DmeBuilder, DmeSession, Topology};
use crate::linalg::{dist2, mean_vecs};
use crate::rng::Rng;

/// Run `trials` rounds of the same inputs through `sess`, accumulating
/// squared error vs `mu` and the max per-machine (sent+recv) bits. With
/// `batch > 1` the trials ride [`DmeSession::round_batch_with_y`] in
/// groups of `batch` slots — **bit-identical** to the sequential loop
/// (each slot is the round at the same index; pinned by a test below),
/// one worker crossing per group instead of per trial.
fn run_trials(
    sess: &mut DmeSession,
    inputs: &[Vec<f64>],
    mu: &[f64],
    y: f64,
    trials: u64,
    batch: usize,
) -> (f64, u64) {
    let mut var = 0.0;
    let mut bits = 0u64;
    let mut tally = |o: &crate::coordinator::RoundOutcome| {
        var += dist2(&o.estimate, mu).powi(2);
        bits = bits.max(
            o.round_traffic
                .iter()
                .map(|tr| tr.sent_bits + tr.recv_bits)
                .max()
                .unwrap(),
        );
    };
    if batch <= 1 {
        for _ in 0..trials {
            tally(&sess.round_with_y(inputs, y));
        }
    } else {
        let mut done = 0u64;
        let mut outcomes = Vec::new();
        while done < trials {
            let take = batch.min((trials - done) as usize);
            let slots = vec![inputs.to_vec(); take];
            let ys = vec![y; take];
            sess.round_batch_into(&slots, &ys, &mut outcomes);
            for o in &outcomes {
                tally(o);
            }
            done += take as u64;
        }
    }
    (var / trials as f64, bits)
}

pub fn run(opts: &ExpOpts) -> String {
    let d = 64;
    let n = 8;
    let y = 1.0;
    let batch = opts.batch.max(1);
    let trials = (20.0 * opts.scale.max(0.05)).ceil() as u64 * 5;
    let mut out = String::from("# Tradeoff — bits vs output variance (Theorems 2/6 shape)\n\n");

    // Fixed inputs centered far from the origin.
    let mut rng = Rng::new(99);
    let inputs: Vec<Vec<f64>> = (0..n)
        .map(|_| {
            (0..d)
                .map(|_| 250.0 + rng.uniform(-y / 2.0, y / 2.0))
                .collect()
        })
        .collect();
    let mu = mean_vecs(&inputs);

    let mut rows = Vec::new();
    for q in [4u32, 8, 16, 32, 64, 128] {
        // Star topology measurements over one persistent session (the
        // round counter advances the shared randomness per trial exactly
        // as the historical per-trial one-shot calls did). Diagnostics
        // stay off, so the leader runs the streaming fold — O(d) memory,
        // one fused decode-accumulate pass per packet — while producing
        // bit-identical estimates.
        let mut star = DmeBuilder::new(n, d).codec(CodecSpec::Lq { q }).seed(7).build();
        let (var_star, bits_star) = run_trials(&mut star, &inputs, &mu, y, trials, batch);
        // Tree topology.
        let mut tree = DmeBuilder::new(n, d)
            .topology(Topology::Tree { m: q as usize })
            .seed(8)
            .build();
        let (var_tree, bits_tree) = run_trials(&mut tree, &inputs, &mu, y, trials, batch);

        // Models.
        let s = 2.0 * y / (q as f64 - 1.0);
        let ub_model = 2.0 * d as f64 * s * s / 12.0; // two quantization stages
        let b = bits_star as f64;
        let lb_model = y * y * (2f64).powf(-2.0 * b / d as f64);
        rows.push(vec![
            format!("{q}"),
            format!("{bits_star}"),
            format!("{var_star:.3e}"),
            format!("{bits_tree}"),
            format!("{var_tree:.3e}"),
            format!("{ub_model:.3e}"),
            format!("{lb_model:.3e}"),
        ]);
    }
    out += &render_table(
        &format!(
            "n={n}, d={d}, y={y}, {trials} trials (batch={batch}; batched rounds are \
             bit-identical to sequential trials); bits = max over machines (sent+recv)"
        ),
        &[
            "q",
            "star bits",
            "star var",
            "tree bits",
            "tree var",
            "UB model",
            "LB shape",
        ],
        &rows,
    );
    out += "expected: star var ≈ UB model, halves ~4x per q doubling; LB shape decays much faster (it is the info-theoretic floor at that many bits).\n";
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batched_trials_reproduce_sequential_report_exactly() {
        // The batch is a pure scheduling change: grouping the trials into
        // round_batch calls must not move a single reported digit (only
        // the batch= header line differs).
        let seq = run(&ExpOpts {
            scale: 0.1,
            seeds: 1,
            out_dir: None,
            batch: 1,
            addr: None,
        });
        let batched = run(&ExpOpts {
            scale: 0.1,
            seeds: 1,
            out_dir: None,
            batch: 7,
            addr: None,
        });
        let strip = |r: &str| -> Vec<String> {
            r.lines()
                .filter(|l| !l.contains("batch="))
                .map(str::to_string)
                .collect()
        };
        assert_eq!(strip(&seq), strip(&batched));
    }

    #[test]
    fn variance_decreases_monotonically_in_q() {
        let opts = ExpOpts {
            scale: 0.2,
            seeds: 1,
            out_dir: None,
            batch: 1,
            addr: None,
        };
        let r = run(&opts);
        let vars: Vec<f64> = r
            .lines()
            .filter(|l| {
                l.trim_start()
                    .chars()
                    .next()
                    .map_or(false, |c| c.is_ascii_digit())
            })
            .map(|l| {
                l.split_whitespace()
                    .nth(2)
                    .unwrap()
                    .parse::<f64>()
                    .unwrap()
            })
            .collect();
        assert!(vars.len() >= 4);
        for w in vars.windows(2) {
            assert!(w[1] < w[0] * 1.2, "variance should trend down: {vars:?}");
        }
        // Roughly 4x drop per q doubling (1/q² per coordinate).
        assert!(vars[0] / vars[2] > 4.0, "{vars:?}");
    }
}
