//! Ablations over the design choices DESIGN.md calls out.
//!
//! A. **Unbiasing mechanism** — shared random offset (§9.1) vs encoder-
//!    side convex-hull stochastic rounding (Algorithm 1): same bits,
//!    compare measured variance (offset should win ~2× per coordinate:
//!    Var[U(−s/2,s/2)] = s²/12 vs hull's s²·p(1−p) ≤ s²/4).
//! B. **y slack** — decode-failure rate and variance vs the slack factor
//!    in the FromQuantized policy (the paper uses 1.5–3.5).
//! C. **Rotation** — LQ vs RLQ under ℓ2 on skewed (single-spike-heavy)
//!    inputs, where the ℓ∞ bound of the unrotated lattice is loose.

use super::{render_table, ExpOpts};
use crate::coordinator::{CodecSpec, YPolicy};
use crate::data::gen_lsq;
use crate::linalg::{dist2, mean_vecs};
use crate::opt::allreduce::Aggregator;
use crate::quant::convex_hull::ConvexHullEncoder;
use crate::quant::{LatticeQuantizer, VectorCodec};
use crate::rng::{hash2, Rng};

fn ablation_a(opts: &ExpOpts) -> String {
    let d = 128;
    let q = 16;
    let y = 1.0;
    let trials = (2000.0 * opts.scale.max(0.05)) as u64;
    let mut rng = Rng::new(1);
    let x: Vec<f64> = (0..d).map(|_| 300.0 + rng.uniform(-y / 2.0, y / 2.0)).collect();
    let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(-y / 2.0, y / 2.0)).collect();

    // Shared-offset nearest rounding.
    let mut var_off = 0.0;
    let mut shared = Rng::new(2);
    for _ in 0..trials {
        let c = LatticeQuantizer::from_y(d, q, y, &mut shared);
        let (msg, _) = c.encode_with_point(&x);
        let z = c.decode(&msg, &xv);
        var_off += dist2(&z, &x).powi(2);
    }
    var_off /= trials as f64;

    // Convex-hull stochastic rounding (fixed lattice).
    let mut var_hull = 0.0;
    let mut enc = ConvexHullEncoder::from_y(d, q, y);
    for t in 0..trials {
        let mut r = Rng::new(hash2(3, t));
        let msg = enc.encode(&x, &mut r);
        let z = enc.decode(&msg, &xv);
        var_hull += dist2(&z, &x).powi(2);
    }
    var_hull /= trials as f64;

    render_table(
        &format!("A. unbiasing mechanism (d={d}, q={q}, {trials} trials, bits equal)"),
        &["encoder", "E‖ẑ−x‖²", "vs offset"],
        &[
            vec!["shared offset (§9.1)".into(), format!("{var_off:.4e}"), "1.00x".into()],
            vec![
                "convex hull (Alg 1)".into(),
                format!("{var_hull:.4e}"),
                format!("{:.2}x", var_hull / var_off),
            ],
        ],
    )
}

fn ablation_b(opts: &ExpOpts) -> String {
    let ds = gen_lsq(opts.samples(4096), 64, 5);
    let mut rows = Vec::new();
    for slack in [1.1, 1.5, 2.0, 3.0] {
        let mut mismatches = 0usize;
        let mut var = 0.0;
        let iters = opts.iters(60);
        let mut agg = Aggregator::new(
            CodecSpec::Lq { q: 16 },
            2,
            64,
            1.0,
            YPolicy::FromQuantized { slack },
            7,
        );
        let mut w = vec![0.0; 64];
        let mut rng = Rng::new(8);
        let warmup = 5; // let y lock on before counting misses
        for it in 0..iters {
            let parts = ds.partition(2, &mut rng);
            let grads: Vec<Vec<f64>> =
                parts.iter().map(|p| ds.batch_gradient(&w, p)).collect();
            let rep = agg.step(&grads);
            if it >= warmup {
                mismatches += rep.decode_mismatches;
                var += dist2(&rep.estimate, &mean_vecs(&grads)).powi(2);
            }
            crate::linalg::axpy(&mut w, -0.3, &rep.estimate);
        }
        let counted = iters - warmup;
        rows.push(vec![
            format!("{slack}"),
            format!("{:.2}%", 100.0 * mismatches as f64 / (2 * counted) as f64),
            format!("{:.3e}", var / counted as f64),
        ]);
    }
    render_table(
        "B. y-slack sweep (LQ q=16, n=2, lsq SGD)",
        &["slack", "decode-miss rate", "mean ‖EST−mean(g)‖²"],
        &rows,
    )
}

fn ablation_c(opts: &ExpOpts) -> String {
    // Skewed inputs: one giant coordinate difference; ℓ∞-driven s is
    // loose for LQ, the rotation spreads it (Theorem 5's mechanism).
    let d = 256;
    let q = 16;
    let trials = (400.0 * opts.scale.max(0.05)) as u64;
    let mut rows = Vec::new();
    for (label, spec) in [
        ("LQSGD(q=16)", CodecSpec::Lq { q }),
        ("RLQSGD(q=16)", CodecSpec::Rlq { q }),
    ] {
        let mut var = 0.0;
        for t in 0..trials {
            let mut rng = Rng::new(hash2(11, t));
            let mut x: Vec<f64> = (0..d).map(|_| 50.0 + 0.01 * rng.next_gaussian()).collect();
            let mut xv = x.clone();
            // Spike: one coordinate differs by 1.0 (ℓ2 distance ≈ spike).
            let j = rng.next_below(d as u64) as usize;
            x[j] += 1.0;
            xv[j] -= 0.0;
            // y: honest per-method bound measured on this pair.
            let y = match spec {
                CodecSpec::Rlq { .. } => {
                    let mut sh = Rng::new(hash2(12, t));
                    let rot = crate::quant::hadamard::Rotation::new(d, &mut sh);
                    crate::linalg::dist_inf(&rot.forward(&x), &rot.forward(&xv)) * 1.5
                }
                _ => crate::linalg::dist_inf(&x, &xv) * 1.5,
            };
            let mut codec = spec.build(d, y.max(1e-9), 12, t);
            let mut er = Rng::new(hash2(13, t));
            let msg = codec.encode(&x, &mut er);
            let z = codec.decode(&msg, &xv);
            var += dist2(&z, &x).powi(2);
        }
        rows.push(vec![label.to_string(), format!("{:.4e}", var / trials as f64)]);
    }
    render_table(
        &format!("C. rotation on skewed inputs (d={d}, spike differences, ℓ2 error)"),
        &["codec", "E‖ẑ−x‖²"],
        &rows,
    )
}

fn ablation_d(opts: &ExpOpts) -> String {
    // D. Lattice choice: D4 vs cubic rate-distortion at matched scale
    // (the §6 future-work lattice; D4 spends 1 bit/bucket less).
    let d = 256;
    let q = 16u32;
    let s = 0.4;
    let trials = (2000.0 * opts.scale.max(0.05)) as u64;
    let mut shared = Rng::new(21);
    let mut rng = Rng::new(22);
    let x: Vec<f64> = (0..d).map(|_| rng.uniform(-10.0, 10.0)).collect();
    let run = |cubic: bool, shared: &mut Rng| -> (f64, f64) {
        let mut mse = 0.0;
        let mut bits = 0.0;
        for _ in 0..trials {
            let (msg_bits, p) = if cubic {
                let c = crate::quant::LatticeQuantizer::new(
                    crate::quant::CubicLattice::random_offset(d, s, shared),
                    q,
                );
                let (m, p) = c.encode_with_point(&x);
                (m.bits, p)
            } else {
                let c = crate::quant::D4Quantizer::new(d, q, s, shared);
                let (m, p) = c.encode_with_point(&x);
                (m.bits, p)
            };
            bits += msg_bits as f64;
            mse += x.iter().zip(&p).map(|(a, b)| (a - b).powi(2)).sum::<f64>();
        }
        (mse / (trials * d as u64) as f64, bits / trials as f64 / d as f64)
    };
    let (mse_c, b_c) = run(true, &mut shared);
    let (mse_d, b_d) = run(false, &mut shared);
    let rd = |mse: f64, b: f64| mse * 4f64.powf(b);
    render_table(
        &format!("D. lattice choice at matched scale (d={d}, q={q}, s={s}, {trials} trials)"),
        &["lattice", "bits/coord", "MSE/coord", "RD product MSE·4^b"],
        &[
            vec![
                "cubic".into(),
                format!("{b_c:.2}"),
                format!("{mse_c:.5e}"),
                format!("{:.4e}", rd(mse_c, b_c)),
            ],
            vec![
                "D4 (checkerboard)".into(),
                format!("{b_d:.2}"),
                format!("{mse_d:.5e}"),
                format!("{:.4e}", rd(mse_d, b_d)),
            ],
        ],
    )
}

pub fn run(opts: &ExpOpts) -> String {
    let mut out = String::from("# Ablations — design choices (DESIGN.md §3)\n\n");
    out += &ablation_a(opts);
    out += &ablation_b(opts);
    out += &ablation_c(opts);
    out += &ablation_d(opts);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offset_beats_hull_and_rotation_helps_on_spikes() {
        let opts = ExpOpts {
            scale: 0.1,
            seeds: 1,
            out_dir: None,
            batch: 1,
            addr: None,
        };
        let a = ablation_a(&opts);
        // hull variance factor must be > 1 (worse than shared offset).
        let factor: f64 = a
            .lines()
            .find(|l| l.contains("convex hull"))
            .and_then(|l| l.split_whitespace().last())
            .and_then(|t| t.trim_end_matches('x').parse().ok())
            .unwrap();
        assert!(factor > 1.1, "hull should be worse: {factor}");

        let c = ablation_c(&opts);
        let grab = |name: &str| -> f64 {
            c.lines()
                .find(|l| l.trim_start().starts_with(name))
                .and_then(|l| l.split_whitespace().last())
                .and_then(|t| t.parse().ok())
                .unwrap()
        };
        let lq = grab("LQSGD");
        let rlq = grab("RLQSGD");
        assert!(rlq < lq, "rotation must help on spikes: rlq {rlq} lq {lq}");
    }

    #[test]
    fn slack_sweep_monotone_failures() {
        let opts = ExpOpts {
            scale: 0.15,
            seeds: 1,
            out_dir: None,
            batch: 1,
            addr: None,
        };
        let b = ablation_b(&opts);
        let rates: Vec<f64> = b
            .lines()
            .filter(|l| l.contains('%'))
            .filter_map(|l| {
                l.split_whitespace()
                    .find(|t| t.ends_with('%'))
                    .and_then(|t| t.trim_end_matches('%').parse().ok())
            })
            .collect();
        assert!(rates.len() >= 3);
        // Failure rate at slack 3.0 must be ≤ at slack 1.1.
        assert!(rates.last().unwrap() <= rates.first().unwrap());
    }
}
