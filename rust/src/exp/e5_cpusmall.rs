//! Experiment 5 (Figures 9–10): convergence on a real dataset with
//! 8 / 16 machines.
//!
//! cpusmall_scale-shaped regression (S = 8192, d = 12), q = 16, batch =
//! S/n, initial weights −1000·𝟙 (far from the optimum, so gradients have
//! huge norm but modest spread — the regime the paper targets). Star
//! topology (Algorithm 3): a random leader collects quantized gradients,
//! broadcasts the quantized average, and broadcasts next round's `y` as a
//! 64-bit float (`y = 3·max‖Q(g_i)−Q(g_j)‖∞`).
//!
//! If a real LIBSVM `cpusmall_scale` file is present at
//! `data/cpusmall_scale` it is used instead of the generator.

use super::{mean_trace, render_series, ExpOpts, Series};
use crate::coordinator::{CodecSpec, YPolicy};
use crate::data::cpusmall_or_synthetic;
use crate::opt::dist_gd::{run_distributed_gd, GdAggregation, GdConfig};

pub fn run(opts: &ExpOpts) -> String {
    let q = 16;
    let mut out = String::from("# E5 — convergence on cpusmall-like data (Figs 9-10)\n\n");
    for (fig, n) in [("Fig 9 (8 machines)", 8usize), ("Fig 10 (16 machines)", 16)] {
        let samples = opts.samples(8192);
        let iters = opts.iters(150);
        let methods: Vec<(String, GdAggregation)> = vec![
            ("naive avg".into(), GdAggregation::Exact),
            (
                format!("LQSGD(q={q})"),
                GdAggregation::Star(CodecSpec::Lq { q }),
            ),
            (
                format!("QSGD-L2(q={q})"),
                GdAggregation::Star(CodecSpec::QsgdL2 { q }),
            ),
            (
                format!("QSGD-Linf(q={q})"),
                GdAggregation::Star(CodecSpec::QsgdLinf { q }),
            ),
            (
                format!("Hadamard(q={q})"),
                GdAggregation::Star(CodecSpec::Hadamard { q }),
            ),
        ];
        let mut series = Vec::new();
        for (label, agg) in methods {
            let traces: Vec<Vec<f64>> = (0..opts.seeds as u64)
                .map(|seed| {
                    let ds = cpusmall_or_synthetic("data/cpusmall_scale", samples, 1234);
                    let d = ds.dim();
                    let cfg = GdConfig {
                        n_machines: n,
                        lr: 0.1,
                        iters,
                        seed,
                        y0: 200.0, // generous bootstrap; leader re-measures
                        y_policy: YPolicy::LeaderMeasured {
                            slack: 3.0,
                            period: 1,
                        },
                        w0: Some(vec![-1000.0; d]),
                        batch_slots: 1,
                    };
                    run_distributed_gd(&ds, &agg, &cfg).loss
                })
                .collect();
            series.push(Series {
                label,
                values: mean_trace(&traces),
            });
        }
        out += &render_series(
            &format!(
                "{fig}: S={samples}, d=12, q={q}, w0=-1000, loss, mean of {} seeds",
                opts.seeds
            ),
            "iter",
            &series,
            12,
        );
        // Transient quality: mean log10-loss over the trajectory (the
        // paper's figures separate methods mid-descent, not at the floor).
        let auc = |i: usize| {
            let v = &series[i].values;
            v.iter().map(|x| x.max(1e-300).log10()).sum::<f64>() / v.len() as f64
        };
        out += &format!(
            "shape check (mean log10 loss): naive {:.4}, LQSGD {:.4}, QSGD-L2 {:.4}, QSGD-Linf {:.4}\n\n",
            auc(0),
            auc(1),
            auc(2),
            auc(3)
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e5_lqsgd_beats_norm_based_far_from_origin() {
        let opts = ExpOpts {
            scale: 0.25,
            seeds: 1,
            out_dir: None,
            batch: 1,
            addr: None,
        };
        let r = run(&opts);
        for line in r.lines().filter(|l| l.starts_with("shape check")) {
            let nums: Vec<f64> = line
                .split_whitespace()
                .filter_map(|t| t.trim_end_matches(',').parse().ok())
                .collect();
            let (naive, lq, qs2) = (nums[0], nums[1], nums[2]);
            // log10 scale: LQSGD must track naive closely and not lose to
            // the norm-based scheme in transient quality.
            assert!(
                lq <= naive + 0.3,
                "LQSGD {lq} should track naive {naive} (log10 AUC)"
            );
            assert!(
                lq <= qs2 + 0.05,
                "LQSGD {lq} must not lose to QSGD-L2 {qs2} at w0=-1000"
            );
        }
    }
}
