//! Experiment 3 (Figures 5–6): convergence under quantized gradients.
//!
//! Same setup as E2 but now the quantized average *drives* the SGD update
//! (lr = 0.8, the paper's deliberately high rate to expose quantization
//! noise). Expected shape: LQSGD/RLQSGD track the naive-averaging curve;
//! norm-based schemes converge slower or stall.

use super::{mean_trace, render_series, ExpOpts, Series};
use crate::data::gen_lsq;
use crate::opt::dist_gd::{run_distributed_gd, GdAggregation, GdConfig};

pub fn run(opts: &ExpOpts) -> String {
    let q = 8;
    let mut out = String::from("# E3 — convergence at 3 bits/coordinate (Figs 5-6)\n\n");
    for (fig, samples) in [("Fig 5 (fewer samples)", 8192), ("Fig 6 (more samples)", 32768)] {
        let s = opts.samples(samples);
        let iters = opts.iters(40);
        let mut series = Vec::new();
        let mut methods: Vec<(String, GdAggregation)> =
            vec![("naive avg".into(), GdAggregation::Exact)];
        methods.extend(super::e2_variance::methods_q(q));
        for (label, agg) in methods {
            let traces: Vec<Vec<f64>> = (0..opts.seeds as u64)
                .map(|seed| {
                    let ds = gen_lsq(s, 100, seed * 10);
                    let cfg = GdConfig {
                        n_machines: 2,
                        lr: 0.8,
                        iters,
                        seed,
                        y0: 1.0,
                        ..Default::default()
                    };
                    run_distributed_gd(&ds, &agg, &cfg).loss
                })
                .collect();
            series.push(Series {
                label,
                values: mean_trace(&traces),
            });
        }
        out += &render_series(
            &format!("{fig}: S={s}, d=100, q={q}, lr=0.8, loss, mean of {} seeds", opts.seeds),
            "iter",
            &series,
            12,
        );
        let last = |i: usize| *series[i].values.last().unwrap();
        out += &format!(
            "shape check (final loss): naive {:.3e}, LQSGD {:.3e}, QSGD-L2 {:.3e}\n\n",
            last(0),
            last(1),
            last(3)
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e3_lqsgd_converges_like_naive() {
        let opts = ExpOpts {
            scale: 0.25,
            seeds: 2,
            out_dir: None,
            batch: 1,
            addr: None,
        };
        let r = run(&opts);
        for line in r.lines().filter(|l| l.starts_with("shape check")) {
            let nums: Vec<f64> = line
                .split_whitespace()
                .filter_map(|t| t.trim_end_matches(',').parse().ok())
                .collect();
            let (naive, lq, qs) = (nums[0], nums[1], nums[2]);
            assert!(
                lq <= naive * 10.0 + 1e-6,
                "LQSGD {lq} should track naive {naive}"
            );
            assert!(lq < qs, "LQSGD {lq} must out-converge QSGD {qs}");
        }
    }
}
