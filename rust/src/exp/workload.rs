//! Seeded chaos workload — hostile traffic against a live DME service.
//!
//! `dme exp chaos` replays a deterministic mix of hostile events
//! (duplicates, NaN payloads, implausibly-far payloads, truncated
//! frames, oversize frames, garbage magic, a slow-loris drip, a
//! rate-limit flood) against a hardened service, then runs honest
//! cohorts through the same edge and proves three things:
//!
//! 1. **Exactness under attack** — every honest cohort's round closes
//!    with the *bit-identical* k-of-k mean an in-process
//!    [`CohortTable`] fold of the same reports produces (n = 2 honest
//!    clients per cohort, so the floating-point fold commutes and
//!    arrival order cannot perturb the comparison).
//! 2. **No panics** — every hostile event is answered by a typed
//!    response (`Error` / `Busy` / `Estimate`), never by a dropped
//!    process.
//! 3. **Accounting** — the service's shed/quarantined ledgers match
//!    the tallies the seed predicts exactly, and no resident
//!    accumulator bytes outlive the run.
//!
//! Every event is a pure function of the chaos seed (default
//! [`DEFAULT_SEED`], overridable via the `DME_CHAOS_SEED` env var), so
//! two runs with the same seed produce the same report modulo the
//! `addr` line — the determinism the CI overload-smoke greps for.
//!
//! With `opts.addr = None` the harness self-hosts a hardened server in
//! a background thread ([`hardened_opts`]: screen=distance, rate limit
//! burst 2 with no refill, resident-byte budget [`RESIDENT_BUDGET`])
//! and additionally asserts the serve summary's peak-resident
//! high-water mark stays under budget. With `opts.addr = Some(..)` it
//! targets an external `dme serve`, which must be started with the
//! matching knobs (`screen=distance rate_burst=2 rate_per_sec=0`) for
//! the shed tallies to line up. Either way the run ends with a
//! shutdown request — point it only at an ephemeral server.

use super::ExpOpts;
use crate::coordinator::CodecSpec;
use crate::net::cohort::{
    client_encoder_rng, cohort_codec, CohortKey, CohortSpec, CohortTable, Submit,
};
use crate::net::screen::ScreenMode;
use crate::net::service::{
    fetch_stats, report_round, request_shutdown, serve, RateLimit, ServeOpts, ServeSummary,
};
use crate::net::wire::{read_response, write_request, Request, Response, REQ_MAGIC};
use crate::quant::Message;
use crate::rng::{hash2, Rng};
use std::fmt::Write as _;
use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::thread;
use std::time::{Duration, Instant};

/// Default chaos seed (env `DME_CHAOS_SEED` overrides).
pub const DEFAULT_SEED: u64 = 0xC4A05;

/// Resident-accumulator budget the self-hosted server enforces and the
/// harness asserts against (1 MiB — far above what the honest cohorts
/// need, far below an accumulator leak).
pub const RESIDENT_BUDGET: usize = 1 << 20;

/// Cohort-id block the harness owns; accounting sums stats over
/// `[COHORT_BASE, COHORT_END)` so an external server's unrelated
/// cohorts cannot perturb the tallies.
const COHORT_BASE: u64 = 100;
const COHORT_END: u64 = 300;

/// The hostile mix. Counts are fixed (not scaled) so the CI tallies
/// are stable across `scale=`.
const DUPS: u64 = 2;
const NANS: u64 = 2;
const FARS: u64 = 2;
const TRUNCS: u64 = 2;
const OVERSIZE: u64 = 2;
const GARBAGE: u64 = 2;
const FLOODS: u64 = 8;
/// Tokens a reporter gets under the harness's rate limit (burst 2, no
/// refill) — the first two flood reports land, the rest shed.
const RATE_BURST: f64 = 2.0;

struct Config {
    seed: u64,
    honest_cohorts: usize,
    d: usize,
    y: f64,
}

impl Config {
    fn from_opts(opts: &ExpOpts) -> Self {
        let seed = std::env::var("DME_CHAOS_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(DEFAULT_SEED);
        Config {
            seed,
            honest_cohorts: ((4.0 * opts.scale) as usize).max(2),
            d: 16,
            y: 8.0,
        }
    }

    fn spec(&self, n: usize, codec: CodecSpec) -> CohortSpec {
        CohortSpec {
            n,
            d: self.d,
            spec: codec,
            y: self.y,
            seed: self.seed,
        }
    }
}

/// Per-event verdicts observed during the hostile phase.
#[derive(Default)]
struct Tally {
    dup_rejected: u64,
    oversize_rejected: u64,
    garbage_rejected: u64,
    trunc_shed: u64,
    flood_shed: u64,
    nan_quarantined: u64,
    far_quarantined: u64,
    loris_survived: u64,
}

fn connect(addr: &str) -> TcpStream {
    let s = TcpStream::connect(addr).expect("chaos: connect");
    s.set_read_timeout(Some(Duration::from_secs(20))).expect("chaos: read timeout");
    let _ = s.set_nodelay(true);
    s
}

/// One raw (retry-free) report over the wire; returns the response.
fn raw_report(
    addr: &str,
    cohort: u64,
    round: u64,
    client: u32,
    spec: &CohortSpec,
    deadline_ms: u32,
    msg: Message,
) -> Response {
    let mut s = connect(addr);
    let req = Request::Report {
        cohort,
        round,
        client,
        spec: *spec,
        deadline_ms,
        msg,
    };
    write_request(&mut s, &req).expect("chaos: write report");
    read_response(&mut s).expect("chaos: typed response, not a dropped connection")
}

/// An honest encode for `(spec, round, client)` — the exact message a
/// well-behaved `dme report` would send.
fn honest_message(spec: &CohortSpec, round: u64, client: usize, x: &[f64]) -> Message {
    let mut codec = cohort_codec(spec, round);
    let mut rng = client_encoder_rng(spec.seed, round, client);
    codec.encode(x, &mut rng)
}

/// A full-precision payload whose every field is `value` — the raw-f32
/// shape lets the harness plant NaN or implausibly-far floats while
/// keeping the frame sizes exactly what the screen's probe expects.
fn full_payload(d: usize, value: f32) -> Message {
    let mut bytes = Vec::with_capacity(4 * d);
    for _ in 0..d {
        bytes.extend_from_slice(&value.to_le_bytes());
    }
    Message {
        bytes,
        bits: 32 * d as u64,
    }
}

// --- phase A: hostile events -----------------------------------------

/// Duplicate reports: the second report from the same client must be
/// refused with a typed error naming the duplicate, and the round's
/// first report still closes (partial) at its deadline.
fn run_dups(addr: &str, cfg: &Config, t: &mut Tally) {
    let spec = cfg.spec(2, CodecSpec::Lq { q: 64 });
    for i in 0..DUPS {
        let cohort = 201 + i;
        let ones = vec![1.0; cfg.d];
        let msg = honest_message(&spec, 0, 0, &ones);
        // First report parks (n = 2); a 400 ms deadline closes it.
        let mut parked = connect(addr);
        let req = Request::Report {
            cohort,
            round: 0,
            client: 0,
            spec,
            deadline_ms: 400,
            msg: msg.clone(),
        };
        write_request(&mut parked, &req).expect("chaos: write parked report");
        // Wait until the server has folded it — the report below must
        // deterministically be the *second* arrival.
        loop {
            let stats = fetch_stats(addr, Duration::from_secs(10)).expect("chaos: health");
            if stats.iter().any(|s| s.cohort == cohort && s.reports == 1) {
                break;
            }
            thread::sleep(Duration::from_millis(2));
        }
        // Same (cohort, round, client) again: a typed rejection.
        match raw_report(addr, cohort, 0, 0, &spec, 400, msg) {
            Response::Error(reason) => {
                assert!(reason.contains("duplicate"), "chaos: dup reason: {reason}");
                t.dup_rejected += 1;
            }
            other => panic!("chaos: duplicate must be rejected, got {other:?}"),
        }
        // The parked stream is answered with the k=1 partial mean.
        match read_response(&mut parked).expect("chaos: parked response") {
            Response::Estimate { received, partial, .. } => {
                assert_eq!((received, partial), (1, true), "chaos: dup round closes k=1");
            }
            other => panic!("chaos: parked stream expected Estimate, got {other:?}"),
        }
    }
}

/// NaN payloads (float hygiene) and implausibly-far payloads (distance
/// filter): both decode cleanly but are quarantined before any fold.
fn run_poison(addr: &str, cfg: &Config, t: &mut Tally) {
    let spec = cfg.spec(2, CodecSpec::Full);
    for i in 0..NANS {
        let cohort = 211 + i;
        match raw_report(addr, cohort, 0, 0, &spec, 150, full_payload(cfg.d, f32::NAN)) {
            Response::Error(reason) => {
                assert!(reason.contains("quarantined"), "chaos: NaN reason: {reason}");
                t.nan_quarantined += 1;
            }
            other => panic!("chaos: NaN payload must be quarantined, got {other:?}"),
        }
    }
    for i in 0..FARS {
        let cohort = 221 + i;
        // Finite but ~1e30: no in-spec input with ‖x‖∞ ≤ y can decode
        // anywhere near this under any cohort codec.
        match raw_report(addr, cohort, 0, 0, &spec, 150, full_payload(cfg.d, 1.0e30)) {
            Response::Error(reason) => {
                assert!(reason.contains("quarantined"), "chaos: far reason: {reason}");
                t.far_quarantined += 1;
            }
            other => panic!("chaos: far payload must be quarantined, got {other:?}"),
        }
    }
}

/// Truncated frames: an honest message with its last byte dropped (and
/// `bits` restated so the frame layer accepts it) no longer matches the
/// round's probe sizes — the screen sheds it before any decode.
fn run_truncs(addr: &str, cfg: &Config, t: &mut Tally) {
    let spec = cfg.spec(2, CodecSpec::Lq { q: 64 });
    for i in 0..TRUNCS {
        let cohort = 231 + i;
        let x = vec![-2.0; cfg.d];
        let mut msg = honest_message(&spec, 0, 0, &x);
        msg.bytes.pop().expect("chaos: non-empty message");
        msg.bits = 8 * msg.bytes.len() as u64;
        match raw_report(addr, cohort, 0, 0, &spec, 150, msg) {
            Response::Busy { retry_after_ms } => {
                assert!(retry_after_ms > 0, "chaos: shed carries a backoff hint");
                t.trunc_shed += 1;
            }
            other => panic!("chaos: truncated frame must be shed, got {other:?}"),
        }
    }
}

/// Oversize frames: a length prefix over the frame cap is refused at
/// the wire layer — the multi-GiB allocation it asks for never happens.
fn run_oversize(addr: &str, cfg: &Config, t: &mut Tally) {
    let spec = cfg.spec(2, CodecSpec::Lq { q: 64 });
    for _ in 0..OVERSIZE {
        let mut s = connect(addr);
        let mut buf = Vec::new();
        buf.extend_from_slice(&REQ_MAGIC.to_le_bytes());
        buf.push(0); // KIND_REPORT
        buf.extend_from_slice(&299u64.to_le_bytes()); // cohort
        buf.extend_from_slice(&0u64.to_le_bytes()); // round
        buf.extend_from_slice(&0u32.to_le_bytes()); // client
        buf.extend_from_slice(&(spec.n as u32).to_le_bytes());
        buf.extend_from_slice(&(spec.d as u32).to_le_bytes());
        buf.push(0); // Lq codec tag
        buf.extend_from_slice(&64u32.to_le_bytes()); // q
        buf.extend_from_slice(&spec.y.to_le_bytes());
        buf.extend_from_slice(&spec.seed.to_le_bytes());
        buf.extend_from_slice(&150u32.to_le_bytes()); // deadline_ms
        // Frame prefix claiming a payload far over MAX_FRAME_BYTES.
        buf.extend_from_slice(&0u64.to_le_bytes()); // bits
        buf.extend_from_slice(&u32::MAX.to_le_bytes()); // len: ~4 GiB
        s.write_all(&buf).expect("chaos: write oversize");
        match read_response(&mut s).expect("chaos: oversize response") {
            Response::Error(reason) => {
                assert!(reason.contains("frame"), "chaos: oversize reason: {reason}");
                t.oversize_rejected += 1;
            }
            other => panic!("chaos: oversize frame must error, got {other:?}"),
        }
    }
}

/// Garbage bytes: a stream that is not the protocol at all gets a typed
/// bad-magic error back.
fn run_garbage(addr: &str, t: &mut Tally) {
    for _ in 0..GARBAGE {
        let mut s = connect(addr);
        s.write_all(b"JUNKJUNKJUNK").expect("chaos: write garbage");
        match read_response(&mut s).expect("chaos: garbage response") {
            Response::Error(reason) => {
                assert!(reason.contains("magic"), "chaos: garbage reason: {reason}");
                t.garbage_rejected += 1;
            }
            other => panic!("chaos: garbage magic must error, got {other:?}"),
        }
    }
}

/// Slow loris: a valid preamble, then one byte per drip. The
/// connection-lifetime deadline must cut it off; the only assertion is
/// survival (the drip ends and the service keeps answering honest
/// traffic) — exact timing is the server's business, not the seed's.
fn run_loris(addr: &str, t: &mut Tally) {
    let start = Instant::now();
    let mut s = connect(addr);
    let mut preamble = REQ_MAGIC.to_le_bytes().to_vec();
    preamble.push(0); // KIND_REPORT — keeps the header parser hungry.
    let _ = s.write_all(&preamble);
    for _ in 0..200u32 {
        if s.write_all(&[0u8]).is_err() || s.flush().is_err() {
            break; // the deadline fired and the server hung up
        }
        thread::sleep(Duration::from_millis(30));
    }
    assert!(
        start.elapsed() < Duration::from_secs(30),
        "chaos: loris outlived every reasonable connection deadline"
    );
    t.loris_survived = 1;
}

/// Rate flood: [`FLOODS`] serial reports from one reporter against an
/// n = 1 cohort. Under the burst-2/no-refill limit the first completes
/// the round, the second is answered late from the cache, and the rest
/// are shed with `Busy` — exactly `FLOODS - 2` sheds, deterministic.
fn run_flood(addr: &str, cfg: &Config, t: &mut Tally) {
    let spec = cfg.spec(1, CodecSpec::Lq { q: 64 });
    let cohort = 241;
    let halves = vec![0.5; cfg.d];
    let msg = honest_message(&spec, 0, 0, &halves);
    for i in 0..FLOODS {
        match raw_report(addr, cohort, 0, 0, &spec, 60_000, msg.clone()) {
            Response::Estimate { .. } => {
                assert!(i < RATE_BURST as u64, "chaos: flood report {i} got past the bucket");
            }
            Response::Busy { .. } => {
                assert!(i >= RATE_BURST as u64, "chaos: flood report {i} shed too early");
                t.flood_shed += 1;
            }
            other => panic!("chaos: flood report {i} got {other:?}"),
        }
    }
}

// --- phase B: honest cohorts -----------------------------------------

/// Honest input for `(cohort index, client)`: seeded uniforms in
/// `[-y/2, y/2]` — comfortably inside the distance screen's envelope.
fn honest_input(cfg: &Config, cohort_idx: usize, client: usize) -> Vec<f64> {
    let mut rng = Rng::new(hash2(hash2(cfg.seed, cohort_idx as u64), client as u64));
    (0..cfg.d).map(|_| (rng.next_f64() - 0.5) * cfg.y).collect()
}

/// Fold the honest reports through a plain in-process table — the
/// estimate the service must reproduce bit for bit. n = 2 folds
/// commute bitwise, so the service's arrival order cannot differ.
fn reference_estimate(spec: &CohortSpec, key: CohortKey, inputs: &[Vec<f64>]) -> Vec<f64> {
    let mut table = CohortTable::new();
    let mut estimate = None;
    for (c, x) in inputs.iter().enumerate() {
        let msg = honest_message(spec, key.round, c, x);
        match table.submit(key, spec, c, &msg, 0, 60_000) {
            Submit::Pending { .. } => {}
            Submit::Complete(r) => estimate = Some(r.estimate),
            other => panic!("chaos: reference fold got {other:?}"),
        }
    }
    estimate.expect("chaos: reference round must close")
}

/// Run every honest cohort (n = 2 concurrent clients each) and check
/// the service's estimate is bit-identical to the local fold. Returns
/// the exact-round count and a digest over all estimates.
fn run_honest(addr: &str, cfg: &Config) -> (usize, u64) {
    let spec = cfg.spec(2, CodecSpec::Lq { q: 64 });
    let mut exact = 0;
    let mut digest = cfg.seed;
    for idx in 0..cfg.honest_cohorts {
        let cohort = COHORT_BASE + 1 + idx as u64;
        let key = CohortKey { cohort, round: 1 };
        let inputs: Vec<Vec<f64>> = (0..2).map(|c| honest_input(cfg, idx, c)).collect();
        let want = reference_estimate(&spec, key, &inputs);
        let mut handles = Vec::new();
        for (c, x) in inputs.iter().enumerate() {
            let addr = addr.to_string();
            let x = x.clone();
            handles.push(thread::spawn(move || {
                report_round(&addr, cohort, 1, c, &spec, &x, 60_000, Duration::from_secs(30))
                    .expect("chaos: honest report")
            }));
        }
        for h in handles {
            let out = h.join().expect("chaos: honest client thread");
            assert_eq!(
                (out.received, out.expected, out.partial),
                (2, 2, false),
                "chaos: honest round must close k-of-k"
            );
            assert_eq!(out.estimate, want, "chaos: service estimate differs from the local fold");
        }
        for &v in &want {
            digest = hash2(digest, v.to_bits());
        }
        exact += 1;
    }
    (exact, digest)
}

// --- phase C: accounting ---------------------------------------------

/// Sum the harness's cohorts' ledgers from the health endpoint.
fn account(addr: &str) -> (u64, u64, u64) {
    let stats = fetch_stats(addr, Duration::from_secs(10)).expect("chaos: health");
    let mut shed = 0;
    let mut quarantined = 0;
    let mut resident = 0;
    for s in &stats {
        if (COHORT_BASE..COHORT_END).contains(&s.cohort) {
            shed += s.shed;
            quarantined += s.quarantined;
            resident += s.resident_bytes;
        }
    }
    (shed, quarantined, resident)
}

/// The hardened `ServeOpts` the self-hosted run uses — external runs
/// must start `dme serve` with the matching CLI knobs for the tallies
/// to line up.
pub fn hardened_opts() -> ServeOpts {
    ServeOpts {
        read_timeout: Duration::from_millis(200),
        conn_deadline: Duration::from_millis(600),
        screen: ScreenMode::Distance,
        max_conns: 32,
        max_open_rounds: 64,
        max_open_cohorts: 64,
        max_resident_bytes: RESIDENT_BUDGET,
        rate_limit: Some(RateLimit {
            burst: RATE_BURST,
            per_sec: 0.0,
        }),
        retry_after_ms: 25,
        ..ServeOpts::default()
    }
}

/// Run the chaos workload and return the report. Panics (failing the
/// run) on any broken invariant — this harness *is* the assertion.
pub fn run(opts: &ExpOpts) -> String {
    let cfg = Config::from_opts(opts);
    // Self-host unless pointed at an external server.
    let (addr, server) = match &opts.addr {
        Some(a) => (a.clone(), None),
        None => {
            let listener = TcpListener::bind("127.0.0.1:0").expect("chaos: bind");
            let addr = listener.local_addr().expect("chaos: local addr").to_string();
            let h = thread::Builder::new()
                .name("dme-chaos-serve".into())
                .spawn(move || serve(listener, hardened_opts()).expect("chaos: serve"))
                .expect("chaos: spawn server");
            (addr, Some(h))
        }
    };

    let mut t = Tally::default();
    run_dups(&addr, &cfg, &mut t);
    run_poison(&addr, &cfg, &mut t);
    run_truncs(&addr, &cfg, &mut t);
    run_oversize(&addr, &cfg, &mut t);
    run_garbage(&addr, &mut t);
    run_loris(&addr, &mut t);
    run_flood(&addr, &cfg, &mut t);
    let (exact, digest) = run_honest(&addr, &cfg);

    // Accounting: the service's ledgers must match the seed's
    // predictions exactly — every shed and quarantined report shows up,
    // nothing else does, and no accumulator bytes stay resident.
    let expected_shed = TRUNCS + (FLOODS - RATE_BURST as u64);
    let expected_quarantined = NANS + FARS;
    assert_eq!(t.trunc_shed + t.flood_shed, expected_shed, "chaos: event sheds");
    assert_eq!(t.nan_quarantined + t.far_quarantined, expected_quarantined, "chaos: quarantines");
    let (shed, quarantined, resident) = account(&addr);
    assert_eq!(shed, expected_shed, "chaos: shed ledger mismatch");
    assert_eq!(quarantined, expected_quarantined, "chaos: quarantine ledger mismatch");
    assert_eq!(resident, 0, "chaos: resident accumulator bytes leaked");

    request_shutdown(&addr, Duration::from_secs(10)).expect("chaos: shutdown");
    let summary: Option<ServeSummary> = server.map(|h| h.join().expect("chaos: server thread"));

    let mut out = String::new();
    let _ = writeln!(out, "## chaos workload");
    let _ = writeln!(
        out,
        "chaos: addr={} ({})",
        addr,
        if opts.addr.is_some() { "external" } else { "self-hosted" }
    );
    let _ = writeln!(
        out,
        "chaos: seed={:#x} honest_cohorts={} clients_per=2 d={}",
        cfg.seed, cfg.honest_cohorts, cfg.d
    );
    let _ = writeln!(out, "chaos: honest_exact={exact}/{}", cfg.honest_cohorts);
    let _ = writeln!(out, "chaos: digest={digest:#018x}");
    let _ = writeln!(
        out,
        "chaos: dup_rejected={} oversize_rejected={} garbage_rejected={} loris_survived={}",
        t.dup_rejected, t.oversize_rejected, t.garbage_rejected, t.loris_survived
    );
    let _ = writeln!(
        out,
        "chaos: shed={shed} quarantined={quarantined} (expected shed={expected_shed} quarantined={expected_quarantined})"
    );
    let _ = writeln!(out, "chaos: resident_bytes={resident}");
    if let Some(s) = &summary {
        assert!(
            s.peak_resident_bytes <= RESIDENT_BUDGET,
            "chaos: peak resident {} over budget {}",
            s.peak_resident_bytes,
            RESIDENT_BUDGET
        );
        // The serve-side ledger agrees with the health-side one (the
        // summary also counts connection-cap sheds; none here).
        assert_eq!(s.shed, expected_shed, "chaos: summary shed mismatch");
        assert_eq!(s.quarantined, expected_quarantined, "chaos: summary quarantine mismatch");
        let _ = writeln!(
            out,
            "chaos: peak_resident_bytes={} budget={} rounds_completed={}",
            s.peak_resident_bytes, RESIDENT_BUDGET, s.rounds_completed
        );
    }
    let _ = writeln!(out, "chaos: ok");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The seed-determined report lines: everything except the addr
    /// line (ephemeral port) and the peak-resident line (a timing-free
    /// value in this serial harness, but not part of the seed's
    /// contract).
    fn tally_lines(report: &str) -> Vec<&str> {
        report
            .lines()
            .filter(|l| {
                l.starts_with("chaos:") && !l.contains("addr=") && !l.contains("peak_resident")
            })
            .collect()
    }

    /// Two self-hosted runs under the same seed produce identical
    /// tallies, digests and verdicts — the determinism CI relies on.
    #[test]
    fn chaos_is_deterministic_under_a_fixed_seed() {
        let opts = ExpOpts::fast();
        let a = run(&opts);
        let b = run(&opts);
        assert!(a.contains("chaos: ok"), "run must pass its own assertions:\n{a}");
        assert_eq!(tally_lines(&a), tally_lines(&b), "seeded runs must match");
    }

    /// The seed's predicted ledgers appear verbatim in the report.
    #[test]
    fn chaos_report_carries_the_expected_tallies() {
        let report = run(&ExpOpts::fast());
        assert!(report.contains("chaos: honest_exact=2/2"), "{report}");
        assert!(
            report.contains("chaos: shed=8 quarantined=4 (expected shed=8 quarantined=4)"),
            "{report}"
        );
        assert!(report.contains("chaos: resident_bytes=0"), "{report}");
        assert!(
            report
                .contains("chaos: dup_rejected=2 oversize_rejected=2 garbage_rejected=2 loris_survived=1"),
            "{report}"
        );
    }
}
