//! Experiment 1 (Figures 1–2): norms relevant to quantization schemes.
//!
//! Least-squares on two machines; iterations use the *full* (unquantized)
//! gradient, and per iteration we record the four quantities of §9.2:
//! `‖g₀−g₁‖₂`, `‖g₀−g₁‖∞`, `‖g₀‖₂`, and `max(g₀)−min(g₀)`.
//! Expected shape: the two distance norms sit far below the two
//! norm-based quantities — inputs are not centered at the origin.

use super::{mean_trace, render_series, ExpOpts, Series};
use crate::data::gen_lsq;
use crate::opt::dist_gd::{run_distributed_gd, GdAggregation, GdConfig};

pub fn run(opts: &ExpOpts) -> String {
    let mut out = String::from("# E1 — norms relevant to quantization (Figs 1-2)\n\n");
    for (fig, samples) in [("Fig 1 (fewer samples)", 8192), ("Fig 2 (more samples)", 32768)] {
        let s = opts.samples(samples);
        let iters = opts.iters(50);
        let mut d2 = Vec::new();
        let mut dinf = Vec::new();
        let mut n2 = Vec::new();
        let mut rng_ = Vec::new();
        for seed in 0..opts.seeds as u64 {
            let ds = gen_lsq(s, 100, seed * 10);
            let cfg = GdConfig {
                n_machines: 2,
                lr: 0.1,
                iters,
                seed,
                ..Default::default()
            };
            let t = run_distributed_gd(&ds, &GdAggregation::Exact, &cfg);
            d2.push(t.grad_dist_2);
            dinf.push(t.grad_dist_inf);
            n2.push(t.grad_norm_2);
            rng_.push(t.grad_range);
        }
        let series = vec![
            Series {
                label: "|g0-g1|_2".into(),
                values: mean_trace(&d2),
            },
            Series {
                label: "|g0-g1|_inf".into(),
                values: mean_trace(&dinf),
            },
            Series {
                label: "|g0|_2".into(),
                values: mean_trace(&n2),
            },
            Series {
                label: "max-min(g0)".into(),
                values: mean_trace(&rng_),
            },
        ];
        out += &render_series(
            &format!("{fig}: S={s}, d=100, n=2, mean of {} seeds", opts.seeds),
            "iter",
            &series,
            12,
        );
        // Headline check printed inline.
        let md2 = series[0].values.iter().sum::<f64>() / series[0].values.len() as f64;
        let mn2 = series[2].values.iter().sum::<f64>() / series[2].values.len() as f64;
        out += &format!(
            "shape check: mean |g0-g1|_2 / |g0|_2 = {:.3} (paper: well below 1)\n\n",
            md2 / mn2
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e1_distance_norms_below_input_norms() {
        let r = run(&ExpOpts::fast());
        assert!(r.contains("Fig 1"));
        assert!(r.contains("Fig 2"));
        // Extract the shape checks and assert the paper's claim holds.
        for line in r.lines().filter(|l| l.starts_with("shape check")) {
            let ratio: f64 = line
                .split('=')
                .nth(1)
                .unwrap()
                .split_whitespace()
                .next()
                .unwrap()
                .parse()
                .unwrap();
            assert!(ratio < 0.7, "distance/norm ratio {ratio} not < 0.7");
        }
    }
}
