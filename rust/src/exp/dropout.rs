//! Dropout vs estimation error — the robustness face of the k-of-n
//! partial-round plane (see `coordinator::api` §Straggler policy).
//!
//! Sweeps a seeded dropout rate over the star session for the paper's
//! codecs (LQSGD, RLQSGD, D4) against baselines, measuring
//! `E‖EST − μ‖²` where `μ` is the mean over **all** `n` inputs — so the
//! reported error combines quantization noise with the bias of the
//! `1/k`-renormalized partial mean over the surviving reports. Every
//! codec sees the *same* fault schedule (one [`FaultPlan`] seed per
//! rate, shared session seed ⇒ identical leaders, identical drop sets
//! per round), so columns are comparable head to head. Expected shape:
//! at rate 0 the error is pure quantization noise; as the rate grows the
//! partial-mean bias dominates and every codec degrades toward the same
//! floor — compression choice stops mattering once dropout does.
//!
//! Alongside the text report the sweep emits `BENCH_dropout.json`
//! (schema 1: one case per codec × rate with `err2` and the mean
//! surviving-report count `k_mean`), the same machine-readable plumbing
//! the bench targets use, so CI can assert the grid parses.

use super::{render_table, ExpOpts};
use crate::config::Json;
use crate::coordinator::{CodecSpec, DmeBuilder, StragglerPolicy};
use crate::linalg::{dist2, mean_vecs};
use crate::net::faulty::FaultPlan;
use crate::rng::{hash2, Rng};
use std::collections::BTreeMap;
use std::time::Duration;

/// Dropout rates swept (fraction of machine-rounds whose sends are
/// silenced).
const RATES: &[f64] = &[0.0, 0.1, 0.3, 0.5];

/// Per-round receive deadline. Healthy in-process reports arrive in
/// microseconds, so this only prices rounds that actually lose reports;
/// it must merely dwarf scheduler jitter for the outcome to be
/// deterministic.
const DEADLINE: Duration = Duration::from_millis(40);

fn codecs() -> Vec<CodecSpec> {
    vec![
        CodecSpec::Lq { q: 64 },
        CodecSpec::Rlq { q: 64 },
        CodecSpec::D4 { q: 64 },
        CodecSpec::QsgdLinf { q: 64 },
        CodecSpec::Hadamard { q: 64 },
        CodecSpec::Full,
    ]
}

/// One cell of the sweep: mean squared error vs the full mean, and the
/// mean number of surviving reports, over `trials` rounds.
fn run_cell(
    spec: CodecSpec,
    rate: f64,
    rate_idx: usize,
    inputs: &[Vec<f64>],
    mu: &[f64],
    y: f64,
    trials: usize,
) -> (f64, f64) {
    let n = inputs.len();
    let d = inputs[0].len();
    // One plan seed per rate: every codec replays the same drop sets.
    let plan = FaultPlan::dropout(hash2(0xD20, rate_idx as u64), rate);
    let policy = StragglerPolicy::deterministic(DEADLINE, 1, 0xD20);
    let mut sess = DmeBuilder::new(n, d)
        .codec(spec)
        .seed(7)
        .fault_plan(plan)
        .build();
    let mut err2 = 0.0;
    let mut k_sum = 0usize;
    let mut done = 0usize;
    for _ in 0..trials {
        // k_min = 1 and the leader always holds its own report, so the
        // quorum cannot fail; skip defensively if it ever does.
        let Ok(out) = sess.round_partial_with_y(inputs, y, &policy) else {
            continue;
        };
        err2 += dist2(&out.estimate, mu).powi(2);
        k_sum += out.participants;
        done += 1;
    }
    let done = done.max(1);
    (err2 / done as f64, k_sum as f64 / done as f64)
}

pub fn run(opts: &ExpOpts) -> String {
    let n = 10;
    let d = 64;
    let y = 1.0;
    let trials = ((8.0 * opts.scale).ceil() as usize).clamp(2, 16);

    // Fixed well-spread inputs; μ is the mean over all n machines, so
    // dropped reports show up as error, not as a moved target.
    let mut rng = Rng::new(42);
    let inputs: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..d).map(|_| 120.0 + rng.uniform(-y / 2.0, y / 2.0)).collect())
        .collect();
    let mu = mean_vecs(&inputs);

    let mut out = String::from(
        "# Dropout — estimation error vs seeded dropout rate (k-of-n partial rounds)\n\n",
    );
    let mut rows = Vec::new();
    let mut cases: Vec<Json> = Vec::new();
    let mut k_means: Vec<f64> = vec![0.0; RATES.len()];
    for spec in codecs() {
        let mut row = vec![spec.label()];
        for (ri, &rate) in RATES.iter().enumerate() {
            let (err2, k_mean) = run_cell(spec, rate, ri, &inputs, &mu, y, trials);
            row.push(format!("{err2:.3e}"));
            // The drop schedule is codec-independent: every codec sees
            // the same k per round, so remembering the last is enough.
            k_means[ri] = k_mean;
            let mut case = BTreeMap::new();
            case.insert("name".to_string(), Json::Str(format!("{}@{rate}", spec.label())));
            case.insert("codec".to_string(), Json::Str(spec.label()));
            case.insert("rate".to_string(), Json::Num(rate));
            case.insert("err2".to_string(), Json::Num(err2));
            case.insert("k_mean".to_string(), Json::Num(k_mean));
            cases.push(Json::Obj(case));
        }
        rows.push(row);
    }

    let mut headers: Vec<String> = vec!["codec".to_string()];
    for (ri, rate) in RATES.iter().enumerate() {
        headers.push(format!("err2@{rate} (k̄={:.1})", k_means[ri]));
    }
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    out += &render_table(
        &format!(
            "n={n}, d={d}, y={y}, {trials} rounds per cell; one fault seed per rate \
             (identical drop sets across codecs); 1/k partial mean vs full-n mean"
        ),
        &header_refs,
        &rows,
    );
    out += "expected: rate 0 is pure quantization noise; as dropout grows the partial-mean \
            bias dominates and all codecs converge to the same error floor.\n";

    // Machine-readable grid, bench-plumbing style (`BENCH_dropout.json`
    // in the working directory, like every bench target's summary).
    // Gated on an out dir so `cargo test` never litters the tree.
    if opts.out_dir.is_some() {
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("dropout".to_string()));
        root.insert("schema".to_string(), Json::Num(1.0));
        root.insert("cases".to_string(), Json::Arr(cases));
        let path = "BENCH_dropout.json";
        if std::fs::write(path, format!("{}\n", Json::Obj(root))).is_ok() {
            eprintln!("[saved {path}: {} cases]", codecs().len() * RATES.len());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dropout_grid_runs_and_degrades_with_rate() {
        let opts = ExpOpts {
            scale: 0.25,
            seeds: 1,
            out_dir: None,
            batch: 1,
            addr: None,
        };
        let r = run(&opts);
        // One row per codec, one error column per rate.
        for spec in codecs() {
            assert!(r.contains(&spec.label()), "missing row for {}", spec.label());
        }
        let lq_row: Vec<f64> = r
            .lines()
            .find(|l| l.contains("LQSGD(q=64)") && !l.contains("RLQSGD"))
            .expect("LQ row")
            .split_whitespace()
            .filter_map(|tok| tok.parse::<f64>().ok())
            .collect();
        assert_eq!(lq_row.len(), RATES.len(), "{r}");
        // Dropping half the reports must cost orders of magnitude more
        // than quantization noise alone (the partial-mean bias).
        assert!(
            lq_row[RATES.len() - 1] > lq_row[0],
            "error should grow with dropout: {lq_row:?}\n{r}"
        );
    }
}
