//! Experiment harness — regenerates every figure and table of the paper's
//! Section 9 (see DESIGN.md §3 for the index).
//!
//! Each `eN::run(&ExpOpts)` returns a plain-text report with the same
//! rows/series the paper plots; `dme exp N` prints it and writes
//! `results/eN.txt`. Absolute values differ from the paper's testbed; the
//! *shape* (who wins, by what factor, where crossovers fall) is the
//! reproduction target.
//!
//! §Perf: these sweeps spend most of their codec time in the *comparator*
//! codecs (QSGD, Suresh–Hadamard, EF-Sign, …), not the paper's own — every
//! experiment pits them head to head. Since the baseline suite rides the
//! blocked data plane (`quant::baselines` §Perf: fused block encode fed by
//! bulk uniforms, fused fold kernels, all bit-identical to the seed scalar
//! loops), the harness picks the win up automatically through the session's
//! `encode_into`/`decode_accumulate_into` calls — reports are unchanged
//! byte for byte, only wall-clock moves (`baseline_bench` quantifies it;
//! `experiments_bench` shows it end to end).

pub mod ablation;
pub mod dropout;
pub mod e1_norms;
pub mod e2_variance;
pub mod e3_convergence;
pub mod e4_sublinear;
pub mod e5_cpusmall;
pub mod e6_local_sgd;
pub mod e7_nn;
pub mod e8_power;
pub mod tradeoff;
pub mod workload;

use std::fmt::Write as _;

/// Options shared by the experiment drivers.
#[derive(Clone, Debug)]
pub struct ExpOpts {
    /// Scale factor: 1.0 = paper-size workloads; smaller for smoke runs.
    pub scale: f64,
    pub seeds: usize,
    pub out_dir: Option<String>,
    /// Batched-round width for session-driven experiments (CLI
    /// `batch=`): `tradeoff` groups its trials into
    /// `round_batch_with_y` calls of this many slots — bit-identical to
    /// the sequential trials, one worker crossing per group. 1 keeps the
    /// sequential loop.
    pub batch: usize,
    /// Address of an already-running `dme serve` for service-driven
    /// experiments (CLI `addr=`); `None` = self-host an in-process
    /// server (the chaos harness configures its own hardened one).
    pub addr: Option<String>,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts {
            scale: 1.0,
            seeds: 5,
            out_dir: Some("results".to_string()),
            batch: 1,
            addr: None,
        }
    }
}

impl ExpOpts {
    pub fn fast() -> Self {
        ExpOpts {
            scale: 0.1,
            seeds: 2,
            out_dir: None,
            batch: 1,
            addr: None,
        }
    }

    /// Scale a sample count (power-of-two floor, min 64).
    pub fn samples(&self, full: usize) -> usize {
        (((full as f64) * self.scale) as usize).max(64)
    }

    /// Scale an iteration count (min 5).
    pub fn iters(&self, full: usize) -> usize {
        (((full as f64) * self.scale) as usize).max(5)
    }
}

/// A labelled series (one figure line).
#[derive(Clone, Debug)]
pub struct Series {
    pub label: String,
    pub values: Vec<f64>,
}

/// Render aligned series as a column table, one row per iteration
/// (sub-sampled to ≤ `max_rows` rows for readability).
pub fn render_series(title: &str, x_label: &str, series: &[Series], max_rows: usize) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "## {title}");
    let n = series.iter().map(|s| s.values.len()).max().unwrap_or(0);
    let step = (n / max_rows.max(1)).max(1);
    let _ = write!(out, "{:>6}", x_label);
    for s in series {
        let _ = write!(out, "  {:>18}", truncate(&s.label, 18));
    }
    let _ = writeln!(out);
    let mut i = 0;
    while i < n {
        let _ = write!(out, "{i:>6}");
        for s in series {
            match s.values.get(i) {
                Some(v) => {
                    let _ = write!(out, "  {v:>18.6e}");
                }
                None => {
                    let _ = write!(out, "  {:>18}", "-");
                }
            }
        }
        let _ = writeln!(out);
        if i + step > n - 1 && i != n - 1 {
            i = n - 1; // always include the last row
        } else {
            i += step;
        }
    }
    out.push('\n');
    out
}

/// Render a simple key/value row table.
pub fn render_table(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "## {title}");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (w, cell) in widths.iter_mut().zip(row) {
            *w = (*w).max(cell.len());
        }
    }
    for (h, w) in headers.iter().zip(&widths) {
        let _ = write!(out, "{h:>w$}  ", w = w);
    }
    let _ = writeln!(out);
    for row in rows {
        for (cell, w) in row.iter().zip(&widths) {
            let _ = write!(out, "{cell:>w$}  ", w = w);
        }
        let _ = writeln!(out);
    }
    out.push('\n');
    out
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        s[..n].to_string()
    }
}

/// Element-wise mean of several equally-long traces.
pub fn mean_trace(traces: &[Vec<f64>]) -> Vec<f64> {
    if traces.is_empty() {
        return Vec::new();
    }
    let n = traces.iter().map(|t| t.len()).min().unwrap();
    (0..n)
        .map(|i| traces.iter().map(|t| t[i]).sum::<f64>() / traces.len() as f64)
        .collect()
}

/// Write a report to `results/<name>.txt` when an out dir is configured.
pub fn save_report(opts: &ExpOpts, name: &str, report: &str) {
    if let Some(dir) = &opts.out_dir {
        let _ = std::fs::create_dir_all(dir);
        let path = format!("{dir}/{name}.txt");
        if std::fs::write(&path, report).is_ok() {
            eprintln!("[saved {path}]");
        }
    }
}

/// Run an experiment by id ("1".."8", "tradeoff", "ablation",
/// "dropout"); returns the report.
pub fn run(id: &str, opts: &ExpOpts) -> Option<String> {
    let report = match id {
        "1" => e1_norms::run(opts),
        "2" => e2_variance::run(opts),
        "3" => e3_convergence::run(opts),
        "4" => e4_sublinear::run(opts),
        "5" => e5_cpusmall::run(opts),
        "6" => e6_local_sgd::run(opts),
        "7" => e7_nn::run(opts),
        "8" => e8_power::run(opts),
        "tradeoff" | "9" => tradeoff::run(opts),
        "ablation" => ablation::run(opts),
        "dropout" => dropout::run(opts),
        "chaos" => workload::run(opts),
        _ => return None,
    };
    let name = match id {
        "tradeoff" | "9" => "tradeoff".to_string(),
        "ablation" => "ablation".to_string(),
        "dropout" => "dropout".to_string(),
        "chaos" => "chaos".to_string(),
        _ => format!("e{id}"),
    };
    save_report(opts, &name, &report);
    Some(report)
}

pub const ALL_IDS: &[&str] = &[
    "1", "2", "3", "4", "5", "6", "7", "8", "tradeoff", "ablation", "dropout", "chaos",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_series_includes_last_row() {
        let s = vec![Series {
            label: "a".into(),
            values: (0..100).map(|i| i as f64).collect(),
        }];
        let r = render_series("t", "it", &s, 10);
        assert!(r.contains("99"));
        assert!(r.lines().count() < 20);
    }

    #[test]
    fn mean_trace_averages() {
        let m = mean_trace(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m, vec![2.0, 3.0]);
    }

    #[test]
    fn render_table_aligns() {
        let t = render_table(
            "T",
            &["method", "acc"],
            &[vec!["LQSGD".into(), "0.95".into()]],
        );
        assert!(t.contains("LQSGD"));
        assert!(t.contains("acc"));
    }
}
