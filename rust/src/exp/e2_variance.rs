//! Experiment 2 (Figures 3–4): output variance of quantization methods.
//!
//! Distributed SGD on two machines at 3 bits/coordinate (q = 8): each
//! iteration the quantized batch gradients are exchanged and averaged;
//! we plot `‖EST − ∇‖²` per iteration for every method, plus the *input*
//! variance `mean_i ‖g_i − ∇‖²`. Expected shape: LQSGD is the only method
//! below the input variance (it achieves variance reduction); norm-based
//! schemes can exceed it.

use super::{mean_trace, render_series, ExpOpts, Series};
use crate::coordinator::CodecSpec;
use crate::data::gen_lsq;
use crate::opt::dist_gd::{run_distributed_gd, GdAggregation, GdConfig};

pub fn methods_q(q: u32) -> Vec<(String, GdAggregation)> {
    vec![
        (
            format!("LQSGD(q={q})"),
            GdAggregation::AllToAll(CodecSpec::Lq { q }),
        ),
        (
            format!("RLQSGD(q={q})"),
            GdAggregation::AllToAll(CodecSpec::Rlq { q }),
        ),
        (
            format!("QSGD-L2(q={q})"),
            GdAggregation::AllToAll(CodecSpec::QsgdL2 { q }),
        ),
        (
            format!("QSGD-Linf(q={q})"),
            GdAggregation::AllToAll(CodecSpec::QsgdLinf { q }),
        ),
        (
            format!("Hadamard(q={q})"),
            GdAggregation::AllToAll(CodecSpec::Hadamard { q }),
        ),
    ]
}

/// Input variance trace: mean_i ‖g_i − ∇_full‖² under the *exact* GD
/// trajectory (the reference the paper compares output variance against).
fn input_variance(samples: usize, iters: usize, seed: u64) -> Vec<f64> {
    let ds = gen_lsq(samples, 100, seed * 10);
    let cfg = GdConfig {
        n_machines: 2,
        lr: 0.8,
        iters,
        seed,
        ..Default::default()
    };
    // Re-derive per-iteration input variance from a custom loop: reuse the
    // Exact driver's recorded ‖g0−g1‖₂ as a proxy is not exact, so
    // recompute directly here.
    let mut w = vec![0.0; ds.dim()];
    let mut rng = crate::rng::Rng::new(crate::rng::hash2(seed, 0xDA7A));
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let parts = ds.partition(2, &mut rng);
        let g0 = ds.batch_gradient(&w, &parts[0]);
        let g1 = ds.batch_gradient(&w, &parts[1]);
        let full = ds.full_gradient(&w);
        let v = (crate::linalg::dist2(&g0, &full).powi(2)
            + crate::linalg::dist2(&g1, &full).powi(2))
            / 2.0;
        out.push(v);
        let est = crate::linalg::mean_vecs(&[g0, g1]);
        crate::linalg::axpy(&mut w, -cfg.lr, &est);
        let _ = &cfg;
    }
    out
}

pub fn run(opts: &ExpOpts) -> String {
    let q = 8;
    let mut out = String::from("# E2 — output variance at 3 bits/coordinate (Figs 3-4)\n\n");
    for (fig, samples) in [("Fig 3 (fewer samples)", 8192), ("Fig 4 (more samples)", 32768)] {
        let s = opts.samples(samples);
        let iters = opts.iters(40);
        let mut series = Vec::new();
        // Input variance reference line.
        let inp: Vec<Vec<f64>> = (0..opts.seeds as u64)
            .map(|seed| input_variance(s, iters, seed))
            .collect();
        series.push(Series {
            label: "input var".into(),
            values: mean_trace(&inp),
        });
        for (label, agg) in methods_q(q) {
            let traces: Vec<Vec<f64>> = (0..opts.seeds as u64)
                .map(|seed| {
                    let ds = gen_lsq(s, 100, seed * 10);
                    let cfg = GdConfig {
                        n_machines: 2,
                        lr: 0.8,
                        iters,
                        seed,
                        y0: 1.0,
                        ..Default::default()
                    };
                    run_distributed_gd(&ds, &agg, &cfg).output_err2
                })
                .collect();
            series.push(Series {
                label,
                values: mean_trace(&traces),
            });
        }
        out += &render_series(
            &format!("{fig}: S={s}, d=100, q={q}, mean of {} seeds", opts.seeds),
            "iter",
            &series,
            12,
        );
        // Shape check: LQSGD mean variance below input variance.
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        let tail = |v: &[f64]| mean(&v[v.len() / 2..]);
        let inp_m = tail(&series[0].values);
        let lq_m = tail(&series[1].values);
        let qs_m = tail(&series[3].values);
        out += &format!(
            "shape check (2nd-half means): LQSGD {:.3e} < input {:.3e} ; QSGD-L2 {:.3e}\n\n",
            lq_m, inp_m, qs_m
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e2_lqsgd_achieves_variance_reduction() {
        let opts = ExpOpts {
            scale: 0.25,
            seeds: 2,
            out_dir: None,
            batch: 1,
            addr: None,
        };
        let r = run(&opts);
        for line in r.lines().filter(|l| l.starts_with("shape check")) {
            // parse "LQSGD <a> < input <b> ; QSGD-L2 <c>"
            let nums: Vec<f64> = line
                .split_whitespace()
                .filter_map(|t| t.trim_end_matches(';').parse().ok())
                .collect();
            assert!(nums.len() >= 3, "line: {line}");
            let (lq, inp, qs) = (nums[0], nums[1], nums[2]);
            assert!(lq < inp, "LQSGD {lq} must beat input variance {inp}");
            assert!(lq < qs, "LQSGD {lq} must beat QSGD {qs}");
        }
    }
}
