//! Explicit SIMD lanes for the fused block kernels, behind the `simd`
//! cargo feature — with the scalar twins always compiled as the
//! bit-parity reference.
//!
//! # §Perf — dispatch design
//!
//! Every kernel here is a *pair*: a public `…_scalar` loop (the exact
//! arithmetic the seed paths performed, op for op) and a dispatching
//! wrapper of the same name that routes to an AVX2 `f64x4`/`u64x4` body
//! when three gates all pass:
//!
//! 1. the crate was built with `--features simd`,
//! 2. the target is `x86_64`,
//! 3. the CPU reports AVX2 at runtime (checked once, cached in an
//!    atomic — the shim costs one relaxed load per call thereafter).
//!
//! Otherwise the wrapper *is* the scalar twin. `std::simd` is still
//! nightly-only, so the lanes are written against stable
//! `core::arch::x86_64` intrinsics; on non-x86_64 targets the feature
//! compiles but stays inert (scalar everywhere).
//!
//! # Bit parity
//!
//! The vector bodies are chosen so every lane performs the *identical*
//! IEEE-754 operation sequence as the scalar twin on its element:
//! `vaddpd`/`vsubpd`/`vmulpd` are the same correctly-rounded f64
//! add/sub/mul per lane (no FMA contraction is ever introduced), and
//! `vroundpd` with `_MM_FROUND_TO_NEAREST_INT` is exactly
//! `f64::round_ties_even`. The one non-obvious kernel is
//! [`uniform_from_bits`], where AVX2 has no u64→f64 convert: the
//! magic-constant split (high 21 bits through 2⁸⁴, low 32 through 2⁵²)
//! reassembles any `x < 2⁵³` *exactly*, because every intermediate value
//! is representable — so it equals the scalar `as f64` cast bit for
//! bit. Integer kernels ([`pack_fields`], [`unpack_fields`]) are
//! shift/or/and, which have no rounding at all. Dispatched ≡ scalar is
//! pinned across widths, misaligned tails, `d = 1`, subnormals and
//! negative zero by `rust/tests/prop.rs` (`prop_simd_*`), and the
//! sessions that ride these kernels stay pinned to their scalar
//! references by the existing parity suites.
//!
//! Consumers: the FWHT butterfly layers ([`crate::quant::hadamard`]),
//! the lattice stochastic-rounding encode/decode stages
//! ([`crate::quant::lq`], [`crate::quant::d4`]), the bulk uniform
//! converter ([`crate::rng::Rng::fill_uniform`]) and the field
//! pack/unpack loops ([`crate::quant::bits`]).

/// True when the crate was compiled with SIMD lanes available for this
/// target (`--features simd` on x86_64).
pub fn compiled() -> bool {
    cfg!(all(feature = "simd", target_arch = "x86_64"))
}

/// True when calls are currently dispatching to the AVX2 lanes (feature
/// compiled in *and* the CPU supports AVX2).
pub fn active() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        avx2()
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        false
    }
}

/// Human-readable lane description for logs and bench headers.
pub fn lanes() -> &'static str {
    if active() {
        "avx2 f64x4"
    } else {
        "scalar"
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[inline]
fn avx2() -> bool {
    use std::sync::atomic::{AtomicU8, Ordering};
    // 0 = unknown, 1 = unavailable, 2 = available.
    static STATE: AtomicU8 = AtomicU8::new(0);
    match STATE.load(Ordering::Relaxed) {
        2 => true,
        1 => false,
        _ => {
            let yes = std::arch::is_x86_feature_detected!("avx2");
            STATE.store(if yes { 2 } else { 1 }, Ordering::Relaxed);
            yes
        }
    }
}

/// Routes `name(args…)` to the AVX2 body under the three dispatch gates,
/// else to `name_scalar`. Keeps the wrapper pairs honest and identical.
macro_rules! dispatch {
    ($avx:path, $scalar:ident, ($($arg:expr),*)) => {{
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        if avx2() {
            // SAFETY: the dispatch gate just verified AVX2 support.
            return unsafe { $avx($($arg),*) };
        }
        $scalar($($arg),*)
    }};
}

// ---------------------------------------------------------------------
// FWHT butterflies (hadamard.rs)
// ---------------------------------------------------------------------

/// One radix-2 butterfly half-layer: `(lo[j], hi[j]) ← (lo[j] + hi[j],
/// lo[j] − hi[j])`.
#[inline]
pub fn butterfly2(lo: &mut [f64], hi: &mut [f64]) {
    dispatch!(avx2_impl::butterfly2, butterfly2_scalar, (lo, hi))
}

/// Scalar reference for [`butterfly2`] (the seed's loop, verbatim).
pub fn butterfly2_scalar(lo: &mut [f64], hi: &mut [f64]) {
    debug_assert_eq!(lo.len(), hi.len());
    for (a, b) in lo.iter_mut().zip(hi) {
        let (u, v) = (*a, *b);
        *a = u + v;
        *b = u - v;
    }
}

/// Fused radix-4 butterfly over four equal-length stride slices — both
/// radix-2 stages in registers, identical add/sub associativity.
#[inline]
pub fn butterfly4(g0: &mut [f64], g1: &mut [f64], g2: &mut [f64], g3: &mut [f64]) {
    dispatch!(avx2_impl::butterfly4, butterfly4_scalar, (g0, g1, g2, g3))
}

/// Scalar reference for [`butterfly4`].
pub fn butterfly4_scalar(g0: &mut [f64], g1: &mut [f64], g2: &mut [f64], g3: &mut [f64]) {
    debug_assert!(g0.len() == g1.len() && g1.len() == g2.len() && g2.len() == g3.len());
    for j in 0..g0.len() {
        let (y0, y1, y2, y3) = (g0[j], g1[j], g2[j], g3[j]);
        // Stage h:
        let u0 = y0 + y1;
        let u1 = y0 - y1;
        let u2 = y2 + y3;
        let u3 = y2 - y3;
        // Stage 2h:
        g0[j] = u0 + u2;
        g1[j] = u1 + u3;
        g2[j] = u0 - u2;
        g3[j] = u1 - u3;
    }
}

/// Radix-2 butterfly with a constant scale fused into the stores (the
/// FWHT's final 1/√d layer).
#[inline]
pub fn butterfly2_scaled(lo: &mut [f64], hi: &mut [f64], scale: f64) {
    dispatch!(
        avx2_impl::butterfly2_scaled,
        butterfly2_scaled_scalar,
        (lo, hi, scale)
    )
}

/// Scalar reference for [`butterfly2_scaled`].
pub fn butterfly2_scaled_scalar(lo: &mut [f64], hi: &mut [f64], scale: f64) {
    debug_assert_eq!(lo.len(), hi.len());
    for (a, b) in lo.iter_mut().zip(hi) {
        let (u, v) = (*a, *b);
        *a = (u + v) * scale;
        *b = (u - v) * scale;
    }
}

/// Radix-2 butterfly with a per-element diagonal fused into the stores
/// (the inverse rotation's `sign[i]·norm` layer).
#[inline]
pub fn butterfly2_diag(lo: &mut [f64], hi: &mut [f64], dlo: &[f64], dhi: &[f64]) {
    dispatch!(
        avx2_impl::butterfly2_diag,
        butterfly2_diag_scalar,
        (lo, hi, dlo, dhi)
    )
}

/// Scalar reference for [`butterfly2_diag`].
pub fn butterfly2_diag_scalar(lo: &mut [f64], hi: &mut [f64], dlo: &[f64], dhi: &[f64]) {
    debug_assert!(lo.len() == hi.len() && lo.len() == dlo.len() && lo.len() == dhi.len());
    for j in 0..lo.len() {
        let (u, v) = (lo[j], hi[j]);
        lo[j] = (u + v) * dlo[j];
        hi[j] = (u - v) * dhi[j];
    }
}

// ---------------------------------------------------------------------
// Lattice quantize/decode stages (lq.rs, d4.rs)
// ---------------------------------------------------------------------

/// Offset-and-scale stage: `out[j] = (x[j] − off[j]) * inv` (the D4
/// bucket kernel's pre-quantization staging).
#[inline]
pub fn scale_offset(x: &[f64], off: &[f64], inv: f64, out: &mut [f64]) {
    dispatch!(avx2_impl::scale_offset, scale_offset_scalar, (x, off, inv, out))
}

/// Scalar reference for [`scale_offset`].
pub fn scale_offset_scalar(x: &[f64], off: &[f64], inv: f64, out: &mut [f64]) {
    debug_assert!(x.len() == off.len() && x.len() == out.len());
    for j in 0..out.len() {
        out[j] = (x[j] - off[j]) * inv;
    }
}

/// Rounded quantize stage: `out[j] = ((x[j] − off[j]) * inv)
/// .round_ties_even()` — the cubic-lattice nearest-index computation
/// (the `as i64` cast and color reduction stay scalar in the caller and
/// consume these exact f64s, so staging changes no bit).
#[inline]
pub fn quantize_scaled(x: &[f64], off: &[f64], inv: f64, out: &mut [f64]) {
    dispatch!(
        avx2_impl::quantize_scaled,
        quantize_scaled_scalar,
        (x, off, inv, out)
    )
}

/// Scalar reference for [`quantize_scaled`].
pub fn quantize_scaled_scalar(x: &[f64], off: &[f64], inv: f64, out: &mut [f64]) {
    debug_assert!(x.len() == off.len() && x.len() == out.len());
    for j in 0..out.len() {
        out[j] = ((x[j] - off[j]) * inv).round_ties_even();
    }
}

/// Lattice decode stage: `out[j] = ((reference[j] − off[j]) * inv_sq −
/// cf[j] * inv_q).round_ties_even()` — the per-coordinate congruence
/// solve of the lattice `decode_fold`, with `cf` the received colors
/// pre-converted to f64.
#[inline]
pub fn fold_decode_indices(
    reference: &[f64],
    off: &[f64],
    cf: &[f64],
    inv_sq: f64,
    inv_q: f64,
    out: &mut [f64],
) {
    dispatch!(
        avx2_impl::fold_decode_indices,
        fold_decode_indices_scalar,
        (reference, off, cf, inv_sq, inv_q, out)
    )
}

/// Scalar reference for [`fold_decode_indices`].
pub fn fold_decode_indices_scalar(
    reference: &[f64],
    off: &[f64],
    cf: &[f64],
    inv_sq: f64,
    inv_q: f64,
    out: &mut [f64],
) {
    debug_assert!(
        reference.len() == off.len() && reference.len() == cf.len() && reference.len() == out.len()
    );
    for j in 0..out.len() {
        out[j] = ((reference[j] - off[j]) * inv_sq - cf[j] * inv_q).round_ties_even();
    }
}

// ---------------------------------------------------------------------
// Bulk uniform conversion (rng.rs)
// ---------------------------------------------------------------------

/// The 53-bit uniform conversion: `out[j] = (words[j] >> 11) as f64 *
/// 2⁻⁵³` — [`crate::rng::Rng::fill_uniform`]'s conversion stage (the
/// xoshiro state recurrence itself is serial and stays in the caller).
#[inline]
pub fn uniform_from_bits(words: &[u64], out: &mut [f64]) {
    dispatch!(
        avx2_impl::uniform_from_bits,
        uniform_from_bits_scalar,
        (words, out)
    )
}

/// Scalar reference for [`uniform_from_bits`].
pub fn uniform_from_bits_scalar(words: &[u64], out: &mut [f64]) {
    const SCALE: f64 = 1.0 / (1u64 << 53) as f64;
    debug_assert_eq!(words.len(), out.len());
    for (o, &w) in out.iter_mut().zip(words) {
        *o = (w >> 11) as f64 * SCALE;
    }
}

// ---------------------------------------------------------------------
// Bit-field pack/unpack (bits.rs)
// ---------------------------------------------------------------------

/// OR-pack `vals` as consecutive `width`-bit fields starting at bit
/// `base` of a fresh accumulator word: returns `⊕ⱼ vals[j] << (base +
/// j·width)`. Caller contract (the `push_block` fast path): `width ≥ 1`
/// and `base + vals.len()·width ≤ 64`, so every shift is `< 64`.
#[inline]
pub fn pack_fields(vals: &[u64], width: u32, base: u32) -> u64 {
    dispatch!(avx2_impl::pack_fields, pack_fields_scalar, (vals, width, base))
}

/// Scalar reference for [`pack_fields`].
pub fn pack_fields_scalar(vals: &[u64], width: u32, base: u32) -> u64 {
    debug_assert!(width >= 1 && base as u64 + vals.len() as u64 * width as u64 <= 64);
    let mut acc = 0u64;
    let mut bits = base;
    for &v in vals {
        acc |= v << bits;
        bits += width;
    }
    acc
}

/// Unpack consecutive `width`-bit fields of `w` into `out`: `out[j] =
/// (w >> (j·width)) & mask`. Caller contract (the `read_block` fast
/// path): `width ≥ 1`, `mask` the `width`-bit mask, and
/// `(out.len() − 1)·width < 64`.
#[inline]
pub fn unpack_fields(w: u64, width: u32, mask: u64, out: &mut [u64]) {
    dispatch!(
        avx2_impl::unpack_fields,
        unpack_fields_scalar,
        (w, width, mask, out)
    )
}

/// Scalar reference for [`unpack_fields`].
pub fn unpack_fields_scalar(w: u64, width: u32, mask: u64, out: &mut [u64]) {
    debug_assert!(width >= 1 && (out.is_empty() || (out.len() as u64 - 1) * width as u64 <= 63));
    for (j, o) in out.iter_mut().enumerate() {
        *o = (w >> (j as u32 * width)) & mask;
    }
}

// ---------------------------------------------------------------------
// AVX2 bodies (x86_64, `simd` feature). Every loop: 4-lane main body +
// the scalar twin's loop on the ragged tail.
// ---------------------------------------------------------------------

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2_impl {
    use std::arch::x86_64::*;

    /// `vroundpd` immediate for round-to-nearest-even, exceptions
    /// suppressed — exactly `f64::round_ties_even` per lane.
    const ROUND_EVEN: i32 = _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC;

    /// # Safety
    /// Caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn butterfly2(lo: &mut [f64], hi: &mut [f64]) {
        debug_assert_eq!(lo.len(), hi.len());
        let n = lo.len();
        let lp = lo.as_mut_ptr();
        let hp = hi.as_mut_ptr();
        let mut j = 0;
        while j + 4 <= n {
            let u = _mm256_loadu_pd(lp.add(j));
            let v = _mm256_loadu_pd(hp.add(j));
            _mm256_storeu_pd(lp.add(j), _mm256_add_pd(u, v));
            _mm256_storeu_pd(hp.add(j), _mm256_sub_pd(u, v));
            j += 4;
        }
        while j < n {
            let (u, v) = (*lp.add(j), *hp.add(j));
            *lp.add(j) = u + v;
            *hp.add(j) = u - v;
            j += 1;
        }
    }

    /// # Safety
    /// Caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn butterfly4(g0: &mut [f64], g1: &mut [f64], g2: &mut [f64], g3: &mut [f64]) {
        debug_assert!(g0.len() == g1.len() && g1.len() == g2.len() && g2.len() == g3.len());
        let n = g0.len();
        let (p0, p1, p2, p3) = (
            g0.as_mut_ptr(),
            g1.as_mut_ptr(),
            g2.as_mut_ptr(),
            g3.as_mut_ptr(),
        );
        let mut j = 0;
        while j + 4 <= n {
            let y0 = _mm256_loadu_pd(p0.add(j));
            let y1 = _mm256_loadu_pd(p1.add(j));
            let y2 = _mm256_loadu_pd(p2.add(j));
            let y3 = _mm256_loadu_pd(p3.add(j));
            let u0 = _mm256_add_pd(y0, y1);
            let u1 = _mm256_sub_pd(y0, y1);
            let u2 = _mm256_add_pd(y2, y3);
            let u3 = _mm256_sub_pd(y2, y3);
            _mm256_storeu_pd(p0.add(j), _mm256_add_pd(u0, u2));
            _mm256_storeu_pd(p1.add(j), _mm256_add_pd(u1, u3));
            _mm256_storeu_pd(p2.add(j), _mm256_sub_pd(u0, u2));
            _mm256_storeu_pd(p3.add(j), _mm256_sub_pd(u1, u3));
            j += 4;
        }
        while j < n {
            let (y0, y1, y2, y3) = (*p0.add(j), *p1.add(j), *p2.add(j), *p3.add(j));
            let u0 = y0 + y1;
            let u1 = y0 - y1;
            let u2 = y2 + y3;
            let u3 = y2 - y3;
            *p0.add(j) = u0 + u2;
            *p1.add(j) = u1 + u3;
            *p2.add(j) = u0 - u2;
            *p3.add(j) = u1 - u3;
            j += 1;
        }
    }

    /// # Safety
    /// Caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn butterfly2_scaled(lo: &mut [f64], hi: &mut [f64], scale: f64) {
        debug_assert_eq!(lo.len(), hi.len());
        let n = lo.len();
        let lp = lo.as_mut_ptr();
        let hp = hi.as_mut_ptr();
        let sv = _mm256_set1_pd(scale);
        let mut j = 0;
        while j + 4 <= n {
            let u = _mm256_loadu_pd(lp.add(j));
            let v = _mm256_loadu_pd(hp.add(j));
            _mm256_storeu_pd(lp.add(j), _mm256_mul_pd(_mm256_add_pd(u, v), sv));
            _mm256_storeu_pd(hp.add(j), _mm256_mul_pd(_mm256_sub_pd(u, v), sv));
            j += 4;
        }
        while j < n {
            let (u, v) = (*lp.add(j), *hp.add(j));
            *lp.add(j) = (u + v) * scale;
            *hp.add(j) = (u - v) * scale;
            j += 1;
        }
    }

    /// # Safety
    /// Caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn butterfly2_diag(lo: &mut [f64], hi: &mut [f64], dlo: &[f64], dhi: &[f64]) {
        debug_assert!(lo.len() == hi.len() && lo.len() == dlo.len() && lo.len() == dhi.len());
        let n = lo.len();
        let lp = lo.as_mut_ptr();
        let hp = hi.as_mut_ptr();
        let dl = dlo.as_ptr();
        let dh = dhi.as_ptr();
        let mut j = 0;
        while j + 4 <= n {
            let u = _mm256_loadu_pd(lp.add(j));
            let v = _mm256_loadu_pd(hp.add(j));
            let a = _mm256_mul_pd(_mm256_add_pd(u, v), _mm256_loadu_pd(dl.add(j)));
            let b = _mm256_mul_pd(_mm256_sub_pd(u, v), _mm256_loadu_pd(dh.add(j)));
            _mm256_storeu_pd(lp.add(j), a);
            _mm256_storeu_pd(hp.add(j), b);
            j += 4;
        }
        while j < n {
            let (u, v) = (*lp.add(j), *hp.add(j));
            *lp.add(j) = (u + v) * *dl.add(j);
            *hp.add(j) = (u - v) * *dh.add(j);
            j += 1;
        }
    }

    /// # Safety
    /// Caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_offset(x: &[f64], off: &[f64], inv: f64, out: &mut [f64]) {
        debug_assert!(x.len() == off.len() && x.len() == out.len());
        let n = out.len();
        let xp = x.as_ptr();
        let op = off.as_ptr();
        let rp = out.as_mut_ptr();
        let iv = _mm256_set1_pd(inv);
        let mut j = 0;
        while j + 4 <= n {
            let t = _mm256_mul_pd(
                _mm256_sub_pd(_mm256_loadu_pd(xp.add(j)), _mm256_loadu_pd(op.add(j))),
                iv,
            );
            _mm256_storeu_pd(rp.add(j), t);
            j += 4;
        }
        while j < n {
            *rp.add(j) = (*xp.add(j) - *op.add(j)) * inv;
            j += 1;
        }
    }

    /// # Safety
    /// Caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn quantize_scaled(x: &[f64], off: &[f64], inv: f64, out: &mut [f64]) {
        debug_assert!(x.len() == off.len() && x.len() == out.len());
        let n = out.len();
        let xp = x.as_ptr();
        let op = off.as_ptr();
        let rp = out.as_mut_ptr();
        let iv = _mm256_set1_pd(inv);
        let mut j = 0;
        while j + 4 <= n {
            let t = _mm256_mul_pd(
                _mm256_sub_pd(_mm256_loadu_pd(xp.add(j)), _mm256_loadu_pd(op.add(j))),
                iv,
            );
            _mm256_storeu_pd(rp.add(j), _mm256_round_pd::<ROUND_EVEN>(t));
            j += 4;
        }
        while j < n {
            *rp.add(j) = ((*xp.add(j) - *op.add(j)) * inv).round_ties_even();
            j += 1;
        }
    }

    /// # Safety
    /// Caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn fold_decode_indices(
        reference: &[f64],
        off: &[f64],
        cf: &[f64],
        inv_sq: f64,
        inv_q: f64,
        out: &mut [f64],
    ) {
        debug_assert!(
            reference.len() == off.len()
                && reference.len() == cf.len()
                && reference.len() == out.len()
        );
        let n = out.len();
        let rp = reference.as_ptr();
        let op = off.as_ptr();
        let cp = cf.as_ptr();
        let mp = out.as_mut_ptr();
        let isq = _mm256_set1_pd(inv_sq);
        let iq = _mm256_set1_pd(inv_q);
        let mut j = 0;
        while j + 4 <= n {
            let t = _mm256_mul_pd(
                _mm256_sub_pd(_mm256_loadu_pd(rp.add(j)), _mm256_loadu_pd(op.add(j))),
                isq,
            );
            let u = _mm256_mul_pd(_mm256_loadu_pd(cp.add(j)), iq);
            _mm256_storeu_pd(mp.add(j), _mm256_round_pd::<ROUND_EVEN>(_mm256_sub_pd(t, u)));
            j += 4;
        }
        while j < n {
            *mp.add(j) =
                ((*rp.add(j) - *op.add(j)) * inv_sq - *cp.add(j) * inv_q).round_ties_even();
            j += 1;
        }
    }

    /// # Safety
    /// Caller must ensure AVX2 is available.
    ///
    /// AVX2 has no packed u64→f64 convert, so the conversion splits the
    /// 53-bit value `x = words[j] >> 11` into high 21 and low 32 bits,
    /// ORs them into the mantissas of 2⁸⁴ and 2⁵² respectively
    /// (`(x>>32) | bits(2⁸⁴)` *is* `2⁸⁴ + (x>>32)·2³²` as an f64), and
    /// reassembles `x = (hi_d − (2⁸⁴ + 2⁵²)) + lo_d`. Every step is
    /// exact for `x < 2⁵³` (all intermediates are representable), so the
    /// result equals the scalar `as f64` cast bit for bit.
    #[target_feature(enable = "avx2")]
    pub unsafe fn uniform_from_bits(words: &[u64], out: &mut [f64]) {
        const SCALE: f64 = 1.0 / (1u64 << 53) as f64;
        const HI_MAGIC: f64 = f64::from_bits(0x4530_0000_0000_0000); // 2^84
        const LO_MAGIC: f64 = f64::from_bits(0x4330_0000_0000_0000); // 2^52
        debug_assert_eq!(words.len(), out.len());
        let n = words.len();
        let wp = words.as_ptr();
        let op = out.as_mut_ptr();
        let hi_bits = _mm256_castpd_si256(_mm256_set1_pd(HI_MAGIC));
        let lo_bits = _mm256_castpd_si256(_mm256_set1_pd(LO_MAGIC));
        let corr = _mm256_set1_pd(HI_MAGIC + LO_MAGIC); // exact: 2^84 + 2^52
        let lo_mask = _mm256_set1_epi64x(0xFFFF_FFFF);
        let scale = _mm256_set1_pd(SCALE);
        let mut j = 0;
        while j + 4 <= n {
            let x = _mm256_srli_epi64::<11>(_mm256_loadu_si256(wp.add(j) as *const __m256i));
            let xh = _mm256_or_si256(_mm256_srli_epi64::<32>(x), hi_bits);
            let xl = _mm256_or_si256(_mm256_and_si256(x, lo_mask), lo_bits);
            let f = _mm256_add_pd(
                _mm256_sub_pd(_mm256_castsi256_pd(xh), corr),
                _mm256_castsi256_pd(xl),
            );
            _mm256_storeu_pd(op.add(j), _mm256_mul_pd(f, scale));
            j += 4;
        }
        while j < n {
            *op.add(j) = (*wp.add(j) >> 11) as f64 * SCALE;
            j += 1;
        }
    }

    /// # Safety
    /// Caller must ensure AVX2 is available. Same shift contract as the
    /// scalar twin: `base + vals.len()·width ≤ 64` (every `vpsllvq`
    /// shift count stays below 64).
    #[target_feature(enable = "avx2")]
    pub unsafe fn pack_fields(vals: &[u64], width: u32, base: u32) -> u64 {
        debug_assert!(width >= 1 && base as u64 + vals.len() as u64 * width as u64 <= 64);
        let n = vals.len();
        let vp = vals.as_ptr();
        let step = _mm256_set1_epi64x(4 * width as i64);
        let mut sh = _mm256_setr_epi64x(
            base as i64,
            (base + width) as i64,
            (base + 2 * width) as i64,
            (base + 3 * width) as i64,
        );
        let mut accv = _mm256_setzero_si256();
        let mut j = 0;
        while j + 4 <= n {
            let v = _mm256_loadu_si256(vp.add(j) as *const __m256i);
            accv = _mm256_or_si256(accv, _mm256_sllv_epi64(v, sh));
            sh = _mm256_add_epi64(sh, step);
            j += 4;
        }
        let halves = _mm_or_si128(
            _mm256_castsi256_si128(accv),
            _mm256_extracti128_si256::<1>(accv),
        );
        let mut acc =
            (_mm_cvtsi128_si64(halves) as u64) | (_mm_extract_epi64::<1>(halves) as u64);
        let mut bits = base + j as u32 * width;
        while j < n {
            acc |= *vp.add(j) << bits;
            bits += width;
            j += 1;
        }
        acc
    }

    /// # Safety
    /// Caller must ensure AVX2 is available. Same shift contract as the
    /// scalar twin: `(out.len() − 1)·width < 64`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn unpack_fields(w: u64, width: u32, mask: u64, out: &mut [u64]) {
        debug_assert!(width >= 1 && (out.is_empty() || (out.len() as u64 - 1) * width as u64 <= 63));
        let n = out.len();
        let op = out.as_mut_ptr();
        let wv = _mm256_set1_epi64x(w as i64);
        let mv = _mm256_set1_epi64x(mask as i64);
        let step = _mm256_set1_epi64x(4 * width as i64);
        let mut sh = _mm256_setr_epi64x(0, width as i64, 2 * width as i64, 3 * width as i64);
        let mut j = 0;
        while j + 4 <= n {
            let v = _mm256_and_si256(_mm256_srlv_epi64(wv, sh), mv);
            _mm256_storeu_si256(op.add(j) as *mut __m256i, v);
            sh = _mm256_add_epi64(sh, step);
            j += 4;
        }
        let mut shift = j as u32 * width;
        while j < n {
            *op.add(j) = (w >> shift) & mask;
            shift += width;
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Values exercising every rounding/edge class: ties, subnormals,
    /// negative zero, large magnitudes, ragged lengths.
    fn edge_vec(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n)
            .map(|i| match i % 7 {
                0 => -0.0,
                1 => f64::from_bits(rng.next_u64() & 0xF_FFFF_FFFF_FFFF), // subnormal
                2 => (rng.next_below(41) as f64 - 20.0) * 0.5,            // exact ties
                3 => rng.uniform(-1e12, 1e12),
                _ => rng.uniform(-8.0, 8.0),
            })
            .collect()
    }

    fn bits_of(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn dispatched_kernels_match_scalar_twins_bitwise() {
        let mut rng = Rng::new(0xD15);
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 33, 64, 127] {
            let a = edge_vec(&mut rng, n);
            let b = edge_vec(&mut rng, n);
            let d1 = edge_vec(&mut rng, n);
            let d2 = edge_vec(&mut rng, n);

            let (mut l1, mut h1) = (a.clone(), b.clone());
            let (mut l2, mut h2) = (a.clone(), b.clone());
            butterfly2(&mut l1, &mut h1);
            butterfly2_scalar(&mut l2, &mut h2);
            assert_eq!(bits_of(&l1), bits_of(&l2), "butterfly2 lo n={n}");
            assert_eq!(bits_of(&h1), bits_of(&h2), "butterfly2 hi n={n}");

            let (mut l1, mut h1) = (a.clone(), b.clone());
            let (mut l2, mut h2) = (a.clone(), b.clone());
            butterfly2_scaled(&mut l1, &mut h1, 0.1234);
            butterfly2_scaled_scalar(&mut l2, &mut h2, 0.1234);
            assert_eq!(bits_of(&l1), bits_of(&l2), "butterfly2_scaled n={n}");
            assert_eq!(bits_of(&h1), bits_of(&h2), "butterfly2_scaled n={n}");

            let (mut l1, mut h1) = (a.clone(), b.clone());
            let (mut l2, mut h2) = (a.clone(), b.clone());
            butterfly2_diag(&mut l1, &mut h1, &d1, &d2);
            butterfly2_diag_scalar(&mut l2, &mut h2, &d1, &d2);
            assert_eq!(bits_of(&l1), bits_of(&l2), "butterfly2_diag n={n}");
            assert_eq!(bits_of(&h1), bits_of(&h2), "butterfly2_diag n={n}");

            let (mut q0, mut q1) = (a.clone(), b.clone());
            let (mut q2, mut q3) = (d1.clone(), d2.clone());
            let (mut r0, mut r1) = (a.clone(), b.clone());
            let (mut r2, mut r3) = (d1.clone(), d2.clone());
            butterfly4(&mut q0, &mut q1, &mut q2, &mut q3);
            butterfly4_scalar(&mut r0, &mut r1, &mut r2, &mut r3);
            for (g, r) in [(&q0, &r0), (&q1, &r1), (&q2, &r2), (&q3, &r3)] {
                assert_eq!(bits_of(g), bits_of(r), "butterfly4 n={n}");
            }

            let mut o1 = vec![0.0; n];
            let mut o2 = vec![0.0; n];
            quantize_scaled(&a, &b, 1.75, &mut o1);
            quantize_scaled_scalar(&a, &b, 1.75, &mut o2);
            assert_eq!(bits_of(&o1), bits_of(&o2), "quantize_scaled n={n}");
            scale_offset(&a, &b, -0.37, &mut o1);
            scale_offset_scalar(&a, &b, -0.37, &mut o2);
            assert_eq!(bits_of(&o1), bits_of(&o2), "scale_offset n={n}");
            fold_decode_indices(&a, &b, &d1, 0.81, 0.0625, &mut o1);
            fold_decode_indices_scalar(&a, &b, &d1, 0.81, 0.0625, &mut o2);
            assert_eq!(bits_of(&o1), bits_of(&o2), "fold_decode_indices n={n}");

            let words: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            uniform_from_bits(&words, &mut o1);
            uniform_from_bits_scalar(&words, &mut o2);
            assert_eq!(bits_of(&o1), bits_of(&o2), "uniform_from_bits n={n}");
        }
    }

    #[test]
    fn field_kernels_match_scalar_twins_every_width() {
        let mut rng = Rng::new(0xB17);
        for width in 1..=64u32 {
            let mask = if width == 64 {
                u64::MAX
            } else {
                (1u64 << width) - 1
            };
            let max_fields = (64 / width) as usize;
            for count in 0..=max_fields {
                let base_room = 64 - count as u32 * width;
                for base in [0, base_room / 2, base_room] {
                    let vals: Vec<u64> = (0..count).map(|_| rng.next_u64() & mask).collect();
                    assert_eq!(
                        pack_fields(&vals, width, base),
                        pack_fields_scalar(&vals, width, base),
                        "pack width={width} count={count} base={base}"
                    );
                }
                let w = rng.next_u64();
                let mut o1 = vec![0u64; count];
                let mut o2 = vec![0u64; count];
                unpack_fields(w, width, mask, &mut o1);
                unpack_fields_scalar(w, width, mask, &mut o2);
                assert_eq!(o1, o2, "unpack width={width} count={count}");
            }
        }
    }

    #[test]
    fn lane_report_is_consistent() {
        // `active()` implies `compiled()`; the label matches.
        assert!(!active() || compiled());
        assert_eq!(lanes() == "scalar", !active());
    }
}
