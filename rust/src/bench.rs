//! criterion-lite: a minimal micro-benchmark harness (the offline build
//! has no criterion crate — see DESIGN.md §6).
//!
//! Provides warmup, adaptive iteration count targeting a fixed measuring
//! window, and median / p10 / p99 statistics. Used by the `benches/`
//! targets (`cargo bench`, `harness = false`).

use std::time::{Duration, Instant};

/// One benchmark's results.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: u64,
    pub median: Duration,
    pub p10: Duration,
    pub p99: Duration,
    pub mean: Duration,
    /// Optional throughput denominator (bytes or elements per iteration).
    pub elems_per_iter: Option<u64>,
}

impl BenchStats {
    pub fn report(&self) -> String {
        let thr = match self.elems_per_iter {
            Some(e) if self.median.as_nanos() > 0 => {
                let per_sec = e as f64 / self.median.as_secs_f64();
                if per_sec > 1e9 {
                    format!("  {:8.2} Gelem/s", per_sec / 1e9)
                } else {
                    format!("  {:8.2} Melem/s", per_sec / 1e6)
                }
            }
            _ => String::new(),
        };
        format!(
            "{:<44} {:>12} median  {:>12} p10  {:>12} p99  ({} iters){}",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.p10),
            fmt_dur(self.p99),
            self.iters,
            thr
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Benchmark runner with a global time budget per benchmark.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    /// Minimum sample count even if over budget.
    pub min_samples: usize,
    results: Vec<BenchStats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(150),
            measure: Duration::from_millis(700),
            min_samples: 10,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick profile for CI-ish runs (`DME_BENCH_FAST=1`).
    pub fn from_env() -> Self {
        if std::env::var("DME_BENCH_FAST").is_ok() {
            Bencher {
                warmup: Duration::from_millis(20),
                measure: Duration::from_millis(100),
                min_samples: 5,
                results: Vec::new(),
            }
        } else {
            Self::default()
        }
    }

    /// Run a benchmark; `f` is one measured iteration and must return a
    /// value (black-boxed to defeat dead-code elimination).
    pub fn bench<T>(&mut self, name: &str, elems: Option<u64>, mut f: impl FnMut() -> T) -> &BenchStats {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure || samples.len() < self.min_samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
            if samples.len() > 2_000_000 {
                break;
            }
        }
        samples.sort_unstable();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let stats = BenchStats {
            name: name.to_string(),
            iters: n as u64,
            median: samples[n / 2],
            p10: samples[n / 10],
            p99: samples[((n * 99) / 100).min(n - 1)],
            mean: total / n as u32,
            elems_per_iter: elems,
        };
        println!("{}", stats.report());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            min_samples: 5,
            results: Vec::new(),
        };
        let s = b.bench("noop-ish", Some(100), || {
            (0..100).map(|i| i * i).sum::<usize>()
        });
        assert!(s.iters >= 5);
        assert!(s.p10 <= s.median);
        assert!(s.median <= s.p99);
    }

    #[test]
    fn fmt_covers_ranges() {
        assert!(fmt_dur(Duration::from_nanos(10)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(10)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(10)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}
