//! criterion-lite: a minimal micro-benchmark harness (the offline build
//! has no criterion crate — see DESIGN.md §6).
//!
//! Provides warmup, adaptive iteration count targeting a fixed measuring
//! window, and median / p10 / p99 statistics. Used by the `benches/`
//! targets (`cargo bench`, `harness = false`).
//!
//! Every bench target finishes with [`Bencher::write_json`], emitting a
//! machine-readable `BENCH_<name>.json` summary (schema documented in
//! `rust/benches/README.md`) so per-case ns/op is trackable across PRs.
//! Passing `--smoke` to a bench binary (CI does) runs exactly one
//! iteration per case — enough to exercise the code and produce the
//! JSON without paying measurement time.

use crate::config::Json;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// One benchmark's results.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: u64,
    pub median: Duration,
    pub p10: Duration,
    pub p99: Duration,
    pub mean: Duration,
    /// Optional throughput denominator (bytes or elements per iteration).
    pub elems_per_iter: Option<u64>,
}

impl BenchStats {
    pub fn report(&self) -> String {
        let thr = match self.elems_per_iter {
            Some(e) if self.median.as_nanos() > 0 => {
                let per_sec = e as f64 / self.median.as_secs_f64();
                if per_sec > 1e9 {
                    format!("  {:8.2} Gelem/s", per_sec / 1e9)
                } else {
                    format!("  {:8.2} Melem/s", per_sec / 1e6)
                }
            }
            _ => String::new(),
        };
        format!(
            "{:<44} {:>12} median  {:>12} p10  {:>12} p99  ({} iters){}",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.p10),
            fmt_dur(self.p99),
            self.iters,
            thr
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Benchmark runner with a global time budget per benchmark.
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    /// Minimum sample count even if over budget.
    pub min_samples: usize,
    results: Vec<BenchStats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(150),
            measure: Duration::from_millis(700),
            min_samples: 10,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// One iteration per case, no warmup — the CI smoke profile: runs
    /// every benchmark body once and still emits the JSON summary.
    pub fn smoke() -> Self {
        Bencher {
            warmup: Duration::from_millis(0),
            measure: Duration::from_millis(0),
            min_samples: 1,
            results: Vec::new(),
        }
    }

    /// Profile from the invocation: `--smoke` (one iteration per case,
    /// CI), `DME_BENCH_FAST=1` (short windows), else the default.
    pub fn from_env() -> Self {
        if std::env::args().any(|a| a == "--smoke") {
            Self::smoke()
        } else if std::env::var("DME_BENCH_FAST").is_ok() {
            Bencher {
                warmup: Duration::from_millis(20),
                measure: Duration::from_millis(100),
                min_samples: 5,
                results: Vec::new(),
            }
        } else {
            Self::default()
        }
    }

    /// Run a benchmark; `f` is one measured iteration and must return a
    /// value (black-boxed to defeat dead-code elimination).
    pub fn bench<T>(&mut self, name: &str, elems: Option<u64>, mut f: impl FnMut() -> T) -> &BenchStats {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure || samples.len() < self.min_samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
            if samples.len() > 2_000_000 {
                break;
            }
        }
        samples.sort_unstable();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let stats = BenchStats {
            name: name.to_string(),
            iters: n as u64,
            median: samples[n / 2],
            p10: samples[n / 10],
            p99: samples[((n * 99) / 100).min(n - 1)],
            mean: total / n as u32,
            elems_per_iter: elems,
        };
        println!("{}", stats.report());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }

    /// The machine-readable summary (`BENCH_<name>.json` schema v1 — see
    /// `rust/benches/README.md`): per-case median/p10/p99/mean ns per
    /// iteration, iteration count, and the optional throughput
    /// denominator.
    pub fn to_json(&self, bench_name: &str) -> Json {
        let cases: Vec<Json> = self
            .results
            .iter()
            .map(|s| {
                let mut o = BTreeMap::new();
                o.insert("name".to_string(), Json::Str(s.name.clone()));
                o.insert("iters".to_string(), Json::Num(s.iters as f64));
                o.insert("median_ns".to_string(), Json::Num(s.median.as_nanos() as f64));
                o.insert("p10_ns".to_string(), Json::Num(s.p10.as_nanos() as f64));
                o.insert("p99_ns".to_string(), Json::Num(s.p99.as_nanos() as f64));
                o.insert("mean_ns".to_string(), Json::Num(s.mean.as_nanos() as f64));
                o.insert(
                    "elems_per_iter".to_string(),
                    match s.elems_per_iter {
                        Some(e) => Json::Num(e as f64),
                        None => Json::Null,
                    },
                );
                Json::Obj(o)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str(bench_name.to_string()));
        root.insert("schema".to_string(), Json::Num(1.0));
        root.insert("cases".to_string(), Json::Arr(cases));
        Json::Obj(root)
    }

    /// Write `BENCH_<name>.json` into the working directory and return
    /// its path. Bench targets call this last; CI smoke runs assert the
    /// file parses.
    pub fn write_json(&self, bench_name: &str) -> std::io::Result<String> {
        let path = format!("BENCH_{bench_name}.json");
        std::fs::write(&path, format!("{}\n", self.to_json(bench_name)))?;
        println!("[saved {path}: {} cases]", self.results.len());
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            min_samples: 5,
            results: Vec::new(),
        };
        let s = b.bench("noop-ish", Some(100), || {
            (0..100).map(|i| i * i).sum::<usize>()
        });
        assert!(s.iters >= 5);
        assert!(s.p10 <= s.median);
        assert!(s.median <= s.p99);
    }

    #[test]
    fn json_summary_round_trips_through_the_parser() {
        let mut b = Bencher::smoke();
        b.bench("case-a", Some(64), || 1 + 1);
        b.bench("case-b", None, || 2 + 2);
        let j = b.to_json("unit");
        let parsed = Json::parse(&j.to_string()).expect("self-emitted json parses");
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("unit"));
        assert_eq!(parsed.get("schema").unwrap().as_f64(), Some(1.0));
        let cases = parsed.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 2);
        assert_eq!(cases[0].get("name").unwrap().as_str(), Some("case-a"));
        assert_eq!(cases[0].get("elems_per_iter").unwrap().as_f64(), Some(64.0));
        assert_eq!(cases[1].get("elems_per_iter"), Some(&Json::Null));
        assert!(cases[0].get("median_ns").unwrap().as_f64().is_some());
        // Smoke profile: exactly one iteration per case.
        assert_eq!(cases[0].get("iters").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn fmt_covers_ranges() {
        assert!(fmt_dur(Duration::from_nanos(10)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(10)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(10)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}
