//! Workload substrates: dataset generators for every experiment, plus a
//! LIBSVM parser so real files can be dropped in when available.
//!
//! * [`gen_lsq`] — the synthetic least-squares instances of §9.2
//!   (A ~ N(0,1)^{S×d}, b = A w*).
//! * [`gen_cpusmall_like`] — stand-in for LIBSVM `cpusmall_scale`
//!   (S=8192, d=12, features scaled to [−1,1], mildly nonlinear target);
//!   used by Experiment 5 when no real file is present (see DESIGN.md §2).
//! * [`gen_classification`] — gaussian-mixture classification for the
//!   neural-network experiment (E7 analogue).
//! * [`gen_power_matrix`] — rows from a gaussian with a controlled
//!   spectrum (first two eigenvalues large and comparable, §9.5).
//! * [`parse_libsvm`] — the standard sparse text format.

use crate::linalg::Matrix;
use crate::rng::Rng;

/// A regression dataset `min_w ‖Aw − b‖²`.
#[derive(Clone, Debug)]
pub struct Regression {
    pub a: Matrix,
    pub b: Vec<f64>,
    /// Ground-truth weights when synthetic (None for parsed data).
    pub w_star: Option<Vec<f64>>,
}

impl Regression {
    pub fn samples(&self) -> usize {
        self.a.rows
    }
    pub fn dim(&self) -> usize {
        self.a.cols
    }

    /// Full-batch least-squares gradient at `w`: (2/S)·Aᵀ(Aw − b).
    pub fn full_gradient(&self, w: &[f64]) -> Vec<f64> {
        let mut r = self.a.matvec(w);
        for (ri, bi) in r.iter_mut().zip(&self.b) {
            *ri -= bi;
        }
        let mut g = self.a.matvec_t(&r);
        let c = 2.0 / self.samples() as f64;
        for gi in g.iter_mut() {
            *gi *= c;
        }
        g
    }

    /// Gradient over a row subset.
    pub fn batch_gradient(&self, w: &[f64], rows: &[usize]) -> Vec<f64> {
        let mut g = vec![0.0; self.dim()];
        for &i in rows {
            let row = self.a.row(i);
            let r = crate::linalg::dot(row, w) - self.b[i];
            crate::linalg::axpy(&mut g, r, row);
        }
        let c = 2.0 / rows.len().max(1) as f64;
        for gi in g.iter_mut() {
            *gi *= c;
        }
        g
    }

    /// Mean squared error ‖Aw−b‖²/S.
    pub fn loss(&self, w: &[f64]) -> f64 {
        let r = self.a.matvec(w);
        r.iter()
            .zip(&self.b)
            .map(|(ri, bi)| (ri - bi) * (ri - bi))
            .sum::<f64>()
            / self.samples() as f64
    }

    /// Random equal partition of rows into `n` groups (fresh each call —
    /// the paper reshuffles every iteration).
    pub fn partition(&self, n: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
        let mut idx: Vec<usize> = (0..self.samples()).collect();
        rng.shuffle(&mut idx);
        let chunk = self.samples() / n;
        (0..n)
            .map(|g| idx[g * chunk..(g + 1) * chunk].to_vec())
            .collect()
    }
}

/// §9.2 synthetic least-squares: A, w* ~ N(0,1), b = A w* (noise-free,
/// so the optimum is exact and gradients vanish at w*).
pub fn gen_lsq(samples: usize, d: usize, seed: u64) -> Regression {
    let mut rng = Rng::new(seed);
    let w_star = rng.gaussian_vec(d);
    let mut a = Matrix::zeros(samples, d);
    for v in a.data.iter_mut() {
        *v = rng.next_gaussian();
    }
    let b = a.matvec(&w_star);
    Regression {
        a,
        b,
        w_star: Some(w_star),
    }
}

/// cpusmall_scale stand-in: 12 features in [−1, 1] with heterogeneous
/// distributions, target a noisy mildly-nonlinear function — shaped like
/// the LIBSVM original (system activity → CPU usage regression).
pub fn gen_cpusmall_like(samples: usize, seed: u64) -> Regression {
    let d = 12;
    let mut rng = Rng::new(seed);
    let w_lin = rng.gaussian_vec(d);
    let mut a = Matrix::zeros(samples, d);
    let mut b = vec![0.0; samples];
    for i in 0..samples {
        for j in 0..d {
            // Heterogeneous feature families, all scaled into [-1, 1].
            let v = match j % 3 {
                0 => rng.uniform(-1.0, 1.0),
                1 => (rng.next_gaussian() * 0.33).clamp(-1.0, 1.0),
                _ => {
                    // skewed (exponential-ish) then scaled
                    let e = -rng.next_f64().max(1e-12).ln() / 3.0;
                    (e.min(1.0)) * 2.0 - 1.0
                }
            };
            a.data[i * d + j] = v;
        }
        let row = &a.data[i * d..(i + 1) * d];
        let lin = crate::linalg::dot(row, &w_lin);
        let nonlin = 0.3 * row[0] * row[1] + 0.2 * row[2].powi(2);
        b[i] = 30.0 * (lin + nonlin) + 50.0 + rng.next_gaussian();
    }
    Regression { a, b, w_star: None }
}

/// Gaussian-mixture classification: `classes` spherical clusters with
/// unit-norm random centers separated enough to be learnable.
pub struct Classification {
    pub x: Matrix,
    pub labels: Vec<usize>,
    pub classes: usize,
}

impl Classification {
    /// Split into (train, validation) at `n_train` samples.
    pub fn split(&self, n_train: usize) -> (Classification, Classification) {
        assert!(n_train < self.x.rows);
        let f = self.x.cols;
        let head = Classification {
            x: self.x.row_block(0, n_train),
            labels: self.labels[..n_train].to_vec(),
            classes: self.classes,
        };
        let tail = Classification {
            x: Matrix {
                rows: self.x.rows - n_train,
                cols: f,
                data: self.x.data[n_train * f..].to_vec(),
            },
            labels: self.labels[n_train..].to_vec(),
            classes: self.classes,
        };
        (head, tail)
    }
}

pub fn gen_classification(
    samples: usize,
    features: usize,
    classes: usize,
    noise: f64,
    seed: u64,
) -> Classification {
    let mut rng = Rng::new(seed);
    let centers: Vec<Vec<f64>> = (0..classes)
        .map(|_| {
            let c = rng.gaussian_vec(features);
            crate::linalg::scale(&crate::linalg::normalize(&c), 2.0)
        })
        .collect();
    let mut x = Matrix::zeros(samples, features);
    let mut labels = vec![0usize; samples];
    for i in 0..samples {
        let c = rng.next_below(classes as u64) as usize;
        labels[i] = c;
        for j in 0..features {
            x.data[i * features + j] = centers[c][j] + noise * rng.next_gaussian();
        }
    }
    Classification {
        x,
        labels,
        classes,
    }
}

/// §9.5 power-iteration input: rows `x = Σ_i √λ_i g_i v_i` with
/// eigenvalues `lambdas` and principal directions either the standard
/// basis (axis-aligned, Fig 14) or a random rotation (Fig 15).
pub fn gen_power_matrix(
    samples: usize,
    d: usize,
    lambdas: &[f64],
    random_directions: bool,
    seed: u64,
) -> (Matrix, Vec<f64>) {
    let mut rng = Rng::new(seed);
    assert!(lambdas.len() <= d);
    // Orthonormal directions: identity, or random via Gram-Schmidt.
    let mut dirs: Vec<Vec<f64>> = Vec::with_capacity(lambdas.len());
    if random_directions {
        for _ in 0..lambdas.len() {
            let mut v = rng.gaussian_vec(d);
            for u in &dirs {
                let c = crate::linalg::dot(&v, u);
                crate::linalg::axpy(&mut v, -c, u);
            }
            dirs.push(crate::linalg::normalize(&v));
        }
    } else {
        for (i, _) in lambdas.iter().enumerate() {
            let mut v = vec![0.0; d];
            // Paper Fig 14: principal eigenvector is e_2.
            v[(i + 1) % d] = 1.0;
            dirs.push(v);
        }
    }
    let mut x = Matrix::zeros(samples, d);
    let resid = 0.05; // small isotropic floor so X is full-rank
    for i in 0..samples {
        let row = &mut x.data[i * d..(i + 1) * d];
        for v in row.iter_mut() {
            *v = resid * rng.next_gaussian();
        }
        for (lam, dir) in lambdas.iter().zip(&dirs) {
            let g = rng.next_gaussian() * lam.sqrt();
            for (rj, dj) in row.iter_mut().zip(dir) {
                *rj += g * dj;
            }
        }
    }
    (x, dirs[0].clone())
}

/// Parse LIBSVM format (`label idx:val idx:val ...`, 1-based indices).
pub fn parse_libsvm(text: &str, dim_hint: Option<usize>) -> Regression {
    let mut rows: Vec<(f64, Vec<(usize, f64)>)> = Vec::new();
    let mut max_idx = dim_hint.unwrap_or(0);
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let label: f64 = match it.next().and_then(|t| t.parse().ok()) {
            Some(l) => l,
            None => continue,
        };
        let mut feats = Vec::new();
        for tok in it {
            if let Some((i, v)) = tok.split_once(':') {
                if let (Ok(i), Ok(v)) = (i.parse::<usize>(), v.parse::<f64>()) {
                    if i >= 1 {
                        max_idx = max_idx.max(i);
                        feats.push((i - 1, v));
                    }
                }
            }
        }
        rows.push((label, feats));
    }
    let d = max_idx;
    let mut a = Matrix::zeros(rows.len(), d);
    let mut b = vec![0.0; rows.len()];
    for (r, (label, feats)) in rows.into_iter().enumerate() {
        b[r] = label;
        for (j, v) in feats {
            a.data[r * d + j] = v;
        }
    }
    Regression { a, b, w_star: None }
}

/// Load `path` as LIBSVM if it exists, else fall back to the generator.
pub fn cpusmall_or_synthetic(path: &str, samples: usize, seed: u64) -> Regression {
    match std::fs::read_to_string(path) {
        Ok(text) => parse_libsvm(&text, Some(12)),
        Err(_) => gen_cpusmall_like(samples, seed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norm2;

    #[test]
    fn lsq_optimum_has_zero_gradient() {
        let ds = gen_lsq(256, 10, 1);
        let w = ds.w_star.clone().unwrap();
        let g = ds.full_gradient(&w);
        assert!(norm2(&g) < 1e-9);
        assert!(ds.loss(&w) < 1e-18);
    }

    #[test]
    fn batch_gradients_average_to_full() {
        let ds = gen_lsq(128, 6, 2);
        let w = vec![0.5; 6];
        let mut rng = Rng::new(3);
        let parts = ds.partition(4, &mut rng);
        let full = ds.full_gradient(&w);
        let mut acc = vec![0.0; 6];
        for p in &parts {
            crate::linalg::axpy(&mut acc, 0.25, &ds.batch_gradient(&w, p));
        }
        for (a, f) in acc.iter().zip(&full) {
            assert!((a - f).abs() < 1e-9);
        }
    }

    #[test]
    fn partition_covers_all_rows_once() {
        let ds = gen_lsq(64, 3, 4);
        let mut rng = Rng::new(5);
        let parts = ds.partition(4, &mut rng);
        let mut all: Vec<usize> = parts.concat();
        all.sort_unstable();
        assert_eq!(all, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn cpusmall_like_shape_and_scaling() {
        let ds = gen_cpusmall_like(512, 6);
        assert_eq!(ds.dim(), 12);
        assert_eq!(ds.samples(), 512);
        for v in &ds.a.data {
            assert!(*v >= -1.0 - 1e-9 && *v <= 1.0 + 1e-9);
        }
        // Targets are far from origin (the whole point of Exp 5).
        let mean_b = ds.b.iter().sum::<f64>() / ds.b.len() as f64;
        assert!(mean_b.abs() > 10.0);
    }

    #[test]
    fn classification_clusters_learnable() {
        let c = gen_classification(200, 8, 3, 0.1, 7);
        // Nearest-center classification should be near-perfect at low noise.
        let mut centers = vec![vec![0.0; 8]; 3];
        let mut counts = [0usize; 3];
        for i in 0..200 {
            let l = c.labels[i];
            counts[l] += 1;
            crate::linalg::axpy(&mut centers[l], 1.0, c.x.row(i));
        }
        for (c_, n) in centers.iter_mut().zip(counts) {
            for v in c_.iter_mut() {
                *v /= n.max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..200 {
            let row = c.x.row(i);
            let best = (0..3)
                .min_by(|&a, &b| {
                    crate::linalg::dist2(row, &centers[a])
                        .partial_cmp(&crate::linalg::dist2(row, &centers[b]))
                        .unwrap()
                })
                .unwrap();
            if best == c.labels[i] {
                correct += 1;
            }
        }
        assert!(correct > 190, "only {correct}/200 separable");
    }

    #[test]
    fn power_matrix_top_direction_dominates() {
        let (x, v1) = gen_power_matrix(2048, 16, &[10.0, 8.0, 1.0], false, 8);
        // Empirical covariance action: ‖Xv1‖ should dominate ‖Xe_k‖ for
        // a non-principal axis.
        let xv = x.matvec(&v1);
        let mut e_other = vec![0.0; 16];
        e_other[7] = 1.0;
        let xo = x.matvec(&e_other);
        assert!(norm2(&xv) > 2.0 * norm2(&xo));
    }

    #[test]
    fn libsvm_parser_roundtrip() {
        let text = "1.5 1:0.5 3:-2.0\n-0.25 2:1.0\n# comment\n";
        let ds = parse_libsvm(text, None);
        assert_eq!(ds.samples(), 2);
        assert_eq!(ds.dim(), 3);
        assert_eq!(ds.b, vec![1.5, -0.25]);
        assert_eq!(ds.a.row(0), &[0.5, 0.0, -2.0]);
        assert_eq!(ds.a.row(1), &[0.0, 1.0, 0.0]);
    }
}
