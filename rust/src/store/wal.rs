//! The write-ahead log: `[len: u32 LE][crc: u32 LE][body]` records
//! appended to `wal.log`, where `crc` is [`super::crc32`] over `body`.
//!
//! A report record's body carries the `(cohort, round, client)`
//! envelope, the full [`crate::net::cohort::CohortSpec`] (so replay can
//! rebuild the round from nothing) and the quantized payload as a
//! [`crate::net::frame`] frame — byte-identical to what traveled on the
//! wire. A close record marks a round's result as delivered, letting
//! replay re-close it (and re-serve late clients) without re-running the
//! deadline clock.
//!
//! [`Wal::open`] scans the whole file front to back. The first record
//! that fails validation — a header or body cut short by a crash, an
//! impossible length, a CRC mismatch from bit rot, an undecodable body —
//! ends the scan: everything after it is suspect (lengths no longer
//! delimit records), so the file is truncated back to the last valid
//! boundary and the damage reported as a [`TailTruncation`]. Suffix
//! truncation preserves the prefix invariant replay depends on: a
//! surviving close record's reports all survive too.

use super::{crc32, io_err, put_f64, put_u32, put_u64, put_u8, SliceReader, StoreError, SyncPolicy};
use crate::net::cohort::CohortSpec;
use crate::net::frame;
use crate::net::wire::{spec_from_wire, spec_to_wire, MAX_WIRE_DIM};
use crate::quant::Message;
use std::fs::OpenOptions;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

const KIND_REPORT: u8 = 0;
const KIND_CLOSE: u8 = 1;

/// Hard cap on one record body: a maximal frame plus envelope headroom.
pub const MAX_RECORD_BYTES: usize = frame::MAX_FRAME_BYTES as usize + 256;

/// Cohort sizes beyond this are rejected at decode (a report for a
/// billion-client cohort is corruption, not a workload).
const MAX_WAL_N: u32 = 1 << 20;

/// One valid WAL record, as replayed.
#[derive(Clone, Debug, PartialEq)]
pub enum WalRecord {
    /// An accepted (deduplicated, validated) client report.
    Report {
        cohort: u64,
        round: u64,
        client: u32,
        spec: CohortSpec,
        /// The *relative* deadline the report carried — a recovered
        /// round's clock restarts at replay time.
        deadline_ms: u64,
        msg: Message,
    },
    /// A round closed and its result was delivered.
    Close {
        cohort: u64,
        round: u64,
        received: u32,
        expected: u32,
        partial: bool,
    },
}

impl WalRecord {
    /// Decode one record body; `None` means the body is corrupt.
    pub(crate) fn decode(body: &[u8]) -> Option<WalRecord> {
        let mut r = SliceReader::new(body);
        match r.u8()? {
            KIND_REPORT => {
                let cohort = r.u64()?;
                let round = r.u64()?;
                let client = r.u32()?;
                let n = r.u32()?;
                let d = r.u32()?;
                let tag = r.u8()?;
                let param = r.u32()?;
                let y = r.f64()?;
                let seed = r.u64()?;
                let deadline_ms = r.u64()?;
                if n == 0 || n > MAX_WAL_N || d == 0 || d > MAX_WIRE_DIM || client >= n {
                    return None;
                }
                let spec = CohortSpec {
                    n: n as usize,
                    d: d as usize,
                    spec: spec_from_wire(tag, param).ok()?,
                    y,
                    seed,
                };
                let mut rest = r.rest();
                let msg = frame::read_frame(&mut rest, frame::MAX_FRAME_BYTES).ok()??;
                if !rest.is_empty() {
                    return None;
                }
                Some(WalRecord::Report {
                    cohort,
                    round,
                    client,
                    spec,
                    deadline_ms,
                    msg,
                })
            }
            KIND_CLOSE => {
                let cohort = r.u64()?;
                let round = r.u64()?;
                let received = r.u32()?;
                let expected = r.u32()?;
                let partial = match r.u8()? {
                    0 => false,
                    1 => true,
                    _ => return None,
                };
                if !r.is_empty() {
                    return None;
                }
                Some(WalRecord::Close {
                    cohort,
                    round,
                    received,
                    expected,
                    partial,
                })
            }
            _ => None,
        }
    }
}

/// Build a report record body (the inverse of [`WalRecord::decode`]).
pub(crate) fn report_body(
    cohort: u64,
    round: u64,
    client: u32,
    spec: &CohortSpec,
    deadline_ms: u64,
    msg: &Message,
) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64 + frame::PREFIX_BYTES + msg.bytes.len());
    put_u8(&mut buf, KIND_REPORT);
    put_u64(&mut buf, cohort);
    put_u64(&mut buf, round);
    put_u32(&mut buf, client);
    put_u32(&mut buf, spec.n as u32);
    put_u32(&mut buf, spec.d as u32);
    let (tag, param) = spec_to_wire(spec.spec);
    put_u8(&mut buf, tag);
    put_u32(&mut buf, param);
    put_f64(&mut buf, spec.y);
    put_u64(&mut buf, spec.seed);
    put_u64(&mut buf, deadline_ms);
    frame::write_frame(&mut buf, msg).expect("writing a frame to a Vec cannot fail");
    buf
}

/// Build a close record body.
pub(crate) fn close_body(
    cohort: u64,
    round: u64,
    received: u32,
    expected: u32,
    partial: bool,
) -> Vec<u8> {
    let mut buf = Vec::with_capacity(32);
    put_u8(&mut buf, KIND_CLOSE);
    put_u64(&mut buf, cohort);
    put_u64(&mut buf, round);
    put_u32(&mut buf, received);
    put_u32(&mut buf, expected);
    put_u8(&mut buf, partial as u8);
    buf
}

/// What [`Wal::open`] cut off the end of a damaged log.
#[derive(Clone, Debug, PartialEq)]
pub struct TailTruncation {
    /// Byte offset of the first bad record — the WAL's valid length
    /// after truncation.
    pub offset: u64,
    /// How many trailing bytes were discarded.
    pub dropped_bytes: u64,
    /// Which validation failed first.
    pub what: &'static str,
}

/// An append-only checksummed log file.
pub struct Wal {
    file: std::fs::File,
    path: PathBuf,
    len: u64,
    sync: SyncPolicy,
}

impl Wal {
    /// Open (or create) the log, validate every record, truncate any
    /// torn/corrupt tail, and return the valid records in append order.
    pub fn open(
        path: &Path,
        sync: SyncPolicy,
    ) -> Result<(Wal, Vec<WalRecord>, Option<TailTruncation>), StoreError> {
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(path)
            .map_err(|e| io_err(path, &e))?;
        let mut buf = Vec::new();
        file.read_to_end(&mut buf).map_err(|e| io_err(path, &e))?;
        let file_len = buf.len() as u64;
        let mut records = Vec::new();
        let mut off = 0usize;
        let mut bad: Option<&'static str> = None;
        while off < buf.len() {
            let rem = buf.len() - off;
            if rem < 8 {
                bad = Some("torn record header");
                break;
            }
            let len = u32::from_le_bytes(buf[off..off + 4].try_into().expect("4 bytes")) as usize;
            let crc = u32::from_le_bytes(buf[off + 4..off + 8].try_into().expect("4 bytes"));
            if len == 0 || len > MAX_RECORD_BYTES {
                bad = Some("impossible record length");
                break;
            }
            if rem - 8 < len {
                bad = Some("torn record body");
                break;
            }
            let body = &buf[off + 8..off + 8 + len];
            if crc32(body) != crc {
                bad = Some("record crc mismatch");
                break;
            }
            match WalRecord::decode(body) {
                Some(r) => records.push(r),
                None => {
                    bad = Some("undecodable record body");
                    break;
                }
            }
            off += 8 + len;
        }
        let valid = off as u64;
        let tail = bad.map(|what| TailTruncation {
            offset: valid,
            dropped_bytes: file_len - valid,
            what,
        });
        if tail.is_some() {
            file.set_len(valid).map_err(|e| io_err(path, &e))?;
        }
        file.seek(SeekFrom::Start(valid)).map_err(|e| io_err(path, &e))?;
        let wal = Wal {
            file,
            path: path.to_path_buf(),
            len: valid,
            sync,
        };
        Ok((wal, records, tail))
    }

    /// Append one record body (length + CRC prepended here). Fsyncs
    /// under [`SyncPolicy::Always`].
    pub fn append(&mut self, body: &[u8]) -> Result<(), StoreError> {
        debug_assert!(!body.is_empty() && body.len() <= MAX_RECORD_BYTES);
        let mut rec = Vec::with_capacity(8 + body.len());
        put_u32(&mut rec, body.len() as u32);
        put_u32(&mut rec, crc32(body));
        rec.extend_from_slice(body);
        self.file.write_all(&rec).map_err(|e| io_err(&self.path, &e))?;
        self.len += rec.len() as u64;
        if self.sync == SyncPolicy::Always {
            self.sync()?;
        }
        Ok(())
    }

    /// Flush appended records to stable storage.
    pub fn sync(&mut self) -> Result<(), StoreError> {
        self.file.sync_data().map_err(|e| io_err(&self.path, &e))
    }

    /// Valid log length in bytes.
    pub fn len(&self) -> u64 {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Checkpoint: drop the whole log (its history is fully reflected
    /// in delivered results) and start appending from offset zero.
    pub fn reset(&mut self) -> Result<(), StoreError> {
        self.file.set_len(0).map_err(|e| io_err(&self.path, &e))?;
        self.file.seek(SeekFrom::Start(0)).map_err(|e| io_err(&self.path, &e))?;
        self.len = 0;
        if self.sync != SyncPolicy::Never {
            self.sync()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CodecSpec;

    fn spec() -> CohortSpec {
        CohortSpec {
            n: 3,
            d: 8,
            spec: CodecSpec::Lq { q: 64 },
            y: 8.0,
            seed: 42,
        }
    }

    fn msg() -> Message {
        Message {
            bytes: vec![0xA5; 11],
            bits: 85,
        }
    }

    #[test]
    fn report_and_close_bodies_roundtrip() {
        let body = report_body(7, 3, 2, &spec(), 1500, &msg());
        match WalRecord::decode(&body) {
            Some(WalRecord::Report {
                cohort,
                round,
                client,
                spec: s,
                deadline_ms,
                msg: m,
            }) => {
                assert_eq!((cohort, round, client, deadline_ms), (7, 3, 2, 1500));
                assert_eq!(s, spec());
                assert_eq!(m, msg());
            }
            other => panic!("expected Report, got {other:?}"),
        }
        let body = close_body(7, 3, 2, 3, true);
        assert_eq!(
            WalRecord::decode(&body),
            Some(WalRecord::Close {
                cohort: 7,
                round: 3,
                received: 2,
                expected: 3,
                partial: true,
            })
        );
    }

    #[test]
    fn corrupt_bodies_decode_to_none_not_panic() {
        // Unknown kind byte.
        assert_eq!(WalRecord::decode(&[9]), None);
        // Empty body.
        assert_eq!(WalRecord::decode(&[]), None);
        // Report cut short mid-envelope.
        let body = report_body(1, 0, 0, &spec(), 0, &msg());
        assert_eq!(WalRecord::decode(&body[..20]), None);
        // Trailing junk after a close record.
        let mut body = close_body(1, 0, 1, 2, false);
        body.push(0);
        assert_eq!(WalRecord::decode(&body), None);
        // Client out of the cohort's range.
        let body = report_body(1, 0, 99, &spec(), 0, &msg());
        assert_eq!(WalRecord::decode(&body), None);
    }
}
