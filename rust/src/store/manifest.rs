//! The manifest: an atomically-replaced snapshot of the store's sealed
//! runs, next run sequence number, and WAL length.
//!
//! Written via `MANIFEST.tmp` + rename so readers only ever observe a
//! complete file. The manifest is *advisory*: recovery replays the
//! self-validating WAL and garbage-collects every run file, so the only
//! state that must survive a crash through the manifest is `next_seq`
//! (keeping run paths monotone across restarts). A corrupt manifest is
//! therefore rebuilt fresh by [`super::Store::open`], not fatal.

use super::{crc32, io_err, put_u32, put_u64, SliceReader, StoreError};
use std::fs::{self, File};
use std::io::Write;
use std::path::Path;

/// Manifest file magic: `"DMEm"`.
pub const MANIFEST_MAGIC: u32 = u32::from_le_bytes(*b"DMEm");

const MAX_MANIFEST_RUNS: u32 = 1 << 20;

/// Snapshot of the store's on-disk layout at the last state change.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Manifest {
    /// WAL length when this snapshot was written — diagnostic only (the
    /// WAL is self-validating; recovery trusts its own scan).
    pub wal_len: u64,
    /// Next run sequence number to allocate.
    pub next_seq: u64,
    /// `(seq, cohort, round)` for every sealed run at write time.
    pub runs: Vec<(u64, u64, u64)>,
}

impl Manifest {
    /// Load the manifest; `Ok(None)` if none exists yet, a typed
    /// [`StoreError::Corrupt`] (which the store treats as "rebuild") if
    /// validation fails.
    pub fn load(path: &Path) -> Result<Option<Manifest>, StoreError> {
        let buf = match fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(io_err(path, &e)),
        };
        let corrupt = |offset: u64, what: &'static str| StoreError::Corrupt {
            path: path.display().to_string(),
            offset,
            what,
        };
        if buf.len() < 8 {
            return Err(corrupt(0, "manifest shorter than its header"));
        }
        let magic = u32::from_le_bytes(buf[0..4].try_into().expect("4 bytes"));
        if magic != MANIFEST_MAGIC {
            return Err(corrupt(0, "bad manifest magic"));
        }
        let crc = u32::from_le_bytes(buf[4..8].try_into().expect("4 bytes"));
        let body = &buf[8..];
        if crc32(body) != crc {
            return Err(corrupt(8, "manifest crc mismatch"));
        }
        let bad = || corrupt(8, "undecodable manifest body");
        let mut r = SliceReader::new(body);
        let wal_len = r.u64().ok_or_else(bad)?;
        let next_seq = r.u64().ok_or_else(bad)?;
        let count = r.u32().ok_or_else(bad)?;
        if count > MAX_MANIFEST_RUNS {
            return Err(corrupt(8, "manifest run count out of range"));
        }
        let mut runs = Vec::with_capacity(count as usize);
        for _ in 0..count {
            let seq = r.u64().ok_or_else(bad)?;
            let cohort = r.u64().ok_or_else(bad)?;
            let round = r.u64().ok_or_else(bad)?;
            runs.push((seq, cohort, round));
        }
        if !r.is_empty() {
            return Err(corrupt(8, "trailing bytes after manifest body"));
        }
        Ok(Some(Manifest {
            wal_len,
            next_seq,
            runs,
        }))
    }

    /// Write atomically: serialize to `<path>.tmp`, then rename over.
    pub fn save(&self, path: &Path, do_sync: bool) -> Result<(), StoreError> {
        let mut body = Vec::with_capacity(24 + 24 * self.runs.len());
        put_u64(&mut body, self.wal_len);
        put_u64(&mut body, self.next_seq);
        put_u32(&mut body, self.runs.len() as u32);
        for &(seq, cohort, round) in &self.runs {
            put_u64(&mut body, seq);
            put_u64(&mut body, cohort);
            put_u64(&mut body, round);
        }
        let mut out = Vec::with_capacity(8 + body.len());
        put_u32(&mut out, MANIFEST_MAGIC);
        put_u32(&mut out, crc32(&body));
        out.extend_from_slice(&body);
        let tmp = path.with_extension("tmp");
        {
            let mut f = File::create(&tmp).map_err(|e| io_err(&tmp, &e))?;
            f.write_all(&out).map_err(|e| io_err(&tmp, &e))?;
            if do_sync {
                f.sync_data().map_err(|e| io_err(&tmp, &e))?;
            }
        }
        fs::rename(&tmp, path).map_err(|e| io_err(path, &e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn temp_path(tag: &str) -> std::path::PathBuf {
        static N: AtomicUsize = AtomicUsize::new(0);
        let n = N.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!("dme-manifest-{}-{tag}-{n}", std::process::id()))
    }

    #[test]
    fn manifest_roundtrips_and_missing_is_none() {
        let path = temp_path("roundtrip");
        assert_eq!(Manifest::load(&path).expect("missing is fine"), None);
        let m = Manifest {
            wal_len: 4096,
            next_seq: 17,
            runs: vec![(15, 8, 0), (16, 8, 1)],
        };
        m.save(&path, false).expect("save");
        assert_eq!(Manifest::load(&path).expect("load"), Some(m));
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn corrupt_manifest_is_a_typed_error() {
        let path = temp_path("corrupt");
        let m = Manifest {
            wal_len: 10,
            next_seq: 1,
            runs: vec![],
        };
        m.save(&path, false).expect("save");
        let mut bytes = fs::read(&path).expect("read");
        let last = bytes.len() - 1;
        bytes[last] ^= 0x80;
        fs::write(&path, &bytes).expect("rewrite");
        match Manifest::load(&path) {
            Err(StoreError::Corrupt { what, .. }) => assert_eq!(what, "manifest crc mismatch"),
            other => panic!("expected Corrupt, got {other:?}"),
        }
        let _ = fs::remove_file(&path);
    }
}
