//! Durable storage for the DME service — write-ahead log, spill-to-disk
//! partial-aggregate runs, and a manifest, LSM-style.
//!
//! The paper's coordinator folds every client report into one in-RAM
//! accumulator ([`crate::net::cohort::CohortTable`]): a crashed leader
//! loses the whole round, and huge-`d` cohorts are capped by memory.
//! This module fixes both without changing a single output bit of the
//! streaming-fold semantics:
//!
//! - **Write-ahead log** ([`Wal`]): every accepted report is appended to
//!   `wal.log` *before* it is folded, as a CRC-checksummed record whose
//!   payload reuses the [`crate::net::frame`] wire format verbatim plus
//!   a `(cohort, round, client)` envelope. Torn or bit-flipped tails are
//!   detected on open and truncated back to the last valid record —
//!   reported as a [`TailTruncation`], never a panic.
//! - **Runs** ([`RunImage`]): when open accumulators exceed a memory
//!   budget, a round's exact `f64` accumulator image is sealed to an
//!   on-disk `run-<seq>.dat` and later reports queue as pending frames;
//!   at compaction or round close the image is loaded back and the
//!   pending frames fold in arrival order — the identical left-to-right
//!   IEEE addition sequence as the all-in-RAM fold, so the result is
//!   bit-identical (a naive merge of independent partial sums would not
//!   be: `f64` addition is not associative).
//! - **Manifest** (`MANIFEST`): an atomically-replaced snapshot of the
//!   sealed runs, the next run sequence number and the WAL length.
//!   Recovery replays the self-validating WAL from offset zero and
//!   garbage-collects every run file, so the manifest is advisory — a
//!   corrupt manifest is rebuilt, not fatal.
//!
//! # Durability vs the paper's bit-cost model
//!
//! The paper meters communication in quantized bits per machine
//! (`msg.bits`); durability adds *disk* bytes on top, invisible to that
//! model: each logged report costs its frame bytes plus a ~57-byte
//! record envelope. The real trade-off is latency, set by
//! [`SyncPolicy`]: `always` issues one fsync per accepted report —
//! millisecond-scale, dominating the microsecond fold, but a kill -9
//! never loses an acknowledged report; `close` (the default) amortizes
//! one fsync per *round* — a crash can drop reports accepted since the
//! last close, but replay still recovers every round closed before the
//! crash; `never` leaves flushing to the OS. The `transport_bench`
//! durability rows measure exactly this spread.

mod manifest;
mod runs;
mod wal;

pub use manifest::Manifest;
pub use runs::RunImage;
pub use wal::{TailTruncation, Wal, WalRecord, MAX_RECORD_BYTES};

use crate::net::cohort::{CohortKey, CohortSpec};
use crate::net::error::TransportError;
use crate::quant::Message;
use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// The write-ahead log's file name inside a data dir.
pub const WAL_FILE: &str = "wal.log";
/// The manifest's file name inside a data dir.
pub const MANIFEST_FILE: &str = "MANIFEST";

/// A storage failure, in a comparable form tests can assert on
/// (`io::Error` is neither `Clone` nor `PartialEq`).
#[derive(Clone, Debug, PartialEq)]
pub enum StoreError {
    /// An I/O operation failed.
    Io {
        path: String,
        kind: io::ErrorKind,
        detail: String,
    },
    /// A file's contents failed validation (magic, CRC, or decode).
    Corrupt {
        path: String,
        /// Byte offset of the first bad structure.
        offset: u64,
        what: &'static str,
    },
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::Io { path, detail, .. } => {
                write!(f, "store i/o error at {path}: {detail}")
            }
            StoreError::Corrupt { path, offset, what } => {
                write!(f, "store corruption in {path} at byte {offset}: {what}")
            }
        }
    }
}

impl std::error::Error for StoreError {}

impl From<StoreError> for TransportError {
    fn from(e: StoreError) -> Self {
        match e {
            StoreError::Io { path, kind, detail } => TransportError::Io {
                kind,
                detail: format!("{path}: {detail}"),
            },
            StoreError::Corrupt { path, offset, what } => TransportError::Io {
                kind: io::ErrorKind::InvalidData,
                detail: format!("{path} corrupt at byte {offset}: {what}"),
            },
        }
    }
}

pub(crate) fn io_err(path: &Path, e: &io::Error) -> StoreError {
    StoreError::Io {
        path: path.display().to_string(),
        kind: e.kind(),
        detail: e.to_string(),
    }
}

/// When the WAL is flushed to stable storage (see the module docs for
/// the latency/durability trade-off each point buys).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SyncPolicy {
    /// fsync after every appended report — no acknowledged report is
    /// ever lost, at one disk flush per report.
    Always,
    /// fsync when a round closes (and at checkpoints) — one flush per
    /// round; a crash can lose reports accepted since the last close.
    #[default]
    OnClose,
    /// Never fsync explicitly; the OS flushes when it pleases.
    Never,
}

impl std::str::FromStr for SyncPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "always" => Ok(SyncPolicy::Always),
            "close" => Ok(SyncPolicy::OnClose),
            "never" => Ok(SyncPolicy::Never),
            other => Err(format!("unknown sync policy '{other}' (expected always|close|never)")),
        }
    }
}

/// Durability configuration for a [`crate::net::cohort::CohortTable`]
/// or a `dme serve` process (`data_dir=` / `mem_budget=` / `sync=`).
#[derive(Clone, Debug)]
pub struct DurabilityOpts {
    /// Directory holding `wal.log`, `MANIFEST` and `run-*.dat`.
    pub data_dir: PathBuf,
    /// Spill open accumulators to disk runs once their resident bytes
    /// exceed this budget (`usize::MAX` = never spill, `0` = spill
    /// everything).
    pub mem_budget: usize,
    pub sync: SyncPolicy,
}

impl DurabilityOpts {
    /// Durability at `data_dir` with an unbounded memory budget and the
    /// default [`SyncPolicy::OnClose`].
    pub fn new(data_dir: impl Into<PathBuf>) -> Self {
        DurabilityOpts {
            data_dir: data_dir.into(),
            mem_budget: usize::MAX,
            sync: SyncPolicy::default(),
        }
    }
}

/// What [`Store::open`] found on disk.
#[derive(Clone, Debug, Default)]
pub struct RecoveryInfo {
    /// Valid WAL bytes after tail validation.
    pub wal_bytes: u64,
    /// Present iff a torn/corrupt tail was truncated away.
    pub tail: Option<TailTruncation>,
    /// Run files deleted at open (recovery is WAL-replay-only, so every
    /// run on disk is stale).
    pub stale_runs_removed: usize,
    /// The manifest failed validation and was rebuilt fresh.
    pub manifest_rebuilt: bool,
}

/// One data dir's WAL + runs + manifest, owned by a single leader.
pub struct Store {
    dir: PathBuf,
    wal: Wal,
    sync: SyncPolicy,
    /// Next run sequence number; monotone across restarts (seeded from
    /// the manifest) so a live run path never collides with a stale one.
    next_seq: u64,
    /// Sealed runs: `seq -> (cohort, round)`.
    runs: BTreeMap<u64, (u64, u64)>,
}

impl Store {
    /// Open (or create) a data dir: validate the WAL — truncating any
    /// torn/corrupt tail — delete stale run files, and return the valid
    /// records for the caller to replay.
    pub fn open(
        opts: &DurabilityOpts,
    ) -> Result<(Store, Vec<WalRecord>, RecoveryInfo), StoreError> {
        let dir = opts.data_dir.clone();
        fs::create_dir_all(&dir).map_err(|e| io_err(&dir, &e))?;
        let manifest_path = dir.join(MANIFEST_FILE);
        let (manifest, manifest_rebuilt) = match Manifest::load(&manifest_path) {
            Ok(m) => (m, false),
            Err(StoreError::Corrupt { .. }) => (None, true),
            Err(e) => return Err(e),
        };
        // GC every run file, manifest-listed and stray alike: recovery
        // replays the WAL from offset zero, which re-derives (and may
        // re-spill) everything a run ever held.
        let mut stale_runs_removed = 0usize;
        for entry in fs::read_dir(&dir).map_err(|e| io_err(&dir, &e))? {
            let entry = entry.map_err(|e| io_err(&dir, &e))?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if name.starts_with("run-") && name.ends_with(".dat") {
                let p = entry.path();
                fs::remove_file(&p).map_err(|e| io_err(&p, &e))?;
                stale_runs_removed += 1;
            }
        }
        let next_seq = manifest.as_ref().map_or(0, |m| m.next_seq);
        let (wal, records, tail) = Wal::open(&dir.join(WAL_FILE), opts.sync)?;
        let store = Store {
            dir,
            wal,
            sync: opts.sync,
            next_seq,
            runs: BTreeMap::new(),
        };
        store.write_manifest()?;
        let info = RecoveryInfo {
            wal_bytes: store.wal.len(),
            tail,
            stale_runs_removed,
            manifest_rebuilt,
        };
        Ok((store, records, info))
    }

    /// Append one accepted report to the WAL (fsynced under
    /// [`SyncPolicy::Always`]). Must happen *before* the fold.
    pub fn log_report(
        &mut self,
        key: CohortKey,
        spec: &CohortSpec,
        client: u32,
        deadline_ms: u64,
        msg: &Message,
    ) -> Result<(), StoreError> {
        let body = wal::report_body(key.cohort, key.round, client, spec, deadline_ms, msg);
        self.wal.append(&body)
    }

    /// Append a round-close marker to the WAL.
    pub fn log_close(
        &mut self,
        key: CohortKey,
        received: u32,
        expected: u32,
        partial: bool,
    ) -> Result<(), StoreError> {
        let body = wal::close_body(key.cohort, key.round, received, expected, partial);
        self.wal.append(&body)
    }

    /// The round-close flush point: fsync unless the policy is `never`.
    pub fn sync_on_close(&mut self) -> Result<(), StoreError> {
        if self.sync == SyncPolicy::Never {
            return Ok(());
        }
        self.wal.sync()
    }

    /// Seal one accumulator image as an on-disk run; returns its seq.
    pub fn seal_run(&mut self, image: &RunImage) -> Result<u64, StoreError> {
        let seq = self.next_seq;
        // Consumed even if the write fails: sequence numbers are never
        // reused, so a half-written file can't shadow a later run.
        self.next_seq += 1;
        let path = self.run_path(seq);
        runs::write_run(&path, image, self.sync == SyncPolicy::Always)?;
        self.runs.insert(seq, (image.cohort, image.round));
        self.write_manifest()?;
        Ok(seq)
    }

    /// Load a sealed run's exact accumulator image back.
    pub fn load_run(&self, seq: u64) -> Result<RunImage, StoreError> {
        runs::read_run(&self.run_path(seq))
    }

    /// Delete a sealed run (missing file is fine — it was already GC'd).
    pub fn drop_run(&mut self, seq: u64) -> Result<(), StoreError> {
        self.runs.remove(&seq);
        let path = self.run_path(seq);
        match fs::remove_file(&path) {
            Ok(()) => {}
            Err(e) if e.kind() == io::ErrorKind::NotFound => {}
            Err(e) => return Err(io_err(&path, &e)),
        }
        self.write_manifest()
    }

    /// All rounds closed: truncate the WAL (its history is fully
    /// reflected in delivered results) and snapshot a fresh manifest.
    pub fn checkpoint(&mut self) -> Result<(), StoreError> {
        self.wal.reset()?;
        self.write_manifest()
    }

    /// Current valid WAL length in bytes.
    pub fn wal_len(&self) -> u64 {
        self.wal.len()
    }

    /// Sealed-run count (live spill state, not a recovery source).
    pub fn run_count(&self) -> usize {
        self.runs.len()
    }

    fn run_path(&self, seq: u64) -> PathBuf {
        self.dir.join(format!("run-{seq}.dat"))
    }

    fn write_manifest(&self) -> Result<(), StoreError> {
        let m = Manifest {
            wal_len: self.wal.len(),
            next_seq: self.next_seq,
            runs: self.runs.iter().map(|(&s, &(c, r))| (s, c, r)).collect(),
        };
        m.save(&self.dir.join(MANIFEST_FILE), self.sync != SyncPolicy::Never)
    }
}

// --- CRC32 (IEEE 802.3, poly 0xEDB88320) — hand-rolled, no deps ------

const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC32 of `bytes` (IEEE polynomial, init/final xor `0xFFFF_FFFF`).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// --- little-endian record primitives ---------------------------------

pub(crate) fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

pub(crate) fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Bounds-checked little-endian cursor over a record body. Every getter
/// returns `None` past the end — corrupt bytes surface as a typed
/// decode failure, never a panic.
pub(crate) struct SliceReader<'a> {
    buf: &'a [u8],
}

impl<'a> SliceReader<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        SliceReader { buf }
    }

    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        if self.buf.len() < n {
            return None;
        }
        let (head, rest) = self.buf.split_at(n);
        self.buf = rest;
        Some(head)
    }

    pub(crate) fn u8(&mut self) -> Option<u8> {
        self.take(1).map(|b| b[0])
    }

    pub(crate) fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|b| u32::from_le_bytes(b.try_into().expect("4 bytes")))
    }

    pub(crate) fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|b| u64::from_le_bytes(b.try_into().expect("8 bytes")))
    }

    pub(crate) fn f64(&mut self) -> Option<f64> {
        self.take(8).map(|b| f64::from_le_bytes(b.try_into().expect("8 bytes")))
    }

    /// Everything not yet consumed.
    pub(crate) fn rest(self) -> &'a [u8] {
        self.buf
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_the_ieee_check_vector() {
        // The canonical CRC-32/ISO-HDLC check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // One flipped bit changes the sum.
        assert_ne!(crc32(b"123456788"), crc32(b"123456789"));
    }

    #[test]
    fn slice_reader_is_bounds_checked() {
        let mut buf = Vec::new();
        put_u8(&mut buf, 7);
        put_u32(&mut buf, 0xDEAD_BEEF);
        put_u64(&mut buf, 42);
        put_f64(&mut buf, -1.5);
        let mut r = SliceReader::new(&buf);
        assert_eq!(r.u8(), Some(7));
        assert_eq!(r.u32(), Some(0xDEAD_BEEF));
        assert_eq!(r.u64(), Some(42));
        assert_eq!(r.f64(), Some(-1.5));
        assert!(r.is_empty());
        assert_eq!(r.u8(), None, "reads past the end are None, not panics");
    }

    #[test]
    fn sync_policy_parses_its_cli_forms() {
        assert_eq!("always".parse(), Ok(SyncPolicy::Always));
        assert_eq!("close".parse(), Ok(SyncPolicy::OnClose));
        assert_eq!("never".parse(), Ok(SyncPolicy::Never));
        assert!("fsync".parse::<SyncPolicy>().is_err());
        assert_eq!(SyncPolicy::default(), SyncPolicy::OnClose);
    }
}
