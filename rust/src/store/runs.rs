//! Sealed partial-aggregate runs: `run-<seq>.dat` files holding one
//! open round's **exact** `f64` accumulator image.
//!
//! Bit-identity is the whole design: IEEE addition is not associative,
//! so merging independently-folded partial sums would not reproduce the
//! all-in-RAM fold. A run therefore seals the accumulator *as folded so
//! far* (the raw `f64` bit patterns), and every report that arrives
//! after the spill is kept as a pending frame; compaction and round
//! close load the image back and fold the pending frames in arrival
//! order — the identical left-to-right addition sequence, hence the
//! identical bits ([`crate::quant::VectorCodec::decode_accumulate_into`]
//! is a pure function of the codec, and cohort codecs rebuild
//! deterministically from `(spec, round)`).
//!
//! Format: `"DMEa"` magic + CRC over the body, then the round envelope,
//! the [`crate::net::cohort::CohortSpec`], the received/got bitmap
//! snapshot and the accumulator. Runs are the *live* spill mechanism
//! only — recovery replays the WAL and deletes every run file on open —
//! so a failed validation here is a typed [`StoreError::Corrupt`], and
//! the in-RAM received/got stay authoritative (close reads only `acc`).

use super::{crc32, io_err, put_f64, put_u32, put_u64, put_u8, SliceReader, StoreError};
use crate::net::cohort::CohortSpec;
use crate::net::wire::{spec_from_wire, spec_to_wire, MAX_WIRE_DIM};
use std::fs::{self, File};
use std::io::Write;
use std::path::Path;

/// Run file magic: `"DMEa"` (aggregate).
pub const RUN_MAGIC: u32 = u32::from_le_bytes(*b"DMEa");

const MAX_RUN_N: u32 = 1 << 20;

/// One spilled round's exact fold state.
#[derive(Clone, Debug, PartialEq)]
pub struct RunImage {
    pub cohort: u64,
    pub round: u64,
    pub spec: CohortSpec,
    /// Absolute-deadline snapshot (caller clock) — diagnostic only.
    pub deadline_ms: u64,
    /// Reports folded into `acc` at seal time (snapshot; the open
    /// round's RAM copy stays authoritative).
    pub received: u32,
    pub got: Vec<bool>,
    /// The accumulator's exact `f64` bit image.
    pub acc: Vec<f64>,
}

pub(crate) fn write_run(path: &Path, image: &RunImage, do_sync: bool) -> Result<(), StoreError> {
    let mut body = Vec::with_capacity(64 + image.got.len() + 8 * image.acc.len());
    put_u64(&mut body, image.cohort);
    put_u64(&mut body, image.round);
    put_u32(&mut body, image.spec.n as u32);
    put_u32(&mut body, image.spec.d as u32);
    let (tag, param) = spec_to_wire(image.spec.spec);
    put_u8(&mut body, tag);
    put_u32(&mut body, param);
    put_f64(&mut body, image.spec.y);
    put_u64(&mut body, image.spec.seed);
    put_u64(&mut body, image.deadline_ms);
    put_u32(&mut body, image.received);
    for &g in &image.got {
        put_u8(&mut body, g as u8);
    }
    for &a in &image.acc {
        put_f64(&mut body, a);
    }
    let mut out = Vec::with_capacity(8 + body.len());
    put_u32(&mut out, RUN_MAGIC);
    put_u32(&mut out, crc32(&body));
    out.extend_from_slice(&body);
    let mut f = File::create(path).map_err(|e| io_err(path, &e))?;
    f.write_all(&out).map_err(|e| io_err(path, &e))?;
    if do_sync {
        f.sync_data().map_err(|e| io_err(path, &e))?;
    }
    Ok(())
}

pub(crate) fn read_run(path: &Path) -> Result<RunImage, StoreError> {
    let buf = fs::read(path).map_err(|e| io_err(path, &e))?;
    let corrupt = |offset: u64, what: &'static str| StoreError::Corrupt {
        path: path.display().to_string(),
        offset,
        what,
    };
    if buf.len() < 8 {
        return Err(corrupt(0, "run file shorter than its header"));
    }
    let magic = u32::from_le_bytes(buf[0..4].try_into().expect("4 bytes"));
    if magic != RUN_MAGIC {
        return Err(corrupt(0, "bad run magic"));
    }
    let crc = u32::from_le_bytes(buf[4..8].try_into().expect("4 bytes"));
    let body = &buf[8..];
    if crc32(body) != crc {
        return Err(corrupt(8, "run crc mismatch"));
    }
    let bad = || corrupt(8, "undecodable run body");
    let mut r = SliceReader::new(body);
    let cohort = r.u64().ok_or_else(bad)?;
    let round = r.u64().ok_or_else(bad)?;
    let n = r.u32().ok_or_else(bad)?;
    let d = r.u32().ok_or_else(bad)?;
    if n == 0 || n > MAX_RUN_N || d == 0 || d > MAX_WIRE_DIM {
        return Err(corrupt(8, "run dimensions out of range"));
    }
    let tag = r.u8().ok_or_else(bad)?;
    let param = r.u32().ok_or_else(bad)?;
    let spec = spec_from_wire(tag, param).map_err(|_| corrupt(8, "unknown codec tag in run"))?;
    let y = r.f64().ok_or_else(bad)?;
    let seed = r.u64().ok_or_else(bad)?;
    let deadline_ms = r.u64().ok_or_else(bad)?;
    let received = r.u32().ok_or_else(bad)?;
    if received > n {
        return Err(corrupt(8, "run received exceeds its cohort size"));
    }
    let mut got = Vec::with_capacity(n as usize);
    for _ in 0..n {
        match r.u8().ok_or_else(bad)? {
            0 => got.push(false),
            1 => got.push(true),
            _ => return Err(corrupt(8, "run got-flag out of range")),
        }
    }
    let mut acc = Vec::with_capacity(d as usize);
    for _ in 0..d {
        acc.push(r.f64().ok_or_else(bad)?);
    }
    if !r.is_empty() {
        return Err(corrupt(8, "trailing bytes after run body"));
    }
    Ok(RunImage {
        cohort,
        round,
        spec: CohortSpec {
            n: n as usize,
            d: d as usize,
            spec,
            y,
            seed,
        },
        deadline_ms,
        received,
        got,
        acc,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CodecSpec;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn temp_path(tag: &str) -> std::path::PathBuf {
        static N: AtomicUsize = AtomicUsize::new(0);
        let n = N.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!("dme-run-{}-{tag}-{n}.dat", std::process::id()))
    }

    fn image() -> RunImage {
        RunImage {
            cohort: 9,
            round: 2,
            spec: CohortSpec {
                n: 4,
                d: 6,
                spec: CodecSpec::Lq { q: 64 },
                y: 8.0,
                seed: 42,
            },
            deadline_ms: 1234,
            received: 2,
            got: vec![true, false, true, false],
            // Awkward bit patterns must survive exactly: negative zero,
            // subnormals, and values with no short decimal form.
            acc: vec![-0.0, 1.5e-310, 0.1 + 0.2, -7.25, f64::MAX, 3.0],
        }
    }

    #[test]
    fn run_image_roundtrips_bit_exactly() {
        let path = temp_path("roundtrip");
        let img = image();
        write_run(&path, &img, false).expect("write run");
        let back = read_run(&path).expect("read run");
        // Compare accumulator *bits*, not float equality (-0.0 == 0.0).
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back.acc), bits(&img.acc));
        assert_eq!(back, img);
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn corrupt_run_files_are_typed_errors_not_panics() {
        let path = temp_path("corrupt");
        let img = image();
        write_run(&path, &img, false).expect("write run");
        let mut bytes = fs::read(&path).expect("read back");
        // Flip one accumulator bit: CRC must catch it.
        let last = bytes.len() - 4;
        bytes[last] ^= 0x10;
        fs::write(&path, &bytes).expect("rewrite");
        match read_run(&path) {
            Err(StoreError::Corrupt { what, .. }) => assert_eq!(what, "run crc mismatch"),
            other => panic!("expected Corrupt, got {other:?}"),
        }
        // Truncated file.
        fs::write(&path, &bytes[..5]).expect("truncate");
        assert!(matches!(read_run(&path), Err(StoreError::Corrupt { .. })));
        // Wrong magic.
        let mut bytes = fs::read(&path).expect("read back");
        bytes.clear();
        bytes.extend_from_slice(b"NOPE\0\0\0\0");
        fs::write(&path, &bytes).expect("rewrite");
        match read_run(&path) {
            Err(StoreError::Corrupt { what, .. }) => assert_eq!(what, "bad run magic"),
            other => panic!("expected Corrupt, got {other:?}"),
        }
        let _ = fs::remove_file(&path);
    }
}
