//! PJRT runtime: load and execute AOT-compiled XLA artifacts.
//!
//! `python/compile/aot.py` lowers the Layer-2 JAX graphs (which in turn call
//! the Layer-1 Pallas kernels) to **HLO text** under `artifacts/`. This module
//! wraps the `xla` crate (`PjRtClient` over the PJRT C API) so the Layer-3
//! coordinator can execute those graphs from the hot path without any Python.
//!
//! HLO *text* (not serialized `HloModuleProto`) is the interchange format:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids and round-trips cleanly.

#[cfg(feature = "pjrt")]
mod client;
#[cfg(not(feature = "pjrt"))]
mod client_stub;
mod manifest;

#[cfg(feature = "pjrt")]
pub use client::{Engine, LoadedGraph};
#[cfg(not(feature = "pjrt"))]
pub use client_stub::{Engine, LoadedGraph};
pub use manifest::{ArtifactManifest, ArtifactSpec};

/// Runtime-layer error (the offline toolchain has no `anyhow`; this is a
/// plain message type that composes with `Box<dyn Error>` call sites).
#[derive(Clone, Debug)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// Result alias used throughout the runtime layer.
pub type Result<T> = std::result::Result<T, RuntimeError>;

pub(crate) fn rt_err(msg: impl Into<String>) -> RuntimeError {
    RuntimeError(msg.into())
}

/// Default artifact directory relative to the repository root.
pub const ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: `$DME_ARTIFACTS`, else `artifacts/` in the
/// current dir, else walking up to 3 parents (so examples/tests work from
/// `target/` working directories).
pub fn find_artifact_dir() -> Option<std::path::PathBuf> {
    if let Ok(p) = std::env::var("DME_ARTIFACTS") {
        let p = std::path::PathBuf::from(p);
        if p.is_dir() {
            return Some(p);
        }
    }
    let mut dir = std::env::current_dir().ok()?;
    for _ in 0..4 {
        let cand = dir.join(ARTIFACT_DIR);
        if cand.join("manifest.json").is_file() {
            return Some(cand);
        }
        if !dir.pop() {
            break;
        }
    }
    None
}
