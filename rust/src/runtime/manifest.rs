//! Artifact manifest: describes every AOT-compiled HLO module emitted by
//! `python/compile/aot.py` (name, file, input/output shapes and dtypes, and
//! the static parameters the graph was specialized with).

use super::{rt_err, Result};
use crate::config::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One AOT-compiled graph.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// Logical name, e.g. `lattice_encode_d128`.
    pub name: String,
    /// HLO text file, relative to the artifact dir.
    pub file: String,
    /// Input shapes (row-major dims per argument).
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes (the graph returns a tuple).
    pub outputs: Vec<Vec<usize>>,
    /// Static specialization parameters (e.g. `{"d": 128, "q": 8}`).
    pub params: BTreeMap<String, f64>,
}

/// The parsed `manifest.json`.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub specs: BTreeMap<String, ArtifactSpec>,
}

fn shapes(v: &Json) -> Result<Vec<Vec<usize>>> {
    v.as_arr()
        .ok_or_else(|| rt_err("expected array of shapes"))?
        .iter()
        .map(|s| {
            s.as_arr()
                .ok_or_else(|| rt_err("expected shape array"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| rt_err("expected dim")))
                .collect()
        })
        .collect()
}

impl ArtifactManifest {
    /// Load `manifest.json` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| rt_err(format!("reading {}: {e}", path.display())))?;
        let json = Json::parse(&text)
            .map_err(|e| rt_err(format!("parsing {}: {e}", path.display())))?;
        let mut specs = BTreeMap::new();
        let graphs = json
            .get("graphs")
            .and_then(|g| g.as_arr())
            .ok_or_else(|| rt_err("manifest missing 'graphs' array"))?;
        for g in graphs {
            let name = g
                .get("name")
                .and_then(|n| n.as_str())
                .ok_or_else(|| rt_err("graph missing 'name'"))?
                .to_string();
            let file = g
                .get("file")
                .and_then(|n| n.as_str())
                .ok_or_else(|| rt_err("graph missing 'file'"))?
                .to_string();
            let inputs = shapes(g.get("inputs").ok_or_else(|| rt_err("missing inputs"))?)?;
            let outputs = shapes(g.get("outputs").ok_or_else(|| rt_err("missing outputs"))?)?;
            let mut params = BTreeMap::new();
            if let Some(p) = g.get("params").and_then(|p| p.as_obj()) {
                for (k, v) in p {
                    if let Some(n) = v.as_f64() {
                        params.insert(k.clone(), n);
                    }
                }
            }
            specs.insert(
                name.clone(),
                ArtifactSpec {
                    name,
                    file,
                    inputs,
                    outputs,
                    params,
                },
            );
        }
        Ok(ArtifactManifest {
            dir: dir.to_path_buf(),
            specs,
        })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.specs
            .get(name)
            .ok_or_else(|| rt_err(format!("artifact '{name}' not in manifest")))
    }

    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_manifest_from_temp() {
        let dir = std::env::temp_dir().join(format!("dme_manifest_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"graphs": [{"name": "g1", "file": "g1.hlo.txt",
                "inputs": [[2,2],[2,2]], "outputs": [[2,2]],
                "params": {"d": 2}}]}"#,
        )
        .unwrap();
        let m = ArtifactManifest::load(&dir).unwrap();
        let s = m.get("g1").unwrap();
        assert_eq!(s.inputs, vec![vec![2, 2], vec![2, 2]]);
        assert_eq!(s.params.get("d"), Some(&2.0));
        assert!(m.get("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
