//! PJRT engine: compiles HLO-text artifacts once, executes them many times.

use super::manifest::ArtifactManifest;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

/// A compiled, ready-to-run XLA graph.
pub struct LoadedGraph {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    /// Output shapes from the manifest (the graph returns a tuple).
    pub out_shapes: Vec<Vec<usize>>,
}

impl LoadedGraph {
    /// Execute with f32 inputs; returns each tuple element flattened.
    ///
    /// `inputs` are (data, dims) pairs; dims must match the artifact spec.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = xla::Literal::vec1(data);
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            let lit = if dims.len() == 1 {
                lit
            } else {
                lit.reshape(&dims_i64)
                    .with_context(|| format!("reshape input to {dims:?}"))?
            };
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing graph '{}'", self.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // aot.py lowers with return_tuple=True: decompose the tuple.
        let elems = out.to_tuple().context("decomposing result tuple")?;
        let mut flat = Vec::with_capacity(elems.len());
        for e in elems {
            flat.push(e.to_vec::<f32>().context("reading f32 output")?);
        }
        Ok(flat)
    }
}

/// The runtime engine: a PJRT CPU client plus a cache of compiled graphs.
///
/// Compilation happens once per artifact (at startup or first use); the
/// request path only executes.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: ArtifactManifest,
    cache: Mutex<HashMap<String, std::sync::Arc<LoadedGraph>>>,
}

impl Engine {
    /// Create an engine over the artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let manifest = ArtifactManifest::load(artifact_dir)?;
        Ok(Engine {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Create an engine by auto-discovering the artifact directory.
    pub fn discover() -> Result<Self> {
        let dir = super::find_artifact_dir()
            .ok_or_else(|| anyhow!("artifact dir not found; run `make artifacts`"))?;
        Self::new(&dir)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile) a graph by manifest name, caching the executable.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<LoadedGraph>> {
        if let Some(g) = self.cache.lock().unwrap().get(name) {
            return Ok(g.clone());
        }
        let spec = self.manifest.get(name)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling '{name}': {e:?}"))?;
        let graph = std::sync::Arc::new(LoadedGraph {
            name: name.to_string(),
            exe,
            out_shapes: spec.outputs.clone(),
        });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), graph.clone());
        Ok(graph)
    }
}
