//! PJRT engine: compiles HLO-text artifacts once, executes them many times.
//!
//! Compiled only with the `pjrt` cargo feature, which additionally requires
//! the `xla` crate (PjRtClient over the PJRT C API) to be vendored into the
//! build environment; without the feature, `client_stub` provides the same
//! API surface with `Engine::discover()` reporting the missing backend.

use super::manifest::ArtifactManifest;
use super::{rt_err, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

/// A compiled, ready-to-run XLA graph.
pub struct LoadedGraph {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    /// Output shapes from the manifest (the graph returns a tuple).
    pub out_shapes: Vec<Vec<usize>>,
}

impl LoadedGraph {
    /// Execute with f32 inputs; returns each tuple element flattened.
    ///
    /// `inputs` are (data, dims) pairs; dims must match the artifact spec.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = xla::Literal::vec1(data);
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            let lit = if dims.len() == 1 {
                lit
            } else {
                lit.reshape(&dims_i64)
                    .map_err(|e| rt_err(format!("reshape input to {dims:?}: {e:?}")))?
            };
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| rt_err(format!("executing graph '{}': {e:?}", self.name)))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| rt_err(format!("fetching result literal: {e:?}")))?;
        // aot.py lowers with return_tuple=True: decompose the tuple.
        let elems = out
            .to_tuple()
            .map_err(|e| rt_err(format!("decomposing result tuple: {e:?}")))?;
        let mut flat = Vec::with_capacity(elems.len());
        for e in elems {
            flat.push(
                e.to_vec::<f32>()
                    .map_err(|e| rt_err(format!("reading f32 output: {e:?}")))?,
            );
        }
        Ok(flat)
    }
}

/// The runtime engine: a PJRT CPU client plus a cache of compiled graphs.
///
/// Compilation happens once per artifact (at startup or first use); the
/// request path only executes.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: ArtifactManifest,
    cache: Mutex<HashMap<String, std::sync::Arc<LoadedGraph>>>,
}

impl Engine {
    /// Create an engine over the artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| rt_err(format!("PJRT cpu client: {e:?}")))?;
        let manifest = ArtifactManifest::load(artifact_dir)?;
        Ok(Engine {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Create an engine by auto-discovering the artifact directory.
    pub fn discover() -> Result<Self> {
        let dir = super::find_artifact_dir()
            .ok_or_else(|| rt_err("artifact dir not found; run `make artifacts`"))?;
        Self::new(&dir)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile) a graph by manifest name, caching the executable.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<LoadedGraph>> {
        if let Some(g) = self.cache.lock().unwrap().get(name) {
            return Ok(g.clone());
        }
        let spec = self.manifest.get(name)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| rt_err("non-utf8 path"))?,
        )
        .map_err(|e| rt_err(format!("parsing HLO text {}: {e:?}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| rt_err(format!("compiling '{name}': {e:?}")))?;
        let graph = std::sync::Arc::new(LoadedGraph {
            name: name.to_string(),
            exe,
            out_shapes: spec.outputs.clone(),
        });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), graph.clone());
        Ok(graph)
    }
}
