//! Stub PJRT engine — built when the `pjrt` feature is off.
//!
//! Mirrors the API surface of [`super::client`] so every consumer (the
//! CLI's `dme runtime`, the AOT examples, the runtime integration tests)
//! compiles unchanged; `Engine::discover()` reports the missing backend
//! and the callers' existing "skip with a notice" paths take over.

use super::{rt_err, ArtifactManifest, Result};
use std::path::Path;
use std::sync::Arc;

const NO_PJRT: &str = "PJRT runtime unavailable: dme was built without the `pjrt` \
     feature (it requires the vendored `xla` crate; see rust/src/runtime/client.rs)";

/// A compiled, ready-to-run XLA graph (stub: never constructible, since
/// [`Engine::new`] always fails without the backend).
pub struct LoadedGraph {
    pub name: String,
    /// Output shapes from the manifest (the graph returns a tuple).
    pub out_shapes: Vec<Vec<usize>>,
}

impl LoadedGraph {
    /// Execute with f32 inputs; returns each tuple element flattened.
    pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        Err(rt_err(format!("cannot execute graph '{}': {NO_PJRT}", self.name)))
    }
}

/// The runtime engine (stub).
pub struct Engine {
    pub manifest: ArtifactManifest,
}

impl Engine {
    /// Create an engine over the artifact directory.
    pub fn new(_artifact_dir: &Path) -> Result<Self> {
        Err(rt_err(NO_PJRT))
    }

    /// Create an engine by auto-discovering the artifact directory.
    pub fn discover() -> Result<Self> {
        Err(rt_err(NO_PJRT))
    }

    pub fn platform(&self) -> String {
        "unavailable (built without `pjrt`)".to_string()
    }

    /// Load (compile) a graph by manifest name.
    pub fn load(&self, name: &str) -> Result<Arc<LoadedGraph>> {
        Err(rt_err(format!("cannot load graph '{name}': {NO_PJRT}")))
    }
}
