//! Distributed substrate: an in-process message-passing cluster with
//! exact per-machine bit metering.
//!
//! The paper's model (Section 1.1 "Distributed Model") is synchronous
//! fault-free message passing, and its cost measure is *bits sent and
//! received by any machine*. This module provides exactly that: `n`
//! endpoints connected all-to-all over typed channels; every `send`
//! increments the sender's sent-counter and the receiver's
//! received-counter by the message's metered bit count (bit-exact, not
//! byte-padded — see `quant::Message`).
//!
//! Machines run as real OS threads (`Cluster::run`), so protocol code is
//! written exactly as it would be against a network stack; there is no
//! global scheduler to accidentally serialize a protocol bug away.

use crate::quant::Message;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// A routed packet.
#[derive(Debug)]
pub struct Packet {
    pub from: usize,
    pub msg: Message,
}

/// Shared per-machine traffic counters.
#[derive(Debug, Default)]
pub struct Meter {
    pub sent_bits: AtomicU64,
    pub recv_bits: AtomicU64,
    pub sent_msgs: AtomicU64,
    pub recv_msgs: AtomicU64,
}

/// Traffic snapshot for reporting.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Traffic {
    pub sent_bits: u64,
    pub recv_bits: u64,
    pub sent_msgs: u64,
    pub recv_msgs: u64,
}

impl Traffic {
    pub fn total_bits(&self) -> u64 {
        self.sent_bits + self.recv_bits
    }

    /// Add another snapshot's counts into this one (the batch round
    /// plane prefix-sums per-slot tallies into cumulative snapshots).
    pub fn accumulate(&mut self, other: &Traffic) {
        self.sent_bits += other.sent_bits;
        self.recv_bits += other.recv_bits;
        self.sent_msgs += other.sent_msgs;
        self.recv_msgs += other.recv_msgs;
    }
}

/// One machine's handle onto the cluster network.
pub struct Endpoint {
    pub id: usize,
    pub n: usize,
    rx: Receiver<Packet>,
    txs: Vec<Sender<Packet>>,
    meters: Arc<Vec<Meter>>,
}

impl Endpoint {
    /// Send `msg` to machine `to`, metering both sides.
    pub fn send(&self, to: usize, msg: Message) {
        assert_ne!(to, self.id, "no self-sends");
        let bits = msg.bits;
        self.meters[self.id].sent_bits.fetch_add(bits, Ordering::Relaxed);
        self.meters[self.id].sent_msgs.fetch_add(1, Ordering::Relaxed);
        self.meters[to].recv_bits.fetch_add(bits, Ordering::Relaxed);
        self.meters[to].recv_msgs.fetch_add(1, Ordering::Relaxed);
        self.txs[to]
            .send(Packet { from: self.id, msg })
            .expect("peer hung up");
    }

    /// Blocking receive of the next packet from anyone.
    pub fn recv(&self) -> Packet {
        self.rx.recv().expect("cluster shut down")
    }

    /// Blocking receive of the next packet from a specific peer
    /// (out-of-order packets from other peers are queued and re-delivered
    /// in arrival order by subsequent calls).
    pub fn recv_from(&mut self, from: usize, stash: &mut Vec<Packet>) -> Packet {
        if let Some(pos) = stash.iter().position(|p| p.from == from) {
            return stash.remove(pos);
        }
        loop {
            let p = self.recv();
            if p.from == from {
                return p;
            }
            stash.push(p);
        }
    }

    /// Send the same message to every other machine.
    pub fn broadcast(&self, msg: &Message) {
        for to in 0..self.n {
            if to != self.id {
                self.send(to, msg.clone());
            }
        }
    }
}

/// The cluster: builds endpoints and runs one closure per machine.
pub struct Cluster {
    pub n: usize,
    meters: Arc<Vec<Meter>>,
}

impl Cluster {
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let meters = Arc::new((0..n).map(|_| Meter::default()).collect::<Vec<_>>());
        Cluster { n, meters }
    }

    /// Construct all endpoints (used by sequential protocol drivers that
    /// want metering without threads, e.g. the tree topology).
    pub fn endpoints(&self) -> Vec<Endpoint> {
        let n = self.n;
        let mut txs = Vec::with_capacity(n);
        let mut rxs = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(rx);
        }
        rxs.into_iter()
            .enumerate()
            .map(|(id, rx)| Endpoint {
                id,
                n,
                rx,
                txs: txs.clone(),
                meters: self.meters.clone(),
            })
            .collect()
    }

    /// Run `f(endpoint)` on `n` threads; returns each machine's result in
    /// machine order. Panics in any machine propagate.
    pub fn run<T, F>(&self, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(Endpoint) -> T + Send + Sync + 'static,
    {
        let endpoints = self.endpoints();
        let f = Arc::new(f);
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|ep| {
                let f = f.clone();
                std::thread::Builder::new()
                    .name(format!("machine-{}", ep.id))
                    .spawn(move || f(ep))
                    .expect("spawn")
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("machine panicked"))
            .collect()
    }

    /// Traffic snapshot per machine.
    pub fn traffic(&self) -> Vec<Traffic> {
        self.meters
            .iter()
            .map(|m| Traffic {
                sent_bits: m.sent_bits.load(Ordering::Relaxed),
                recv_bits: m.recv_bits.load(Ordering::Relaxed),
                sent_msgs: m.sent_msgs.load(Ordering::Relaxed),
                recv_msgs: m.recv_msgs.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Fold externally-metered traffic into the per-machine counters —
    /// used by session rounds whose protocol runs off-cluster (robust VR,
    /// sublinear broadcast) so cumulative accounting stays unified.
    pub fn add_traffic(&self, extra: &[Traffic]) {
        assert_eq!(extra.len(), self.n);
        for (m, t) in self.meters.iter().zip(extra) {
            m.sent_bits.fetch_add(t.sent_bits, Ordering::Relaxed);
            m.recv_bits.fetch_add(t.recv_bits, Ordering::Relaxed);
            m.sent_msgs.fetch_add(t.sent_msgs, Ordering::Relaxed);
            m.recv_msgs.fetch_add(t.recv_msgs, Ordering::Relaxed);
        }
    }

    /// Reset counters between rounds.
    pub fn reset_traffic(&self) {
        for m in self.meters.iter() {
            m.sent_bits.store(0, Ordering::Relaxed);
            m.recv_bits.store(0, Ordering::Relaxed);
            m.sent_msgs.store(0, Ordering::Relaxed);
            m.recv_msgs.store(0, Ordering::Relaxed);
        }
    }
}

/// Summary statistics over per-machine traffic (the paper reports the
/// worst machine and the mean).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TrafficSummary {
    pub max_sent: u64,
    pub max_recv: u64,
    pub mean_sent: f64,
    pub mean_recv: f64,
    pub max_total: u64,
}

pub fn summarize(traffic: &[Traffic]) -> TrafficSummary {
    let n = traffic.len().max(1) as f64;
    TrafficSummary {
        max_sent: traffic.iter().map(|t| t.sent_bits).max().unwrap_or(0),
        max_recv: traffic.iter().map(|t| t.recv_bits).max().unwrap_or(0),
        mean_sent: traffic.iter().map(|t| t.sent_bits).sum::<u64>() as f64 / n,
        mean_recv: traffic.iter().map(|t| t.recv_bits).sum::<u64>() as f64 / n,
        max_total: traffic.iter().map(|t| t.total_bits()).max().unwrap_or(0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(bits: u64) -> Message {
        Message {
            bytes: vec![0u8; (bits as usize + 7) / 8],
            bits,
        }
    }

    #[test]
    fn ping_pong_two_threads() {
        let cluster = Cluster::new(2);
        let results = cluster.run(|mut ep| {
            let mut stash = Vec::new();
            if ep.id == 0 {
                ep.send(1, msg(100));
                let p = ep.recv_from(1, &mut stash);
                p.msg.bits
            } else {
                let p = ep.recv_from(0, &mut stash);
                ep.send(0, msg(p.msg.bits * 2));
                0
            }
        });
        assert_eq!(results[0], 200);
        let t = cluster.traffic();
        assert_eq!(t[0].sent_bits, 100);
        assert_eq!(t[0].recv_bits, 200);
        assert_eq!(t[1].sent_bits, 200);
        assert_eq!(t[1].recv_bits, 100);
    }

    #[test]
    fn broadcast_meters_all_receivers() {
        let cluster = Cluster::new(4);
        cluster.run(|ep| {
            if ep.id == 0 {
                ep.broadcast(&msg(64));
            } else {
                let p = ep.recv();
                assert_eq!(p.from, 0);
            }
        });
        let t = cluster.traffic();
        assert_eq!(t[0].sent_bits, 3 * 64);
        for i in 1..4 {
            assert_eq!(t[i].recv_bits, 64);
        }
        let s = summarize(&t);
        assert_eq!(s.max_sent, 192);
        assert_eq!(s.max_recv, 64);
    }

    #[test]
    fn recv_from_stashes_out_of_order() {
        let cluster = Cluster::new(3);
        let results = cluster.run(|mut ep| {
            let mut stash = Vec::new();
            match ep.id {
                0 => {
                    // Wait for 2 first even though 1 likely arrives first.
                    let a = ep.recv_from(2, &mut stash);
                    let b = ep.recv_from(1, &mut stash);
                    (a.msg.bits, b.msg.bits)
                }
                1 => {
                    ep.send(0, msg(11));
                    (0, 0)
                }
                _ => {
                    ep.send(0, msg(22));
                    (0, 0)
                }
            }
        });
        assert_eq!(results[0], (22, 11));
    }

    #[test]
    fn reset_traffic_clears() {
        let cluster = Cluster::new(2);
        cluster.run(|ep| {
            if ep.id == 0 {
                ep.send(1, msg(10));
            } else {
                ep.recv();
            }
        });
        cluster.reset_traffic();
        assert_eq!(cluster.traffic()[0].sent_bits, 0);
    }
}
