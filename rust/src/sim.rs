//! Distributed substrate: an in-process message-passing cluster with
//! exact per-machine bit metering.
//!
//! The paper's model (Section 1.1 "Distributed Model") is synchronous
//! fault-free message passing, and its cost measure is *bits sent and
//! received by any machine*. This module provides exactly that: `n`
//! endpoints connected all-to-all over typed channels; every `send`
//! increments the sender's sent-counter and the receiver's
//! received-counter by the message's metered bit count (bit-exact, not
//! byte-padded — see `quant::Message`).
//!
//! Machines run as real OS threads (`Cluster::run`), so protocol code is
//! written exactly as it would be against a network stack; there is no
//! global scheduler to accidentally serialize a protocol bug away.
//!
//! This cluster is also the *reference implementation* of the
//! [`crate::net`] transport layer: [`Endpoint`] implements
//! [`TransportEndpoint`] and [`Cluster`] implements
//! [`crate::net::Transport`], and protocol code generic over those
//! traits is bit-identical here to the hardwired legacy methods (the
//! parity suite runs both). Two API surfaces coexist on [`Endpoint`]:
//!
//! - the **legacy infallible surface** (`send`/`recv`/`recv_from` with a
//!   caller-owned stash) kept verbatim for the sequential reference
//!   drivers in `tests/session_parity.rs` — it panics on a dead cluster;
//! - the **fallible surface** (`try_send`/`try_recv`/`try_recv_from`
//!   plus the trait impl) which returns [`TransportError`] and manages
//!   an internal per-peer FIFO [`Stash`].
//!
//! Don't interleave the two receive disciplines on one endpoint: each
//! tracks its own stash. All production paths use the fallible surface.

use crate::net::{Stash, TransportError};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

pub use crate::net::{summarize, Meter, Packet, Traffic, TrafficSummary};
use crate::net::{Transport, TransportEndpoint};
use crate::quant::Message;

/// One machine's handle onto the cluster network.
pub struct Endpoint {
    pub id: usize,
    pub n: usize,
    rx: Receiver<Packet>,
    /// Senders to every peer; the self slot is `None` so an endpoint
    /// never keeps its own receiver alive (a machine blocked in `recv`
    /// sees `Shutdown` once every *peer* is gone, instead of deadlocking
    /// on its own sender clone).
    txs: Vec<Option<Sender<Packet>>>,
    meters: Arc<Vec<Meter>>,
    stash: Stash,
}

impl Endpoint {
    // ---- fallible surface (the transport contract) -------------------

    /// Send `msg` to machine `to`, metering both sides. The meters are
    /// charged before delivery is attempted — a send to a dead peer is
    /// still a send, matching what a socket transport can observe.
    pub fn try_send(&self, to: usize, msg: Message) -> Result<(), TransportError> {
        assert_ne!(to, self.id, "no self-sends");
        let bits = msg.bits;
        self.meters[self.id].note_sent(bits);
        self.meters[to].note_recv(bits);
        self.txs[to]
            .as_ref()
            .expect("self slot is the only None")
            .send(Packet { from: self.id, msg })
            .map_err(|_| TransportError::PeerClosed { peer: to })
    }

    /// Blocking receive of the next packet: oldest internally-stashed
    /// packet first, then the channel. `Shutdown` once every peer's
    /// endpoint has been dropped.
    pub fn try_recv(&mut self) -> Result<Packet, TransportError> {
        if let Some(p) = self.stash.pop_earliest() {
            return Ok(p);
        }
        self.rx.recv().map_err(|_| TransportError::Shutdown)
    }

    /// Blocking receive from the specific peer `from`; packets from
    /// other peers are stashed (per-peer FIFO, O(1) per packet).
    pub fn try_recv_from(&mut self, from: usize) -> Result<Packet, TransportError> {
        if let Some(p) = self.stash.pop_from(from) {
            return Ok(p);
        }
        loop {
            let p = self.rx.recv().map_err(|_| TransportError::Shutdown)?;
            if p.from == from {
                return Ok(p);
            }
            self.stash.push(p);
        }
    }

    /// Like [`Endpoint::try_recv`], but gives up after `timeout`.
    pub fn try_recv_timeout(&mut self, timeout: Duration) -> Result<Packet, TransportError> {
        if let Some(p) = self.stash.pop_earliest() {
            return Ok(p);
        }
        self.rx.recv_timeout(timeout).map_err(|e| match e {
            RecvTimeoutError::Timeout => TransportError::Timeout { peer: None },
            RecvTimeoutError::Disconnected => TransportError::Shutdown,
        })
    }

    // ---- legacy infallible surface (reference drivers) ---------------

    /// Send `msg` to machine `to`, metering both sides.
    ///
    /// Legacy surface: panics if the peer is gone. Production paths use
    /// [`Endpoint::try_send`].
    pub fn send(&self, to: usize, msg: Message) {
        self.try_send(to, msg)
            .unwrap_or_else(|e| panic!("in-process transport: {e}"));
    }

    /// Blocking receive of the next packet from anyone.
    ///
    /// Legacy surface: reads the channel only (ignores the internal
    /// stash) and panics once the cluster is gone.
    pub fn recv(&self) -> Packet {
        self.rx
            .recv()
            .unwrap_or_else(|_| panic!("in-process transport: {}", TransportError::Shutdown))
    }

    /// Blocking receive of the next packet from a specific peer
    /// (out-of-order packets from other peers are queued in the
    /// caller-owned `stash` and re-delivered in arrival order by
    /// subsequent calls).
    ///
    /// Legacy surface for the sequential reference drivers, which share
    /// one stash across endpoints; the trait surface keeps an internal
    /// per-peer FIFO instead.
    pub fn recv_from(&mut self, from: usize, stash: &mut Vec<Packet>) -> Packet {
        if let Some(pos) = stash.iter().position(|p| p.from == from) {
            return stash.remove(pos);
        }
        loop {
            let p = self.recv();
            if p.from == from {
                return p;
            }
            stash.push(p);
        }
    }

    /// Send the same message to every other machine.
    pub fn broadcast(&self, msg: &Message) {
        for to in 0..self.n {
            if to != self.id {
                self.send(to, msg.clone());
            }
        }
    }
}

impl TransportEndpoint for Endpoint {
    fn id(&self) -> usize {
        self.id
    }

    fn n(&self) -> usize {
        self.n
    }

    fn send(&mut self, to: usize, msg: Message) -> Result<(), TransportError> {
        self.try_send(to, msg)
    }

    fn recv(&mut self) -> Result<Packet, TransportError> {
        self.try_recv()
    }

    fn recv_from(&mut self, from: usize) -> Result<Packet, TransportError> {
        self.try_recv_from(from)
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Packet, TransportError> {
        self.try_recv_timeout(timeout)
    }

    fn traffic(&self) -> Traffic {
        self.meters[self.id].snapshot()
    }
}

/// The cluster: builds endpoints and runs one closure per machine.
pub struct Cluster {
    pub n: usize,
    meters: Arc<Vec<Meter>>,
}

impl Cluster {
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let meters = Arc::new((0..n).map(|_| Meter::default()).collect::<Vec<_>>());
        Cluster { n, meters }
    }

    /// Construct all endpoints (used by sequential protocol drivers that
    /// want metering without threads, e.g. the tree topology).
    pub fn endpoints(&self) -> Vec<Endpoint> {
        let n = self.n;
        let mut txs = Vec::with_capacity(n);
        let mut rxs = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(rx);
        }
        rxs.into_iter()
            .enumerate()
            .map(|(id, rx)| Endpoint {
                id,
                n,
                rx,
                txs: txs
                    .iter()
                    .enumerate()
                    .map(|(to, tx)| (to != id).then(|| tx.clone()))
                    .collect(),
                meters: self.meters.clone(),
                stash: Stash::new(n),
            })
            .collect()
    }

    /// Run `f(endpoint)` on `n` parallel machines; returns each machine's
    /// result in machine order. Panics in any machine propagate.
    ///
    /// §Perf: machines run on leased threads from the process-wide pool
    /// ([`crate::pool::lease`]) — parked threads are reused across
    /// clusters/rounds instead of spawned per call, so repeated-round
    /// drivers stop paying n thread spawns per round.
    pub fn run<T, F>(&self, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(Endpoint) -> T + Send + Sync + 'static,
    {
        let endpoints = self.endpoints();
        let f = Arc::new(f);
        let leases: Vec<_> = endpoints
            .into_iter()
            .map(|ep| {
                let f = f.clone();
                crate::pool::lease(move || f(ep)).expect("lease machine worker thread")
            })
            .collect();
        leases
            .into_iter()
            .map(|l| l.join().expect("machine panicked"))
            .collect()
    }

    /// Graceful-shutdown variant of [`Cluster::run`]: each machine
    /// returns a `Result`, and a machine that panics yields
    /// `Err(WorkerPanicked)` in its slot instead of poisoning the whole
    /// process. A machine whose worker thread cannot even be obtained
    /// (pool exhausted and OS spawn failed) yields `Err(Io)` in its slot
    /// — its endpoint is dropped unstarted, so surviving machines observe
    /// it as a dead peer (`Err(PeerClosed)` from `try_send`, or
    /// `Timeout`/`Shutdown` from the receive side) and unwind cleanly,
    /// consistent with the no-panic transport policy.
    pub fn try_run<T, F>(&self, f: F) -> Vec<Result<T, TransportError>>
    where
        T: Send + 'static,
        F: Fn(Endpoint) -> Result<T, TransportError> + Send + Sync + 'static,
    {
        let endpoints = self.endpoints();
        let f = Arc::new(f);
        let leases: Vec<_> = endpoints
            .into_iter()
            .map(|ep| {
                let f = f.clone();
                crate::pool::lease(move || f(ep))
            })
            .collect();
        leases
            .into_iter()
            .enumerate()
            .map(|(machine, lease)| match lease {
                Ok(l) => match l.join() {
                    Ok(r) => r,
                    Err(_) => Err(TransportError::WorkerPanicked { machine }),
                },
                Err(e) => Err(TransportError::from_io(&e)),
            })
            .collect()
    }

    /// Traffic snapshot per machine.
    pub fn traffic(&self) -> Vec<Traffic> {
        self.meters.iter().map(|m| m.snapshot()).collect()
    }

    /// Fold externally-metered traffic into the per-machine counters —
    /// used by session rounds whose protocol runs off-cluster (robust VR,
    /// sublinear broadcast) so cumulative accounting stays unified.
    pub fn add_traffic(&self, extra: &[Traffic]) {
        assert_eq!(extra.len(), self.n);
        for (m, t) in self.meters.iter().zip(extra) {
            use std::sync::atomic::Ordering;
            m.sent_bits.fetch_add(t.sent_bits, Ordering::Relaxed);
            m.recv_bits.fetch_add(t.recv_bits, Ordering::Relaxed);
            m.sent_msgs.fetch_add(t.sent_msgs, Ordering::Relaxed);
            m.recv_msgs.fetch_add(t.recv_msgs, Ordering::Relaxed);
        }
    }

    /// Reset counters between rounds.
    pub fn reset_traffic(&self) {
        use std::sync::atomic::Ordering;
        for m in self.meters.iter() {
            m.sent_bits.store(0, Ordering::Relaxed);
            m.recv_bits.store(0, Ordering::Relaxed);
            m.sent_msgs.store(0, Ordering::Relaxed);
            m.recv_msgs.store(0, Ordering::Relaxed);
        }
    }
}

impl Transport for Cluster {
    type Endpoint = Endpoint;

    fn n(&self) -> usize {
        self.n
    }

    fn open(&mut self) -> Result<Vec<Endpoint>, TransportError> {
        Ok(self.endpoints())
    }

    fn traffic(&self) -> Vec<Traffic> {
        Cluster::traffic(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(bits: u64) -> Message {
        Message {
            bytes: vec![0u8; (bits as usize + 7) / 8],
            bits,
        }
    }

    #[test]
    fn ping_pong_two_threads() {
        let cluster = Cluster::new(2);
        let results = cluster.run(|mut ep| {
            let mut stash = Vec::new();
            if ep.id == 0 {
                ep.send(1, msg(100));
                let p = ep.recv_from(1, &mut stash);
                p.msg.bits
            } else {
                let p = ep.recv_from(0, &mut stash);
                ep.send(0, msg(p.msg.bits * 2));
                0
            }
        });
        assert_eq!(results[0], 200);
        let t = cluster.traffic();
        assert_eq!(t[0].sent_bits, 100);
        assert_eq!(t[0].recv_bits, 200);
        assert_eq!(t[1].sent_bits, 200);
        assert_eq!(t[1].recv_bits, 100);
    }

    #[test]
    fn broadcast_meters_all_receivers() {
        let cluster = Cluster::new(4);
        cluster.run(|ep| {
            if ep.id == 0 {
                ep.broadcast(&msg(64));
            } else {
                let p = ep.recv();
                assert_eq!(p.from, 0);
            }
        });
        let t = cluster.traffic();
        assert_eq!(t[0].sent_bits, 3 * 64);
        for i in 1..4 {
            assert_eq!(t[i].recv_bits, 64);
        }
        let s = summarize(&t);
        assert_eq!(s.max_sent, 192);
        assert_eq!(s.max_recv, 64);
    }

    #[test]
    fn recv_from_stashes_out_of_order() {
        let cluster = Cluster::new(3);
        let results = cluster.run(|mut ep| {
            let mut stash = Vec::new();
            match ep.id {
                0 => {
                    // Wait for 2 first even though 1 likely arrives first.
                    let a = ep.recv_from(2, &mut stash);
                    let b = ep.recv_from(1, &mut stash);
                    (a.msg.bits, b.msg.bits)
                }
                1 => {
                    ep.send(0, msg(11));
                    (0, 0)
                }
                _ => {
                    ep.send(0, msg(22));
                    (0, 0)
                }
            }
        });
        assert_eq!(results[0], (22, 11));
    }

    /// Delivery-order pin for the trait surface's internal per-peer
    /// stash: packets from one sender are delivered strictly in send
    /// order even when receives interleave peers, and `recv()` drains
    /// stashed packets in global arrival order before the channel.
    #[test]
    fn trait_recv_from_preserves_per_peer_fifo() {
        let cluster = Cluster::new(3);
        let results = cluster.try_run(|mut ep| {
            match ep.id {
                0 => {
                    // Wait on peer 2 first, forcing 1's burst to stash;
                    // then drain 1 and assert its FIFO order survived.
                    let first = ep.try_recv_from(2)?.msg.bits;
                    let mut order = vec![first];
                    for _ in 0..3 {
                        order.push(ep.try_recv_from(1)?.msg.bits);
                    }
                    // 2's second packet is still stashed; plain recv
                    // must surface it (arrival order) without blocking.
                    order.push(ep.try_recv()?.msg.bits);
                    Ok(order)
                }
                1 => {
                    for bits in [10, 11, 12] {
                        ep.try_send(0, msg(bits))?;
                    }
                    Ok(vec![])
                }
                _ => {
                    ep.try_send(0, msg(20))?;
                    ep.try_send(0, msg(21))?;
                    Ok(vec![])
                }
            }
        });
        let order = results[0].as_ref().expect("machine 0 clean");
        assert_eq!(order[0], 20);
        assert_eq!(&order[1..4], &[10, 11, 12], "per-peer FIFO violated");
        assert_eq!(order[4], 21);
    }

    #[test]
    fn reset_traffic_clears() {
        let cluster = Cluster::new(2);
        cluster.run(|ep| {
            if ep.id == 0 {
                ep.send(1, msg(10));
            } else {
                ep.recv();
            }
        });
        cluster.reset_traffic();
        assert_eq!(cluster.traffic()[0].sent_bits, 0);
    }

    /// Graceful shutdown: a peer dropping its endpoint surfaces as a
    /// typed error on the survivors, and a panicking machine yields
    /// `WorkerPanicked` in its slot without poisoning the process.
    #[test]
    fn try_run_survives_dead_and_panicking_peers() {
        let cluster = Cluster::new(3);
        let results = cluster.try_run(|mut ep| match ep.id {
            0 => {
                // Machine 1 announces itself, then drops. Sends to it
                // must eventually fail PeerClosed rather than panic.
                ep.try_recv_from(1)?;
                for _ in 0..10_000 {
                    if let Err(e) = ep.try_send(1, msg(8)) {
                        assert_eq!(e, TransportError::PeerClosed { peer: 1 });
                        return Err(e);
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
                panic!("send to dead peer never failed");
            }
            1 => {
                ep.try_send(0, msg(8))?;
                Ok(()) // returns early; endpoint drops
            }
            _ => panic!("injected machine panic"),
        });
        assert_eq!(results[0], Err(TransportError::PeerClosed { peer: 1 }));
        assert_eq!(results[1], Ok(()));
        assert_eq!(results[2], Err(TransportError::WorkerPanicked { machine: 2 }));
    }

    /// A receive deadline elapses as `Timeout`, not a hang, when the
    /// awaited peer never sends.
    #[test]
    fn recv_timeout_elapses_cleanly() {
        let cluster = Cluster::new(2);
        let results = cluster.try_run(|mut ep| {
            if ep.id == 0 {
                let r = match ep.try_recv_timeout(Duration::from_millis(20)) {
                    Err(TransportError::Timeout { .. }) => Ok(true),
                    other => panic!("expected Timeout, got {other:?}"),
                };
                // Unblock the peer so it can exit.
                ep.try_send(1, msg(1))?;
                r
            } else {
                // Stay alive (blocked on a packet that arrives only
                // after the deadline fired) so machine 0 observes a
                // Timeout rather than a whole-cluster Shutdown.
                ep.try_recv()?;
                Ok(false)
            }
        });
        assert_eq!(results[0], Ok(true));
    }
}
