//! Small dense linear-algebra substrate.
//!
//! The experiments need matvecs, Gram products and norms over modest
//! matrices (≤ 32768 × 256). The offline build has no BLAS crate, so this
//! module provides a compact row-major implementation tuned enough (tiled
//! transpose-matvec, fused residual updates) that the workload generators
//! never dominate an experiment run.

/// Row-major dense matrix.
#[derive(Clone, Debug)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(&row);
        }
        Matrix { rows: r, cols: c, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// View of a contiguous row range `[lo, hi)` as a sub-matrix.
    pub fn row_block(&self, lo: usize, hi: usize) -> Matrix {
        Matrix {
            rows: hi - lo,
            cols: self.cols,
            data: self.data[lo * self.cols..hi * self.cols].to_vec(),
        }
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            y[i] = dot(self.row(i), x);
        }
        y
    }

    /// y = Aᵀ x (single pass over A, accumulating rows — cache friendly).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let row = self.row(i);
            for (yj, aij) in y.iter_mut().zip(row) {
                *yj += xi * aij;
            }
        }
        y
    }

    /// Gram product u = Aᵀ (A v) without materializing A v twice.
    pub fn gram_apply(&self, v: &[f64]) -> Vec<f64> {
        let av = self.matvec(v);
        self.matvec_t(&av)
    }

    /// C = A B (small sizes only; used by PowerSGD factors).
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows);
        let mut c = Matrix::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self.data[i * self.cols + k];
                if aik == 0.0 {
                    continue;
                }
                let brow = &b.data[k * b.cols..(k + 1) * b.cols];
                let crow = &mut c.data[i * b.cols..(i + 1) * b.cols];
                for (cj, bj) in crow.iter_mut().zip(brow) {
                    *cj += aik * bj;
                }
            }
        }
        c
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }
}

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled for ILP; the compiler auto-vectorizes this shape well.
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// a + b.
pub fn add(a: &[f64], b: &[f64]) -> Vec<f64> {
    a.iter().zip(b).map(|(x, y)| x + y).collect()
}

/// a - b.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// c * a.
pub fn scale(a: &[f64], c: f64) -> Vec<f64> {
    a.iter().map(|x| c * x).collect()
}

/// y += c * x (in place).
pub fn axpy(y: &mut [f64], c: f64, x: &[f64]) {
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += c * xi;
    }
}

/// ℓ2 norm.
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// ℓ2 distance.
pub fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}

/// ℓ∞ norm.
pub fn norm_inf(a: &[f64]) -> f64 {
    a.iter().fold(0.0, |m, x| m.max(x.abs()))
}

/// ℓ∞ distance.
pub fn dist_inf(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).fold(0.0, |m, (x, y)| m.max((x - y).abs()))
}

/// ℓ1 norm.
pub fn norm1(a: &[f64]) -> f64 {
    a.iter().map(|x| x.abs()).sum()
}

/// max(a) - min(a) — the "coordinate difference" QSGD-Linf uses (Exp 1).
pub fn coord_range(a: &[f64]) -> f64 {
    let mx = a.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mn = a.iter().cloned().fold(f64::INFINITY, f64::min);
    mx - mn
}

/// Mean of several equally-long vectors.
pub fn mean_vecs(vs: &[Vec<f64>]) -> Vec<f64> {
    assert!(!vs.is_empty());
    let d = vs[0].len();
    let mut m = vec![0.0; d];
    for v in vs {
        axpy(&mut m, 1.0, v);
    }
    scale(&m, 1.0 / vs.len() as f64)
}

/// Normalize to unit ℓ2 norm (returns zero vector unchanged).
pub fn normalize(a: &[f64]) -> Vec<f64> {
    let n = norm2(a);
    if n == 0.0 {
        a.to_vec()
    } else {
        scale(a, 1.0 / n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..13).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..13).map(|i| 13.0 - i as f64).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn matvec_t_matches_transpose() {
        let m = Matrix::from_rows(vec![
            vec![1.0, 2.0, 3.0],
            vec![4.0, 5.0, 6.0],
        ]);
        let x = vec![7.0, 9.0];
        let direct = m.matvec_t(&x);
        let via_t = m.transpose().matvec(&x);
        for (a, b) in direct.iter().zip(&via_t) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn gram_apply_matches_composition() {
        let m = Matrix::from_rows(vec![
            vec![1.0, -1.0],
            vec![2.0, 0.5],
            vec![0.0, 3.0],
        ]);
        let v = vec![0.3, -0.7];
        let g = m.gram_apply(&v);
        let expect = m.matvec_t(&m.matvec(&v));
        for (a, b) in g.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let i = Matrix::from_rows(vec![vec![1.0, 0.0], vec![0.0, 1.0]]);
        let c = a.matmul(&i);
        assert_eq!(c.data, a.data);
    }

    #[test]
    fn norms() {
        let a = vec![3.0, -4.0];
        assert!((norm2(&a) - 5.0).abs() < 1e-12);
        assert!((norm1(&a) - 7.0).abs() < 1e-12);
        assert!((norm_inf(&a) - 4.0).abs() < 1e-12);
        assert!((coord_range(&a) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn mean_vecs_simple() {
        let m = mean_vecs(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m, vec![2.0, 3.0]);
    }
}
