//! VarianceReduction — the reduction to MeanEstimation (Theorems 17/19)
//! and the error-detecting Algorithm 6 (Theorem 4).
//!
//! The reduction: inputs are i.i.d. unbiased estimates of an unknown `∇`
//! with variance σ²; by Chebyshev all pairwise distances are
//! `≤ 2σ√(αn)` with probability `1 − 1/α`, so MeanEstimation with
//! `y = 2σ√(αn)` solves VR. Algorithm 6 instead runs RobustAgreement
//! pairwise with a random leader, so the bit cost *adapts* to the true
//! distances instead of paying the worst case: `O(d log q + log n)`
//! expected bits (Theorem 4).

use crate::quant::robust::RobustAgreement;
use crate::rng::{hash2, Rng};
use crate::sim::Traffic;

/// The Chebyshev distance bound for the VR→ME reduction (Theorem 17):
/// `y = 2σ√(αn)`.
pub fn vr_y_bound(sigma: f64, n: usize, alpha: f64) -> f64 {
    2.0 * sigma * (alpha * n as f64).sqrt()
}

/// Theorem 17/19: VarianceReduction by reduction to MeanEstimation with
/// `y = 2σ√(αn)` over the star topology (Algorithm 3). Succeeds with
/// probability ≥ 1 − 1/α; use [`robust_variance_reduction`] when inputs
/// may be heavier-tailed than the Chebyshev envelope.
pub fn variance_reduction_star(
    inputs: &[Vec<f64>],
    spec: &super::CodecSpec,
    sigma: f64,
    alpha: f64,
    seed: u64,
    round: u64,
) -> super::star::StarOutcome {
    let y = vr_y_bound(sigma, inputs.len(), alpha);
    super::star::mean_estimation_star(inputs, spec, y, seed, round)
}

/// Result of Algorithm 6.
#[derive(Clone, Debug)]
pub struct RobustVrOutcome {
    /// Common output estimate of ∇ (all machines).
    pub estimate: Vec<f64>,
    pub traffic: Vec<Traffic>,
    pub leader: usize,
    /// Escalation rounds per pairwise exchange (first stage, then second).
    pub rounds_stage1: Vec<u32>,
    pub rounds_stage2: Vec<u32>,
}

/// Algorithm 6: VarianceReduction with error detection.
///
/// `q0` is the initial quantization parameter; `sigma` the input standard
/// deviation estimate (sets the initial lattice scale ε = σ/q0²-ish; we
/// use the practical `s = 2σ/(q0−1)` and let escalation absorb outliers).
///
/// Legacy one-round entry point, now a thin wrapper over a one-round
/// [`super::DmeSession`] built with
/// [`robust(q0)`](super::DmeBuilder::robust); bit-identical behavior.
pub fn robust_variance_reduction(
    inputs: &[Vec<f64>],
    sigma: f64,
    q0: u32,
    seed: u64,
    round: u64,
) -> RobustVrOutcome {
    let n = inputs.len();
    assert!(n >= 1);
    let d = inputs[0].len();
    let mut sess = super::api::DmeBuilder::new(n, d).robust(q0).seed(seed).build();
    sess.set_round(round);
    let out = sess.round_vr(inputs, sigma);
    RobustVrOutcome {
        estimate: out.estimate,
        traffic: out.round_traffic,
        leader: out.leader.expect("robust VR reports a leader"),
        rounds_stage1: out.rounds_stage1,
        rounds_stage2: out.rounds_stage2,
    }
}

/// The sequential Algorithm-6 round shared by the session API and the
/// legacy wrapper above.
pub(crate) fn robust_vr_core(
    inputs: &[Vec<f64>],
    sigma: f64,
    q0: u32,
    seed: u64,
    round: u64,
) -> RobustVrOutcome {
    let n = inputs.len();
    assert!(n >= 1);
    let d = inputs[0].len();
    let leader = Rng::new(hash2(seed, round ^ 0x10BD)).next_below(n as u64) as usize;
    let mut traffic = vec![Traffic::default(); n];
    let mut rounds_stage1 = Vec::with_capacity(n.saturating_sub(1));
    let mut rounds_stage2 = Vec::with_capacity(n.saturating_sub(1));

    // Stage 1: every worker u runs RobustAgreement(x_u -> leader).
    let mut estimates: Vec<Vec<f64>> = Vec::with_capacity(n);
    for u in 0..n {
        if u == leader {
            estimates.push(inputs[leader].clone());
            continue;
        }
        let ra = RobustAgreement::new(d, q0, sigma.max(1e-12), hash2(seed, round * 1000 + u as u64));
        let t = ra.run(&inputs[u], &inputs[leader]);
        traffic[u].sent_bits += t.bits_forward;
        traffic[leader].recv_bits += t.bits_forward;
        traffic[leader].sent_bits += t.bits_backward;
        traffic[u].recv_bits += t.bits_backward;
        traffic[u].sent_msgs += t.rounds as u64;
        rounds_stage1.push(t.rounds);
        estimates.push(t.estimate.expect("robust agreement exhausted"));
    }

    // Leader averages all received estimates (plus its own input).
    let nabla_hat = crate::linalg::mean_vecs(&estimates);

    // Stage 2: leader sends ∇̂ to every machine with RobustAgreement,
    // using the same encoded point z each time (shared seed per round).
    let ra_bcast =
        RobustAgreement::new(d, q0, sigma.max(1e-12), hash2(seed, round * 1000 + 0xBCA5));
    let mut estimate = nabla_hat.clone();
    for u in 0..n {
        if u == leader {
            continue;
        }
        let t = ra_bcast.run(&nabla_hat, &inputs[u]);
        traffic[leader].sent_bits += t.bits_forward;
        traffic[u].recv_bits += t.bits_forward;
        traffic[u].sent_bits += t.bits_backward;
        traffic[leader].recv_bits += t.bits_backward;
        rounds_stage2.push(t.rounds);
        // All runs share the same lattice/hash seed, so the decoded z is
        // identical across machines; keep one as the common output.
        estimate = t.estimate.expect("broadcast agreement exhausted");
    }

    RobustVrOutcome {
        estimate,
        traffic,
        leader,
        rounds_stage1,
        rounds_stage2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dist2, norm2};

    /// Inputs = ∇ + gaussian noise of per-coordinate std `sig_c`.
    fn vr_inputs(n: usize, d: usize, center: f64, sig_c: f64, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let nabla: Vec<f64> = (0..d).map(|_| center + rng.next_gaussian()).collect();
        let inputs = (0..n)
            .map(|_| {
                nabla
                    .iter()
                    .map(|v| v + sig_c * rng.next_gaussian())
                    .collect()
            })
            .collect();
        (inputs, nabla)
    }

    #[test]
    fn chebyshev_bound_formula() {
        assert!((vr_y_bound(1.0, 4, 4.0) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn reduces_variance_below_single_input() {
        let n = 16;
        let d = 32;
        let sig_c = 0.1;
        let mut err_in = 0.0;
        let mut err_out = 0.0;
        for round in 0..20 {
            let (inputs, nabla) = vr_inputs(n, d, 100.0, sig_c, 40 + round);
            let out = robust_variance_reduction(&inputs, sig_c * (d as f64).sqrt(), 16, 41, round);
            err_in += dist2(&inputs[0], &nabla).powi(2);
            err_out += dist2(&out.estimate, &nabla).powi(2);
        }
        assert!(
            err_out < err_in / 4.0,
            "VR must reduce variance: in {err_in} out {err_out}"
        );
    }

    #[test]
    fn far_outlier_triggers_escalation_not_corruption() {
        let n = 8;
        let d = 16;
        let (mut inputs, nabla) = vr_inputs(n, d, 0.0, 0.05, 50);
        // One machine got a wild estimate (heavy-tailed input).
        for v in inputs[3].iter_mut() {
            *v += 50.0;
        }
        let out = robust_variance_reduction(&inputs, 0.05 * (d as f64).sqrt(), 8, 51, 0);
        // Escalation happened somewhere in stage 1...
        assert!(out.rounds_stage1.iter().any(|&r| r > 1));
        // ...and the output is still a sane average (dominated by the
        // outlier's 50/n shift, not by decode corruption).
        let expected_shift = 50.0 * (d as f64).sqrt() / n as f64;
        assert!(dist2(&out.estimate, &nabla) < 3.0 * expected_shift + 3.0 * norm2(&vec![0.05; d]));
    }

    #[test]
    fn bits_adapt_to_actual_distance() {
        // Tight inputs use fewer leader-received bits than spread inputs.
        let n = 8;
        let d = 32;
        let (tight, _) = vr_inputs(n, d, 10.0, 0.01, 60);
        let (spread, _) = vr_inputs(n, d, 10.0, 10.0, 61);
        let sig = 0.01 * (d as f64).sqrt();
        let a = robust_variance_reduction(&tight, sig, 8, 62, 0);
        let b = robust_variance_reduction(&spread, sig, 8, 62, 0);
        let bits = |o: &RobustVrOutcome| o.traffic.iter().map(|t| t.recv_bits).max().unwrap();
        assert!(
            bits(&a) < bits(&b),
            "adaptive bits: tight {} spread {}",
            bits(&a),
            bits(&b)
        );
    }
}
