//! Algorithm 9 — SublinearMeanEstimation.
//!
//! In the o(d)-bits regime no variance reduction is possible (Theorems
//! 7/38), so averaging is pointless: a uniformly random source machine
//! broadcasts its sublinearly-quantized input down a binary tree and
//! everyone outputs the decode. The source's input is itself an unbiased
//! estimator of μ with variance ≤ y², and the quantizer adds O(y²/q²)
//! (Theorem 36).
//!
//! Uses the exact small-d codec for d ≤ 8 and meters the analytic bit
//! cost `d·log₂(1+q)` either way (the paper's own Exp-4 methodology for
//! high d, where it shows the exact scheme is computationally
//! infeasible — DESIGN.md §2).

use crate::quant::sublinear::{SublinearCodec, SublinearModel};
use crate::rng::{hash2, Rng};
use crate::sim::Traffic;

/// Result of one sublinear MeanEstimation round.
#[derive(Clone, Debug)]
pub struct SublinearOutcome {
    /// Common output (all machines).
    pub estimate: Vec<f64>,
    pub source: usize,
    pub traffic: Vec<Traffic>,
    /// Analytic added variance `d·s²/12` at the chosen parameters.
    pub model_variance: f64,
    /// Whether the exact codec ran (d ≤ 8) or the model-metered path.
    pub exact: bool,
}

/// Run Algorithm 9: `q` may be < 1 (the sublinear regime: ~`d·q` bits).
pub fn sublinear_mean_estimation(
    inputs: &[Vec<f64>],
    q: f64,
    y: f64,
    seed: u64,
    round: u64,
) -> SublinearOutcome {
    let n = inputs.len();
    assert!(n >= 1 && q > 0.0 && y > 0.0);
    let d = inputs[0].len();
    let source = Rng::new(hash2(seed, round ^ 0x50BC)).next_below(n as u64) as usize;
    let model = SublinearModel { d, y };
    // ε-lattice at side s = y/q ⇒ decode radius qε covers ‖x_u−x_v‖ ≤ y.
    let s = y / q.max(1e-12) * 2.0;
    let bits = (d as f64 * (1.0 + 2.0 * q).log2()).ceil() as u64;

    let mut traffic = vec![Traffic::default(); n];
    // Binary-tree broadcast: every non-source machine receives once; each
    // internal node sends ≤ 2 copies.
    let order: Vec<usize> = (0..n).map(|i| (source + i) % n).collect();
    for pos in 0..n {
        for c in [2 * pos + 1, 2 * pos + 2] {
            if c < n {
                traffic[order[pos]].sent_bits += bits;
                traffic[order[pos]].sent_msgs += 1;
                traffic[order[c]].recv_bits += bits;
                traffic[order[c]].recv_msgs += 1;
            }
        }
    }

    if d <= 8 {
        let codec = SublinearCodec::new(d, s, q, hash2(seed, round));
        if let Some((msg, _est)) = codec.encode(&inputs[source]) {
            // Every machine decodes against its own input; within radius
            // they all recover the same lattice point.
            let mut outputs: Vec<Option<Vec<f64>>> =
                (0..n).map(|v| codec.decode(&msg, &inputs[v])).collect();
            if outputs.iter().all(|o| o.is_some()) {
                let first = outputs.swap_remove(0).unwrap();
                return SublinearOutcome {
                    estimate: first,
                    source,
                    traffic,
                    model_variance: model.variance_for_side(s),
                    exact: true,
                };
            }
        }
        // Exact path failed (radius exceeded): fall through to the model
        // path, which is what high-d deployments use anyway.
    }
    // Model path: randomly offset cubic quantization of the source input
    // (the estimator Exp 4 simulates), metered at the sublinear bit cost.
    let mut shared = Rng::new(hash2(seed, round ^ 0x0FF5));
    let est: Vec<f64> = inputs[source]
        .iter()
        .map(|v| {
            let off = shared.uniform(-s / 2.0, s / 2.0);
            ((v - off) / s).round_ties_even() * s + off
        })
        .collect();
    SublinearOutcome {
        estimate: est,
        source,
        traffic,
        model_variance: model.variance_for_side(s),
        exact: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dist2, mean_vecs};

    fn gen(n: usize, d: usize, center: f64, spread: f64, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                (0..d)
                    .map(|_| center + rng.uniform(-spread, spread))
                    .collect()
            })
            .collect()
    }

    #[test]
    fn sublinear_bits_are_sublinear() {
        let inputs = gen(8, 64, 10.0, 0.5, 1);
        let out = sublinear_mean_estimation(&inputs, 0.2, 1.0, 2, 0);
        // 64·log2(1.4) ≈ 31 bits ≪ 64 coordinates.
        let max_sent = out.traffic.iter().map(|t| t.sent_bits).max().unwrap();
        assert!(max_sent <= 2 * 32, "bits {max_sent}");
        assert!(!out.exact);
    }

    #[test]
    fn exact_small_d_path_agrees_across_machines() {
        let inputs = gen(6, 4, 5.0, 0.05, 3);
        let out = sublinear_mean_estimation(&inputs, 2.0, 0.5, 4, 0);
        // estimate near the source input (variance d·s²/12 envelope).
        let s = 0.5 / 2.0 * 2.0;
        assert!(dist2(&out.estimate, &inputs[out.source]) <= s * 2.0);
    }

    #[test]
    fn unbiased_for_the_mean_over_rounds() {
        // E[EST] = E[x_source] = μ (+ unbiased quantization).
        let inputs = gen(4, 4, 0.0, 1.0, 5);
        let mu = mean_vecs(&inputs);
        let rounds = 4000;
        let mut acc = vec![0.0; 4];
        for r in 0..rounds {
            let out = sublinear_mean_estimation(&inputs, 0.5, 2.5, 6, r);
            crate::linalg::axpy(&mut acc, 1.0, &out.estimate);
        }
        for (a, m) in acc.iter().zip(&mu) {
            let mean = a / rounds as f64;
            assert!((mean - m).abs() < 0.2, "{mean} vs {m}");
        }
    }

    #[test]
    fn variance_model_decreases_with_q() {
        let inputs = gen(2, 16, 0.0, 1.0, 7);
        let v1 = sublinear_mean_estimation(&inputs, 0.25, 1.0, 8, 0).model_variance;
        let v2 = sublinear_mean_estimation(&inputs, 1.0, 1.0, 8, 0).model_variance;
        assert!(v2 < v1);
    }
}
