//! Input-variance (`y`) estimation policies — Section 9's practical
//! mechanisms for maintaining the distance bound across SGD iterations.
//!
//! * `Fixed` — a constant bound (used when a pre-computed estimate exists).
//! * `FromQuantized` — Experiment 2/3's rule: after a successful round,
//!!  every machine knows all quantized points, so
//!   `y(t+1) = slack · max_{i,j} ‖Q(g_i) − Q(g_j)‖∞` needs no extra
//!   communication.
//! * `LeaderMeasured` — Experiment 5's rule: the leader measures the same
//!   quantity and broadcasts it as one 64-bit float per round (the bit
//!   cost is charged to the caller via [`YEstimator::broadcast_bits`]).
//!
//! For RLQSGD the same policies apply to the *rotated* vectors (`y_R`).

use crate::linalg::dist_inf;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum YPolicy {
    /// Constant y.
    Fixed,
    /// y(t+1) = slack · max pairwise ℓ∞ distance of quantized points;
    /// every machine computes it locally (zero communication).
    FromQuantized { slack: f64 },
    /// As `FromQuantized` but computed at the leader and broadcast as a
    /// 64-bit float (n−1 messages charged per update period).
    LeaderMeasured { slack: f64, period: usize },
}

/// Stateful y estimator driven once per round.
#[derive(Clone, Debug)]
pub struct YEstimator {
    pub policy: YPolicy,
    pub y: f64,
    rounds_seen: usize,
}

impl YEstimator {
    pub fn new(policy: YPolicy, y0: f64) -> Self {
        assert!(y0 > 0.0, "initial y must be positive");
        YEstimator {
            policy,
            y: y0,
            rounds_seen: 0,
        }
    }

    /// Max pairwise ℓ∞ distance among vectors.
    pub fn max_pairwise_inf(points: &[Vec<f64>]) -> f64 {
        let mut m: f64 = 0.0;
        for i in 0..points.len() {
            for j in i + 1..points.len() {
                m = m.max(dist_inf(&points[i], &points[j]));
            }
        }
        m
    }

    /// Whether the *next* [`Self::update_spread`] will consume a spread
    /// measurement. The session forwards this to the leader's machine
    /// thread so the O(n²·d) pairwise measurement (and the O(n·d) decoded
    /// collection behind it) runs only on rounds that need it — the
    /// streaming-fold leader path skips both entirely.
    pub fn needs_spread(&self) -> bool {
        match self.policy {
            YPolicy::Fixed => false,
            YPolicy::FromQuantized { .. } => true,
            YPolicy::LeaderMeasured { period, .. } => {
                period > 0 && (self.rounds_seen + 1) % period.max(1) == 0
            }
        }
    }

    /// Update from this round's quantized points (decoded at the leader).
    /// Returns the bits of side communication incurred by the policy.
    pub fn update(&mut self, quantized_points: &[Vec<f64>], n_machines: usize) -> u64 {
        let spread = if self.needs_spread() {
            Some(Self::max_pairwise_inf(quantized_points))
        } else {
            None
        };
        self.update_spread(spread, n_machines)
    }

    /// Update from a pre-computed max-pairwise-ℓ∞ spread measurement
    /// (`None` when the policy did not request one this round — see
    /// [`Self::needs_spread`]). This is the session's entry point: the
    /// measurement is taken at the leader, which ships back one scalar
    /// instead of `n` decoded vectors. Returns the policy's side bits.
    pub fn update_spread(&mut self, spread: Option<f64>, n_machines: usize) -> u64 {
        self.rounds_seen += 1;
        match self.policy {
            YPolicy::Fixed => 0,
            YPolicy::FromQuantized { slack } => {
                self.apply(slack, spread.expect("FromQuantized measures every round"));
                0
            }
            YPolicy::LeaderMeasured { slack, period } => {
                if period == 0 || self.rounds_seen % period.max(1) != 0 {
                    return 0;
                }
                self.apply(slack, spread.expect("LeaderMeasured measures on period rounds"));
                // Leader broadcasts one f64 to n−1 machines.
                64 * (n_machines.saturating_sub(1) as u64)
            }
        }
    }

    fn apply(&mut self, slack: f64, m: f64) {
        if m > 0.0 {
            self.y = slack * m;
        } else {
            // All points quantized identically: the lattice is far
            // coarser than the true spread. Decay y geometrically
            // so the side length tracks the shrinking gradients
            // (decode still succeeds — spread < s/2 certainly).
            self.y *= 0.5;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_never_moves() {
        let mut e = YEstimator::new(YPolicy::Fixed, 2.0);
        e.update(&[vec![0.0, 0.0], vec![100.0, 0.0]], 4);
        assert_eq!(e.y, 2.0);
    }

    #[test]
    fn from_quantized_tracks_spread() {
        let mut e = YEstimator::new(YPolicy::FromQuantized { slack: 1.5 }, 1.0);
        let bits = e.update(&[vec![0.0, 0.0], vec![0.4, -0.2], vec![0.1, 0.6]], 3);
        assert_eq!(bits, 0);
        assert!((e.y - 1.5 * 0.8).abs() < 1e-12);
    }

    #[test]
    fn zero_spread_decays_y_geometrically() {
        let mut e = YEstimator::new(YPolicy::FromQuantized { slack: 2.0 }, 0.7);
        e.update(&[vec![1.0, 1.0], vec![1.0, 1.0]], 2);
        assert_eq!(e.y, 0.35, "degenerate measurement must decay, not zero");
        e.update(&[vec![1.0, 1.0], vec![1.0, 1.0]], 2);
        assert_eq!(e.y, 0.175);
    }

    #[test]
    fn update_spread_matches_update_and_needs_spread_gates_measurement() {
        let pts = vec![vec![0.0, 0.0], vec![0.4, -0.2], vec![0.1, 0.6]];
        let mut a = YEstimator::new(YPolicy::FromQuantized { slack: 1.5 }, 1.0);
        let mut b = YEstimator::new(YPolicy::FromQuantized { slack: 1.5 }, 1.0);
        assert!(b.needs_spread());
        a.update(&pts, 3);
        b.update_spread(Some(YEstimator::max_pairwise_inf(&pts)), 3);
        assert_eq!(a.y, b.y);

        // LeaderMeasured only wants a measurement on period rounds.
        let mut e = YEstimator::new(
            YPolicy::LeaderMeasured {
                slack: 2.0,
                period: 3,
            },
            1.0,
        );
        let mut measured = 0;
        for _ in 0..9 {
            let spread = e.needs_spread().then_some(2.0);
            if spread.is_some() {
                measured += 1;
            }
            e.update_spread(spread, 4);
        }
        assert_eq!(measured, 3);
        assert!((e.y - 4.0).abs() < 1e-12);
        assert!(!YEstimator::new(YPolicy::Fixed, 1.0).needs_spread());
    }

    #[test]
    fn leader_measured_charges_bits_periodically() {
        let mut e = YEstimator::new(
            YPolicy::LeaderMeasured {
                slack: 3.0,
                period: 5,
            },
            1.0,
        );
        let pts = vec![vec![0.0], vec![2.0]];
        let mut total = 0;
        for _ in 0..10 {
            total += e.update(&pts, 8);
        }
        assert_eq!(total, 2 * 64 * 7);
        assert!((e.y - 6.0).abs() < 1e-12);
    }
}
