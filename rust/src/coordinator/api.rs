//! The unified session API — `DmeBuilder` → [`DmeSession`].
//!
//! The paper's deployment story (§9, variance-reduced parallel SGD) is
//! thousands of rounds over the same machines, so the primary entry point
//! is a *persistent* session rather than the historical one-shot free
//! functions: the builder fixes the cluster shape (`n`, `d`), the
//! [`Topology`] (star or binary tree), the [`CodecSpec`], the `y`
//! maintenance [`YPolicy`] and the variance-reduction [`Robustness`];
//! [`DmeSession::round`] then drives MeanEstimation rounds over
//! long-lived machine threads, and every protocol — star, tree, robust
//! VR, sublinear — reports through one [`RoundOutcome`].
//!
//! Performance (§Perf): spawning one thread per machine per round costs
//! ~20 µs/thread, an order of magnitude more than the quantization work
//! itself at small `d`. The session keeps the cluster threads alive for
//! its whole lifetime — and those threads are **leases from the
//! process-wide persistent pool** ([`crate::pool::lease`]): the first
//! session pays the OS spawns, every later session (and every ad-hoc
//! [`crate::sim::Cluster::run`]) reuses the parked threads, so
//! build-session-per-experiment loops stop paying n spawns each. The
//! pool's fixed-size chunk tier similarly backs the sharded
//! [`crate::quant::encode_chunked`] / [`super::fold_mean_chunked`] data
//! plane — see [`crate::pool`] §Perf for the two-tier lifecycle. The
//! session also recycles every per-machine buffer through the round loop (input and
//! output vectors ping-pong between driver and workers; encode/decode go
//! through [`VectorCodec::encode_into`] / `decode_into` scratch space),
//! so the steady-state round allocates O(1) rather than O(n·d) vectors.
//!
//! Aggregation is a **streaming fold** (§Perf): the leader never
//! materializes the `n` decoded vectors — each arriving packet is folded
//! straight into the O(d) accumulator by
//! [`VectorCodec::decode_accumulate_into`], one fused pass over the
//! packed bitstream, in pinned machine order (machine 0 first, the
//! leader's own input folded at its machine index) so the sum is
//! bit-identical to the historical decode-all-then-sum. The O(n·d)
//! decoded collection survives only behind [`DmeBuilder::diagnostics`]
//! and the `y`-policy measurement rounds, in buffers the leader recycles
//! across rounds; `y` policies ship one spread scalar back to the driver
//! instead of `n` vectors. Tree inner nodes fold their children the same
//! way. For offline aggregation of very wide vectors there is also a
//! chunk-sharded parallel fold — see [`super::fold`].
//!
//! The encode side of every machine is the same story in the other
//! direction (§Perf): `encode_into` runs the codecs' fused block
//! kernels — round → mask-color → one packed accumulator store per
//! ⌊64/width⌋ colors via [`crate::quant::bits::BitWriter::push_block`],
//! with RLQSGD's rotation a single-pass cache-blocked multi-radix FWHT —
//! so sessions pick the whole vectorized encode plane up automatically,
//! bit-identically to the scalar per-coordinate encode (pinned by
//! `rust/tests/session_parity.rs`). The baseline comparators ride the
//! same surface (fused block encode fed by bulk uniforms, fused fold
//! kernels — see [`crate::quant::baselines`] §Perf), so head-to-head
//! experiment sessions are fast on *both* sides of the comparison. A
//! machine encoding one huge gradient can additionally shard the pack
//! across cores with [`crate::quant::encode_chunked`] (codecs gated by
//! [`crate::quant::VectorCodec::supports_encode_range`]: the lattice
//! family minus RLQSGD, full precision, and the fixed-width baselines),
//! the write-side twin of the chunked fold.
//!
//! With the data plane vectorized, the per-round *control plane* — one
//! command/response channel crossing per worker (~20 µs/machine), one
//! staged wire `Message` per worker, one shared-randomness derivation —
//! dominates at small-to-medium `d`. The batch round plane (§Perf)
//! amortizes all three: [`DmeSession::round_batch`] (and
//! `round_batch_with_y` / `round_vr_batch`) processes `B` vectors per
//! machine with **one** crossing per worker per batch. Inputs and
//! outputs travel as flat per-worker arenas (slot vectors concatenated,
//! recycled across batches); each worker pre-encodes all its uploads
//! back-to-back through the fused block kernels into a pooled
//! [`crate::quant::PacketArena`] (one recycled `Vec<u8>` of
//! length-prefixed packets — replacing the per-round staged `Message`);
//! per-slot shared randomness comes from a single
//! [`crate::rng::fork_round_seeds`] fan-out per batch; and the leader
//! folds each slot through the same streaming
//! `decode_accumulate_into` path as sequential rounds. The batch is a
//! pure *scheduling* change: slot `b` of a batch starting at round `r`
//! is bit-identical — estimate, outputs, and per-machine traffic — to a
//! sequential round at index `r + b` with the same `(seed, y)`, pinned
//! by `rust/tests/session_parity.rs`. Steady-state batch allocation is
//! O(1): input/output arenas, traffic tallies, and the packet arena are
//! recycled, and `round_batch_into` additionally recycles the caller's
//! outcome buffers. (Per-slot codec construction — the shared-randomness
//! dither offsets — and the wire packets themselves are data-plane costs
//! identical to sequential rounds.)
//!
//! Protocol behavior is bit-identical to the legacy one-shot functions
//! (`mean_estimation_star`, `mean_estimation_tree`,
//! `robust_variance_reduction`) for the same `(seed, round)` — those now
//! wrap a one-round session, and `rust/tests/session_parity.rs` pins the
//! equivalence against independent reference implementations.
//!
//! # Transport
//!
//! The protocol bodies are generic over
//! [`crate::net::TransportEndpoint`], so the *same code* that the
//! session workers run over in-process channels also runs over TCP (or
//! any other transport) — parity is by construction, not by a parallel
//! implementation. The contract the bodies rely on:
//!
//! - **Trait surface**: `send`/`recv`/`recv_from`/`broadcast`, all
//!   returning [`crate::net::TransportError`]; `recv_from` maintains
//!   per-peer FIFO delivery (out-of-order packets from other peers are
//!   stashed, never dropped), which is what lets the leader stream-fold
//!   in pinned machine order and lets batch slots interleave across
//!   machines.
//! - **Framing**: wire messages are [`Message`]s; over byte streams
//!   they travel as `[bits: u64 LE][len: u32 LE][bytes]` frames — the
//!   [`PacketArena`] format verbatim (`crate::net::frame`), so the
//!   staged in-process batch arena and a TCP upload stream are
//!   byte-identical.
//! - **Metering**: senders charge `msg.bits` (the codec's exact metered
//!   bits, not padded wire bytes) before delivery is attempted;
//!   receivers are charged at delivery. After any completed round the
//!   per-machine [`Traffic`] totals are transport-independent — the
//!   loopback-TCP parity suite (`rust/tests/transport.rs`) asserts
//!   estimates, diagnostics *and* metered bit counts match the
//!   in-process reference exactly.
//!
//! [`star_round_over`] / [`vr_round_over`] expose one machine's side of
//! a star ME / VR round over any endpoint; inside the session the same
//! core runs behind the worker loops. A worker hitting a transport
//! error reports a fatal message to the driver instead of panicking the
//! process ([`crate::sim::Cluster::try_run`] is the graceful variant
//! for ad-hoc cluster closures).
//!
//! # Straggler policy (k-of-n partial rounds)
//!
//! Full rounds are all-or-nothing: every receive blocks until its
//! packet arrives, so one lost upload wedges the round. A
//! [`StragglerPolicy`] — a per-round deadline, a minimum quorum
//! `k_min`, and a [`RetrySchedule`] whose jittered backoff windows pace
//! the receive attempts — turns the same protocols into k-of-n rounds:
//! [`DmeSession::round_partial`], [`star_round_partial_over`] /
//! [`vr_round_partial_over`], and the tree's partial fold.
//!
//! The semantics deliberately mirror the PR 6 service layer
//! ([`crate::net::service`]) — see the mapping in the [`crate::net`]
//! module docs. In a star round the leader gathers whatever uploads
//! beat the deadline (first copy per sender; duplicates are discarded),
//! folds the `k ≤ n` reports **in pinned machine order** — so the
//! partial estimate is a deterministic function of the arrived *set*,
//! not of arrival timing — and renormalizes by `1/k` with the identical
//! `inv_k * acc` arithmetic as the cohort table's `OpenRound::close`.
//! In a tree round a parent that times out on a child folds only the
//! arrived side: with both children present it halves exactly like the
//! full fold (so a zero-fault partial round is bit-identical to the
//! full path), with one present the surviving child passes through
//! unhalved — the pairwise analogue of the star's renormalization —
//! and arrived-leaf counts ride the upward messages so the root knows
//! its exact participation `k`. If `k < k_min` the coordinator answers
//! nobody and the round surfaces as the typed
//! [`TransportError::QuorumFailed`]; the session stays usable.
//!
//! Partial-mode wire messages carry a 17-byte
//! `[round: u64][weight: u64][dir: u8]` trailer (honestly metered): the
//! round tag lets deadline-crossing packets from earlier rounds be
//! recognized and discarded — the in-round form of the service
//! protocol's explicit `(cohort, round)` keys — the weight carries the
//! arrived-leaf counts, and the direction bit disambiguates an upward
//! report from a downward relay when drops reorder who hears what.
//! Every receive wait is paced by the policy's retry windows;
//! [`RoundOutcome::retries_used`] totals the windows that expired,
//! [`RoundOutcome::participants`] and [`RoundOutcome::dropped`] report
//! who made it. Faults to exercise all of this come from a seeded
//! [`crate::net::faulty::FaultPlan`] attached via
//! [`DmeBuilder::fault_plan`]; a session holding a plan must drive
//! `round_partial` (full rounds would block forever on a dropped
//! packet, so they assert the plan is absent).

use super::topology::Topology;
use super::tree::tree_round_schedule;
use super::variance_reduction::{robust_vr_core, vr_y_bound};
use super::{CodecSpec, YEstimator, YPolicy};
use crate::net::faulty::{FaultPlan, FaultyEndpoint};
use crate::net::retry::{BackoffWindows, RetrySchedule};
use crate::net::{TransportEndpoint, TransportError};
use crate::quant::{CubicLattice, LatticeQuantizer, Message, PacketArena, VectorCodec};
use crate::rng::{fork_round_seeds, hash2, Rng};
use crate::sim::{summarize, Cluster, Endpoint, Traffic, TrafficSummary};
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

/// How [`DmeSession::round_vr`] turns a variance bound into a protocol.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Robustness {
    /// Theorem 17 reduction: MeanEstimation with the Chebyshev envelope
    /// `y = 2σ√(αn)` over the session's topology. Succeeds with
    /// probability ≥ 1 − 1/α.
    Chebyshev,
    /// Algorithm 6: pairwise RobustAgreement through a random leader —
    /// bits adapt to the true distances and heavy-tailed inputs escalate
    /// instead of corrupting the mean. `q0` is the starting quantization
    /// parameter.
    ErrorDetecting { q0: u32 },
}

/// Per-round straggler policy for k-of-n partial rounds (see the module
/// §Straggler policy): how long the coordinator gathers, how many
/// reports it must fold, and how the receive attempts are paced.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StragglerPolicy {
    /// Gather budget per wait. The coordinator's gather runs at most
    /// this long; machines waiting for the coordinator's answer wait up
    /// to `2 × deadline` (a healthy coordinator always answers within
    /// its own gather deadline, so its broadcast lands in that window).
    pub deadline: Duration,
    /// Minimum quorum, counting the coordinator's own input. A round
    /// whose deadline passes with fewer than `k_min` reports fails with
    /// [`TransportError::QuorumFailed`] instead of producing an
    /// estimate.
    pub k_min: usize,
    /// Backoff windows pacing the receive attempts (seed it for
    /// reproducible retry counts — the same schedule the TCP transport
    /// dials with, see [`crate::net::tcp::TcpOpts::retry_schedule`]).
    pub retry: RetrySchedule,
}

impl Default for StragglerPolicy {
    fn default() -> Self {
        StragglerPolicy {
            deadline: Duration::from_millis(1_000),
            k_min: 1,
            retry: RetrySchedule::default(),
        }
    }
}

impl StragglerPolicy {
    /// A deterministic policy sized for in-process tests: backoff
    /// windows that exhaust well before `deadline` (so retry counts are
    /// timing-independent) and seeded jitter.
    pub fn deterministic(deadline: Duration, k_min: usize, seed: u64) -> Self {
        StragglerPolicy {
            deadline,
            k_min,
            retry: RetrySchedule::deterministic(
                3,
                Duration::from_millis(10),
                Duration::from_millis(40),
                seed,
            ),
        }
    }
}

/// One round's result — the single outcome type for every protocol the
/// session runs (star / tree MeanEstimation, robust VR, sublinear ME).
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    /// The session round this outcome belongs to.
    pub round: u64,
    /// The common output estimate of the mean.
    pub estimate: Vec<f64>,
    /// The agreement invariant: did every machine output the same vector?
    pub agreement: bool,
    /// The distance bound in effect (for VR rounds: σ, or the Chebyshev
    /// `y` via [`Robustness::Chebyshev`]).
    pub y_used: f64,
    /// Star leader / robust-VR leader / sublinear source machine.
    pub leader: Option<usize>,
    /// Tree topology: the sampled leaf set T (empty otherwise).
    pub leaves: Vec<usize>,
    /// Tree topology: effective color count of the tree quantizer.
    pub q_used: Option<u32>,
    /// Robust VR: RobustAgreement escalation rounds per worker (stage 1)
    /// and per broadcast (stage 2); empty for other protocols.
    pub rounds_stage1: Vec<u32>,
    pub rounds_stage2: Vec<u32>,
    /// Every machine's output — populated only with
    /// [`DmeBuilder::diagnostics`] (the hot path recycles these buffers).
    pub outputs: Vec<Vec<f64>>,
    /// Star topology: the leader's decoded per-worker estimates, present
    /// only with [`DmeBuilder::diagnostics`] (the hot path streams the
    /// fold and never materializes them; `y` policies consume a spread
    /// scalar measured at the leader instead).
    pub decoded_at_leader: Vec<Vec<f64>>,
    /// Exact per-machine traffic of *this round* (including `y`-policy
    /// side communication).
    pub round_traffic: Vec<Traffic>,
    /// Cumulative traffic summary since session start.
    pub traffic: TrafficSummary,
    /// How many machines' reports the coordinator folded — `n` for full
    /// rounds, the quorum `k ≤ n` for k-of-n partial rounds.
    pub participants: usize,
    /// k-of-n rounds: machines whose reports missed the deadline (star:
    /// the leader's exact arrival record; tree: the machines whose
    /// endpoints were send-silenced this round). Empty for full rounds.
    pub dropped: Vec<usize>,
    /// k-of-n rounds: total backoff windows that expired across all
    /// machines' receive waits this round. 0 for full rounds.
    pub retries_used: u32,
}

impl RoundOutcome {
    /// Max bits sent by any machine this round — the per-iteration cost
    /// the optimizer traces record.
    pub fn max_sent_bits(&self) -> u64 {
        self.round_traffic
            .iter()
            .map(|t| t.sent_bits)
            .max()
            .unwrap_or(0)
    }

    /// Reset every field for reuse, keeping buffer capacity — the batch
    /// plane's outcome recycling (see [`DmeSession::round_batch_into`]).
    /// The exhaustive destructuring makes adding a `RoundOutcome` field
    /// without updating this reset a compile error, so recycled outcomes
    /// can never leak a stale field across batches.
    fn reset_for_reuse(&mut self) {
        let RoundOutcome {
            round,
            estimate,
            agreement,
            y_used,
            leader,
            leaves,
            q_used,
            rounds_stage1,
            rounds_stage2,
            outputs,
            decoded_at_leader,
            round_traffic,
            traffic,
            participants,
            dropped,
            retries_used,
        } = self;
        *round = 0;
        estimate.clear();
        *agreement = true;
        *y_used = 0.0;
        *leader = None;
        leaves.clear();
        *q_used = None;
        rounds_stage1.clear();
        rounds_stage2.clear();
        outputs.clear();
        decoded_at_leader.clear();
        round_traffic.clear();
        *traffic = TrafficSummary::default();
        *participants = 0;
        dropped.clear();
        *retries_used = 0;
    }
}

/// Configures and builds a [`DmeSession`].
#[derive(Clone, Debug)]
pub struct DmeBuilder {
    n: usize,
    d: usize,
    topology: Topology,
    spec: CodecSpec,
    y0: f64,
    y_policy: YPolicy,
    robustness: Robustness,
    alpha: f64,
    seed: u64,
    diagnostics: bool,
    fault_plan: Option<FaultPlan>,
}

impl DmeBuilder {
    /// Start a builder for `n` machines exchanging `d`-dimensional
    /// vectors. Defaults: star topology, `LQSGD(q=16)`, fixed `y = 1`,
    /// Chebyshev VR with `α = 4`, seed 0, diagnostics off.
    pub fn new(n: usize, d: usize) -> Self {
        assert!(n >= 1, "need at least one machine");
        assert!(d >= 1, "need at least one dimension");
        DmeBuilder {
            n,
            d,
            topology: Topology::Star,
            spec: CodecSpec::Lq { q: 16 },
            y0: 1.0,
            y_policy: YPolicy::Fixed,
            robustness: Robustness::Chebyshev,
            alpha: 4.0,
            seed: 0,
            diagnostics: false,
            fault_plan: None,
        }
    }

    /// Select the communication topology (see [`Topology`]).
    pub fn topology(mut self, topology: Topology) -> Self {
        self.topology = topology;
        self
    }

    /// Select the compressor (star topology; the tree uses the paper's
    /// own `ε = y/m²`, `q = m³` lattice parameterization). Stateful
    /// codecs (EF-SignSGD, PowerSGD, Top-K) are built once per machine
    /// and keep their error memory across the session's rounds; shared-
    /// randomness codecs are rebuilt from `(seed, round)` every round.
    pub fn codec(mut self, spec: CodecSpec) -> Self {
        self.spec = spec;
        self
    }

    /// Initial distance bound `y` (ℓ∞; rotated-space for RLQ).
    pub fn y0(mut self, y0: f64) -> Self {
        assert!(y0 > 0.0, "y0 must be positive");
        self.y0 = y0;
        self
    }

    /// How `y` is maintained across rounds (star topology only — the
    /// tree's `y` is an explicit per-round argument; see
    /// [`DmeSession::round_with_y`]).
    pub fn y_policy(mut self, policy: YPolicy) -> Self {
        self.y_policy = policy;
        self
    }

    /// Seed for all shared randomness (leader schedule, lattice offsets,
    /// rotations); two sessions with equal configuration and seed run
    /// bit-identical protocols.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Chebyshev VR failure-budget parameter (success prob ≥ 1 − 1/α).
    pub fn alpha(mut self, alpha: f64) -> Self {
        assert!(alpha > 1.0, "alpha must exceed 1");
        self.alpha = alpha;
        self
    }

    /// Use error-detecting VR (Algorithm 6) with initial parameter `q0`
    /// instead of the Chebyshev reduction.
    pub fn robust(mut self, q0: u32) -> Self {
        self.robustness = Robustness::ErrorDetecting { q0 };
        self
    }

    /// Collect per-machine outputs and the leader's decoded points into
    /// each [`RoundOutcome`] (off by default: the hot path recycles those
    /// buffers instead).
    pub fn diagnostics(mut self, on: bool) -> Self {
        self.diagnostics = on;
        self
    }

    /// Inject deterministic per-machine per-round faults into the
    /// session's transport (see [`FaultPlan`]). A faulted session must
    /// be driven through [`DmeSession::round_partial`] — the
    /// full-participation planes block on every machine's report and
    /// assert the plan is absent (see the module §Straggler policy).
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    /// Build the session. Machine threads spawn lazily on the first
    /// MeanEstimation round and live until the session drops.
    pub fn build(self) -> DmeSession {
        if matches!(self.topology, Topology::Tree { .. }) {
            assert!(
                self.y_policy == YPolicy::Fixed,
                "tree topology has no leader to measure y: use YPolicy::Fixed \
                 and round_with_y (got {:?})",
                self.y_policy
            );
        }
        DmeSession {
            n: self.n,
            d: self.d,
            topology: self.topology,
            spec: self.spec,
            seed: self.seed,
            robustness: self.robustness,
            alpha: self.alpha,
            diagnostics: self.diagnostics,
            fault_plan: self.fault_plan,
            y_est: YEstimator::new(self.y_policy, self.y0),
            cluster: Cluster::new(self.n),
            workers: None,
            round: 0,
            last_snapshot: vec![Traffic::default(); self.n],
            bufs: (0..self.n).map(|_| None).collect(),
            batch_bufs: (0..self.n).map(|_| None).collect(),
        }
    }
}

/// A long-lived cluster running the paper's protocols round after round —
/// see the [module docs](self) for the design and cost model.
pub struct DmeSession {
    n: usize,
    d: usize,
    topology: Topology,
    spec: CodecSpec,
    seed: u64,
    robustness: Robustness,
    alpha: f64,
    diagnostics: bool,
    fault_plan: Option<FaultPlan>,
    y_est: YEstimator,
    cluster: Cluster,
    workers: Option<Workers>,
    round: u64,
    /// Meter snapshot at the end of the previous round (per-round deltas).
    last_snapshot: Vec<Traffic>,
    /// Recycled per-machine (input, output) buffers.
    bufs: Vec<Option<(Vec<f64>, Vec<f64>)>>,
    /// Recycled per-machine batch arenas (§Perf: one flat input arena,
    /// one output arena, one tally vector per worker, reused across
    /// `round_batch` calls).
    batch_bufs: Vec<Option<BatchCmd>>,
}

struct Workers {
    cmd_tx: Vec<Sender<Cmd>>,
    out_rx: Vec<Receiver<WorkerMsg>>,
    /// Leased pool threads (§Perf): the session borrows parked workers
    /// from [`crate::pool`] for its lifetime instead of spawning; on drop
    /// the threads return to the pool for the next session to reuse.
    handles: Vec<crate::pool::Lease<()>>,
}

/// One driver→worker channel crossing: a single round, a whole batch,
/// or a k-of-n partial round under a straggler policy.
enum Cmd {
    Round(RoundCmd),
    Batch(BatchCmd),
    Partial(PartialCmd),
}

/// One round's instruction to a machine thread. The vectors are recycled
/// buffers owned by the driver between rounds and by the worker during
/// one: `input` arrives filled, `out` returns filled.
struct RoundCmd {
    round: u64,
    y: f64,
    /// The `y` policy wants a spread measurement this round (the leader
    /// then collects decoded points and measures max pairwise ℓ∞).
    measure: bool,
    input: Vec<f64>,
    out: Vec<f64>,
}

/// A batch of `B` rounds in one crossing (§Perf). All vectors are
/// recycled driver-owned arenas: `input`/`out` hold the machine's `B`
/// slot vectors concatenated in slot order (`dims[b]` coordinates each),
/// `traffic` arrives zeroed and returns the worker's exact per-slot
/// sent/received tally (the per-slot decomposition of the cluster
/// meters, which only observe the batch total).
#[derive(Default)]
struct BatchCmd {
    first_round: u64,
    /// Explicit distance bound per slot.
    ys: Vec<f64>,
    /// Per-slot dimensions (identical across machines).
    dims: Vec<usize>,
    input: Vec<f64>,
    out: Vec<f64>,
    traffic: Vec<Traffic>,
}

/// One partial round's instruction to a machine thread (the k-of-n
/// plane; see the module §Straggler policy). Buffers recycle exactly
/// like [`RoundCmd`]'s.
struct PartialCmd {
    round: u64,
    y: f64,
    policy: StragglerPolicy,
    input: Vec<f64>,
    out: Vec<f64>,
}

enum WorkerMsg {
    Round(WorkerOut),
    Batch(BatchOut),
    Partial(PartialOut),
    /// The worker hit a transport failure and is exiting; the driver
    /// surfaces it instead of the old poison-the-process panic cascade.
    Fatal(TransportError),
}

struct WorkerOut {
    input: Vec<f64>,
    output: Vec<f64>,
    /// Leader only, with diagnostics on (a per-round copy for the caller;
    /// the working buffers stay in the worker and are recycled).
    decoded: Vec<Vec<f64>>,
    /// Leader only, when `RoundCmd::measure` asked for it: the max
    /// pairwise ℓ∞ distance of the decoded points (§9.2 `y` policies).
    spread: Option<f64>,
}

/// A batch's response: the same recycled arenas handed back, plus (with
/// diagnostics on) the decoded per-machine points of every slot this
/// machine led.
struct BatchOut {
    ys: Vec<f64>,
    dims: Vec<usize>,
    input: Vec<f64>,
    out: Vec<f64>,
    traffic: Vec<Traffic>,
    /// `decoded[b]` is non-empty only for slots this machine led while
    /// diagnostics were on.
    decoded: Vec<Vec<Vec<f64>>>,
}

/// A partial round's response. `k`/`arrived`/`quorum_failed` are
/// authoritative only on the machine whose `is_coordinator` is set (the
/// star leader / tree root); everyone reports its own `out`, whether it
/// received one, its retry tally and whether the fault plan silenced
/// its sends this round.
struct PartialOut {
    input: Vec<f64>,
    out: Vec<f64>,
    /// This machine decoded an estimate (coordinator always; others
    /// only if the downward broadcast reached them before the cutoff).
    got_output: bool,
    k: usize,
    /// Star coordinator only: exact per-machine arrival record.
    arrived: Vec<bool>,
    retries: u32,
    quorum_failed: bool,
    /// The fault plan silenced this machine's sends this round.
    silenced: bool,
    is_coordinator: bool,
}

/// What a cluster round produced before traffic accounting.
struct Collected {
    estimate: Vec<f64>,
    agreement: bool,
    outputs: Vec<Vec<f64>>,
    decoded_at_leader: Vec<Vec<f64>>,
    spread: Option<f64>,
    leader: Option<usize>,
    leaves: Vec<usize>,
    q_used: Option<u32>,
}

fn star_leader(seed: u64, round: u64, n: usize) -> usize {
    Rng::new(hash2(seed, round ^ 0x1EAD)).next_below(n as u64) as usize
}

/// Take a recycled outcome from `pool` (every field reset, buffer
/// capacity kept) or build an empty one — the batch plane's outcome
/// recycling (§Perf; see [`DmeSession::round_batch_into`]).
fn recycle_outcome(pool: &mut Vec<RoundOutcome>) -> RoundOutcome {
    match pool.pop() {
        Some(mut o) => {
            o.reset_for_reuse();
            o
        }
        None => RoundOutcome {
            round: 0,
            estimate: Vec::new(),
            agreement: true,
            y_used: 0.0,
            leader: None,
            leaves: Vec::new(),
            q_used: None,
            rounds_stage1: Vec::new(),
            rounds_stage2: Vec::new(),
            outputs: Vec::new(),
            decoded_at_leader: Vec::new(),
            round_traffic: Vec::new(),
            traffic: TrafficSummary::default(),
            participants: 0,
            dropped: Vec::new(),
            retries_used: 0,
        },
    }
}

impl DmeSession {
    /// Run one MeanEstimation round with the session's current `y`
    /// (maintained by the configured [`YPolicy`]); `inputs[v]` is machine
    /// v's vector.
    pub fn round(&mut self, inputs: &[Vec<f64>]) -> RoundOutcome {
        self.check_inputs(inputs);
        let y = self.y_est.y;
        let round = self.next_round();
        let measure = self.y_est.needs_spread();
        let parts = self.run_cluster_round(inputs, y, round, measure);
        // Maintain y from the spread the leader measured over its decoded
        // points (§9.2 policies) — one scalar crosses the channel, not
        // n vectors. The builder restricts non-Fixed policies to the star
        // topology.
        if self.y_est.policy != YPolicy::Fixed {
            debug_assert!(matches!(self.topology, Topology::Star));
            let side = self.y_est.update_spread(parts.spread, self.n);
            if side > 0 && self.n > 1 {
                // LeaderMeasured: the leader ships one f64 per peer.
                let leader = parts.leader.unwrap_or(0);
                let per = side / (self.n as u64 - 1);
                let mut extra = vec![Traffic::default(); self.n];
                for (v, t) in extra.iter_mut().enumerate() {
                    if v == leader {
                        t.sent_bits = side;
                    } else {
                        t.recv_bits = per;
                    }
                }
                self.cluster.add_traffic(&extra);
            }
        }
        self.outcome(round, y, parts)
    }

    /// Run one MeanEstimation round at an explicit distance bound,
    /// leaving the session's `y` estimator untouched (the legacy one-shot
    /// contract; also the natural call for the tree topology).
    pub fn round_with_y(&mut self, inputs: &[Vec<f64>], y: f64) -> RoundOutcome {
        self.check_inputs(inputs);
        let round = self.next_round();
        let parts = self.run_cluster_round(inputs, y, round, false);
        self.outcome(round, y, parts)
    }

    /// Run `B = inputs.len()` MeanEstimation rounds in one batch at the
    /// session's current `y` (§Perf): `inputs[b]` is slot `b`'s
    /// per-machine vectors — exactly the argument a sequential
    /// [`DmeSession::round`] call would take. The whole batch costs
    /// **one** command/response channel crossing per worker; each worker
    /// pre-encodes all its uploads back-to-back into a pooled
    /// [`PacketArena`] and per-slot shared randomness is derived by a
    /// single [`fork_round_seeds`] fan-out. Slot `b` is bit-identical —
    /// estimate, outputs, per-machine traffic — to a sequential round at
    /// index `first_round + b` (pinned by `rust/tests/session_parity.rs`).
    ///
    /// Slots may have different dimensions than the session's `d` (the
    /// per-layer SGD use: one slot per layer gradient); stateful codecs
    /// (EF-SignSGD, PowerSGD, Top-K) keep one error memory at dimension
    /// `d` and therefore require uniform `d`-sized slots. Adaptive `y`
    /// policies measure at the leader *between* rounds, which a batch
    /// deliberately amortizes away — sessions with a non-`Fixed` policy
    /// should either drive sequential [`DmeSession::round`] calls or pass
    /// explicit per-slot bounds via [`DmeSession::round_batch_with_y`].
    pub fn round_batch(&mut self, inputs: &[Vec<Vec<f64>>]) -> Vec<RoundOutcome> {
        assert_eq!(
            self.y_est.policy,
            YPolicy::Fixed,
            "adaptive y policies measure at the leader between rounds; use \
             sequential round() or explicit bounds via round_batch_with_y"
        );
        let ys = vec![self.y_est.y; inputs.len()];
        let mut outcomes = Vec::new();
        self.round_batch_core(inputs, &ys, &mut outcomes);
        outcomes
    }

    /// [`DmeSession::round_batch`] with an explicit distance bound per
    /// slot, leaving the session's `y` estimator untouched (the batched
    /// form of [`DmeSession::round_with_y`]). `ys[b]` is slot `b`'s
    /// bound, so per-layer batches can carry per-layer bounds.
    pub fn round_batch_with_y(
        &mut self,
        inputs: &[Vec<Vec<f64>>],
        ys: &[f64],
    ) -> Vec<RoundOutcome> {
        let mut outcomes = Vec::new();
        self.round_batch_core(inputs, ys, &mut outcomes);
        outcomes
    }

    /// Zero-steady-state-allocation form of
    /// [`DmeSession::round_batch_with_y`]: outcome buffers already in
    /// `outcomes` are recycled (cleared, capacity kept) before it is
    /// refilled with the batch's `B` outcomes, so a driver passing the
    /// same vector back every batch allocates nothing once warm.
    pub fn round_batch_into(
        &mut self,
        inputs: &[Vec<Vec<f64>>],
        ys: &[f64],
        outcomes: &mut Vec<RoundOutcome>,
    ) {
        self.round_batch_core(inputs, ys, outcomes);
    }

    /// Batched VarianceReduction: each slot holds i.i.d. unbiased
    /// estimates with standard deviation ≤ `sigma`. The Chebyshev
    /// reduction maps the whole batch onto [`DmeSession::round_batch_with_y`]
    /// at `y = 2σ√(αn)` (one crossing per worker); error-detecting
    /// robustness runs its escalation protocol off-cluster per slot —
    /// there is no worker crossing to amortize — so it falls back to
    /// sequential [`DmeSession::round_vr`] calls.
    pub fn round_vr_batch(&mut self, inputs: &[Vec<Vec<f64>>], sigma: f64) -> Vec<RoundOutcome> {
        match self.robustness {
            Robustness::Chebyshev => {
                let y = vr_y_bound(sigma, self.n, self.alpha);
                let ys = vec![y; inputs.len()];
                self.round_batch_with_y(inputs, &ys)
            }
            Robustness::ErrorDetecting { .. } => {
                inputs.iter().map(|slot| self.round_vr(slot, sigma)).collect()
            }
        }
    }

    /// Run one VarianceReduction round: inputs are i.i.d. unbiased
    /// estimates with standard deviation ≤ `sigma`. Dispatches on the
    /// configured [`Robustness`].
    pub fn round_vr(&mut self, inputs: &[Vec<f64>], sigma: f64) -> RoundOutcome {
        match self.robustness {
            Robustness::Chebyshev => {
                let y = vr_y_bound(sigma, self.n, self.alpha);
                self.round_with_y(inputs, y)
            }
            Robustness::ErrorDetecting { q0 } => {
                self.check_inputs(inputs);
                let round = self.next_round();
                let r = robust_vr_core(inputs, sigma, q0, self.seed, round);
                self.cluster.add_traffic(&r.traffic);
                let (round_traffic, traffic) = self.take_round_traffic();
                RoundOutcome {
                    round,
                    agreement: true,
                    y_used: sigma,
                    leader: Some(r.leader),
                    leaves: Vec::new(),
                    q_used: None,
                    rounds_stage1: r.rounds_stage1,
                    rounds_stage2: r.rounds_stage2,
                    outputs: if self.diagnostics {
                        vec![r.estimate.clone(); self.n]
                    } else {
                        Vec::new()
                    },
                    decoded_at_leader: Vec::new(),
                    estimate: r.estimate,
                    round_traffic,
                    traffic,
                    participants: self.n,
                    dropped: Vec::new(),
                    retries_used: 0,
                }
            }
        }
    }

    /// Run one sublinear MeanEstimation round (Algorithm 9): a random
    /// source's input is broadcast at `~d·log₂(1+2q)` bits (`q` may be
    /// < 1) under distance bound `y`. No averaging happens — variance
    /// reduction is impossible in the o(d) regime (Theorem 7).
    pub fn round_sublinear(&mut self, inputs: &[Vec<f64>], q: f64, y: f64) -> RoundOutcome {
        self.check_inputs(inputs);
        let round = self.next_round();
        let out = super::sublinear_me::sublinear_mean_estimation(inputs, q, y, self.seed, round);
        self.cluster.add_traffic(&out.traffic);
        let (round_traffic, traffic) = self.take_round_traffic();
        RoundOutcome {
            round,
            agreement: true,
            y_used: y,
            leader: Some(out.source),
            leaves: Vec::new(),
            q_used: None,
            rounds_stage1: Vec::new(),
            rounds_stage2: Vec::new(),
            outputs: if self.diagnostics {
                vec![out.estimate.clone(); self.n]
            } else {
                Vec::new()
            },
            decoded_at_leader: Vec::new(),
            estimate: out.estimate,
            round_traffic,
            traffic,
            participants: self.n,
            dropped: Vec::new(),
            retries_used: 0,
        }
    }

    /// Run one k-of-n MeanEstimation round under `policy` at the
    /// session's current distance bound (see the module §Straggler
    /// policy). This is the only round plane a session built with
    /// [`DmeBuilder::fault_plan`] may drive: every receive carries a
    /// deadline, dropped reports are renormalized away (the `1/k`
    /// partial mean of [`crate::net::cohort`]'s service), and a round
    /// that closes below `policy.k_min` reports
    /// [`TransportError::QuorumFailed`] instead of panicking — the
    /// session stays usable and the next round may succeed.
    pub fn round_partial(
        &mut self,
        inputs: &[Vec<f64>],
        policy: &StragglerPolicy,
    ) -> Result<RoundOutcome, TransportError> {
        let y = self.y_est.y;
        self.round_partial_with_y(inputs, y, policy)
    }

    /// [`round_partial`](Self::round_partial) with an explicit distance
    /// bound (required for tree sessions, whose `y` is a per-round
    /// argument). Partial rounds never measure spread: `y` policies do
    /// not advance.
    pub fn round_partial_with_y(
        &mut self,
        inputs: &[Vec<f64>],
        y: f64,
        policy: &StragglerPolicy,
    ) -> Result<RoundOutcome, TransportError> {
        assert!(y > 0.0, "y must be positive");
        assert!(
            policy.k_min <= self.n,
            "k_min = {} exceeds the cluster size {}",
            policy.k_min,
            self.n
        );
        self.check_inputs(inputs);
        let round = self.next_round();
        let (leader, leaves, q_used) = self.slot_schedule(round, y);

        if self.n == 1 {
            // Degenerate cluster: the machine reports to itself, k = 1.
            let x = inputs[0].clone();
            let parts = Collected {
                agreement: true,
                outputs: if self.diagnostics { vec![x.clone()] } else { Vec::new() },
                decoded_at_leader: Vec::new(),
                spread: None,
                estimate: x,
                leader,
                leaves,
                q_used,
            };
            let mut oc = self.outcome(round, y, parts);
            oc.participants = 1;
            return Ok(oc);
        }

        self.ensure_workers();
        let d = self.d;
        let workers = self.workers.as_ref().expect("workers spawned");
        for (i, input) in inputs.iter().enumerate() {
            let (mut inbuf, outbuf) = self.bufs[i]
                .take()
                .unwrap_or_else(|| (vec![0.0; d], vec![0.0; d]));
            inbuf.copy_from_slice(input);
            workers.cmd_tx[i]
                .send(Cmd::Partial(PartialCmd {
                    round,
                    y,
                    policy: *policy,
                    input: inbuf,
                    out: outbuf,
                }))
                .expect("machine thread alive");
        }
        // Collect every machine's reply even past a failure (the workers
        // must drain before the next command), then surface the first
        // fatal.
        let mut replies: Vec<Option<PartialOut>> = (0..self.n).map(|_| None).collect();
        let mut fatal: Option<TransportError> = None;
        for (i, rx) in workers.out_rx.iter().enumerate() {
            match rx.recv() {
                Ok(WorkerMsg::Partial(po)) => replies[i] = Some(po),
                Ok(WorkerMsg::Fatal(e)) => {
                    fatal.get_or_insert(e);
                }
                Ok(_) => unreachable!("non-partial reply to a partial command"),
                Err(_) => {
                    fatal.get_or_insert(TransportError::Shutdown);
                }
            }
        }
        if let Some(e) = fatal {
            for (i, po) in replies.into_iter().enumerate() {
                if let Some(po) = po {
                    self.bufs[i] = Some((po.input, po.out));
                }
            }
            let _ = self.take_round_traffic();
            return Err(e);
        }
        let outs: Vec<PartialOut> = replies
            .into_iter()
            .map(|po| po.expect("reply per machine"))
            .collect();
        let coord = outs
            .iter()
            .position(|po| po.is_coordinator)
            .expect("one coordinator per round");
        let k = outs[coord].k;
        if outs[coord].quorum_failed {
            for (i, po) in outs.into_iter().enumerate() {
                self.bufs[i] = Some((po.input, po.out));
            }
            // The uploads still cost wire traffic: advance the snapshot
            // so the next round's deltas stay exact.
            let _ = self.take_round_traffic();
            return Err(TransportError::QuorumFailed {
                got: k,
                need: policy.k_min,
            });
        }
        // Participation: the star coordinator holds the exact arrival
        // record; the tree's is derived from which machines the plan
        // silenced this round (its k counts folded *leaf* reports).
        let dropped: Vec<usize> = if outs[coord].arrived.is_empty() {
            (0..self.n).filter(|&v| outs[v].silenced).collect()
        } else {
            (0..self.n).filter(|&v| !outs[coord].arrived[v]).collect()
        };
        let retries_used: u32 = outs.iter().map(|po| po.retries).sum();
        let estimate = outs[coord].out.clone();
        // Agreement is meaningful only over the machines the broadcast
        // reached; diagnostics report an empty vector for the others.
        let mut agreement = true;
        let mut outputs = Vec::new();
        for po in &outs {
            if po.got_output && po.out != estimate {
                agreement = false;
            }
            if self.diagnostics {
                outputs.push(if po.got_output { po.out.clone() } else { Vec::new() });
            }
        }
        for (i, po) in outs.into_iter().enumerate() {
            self.bufs[i] = Some((po.input, po.out));
        }
        let parts = Collected {
            estimate,
            agreement,
            outputs,
            decoded_at_leader: Vec::new(),
            spread: None,
            leader,
            leaves,
            q_used,
        };
        let mut oc = self.outcome(round, y, parts);
        oc.participants = k;
        oc.dropped = dropped;
        oc.retries_used = retries_used;
        Ok(oc)
    }

    /// Jump the round counter (reproduce a specific legacy round: the
    /// one-shot wrappers use this to pin `(seed, round)` randomness).
    pub fn set_round(&mut self, round: u64) {
        self.round = round;
    }

    /// Rounds run so far (the next round's index).
    pub fn rounds_run(&self) -> u64 {
        self.round
    }

    /// The current distance-bound estimate.
    pub fn y(&self) -> f64 {
        self.y_est.y
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn topology(&self) -> Topology {
        self.topology
    }

    pub fn spec(&self) -> CodecSpec {
        self.spec
    }

    /// Cumulative traffic summary since session start.
    pub fn cumulative_traffic(&self) -> TrafficSummary {
        summarize(&self.cluster.traffic())
    }

    // ------------------------------------------------------------------
    // internals
    // ------------------------------------------------------------------

    fn check_inputs(&self, inputs: &[Vec<f64>]) {
        assert_eq!(inputs.len(), self.n, "one input vector per machine");
        for x in inputs {
            assert_eq!(x.len(), self.d, "input dimension mismatch");
        }
    }

    fn next_round(&mut self) -> u64 {
        let r = self.round;
        self.round += 1;
        r
    }

    /// Per-round traffic delta plus the cumulative summary.
    fn take_round_traffic(&mut self) -> (Vec<Traffic>, TrafficSummary) {
        let now = self.cluster.traffic();
        let delta = now
            .iter()
            .zip(&self.last_snapshot)
            .map(|(a, b)| Traffic {
                sent_bits: a.sent_bits - b.sent_bits,
                recv_bits: a.recv_bits - b.recv_bits,
                sent_msgs: a.sent_msgs - b.sent_msgs,
                recv_msgs: a.recv_msgs - b.recv_msgs,
            })
            .collect();
        let summary = summarize(&now);
        self.last_snapshot = now;
        (delta, summary)
    }

    fn outcome(&mut self, round: u64, y: f64, parts: Collected) -> RoundOutcome {
        let (round_traffic, traffic) = self.take_round_traffic();
        RoundOutcome {
            round,
            estimate: parts.estimate,
            agreement: parts.agreement,
            y_used: y,
            leader: parts.leader,
            leaves: parts.leaves,
            q_used: parts.q_used,
            rounds_stage1: Vec::new(),
            rounds_stage2: Vec::new(),
            outputs: parts.outputs,
            decoded_at_leader: parts.decoded_at_leader,
            round_traffic,
            traffic,
            participants: self.n,
            dropped: Vec::new(),
            retries_used: 0,
        }
    }

    fn ensure_workers(&mut self) {
        if self.workers.is_some() {
            return;
        }
        let endpoints = self.cluster.endpoints();
        let mut cmd_tx = Vec::with_capacity(self.n);
        let mut out_rx = Vec::with_capacity(self.n);
        let mut handles = Vec::with_capacity(self.n);
        for ep in endpoints {
            // Every worker drives its endpoint through the fault wrapper;
            // with no plan it is a transparent pass-through.
            let fep = match &self.fault_plan {
                Some(plan) => FaultyEndpoint::with_plan(ep, plan.clone()),
                None => FaultyEndpoint::new(ep),
            };
            let (ctx, crx) = channel::<Cmd>();
            let (otx, orx) = channel::<WorkerMsg>();
            cmd_tx.push(ctx);
            out_rx.push(orx);
            let spec = self.spec;
            let seed = self.seed;
            let d = self.d;
            let diagnostics = self.diagnostics;
            let topology = self.topology;
            handles.push(
                crate::pool::lease(move || match topology {
                    Topology::Star => star_worker(fep, spec, d, seed, diagnostics, crx, otx),
                    Topology::Tree { m } => tree_worker(fep, m, seed, crx, otx),
                })
                .expect("lease machine worker thread"),
            );
        }
        self.workers = Some(Workers {
            cmd_tx,
            out_rx,
            handles,
        });
    }

    /// Shared-randomness protocol stats for one round index, re-derived
    /// driver-side for reporting (every machine derives the same).
    fn slot_schedule(&self, round: u64, y: f64) -> (Option<usize>, Vec<usize>, Option<u32>) {
        match self.topology {
            Topology::Star => (Some(star_leader(self.seed, round, self.n)), Vec::new(), None),
            Topology::Tree { m } => {
                let (leaves, _side, q) = tree_round_schedule(self.n, m, y, self.seed, round);
                (None, leaves, Some(q))
            }
        }
    }

    /// The batch round plane's driver side (§Perf, module docs): validate
    /// the slots, advance the round window by `B`, ship **one**
    /// [`Cmd::Batch`] per worker, and decompose the responses into
    /// per-slot outcomes. Per-slot traffic deltas come from the workers'
    /// exact tallies (the cluster meters only observe the batch total);
    /// their prefix sums reproduce the cumulative summaries sequential
    /// rounds would have reported, and the decomposition is checked
    /// against the meters in debug builds.
    fn round_batch_core(
        &mut self,
        inputs: &[Vec<Vec<f64>>],
        ys: &[f64],
        outcomes: &mut Vec<RoundOutcome>,
    ) {
        assert!(
            self.fault_plan.is_none(),
            "the batch plane blocks on every machine's report: drive faulted \
             sessions through round_partial"
        );
        let b_total = inputs.len();
        assert_eq!(ys.len(), b_total, "one distance bound per slot");
        let mut pool = std::mem::take(outcomes);
        if b_total == 0 {
            *outcomes = pool;
            return;
        }
        let n = self.n;
        let stateful = self.spec.is_stateful();
        let mut dims = Vec::with_capacity(b_total);
        let mut total = 0usize;
        for (b, slot) in inputs.iter().enumerate() {
            assert_eq!(slot.len(), n, "slot {b}: one input vector per machine");
            let d_b = slot[0].len();
            assert!(d_b >= 1, "slot {b}: need at least one dimension");
            for x in slot {
                assert_eq!(x.len(), d_b, "slot {b}: input dimension mismatch");
            }
            if stateful {
                assert_eq!(
                    d_b, self.d,
                    "stateful codecs carry one error memory at the session dimension"
                );
            }
            dims.push(d_b);
            total += d_b;
        }
        for (b, y) in ys.iter().enumerate() {
            assert!(*y > 0.0, "slot {b}: y must be positive");
        }
        let first_round = self.round;
        self.round += b_total as u64;

        if n == 1 {
            // Degenerate cluster, slot by slot (matches the sequential
            // n = 1 path: the machine outputs its own input, no wire).
            for (b, slot) in inputs.iter().enumerate() {
                let r = first_round + b as u64;
                let (leader, leaves, q_used) = self.slot_schedule(r, ys[b]);
                let mut oc = recycle_outcome(&mut pool);
                oc.round = r;
                oc.estimate.extend_from_slice(&slot[0]);
                oc.agreement = true;
                oc.y_used = ys[b];
                oc.leader = leader;
                oc.leaves = leaves;
                oc.q_used = q_used;
                if self.diagnostics {
                    oc.outputs.push(slot[0].clone());
                    if oc.leader.is_some() {
                        oc.decoded_at_leader.push(slot[0].clone());
                    }
                }
                let (rt, summary) = self.take_round_traffic();
                oc.round_traffic = rt;
                oc.traffic = summary;
                oc.participants = 1;
                outcomes.push(oc);
            }
            return;
        }

        self.ensure_workers();
        let workers = self.workers.as_ref().expect("workers spawned");
        for i in 0..n {
            let mut bc = self.batch_bufs[i].take().unwrap_or_default();
            bc.first_round = first_round;
            bc.ys.clear();
            bc.ys.extend_from_slice(ys);
            bc.dims.clear();
            bc.dims.extend_from_slice(&dims);
            bc.input.clear();
            for slot in inputs {
                bc.input.extend_from_slice(&slot[i]);
            }
            bc.out.clear();
            bc.out.resize(total, 0.0);
            bc.traffic.clear();
            bc.traffic.resize(b_total, Traffic::default());
            workers.cmd_tx[i]
                .send(Cmd::Batch(bc))
                .expect("machine thread alive");
        }
        let mut outs: Vec<BatchOut> = Vec::with_capacity(n);
        for rx in workers.out_rx.iter() {
            match rx.recv().expect("machine thread alive") {
                WorkerMsg::Batch(bo) => outs.push(bo),
                WorkerMsg::Round(_) | WorkerMsg::Partial(_) => {
                    unreachable!("single-round reply to a batch command")
                }
                WorkerMsg::Fatal(e) => panic!("machine transport failure mid-batch: {e}"),
            }
        }

        let mut cum = self.last_snapshot.clone();
        let mut lo = 0usize;
        for b in 0..b_total {
            let hi = lo + dims[b];
            let r = first_round + b as u64;
            let (leader, leaves, q_used) = self.slot_schedule(r, ys[b]);
            let est = &outs[0].out[lo..hi];
            let mut oc = recycle_outcome(&mut pool);
            oc.round = r;
            oc.estimate.extend_from_slice(est);
            oc.agreement = outs.iter().all(|o| o.out[lo..hi] == *est);
            oc.y_used = ys[b];
            oc.leader = leader;
            oc.leaves = leaves;
            oc.q_used = q_used;
            if self.diagnostics {
                for o in &outs {
                    oc.outputs.push(o.out[lo..hi].to_vec());
                }
                if let Some(l) = leader {
                    if let Some(dec) = outs[l].decoded.get(b) {
                        oc.decoded_at_leader = dec.clone();
                    }
                }
            }
            for (v, o) in outs.iter().enumerate() {
                let t = o.traffic[b];
                oc.round_traffic.push(t);
                cum[v].accumulate(&t);
            }
            oc.traffic = summarize(&cum);
            oc.participants = n;
            outcomes.push(oc);
            lo = hi;
        }
        self.last_snapshot = self.cluster.traffic();
        debug_assert_eq!(
            cum, self.last_snapshot,
            "per-slot tallies must decompose the cluster meters exactly"
        );
        for (i, bo) in outs.into_iter().enumerate() {
            self.batch_bufs[i] = Some(BatchCmd {
                first_round: 0,
                ys: bo.ys,
                dims: bo.dims,
                input: bo.input,
                out: bo.out,
                traffic: bo.traffic,
            });
        }
    }

    fn run_cluster_round(
        &mut self,
        inputs: &[Vec<f64>],
        y: f64,
        round: u64,
        measure: bool,
    ) -> Collected {
        assert!(
            self.fault_plan.is_none(),
            "full-participation rounds block on every machine's report: drive \
             faulted sessions through round_partial"
        );
        // Protocol stats every machine derives from shared randomness —
        // derived once more here so the driver can report them.
        let (leader, leaves, q_used) = self.slot_schedule(round, y);

        if self.n == 1 {
            // Degenerate cluster: the machine outputs its own input, no
            // communication (matches the legacy one-shot functions).
            let x = inputs[0].clone();
            return Collected {
                agreement: true,
                outputs: if self.diagnostics { vec![x.clone()] } else { Vec::new() },
                decoded_at_leader: if self.diagnostics && leader.is_some() {
                    vec![x.clone()]
                } else {
                    Vec::new()
                },
                // A single point has zero spread (the legacy measurement
                // over the one-element decoded set).
                spread: if measure { Some(0.0) } else { None },
                estimate: x,
                leader,
                leaves,
                q_used,
            };
        }

        self.ensure_workers();
        let d = self.d;
        let workers = self.workers.as_ref().expect("workers spawned");
        for (i, input) in inputs.iter().enumerate() {
            let (mut inbuf, outbuf) = self.bufs[i]
                .take()
                .unwrap_or_else(|| (vec![0.0; d], vec![0.0; d]));
            inbuf.copy_from_slice(input);
            workers.cmd_tx[i]
                .send(Cmd::Round(RoundCmd {
                    round,
                    y,
                    measure,
                    input: inbuf,
                    out: outbuf,
                }))
                .expect("machine thread alive");
        }
        let mut estimate = Vec::new();
        let mut agreement = true;
        let mut outputs = Vec::new();
        let mut decoded_at_leader = Vec::new();
        let mut spread = None;
        for (i, rx) in workers.out_rx.iter().enumerate() {
            let wo = match rx.recv().expect("machine thread alive") {
                WorkerMsg::Round(wo) => wo,
                WorkerMsg::Batch(_) | WorkerMsg::Partial(_) => {
                    unreachable!("batch reply to a single-round command")
                }
                WorkerMsg::Fatal(e) => panic!("machine {i} transport failure: {e}"),
            };
            if i == 0 {
                estimate = wo.output.clone();
            } else if agreement && wo.output != estimate {
                agreement = false;
            }
            if self.diagnostics {
                outputs.push(wo.output.clone());
            }
            if !wo.decoded.is_empty() {
                decoded_at_leader = wo.decoded;
            }
            if wo.spread.is_some() {
                spread = wo.spread;
            }
            self.bufs[i] = Some((wo.input, wo.output));
        }
        Collected {
            estimate,
            agreement,
            outputs,
            decoded_at_leader,
            spread,
            leader,
            leaves,
            q_used,
        }
    }
}

impl Drop for DmeSession {
    fn drop(&mut self) {
        if let Some(w) = self.workers.take() {
            // Closing the command channels unblocks every worker's recv.
            drop(w.cmd_tx);
            for h in w.handles {
                let _ = h.join();
            }
        }
    }
}

/// One machine's side of one star MeanEstimation round (Algorithm 3),
/// generic over the transport — the exact body the session workers run
/// in-process, shared with every other [`TransportEndpoint`] so
/// transport parity holds by construction (see the module §Transport).
///
/// The leader's aggregation is a streaming fold: each packet is decoded
/// and accumulated into the O(d) `mu` buffer in one fused pass
/// ([`VectorCodec::decode_accumulate_into`]), in pinned machine order —
/// machine 0 first, the leader's own input folded at index `id` — which
/// is bit-for-bit the legacy decode-all-then-sum order. Only the
/// collecting path (`diagnostics`/`measure`) still materializes the
/// O(n·d) decoded set, into caller-recycled buffers.
#[allow(clippy::too_many_arguments)]
fn star_round_core<E: TransportEndpoint>(
    ep: &mut E,
    codec: &mut dyn VectorCodec,
    seed: u64,
    round: u64,
    diagnostics: bool,
    measure: bool,
    input: &[f64],
    out: &mut [f64],
    mu: &mut [f64],
    msg: &mut Message,
    decoded: &mut Vec<Vec<f64>>,
) -> Result<(Option<f64>, Vec<Vec<f64>>), TransportError> {
    let id = ep.id();
    let n = ep.n();
    let d = input.len();
    let leader = star_leader(seed, round, n);
    // Per-machine encoder randomness must differ across machines
    // (stochastic rounding draws), while codec-internal *shared*
    // randomness comes from (seed, round) inside build().
    let mut enc_rng = Rng::new(hash2(hash2(seed, round), id as u64 + 1));
    let mut decoded_out = Vec::new();
    let mut spread = None;
    if id == leader {
        for m in mu.iter_mut() {
            *m = 0.0;
        }
        if diagnostics || measure {
            // Collecting path (diagnostics / §9.2 spread measurement):
            // decode every worker's message against our input as it
            // arrives, stored by sender in recycled buffers, then sum
            // in machine order (bit-for-bit the legacy order).
            if decoded.is_empty() {
                *decoded = vec![vec![0.0; d]; n];
            }
            decoded[id].copy_from_slice(input);
            for _ in 0..n - 1 {
                let p = ep.recv()?;
                codec.decode_into(&p.msg, input, &mut decoded[p.from]);
            }
            for z in decoded.iter() {
                crate::linalg::axpy(mu, 1.0, z);
            }
            if measure {
                spread = Some(YEstimator::max_pairwise_inf(decoded));
            }
            if diagnostics {
                decoded_out = decoded.clone();
            }
        } else {
            // Streaming fold (the hot path): gather in machine order
            // via recv_from (out-of-order arrivals wait in the stash)
            // and fold each bitstream straight into `mu` — O(d)
            // leader memory however large the cluster.
            for v in 0..n {
                if v == id {
                    crate::linalg::axpy(mu, 1.0, input);
                } else {
                    let p = ep.recv_from(v)?;
                    codec.decode_accumulate_into(&p.msg, input, 1.0, mu);
                }
            }
        }
        let inv_n = 1.0 / n as f64;
        for m in mu.iter_mut() {
            *m = inv_n * *m;
        }
        // Broadcast the quantized average.
        codec.encode_into(mu, &mut enc_rng, msg);
        ep.broadcast(msg)?;
        codec.decode_into(msg, input, out);
    } else {
        codec.encode_into(input, &mut enc_rng, msg);
        ep.send(leader, msg.clone())?;
        let p = ep.recv_from(leader)?;
        codec.decode_into(&p.msg, input, out);
    }
    Ok((spread, decoded_out))
}

/// An upward report (machine → coordinator).
const DIR_UP: u8 = 0;
/// A downward broadcast or relay (coordinator → machines).
const DIR_DOWN: u8 = 1;
/// Trailer appended to every partial-round packet:
/// `[round: u64 LE][weight: u64 LE][dir: u8]`.
const ENVELOPE_BYTES: usize = 17;
const ENVELOPE_BITS: u64 = 8 * ENVELOPE_BYTES as u64;

/// Tag a partial-round packet. The round index lets receivers discard
/// stale packets from an earlier round a sender's fault delayed past
/// its deadline; the weight carries the subtree's arrived-report count
/// (so the coordinator's `k` rides the broadcast); the direction
/// disambiguates a machine's dropped report from a relay it forwards
/// downward. The 17 bytes / 136 bits are metered like any payload —
/// the price of fault tolerance on the wire.
fn wrap_partial(msg: &mut Message, round: u64, weight: u64, dir: u8) {
    msg.bytes.extend_from_slice(&round.to_le_bytes());
    msg.bytes.extend_from_slice(&weight.to_le_bytes());
    msg.bytes.push(dir);
    msg.bits += ENVELOPE_BITS;
}

/// Strip the partial-round trailer, returning `(round, weight, dir)`.
/// `None` means the packet cannot carry one — treated as corruption and
/// discarded by the receive loop.
fn unwrap_partial(msg: &mut Message) -> Option<(u64, u64, u8)> {
    let len = msg.bytes.len();
    if len < ENVELOPE_BYTES || msg.bits < ENVELOPE_BITS {
        return None;
    }
    let dir = msg.bytes[len - 1];
    let weight = u64::from_le_bytes(msg.bytes[len - 9..len - 1].try_into().expect("8 bytes"));
    let round = u64::from_le_bytes(msg.bytes[len - 17..len - 9].try_into().expect("8 bytes"));
    msg.bytes.truncate(len - ENVELOPE_BYTES);
    msg.bits -= ENVELOPE_BITS;
    Some((round, weight, dir))
}

/// Send, treating a closed peer like a dropped packet. In a faulted
/// round a peer may already have given up on its deadline and exited;
/// its absence must not kill this machine's round.
fn send_lossy<E: TransportEndpoint>(
    ep: &mut E,
    to: usize,
    msg: Message,
) -> Result<(), TransportError> {
    match ep.send(to, msg) {
        Ok(()) | Err(TransportError::PeerClosed { .. }) => Ok(()),
        Err(e) => Err(e),
    }
}

/// Envelope-aware receive loop for one machine's side of one partial
/// round: pulls packets until one matching `(sender, direction)` for
/// this round arrives (`Ok(Some(_))`), the cutoff passes (`Ok(None)` —
/// the straggler verdict), or the transport genuinely fails. Waiting is
/// paced by the policy's bounded-retry backoff windows; once the
/// schedule is exhausted, a final window runs to the cutoff, so
/// `retries` counts expired windows and — windows being deterministic
/// under a seeded [`RetrySchedule`] — is reproducible run to run.
/// Packets for this round that were not the awaited `(sender,
/// direction)` wait in per-sender queues; malformed, stale-round,
/// impossible-weight and unknown-direction packets are discarded (a
/// corrupted trailer degrades to a drop, deterministically).
struct PartialGather {
    round: u64,
    n: usize,
    deadline: Instant,
    windows: BackoffWindows,
    retries: u32,
    pending: Vec<VecDeque<(u8, u64, Message)>>,
}

impl PartialGather {
    fn new(round: u64, n: usize, policy: &StragglerPolicy, salt: u64) -> Self {
        PartialGather {
            round,
            n,
            deadline: Instant::now() + policy.deadline,
            windows: policy.retry.windows(salt),
            retries: 0,
            pending: (0..n).map(|_| VecDeque::new()).collect(),
        }
    }

    /// Move the cutoff to an absolute instant (the tree's per-level
    /// budget: a parent at level `l` waits until `start + l·deadline`,
    /// so a child that itself waited out a straggler still lands well
    /// inside its parent's window).
    fn set_deadline(&mut self, at: Instant) {
        self.deadline = at;
    }

    /// Push the cutoff out by `extra` (the star non-leader's return
    /// leg: one deadline for the gather, one for the broadcast).
    fn extend_deadline(&mut self, extra: Duration) {
        self.deadline += extra;
    }

    fn take_pending(&mut self, from: Option<usize>, dir: u8) -> Option<(usize, u64, Message)> {
        let senders: Box<dyn Iterator<Item = usize>> = match from {
            Some(v) => Box::new(std::iter::once(v)),
            None => Box::new(0..self.n),
        };
        for v in senders {
            let q = &mut self.pending[v];
            for i in 0..q.len() {
                if q[i].0 == dir {
                    let (_, w, m) = q.remove(i).expect("index in bounds");
                    return Some((v, w, m));
                }
            }
        }
        None
    }

    /// Wait for a `dir` packet from `from` (any sender when `None`).
    fn recv_dir<E: TransportEndpoint>(
        &mut self,
        ep: &mut E,
        from: Option<usize>,
        dir: u8,
    ) -> Result<Option<(usize, u64, Message)>, TransportError> {
        if let Some(hit) = self.take_pending(from, dir) {
            return Ok(Some(hit));
        }
        loop {
            let now = Instant::now();
            if now >= self.deadline {
                return Ok(None);
            }
            let remaining = self.deadline - now;
            let wait = match self.windows.next() {
                Some(w) => w.min(remaining),
                None => remaining,
            };
            match ep.recv_timeout(wait) {
                Ok(p) => {
                    let mut msg = p.msg;
                    let Some((round, weight, pdir)) = unwrap_partial(&mut msg) else {
                        continue;
                    };
                    if round != self.round
                        || weight > self.n as u64
                        || (pdir != DIR_UP && pdir != DIR_DOWN)
                        || p.from >= self.n
                    {
                        continue;
                    }
                    let sender_ok = match from {
                        Some(v) => v == p.from,
                        None => true,
                    };
                    if pdir == dir && sender_ok {
                        return Ok(Some((p.from, weight, msg)));
                    }
                    self.pending[p.from].push_back((pdir, weight, msg));
                }
                Err(TransportError::Timeout { .. }) => {
                    self.retries += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }
}

/// What [`star_partial_core`] produced on this machine.
struct StarPartial {
    leader: usize,
    k: usize,
    /// Leader only: exact arrival record (own slot always true).
    arrived: Vec<bool>,
    retries: u32,
    got_output: bool,
    quorum_failed: bool,
}

/// One machine's side of one **k-of-n** star round (the module
/// §Straggler policy), generic over the transport like
/// [`star_round_core`]. With every report arrived it is arithmetically
/// the full round: same leader schedule, same encoder randomness, same
/// pinned machine-order fold, and `1/k = 1/n`. With reports missing at
/// the deadline the leader folds the k that arrived and renormalizes by
/// `1/k` — bit-for-bit the service's partial mean
/// ([`crate::net::cohort::OpenRound`]). Below `policy.k_min` the leader
/// reports a failed quorum and broadcasts nothing.
#[allow(clippy::too_many_arguments)]
fn star_partial_core<E: TransportEndpoint>(
    ep: &mut E,
    codec: &mut dyn VectorCodec,
    seed: u64,
    round: u64,
    policy: &StragglerPolicy,
    input: &[f64],
    out: &mut [f64],
    mu: &mut [f64],
    msg: &mut Message,
) -> Result<StarPartial, TransportError> {
    let id = ep.id();
    let n = ep.n();
    let leader = star_leader(seed, round, n);
    let mut enc_rng = Rng::new(hash2(hash2(seed, round), id as u64 + 1));
    let mut gather = PartialGather::new(round, n, policy, hash2(round, id as u64));
    if id == leader {
        // Gather first-copy-per-sender until the deadline (duplicates
        // from a duplicating fault are identical packets; the first
        // wins).
        let mut arrived = vec![false; n];
        arrived[id] = true;
        let mut held: Vec<Option<Message>> = (0..n).map(|_| None).collect();
        let mut k = 1usize;
        while k < n {
            match gather.recv_dir(ep, None, DIR_UP)? {
                Some((from, _w, m)) => {
                    if !arrived[from] {
                        arrived[from] = true;
                        held[from] = Some(m);
                        k += 1;
                    }
                }
                None => break,
            }
        }
        if k < policy.k_min {
            return Ok(StarPartial {
                leader,
                k,
                arrived,
                retries: gather.retries,
                got_output: false,
                quorum_failed: true,
            });
        }
        // Fold the arrived reports in pinned machine order — the full
        // round's order, restricted to the k that made it.
        for m in mu.iter_mut() {
            *m = 0.0;
        }
        for v in 0..n {
            if v == id {
                crate::linalg::axpy(mu, 1.0, input);
            } else if let Some(m) = held[v].as_ref() {
                codec.decode_accumulate_into(m, input, 1.0, mu);
            }
        }
        // Mirror of `OpenRound::close`: renormalize by the k reports
        // that arrived, not the cohort size.
        let inv_k = 1.0 / (k.max(1) as f64);
        for m in mu.iter_mut() {
            *m = inv_k * *m;
        }
        codec.encode_into(mu, &mut enc_rng, msg);
        codec.decode_into(msg, input, out);
        wrap_partial(msg, round, k as u64, DIR_DOWN);
        for v in 0..n {
            if v != id {
                send_lossy(ep, v, msg.clone())?;
            }
        }
        Ok(StarPartial {
            leader,
            k,
            arrived,
            retries: gather.retries,
            got_output: true,
            quorum_failed: false,
        })
    } else {
        codec.encode_into(input, &mut enc_rng, msg);
        wrap_partial(msg, round, 1, DIR_UP);
        send_lossy(ep, leader, msg.clone())?;
        gather.extend_deadline(policy.deadline);
        match gather.recv_dir(ep, Some(leader), DIR_DOWN)? {
            Some((_from, weight, m)) => {
                codec.decode_into(&m, input, out);
                Ok(StarPartial {
                    leader,
                    k: weight as usize,
                    arrived: Vec::new(),
                    retries: gather.retries,
                    got_output: true,
                    quorum_failed: false,
                })
            }
            None => Ok(StarPartial {
                leader,
                k: 0,
                arrived: Vec::new(),
                retries: gather.retries,
                got_output: false,
                quorum_failed: false,
            }),
        }
    }
}

/// What [`star_round_over`] produced on this machine.
#[derive(Clone, Debug)]
pub struct StarRoundReport {
    /// The round's shared-randomness leader.
    pub leader: usize,
    /// This machine's decoded output (the common estimate).
    pub output: Vec<f64>,
    /// Leader only, with `collect`: the decoded per-machine points.
    pub decoded_at_leader: Vec<Vec<f64>>,
    /// Leader only, with `collect`: max pairwise ℓ∞ of the decoded set.
    pub spread: Option<f64>,
}

/// Run one machine's side of a star MeanEstimation round over any
/// [`TransportEndpoint`] — the identical protocol the in-process
/// session executes, so estimates, diagnostics and metered bits match
/// the reference transport exactly (pinned by `rust/tests/transport.rs`).
/// All `n` machines must call this with the same `(spec, seed, round,
/// y)`; `collect` enables the leader's decoded-set collection (same
/// wire traffic, different leader-side bookkeeping).
///
/// The codec is built fresh per call; stateful codecs (EF-SignSGD,
/// PowerSGD, Top-K) therefore start each call with empty error memory —
/// drive a [`DmeSession`] when cross-round memory matters.
pub fn star_round_over<E: TransportEndpoint>(
    ep: &mut E,
    spec: CodecSpec,
    seed: u64,
    round: u64,
    y: f64,
    input: &[f64],
    collect: bool,
) -> Result<StarRoundReport, TransportError> {
    let d = input.len();
    let n = ep.n();
    let leader = star_leader(seed, round, n);
    let mut codec = spec.build(d, y, seed, round);
    let mut out = vec![0.0; d];
    let mut mu = vec![0.0; d];
    let mut msg = Message::empty();
    let mut decoded = Vec::new();
    let (spread, decoded_out) = star_round_core(
        ep,
        &mut *codec,
        seed,
        round,
        collect,
        collect,
        input,
        &mut out,
        &mut mu,
        &mut msg,
        &mut decoded,
    )?;
    Ok(StarRoundReport {
        leader,
        output: out,
        decoded_at_leader: decoded_out,
        spread,
    })
}

/// Chebyshev VarianceReduction over any transport (Theorem 17): maps
/// the VR instance onto [`star_round_over`] at `y = 2σ√(αn)` — exactly
/// what a [`Robustness::Chebyshev`] session round does in-process.
#[allow(clippy::too_many_arguments)]
pub fn vr_round_over<E: TransportEndpoint>(
    ep: &mut E,
    spec: CodecSpec,
    seed: u64,
    round: u64,
    sigma: f64,
    alpha: f64,
    input: &[f64],
    collect: bool,
) -> Result<StarRoundReport, TransportError> {
    let y = vr_y_bound(sigma, ep.n(), alpha);
    star_round_over(ep, spec, seed, round, y, input, collect)
}

/// What [`star_round_partial_over`] produced on this machine.
#[derive(Clone, Debug)]
pub struct PartialRoundReport {
    /// The round's shared-randomness leader.
    pub leader: usize,
    /// This machine's decoded estimate — `None` when the downward
    /// broadcast never reached it before its cutoff.
    pub output: Option<Vec<f64>>,
    /// Reports folded into the estimate. On the leader this is exact;
    /// elsewhere it is the count the broadcast's envelope carried
    /// (0 when no broadcast arrived).
    pub k: usize,
    /// Leader only: exact per-machine arrival record.
    pub arrived: Vec<bool>,
    /// Receive windows that expired on this machine this round.
    pub retries: u32,
}

/// Run one machine's side of a **k-of-n** star round over any
/// [`TransportEndpoint`] — the identical protocol
/// [`DmeSession::round_partial`] executes in-process (see the module
/// §Straggler policy). All `n` machines must call this with the same
/// `(spec, seed, round, y, policy)`. The leader raises
/// [`TransportError::QuorumFailed`] when fewer than `policy.k_min`
/// reports arrive by the deadline (it broadcasts nothing, so the other
/// machines report `output: None`). To inject faults, wrap the endpoint
/// in a [`FaultyEndpoint`] and [`FaultyEndpoint::set_round`] before
/// each call.
pub fn star_round_partial_over<E: TransportEndpoint>(
    ep: &mut E,
    spec: CodecSpec,
    seed: u64,
    round: u64,
    y: f64,
    policy: &StragglerPolicy,
    input: &[f64],
) -> Result<PartialRoundReport, TransportError> {
    let d = input.len();
    let mut codec = spec.build(d, y, seed, round);
    let mut out = vec![0.0; d];
    let mut mu = vec![0.0; d];
    let mut msg = Message::empty();
    let sp = star_partial_core(
        ep, &mut *codec, seed, round, policy, input, &mut out, &mut mu, &mut msg,
    )?;
    if sp.quorum_failed {
        return Err(TransportError::QuorumFailed {
            got: sp.k,
            need: policy.k_min,
        });
    }
    Ok(PartialRoundReport {
        leader: sp.leader,
        output: if sp.got_output { Some(out) } else { None },
        k: sp.k,
        arrived: sp.arrived,
        retries: sp.retries,
    })
}

/// Chebyshev VarianceReduction as a k-of-n partial round: maps the VR
/// instance onto [`star_round_partial_over`] at `y = 2σ√(αn)` — the
/// fault-tolerant analogue of [`vr_round_over`]. Note the bound still
/// uses the full cluster size `n`: the distance bound is a property of
/// the inputs, not of which reports survive the round.
#[allow(clippy::too_many_arguments)]
pub fn vr_round_partial_over<E: TransportEndpoint>(
    ep: &mut E,
    spec: CodecSpec,
    seed: u64,
    round: u64,
    sigma: f64,
    alpha: f64,
    policy: &StragglerPolicy,
    input: &[f64],
) -> Result<PartialRoundReport, TransportError> {
    let y = vr_y_bound(sigma, ep.n(), alpha);
    star_round_partial_over(ep, spec, seed, round, y, policy, input)
}

/// Star machine loop — Algorithm 3 with persistent scratch space. The
/// protocol (leader schedule, codec construction, encoder randomness,
/// summation order) matches the legacy one-shot implementation exactly;
/// the round body itself is the transport-generic [`star_round_core`].
/// A transport failure reports [`WorkerMsg::Fatal`] and exits the loop
/// instead of panicking the process.
fn star_worker(
    mut ep: FaultyEndpoint<Endpoint>,
    spec: CodecSpec,
    d: usize,
    seed: u64,
    diagnostics: bool,
    crx: Receiver<Cmd>,
    otx: Sender<WorkerMsg>,
) {
    let mut msg = Message::empty();
    // Leader-role scratch, sized lazily on first collecting leadership.
    let mut decoded: Vec<Vec<f64>> = Vec::new();
    let mut mu = vec![0.0; d];
    // Batch-plane scratch (§Perf): the pooled upload arena and a fold
    // accumulator sized to the largest slot seen, both recycled across
    // batches.
    let mut arena = PacketArena::new();
    let mut batch_mu: Vec<f64> = Vec::new();
    // Stateful codecs (EF-SignSGD, PowerSGD, Top-K) carry error memory
    // across rounds and must be built once per machine (the Aggregator
    // contract — see `CodecSpec::is_stateful`); shared-randomness codecs
    // are rebuilt from (seed, round) every round.
    let mut held_codec: Option<Box<dyn VectorCodec>> = None;
    while let Ok(cmd) = crx.recv() {
        let RoundCmd {
            round,
            y,
            measure,
            input,
            mut out,
        } = match cmd {
            Cmd::Round(rc) => rc,
            Cmd::Partial(pc) => {
                // The fault wrapper's behavior is a pure function of
                // (plan seed, machine, round): pin the round first.
                ep.set_round(pc.round);
                if held_codec.is_none() || !spec.is_stateful() {
                    held_codec = Some(spec.build(d, pc.y, seed, pc.round));
                }
                let codec = held_codec.as_mut().expect("codec built");
                let input = pc.input;
                let mut out = pc.out;
                let sp = match star_partial_core(
                    &mut ep,
                    &mut **codec,
                    seed,
                    pc.round,
                    &pc.policy,
                    &input,
                    &mut out,
                    &mut mu,
                    &mut msg,
                ) {
                    Ok(sp) => sp,
                    Err(e) => {
                        let _ = otx.send(WorkerMsg::Fatal(e));
                        break;
                    }
                };
                let silenced = ep.fault().silences();
                let is_coordinator = sp.leader == ep.id();
                if otx
                    .send(WorkerMsg::Partial(PartialOut {
                        input,
                        out,
                        got_output: sp.got_output,
                        k: sp.k,
                        arrived: sp.arrived,
                        retries: sp.retries,
                        quorum_failed: sp.quorum_failed,
                        silenced,
                        is_coordinator,
                    }))
                    .is_err()
                {
                    break;
                }
                continue;
            }
            Cmd::Batch(mut bc) => {
                let slot_decoded = match star_batch_slots(
                    &mut ep,
                    spec,
                    seed,
                    diagnostics,
                    &mut bc,
                    &mut msg,
                    &mut batch_mu,
                    &mut arena,
                    &mut held_codec,
                ) {
                    Ok(sd) => sd,
                    Err(e) => {
                        let _ = otx.send(WorkerMsg::Fatal(e));
                        break;
                    }
                };
                if otx
                    .send(WorkerMsg::Batch(BatchOut {
                        ys: bc.ys,
                        dims: bc.dims,
                        input: bc.input,
                        out: bc.out,
                        traffic: bc.traffic,
                        decoded: slot_decoded,
                    }))
                    .is_err()
                {
                    break;
                }
                continue;
            }
        };
        if held_codec.is_none() || !spec.is_stateful() {
            held_codec = Some(spec.build(d, y, seed, round));
        }
        let codec = held_codec.as_mut().expect("codec built");
        let (spread, decoded_out) = match star_round_core(
            &mut ep,
            &mut **codec,
            seed,
            round,
            diagnostics,
            measure,
            &input,
            &mut out,
            &mut mu,
            &mut msg,
            &mut decoded,
        ) {
            Ok(r) => r,
            Err(e) => {
                let _ = otx.send(WorkerMsg::Fatal(e));
                break;
            }
        };
        if otx
            .send(WorkerMsg::Round(WorkerOut {
                input,
                output: out,
                decoded: decoded_out,
                spread,
            }))
            .is_err()
        {
            break;
        }
    }
}

/// One worker's side of a whole batch (§Perf; star topology).
///
/// Phase 1 pre-encodes every upload — the slots this machine does *not*
/// lead — back-to-back through the codecs' fused block kernels into the
/// pooled [`PacketArena`], with all per-slot shared randomness derived
/// by one [`fork_round_seeds`] fan-out. Phase 2 walks the slots in round
/// order and plays the exact sequential protocol per slot: uploads come
/// off the arena, leader slots stream-fold in pinned machine order
/// (`recv_from`, machine 0 first), and every send/receive is tallied
/// into the slot's `Traffic` entry so the driver can report per-slot
/// deltas. Stateful codecs skip the staging phase — their error memory
/// must advance in protocol order — and encode inline in phase 2.
///
/// Slot `b` is bit-identical to a sequential round at index
/// `first_round + b`: same leader, same codec stream, same encoder
/// randomness (`hash2(hash2(seed, round), id + 1)`), same fold order.
#[allow(clippy::too_many_arguments)]
fn star_batch_slots<E: TransportEndpoint>(
    ep: &mut E,
    spec: CodecSpec,
    seed: u64,
    diagnostics: bool,
    cmd: &mut BatchCmd,
    msg: &mut Message,
    mu: &mut Vec<f64>,
    arena: &mut PacketArena,
    held_codec: &mut Option<Box<dyn VectorCodec>>,
) -> Result<Vec<Vec<Vec<f64>>>, TransportError> {
    let id = ep.id();
    let n = ep.n();
    let b_total = cmd.dims.len();
    let stateful = spec.is_stateful();
    let seeds = fork_round_seeds(seed, cmd.first_round, b_total);
    let leaders: Vec<usize> = (0..b_total)
        .map(|b| star_leader(seed, cmd.first_round + b as u64, n))
        .collect();

    // --- Phase 1: stage the uploads into the pooled arena.
    arena.clear();
    let mut codecs: Vec<Option<Box<dyn VectorCodec>>> = Vec::with_capacity(b_total);
    if stateful {
        codecs.resize_with(b_total, || None);
    } else {
        let mut lo = 0usize;
        for b in 0..b_total {
            let d_b = cmd.dims[b];
            let mut codec = spec.build_with(d_b, cmd.ys[b], &mut Rng::new(seeds[b]));
            if id != leaders[b] {
                let mut enc_rng = Rng::new(hash2(seeds[b], id as u64 + 1));
                codec.encode_into(&cmd.input[lo..lo + d_b], &mut enc_rng, msg);
                arena.push(msg);
            }
            codecs.push(Some(codec));
            lo += d_b;
        }
    }

    // --- Phase 2: play each slot's round.
    let mut uploads = arena.reader();
    let mut slot_decoded: Vec<Vec<Vec<f64>>> = if diagnostics {
        vec![Vec::new(); b_total]
    } else {
        Vec::new()
    };
    let mut lo = 0usize;
    for b in 0..b_total {
        let d_b = cmd.dims[b];
        let r = cmd.first_round + b as u64;
        let leader = leaders[b];
        let input = &cmd.input[lo..lo + d_b];
        let out = &mut cmd.out[lo..lo + d_b];
        let t = &mut cmd.traffic[b];
        if stateful && held_codec.is_none() {
            *held_codec = Some(spec.build(d_b, cmd.ys[b], seed, r));
        }
        let codec = if stateful {
            held_codec.as_mut().expect("stateful codec built")
        } else {
            codecs[b].as_mut().expect("slot codec built")
        };
        let mut enc_rng = Rng::new(hash2(seeds[b], id as u64 + 1));
        if id == leader {
            if mu.len() < d_b {
                mu.resize(d_b, 0.0);
            }
            let acc = &mut mu[..d_b];
            for m in acc.iter_mut() {
                *m = 0.0;
            }
            if diagnostics {
                // Collecting path: decode per sender (pinned machine
                // order — required in a batch, where arrival order may
                // interleave slots), then sum in machine order; decodes
                // are independent, so this is bit-identical to the
                // sequential arrival-order collection.
                let mut dec = vec![vec![0.0; d_b]; n];
                dec[id].copy_from_slice(input);
                for v in 0..n {
                    if v == id {
                        continue;
                    }
                    let p = ep.recv_from(v)?;
                    t.recv_bits += p.msg.bits;
                    t.recv_msgs += 1;
                    codec.decode_into(&p.msg, input, &mut dec[v]);
                }
                for z in &dec {
                    crate::linalg::axpy(acc, 1.0, z);
                }
                slot_decoded[b] = dec;
            } else {
                // Streaming fold, pinned machine order (the hot path).
                for v in 0..n {
                    if v == id {
                        crate::linalg::axpy(acc, 1.0, input);
                    } else {
                        let p = ep.recv_from(v)?;
                        t.recv_bits += p.msg.bits;
                        t.recv_msgs += 1;
                        codec.decode_accumulate_into(&p.msg, input, 1.0, acc);
                    }
                }
            }
            let inv_n = 1.0 / n as f64;
            for m in acc.iter_mut() {
                *m = inv_n * *m;
            }
            codec.encode_into(acc, &mut enc_rng, msg);
            t.sent_bits += msg.bits * (n as u64 - 1);
            t.sent_msgs += n as u64 - 1;
            ep.broadcast(msg)?;
            codec.decode_into(msg, input, out);
        } else {
            let up = if stateful {
                codec.encode_into(input, &mut enc_rng, msg);
                msg.clone()
            } else {
                uploads.next_message().expect("staged upload packet")
            };
            t.sent_bits += up.bits;
            t.sent_msgs += 1;
            ep.send(leader, up)?;
            let p = ep.recv_from(leader)?;
            t.recv_bits += p.msg.bits;
            t.recv_msgs += 1;
            codec.decode_into(&p.msg, input, out);
        }
        lo += d_b;
    }
    Ok(slot_decoded)
}

/// Tree machine loop — Algorithm 4. Every machine derives the full
/// deterministic schedule (leaf sample, per-level round-robin roles,
/// broadcast order) from shared randomness and executes only its own
/// sends/receives; since `sim` sends never block and all machines walk
/// the schedule in the same global (level, node, child) order, every
/// receive's matching send is already issued — no deadlock. Messages and
/// metering are bit-identical to the legacy sequential driver.
fn tree_worker(
    mut ep: FaultyEndpoint<Endpoint>,
    m: usize,
    seed: u64,
    crx: Receiver<Cmd>,
    otx: Sender<WorkerMsg>,
) {
    while let Ok(cmd) = crx.recv() {
        match cmd {
            Cmd::Partial(pc) => {
                ep.set_round(pc.round);
                let input = pc.input;
                let mut out = pc.out;
                let tp = match tree_partial_round(
                    &mut ep, m, seed, pc.round, pc.y, &pc.policy, &input, &mut out,
                ) {
                    Ok(tp) => tp,
                    Err(e) => {
                        let _ = otx.send(WorkerMsg::Fatal(e));
                        break;
                    }
                };
                let silenced = ep.fault().silences();
                let is_coordinator = tp.root == ep.id();
                if otx
                    .send(WorkerMsg::Partial(PartialOut {
                        input,
                        out,
                        got_output: tp.got_output,
                        k: tp.k,
                        arrived: Vec::new(),
                        retries: tp.retries,
                        quorum_failed: tp.quorum_failed,
                        silenced,
                        is_coordinator,
                    }))
                    .is_err()
                {
                    break;
                }
            }
            Cmd::Round(RoundCmd {
                round,
                y,
                measure: _,
                input,
                mut out,
            }) => {
                let shared_seed = hash2(seed, round);
                let mut tally = Traffic::default();
                if let Err(e) = tree_slot_round(
                    &mut ep, m, seed, shared_seed, round, y, &input, &mut out, &mut tally,
                ) {
                    let _ = otx.send(WorkerMsg::Fatal(e));
                    break;
                }
                if otx
                    .send(WorkerMsg::Round(WorkerOut {
                        input,
                        output: out,
                        decoded: Vec::new(),
                        spread: None,
                    }))
                    .is_err()
                {
                    break;
                }
            }
            Cmd::Batch(mut bc) => {
                // The batched tree plane: one crossing per worker, the
                // per-slot shared-randomness streams derived in one
                // fan-out, then the exact sequential tree round per slot
                // (every receive is already sender-addressed, so slots
                // interleave safely across machines).
                let b_total = bc.dims.len();
                let seeds = fork_round_seeds(seed, bc.first_round, b_total);
                let mut lo = 0usize;
                let mut fatal = None;
                for b in 0..b_total {
                    let d_b = bc.dims[b];
                    let r = bc.first_round + b as u64;
                    if let Err(e) = tree_slot_round(
                        &mut ep,
                        m,
                        seed,
                        seeds[b],
                        r,
                        bc.ys[b],
                        &bc.input[lo..lo + d_b],
                        &mut bc.out[lo..lo + d_b],
                        &mut bc.traffic[b],
                    ) {
                        fatal = Some(e);
                        break;
                    }
                    lo += d_b;
                }
                if let Some(e) = fatal {
                    let _ = otx.send(WorkerMsg::Fatal(e));
                    break;
                }
                if otx
                    .send(WorkerMsg::Batch(BatchOut {
                        ys: bc.ys,
                        dims: bc.dims,
                        input: bc.input,
                        out: bc.out,
                        traffic: bc.traffic,
                        decoded: Vec::new(),
                    }))
                    .is_err()
                {
                    break;
                }
            }
        }
    }
}

/// One machine's side of one tree round — the body both the sequential
/// loop and the batch plane execute, parameterized by the slot's
/// `(round, y, input, out)` and tallying every send/receive into `t`
/// (the batch plane's per-slot traffic decomposition; the sequential
/// path discards the tally — its metering comes from the cluster).
/// `shared_seed` must equal `hash2(seed, round)` (the batch plane
/// derives it once per batch via [`fork_round_seeds`]).
#[allow(clippy::too_many_arguments)]
fn tree_slot_round<E: TransportEndpoint>(
    ep: &mut E,
    m: usize,
    seed: u64,
    shared_seed: u64,
    round: u64,
    y: f64,
    input: &[f64],
    out: &mut [f64],
    t: &mut Traffic,
) -> Result<(), TransportError> {
    let id = ep.id();
    let n = ep.n();
    let d = input.len();
    let (leaves, side, q) = tree_round_schedule(n, m, y, seed, round);
    // One shared-lattice codec per round (the legacy driver rebuilds
    // an identical one per edge; construction is deterministic in
    // (seed, round), so one instance is equivalent).
    let codec = {
        let mut sr = Rng::new(shared_seed);
        LatticeQuantizer::new(CubicLattice::random_offset(d, side, &mut sr), q)
    };

    // --- Upward pass: (owner, estimate-if-mine) per node, level by
    // level; internal node j at level l is played by machine
    // (2j + 3l) mod n.
    let mut ests: Vec<(usize, Option<Vec<f64>>)> = leaves
        .iter()
        .map(|&v| (v, if v == id { Some(input.to_vec()) } else { None }))
        .collect();
    let mut level = 0usize;
    while ests.len() > 1 {
        level += 1;
        let pairs = ests.len() / 2;
        let mut next: Vec<(usize, Option<Vec<f64>>)> = Vec::with_capacity(pairs + 1);
        for j in 0..pairs {
            let parent = (j * 2 + level * 3) % n;
            // Streaming fold at the inner node: both children are
            // decode-accumulated straight into the node's estimate
            // buffer (no per-child decoded vectors), then halved in
            // place — bit-identical to the legacy add-then-scale.
            let mut acc = if parent == id {
                Some(vec![0.0; d])
            } else {
                None
            };
            for c in 0..2 {
                let idx = 2 * j + c;
                let child = ests[idx].0;
                if child == id {
                    let est = ests[idx].1.as_ref().expect("owner holds estimate");
                    let (msg, _pt) = codec.encode_with_point(est);
                    if child != parent {
                        t.sent_bits += msg.bits;
                        t.sent_msgs += 1;
                        ep.send(parent, msg)?;
                    } else {
                        // Same machine plays both roles: no wire cost.
                        let a = acc.as_mut().expect("parent holds accumulator");
                        codec.decode_accumulate_into(&msg, input, 1.0, a);
                    }
                } else if parent == id {
                    let p = ep.recv_from(child)?;
                    t.recv_bits += p.msg.bits;
                    t.recv_msgs += 1;
                    let a = acc.as_mut().expect("parent holds accumulator");
                    codec.decode_accumulate_into(&p.msg, input, 1.0, a);
                }
            }
            if let Some(a) = acc.as_mut() {
                for v in a.iter_mut() {
                    *v *= 0.5;
                }
            }
            next.push((parent, acc));
        }
        if ests.len() % 2 == 1 {
            // Odd node passes through unchanged.
            next.push(ests.pop().expect("odd tail node"));
        }
        ests = next;
    }
    let (root, root_est) = ests.pop().expect("tree root");

    // --- Downward broadcast over a binary tree rooted at `root`
    // covering all machines (ids re-indexed so root is position 0);
    // everyone relays the identical message.
    let mypos = (id + n - root) % n;
    let bmsg = if id == root {
        codec
            .encode_with_point(root_est.as_ref().expect("root owns estimate"))
            .0
    } else {
        let parent = (root + (mypos - 1) / 2) % n;
        let p = ep.recv_from(parent)?;
        t.recv_bits += p.msg.bits;
        t.recv_msgs += 1;
        p.msg
    };
    for cpos in [2 * mypos + 1, 2 * mypos + 2] {
        if cpos < n {
            t.sent_bits += bmsg.bits;
            t.sent_msgs += 1;
            ep.send((root + cpos) % n, bmsg.clone())?;
        }
    }
    codec.decode_into(&bmsg, input, out);
    Ok(())
}

/// What [`tree_partial_round`] produced on this machine.
struct TreePartial {
    root: usize,
    /// Root only: arrived-leaf reports folded into its estimate.
    k: usize,
    retries: u32,
    got_output: bool,
    quorum_failed: bool,
}

/// One machine's side of one **k-of-n** tree round (the module
/// §Straggler policy). The schedule and codec are exactly
/// [`tree_slot_round`]'s; the fold differs only where reports are
/// missing:
///
/// - both children arrived → decode both, average (`× 0.5`) — with
///   every report present this is arithmetically the full round;
/// - one child arrived → its estimate passes through *unhalved* (the
///   pairwise analogue of the star's `1/k` renormalization), its
///   arrived-leaf weight riding the wire envelope so the root learns
///   the exact `k`;
/// - neither arrived → the node is empty; a healthy owner sends a
///   weight-0 marker so its parent skips the child instead of burning a
///   timeout window (a silenced owner always costs its parent one).
///
/// Waiting is budgeted per level — a parent at level `l` waits until
/// `start + l·deadline` — so a machine that itself waited out a
/// straggler still lands inside its parent's window, keeping the
/// outcome deterministic. The downward broadcast gets one more
/// deadline on top of the upward budget.
#[allow(clippy::too_many_arguments)]
fn tree_partial_round<E: TransportEndpoint>(
    ep: &mut E,
    m: usize,
    seed: u64,
    round: u64,
    y: f64,
    policy: &StragglerPolicy,
    input: &[f64],
    out: &mut [f64],
) -> Result<TreePartial, TransportError> {
    let id = ep.id();
    let n = ep.n();
    let d = input.len();
    let shared_seed = hash2(seed, round);
    let (leaves, side, q) = tree_round_schedule(n, m, y, seed, round);
    let codec = {
        let mut sr = Rng::new(shared_seed);
        LatticeQuantizer::new(CubicLattice::random_offset(d, side, &mut sr), q)
    };
    let start = Instant::now();
    let mut gather = PartialGather::new(round, n, policy, hash2(round, id as u64));

    // Upward: (owner, Some((estimate, arrived-leaf weight)) iff this
    // machine owns the node; weight 0 = empty subtree).
    let mut ests: Vec<(usize, Option<(Vec<f64>, u64)>)> = leaves
        .iter()
        .map(|&v| (v, if v == id { Some((input.to_vec(), 1)) } else { None }))
        .collect();
    let mut level = 0usize;
    while ests.len() > 1 {
        level += 1;
        gather.set_deadline(start + policy.deadline * level as u32);
        let pairs = ests.len() / 2;
        let mut next: Vec<(usize, Option<(Vec<f64>, u64)>)> = Vec::with_capacity(pairs + 1);
        for j in 0..pairs {
            let parent = (j * 2 + level * 3) % n;
            // Decoded child estimates present at the parent, child order.
            let mut got: Vec<(Vec<f64>, u64)> = Vec::new();
            for c in 0..2 {
                let idx = 2 * j + c;
                let child = ests[idx].0;
                if child == id {
                    let (est, w) = ests[idx].1.take().expect("owner holds node state");
                    if child == parent {
                        // Same machine plays both roles: no wire.
                        if w > 0 {
                            let (msg, _pt) = codec.encode_with_point(&est);
                            let mut dec = vec![0.0; d];
                            codec.decode_into(&msg, input, &mut dec);
                            got.push((dec, w));
                        }
                    } else if w == 0 {
                        let mut marker = Message::empty();
                        wrap_partial(&mut marker, round, 0, DIR_UP);
                        send_lossy(ep, parent, marker)?;
                    } else {
                        let (mut msg, _pt) = codec.encode_with_point(&est);
                        wrap_partial(&mut msg, round, w, DIR_UP);
                        send_lossy(ep, parent, msg)?;
                    }
                } else if parent == id {
                    match gather.recv_dir(ep, Some(child), DIR_UP)? {
                        Some((_from, w, msg)) if w > 0 => {
                            let mut dec = vec![0.0; d];
                            codec.decode_into(&msg, input, &mut dec);
                            got.push((dec, w));
                        }
                        // Weight-0 marker or deadline: no contribution.
                        _ => {}
                    }
                }
            }
            let state = if parent == id {
                Some(match got.len() {
                    2 => {
                        let (c1, w1) = got.pop().expect("second child");
                        let (mut acc, w0) = got.pop().expect("first child");
                        for (a, z) in acc.iter_mut().zip(&c1) {
                            *a = (*a + *z) * 0.5;
                        }
                        (acc, w0 + w1)
                    }
                    1 => got.pop().expect("only child"),
                    _ => (Vec::new(), 0),
                })
            } else {
                None
            };
            next.push((parent, state));
        }
        if ests.len() % 2 == 1 {
            next.push(ests.pop().expect("odd tail node"));
        }
        ests = next;
    }
    let (root, root_state) = ests.pop().expect("tree root");

    if id == root {
        let (est, w) = root_state.expect("root owns its state");
        let k = w as usize;
        if k < policy.k_min.max(1) {
            // No broadcast: the other machines wait out their downward
            // cutoff and report no output; the driver raises the typed
            // quorum error.
            return Ok(TreePartial {
                root,
                k,
                retries: gather.retries,
                got_output: false,
                quorum_failed: true,
            });
        }
        let (mut bmsg, _pt) = codec.encode_with_point(&est);
        codec.decode_into(&bmsg, input, out);
        wrap_partial(&mut bmsg, round, w, DIR_DOWN);
        for cpos in [1usize, 2] {
            if cpos < n {
                send_lossy(ep, (root + cpos) % n, bmsg.clone())?;
            }
        }
        Ok(TreePartial {
            root,
            k,
            retries: gather.retries,
            got_output: true,
            quorum_failed: false,
        })
    } else {
        let mypos = (id + n - root) % n;
        let parent = (root + (mypos - 1) / 2) % n;
        gather.set_deadline(start + policy.deadline * (level as u32 + 1));
        match gather.recv_dir(ep, Some(parent), DIR_DOWN)? {
            Some((_from, w, msg)) => {
                codec.decode_into(&msg, input, out);
                let mut relay = msg;
                wrap_partial(&mut relay, round, w, DIR_DOWN);
                for cpos in [2 * mypos + 1, 2 * mypos + 2] {
                    if cpos < n {
                        send_lossy(ep, (root + cpos) % n, relay.clone())?;
                    }
                }
                Ok(TreePartial {
                    root,
                    k: w as usize,
                    retries: gather.retries,
                    got_output: true,
                    quorum_failed: false,
                })
            }
            None => Ok(TreePartial {
                root,
                k: 0,
                retries: gather.retries,
                got_output: false,
                quorum_failed: false,
            }),
        }
    }
}

/// What [`tree_partial_reference`] predicts for one faulted tree round.
#[derive(Clone, Debug, PartialEq)]
pub struct TreePartialReference {
    /// The upward fold's root (the round's coordinator).
    pub root: usize,
    /// The estimate the root decodes — `None` when every leaf report
    /// was lost (`k = 0`).
    pub estimate: Option<Vec<f64>>,
    /// Arrived-leaf reports folded into the estimate.
    pub k: usize,
}

/// Transport-free oracle for the k-of-n tree round: replays
/// [`tree_partial_round`]'s exact fold — same schedule, same shared
/// codec, decode-at-parent, halve-when-both / pass-through-when-one —
/// for a given set of send-`silenced` machines, without spawning a
/// cluster. A node's report reaches its parent iff the node is
/// non-empty and its owner either *is* the parent (no wire) or is not
/// silenced. Integration tests assert a faulted session's estimate
/// equals this value exactly (the round schedule is crate-private, so
/// the replay lives here).
pub fn tree_partial_reference(
    n: usize,
    m: usize,
    y: f64,
    seed: u64,
    round: u64,
    inputs: &[Vec<f64>],
    silenced: &[usize],
) -> TreePartialReference {
    assert_eq!(inputs.len(), n, "one input vector per machine");
    assert!(n >= 1, "need at least one machine");
    let d = inputs[0].len();
    let shared_seed = hash2(seed, round);
    let (leaves, side, q) = tree_round_schedule(n, m, y, seed, round);
    let codec = {
        let mut sr = Rng::new(shared_seed);
        LatticeQuantizer::new(CubicLattice::random_offset(d, side, &mut sr), q)
    };
    // (owner, estimate, arrived-leaf weight); weight 0 = empty subtree.
    let mut ests: Vec<(usize, Vec<f64>, u64)> = leaves
        .iter()
        .map(|&v| (v, inputs[v].clone(), 1))
        .collect();
    let mut level = 0usize;
    while ests.len() > 1 {
        level += 1;
        let pairs = ests.len() / 2;
        let mut next: Vec<(usize, Vec<f64>, u64)> = Vec::with_capacity(pairs + 1);
        for j in 0..pairs {
            let parent = (j * 2 + level * 3) % n;
            let mut got: Vec<(Vec<f64>, u64)> = Vec::new();
            for c in 0..2 {
                let (owner, est, w) = &ests[2 * j + c];
                if *w > 0 && (*owner == parent || !silenced.contains(owner)) {
                    let (msg, _pt) = codec.encode_with_point(est);
                    let mut dec = vec![0.0; d];
                    codec.decode_into(&msg, &inputs[parent], &mut dec);
                    got.push((dec, *w));
                }
            }
            let (est, w) = match got.len() {
                2 => {
                    let (c1, w1) = got.pop().expect("second child");
                    let (mut acc, w0) = got.pop().expect("first child");
                    for (a, z) in acc.iter_mut().zip(&c1) {
                        *a = (*a + *z) * 0.5;
                    }
                    (acc, w0 + w1)
                }
                1 => got.pop().expect("only child"),
                _ => (Vec::new(), 0),
            };
            next.push((parent, est, w));
        }
        if ests.len() % 2 == 1 {
            next.push(ests.pop().expect("odd tail node"));
        }
        ests = next;
    }
    let (root, est, w) = ests.pop().expect("tree root");
    let k = w as usize;
    if k == 0 {
        return TreePartialReference {
            root,
            estimate: None,
            k: 0,
        };
    }
    let (msg, _pt) = codec.encode_with_point(&est);
    let mut out = vec![0.0; d];
    codec.decode_into(&msg, &inputs[root], &mut out);
    TreePartialReference {
        root,
        estimate: Some(out),
        k,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dist_inf, mean_vecs};

    fn gen(n: usize, d: usize, center: f64, spread: f64, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..d).map(|_| center + rng.uniform(-spread, spread)).collect())
            .collect()
    }

    #[test]
    fn star_session_many_rounds_agree_and_meter_cumulatively() {
        let n = 6;
        let d = 32;
        let inputs = gen(n, d, 50.0, 0.4, 1);
        let mu = mean_vecs(&inputs);
        let mut sess = DmeBuilder::new(n, d)
            .codec(CodecSpec::Lq { q: 64 })
            .seed(7)
            .build();
        let mut prev = 0;
        for r in 0..30 {
            let out = sess.round_with_y(&inputs, 1.0);
            assert_eq!(out.round, r);
            assert!(out.agreement, "round {r} disagreed");
            assert!(out.leader.is_some());
            assert!(dist_inf(&out.estimate, &mu) < 0.1);
            assert!(out.traffic.max_sent > prev, "cumulative bits must grow");
            prev = out.traffic.max_sent;
        }
        assert_eq!(sess.rounds_run(), 30);
    }

    #[test]
    fn tree_session_many_rounds_agree() {
        let n = 8;
        let d = 16;
        let inputs = gen(n, d, 20.0, 0.5, 2);
        let mu = mean_vecs(&inputs);
        let mut sess = DmeBuilder::new(n, d)
            .topology(Topology::Tree { m: n })
            .seed(3)
            .build();
        for _ in 0..20 {
            let out = sess.round_with_y(&inputs, 1.2);
            assert!(out.agreement);
            assert_eq!(out.leaves.len(), n);
            assert!(out.q_used.is_some());
            assert!(dist_inf(&out.estimate, &mu) < 0.5);
        }
    }

    #[test]
    fn round_traffic_deltas_sum_to_cumulative() {
        let n = 5;
        let d = 24;
        let inputs = gen(n, d, 0.0, 0.4, 4);
        let mut sess = DmeBuilder::new(n, d).seed(11).build();
        let mut acc = vec![0u64; n];
        let mut last = None;
        for _ in 0..7 {
            let out = sess.round_with_y(&inputs, 1.0);
            for (a, t) in acc.iter_mut().zip(&out.round_traffic) {
                *a += t.sent_bits;
            }
            last = Some(out);
        }
        let cum = last.unwrap().traffic;
        assert_eq!(cum.max_sent, *acc.iter().max().unwrap());
    }

    #[test]
    fn y_policy_adapts_inside_session() {
        let n = 4;
        let d = 16;
        let inputs = gen(n, d, 5.0, 0.01, 5);
        let mut sess = DmeBuilder::new(n, d)
            .y0(10.0) // deliberately loose start
            .y_policy(YPolicy::FromQuantized { slack: 1.5 })
            .seed(6)
            .build();
        sess.round(&inputs);
        assert!(sess.y() < 10.0, "y should tighten: {}", sess.y());
    }

    #[test]
    fn chebyshev_vr_round_reduces_variance() {
        let n = 16;
        let d = 32;
        let sig_c = 0.1;
        let mut rng = Rng::new(40);
        let nabla: Vec<f64> = (0..d).map(|_| 100.0 + rng.next_gaussian()).collect();
        let inputs: Vec<Vec<f64>> = (0..n)
            .map(|_| nabla.iter().map(|v| v + sig_c * rng.next_gaussian()).collect())
            .collect();
        let mut sess = DmeBuilder::new(n, d)
            .codec(CodecSpec::Lq { q: 4096 })
            .seed(41)
            .build();
        let out = sess.round_vr(&inputs, sig_c * (d as f64).sqrt());
        let e_in = crate::linalg::dist2(&inputs[0], &nabla);
        let e_out = crate::linalg::dist2(&out.estimate, &nabla);
        assert!(e_out < e_in, "VR must reduce error: in {e_in} out {e_out}");
    }

    #[test]
    fn robust_vr_round_reports_stages() {
        let n = 6;
        let d = 16;
        let inputs = gen(n, d, 0.0, 0.05, 50);
        let mut sess = DmeBuilder::new(n, d).robust(8).seed(51).build();
        let out = sess.round_vr(&inputs, 0.1);
        assert_eq!(out.rounds_stage1.len(), n - 1);
        assert!(out.leader.is_some());
        assert!(out.round_traffic.iter().any(|t| t.sent_bits > 0));
    }

    #[test]
    fn sublinear_round_through_session() {
        let inputs = gen(8, 64, 10.0, 0.5, 60);
        let mut sess = DmeBuilder::new(8, 64).seed(61).build();
        let out = sess.round_sublinear(&inputs, 0.2, 1.0);
        assert!(out.leader.is_some());
        let max_sent = out.round_traffic.iter().map(|t| t.sent_bits).max().unwrap();
        assert!(max_sent <= 64, "sublinear bits must stay o(d): {max_sent}");
    }

    #[test]
    fn diagnostics_mode_returns_outputs_and_decoded() {
        let n = 4;
        let d = 8;
        let inputs = gen(n, d, 1.0, 0.2, 70);
        let mut sess = DmeBuilder::new(n, d).diagnostics(true).seed(71).build();
        let out = sess.round_with_y(&inputs, 1.0);
        assert_eq!(out.outputs.len(), n);
        assert_eq!(out.decoded_at_leader.len(), n);
        for o in &out.outputs {
            assert_eq!(o, &out.estimate);
        }
    }

    #[test]
    fn single_machine_identity() {
        let inputs = gen(1, 8, 5.0, 0.1, 80);
        let mut sess = DmeBuilder::new(1, 8).diagnostics(true).seed(81).build();
        let out = sess.round_with_y(&inputs, 1.0);
        assert_eq!(out.estimate, inputs[0]);
        assert_eq!(out.round_traffic, vec![Traffic::default()]);
    }

    #[test]
    #[should_panic(expected = "tree topology")]
    fn tree_rejects_adaptive_y_policy() {
        let _ = DmeBuilder::new(4, 8)
            .topology(Topology::Tree { m: 4 })
            .y_policy(YPolicy::FromQuantized { slack: 1.5 })
            .build();
    }

    #[test]
    fn stateful_codec_persists_across_session_rounds() {
        // EF-SignSGD's error memory must survive the round loop (the
        // Aggregator contract): round 1 of a warm session encodes
        // x + e with e ≠ 0, so its estimate differs from round 1 of a
        // fresh session (e = 0) at the same (seed, round).
        let n = 4;
        let d = 8;
        let inputs = gen(n, d, 0.5, 0.3, 95);
        let mk = || DmeBuilder::new(n, d).codec(CodecSpec::EfSign).seed(21).build();
        let mut warm = mk();
        let _r0 = warm.round_with_y(&inputs, 1.0);
        let r1 = warm.round_with_y(&inputs, 1.0);
        let mut fresh = mk();
        fresh.set_round(1);
        let f1 = fresh.round_with_y(&inputs, 1.0);
        assert_ne!(
            r1.estimate, f1.estimate,
            "error feedback must persist across session rounds"
        );
    }

    #[test]
    fn drop_joins_cleanly() {
        let inputs = gen(3, 8, 0.0, 0.3, 90);
        let mut sess = DmeBuilder::new(3, 8).seed(91).build();
        let _ = sess.round_with_y(&inputs, 1.0);
        drop(sess); // must not hang or panic
    }

    #[test]
    fn round_batch_agrees_and_advances_round_window() {
        let n = 5;
        let d = 16;
        let slots: Vec<Vec<Vec<f64>>> = (0..4).map(|b| gen(n, d, 30.0, 0.4, 200 + b)).collect();
        let mut sess = DmeBuilder::new(n, d).codec(CodecSpec::Lq { q: 64 }).seed(21).build();
        let outs = sess.round_batch(&slots);
        assert_eq!(outs.len(), 4);
        assert_eq!(sess.rounds_run(), 4);
        for (b, o) in outs.iter().enumerate() {
            assert_eq!(o.round, b as u64);
            assert!(o.agreement, "slot {b} disagreed");
            assert!(o.leader.is_some());
            let mu = mean_vecs(&slots[b]);
            assert!(dist_inf(&o.estimate, &mu) < 0.1, "slot {b}");
        }
        // Cumulative traffic grows slot over slot.
        for w in outs.windows(2) {
            assert!(w[1].traffic.max_sent > w[0].traffic.max_sent);
        }
        // The next sequential round continues the window.
        let o = sess.round_with_y(&slots[0], 1.0);
        assert_eq!(o.round, 4);
    }

    #[test]
    fn round_batch_supports_per_layer_slot_dimensions() {
        // The per-layer SGD shape: slots of different widths through one
        // session, each with its own distance bound.
        let n = 4;
        let dims = [24usize, 4, 12, 3];
        let slots: Vec<Vec<Vec<f64>>> = dims
            .iter()
            .enumerate()
            .map(|(b, &d_b)| gen(n, d_b, 5.0, 0.25, 300 + b as u64))
            .collect();
        let ys = [2.0, 1.5, 1.8, 1.2];
        let mut sess = DmeBuilder::new(n, 24).seed(31).build();
        let outs = sess.round_batch_with_y(&slots, &ys);
        for (b, o) in outs.iter().enumerate() {
            assert_eq!(o.estimate.len(), dims[b]);
            assert!(o.agreement, "slot {b}");
            assert_eq!(o.y_used, ys[b]);
            let mu = mean_vecs(&slots[b]);
            assert!(dist_inf(&o.estimate, &mu) < ys[b], "slot {b}");
        }
    }

    #[test]
    fn round_batch_into_recycles_outcome_buffers() {
        let n = 3;
        let d = 8;
        let slots: Vec<Vec<Vec<f64>>> = (0..3).map(|b| gen(n, d, 2.0, 0.3, 400 + b)).collect();
        let ys = vec![1.0; 3];
        let mut sess = DmeBuilder::new(n, d).seed(41).build();
        let mut outcomes = Vec::new();
        sess.round_batch_into(&slots, &ys, &mut outcomes);
        let first: Vec<Vec<f64>> = outcomes.iter().map(|o| o.estimate.clone()).collect();
        // Second batch reuses the same outcome vector; results must be
        // the fresh rounds 3..6, not stale round-0 leftovers.
        sess.round_batch_into(&slots, &ys, &mut outcomes);
        assert_eq!(outcomes.len(), 3);
        for (b, o) in outcomes.iter().enumerate() {
            assert_eq!(o.round, 3 + b as u64);
            assert!(o.agreement);
            assert_eq!(o.estimate.len(), d);
            assert!(o.outputs.is_empty() && o.decoded_at_leader.is_empty());
        }
        // Shared randomness moved on, so estimates differ in general.
        assert_ne!(first[0], outcomes[0].estimate);
    }

    #[test]
    fn round_vr_batch_matches_sequential_round_vr() {
        let n = 8;
        let d = 16;
        let sigma = 0.2;
        let slots: Vec<Vec<Vec<f64>>> = (0..3).map(|b| gen(n, d, 10.0, 0.1, 500 + b)).collect();
        let mut batched = DmeBuilder::new(n, d).seed(51).build();
        let mut seq = DmeBuilder::new(n, d).seed(51).build();
        let outs = batched.round_vr_batch(&slots, sigma);
        for (b, o) in outs.iter().enumerate() {
            let s = seq.round_vr(&slots[b], sigma);
            assert_eq!(o.estimate, s.estimate, "slot {b}");
            assert_eq!(o.y_used, s.y_used, "slot {b}");
            assert_eq!(o.round_traffic, s.round_traffic, "slot {b}");
        }
        // Error-detecting robustness falls back to sequential rounds.
        let mut robust = DmeBuilder::new(n, d).robust(8).seed(52).build();
        let r = robust.round_vr_batch(&slots[..2], sigma);
        assert_eq!(r.len(), 2);
        assert_eq!(robust.rounds_run(), 2);
        assert!(r.iter().all(|o| !o.rounds_stage1.is_empty()));
    }

    #[test]
    fn round_batch_single_machine_identity() {
        let slots: Vec<Vec<Vec<f64>>> = (0..2).map(|b| gen(1, 8, 5.0, 0.1, 600 + b)).collect();
        let mut sess = DmeBuilder::new(1, 8).diagnostics(true).seed(61).build();
        let outs = sess.round_batch(&slots);
        for (b, o) in outs.iter().enumerate() {
            assert_eq!(o.estimate, slots[b][0]);
            assert_eq!(o.round_traffic, vec![Traffic::default()]);
            assert_eq!(o.outputs, vec![slots[b][0].clone()]);
        }
    }

    #[test]
    #[should_panic(expected = "adaptive y policies")]
    fn round_batch_rejects_adaptive_y_policy() {
        let slots = vec![gen(4, 8, 1.0, 0.2, 700)];
        let mut sess = DmeBuilder::new(4, 8)
            .y_policy(YPolicy::FromQuantized { slack: 1.5 })
            .build();
        let _ = sess.round_batch(&slots);
    }

    #[test]
    fn round_batch_empty_is_a_noop() {
        let mut sess = DmeBuilder::new(3, 8).seed(71).build();
        let outs = sess.round_batch(&[]);
        assert!(outs.is_empty());
        assert_eq!(sess.rounds_run(), 0);
    }
}
