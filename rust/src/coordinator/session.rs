//! Back-compat star session — the original persistent-cluster API, now a
//! thin shim over the topology-agnostic [`super::DmeSession`].
//!
//! Historically this module carried the only multi-round deployment of
//! Algorithm 3 (star-only, input vectors cloned into every round). The
//! generalized session in [`super::api`] supersedes it: both topologies,
//! recycled buffers, unified [`super::RoundOutcome`]. `StarSession` is
//! kept so existing callers and benchmarks compile unchanged; new code
//! should use [`super::DmeBuilder`] directly.

use super::api::DmeBuilder;
use super::CodecSpec;
use crate::sim::TrafficSummary;

/// One round's result from a persistent session.
#[derive(Clone, Debug)]
pub struct SessionRound {
    pub estimate: Vec<f64>,
    pub leader: usize,
    /// Cumulative traffic summary since session start.
    pub traffic: TrafficSummary,
}

/// A long-lived star-topology cluster: spawn once, run many rounds.
pub struct StarSession {
    inner: super::DmeSession,
    spec: CodecSpec,
}

impl StarSession {
    pub fn new(n: usize, d: usize, spec: CodecSpec, seed: u64) -> Self {
        assert!(n >= 2);
        StarSession {
            inner: DmeBuilder::new(n, d).codec(spec).seed(seed).build(),
            spec,
        }
    }

    /// Run one MeanEstimation round; `inputs[v]` is machine v's vector.
    pub fn round(&mut self, inputs: &[Vec<f64>], y: f64) -> SessionRound {
        let out = self.inner.round_with_y(inputs, y);
        debug_assert!(out.agreement);
        SessionRound {
            estimate: out.estimate,
            leader: out.leader.expect("star round reports a leader"),
            traffic: out.traffic,
        }
    }

    pub fn spec(&self) -> &CodecSpec {
        &self.spec
    }

    pub fn rounds_run(&self) -> u64 {
        self.inner.rounds_run()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dist_inf, mean_vecs};
    use crate::rng::Rng;

    fn gen(n: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..d).map(|_| 50.0 + rng.uniform(-0.5, 0.5)).collect())
            .collect()
    }

    #[test]
    fn session_matches_one_shot_protocol() {
        let n = 6;
        let d = 32;
        let y = 1.0;
        let inputs = gen(n, d, 3);
        let mut sess = StarSession::new(n, d, CodecSpec::Lq { q: 16 }, 9);
        let r0 = sess.round(&inputs, y);
        // Same (seed, round) ⇒ same leader and same shared randomness as
        // the one-shot implementation.
        let one =
            super::super::star::mean_estimation_star(&inputs, &CodecSpec::Lq { q: 16 }, y, 9, 0);
        assert_eq!(r0.leader, one.leader);
        assert_eq!(r0.estimate, one.outputs[0]);
    }

    #[test]
    fn session_runs_many_rounds_and_meters_cumulatively() {
        let n = 4;
        let d = 16;
        let inputs = gen(n, d, 4);
        let mu = mean_vecs(&inputs);
        let mut sess = StarSession::new(n, d, CodecSpec::Lq { q: 64 }, 10);
        let mut prev_bits = 0;
        for _ in 0..50 {
            let r = sess.round(&inputs, 1.0);
            assert!(dist_inf(&r.estimate, &mu) < 0.1);
            assert!(r.traffic.max_sent > prev_bits);
            prev_bits = r.traffic.max_sent;
        }
        assert_eq!(sess.rounds_run(), 50);
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let inputs = gen(3, 8, 5);
        let mut sess = StarSession::new(3, 8, CodecSpec::Full, 11);
        let _ = sess.round(&inputs, 1.0);
        drop(sess); // must not hang or panic
    }
}
