//! Persistent star session — the multi-round deployment of Algorithm 3.
//!
//! [`super::star::mean_estimation_star`] spawns one thread per machine
//! per round, which is faithful but dominates wall time for small d
//! (§Perf: ~20 µs/thread spawn vs ~3 µs of quantization work at d=128).
//! In an SGD deployment the same machines run thousands of rounds, so
//! this module keeps the cluster threads alive and drives rounds through
//! per-machine input/output channels. Bit metering and protocol logic
//! are identical (same codec construction, same leader schedule).

use super::CodecSpec;
use crate::rng::{hash2, Rng};
use crate::sim::{summarize, Cluster, TrafficSummary};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

enum Cmd {
    Round { round: u64, y: f64, input: Vec<f64> },
    Shutdown,
}

/// One round's result from a persistent session.
#[derive(Clone, Debug)]
pub struct SessionRound {
    pub estimate: Vec<f64>,
    pub leader: usize,
    /// Cumulative traffic summary since session start.
    pub traffic: TrafficSummary,
}

/// A long-lived star-topology cluster: spawn once, run many rounds.
pub struct StarSession {
    n: usize,
    spec: CodecSpec,
    seed: u64,
    cmd_tx: Vec<Sender<Cmd>>,
    out_rx: Vec<Receiver<Vec<f64>>>,
    handles: Vec<JoinHandle<()>>,
    cluster: Cluster,
    round: u64,
}

impl StarSession {
    pub fn new(n: usize, d: usize, spec: CodecSpec, seed: u64) -> Self {
        assert!(n >= 2);
        let cluster = Cluster::new(n);
        let endpoints = cluster.endpoints();
        let mut cmd_tx = Vec::with_capacity(n);
        let mut out_rx = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for mut ep in endpoints {
            let (ctx, crx) = channel::<Cmd>();
            let (otx, orx) = channel::<Vec<f64>>();
            cmd_tx.push(ctx);
            out_rx.push(orx);
            let spec = spec;
            handles.push(
                std::thread::Builder::new()
                    .name(format!("star-machine-{}", ep.id))
                    .spawn(move || {
                        let id = ep.id;
                        let n = ep.n;
                        let mut stash = Vec::new();
                        while let Ok(Cmd::Round { round, y, input }) = crx.recv() {
                            let leader = Rng::new(hash2(seed, round ^ 0x1EAD))
                                .next_below(n as u64)
                                as usize;
                            let mut codec = spec.build(d, y, seed, round);
                            let mut enc_rng =
                                Rng::new(hash2(hash2(seed, round), id as u64 + 1));
                            let output = if id == leader {
                                let mut sum = input.clone();
                                for _ in 0..n - 1 {
                                    let p = ep.recv();
                                    let z = codec.decode(&p.msg, &input);
                                    crate::linalg::axpy(&mut sum, 1.0, &z);
                                }
                                let mu = crate::linalg::scale(&sum, 1.0 / n as f64);
                                let bmsg = codec.encode(&mu, &mut enc_rng);
                                ep.broadcast(&bmsg);
                                codec.decode(&bmsg, &input)
                            } else {
                                let msg = codec.encode(&input, &mut enc_rng);
                                ep.send(leader, msg);
                                let p = ep.recv_from(leader, &mut stash);
                                codec.decode(&p.msg, &input)
                            };
                            let _ = otx.send(output);
                        }
                    })
                    .expect("spawn"),
            );
        }
        StarSession {
            n,
            spec,
            seed,
            cmd_tx,
            out_rx,
            handles,
            cluster,
            round: 0,
        }
    }

    /// Run one MeanEstimation round; `inputs[v]` is machine v's vector.
    pub fn round(&mut self, inputs: &[Vec<f64>], y: f64) -> SessionRound {
        assert_eq!(inputs.len(), self.n);
        let round = self.round;
        self.round += 1;
        for (tx, input) in self.cmd_tx.iter().zip(inputs) {
            tx.send(Cmd::Round {
                round,
                y,
                input: input.clone(),
            })
            .expect("machine alive");
        }
        let mut outputs: Vec<Vec<f64>> = Vec::with_capacity(self.n);
        for rx in &self.out_rx {
            outputs.push(rx.recv().expect("machine alive"));
        }
        debug_assert!(outputs.iter().all(|o| o == &outputs[0]));
        let leader =
            Rng::new(hash2(self.seed, round ^ 0x1EAD)).next_below(self.n as u64) as usize;
        SessionRound {
            estimate: outputs.swap_remove(0),
            leader,
            traffic: summarize(&self.cluster.traffic()),
        }
    }

    pub fn spec(&self) -> &CodecSpec {
        &self.spec
    }

    pub fn rounds_run(&self) -> u64 {
        self.round
    }
}

impl Drop for StarSession {
    fn drop(&mut self) {
        for tx in &self.cmd_tx {
            let _ = tx.send(Cmd::Shutdown);
        }
        // Channels closing unblocks recv(); join everything.
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dist_inf, mean_vecs};

    fn gen(n: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..d).map(|_| 50.0 + rng.uniform(-0.5, 0.5)).collect())
            .collect()
    }

    #[test]
    fn session_matches_one_shot_protocol() {
        let n = 6;
        let d = 32;
        let y = 1.0;
        let inputs = gen(n, d, 3);
        let mut sess = StarSession::new(n, d, CodecSpec::Lq { q: 16 }, 9);
        let r0 = sess.round(&inputs, y);
        // Same (seed, round) ⇒ same leader and same shared randomness as
        // the one-shot implementation.
        let one =
            super::super::star::mean_estimation_star(&inputs, &CodecSpec::Lq { q: 16 }, y, 9, 0);
        assert_eq!(r0.leader, one.leader);
        assert_eq!(r0.estimate, one.outputs[0]);
    }

    #[test]
    fn session_runs_many_rounds_and_meters_cumulatively() {
        let n = 4;
        let d = 16;
        let inputs = gen(n, d, 4);
        let mu = mean_vecs(&inputs);
        let mut sess = StarSession::new(n, d, CodecSpec::Lq { q: 64 }, 10);
        let mut prev_bits = 0;
        for _ in 0..50 {
            let r = sess.round(&inputs, 1.0);
            assert!(dist_inf(&r.estimate, &mu) < 0.1);
            assert!(r.traffic.max_sent > prev_bits);
            prev_bits = r.traffic.max_sent;
        }
        assert_eq!(sess.rounds_run(), 50);
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let inputs = gen(3, 8, 5);
        let mut sess = StarSession::new(3, 8, CodecSpec::Full, 11);
        let _ = sess.round(&inputs, 1.0);
        drop(sess); // must not hang or panic
    }
}
