//! Communication topologies the unified session API runs over.
//!
//! The paper gives two MeanEstimation layouts with complementary cost
//! profiles: the star (Algorithm 3, expected `O(d log q)` bits per
//! machine, leader pays `O(nd log q)`) and the binary tree (Algorithm 4,
//! worst-case `O(d log q)` for everyone). [`Topology`] selects between
//! them at session-build time; the rest of the
//! [`DmeSession`](super::DmeSession) API is identical for both.

/// Which protocol layout a [`super::DmeSession`] drives each round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Algorithm 3: two rounds through a per-round random leader.
    Star,
    /// Algorithm 4: `min(m, n)` sampled leaves averaged up a binary tree
    /// with re-quantization at every internal node, then broadcast down.
    /// `m` is the sample size (`m >= n` ⇒ every machine is a leaf). The
    /// tree codec is the paper's own parameterization (`ε = y/m²`,
    /// `q = m³` — see [`super::tree::tree_params`]); the session's
    /// [`super::CodecSpec`] is not consulted.
    Tree { m: usize },
}

impl Topology {
    /// Short label for tables and CLI output.
    pub fn label(&self) -> String {
        match *self {
            Topology::Star => "star".to_string(),
            Topology::Tree { m } => format!("tree(m={m})"),
        }
    }

    /// Parse a CLI argument: `star`, `tree` (full participation given
    /// `n`), or `tree:<m>`.
    pub fn parse(s: &str, n: usize) -> Result<Topology, String> {
        match s {
            "star" => Ok(Topology::Star),
            "tree" => Ok(Topology::Tree { m: n }),
            _ => match s.strip_prefix("tree:") {
                Some(m) => m
                    .parse()
                    .map(|m| Topology::Tree { m })
                    .map_err(|_| format!("bad tree sample size '{m}'")),
                None => Err(format!("unknown topology '{s}' (star | tree | tree:<m>)")),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_forms() {
        assert_eq!(Topology::parse("star", 8), Ok(Topology::Star));
        assert_eq!(Topology::parse("tree", 8), Ok(Topology::Tree { m: 8 }));
        assert_eq!(Topology::parse("tree:4", 8), Ok(Topology::Tree { m: 4 }));
        assert!(Topology::parse("ring", 8).is_err());
        assert!(Topology::parse("tree:x", 8).is_err());
    }

    #[test]
    fn labels() {
        assert_eq!(Topology::Star.label(), "star");
        assert_eq!(Topology::Tree { m: 4 }.label(), "tree(m=4)");
    }
}
