//! Streaming and chunk-sharded aggregation folds over packed bitstreams.
//!
//! The session's star leader folds packets as they *arrive* (see
//! [`super::api`]), which is the right shape when messages trickle in
//! over a network. This module covers the other deployment shape the
//! paper's §9 serving story implies: all `n` messages are already in
//! leader memory (a batch of RPCs, a replay log, a parameter-server
//! shard) and the only question is how fast `d` coordinates can be
//! folded. [`fold_mean`] is the sequential fused fold;
//! [`fold_mean_chunked`] shards `d` into cache-sized chunks folded in
//! parallel on the process-wide persistent worker pool
//! ([`crate::pool::ChunkPool`] — spawned once, parked between folds) via
//! [`VectorCodec::decode_accumulate_range`] — a
//! fixed-width bitstream is random-access, so each thread seeks straight
//! to its chunk's bit offset in every message. The chunked fold pays off
//! only for codecs that *override* `decode_accumulate_range` with a real
//! seek: the lattice family, full precision, and the fixed-width
//! baselines (QSGD both norms, TernGrad, EF-Sign — their byte-aligned
//! headers don't disturb the seek; Top-K's range fold is sparse and
//! O(k)). Codecs on the allocating default — and Suresh–Hadamard, whose
//! global rotation forces a full dequant per chunk — would decode the
//! full vector once per chunk, so stick with [`fold_mean`] for those.
//!
//! Both folds add per coordinate in the same pinned order (part 0 first),
//! so `fold_mean`, `fold_mean_chunked`, and the session leader's
//! streaming fold produce bit-identical estimates — the property
//! `rust/tests/prop.rs` and the unit tests below pin.
//!
//! The write-side twin of the chunked fold lives in the quant layer:
//! [`crate::quant::encode_chunked`] shards one machine's *encode* of a
//! huge gradient across threads at byte-aligned chunk boundaries, again
//! bit-identically to the sequential stream.

use crate::quant::{Message, VectorCodec};

/// One aggregation input: either the folder's own uncompressed vector
/// (the leader folds its input without a wire round-trip) or an encoded
/// packet from a peer.
pub enum FoldPart<'a> {
    Own(&'a [f64]),
    Encoded(&'a Message),
}

/// Sequential streaming fold: `out = (Σ parts) / parts.len()`, decoding
/// every encoded part against `reference` and accumulating in part order
/// with a single fused pass per part. O(d) memory, zero allocations.
pub fn fold_mean(
    codec: &dyn VectorCodec,
    parts: &[FoldPart],
    reference: &[f64],
    out: &mut [f64],
) {
    assert!(!parts.is_empty(), "fold needs at least one part");
    for o in out.iter_mut() {
        *o = 0.0;
    }
    for part in parts {
        match part {
            FoldPart::Own(x) => crate::linalg::axpy(out, 1.0, x),
            FoldPart::Encoded(msg) => codec.decode_accumulate_into(msg, reference, 1.0, out),
        }
    }
    let inv_n = 1.0 / parts.len() as f64;
    for o in out.iter_mut() {
        *o = inv_n * *o;
    }
}

/// Chunk-sharded parallel fold: splits `d` into chunks of ~`chunk`
/// coordinates (rounded up to the codec's
/// [`VectorCodec::fold_chunk_align`]) and folds each chunk across *all*
/// parts, chunks distributed over the parked workers of the process-wide
/// [`crate::pool::ChunkPool`] (sized to `available_parallelism`, queried
/// once at pool construction; each worker walks its run of cache-sized
/// chunks in order, so tiny chunks or huge `d` never explode the
/// fan-out). Per
/// coordinate the additions happen in the identical part order as
/// [`fold_mean`], so the result is bit-identical — sharding changes
/// wall-clock, never the estimate.
///
/// Requires a `Sync` codec (everything but RLQSGD, whose decode scratch
/// is interior-mutable — and whose global rotation rules out range
/// decoding anyway). Only worth calling for codecs that override
/// [`VectorCodec::decode_accumulate_range`] with a seek-based kernel
/// (`LatticeQuantizer`, `D4Quantizer`, `FullPrecision`, and the
/// fixed-width baselines QSGD / TernGrad / EF-Sign; Top-K's override is
/// sparse): on the default implementation — and on Suresh–Hadamard's
/// rotation-bound override — every chunk re-decodes the full vector,
/// which is strictly more work than [`fold_mean`].
pub fn fold_mean_chunked<C: VectorCodec + Sync + ?Sized>(
    codec: &C,
    parts: &[FoldPart],
    reference: &[f64],
    out: &mut [f64],
    chunk: usize,
) {
    fold_mean_chunked_on(crate::pool::ChunkPool::global(), codec, parts, reference, out, chunk)
}

/// [`fold_mean_chunked`] on an explicit [`crate::pool::ChunkPool`] — the
/// plain entry point is this function on the process-wide
/// [`crate::pool::ChunkPool::global`] (§Perf: workers spawned once and
/// parked between folds, instead of a scoped spawn per call; shard i
/// runs on worker i mod pool-size, no stealing). Public so the prop
/// tests can pin the bit-identity guarantee across pool sizes: each
/// run's output depends only on its coordinate range, never on which
/// worker folds it or how many there are.
pub fn fold_mean_chunked_on<C: VectorCodec + Sync + ?Sized>(
    pool: &crate::pool::ChunkPool,
    codec: &C,
    parts: &[FoldPart],
    reference: &[f64],
    out: &mut [f64],
    chunk: usize,
) {
    assert!(!parts.is_empty(), "fold needs at least one part");
    let align = codec.fold_chunk_align().max(1);
    let chunk = chunk.max(1).div_ceil(align) * align;
    // Contiguous runs of chunks per worker, capped at the pool size
    // (which caches `available_parallelism()` from construction time).
    let threads = pool.size();
    let n_chunks = out.len().div_ceil(chunk).max(1);
    let group = n_chunks.div_ceil(threads) * chunk;
    let inv_n = 1.0 / parts.len() as f64;
    let tasks: Vec<_> = out
        .chunks_mut(group)
        .enumerate()
        .map(|(gi, run)| {
            move || {
                for (ci, shard) in run.chunks_mut(chunk).enumerate() {
                    let lo = gi * group + ci * chunk;
                    for o in shard.iter_mut() {
                        *o = 0.0;
                    }
                    for part in parts {
                        match part {
                            FoldPart::Own(x) => {
                                crate::linalg::axpy(shard, 1.0, &x[lo..lo + shard.len()])
                            }
                            FoldPart::Encoded(msg) => {
                                codec.decode_accumulate_range(msg, reference, 1.0, lo, shard)
                            }
                        }
                    }
                    for o in shard.iter_mut() {
                        *o = inv_n * *o;
                    }
                }
            }
        })
        .collect();
    pool.run_sharded(tasks);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::baselines::FullPrecision;
    use crate::quant::{D4Quantizer, LatticeQuantizer};
    use crate::rng::Rng;

    fn gen(n: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..d).map(|_| 10.0 + rng.uniform(-0.45, 0.45)).collect())
            .collect()
    }

    /// Reference: decode every message into its own buffer, then sum in
    /// part order and divide — the legacy leader data plane.
    fn decode_then_sum(
        codec: &dyn VectorCodec,
        parts: &[FoldPart],
        reference: &[f64],
        d: usize,
    ) -> Vec<f64> {
        let mut mu = vec![0.0; d];
        for part in parts {
            match part {
                FoldPart::Own(x) => crate::linalg::axpy(&mut mu, 1.0, x),
                FoldPart::Encoded(msg) => {
                    let z = codec.decode(msg, reference);
                    crate::linalg::axpy(&mut mu, 1.0, &z);
                }
            }
        }
        let inv_n = 1.0 / parts.len() as f64;
        for m in mu.iter_mut() {
            *m = inv_n * *m;
        }
        mu
    }

    #[test]
    fn streaming_and_chunked_folds_match_decode_then_sum() {
        let n = 9;
        let d = 257;
        let inputs = gen(n, d, 5);
        let mut shared = Rng::new(6);
        let mut codec = LatticeQuantizer::from_y(d, 16, 1.0, &mut shared);
        let mut rng = Rng::new(7);
        let reference = inputs[0].clone();
        let msgs: Vec<Message> = inputs[1..]
            .iter()
            .map(|x| crate::quant::VectorCodec::encode(&mut codec, x, &mut rng))
            .collect();
        let mut parts = vec![FoldPart::Own(&inputs[0])];
        parts.extend(msgs.iter().map(FoldPart::Encoded));

        let expect = decode_then_sum(&codec, &parts, &reference, d);
        let mut seq = vec![9.9; d];
        fold_mean(&codec, &parts, &reference, &mut seq);
        assert_eq!(seq, expect, "sequential fused fold");
        for chunk in [1usize, 7, 64, 300] {
            let mut par = vec![-1.0; d];
            fold_mean_chunked(&codec, &parts, &reference, &mut par, chunk);
            assert_eq!(par, expect, "chunked fold, chunk={chunk}");
        }
    }

    #[test]
    fn chunked_fold_respects_d4_bucket_alignment() {
        let n = 5;
        let d = 64;
        let inputs = gen(n, d, 8);
        let mut shared = Rng::new(9);
        let mut codec = D4Quantizer::from_y(d, 16, 1.0, &mut shared);
        let mut rng = Rng::new(10);
        let reference = inputs[0].clone();
        let msgs: Vec<Message> = inputs[1..]
            .iter()
            .map(|x| crate::quant::VectorCodec::encode(&mut codec, x, &mut rng))
            .collect();
        let mut parts = vec![FoldPart::Own(&inputs[0])];
        parts.extend(msgs.iter().map(FoldPart::Encoded));
        let expect = decode_then_sum(&codec, &parts, &reference, d);
        // chunk=6 would split a bucket; alignment rounds it up to 8.
        let mut par = vec![0.0; d];
        fold_mean_chunked(&codec, &parts, &reference, &mut par, 6);
        assert_eq!(par, expect);
    }

    #[test]
    fn folds_cover_reference_free_codecs() {
        let n = 4;
        let d = 33;
        let inputs = gen(n, d, 11);
        let mut codec = FullPrecision::new(d);
        let mut rng = Rng::new(12);
        let msgs: Vec<Message> = inputs
            .iter()
            .map(|x| crate::quant::VectorCodec::encode(&mut codec, x, &mut rng))
            .collect();
        let parts: Vec<FoldPart> = msgs.iter().map(FoldPart::Encoded).collect();
        let expect = decode_then_sum(&codec, &parts, &inputs[0], d);
        let mut seq = vec![0.0; d];
        fold_mean(&codec, &parts, &inputs[0], &mut seq);
        let mut par = vec![0.0; d];
        fold_mean_chunked(&codec, &parts, &inputs[0], &mut par, 8);
        assert_eq!(seq, expect);
        assert_eq!(par, expect);
    }
}
