//! Algorithm 4 — tree-topology MeanEstimation(m).
//!
//! Worst-case (not just expected) per-machine communication: sample
//! `min(m, n)` machines as leaves of a complete binary tree, average
//! upward with re-quantization at every internal node (parameters
//! `ε = y/m²`, `q = m³` in paper terms — here lattice side `s = 2y/m²`
//! and color count `q = m³` capped for word width), then broadcast the
//! root's estimate down a binary tree over *all* machines, each relaying
//! the identical message.
//!
//! Internal-node roles are assigned to machines round-robin so every
//! machine plays O(1) roles (the paper's requirement); bits are metered
//! against the *machine* playing each role via [`crate::sim`] endpoints
//! driven sequentially (the tree has data dependencies level by level, so
//! sequential execution is the faithful schedule).

use crate::linalg::scale;
use crate::quant::VectorCodec;
use crate::rng::{hash2, Rng};
use crate::sim::{Cluster, Traffic};

/// Result of one tree-topology MeanEstimation round.
#[derive(Clone, Debug)]
pub struct TreeOutcome {
    pub outputs: Vec<Vec<f64>>,
    pub traffic: Vec<Traffic>,
    /// The sampled leaf set T.
    pub leaves: Vec<usize>,
    /// Effective quantizer parameters used (s-side, q-colors).
    pub q_used: u32,
}

impl TreeOutcome {
    pub fn estimate(&self) -> &[f64] {
        debug_assert!(self.outputs.iter().all(|o| o == &self.outputs[0]));
        &self.outputs[0]
    }
}

/// Tree quantizer parameters for a given `m` (paper: ε=y/m², q=m³).
/// Returns (side, colors): side = 2·y/m², colors = min(m³, 2²⁰).
pub fn tree_params(m: usize, y: f64) -> (f64, u32) {
    let m = m.max(2) as f64;
    let side = 2.0 * y / (m * m);
    let q = (m * m * m).min((1u64 << 20) as f64) as u32;
    (side.max(f64::MIN_POSITIVE), q.max(4))
}

/// Run Algorithm 4 with sample size `m`.
pub fn mean_estimation_tree(
    inputs: &[Vec<f64>],
    m: usize,
    y: f64,
    seed: u64,
    round: u64,
) -> TreeOutcome {
    let n = inputs.len();
    assert!(n >= 1);
    let d = inputs[0].len();
    let mut shared = Rng::new(hash2(seed, round ^ 0x7EEE));
    let m_eff = m.min(n).next_power_of_two().min(n.next_power_of_two());
    // Sample T uniformly (if m >= n, T = all machines).
    let leaves: Vec<usize> = if m_eff >= n {
        (0..n).collect()
    } else {
        shared.sample_indices(n, m_eff)
    };
    let _n_leaves = leaves.len();
    let (side, q) = tree_params(m.max(2), y);

    // Build one shared-lattice codec (same (seed,round) ⇒ same offset).
    let make_codec = || {
        let mut sr = Rng::new(hash2(seed, round));
        crate::quant::LatticeQuantizer::new(
            crate::quant::CubicLattice::random_offset(d, side, &mut sr),
            q,
        )
    };

    if n == 1 {
        return TreeOutcome {
            outputs: vec![inputs[0].clone()],
            traffic: vec![Traffic::default()],
            leaves,
            q_used: q,
        };
    }

    let cluster = Cluster::new(n);
    let mut eps = cluster.endpoints();

    // --- Upward pass over a complete binary tree with `n_leaves` leaves.
    // Level 0: the sampled leaves' own inputs. Internal node j at level l
    // is played by machine role_of(l, j) (round-robin over all machines).
    let role_of = |level: usize, j: usize| -> usize { (j * 2 + level * 3) % n };
    let mut estimates: Vec<Vec<f64>> = leaves.iter().map(|&v| inputs[v].clone()).collect();
    let mut owners: Vec<usize> = leaves.clone();
    let mut level = 0usize;
    while estimates.len() > 1 {
        level += 1;
        let mut next_est = Vec::with_capacity(estimates.len() / 2);
        let mut next_own = Vec::with_capacity(estimates.len() / 2);
        for j in 0..estimates.len() / 2 {
            let parent = role_of(level, j);
            // Children send their quantized estimates to the parent.
            let mut decoded = Vec::with_capacity(2);
            for c in 0..2 {
                let child_idx = 2 * j + c;
                let child = owners[child_idx];
                let codec = make_codec();
                let (msg, _pt) = codec.encode_with_point(&estimates[child_idx]);
                if child != parent {
                    eps[child].send(parent, msg.clone());
                    let p = {
                        let mut stash = Vec::new();
                        eps[parent].recv_from(child, &mut stash)
                    };
                    decoded.push(codec.decode(&p.msg, &inputs[parent]));
                } else {
                    // Same machine plays both roles: no wire cost.
                    decoded.push(codec.decode(&msg, &inputs[parent]));
                }
            }
            let avg = scale(&crate::linalg::add(&decoded[0], &decoded[1]), 0.5);
            next_est.push(avg);
            next_own.push(parent);
        }
        if estimates.len() % 2 == 1 {
            // Odd node passes through unchanged.
            next_est.push(estimates.last().unwrap().clone());
            next_own.push(*owners.last().unwrap());
        }
        estimates = next_est;
        owners = next_own;
    }
    let root_est = estimates.pop().unwrap();
    let root = owners.pop().unwrap();

    // --- Downward broadcast over a binary tree rooted at `root` covering
    // all machines; everyone relays the identical message.
    let codec = make_codec();
    let (bmsg, _pt) = codec.encode_with_point(&root_est);
    // BFS order: machine ids re-indexed so root is position 0.
    let order: Vec<usize> = (0..n).map(|i| (root + i) % n).collect();
    for pos in 0..n {
        let me = order[pos];
        let c1 = 2 * pos + 1;
        let c2 = 2 * pos + 2;
        for c in [c1, c2] {
            if c < n {
                eps[me].send(order[c], bmsg.clone());
                // Receive at the child (sequential schedule).
                let mut stash = Vec::new();
                let _ = eps[order[c]].recv_from(me, &mut stash);
            }
        }
    }
    let outputs: Vec<Vec<f64>> = (0..n).map(|v| codec.decode(&bmsg, &inputs[v])).collect();

    TreeOutcome {
        outputs,
        traffic: cluster.traffic(),
        leaves,
        q_used: q,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dist_inf, mean_vecs};

    fn gen_inputs(n: usize, d: usize, center: f64, spread: f64, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                (0..d)
                    .map(|_| center + rng.uniform(-spread, spread))
                    .collect()
            })
            .collect()
    }

    #[test]
    fn agreement_and_accuracy_full_sample() {
        let n = 8;
        let inputs = gen_inputs(n, 16, 50.0, 0.5, 1);
        let y = 1.2;
        let out = mean_estimation_tree(&inputs, n, y, 2, 0);
        for o in &out.outputs {
            assert_eq!(o, &out.outputs[0]);
        }
        let mu = mean_vecs(&inputs);
        // Lemma 18: error ≤ O(y log m / m²) — generous envelope here.
        let m = n as f64;
        let bound = 10.0 * y * (m.log2() + 1.0) / (m * m);
        assert!(
            dist_inf(out.estimate(), &mu) <= bound,
            "err {} bound {}",
            dist_inf(out.estimate(), &mu),
            bound
        );
    }

    #[test]
    fn subsample_unbiased_over_rounds() {
        // With m < n the sample mean is an unbiased estimator of μ.
        let n = 16;
        let d = 4;
        let inputs = gen_inputs(n, d, 0.0, 1.0, 3);
        let mu = mean_vecs(&inputs);
        let mut acc = vec![0.0; d];
        let rounds = 400;
        for r in 0..rounds {
            let out = mean_estimation_tree(&inputs, 4, 2.5, 5, r);
            crate::linalg::axpy(&mut acc, 1.0, out.estimate());
        }
        for (a, m) in acc.iter().zip(&mu) {
            let mean = a / rounds as f64;
            assert!((mean - m).abs() < 0.15, "{mean} vs {m}");
        }
    }

    #[test]
    fn per_machine_bits_bounded() {
        // Worst-case guarantee: every machine sends/receives O(d log q)
        // per upward role (O(1) roles) + 2 broadcast messages.
        let n = 16;
        let d = 32;
        let inputs = gen_inputs(n, d, 0.0, 1.0, 7);
        let out = mean_estimation_tree(&inputs, n, 2.5, 8, 0);
        let msg_bits = d as u64 * crate::quant::bits::width_for(out.q_used as u64) as u64;
        let cap = 8 * msg_bits; // O(1) roles × O(d log q)
        for t in &out.traffic {
            assert!(t.sent_bits <= cap, "sent {} > cap {}", t.sent_bits, cap);
            assert!(t.recv_bits <= cap, "recv {} > cap {}", t.recv_bits, cap);
        }
    }

    #[test]
    fn tree_params_formula() {
        let (s, q) = tree_params(8, 1.0);
        assert!((s - 2.0 / 64.0).abs() < 1e-12);
        assert_eq!(q, 512);
    }

    #[test]
    fn odd_machine_counts_work() {
        for n in [3, 5, 7, 9] {
            let inputs = gen_inputs(n, 8, 10.0, 0.2, n as u64);
            let out = mean_estimation_tree(&inputs, n, 0.5, 9, 0);
            for o in &out.outputs {
                assert_eq!(o, &out.outputs[0]);
            }
        }
    }
}
