//! Algorithm 4 — tree-topology MeanEstimation(m).
//!
//! Worst-case (not just expected) per-machine communication: sample
//! `min(m, n)` machines as leaves of a complete binary tree, average
//! upward with re-quantization at every internal node (parameters
//! `ε = y/m²`, `q = m³` in paper terms — here lattice side `s = 2y/m²`
//! and color count `q = m³` capped for word width), then broadcast the
//! root's estimate down a binary tree over *all* machines, each relaying
//! the identical message.
//!
//! Internal-node roles are assigned to machines round-robin so every
//! machine plays O(1) roles (the paper's requirement). The protocol now
//! executes on the persistent machine threads of
//! [`super::DmeSession`] — every machine derives the full deterministic
//! schedule from shared randomness and runs its own sends/receives —
//! and [`mean_estimation_tree`] is a thin one-round wrapper kept for the
//! legacy API (bit-identical outputs and metering; see
//! `rust/tests/session_parity.rs`).

use super::api::DmeBuilder;
use super::topology::Topology;
use crate::rng::{hash2, Rng};
use crate::sim::Traffic;

/// Result of one tree-topology MeanEstimation round.
#[derive(Clone, Debug)]
pub struct TreeOutcome {
    pub outputs: Vec<Vec<f64>>,
    pub traffic: Vec<Traffic>,
    /// The sampled leaf set T.
    pub leaves: Vec<usize>,
    /// Effective quantizer parameters used (s-side, q-colors).
    pub q_used: u32,
}

impl TreeOutcome {
    pub fn estimate(&self) -> &[f64] {
        debug_assert!(self.outputs.iter().all(|o| o == &self.outputs[0]));
        &self.outputs[0]
    }
}

/// Tree quantizer parameters for a given `m` (paper: ε=y/m², q=m³).
/// Returns (side, colors): side = 2·y/m², colors = min(m³, 2²⁰).
pub fn tree_params(m: usize, y: f64) -> (f64, u32) {
    let m = m.max(2) as f64;
    let side = 2.0 * y / (m * m);
    let q = (m * m * m).min((1u64 << 20) as f64) as u32;
    (side.max(f64::MIN_POSITIVE), q.max(4))
}

/// The deterministic per-round schedule every machine (and the session
/// driver) derives from shared randomness: the sampled leaf set plus the
/// quantizer parameters `(leaves, side, q)`.
pub(crate) fn tree_round_schedule(
    n: usize,
    m: usize,
    y: f64,
    seed: u64,
    round: u64,
) -> (Vec<usize>, f64, u32) {
    let mut shared = Rng::new(hash2(seed, round ^ 0x7EEE));
    let m_eff = m.min(n).next_power_of_two().min(n.next_power_of_two());
    // Sample T uniformly (if m >= n, T = all machines).
    let leaves: Vec<usize> = if m_eff >= n {
        (0..n).collect()
    } else {
        shared.sample_indices(n, m_eff)
    };
    let (side, q) = tree_params(m.max(2), y);
    (leaves, side, q)
}

/// Run Algorithm 4 with sample size `m` — legacy one-round entry point;
/// new code should hold a [`DmeBuilder`]-built session across rounds.
pub fn mean_estimation_tree(
    inputs: &[Vec<f64>],
    m: usize,
    y: f64,
    seed: u64,
    round: u64,
) -> TreeOutcome {
    let n = inputs.len();
    assert!(n >= 1);
    let d = inputs[0].len();
    let mut sess = DmeBuilder::new(n, d)
        .topology(Topology::Tree { m })
        .seed(seed)
        .diagnostics(true)
        .build();
    sess.set_round(round);
    let out = sess.round_with_y(inputs, y);
    TreeOutcome {
        outputs: out.outputs,
        traffic: out.round_traffic,
        leaves: out.leaves,
        q_used: out.q_used.expect("tree round reports q"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dist_inf, mean_vecs};

    fn gen_inputs(n: usize, d: usize, center: f64, spread: f64, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                (0..d)
                    .map(|_| center + rng.uniform(-spread, spread))
                    .collect()
            })
            .collect()
    }

    #[test]
    fn agreement_and_accuracy_full_sample() {
        let n = 8;
        let inputs = gen_inputs(n, 16, 50.0, 0.5, 1);
        let y = 1.2;
        let out = mean_estimation_tree(&inputs, n, y, 2, 0);
        for o in &out.outputs {
            assert_eq!(o, &out.outputs[0]);
        }
        let mu = mean_vecs(&inputs);
        // Lemma 18: error ≤ O(y log m / m²) — generous envelope here.
        let m = n as f64;
        let bound = 10.0 * y * (m.log2() + 1.0) / (m * m);
        assert!(
            dist_inf(out.estimate(), &mu) <= bound,
            "err {} bound {}",
            dist_inf(out.estimate(), &mu),
            bound
        );
    }

    #[test]
    fn subsample_unbiased_over_rounds() {
        // With m < n the sample mean is an unbiased estimator of μ.
        let n = 16;
        let d = 4;
        let inputs = gen_inputs(n, d, 0.0, 1.0, 3);
        let mu = mean_vecs(&inputs);
        let mut acc = vec![0.0; d];
        let rounds = 400;
        for r in 0..rounds {
            let out = mean_estimation_tree(&inputs, 4, 2.5, 5, r);
            crate::linalg::axpy(&mut acc, 1.0, out.estimate());
        }
        for (a, m) in acc.iter().zip(&mu) {
            let mean = a / rounds as f64;
            assert!((mean - m).abs() < 0.15, "{mean} vs {m}");
        }
    }

    #[test]
    fn per_machine_bits_bounded() {
        // Worst-case guarantee: every machine sends/receives O(d log q)
        // per upward role (O(1) roles) + 2 broadcast messages.
        let n = 16;
        let d = 32;
        let inputs = gen_inputs(n, d, 0.0, 1.0, 7);
        let out = mean_estimation_tree(&inputs, n, 2.5, 8, 0);
        let msg_bits = d as u64 * crate::quant::bits::width_for(out.q_used as u64) as u64;
        let cap = 8 * msg_bits; // O(1) roles × O(d log q)
        for t in &out.traffic {
            assert!(t.sent_bits <= cap, "sent {} > cap {}", t.sent_bits, cap);
            assert!(t.recv_bits <= cap, "recv {} > cap {}", t.recv_bits, cap);
        }
    }

    #[test]
    fn tree_params_formula() {
        let (s, q) = tree_params(8, 1.0);
        assert!((s - 2.0 / 64.0).abs() < 1e-12);
        assert_eq!(q, 512);
    }

    #[test]
    fn odd_machine_counts_work() {
        for n in [3, 5, 7, 9] {
            let inputs = gen_inputs(n, 8, 10.0, 0.2, n as u64);
            let out = mean_estimation_tree(&inputs, n, 0.5, 9, 0);
            for o in &out.outputs {
                assert_eq!(o, &out.outputs[0]);
            }
        }
    }

    #[test]
    fn single_machine_identity() {
        let inputs = gen_inputs(1, 8, 5.0, 0.1, 10);
        let out = mean_estimation_tree(&inputs, 1, 1.0, 11, 0);
        assert_eq!(out.estimate(), &inputs[0][..]);
        assert_eq!(out.traffic, vec![Traffic::default()]);
    }
}
