//! Algorithm 3 — star-topology MeanEstimation.
//!
//! A leader is chosen from shared randomness; every other machine sends
//! its quantized input to the leader, which decodes against its own
//! input, averages (including its own input), re-encodes the average and
//! broadcasts it; all machines decode against their own inputs and
//! output. Expected per-machine cost is `O(d log q)` bits (Theorem 16)
//! because the `O(nd log q)` leader role is uniformly random.
//!
//! The protocol runs on the persistent machine threads of
//! [`super::DmeSession`] and works for *any* [`CodecSpec`]; for
//! reference-free baselines it degenerates to quantized gather +
//! broadcast, which is exactly how the paper's Experiment 5 runs them.
//! [`mean_estimation_star`] is the legacy one-round entry point, kept as
//! a thin wrapper over a one-round session (bit-identical outputs and
//! metering; see `rust/tests/session_parity.rs`).

use super::api::DmeBuilder;
use super::CodecSpec;
use crate::sim::Traffic;

/// Result of one star-topology MeanEstimation round.
#[derive(Clone, Debug)]
pub struct StarOutcome {
    /// Every machine's output (the agreement invariant: all equal).
    pub outputs: Vec<Vec<f64>>,
    /// The leader's decoded per-worker estimates (diagnostics: lets
    /// experiments compute per-input quantization error and maintain the
    /// `y` estimate from quantized points as in §9.2).
    pub decoded_at_leader: Vec<Vec<f64>>,
    pub traffic: Vec<Traffic>,
    pub leader: usize,
}

impl StarOutcome {
    /// The common output (asserts agreement in debug builds).
    pub fn estimate(&self) -> &[f64] {
        debug_assert!(self
            .outputs
            .iter()
            .all(|o| o == &self.outputs[0]));
        &self.outputs[0]
    }
}

/// Run one MeanEstimation round over the star topology — legacy one-round
/// entry point; new code should hold a [`DmeBuilder`]-built session
/// across rounds.
///
/// * `inputs[v]` — machine v's vector (all of equal dimension `d`).
/// * `spec`, `y` — compressor and its distance-bound parameter (for RLQ,
///   `y` is the rotated-space bound).
/// * `seed`, `round` — derive the leader and all shared randomness.
pub fn mean_estimation_star(
    inputs: &[Vec<f64>],
    spec: &CodecSpec,
    y: f64,
    seed: u64,
    round: u64,
) -> StarOutcome {
    let n = inputs.len();
    assert!(n >= 1);
    let d = inputs[0].len();
    let mut sess = DmeBuilder::new(n, d)
        .codec(*spec)
        .seed(seed)
        .diagnostics(true)
        .build();
    sess.set_round(round);
    let out = sess.round_with_y(inputs, y);
    StarOutcome {
        outputs: out.outputs,
        decoded_at_leader: out.decoded_at_leader,
        traffic: out.round_traffic,
        leader: out.leader.expect("star round reports a leader"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dist2, dist_inf, mean_vecs};
    use crate::rng::Rng;

    fn gen_inputs(n: usize, d: usize, center: f64, spread: f64, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                (0..d)
                    .map(|_| center + rng.uniform(-spread, spread))
                    .collect()
            })
            .collect()
    }

    #[test]
    fn all_machines_agree_lq() {
        let inputs = gen_inputs(8, 32, 100.0, 0.5, 1);
        let out = mean_estimation_star(&inputs, &CodecSpec::Lq { q: 16 }, 1.5, 7, 0);
        for o in &out.outputs {
            assert_eq!(o, &out.outputs[0], "agreement violated");
        }
    }

    #[test]
    fn lq_estimate_close_to_mean_despite_large_norm() {
        // Inputs centered at 1000 (huge norm, tiny spread): the lattice
        // scheme's error depends only on spread — the paper's headline.
        let inputs = gen_inputs(4, 64, 1000.0, 0.1, 2);
        let mu = mean_vecs(&inputs);
        let y = 0.3;
        let out = mean_estimation_star(&inputs, &CodecSpec::Lq { q: 16 }, y, 3, 0);
        let s = 2.0 * y / 15.0;
        // decode error ≤ s/2 per stage, two stages + averaging.
        assert!(
            dist_inf(out.estimate(), &mu) <= 1.5 * s,
            "err {} vs s {}",
            dist_inf(out.estimate(), &mu),
            s
        );
    }

    #[test]
    fn qsgd_estimate_much_worse_at_large_center() {
        // Sanity for the paper's claim: at equal bits QSGD error scales
        // with the norm (center), LQSGD with the spread.
        let inputs = gen_inputs(4, 64, 1000.0, 0.1, 4);
        let mu = mean_vecs(&inputs);
        let lq = mean_estimation_star(&inputs, &CodecSpec::Lq { q: 8 }, 0.3, 5, 0);
        let qs = mean_estimation_star(&inputs, &CodecSpec::QsgdL2 { q: 8 }, 0.3, 5, 0);
        let e_lq = dist2(lq.estimate(), &mu);
        let e_qs = dist2(qs.estimate(), &mu);
        assert!(
            e_lq * 10.0 < e_qs,
            "LQ {e_lq} should beat QSGD {e_qs} by >10x here"
        );
    }

    #[test]
    fn traffic_matches_formula() {
        let n = 6;
        let d = 32;
        let q = 16u32;
        let inputs = gen_inputs(n, d, 0.0, 1.0, 6);
        let out = mean_estimation_star(&inputs, &CodecSpec::Lq { q }, 2.5, 8, 0);
        let msg_bits = d as u64 * 4; // log2(16)
        let t = &out.traffic;
        for v in 0..n {
            if v == out.leader {
                assert_eq!(t[v].recv_bits, (n as u64 - 1) * msg_bits);
                assert_eq!(t[v].sent_bits, (n as u64 - 1) * msg_bits);
            } else {
                assert_eq!(t[v].sent_bits, msg_bits);
                assert_eq!(t[v].recv_bits, msg_bits);
            }
        }
    }

    #[test]
    fn leader_uniform_over_rounds() {
        let inputs = gen_inputs(5, 4, 0.0, 1.0, 9);
        let mut counts = [0usize; 5];
        for round in 0..200 {
            let out = mean_estimation_star(&inputs, &CodecSpec::Full, 1.0, 10, round);
            counts[out.leader] += 1;
        }
        for c in counts {
            assert!(c > 15, "leader distribution too skewed: {counts:?}");
        }
    }

    #[test]
    fn single_machine_identity() {
        let inputs = gen_inputs(1, 8, 5.0, 0.1, 10);
        let out = mean_estimation_star(&inputs, &CodecSpec::Lq { q: 8 }, 1.0, 11, 0);
        assert_eq!(out.estimate(), &inputs[0][..]);
    }
}
