//! Layer-3 coordinator — the paper's distributed algorithms behind one
//! session-oriented API.
//!
//! **Primary entry point:** [`DmeBuilder`] → [`DmeSession`] (module
//! [`api`]). The builder fixes `n`, `d`, the [`Topology`] (star or
//! binary tree), the [`CodecSpec`], the `y`-maintenance [`YPolicy`] and
//! the VR [`Robustness`]; the session keeps the cluster threads alive
//! across rounds (the §9 deployment pattern: thousands of rounds over
//! the same machines) and reports every protocol through one
//! [`RoundOutcome`].
//!
//! Protocol modules:
//!
//! * [`api`] — the `DmeBuilder`/`DmeSession` pair and `RoundOutcome`.
//!   Leader aggregation is a streaming fold: packets are decoded and
//!   accumulated in one fused pass per packet
//!   (`VectorCodec::decode_accumulate_into`) at O(d) leader memory, with
//!   the O(n·d) decoded collection surviving only behind diagnostics /
//!   `y`-policy measurement rounds. Sessions built with
//!   [`DmeBuilder::fault_plan`] run k-of-n partial rounds under a
//!   [`StragglerPolicy`] (`DmeSession::round_partial` — see api's
//!   §Straggler policy).
//! * [`fold`] — the fold kernels as free functions: sequential
//!   [`fold_mean`] plus the chunk-sharded parallel [`fold_mean_chunked`]
//!   for batch aggregation of very wide vectors.
//! * [`topology`] — star vs binary-tree layout selection.
//! * [`star`] — Algorithm 3: two-round MeanEstimation through a randomly
//!   chosen leader (expected-cost bounds, Theorem 16).
//! * [`tree`] — Algorithm 4: binary-tree MeanEstimation with worst-case
//!   per-machine bounds (Theorem 2).
//! * [`variance_reduction`] — the VR reduction (Theorems 17/19) and the
//!   error-detecting Algorithm 6 built on RobustAgreement (Theorem 4).
//! * [`sublinear_me`] — Algorithm 9, the o(d)-bits regime.
//! * [`y_estimator`] — the Section-9 policies for maintaining the input
//!   variance estimate `y` across SGD iterations.
//!
//! The historical one-shot free functions ([`mean_estimation_star`],
//! [`mean_estimation_tree`], [`robust_variance_reduction`],
//! [`sublinear_mean_estimation`]) remain as thin wrappers over one-round
//! sessions, bit-identical for the same `(seed, round)` — existing tests
//! and experiments pin that behavior (`rust/tests/session_parity.rs`).
//!
//! All protocols run over [`crate::sim`] with exact bit metering; every
//! round reports the *agreement* invariant (all machines output the same
//! vector) alongside accuracy and traffic.

pub mod api;
pub mod fold;
pub mod session;
pub mod star;
pub mod sublinear_me;
pub mod topology;
pub mod tree;
pub mod variance_reduction;
pub mod y_estimator;

pub use api::{
    star_round_over, star_round_partial_over, tree_partial_reference, vr_round_over,
    vr_round_partial_over, DmeBuilder, DmeSession, PartialRoundReport, Robustness, RoundOutcome,
    StarRoundReport, StragglerPolicy, TreePartialReference,
};
pub use fold::{fold_mean, fold_mean_chunked, fold_mean_chunked_on, FoldPart};
pub use session::{SessionRound, StarSession};
pub use star::{mean_estimation_star, StarOutcome};
pub use sublinear_me::{sublinear_mean_estimation, SublinearOutcome};
pub use topology::Topology;
pub use tree::{mean_estimation_tree, TreeOutcome};
pub use variance_reduction::{
    robust_variance_reduction, variance_reduction_star, vr_y_bound, RobustVrOutcome,
};
pub use y_estimator::{YEstimator, YPolicy};

use crate::quant::baselines::{
    EfSignSgd, FullPrecision, PowerSgd, Qsgd, QsgdNorm, SureshHadamard, TernGrad, TopK,
    VqsgdCrossPolytope,
};
use crate::quant::convex_hull::ConvexHullEncoder;
use crate::quant::{LatticeQuantizer, RotatedLatticeQuantizer, VectorCodec};
use crate::rng::{hash2, Rng};

/// Which compressor a protocol round should use.
///
/// `build` derives all *shared* randomness (lattice offset, rotation
/// diagonal) deterministically from `(seed, round)`, so every machine
/// constructs an identical codec without extra communication — exactly
/// the shared-randomness assumption of Section 9.1. Stateful codecs
/// (EF-SignSGD, PowerSGD, Top-K) carry error memory across rounds and
/// must be built once per machine and reused; `CodecSpec::build` gives a
/// fresh instance (drivers for those keep it alive across rounds).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CodecSpec {
    /// LQSGD — the paper's practical cubic-lattice scheme (§9.1).
    Lq { q: u32 },
    /// RLQSGD — LQSGD after the Walsh–Hadamard rotation (§6). `y` passed
    /// to `build` must be the *rotated-space* ℓ∞ bound `y_R`.
    Rlq { q: u32 },
    /// Algorithm-1 stochastic rounding variant (no shared offset).
    LqHull { q: u32 },
    /// D4 checkerboard lattice, bucketed by 4 (§6 future work; saves one
    /// bit per bucket via the parity-implied color LSB). d % 4 == 0.
    D4 { q: u32 },
    QsgdL2 { q: u32 },
    QsgdLinf { q: u32 },
    Hadamard { q: u32 },
    Vqsgd { reps: u32 },
    EfSign,
    PowerSgd { rank: usize },
    TernGrad,
    TopK { k: usize },
    Full,
}

impl CodecSpec {
    /// Instantiate for dimension `d`, distance bound `y`, at a round seed.
    pub fn build(&self, d: usize, y: f64, seed: u64, round: u64) -> Box<dyn VectorCodec> {
        self.build_with(d, y, &mut Rng::new(hash2(seed, round)))
    }

    /// Instantiate from an explicit shared-randomness stream — the batch
    /// round plane derives all per-slot streams in one
    /// [`crate::rng::fork_round_seeds`] fan-out per batch and then builds
    /// each slot's codec from its stream, bit-identically to
    /// [`Self::build`] at the matching `(seed, round)`.
    pub fn build_with(&self, d: usize, y: f64, shared: &mut Rng) -> Box<dyn VectorCodec> {
        match *self {
            CodecSpec::Lq { q } => Box::new(LatticeQuantizer::from_y(d, q, y, shared)),
            CodecSpec::Rlq { q } => {
                Box::new(RotatedLatticeQuantizer::from_y_rot(d, q, y, shared))
            }
            CodecSpec::LqHull { q } => Box::new(ConvexHullEncoder::from_y(d, q, y)),
            CodecSpec::D4 { q } => {
                Box::new(crate::quant::D4Quantizer::from_y(d, q, y, shared))
            }
            CodecSpec::QsgdL2 { q } => Box::new(Qsgd::new(d, q, QsgdNorm::L2)),
            CodecSpec::QsgdLinf { q } => Box::new(Qsgd::new(d, q, QsgdNorm::Linf)),
            CodecSpec::Hadamard { q } => Box::new(SureshHadamard::new(d, q, shared)),
            CodecSpec::Vqsgd { reps } => Box::new(VqsgdCrossPolytope::new(d, reps)),
            CodecSpec::EfSign => Box::new(EfSignSgd::new(d)),
            CodecSpec::PowerSgd { rank } => Box::new(PowerSgd::for_dim(d, rank, shared)),
            CodecSpec::TernGrad => Box::new(TernGrad::new(d)),
            CodecSpec::TopK { k } => Box::new(TopK::new(d, k)),
            CodecSpec::Full => Box::new(FullPrecision::new(d)),
        }
    }

    /// Short label for tables.
    pub fn label(&self) -> String {
        match *self {
            CodecSpec::Lq { q } => format!("LQSGD(q={q})"),
            CodecSpec::Rlq { q } => format!("RLQSGD(q={q})"),
            CodecSpec::LqHull { q } => format!("LQ-hull(q={q})"),
            CodecSpec::D4 { q } => format!("D4LQ(q={q})"),
            CodecSpec::QsgdL2 { q } => format!("QSGD-L2(q={q})"),
            CodecSpec::QsgdLinf { q } => format!("QSGD-Linf(q={q})"),
            CodecSpec::Hadamard { q } => format!("Hadamard(q={q})"),
            CodecSpec::Vqsgd { reps } => format!("vQSGD(R={reps})"),
            CodecSpec::EfSign => "EF-SignSGD".into(),
            CodecSpec::PowerSgd { rank } => format!("PowerSGD(r={rank})"),
            CodecSpec::TernGrad => "TernGrad".into(),
            CodecSpec::TopK { k } => format!("TopK(k={k})"),
            CodecSpec::Full => "full32".into(),
        }
    }

    /// Whether the codec keeps cross-round state (drivers must then reuse
    /// one instance instead of rebuilding each round).
    pub fn is_stateful(&self) -> bool {
        matches!(
            self,
            CodecSpec::EfSign | CodecSpec::PowerSgd { .. } | CodecSpec::TopK { .. }
        )
    }
}
