//! Shared persistent worker pool — threads spawned once, parked between
//! jobs, reused by every parallel entry point in the crate.
//!
//! # §Perf — why a pool
//!
//! Before this module, all three parallel call sites paid a full thread
//! spawn + join per call: [`crate::quant::encode_chunked`] and
//! [`crate::coordinator::fold_mean_chunked`] spawned scoped threads per
//! invocation, and [`crate::sim::Cluster::run`] / the
//! `DmeSession` workers spawned one OS thread per machine per cluster
//! construction. A spawn costs ~20 µs — an order of magnitude more than
//! the quantization work itself at small `d`, which erased the
//! chunk-parallel win exactly where the paper's comparison lives
//! (Suresh et al.'s Hadamard baseline, per-layer gradients). The pool
//! spawns threads once at first use and parks them between jobs, so the
//! steady-state cost of a parallel call is a channel send + a condvar
//! wait.
//!
//! Two layers, matching the two call-site shapes:
//!
//! * [`ChunkPool`] — a **fixed-size** pool for short, CPU-bound shard
//!   jobs (encode/fold chunks). Handoff is a fixed per-worker queue:
//!   task `i` of a call always goes to worker `i mod size` — no work
//!   stealing, so the shard→worker assignment is deterministic. (Shards
//!   write disjoint output slots, so results are bit-identical to the
//!   sequential reference *regardless* of scheduling; determinism here
//!   removes even scheduling jitter from the equation and is pinned by
//!   the pool prop tests.) Shard jobs must never block on each other:
//!   workers run jobs to completion in queue order. A job that itself
//!   calls [`ChunkPool::run_sharded`] runs its tasks inline (detected
//!   via a thread-local), so nesting cannot deadlock the pool.
//! * [`lease`] — a **growable** thread cache for long-lived,
//!   possibly-blocking jobs (the per-machine protocol workers in
//!   [`crate::sim`] and `coordinator::api`, which block on each other's
//!   messages and therefore must each own a thread). A lease pops an
//!   idle parked thread or spawns a new one; when the job finishes the
//!   thread parks itself back on the idle stack. Spawn failure surfaces
//!   as `io::Error` (not a panic) so [`crate::sim::Cluster::try_run`]
//!   can report it as a typed `TransportError`.
//!
//! [`threads`] caches `available_parallelism()` once — callers that used
//! to query it per call now read a `OnceLock`.
//!
//! Everything here is scheduling only: no pool path touches the wire
//! arithmetic, and the chunked entry points stay bit-identical to their
//! sequential references (pinned by `rust/tests/prop.rs`).

use std::cell::Cell;
use std::io;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, SendError};
use std::sync::{Condvar, Mutex, OnceLock};

/// Cached `available_parallelism()` — queried from the OS exactly once
/// per process (the chunked entry points used to ask on every call).
pub fn threads() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// A pool job: erased to `'static` at the dispatch boundary. Jobs built
/// from borrowing closures are transmuted to this type; soundness is the
/// caller's latch (see [`ChunkPool::run_sharded`]).
type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// Set on chunk-pool worker threads: a nested `run_sharded` from a
    /// worker runs inline instead of re-dispatching (a worker waiting on
    /// its own pool could deadlock it).
    static IN_CHUNK_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Count-up completion latch: each finished job arrives, the dispatcher
/// waits for the number it actually managed to dispatch.
struct Latch {
    done: Mutex<usize>,
    cv: Condvar,
}

impl Latch {
    fn new() -> Self {
        Latch {
            done: Mutex::new(0),
            cv: Condvar::new(),
        }
    }

    fn arrive(&self) {
        let mut done = self.done.lock().unwrap_or_else(|e| e.into_inner());
        *done += 1;
        self.cv.notify_all();
    }

    fn wait(&self, target: usize) {
        let mut done = self.done.lock().unwrap_or_else(|e| e.into_inner());
        while *done < target {
            done = self.cv.wait(done).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// Fixed-size persistent pool for short CPU-bound shard jobs.
///
/// Workers are spawned in the constructor and park in `recv` between
/// jobs. Dispatch is a fixed chunk-queue handoff — task `i` always goes
/// to worker `i mod size`, no stealing — so assignment is deterministic
/// across calls. See the module docs for the blocking contract (shard
/// jobs must not wait on each other; nested dispatch runs inline).
pub struct ChunkPool {
    queues: Vec<Sender<Job>>,
}

impl ChunkPool {
    /// Spawn a pool of `size.max(1)` parked workers. The process-wide
    /// instance most callers want is [`ChunkPool::global`]; private
    /// pools exist for tests (pool-size determinism) and benches.
    pub fn new(size: usize) -> Self {
        let queues = (0..size.max(1))
            .map(|i| {
                let (tx, rx) = channel::<Job>();
                std::thread::Builder::new()
                    .name(format!("dme-chunk-{i}"))
                    .spawn(move || {
                        IN_CHUNK_WORKER.with(|c| c.set(true));
                        while let Ok(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("spawn chunk-pool worker");
                tx
            })
            .collect();
        ChunkPool { queues }
    }

    /// The shared process-wide pool, sized [`threads()`], spawned on
    /// first use and kept for the life of the process.
    pub fn global() -> &'static ChunkPool {
        static POOL: OnceLock<ChunkPool> = OnceLock::new();
        POOL.get_or_init(|| ChunkPool::new(threads()))
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.queues.len()
    }

    /// Run every task to completion and return their results in task
    /// order. Task `i` runs on worker `i mod size`; a single task (or a
    /// call from inside a pool worker) runs inline on the caller. Panics
    /// in a task are caught on the worker (which survives) and resumed
    /// on the caller, first panicking task first.
    pub fn run_sharded<T, F>(&self, tasks: Vec<F>) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        if tasks.len() <= 1 || IN_CHUNK_WORKER.with(|c| c.get()) {
            return tasks.into_iter().map(|t| t()).collect();
        }
        let n = tasks.len();
        let k = self.queues.len();
        let latch = Latch::new();
        let mut slots: Vec<Option<std::thread::Result<T>>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        let mut dispatched = 0usize;
        let mut queue_gone = false;
        for (i, (task, slot)) in tasks.into_iter().zip(slots.iter_mut()).enumerate() {
            let latch = &latch;
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                *slot = Some(catch_unwind(AssertUnwindSafe(task)));
                latch.arrive();
            });
            // SAFETY: the job borrows `slots` and `latch`, both of which
            // outlive every dispatched job: `latch.wait(dispatched)`
            // below blocks until each dispatched job has run to
            // completion (`arrive` is the job's final action), and
            // workers run every job they receive exactly once — they
            // only exit when the pool (holding the senders) is dropped.
            // A job that fails to send is dropped here without running
            // (its borrows die immediately; its slot stays `None`).
            let job: Job = unsafe { std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(job) };
            if self.queues[i % k].send(job).is_err() {
                queue_gone = true;
                break;
            }
            dispatched += 1;
        }
        latch.wait(dispatched);
        assert!(
            !queue_gone,
            "chunk-pool worker exited while the pool was alive"
        );
        slots
            .into_iter()
            .map(|slot| match slot.expect("dispatched shard completed") {
                Ok(v) => v,
                Err(panic) => resume_unwind(panic),
            })
            .collect()
    }
}

/// Idle parked machine threads, most-recently-parked first. Each entry
/// is the sender side of a parked worker's job queue.
static IDLE: Mutex<Vec<Sender<Job>>> = Mutex::new(Vec::new());
/// Total machine threads ever spawned by [`lease`] (never shrinks —
/// threads park rather than exit).
static SPAWNED: AtomicUsize = AtomicUsize::new(0);

/// Machine threads ever spawned by the lease layer (stats/tests; the
/// pool never shrinks, so `spawned - idle` threads are on lease).
pub fn spawned_workers() -> usize {
    SPAWNED.load(Ordering::Relaxed)
}

/// Machine threads currently parked and reusable by [`lease`].
pub fn idle_workers() -> usize {
    IDLE.lock().unwrap_or_else(|e| e.into_inner()).len()
}

/// A handle to a job running on a leased pool thread — the pool
/// counterpart of `std::thread::JoinHandle`.
pub struct Lease<T> {
    rx: Receiver<std::thread::Result<T>>,
}

impl<T> Lease<T> {
    /// Wait for the job to finish. `Err` carries the job's panic payload
    /// (the leased thread itself survives and returns to the pool).
    pub fn join(self) -> std::thread::Result<T> {
        match self.rx.recv() {
            Ok(r) => r,
            // Unreachable in practice: the worker always sends a result
            // (panics are caught inside the job wrapper) — but a
            // defensive arm beats a poisoned unwrap.
            Err(gone) => Err(Box::new(gone)),
        }
    }
}

/// Run `f` on a pooled thread: pops an idle parked worker or, when none
/// is available, spawns a new one (the pool grows on demand — machine
/// jobs may block on each other, so a fixed-size pool could deadlock a
/// cluster larger than the pool). The thread parks itself back on the
/// idle stack when `f` returns.
///
/// Spawn failure (thread exhaustion) is returned as `io::Error` rather
/// than panicking — [`crate::sim::Cluster::try_run`] maps it to a typed
/// `TransportError`, and the never-run job's captured endpoint is
/// dropped, so surviving machines observe the dead peer as `PeerClosed`
/// instead of hanging.
pub fn lease<T, F>(f: F) -> io::Result<Lease<T>>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let (rtx, rrx) = channel();
    let mut job: Job = Box::new(move || {
        let _ = rtx.send(catch_unwind(AssertUnwindSafe(f)));
    });
    // Reuse a parked worker if any. A worker whose channel has closed
    // (impossible today — workers never drop their own sender — but
    // cheap to tolerate) is discarded and the next one tried.
    loop {
        let idle = IDLE.lock().unwrap_or_else(|e| e.into_inner()).pop();
        let Some(tx) = idle else { break };
        match tx.send(job) {
            Ok(()) => return Ok(Lease { rx: rrx }),
            Err(SendError(j)) => job = j,
        }
    }
    let (wtx, wrx) = channel::<Job>();
    let idx = SPAWNED.fetch_add(1, Ordering::Relaxed);
    let self_tx = wtx.clone();
    std::thread::Builder::new()
        .name(format!("dme-pool-{idx}"))
        .spawn(move || {
            while let Ok(job) = wrx.recv() {
                job();
                // Park: re-register only after the job fully finished,
                // so a leased thread is never handed a second job while
                // the first could still block (machine jobs wait on each
                // other; queuing behind one would deadlock the cluster).
                IDLE.lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .push(self_tx.clone());
            }
        })?;
    wtx.send(job).expect("freshly spawned pool worker receives");
    Ok(Lease { rx: rrx })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    #[test]
    fn run_sharded_returns_results_in_task_order() {
        let pool = ChunkPool::new(3);
        for _ in 0..4 {
            let tasks: Vec<_> = (0..17).map(|i| move || i * 10).collect();
            let got = pool.run_sharded(tasks);
            assert_eq!(got, (0..17).map(|i| i * 10).collect::<Vec<_>>());
        }
    }

    #[test]
    fn run_sharded_is_deterministic_across_pool_sizes() {
        let expect: Vec<u64> = (0..40u64).map(|i| i.wrapping_mul(0x9E3779B9)).collect();
        for size in [1, 2, 5, 16] {
            let pool = ChunkPool::new(size);
            let tasks: Vec<_> = (0..40u64)
                .map(|i| move || i.wrapping_mul(0x9E3779B9))
                .collect();
            assert_eq!(pool.run_sharded(tasks), expect, "size={size}");
        }
    }

    #[test]
    fn nested_run_sharded_runs_inline_without_deadlock() {
        let pool = ChunkPool::global();
        let tasks: Vec<_> = (0..8)
            .map(|i| {
                move || {
                    let inner: Vec<_> = (0..4).map(|j| move || i * 100 + j).collect();
                    pool.run_sharded(inner).iter().sum::<i32>()
                }
            })
            .collect();
        let got = pool.run_sharded(tasks);
        let expect: Vec<i32> = (0..8).map(|i| 4 * i * 100 + 6).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn run_sharded_propagates_first_task_panic() {
        let pool = ChunkPool::new(2);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = vec![
                Box::new(|| 1),
                Box::new(|| panic!("shard boom")),
                Box::new(|| 3),
            ];
            pool.run_sharded(tasks)
        }));
        let payload = result.expect_err("panic must propagate");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "shard boom");
        // The pool survives a panicking shard.
        let again: Vec<fn() -> i32> = vec![|| 7, || 8];
        assert_eq!(pool.run_sharded(again), vec![7, 8]);
    }

    #[test]
    fn lease_runs_jobs_and_reuses_parked_threads() {
        use std::collections::HashSet;
        let l = lease(|| 41 + 1).expect("lease");
        assert_eq!(l.join().expect("job ok"), 42);
        // Reuse is observed via thread identity, not the global counters
        // — other tests in this binary lease concurrently, so exact
        // counter assertions would race. LIFO parking means sequential
        // cycles overwhelmingly land on the same thread; requiring *any*
        // repeat across the cycles keeps the pin interference-tolerant.
        let cycles = 10;
        let mut ids = HashSet::new();
        for _ in 0..cycles {
            let l = lease(|| std::thread::current().id()).expect("lease");
            ids.insert(l.join().expect("job ok"));
            let deadline = Instant::now() + Duration::from_secs(5);
            while idle_workers() == 0 && Instant::now() < deadline {
                std::thread::yield_now();
            }
        }
        assert!(spawned_workers() >= 1);
        assert!(
            ids.len() < cycles,
            "no lease cycle ever reused a parked thread"
        );
    }

    #[test]
    fn lease_join_reports_job_panic_and_thread_survives() {
        let l = lease(|| -> u32 { panic!("machine boom") }).expect("lease");
        let err = l.join().expect_err("panic surfaces in join");
        assert_eq!(err.downcast_ref::<&str>(), Some(&"machine boom"));
        // The pool thread caught the panic and is leasable again.
        let l = lease(|| 5u32).expect("lease");
        assert_eq!(l.join().expect("job ok"), 5);
    }

    #[test]
    fn concurrent_leases_get_dedicated_threads() {
        // n mutually-blocking jobs (a barrier) must each own a thread —
        // the growable layer's reason to exist. With queued handoff this
        // test would deadlock rather than fail.
        use std::sync::{Arc, Barrier};
        let n = 6;
        let barrier = Arc::new(Barrier::new(n));
        let leases: Vec<_> = (0..n)
            .map(|i| {
                let b = barrier.clone();
                lease(move || {
                    b.wait();
                    i
                })
                .expect("lease")
            })
            .collect();
        let mut got: Vec<usize> = leases.into_iter().map(|l| l.join().expect("ok")).collect();
        got.sort_unstable();
        assert_eq!(got, (0..n).collect::<Vec<_>>());
    }
}
