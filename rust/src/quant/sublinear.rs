//! Sublinear-communication quantization (Section 7, Algorithms 7–9).
//!
//! Two components:
//!
//! 1. **Analytic model** ([`SublinearModel`]) — what the paper's own
//!    Experiment 4 evaluates: at a budget of `b` bits total
//!    (`b/d = log₂(1 + 4y/s)` per coordinate), the induced output variance
//!    of the randomly-offset cubic lattice is `d·s²/12`. The paper states
//!    a naive implementation is infeasible at high d and simulates this
//!    model; we reproduce exactly that (and additionally implement the
//!    scheme for small d, below).
//!
//! 2. **Exact small-d implementation** ([`SublinearCodec`]) — Algorithms
//!    7–8 on the cubic lattice under ℓ₂: random offset θ ~ Vor(0), round
//!    `x+θ` to the nearest lattice point `z`, color it with a salted hash
//!    into `(1+2q)^{3d}` colors (the random coloring `ĉ ∘ c_{3+2q}`),
//!    retry with fresh shared randomness until the color of `z` is unique
//!    among lattice points whose *expanded Voronoi region* contains `x+θ`;
//!    the decoder searches lattice points near `x_v + θ` for the matching
//!    color. Enumeration over the `(2⌈q⌉+3)^d` index box restricts this to
//!    small d (the paper's own conclusion) — it exists here to validate
//!    the model's unbiasedness and success probability, not for speed.

use super::Message;
use crate::rng::{hash2, Rng};

/// The analytic bits↔variance model used by Experiment 4.
#[derive(Clone, Copy, Debug)]
pub struct SublinearModel {
    pub d: usize,
    /// ℓ∞ distance bound between encode and decode vectors.
    pub y: f64,
}

impl SublinearModel {
    /// Side length that spends `bits_per_coord` bits per coordinate:
    /// from `log₂(1 + 4y/s) = b/d` ⇒ `s = 4y / (2^{b/d} − 1)`.
    pub fn side_for_bits(&self, bits_per_coord: f64) -> f64 {
        assert!(bits_per_coord > 0.0);
        4.0 * self.y / ((2f64).powf(bits_per_coord) - 1.0)
    }

    /// Output variance (ℓ₂², expectation) of the randomly-offset cubic
    /// lattice at side `s`: each coordinate error is U[−s/2, s/2).
    pub fn variance_for_side(&self, s: f64) -> f64 {
        self.d as f64 * s * s / 12.0
    }

    /// Variance at a bit budget (the quantity plotted in Figs 7–8).
    pub fn variance_for_bits(&self, bits_per_coord: f64) -> f64 {
        self.variance_for_side(self.side_for_bits(bits_per_coord))
    }
}

/// Exact Algorithm 7/8 for small d (≤ ~6).
pub struct SublinearCodec {
    pub d: usize,
    /// Lattice side (`2ε` in paper terms; Voronoi cell = side-s cube).
    pub s: f64,
    /// Sublinear parameter q (may be < 1); colors = ceil((1+2q)^{3d}).
    pub q: f64,
    /// Shared randomness seed (both parties derive θ and the coloring).
    pub seed: u64,
    /// Cap on encode retries.
    pub max_iters: u32,
}

impl SublinearCodec {
    pub fn new(d: usize, s: f64, q: f64, seed: u64) -> Self {
        assert!(d <= 8, "exact sublinear codec is exponential in d");
        assert!(s > 0.0 && q > 0.0);
        SublinearCodec {
            d,
            s,
            q,
            seed,
            max_iters: 64,
        }
    }

    /// Number of colors `(1+2q)^{3d}` (≥ 2) and bits per message.
    pub fn n_colors(&self) -> u64 {
        let c = (1.0 + 2.0 * self.q).powi(3 * self.d as i32).ceil();
        (c as u64).max(2)
    }

    pub fn bits_per_message(&self) -> f64 {
        (self.n_colors() as f64).log2()
    }

    fn theta(&self, iter: u32) -> Vec<f64> {
        let mut r = Rng::new(hash2(self.seed, iter as u64));
        (0..self.d)
            .map(|_| r.uniform(-self.s / 2.0, self.s / 2.0))
            .collect()
    }

    fn color(&self, k: &[i64], iter: u32) -> u64 {
        let mut h = hash2(self.seed, 0xC0105 ^ iter as u64);
        for &ki in k {
            h = hash2(h, ki as u64);
        }
        h % self.n_colors()
    }

    fn nearest(&self, p: &[f64]) -> Vec<i64> {
        p.iter()
            .map(|v| (v / self.s).round_ties_even() as i64)
            .collect()
    }

    fn point(&self, k: &[i64]) -> Vec<f64> {
        k.iter().map(|&ki| ki as f64 * self.s).collect()
    }

    /// Lattice points whose expanded Voronoi region contains `p`:
    /// for the cubic lattice, `Vor⁺(λ)` is the cube of half-side
    /// `s/2 + 2qε = s(1+2q)/2` around λ (ℓ∞ over-approximation of the
    /// ℓ₂ expansion — conservative, so success only improves).
    fn expanded_regions(&self, p: &[f64]) -> Vec<Vec<i64>> {
        let radius = self.s * (1.0 + 2.0 * self.q) / 2.0;
        let lo_hi: Vec<(i64, i64)> = p
            .iter()
            .map(|v| {
                (
                    ((v - radius) / self.s).ceil() as i64,
                    ((v + radius) / self.s).floor() as i64,
                )
            })
            .collect();
        let mut out = Vec::new();
        let mut idx: Vec<i64> = lo_hi.iter().map(|&(lo, _)| lo).collect();
        loop {
            // all coordinates within the expanded cube by construction
            out.push(idx.clone());
            // odometer
            let mut c = 0;
            loop {
                idx[c] += 1;
                if idx[c] <= lo_hi[c].1 {
                    break;
                }
                idx[c] = lo_hi[c].0;
                c += 1;
                if c == self.d {
                    return out;
                }
            }
        }
    }

    /// Algorithm 7: returns (message, encoded point z − θ) on success.
    pub fn encode(&self, x: &[f64]) -> Option<(Message, Vec<f64>)> {
        assert_eq!(x.len(), self.d);
        for iter in 0..self.max_iters {
            let theta = self.theta(iter);
            let shifted: Vec<f64> = x.iter().zip(&theta).map(|(a, t)| a + t).collect();
            let z = self.nearest(&shifted);
            let cz = self.color(&z, iter);
            let unique = self
                .expanded_regions(&shifted)
                .iter()
                .all(|k| k == &z || self.color(k, iter) != cz);
            if unique {
                // Message: iteration counter + color index.
                let mut w = super::bits::BitWriter::new();
                w.push(iter as u64, 32);
                let cbits = super::bits::width_for(self.n_colors()).max(1);
                w.push(cz, cbits);
                let (bytes, _) = w.finish();
                // Metered at the *information* cost: log2(n_colors) + |i|.
                let bits = (self.bits_per_message().ceil() as u64).max(1) + 8;
                let zp = self.point(&z);
                let est: Vec<f64> = zp.iter().zip(&theta).map(|(a, t)| a - t).collect();
                return Some((Message { bytes, bits }, est));
            }
        }
        None
    }

    /// Algorithm 8: decode against `x_v`; exact when `‖x−x_v‖₂ ≤ qε = qs/2`.
    pub fn decode(&self, msg: &Message, x_v: &[f64]) -> Option<Vec<f64>> {
        let mut r = super::bits::BitReader::new(&msg.bytes);
        let iter = r.read(32) as u32;
        let cbits = super::bits::width_for(self.n_colors()).max(1);
        let cz = r.read(cbits);
        let theta = self.theta(iter);
        let shifted: Vec<f64> = x_v.iter().zip(&theta).map(|(a, t)| a + t).collect();
        // Search lattice points whose Voronoi region intersects
        // B_{qε}(x_v + θ): superset = expanded regions of the point.
        let mut best: Option<(f64, Vec<i64>)> = None;
        for k in self.expanded_regions(&shifted) {
            if self.color(&k, iter) == cz {
                let p = self.point(&k);
                let d2: f64 = p
                    .iter()
                    .zip(&shifted)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if best.as_ref().map_or(true, |(bd, _)| d2 < *bd) {
                    best = Some((d2, k));
                }
            }
        }
        best.map(|(_, k)| {
            let p = self.point(&k);
            p.iter().zip(&theta).map(|(a, t)| a - t).collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dist2;

    #[test]
    fn model_matches_paper_formula() {
        let m = SublinearModel { d: 256, y: 1.0 };
        // 0.5 bits/coord: s = 4y/(sqrt(2)-1)
        let s = m.side_for_bits(0.5);
        assert!((s - 4.0 / (2f64.sqrt() - 1.0)).abs() < 1e-9);
        let v = m.variance_for_bits(0.5);
        assert!((v - 256.0 * s * s / 12.0).abs() < 1e-9);
    }

    #[test]
    fn model_monotone_in_bits() {
        let m = SublinearModel { d: 128, y: 2.0 };
        let mut prev = f64::INFINITY;
        for b in [0.1, 0.25, 0.5, 1.0, 2.0, 4.0] {
            let v = m.variance_for_bits(b);
            assert!(v < prev);
            prev = v;
        }
    }

    #[test]
    fn exact_codec_roundtrip_close_inputs() {
        let d = 3;
        let c = SublinearCodec::new(d, 1.0, 1.5, 99);
        let mut rng = Rng::new(5);
        let mut ok = 0;
        let mut total = 0;
        for _ in 0..100 {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-5.0, 5.0)).collect();
            // ‖x − x_v‖₂ ≤ q·s/2
            let lim = c.q * c.s / 2.0 / (d as f64).sqrt();
            let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(-lim, lim)).collect();
            if let Some((msg, est)) = c.encode(&x) {
                total += 1;
                if let Some(z) = c.decode(&msg, &xv) {
                    if dist2(&z, &est) < 1e-9 {
                        ok += 1;
                    }
                }
            }
        }
        assert!(total > 80, "encode should almost always succeed");
        assert!(ok as f64 >= 0.95 * total as f64, "{ok}/{total} decoded");
    }

    #[test]
    fn exact_codec_unbiased() {
        let d = 2;
        let x = vec![0.337, -1.29];
        let mut acc = vec![0.0; d];
        let trials = 20_000;
        let mut got = 0;
        for t in 0..trials {
            let c = SublinearCodec::new(d, 0.8, 1.0, 7000 + t);
            if let Some((_, est)) = c.encode(&x) {
                acc[0] += est[0];
                acc[1] += est[1];
                got += 1;
            }
        }
        for i in 0..d {
            let mean = acc[i] / got as f64;
            let tol = 5.0 * 0.8 / (got as f64).sqrt();
            assert!((mean - x[i]).abs() < tol, "coord {i}: {mean} vs {}", x[i]);
        }
    }

    #[test]
    fn bits_scale_sublinearly() {
        // q < 1 → bits/coord = 3·log2(1+2q) < 3 — sublinear regime exists.
        let c = SublinearCodec::new(4, 1.0, 0.2, 1);
        assert!(c.bits_per_message() / 4.0 < 3.0);
    }
}
