//! Top-K sparsification with error feedback — a standard sparsifying
//! baseline (Stich et al. 2018; library extension beyond the paper's set).
//!
//! Sends the k largest-magnitude coordinates as (index, f32) pairs;
//! the residual is kept in error memory. Biased but EF-corrected.

use crate::quant::bits::{width_for, BitReader, BitWriter};
use crate::quant::{Message, VectorCodec};
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct TopK {
    pub d: usize,
    pub k: usize,
    error: Vec<f64>,
}

impl TopK {
    pub fn new(d: usize, k: usize) -> Self {
        assert!(k >= 1 && k <= d);
        TopK {
            d,
            k,
            error: vec![0.0; d],
        }
    }

    fn idx_width(&self) -> u32 {
        width_for(self.d as u64).max(1)
    }
}

impl VectorCodec for TopK {
    fn name(&self) -> String {
        format!("TopK(k={})", self.k)
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn encode(&mut self, x: &[f64], _rng: &mut Rng) -> Message {
        assert_eq!(x.len(), self.d);
        let p: Vec<f64> = x.iter().zip(&self.error).map(|(a, e)| a + e).collect();
        let mut idx: Vec<usize> = (0..self.d).collect();
        idx.sort_by(|&a, &b| p[b].abs().partial_cmp(&p[a].abs()).unwrap());
        idx.truncate(self.k);
        idx.sort_unstable();
        let mut w = BitWriter::with_capacity(self.k * (self.idx_width() as usize + 32));
        for &i in &idx {
            w.push(i as u64, self.idx_width());
            w.push_f32(p[i] as f32);
        }
        // error feedback
        let mut kept = vec![false; self.d];
        for &i in &idx {
            kept[i] = true;
        }
        for i in 0..self.d {
            self.error[i] = if kept[i] { p[i] - p[i] as f32 as f64 } else { p[i] };
        }
        let (bytes, bits) = w.finish();
        Message { bytes, bits }
    }

    fn decode(&self, msg: &Message, _reference: &[f64]) -> Vec<f64> {
        let mut r = BitReader::new(&msg.bytes);
        let mut out = vec![0.0; self.d];
        for _ in 0..self.k {
            let i = r.read(self.idx_width()) as usize;
            out[i] = r.read_f32() as f64;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_largest() {
        let mut c = TopK::new(6, 2);
        let mut rng = Rng::new(60);
        let x = vec![0.1, -5.0, 0.2, 3.0, -0.05, 0.0];
        let msg = c.encode(&x, &mut rng);
        let z = c.decode(&msg, &[]);
        assert!((z[1] - -5.0).abs() < 1e-6);
        assert!((z[3] - 3.0).abs() < 1e-6);
        assert_eq!(z[0], 0.0);
        assert_eq!(msg.bits, 2 * (3 + 32));
    }

    #[test]
    fn error_feedback_flushes_small_coords() {
        let mut c = TopK::new(3, 1);
        let mut rng = Rng::new(61);
        let x = vec![1.0, 0.9, 0.0];
        let _ = c.encode(&x, &mut rng); // sends idx 0
        let msg = c.encode(&x, &mut rng); // now idx 1 has error 0.9 + 0.9
        let z = c.decode(&msg, &[]);
        assert!(z[1] > 1.5, "EF must promote the starved coordinate");
    }
}
