//! Top-K sparsification with error feedback — a standard sparsifying
//! baseline (Stich et al. 2018; library extension beyond the paper's set).
//!
//! Sends the k largest-magnitude coordinates as (index, f32) pairs;
//! the residual is kept in error memory. Biased but EF-corrected.
//!
//! §Perf: ranking is O(d) (`select_nth_unstable_by` over
//! [`f64::total_cmp`] with an index tie-break — same selected set as the
//! seed's stable descending sort, minus the O(d log d) sort and its
//! NaN-`unwrap` panic path), encode recycles the `p`/index scratch and
//! the message bytes, and the decode-side fold kernels are *sparse*:
//! `decode_accumulate_into`/`_range` touch the k shipped entries instead
//! of materializing a d-length vector. (Sparse accumulate skips the
//! `acc[i] += weight·0.0` no-ops a dense decode+axpy would execute; for
//! finite accumulators that add is the identity, so the folds only
//! differ on `-0.0`/non-finite accumulator entries, which the dense path
//! would rewrite.)

use crate::quant::bits::{width_for, BitReader, BitWriter};
use crate::quant::{Message, VectorCodec};
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct TopK {
    pub d: usize,
    pub k: usize,
    error: Vec<f64>,
    /// `x + e` scratch (recycled across rounds).
    p: Vec<f64>,
    /// Selection scratch (recycled across rounds).
    idx: Vec<usize>,
}

impl TopK {
    pub fn new(d: usize, k: usize) -> Self {
        assert!(k >= 1 && k <= d);
        TopK {
            d,
            k,
            error: vec![0.0; d],
            p: Vec::new(),
            idx: Vec::new(),
        }
    }

    fn idx_width(&self) -> u32 {
        width_for(self.d as u64).max(1)
    }

    /// Rank, serialize, and apply error feedback — the shared body of
    /// `encode`/`encode_into` (they differ only in writer scratch).
    fn encode_core(&mut self, x: &[f64], w: &mut BitWriter) {
        assert_eq!(x.len(), self.d);
        self.p.clear();
        self.p
            .extend(x.iter().zip(&self.error).map(|(a, e)| a + e));
        self.idx.clear();
        self.idx.extend(0..self.d);
        // O(d) partition: the k top-magnitude indices land in the first k
        // slots. Descending |p| with ascending-index tie-break — the same
        // set (and tie winners) the seed's stable descending sort picked,
        // but total_cmp keeps NaN inputs deterministic instead of
        // panicking. (k ≥ 1 by construction; k == d keeps everything, no
        // partition needed.)
        if self.k < self.d {
            let p = &self.p;
            self.idx.select_nth_unstable_by(self.k - 1, |&a, &b| {
                p[b].abs().total_cmp(&p[a].abs()).then(a.cmp(&b))
            });
        }
        self.idx.truncate(self.k);
        self.idx.sort_unstable();
        let iw = self.idx_width();
        for &i in &self.idx {
            w.push(i as u64, iw);
            w.push_f32(self.p[i] as f32);
        }
        // Error feedback: unsent coordinates keep their whole value, sent
        // ones keep only the f64→f32 serialization residue.
        self.error.copy_from_slice(&self.p);
        for &i in &self.idx {
            self.error[i] = self.p[i] - (self.p[i] as f32 as f64);
        }
    }

    /// The shared sparse decode loop: the k (index, value) pairs are read
    /// and handed to `emit`; every decode entry point is this loop with a
    /// different sink.
    fn decode_fold(&self, msg: &Message, mut emit: impl FnMut(usize, f64)) {
        let mut r = BitReader::new(&msg.bytes);
        let iw = self.idx_width();
        for _ in 0..self.k {
            let i = r.read(iw) as usize;
            let v = r.read_f32() as f64;
            emit(i, v);
        }
    }
}

impl VectorCodec for TopK {
    fn name(&self) -> String {
        format!("TopK(k={})", self.k)
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn encode(&mut self, x: &[f64], _rng: &mut Rng) -> Message {
        let mut w = BitWriter::with_capacity(self.k * (self.idx_width() as usize + 32));
        self.encode_core(x, &mut w);
        let (bytes, bits) = w.finish();
        Message { bytes, bits }
    }

    /// Zero-realloc encode: same ranking + serialization, recycled
    /// message bytes and selection scratch.
    fn encode_into(&mut self, x: &[f64], _rng: &mut Rng, out: &mut Message) {
        let mut w = BitWriter::reusing(std::mem::take(&mut out.bytes));
        self.encode_core(x, &mut w);
        let (bytes, bits) = w.finish();
        out.bytes = bytes;
        out.bits = bits;
    }

    fn decode(&self, msg: &Message, reference: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.d];
        self.decode_into(msg, reference, &mut out);
        out
    }

    fn decode_into(&self, msg: &Message, _reference: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), self.d);
        out.fill(0.0);
        self.decode_fold(msg, |i, v| out[i] = v);
    }

    /// Sparse fold: touches the k shipped entries, not d. Identical to
    /// dense decode+axpy on every finite accumulator entry (see module
    /// §Perf for the `-0.0` caveat).
    fn decode_accumulate_into(&self, msg: &Message, _reference: &[f64], weight: f64, acc: &mut [f64]) {
        assert_eq!(acc.len(), self.d);
        self.decode_fold(msg, |i, v| acc[i] += weight * v);
    }

    /// Sparse range fold: reads the k pairs once and accumulates those
    /// that fall in `lo..lo + acc.len()` — O(k) regardless of chunk size.
    fn decode_accumulate_range(
        &self,
        msg: &Message,
        _reference: &[f64],
        weight: f64,
        lo: usize,
        acc: &mut [f64],
    ) {
        assert!(lo + acc.len() <= self.d);
        let hi = lo + acc.len();
        self.decode_fold(msg, |i, v| {
            if i >= lo && i < hi {
                acc[i - lo] += weight * v;
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_largest() {
        let mut c = TopK::new(6, 2);
        let mut rng = Rng::new(60);
        let x = vec![0.1, -5.0, 0.2, 3.0, -0.05, 0.0];
        let msg = c.encode(&x, &mut rng);
        let z = c.decode(&msg, &[]);
        assert!((z[1] - -5.0).abs() < 1e-6);
        assert!((z[3] - 3.0).abs() < 1e-6);
        assert_eq!(z[0], 0.0);
        assert_eq!(msg.bits, 2 * (3 + 32));
    }

    #[test]
    fn error_feedback_flushes_small_coords() {
        let mut c = TopK::new(3, 1);
        let mut rng = Rng::new(61);
        let x = vec![1.0, 0.9, 0.0];
        let _ = c.encode(&x, &mut rng); // sends idx 0
        let msg = c.encode(&x, &mut rng); // now idx 1 has error 0.9 + 0.9
        let z = c.decode(&msg, &[]);
        assert!(z[1] > 1.5, "EF must promote the starved coordinate");
    }

    #[test]
    fn selection_breaks_ties_by_lowest_index() {
        // Four equal magnitudes, k = 2: the stable-sort seed kept the two
        // lowest indices; the O(d) partition must pick the same pair.
        let mut c = TopK::new(5, 2);
        let mut rng = Rng::new(62);
        let x = vec![2.0, -2.0, 2.0, 2.0, 0.5];
        let msg = c.encode(&x, &mut rng);
        let z = c.decode(&msg, &[]);
        assert!((z[0] - 2.0).abs() < 1e-6);
        assert!((z[1] - -2.0).abs() < 1e-6);
        assert_eq!(z[2], 0.0);
        assert_eq!(z[3], 0.0);
    }

    #[test]
    fn nan_input_does_not_panic_and_is_deterministic() {
        // The seed's partial_cmp().unwrap() panicked on NaN; total_cmp
        // ranks NaN above every finite magnitude, deterministically.
        let x = vec![1.0, f64::NAN, 0.5, -3.0];
        let mut a = TopK::new(4, 2);
        let mut b = TopK::new(4, 2);
        let mut rng = Rng::new(63);
        let ma = a.encode(&x, &mut rng);
        let mb = b.encode(&x, &mut rng);
        assert_eq!(ma, mb);
    }

    #[test]
    fn sparse_folds_touch_only_shipped_entries() {
        let d = 8;
        let mut c = TopK::new(d, 3);
        let mut rng = Rng::new(64);
        let x = vec![5.0, 0.1, -4.0, 0.2, 3.0, 0.0, 0.3, -0.2];
        let msg = c.encode(&x, &mut rng);
        let z = c.decode(&msg, &[]);
        // Dense reference.
        let stale: Vec<f64> = (0..d).map(|i| 0.25 * i as f64 - 1.0).collect();
        let mut expect = stale.clone();
        crate::linalg::axpy(&mut expect, -1.5, &z);
        let mut acc = stale.clone();
        c.decode_accumulate_into(&msg, &[], -1.5, &mut acc);
        assert_eq!(acc, expect);
        // Range over an interior chunk.
        let mut acc_r = stale[2..6].to_vec();
        c.decode_accumulate_range(&msg, &[], -1.5, 2, &mut acc_r);
        assert_eq!(acc_r, expect[2..6]);
    }
}
