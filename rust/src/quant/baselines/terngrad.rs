//! TernGrad (Wen et al., NeurIPS 2017) — ternary gradients. Included as a
//! library extension (not in the paper's comparison set, but a standard
//! point on the bits/variance curve between EF-Sign and QSGD).
//!
//! Each coordinate is quantized to `{−1, 0, +1}·‖x‖∞` with stochastic
//! rounding on `|x_i|/‖x‖∞`. Cost: 2 bits/coordinate + one float.

use crate::quant::bits::{BitReader, BitWriter};
use crate::quant::{Message, VectorCodec};
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct TernGrad {
    pub d: usize,
}

impl TernGrad {
    pub fn new(d: usize) -> Self {
        TernGrad { d }
    }
}

impl VectorCodec for TernGrad {
    fn name(&self) -> String {
        "TernGrad".to_string()
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn encode(&mut self, x: &[f64], rng: &mut Rng) -> Message {
        assert_eq!(x.len(), self.d);
        let m = crate::linalg::norm_inf(x);
        let mut w = BitWriter::with_capacity(self.d * 2 + 64);
        w.push_f64(m);
        for &v in x {
            let t = if m > 0.0 && rng.next_f64() < v.abs() / m {
                if v < 0.0 {
                    2u64 // -1
                } else {
                    1u64 // +1
                }
            } else {
                0u64
            };
            w.push(t, 2);
        }
        let (bytes, bits) = w.finish();
        Message { bytes, bits }
    }

    fn decode(&self, msg: &Message, _reference: &[f64]) -> Vec<f64> {
        let mut r = BitReader::new(&msg.bytes);
        let m = r.read_f64();
        (0..self.d)
            .map(|_| match r.read(2) {
                1 => m,
                2 => -m,
                _ => 0.0,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbiased() {
        let d = 4;
        let mut c = TernGrad::new(d);
        let x = vec![0.5, -0.25, 1.0, 0.0];
        let mut rng = Rng::new(50);
        let trials = 60_000;
        let mut acc = vec![0.0; d];
        for _ in 0..trials {
            let msg = c.encode(&x, &mut rng);
            let z = c.decode(&msg, &[]);
            for (a, zi) in acc.iter_mut().zip(&z) {
                *a += zi;
            }
        }
        for (a, xi) in acc.iter().zip(&x) {
            let mean = a / trials as f64;
            assert!((mean - xi).abs() < 0.02, "{mean} vs {xi}");
        }
    }

    #[test]
    fn two_bits_per_coord() {
        let mut c = TernGrad::new(64);
        let mut rng = Rng::new(51);
        let msg = c.encode(&vec![0.3; 64], &mut rng);
        assert_eq!(msg.bits, 64 + 128);
    }
}
