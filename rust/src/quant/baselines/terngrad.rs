//! TernGrad (Wen et al., NeurIPS 2017) — ternary gradients. Included as a
//! library extension (not in the paper's comparison set, but a standard
//! point on the bits/variance curve between EF-Sign and QSGD).
//!
//! Each coordinate is quantized to `{−1, 0, +1}·‖x‖∞` with stochastic
//! rounding on `|x_i|/‖x‖∞`. Cost: 2 bits/coordinate + one float.
//!
//! §Perf: a 64-bit header plus 2-bit fields — the full fast-path surface
//! (see [`super`] §Perf): bulk-uniform [`VectorCodec::encode_prepare`]
//! (the seed drew *no* uniforms for the zero vector, and neither does
//! the prepare), [`BitWriter::push_block`] packing (32 trits per word
//! store), one `decode_fold` block loop behind every decode entry point,
//! seekable `decode_accumulate_range`, and chunk-parallel
//! `encode_range` — all bit-identical to the seed scalar path (pinned in
//! `rust/tests/prop.rs`).

use crate::quant::bits::{byte_align_fields, BitReader, BitWriter};
use crate::quant::{Message, VectorCodec};
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct TernGrad {
    pub d: usize,
    /// ‖x‖∞ header captured by `encode_prepare`.
    m: f64,
    /// Pre-drawn stochastic-rounding uniforms (empty when `m == 0`: the
    /// seed's short-circuit drew nothing for the zero vector).
    unis: Vec<f64>,
}

impl TernGrad {
    pub fn new(d: usize) -> Self {
        TernGrad {
            d,
            m: 0.0,
            unis: Vec::new(),
        }
    }

    /// The shared fused decode loop (header, then 2-bit trits through the
    /// block kernel); every decode entry point is this loop with a
    /// different sink.
    fn decode_fold(&self, msg: &Message, lo: usize, len: usize, mut emit: impl FnMut(usize, f64)) {
        const BLOCK: usize = 128;
        let mut r = BitReader::new(&msg.bytes);
        let m = r.read_f64();
        r.seek(64 + 2 * lo as u64);
        let mut fields = [0u64; BLOCK];
        let mut done = 0;
        while done < len {
            let take = (len - done).min(BLOCK);
            r.read_block(2, &mut fields[..take]);
            for (j, &f) in fields[..take].iter().enumerate() {
                emit(
                    lo + done + j,
                    match f {
                        1 => m,
                        2 => -m,
                        _ => 0.0,
                    },
                );
            }
            done += take;
        }
    }
}

impl VectorCodec for TernGrad {
    fn name(&self) -> String {
        "TernGrad".to_string()
    }

    fn dim(&self) -> usize {
        self.d
    }

    /// Sequential pre-pass: the ℓ∞ header and one bulk uniform per
    /// coordinate — except for the zero vector, where the seed's
    /// `m > 0.0 &&` short-circuit consumed no draws, so neither do we.
    fn encode_prepare(&mut self, x: &[f64], rng: &mut Rng) {
        assert_eq!(x.len(), self.d);
        self.m = crate::linalg::norm_inf(x);
        self.unis.resize(self.d, 0.0);
        if self.m > 0.0 {
            rng.fill_uniform(&mut self.unis);
        }
    }

    fn encode(&mut self, x: &[f64], rng: &mut Rng) -> Message {
        self.encode_prepare(x, rng);
        let mut w = BitWriter::with_capacity(self.d * 2 + 64);
        self.encode_range(x, 0, self.d, &mut w);
        let (bytes, bits) = w.finish();
        Message { bytes, bits }
    }

    /// Zero-realloc encode: same kernel, recycled scratch bytes.
    fn encode_into(&mut self, x: &[f64], rng: &mut Rng, out: &mut Message) {
        self.encode_prepare(x, rng);
        let mut w = BitWriter::reusing(std::mem::take(&mut out.bytes));
        self.encode_range(x, 0, self.d, &mut w);
        let (bytes, bits) = w.finish();
        out.bytes = bytes;
        out.bits = bits;
    }

    /// Fused block encode kernel for coordinates `lo..lo + len` (header
    /// emitted by the `lo == 0` chunk). Requires a preceding
    /// [`Self::encode_prepare`] for the same `x`.
    fn encode_range(&self, x: &[f64], lo: usize, len: usize, w: &mut BitWriter) {
        const BLOCK: usize = 128;
        assert_eq!(x.len(), self.d);
        assert!(lo + len <= self.d);
        assert_eq!(
            self.unis.len(),
            self.d,
            "encode_prepare must precede encode_range"
        );
        let m = self.m;
        if lo == 0 {
            w.push_f64(m);
        }
        let mut fields = [0u64; BLOCK];
        let mut done = 0;
        while done < len {
            let take = (len - done).min(BLOCK);
            let base = lo + done;
            for (j, f) in fields[..take].iter_mut().enumerate() {
                let v = x[base + j];
                *f = if m > 0.0 && self.unis[base + j] < v.abs() / m {
                    if v < 0.0 {
                        2 // -1
                    } else {
                        1 // +1
                    }
                } else {
                    0
                };
            }
            w.push_block(&fields[..take], 2);
            done += take;
        }
    }

    fn supports_encode_range(&self) -> bool {
        true
    }

    fn encode_chunk_align(&self) -> usize {
        byte_align_fields(2)
    }

    fn decode(&self, msg: &Message, reference: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.d];
        self.decode_into(msg, reference, &mut out);
        out
    }

    fn decode_into(&self, msg: &Message, _reference: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), self.d);
        self.decode_fold(msg, 0, self.d, |idx, v| out[idx] = v);
    }

    /// Fused streaming-fold kernel: one pass bitstream → accumulator.
    fn decode_accumulate_into(&self, msg: &Message, _reference: &[f64], weight: f64, acc: &mut [f64]) {
        assert_eq!(acc.len(), self.d);
        self.decode_fold(msg, 0, self.d, |idx, v| acc[idx] += weight * v);
    }

    /// Chunk-sharded fold kernel: seeks past the header to the chunk's
    /// 2-bit field offset.
    fn decode_accumulate_range(
        &self,
        msg: &Message,
        _reference: &[f64],
        weight: f64,
        lo: usize,
        acc: &mut [f64],
    ) {
        assert!(lo + acc.len() <= self.d);
        self.decode_fold(msg, lo, acc.len(), |idx, v| acc[idx - lo] += weight * v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbiased() {
        let d = 4;
        let mut c = TernGrad::new(d);
        let x = vec![0.5, -0.25, 1.0, 0.0];
        let mut rng = Rng::new(50);
        let trials = 60_000;
        let mut acc = vec![0.0; d];
        for _ in 0..trials {
            let msg = c.encode(&x, &mut rng);
            let z = c.decode(&msg, &[]);
            for (a, zi) in acc.iter_mut().zip(&z) {
                *a += zi;
            }
        }
        for (a, xi) in acc.iter().zip(&x) {
            let mean = a / trials as f64;
            assert!((mean - xi).abs() < 0.02, "{mean} vs {xi}");
        }
    }

    #[test]
    fn two_bits_per_coord() {
        let mut c = TernGrad::new(64);
        let mut rng = Rng::new(51);
        let msg = c.encode(&vec![0.3; 64], &mut rng);
        assert_eq!(msg.bits, 64 + 128);
    }

    #[test]
    fn zero_vector_consumes_no_draws() {
        // The seed's `m > 0.0 &&` short-circuit never touched the RNG for
        // an all-zero input; the bulk prepare must preserve that.
        let d = 9;
        let mut c = TernGrad::new(d);
        let mut rng = Rng::new(52);
        let msg = c.encode(&vec![0.0; d], &mut rng);
        assert_eq!(rng.next_u64(), Rng::new(52).next_u64());
        assert!(c.decode(&msg, &[]).iter().all(|v| *v == 0.0));
    }
}
