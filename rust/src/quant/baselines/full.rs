//! Full-precision "codec": ships raw f32 coordinates. The paper's naive
//! averaging baseline (32 bits/coordinate, no quantization variance beyond
//! the f64→f32 cast, which is negligible at experiment scales).

use crate::quant::bits::{BitReader, BitWriter};
use crate::quant::{Message, VectorCodec};
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct FullPrecision {
    pub d: usize,
}

impl FullPrecision {
    pub fn new(d: usize) -> Self {
        FullPrecision { d }
    }
}

impl VectorCodec for FullPrecision {
    fn name(&self) -> String {
        "full32".to_string()
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn encode(&mut self, x: &[f64], _rng: &mut Rng) -> Message {
        let mut w = BitWriter::with_capacity(self.d * 32);
        self.encode_range(x, 0, self.d, &mut w);
        let (bytes, bits) = w.finish();
        Message { bytes, bits }
    }

    fn decode(&self, msg: &Message, _reference: &[f64]) -> Vec<f64> {
        let mut r = BitReader::new(&msg.bytes);
        (0..self.d).map(|_| r.read_f32() as f64).collect()
    }

    fn encode_into(&mut self, x: &[f64], _rng: &mut Rng, out: &mut Message) {
        let mut w = BitWriter::reusing(std::mem::take(&mut out.bytes));
        self.encode_range(x, 0, self.d, &mut w);
        let (bytes, bits) = w.finish();
        out.bytes = bytes;
        out.bits = bits;
    }

    /// Chunk kernel for the parallel encode: f32 fields are fixed-width,
    /// so coordinates `lo..lo + len` occupy exactly bits `32·lo..32·(lo+len)`.
    fn encode_range(&self, x: &[f64], lo: usize, len: usize, w: &mut BitWriter) {
        assert_eq!(x.len(), self.d);
        assert!(lo + len <= self.d);
        for &v in &x[lo..lo + len] {
            w.push_f32(v as f32);
        }
    }

    fn supports_encode_range(&self) -> bool {
        true
    }

    fn decode_into(&self, msg: &Message, _reference: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), self.d);
        let mut r = BitReader::new(&msg.bytes);
        for o in out.iter_mut() {
            *o = r.read_f32() as f64;
        }
    }

    /// Fused streaming-fold kernel: widen-and-accumulate in one pass.
    fn decode_accumulate_into(&self, msg: &Message, _reference: &[f64], weight: f64, acc: &mut [f64]) {
        assert_eq!(acc.len(), self.d);
        let mut r = BitReader::new(&msg.bytes);
        for a in acc.iter_mut() {
            *a += weight * (r.read_f32() as f64);
        }
    }

    /// Chunk-sharded fold kernel: f32 fields are fixed-width, so chunk
    /// `lo` starts at bit `32·lo`.
    fn decode_accumulate_range(
        &self,
        msg: &Message,
        _reference: &[f64],
        weight: f64,
        lo: usize,
        acc: &mut [f64],
    ) {
        assert!(lo + acc.len() <= self.d);
        let mut r = BitReader::new(&msg.bytes);
        r.seek(32 * lo as u64);
        for a in acc.iter_mut() {
            *a += weight * (r.read_f32() as f64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_is_f32_exact() {
        let mut c = FullPrecision::new(5);
        let x = vec![1.5, -2.25, 0.0, 1e10, -3.5e-5];
        let mut rng = Rng::new(0);
        let msg = c.encode(&x, &mut rng);
        assert_eq!(msg.bits, 5 * 32);
        let z = c.decode(&msg, &[]);
        for (a, b) in x.iter().zip(&z) {
            assert_eq!(*a as f32, *b as f32);
        }
    }
}
