//! Baseline compressors — every comparator in the paper's Section 9.
//!
//! | codec | reference | experiments |
//! |---|---|---|
//! | [`FullPrecision`] | "none" / naive averaging | E2–E8 |
//! | [`Qsgd`] (L2 and L∞ normalization) | Alistarh et al. 2017 | E1–E5, E7, E8 |
//! | [`SureshHadamard`] | Suresh et al. 2017 | E2–E3, E8 |
//! | [`VqsgdCrossPolytope`] | Gandikota et al. 2019 | E4 |
//! | [`EfSignSgd`] | Karimireddy et al. 2019 | E7 |
//! | [`PowerSgd`] | Vogels et al. 2019 | E7 |
//! | [`TernGrad`] | Wen et al. 2017 | extension |
//! | [`TopK`] | sparsification baseline | extension |

mod ef_sign;
mod full;
mod powersgd;
mod qsgd;
mod suresh;
mod terngrad;
mod topk;
mod vqsgd;

pub use ef_sign::EfSignSgd;
pub use full::FullPrecision;
pub use powersgd::PowerSgd;
pub use qsgd::{Qsgd, QsgdNorm};
pub use suresh::SureshHadamard;
pub use terngrad::TernGrad;
pub use topk::TopK;
pub use vqsgd::VqsgdCrossPolytope;
