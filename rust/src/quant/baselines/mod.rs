//! Baseline compressors — every comparator in the paper's Section 9.
//!
//! | codec | reference | experiments |
//! |---|---|---|
//! | [`FullPrecision`] | "none" / naive averaging | E2–E8 |
//! | [`Qsgd`] (L2 and L∞ normalization) | Alistarh et al. 2017 | E1–E5, E7, E8 |
//! | [`SureshHadamard`] | Suresh et al. 2017 | E2–E3, E8 |
//! | [`VqsgdCrossPolytope`] | Gandikota et al. 2019 | E4 |
//! | [`EfSignSgd`] | Karimireddy et al. 2019 | E7 |
//! | [`PowerSgd`] | Vogels et al. 2019 | E7 |
//! | [`TernGrad`] | Wen et al. 2017 | extension |
//! | [`TopK`] | sparsification baseline | extension |
//!
//! # §Perf — the comparator suite on the blocked data plane
//!
//! The paper's experiments (E1–E8 + ablation) measure the lattice codecs
//! *against* these baselines, so comparator throughput bounds every
//! sweep's wall-clock. All eight ride the same fast-path surface as the
//! lattice family (see [`crate::quant`] §Perf):
//!
//! * **Fixed-width baselines** — [`Qsgd`] (both norms),
//!   [`SureshHadamard`], [`TernGrad`], [`EfSignSgd`], plus
//!   [`FullPrecision`] — have a byte-aligned float header followed by
//!   one fixed-width field per (padded, for Suresh) coordinate. They
//!   implement the *full* surface and advertise
//!   `supports_encode_range() == true`: zero-realloc
//!   `encode_into`/`decode_into`; fused block encode through
//!   [`crate::quant::bits::BitWriter::push_block`] with stochastic
//!   rounding fed by one bulk [`crate::rng::Rng::fill_uniform`] in
//!   `encode_prepare` (stream-identical to the seed's per-coordinate
//!   draws); a shared `decode_fold` block loop
//!   ([`crate::quant::bits::BitReader::read_block`]) behind
//!   `decode_accumulate_into`; and seekable `decode_accumulate_range` /
//!   `encode_range` so they ride
//!   [`crate::coordinator::fold_mean_chunked`],
//!   [`crate::quant::encode_chunked`], and the batched session arenas
//!   end to end. Suresh–Hadamard additionally uses the one-pass scratch
//!   rotation (`Rotation::forward_into`/`inverse_in_place`); its global
//!   rotation makes the *range* fold correct but not sublinear, and its
//!   `wire_fields()` is the padded rotated dimension.
//! * **Structured baselines** — [`TopK`] ranks in O(d)
//!   (`select_nth_unstable_by` over `total_cmp`) and folds *sparsely*
//!   (k entries touched, never a d-length temporary); [`PowerSgd`] and
//!   [`VqsgdCrossPolytope`] get zero-realloc `encode_into`/`decode_into`
//!   but no range kernels (matrix factors / repetition fields have no
//!   coordinate sub-stream).
//!
//! Every fused path is bit-identical to the seed scalar path — same RNG
//! draw order, same IEEE expression order — pinned per codec by the
//! `baseline_*` prop tests in `rust/tests/prop.rs` and measured in
//! `quant_bench`'s `baseline_bench` section (scalar vs fused vs
//! chunk-parallel at d ∈ {128, 4096, 65536}).

mod ef_sign;
mod full;
mod powersgd;
mod qsgd;
mod suresh;
mod terngrad;
mod topk;
mod vqsgd;

pub use ef_sign::EfSignSgd;
pub use full::FullPrecision;
pub use powersgd::PowerSgd;
pub use qsgd::{Qsgd, QsgdNorm};
pub use suresh::SureshHadamard;
pub use terngrad::TernGrad;
pub use topk::TopK;
pub use vqsgd::VqsgdCrossPolytope;
