//! PowerSGD (Vogels et al., NeurIPS 2019) — rank-r gradient compression,
//! the low-rank comparator of Experiment 7.
//!
//! The gradient vector is viewed as an `a×b` matrix `M`. One power
//! iteration with a warm-started right factor `Q`:
//! `P = M Q`, orthonormalize `P` (Gram–Schmidt), `Q' = Mᵀ P`.
//! Message = (P, Q') as f32, `(a + b)·r·32` bits; decode is `P Q'ᵀ`.
//! Error feedback is applied as in the original paper.

use crate::linalg::Matrix;
use crate::quant::bits::{BitReader, BitWriter};
use crate::quant::{Message, VectorCodec};
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct PowerSgd {
    pub rows: usize,
    pub cols: usize,
    pub rank: usize,
    /// Warm-started right factor (cols × rank).
    q: Matrix,
    /// Error-feedback memory.
    error: Vec<f64>,
}

impl PowerSgd {
    /// Shape a length-`d` vector into `rows×cols` with `rows·cols = d`
    /// (closest-to-square factorization is chosen by `for_dim`).
    pub fn new(rows: usize, cols: usize, rank: usize, rng: &mut Rng) -> Self {
        let mut q = Matrix::zeros(cols, rank);
        for v in q.data.iter_mut() {
            *v = rng.next_gaussian();
        }
        PowerSgd {
            rows,
            cols,
            rank,
            q,
            error: vec![0.0; rows * cols],
        }
    }

    /// Closest-to-square factorization of d.
    pub fn for_dim(d: usize, rank: usize, rng: &mut Rng) -> Self {
        let mut best = (1, d);
        let mut r = (d as f64).sqrt() as usize;
        while r >= 1 {
            if d % r == 0 {
                best = (r, d / r);
                break;
            }
            r -= 1;
        }
        Self::new(best.0, best.1, rank, rng)
    }

    fn orthonormalize(m: &mut Matrix) {
        // Modified Gram–Schmidt over columns.
        let (rows, cols) = (m.rows, m.cols);
        for j in 0..cols {
            for k in 0..j {
                let mut dot = 0.0;
                for i in 0..rows {
                    dot += m.data[i * cols + j] * m.data[i * cols + k];
                }
                for i in 0..rows {
                    let vk = m.data[i * cols + k];
                    m.data[i * cols + j] -= dot * vk;
                }
            }
            let mut norm = 0.0;
            for i in 0..rows {
                norm += m.data[i * cols + j].powi(2);
            }
            let norm = norm.sqrt().max(1e-12);
            for i in 0..rows {
                m.data[i * cols + j] /= norm;
            }
        }
    }
}

impl PowerSgd {
    /// One warm-started power iteration with error feedback — the shared
    /// body of `encode`/`encode_into` (they differ only in writer
    /// scratch). Returns the (P, Q') factor pair to serialize.
    fn factors(&mut self, x: &[f64]) -> (Matrix, Matrix) {
        assert_eq!(x.len(), self.dim());
        let m = Matrix {
            rows: self.rows,
            cols: self.cols,
            data: x.iter().zip(&self.error).map(|(a, e)| a + e).collect(),
        };
        // P = M Q, orthonormalized.
        let mut p = m.matmul(&self.q);
        Self::orthonormalize(&mut p);
        // Q' = Mᵀ P.
        let q_new = m.transpose().matmul(&p);
        // Decode locally for error feedback: M̂ = P Q'ᵀ.
        let m_hat = p.matmul(&q_new.transpose());
        for ((e, mi), mh) in self.error.iter_mut().zip(&m.data).zip(&m_hat.data) {
            *e = mi - mh;
        }
        self.q = q_new.clone();
        (p, q_new)
    }
}

impl VectorCodec for PowerSgd {
    fn name(&self) -> String {
        format!("PowerSGD(r={})", self.rank)
    }

    fn dim(&self) -> usize {
        self.rows * self.cols
    }

    fn encode(&mut self, x: &[f64], _rng: &mut Rng) -> Message {
        let (p, q_new) = self.factors(x);
        // Serialize P then Q' as f32.
        let mut w = BitWriter::with_capacity((p.data.len() + q_new.data.len()) * 32);
        for &v in p.data.iter().chain(&q_new.data) {
            w.push_f32(v as f32);
        }
        let (bytes, bits) = w.finish();
        Message { bytes, bits }
    }

    /// Zero-realloc (message-side) encode: same iteration, recycled
    /// scratch bytes.
    fn encode_into(&mut self, x: &[f64], _rng: &mut Rng, out: &mut Message) {
        let (p, q_new) = self.factors(x);
        let mut w = BitWriter::reusing(std::mem::take(&mut out.bytes));
        for &v in p.data.iter().chain(&q_new.data) {
            w.push_f32(v as f32);
        }
        let (bytes, bits) = w.finish();
        out.bytes = bytes;
        out.bits = bits;
    }

    fn decode(&self, msg: &Message, reference: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        self.decode_into(msg, reference, &mut out);
        out
    }

    /// Reconstruct `P Q'ᵀ` straight into the caller's buffer — the same
    /// skip-zero ikj accumulation [`Matrix::matmul`] performs (the seed's
    /// `p.matmul(&q.transpose())` decode, bit for bit), minus the result
    /// matrix; `decode` is this plus an allocation.
    fn decode_into(&self, msg: &Message, _reference: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), self.dim());
        let mut r = BitReader::new(&msg.bytes);
        let p: Vec<f64> = (0..self.rows * self.rank)
            .map(|_| r.read_f32() as f64)
            .collect();
        let q: Vec<f64> = (0..self.cols * self.rank)
            .map(|_| r.read_f32() as f64)
            .collect();
        out.fill(0.0);
        for i in 0..self.rows {
            for k in 0..self.rank {
                let aik = p[i * self.rank + k];
                if aik == 0.0 {
                    continue;
                }
                let orow = &mut out[i * self.cols..(i + 1) * self.cols];
                for (j, oj) in orow.iter_mut().enumerate() {
                    *oj += aik * q[j * self.rank + k];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dist2, norm2};

    #[test]
    fn bit_cost() {
        let mut rng = Rng::new(40);
        let mut c = PowerSgd::new(10, 10, 2, &mut rng);
        let msg = c.encode(&vec![1.0; 100], &mut rng);
        assert_eq!(msg.bits, (10 + 10) * 2 * 32);
    }

    #[test]
    fn exact_for_rank_r_matrices() {
        // A rank-1 "gradient" is reconstructed (nearly) exactly after a
        // couple of warm-started iterations.
        let mut rng = Rng::new(41);
        let rows = 8;
        let cols = 8;
        let u: Vec<f64> = (0..rows).map(|_| rng.next_gaussian()).collect();
        let v: Vec<f64> = (0..cols).map(|_| rng.next_gaussian()).collect();
        let mut x = vec![0.0; rows * cols];
        for i in 0..rows {
            for j in 0..cols {
                x[i * cols + j] = u[i] * v[j];
            }
        }
        let mut c = PowerSgd::new(rows, cols, 1, &mut rng);
        let mut z = Vec::new();
        for _ in 0..3 {
            c.error.iter_mut().for_each(|e| *e = 0.0); // isolate per-step
            let msg = c.encode(&x, &mut rng);
            z = c.decode(&msg, &[]);
        }
        assert!(dist2(&z, &x) < 1e-4 * norm2(&x).max(1.0));
    }

    #[test]
    fn for_dim_factorizes() {
        let mut rng = Rng::new(42);
        let c = PowerSgd::for_dim(100, 2, &mut rng);
        assert_eq!(c.rows * c.cols, 100);
        assert!(c.rows >= 2);
    }
}
