//! Suresh et al. (ICML 2017) structured-rotation stochastic quantization —
//! the "Hadamard" baseline of the paper's Experiments 2–3.
//!
//! Scheme: rotate by `HD` (shared-random sign diagonal), then stochastic
//! uniform quantization of the rotated vector between its per-vector min
//! and max with `L` levels. Cost: `d·⌈log₂ L⌉` bits + two floats. Like
//! QSGD (and unlike the lattice scheme) the error scales with the input
//! *norm*, which is exactly the gap the paper exposes.
//!
//! §Perf: the encode rides the one-pass scratch rotation
//! ([`Rotation::forward_into`] — sign diagonal and 1/√d fused into the
//! butterflies, zero allocations after the first round) and the fused
//! block kernels (bulk uniforms in [`VectorCodec::encode_prepare`],
//! [`BitWriter::push_block`] packing). The wire format is a 128-bit
//! min/max header plus one fixed-width field per *padded rotated*
//! coordinate, so [`VectorCodec::wire_fields`] is the padded dimension
//! and [`VectorCodec::encode_range`] shards the rotated field stream
//! across cores. Decode dequantizes through
//! [`BitReader::read_block`] into one padded buffer and inverse-rotates
//! in place ([`Rotation::inverse_in_place`]); the global rotation means
//! `decode_accumulate_range` still pays a full dequant+rotate per chunk
//! (it exists for correctness under `fold_mean_chunked`, not speed —
//! prefer `fold_mean` for this codec). All paths are bit-identical to
//! the seed scalar pipeline (pinned in `rust/tests/prop.rs`).

use crate::quant::bits::{byte_align_fields, width_for, BitReader, BitWriter};
use crate::quant::hadamard::Rotation;
use crate::quant::{Message, VectorCodec};
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct SureshHadamard {
    pub rotation: Rotation,
    pub levels: u32,
    /// Rotated input (padded length), filled by `encode_prepare`.
    rx: Vec<f64>,
    /// Pre-drawn stochastic-rounding uniforms, one per padded rotated
    /// coordinate (the seed's per-coordinate draw order).
    unis: Vec<f64>,
    /// Min/max of the rotated input (the wire header).
    mn: f64,
    mx: f64,
}

impl SureshHadamard {
    /// `q` quantization points per coordinate (q=8 ⇒ 3 bits/coord).
    pub fn new(d: usize, q: u32, shared: &mut Rng) -> Self {
        assert!(q >= 2);
        SureshHadamard {
            rotation: Rotation::new(d, shared),
            levels: q - 1,
            rx: Vec::new(),
            unis: Vec::new(),
            mn: 0.0,
            mx: 0.0,
        }
    }

    fn width(&self) -> u32 {
        width_for(self.levels as u64 + 1)
    }

    /// Dequantize all padded fields into `rz` (recycled to padded length)
    /// and inverse-rotate in place — the shared first stage of every
    /// decode entry point, expression-identical to the seed's scalar
    /// decode loop followed by [`Rotation::inverse`].
    fn dequant_rotate(&self, msg: &Message, rz: &mut Vec<f64>) {
        const BLOCK: usize = 128;
        let dp = self.rotation.padded_dim();
        let mut r = BitReader::new(&msg.bytes);
        let mn = r.read_f64();
        let mx = r.read_f64();
        let range = mx - mn;
        let w_lvl = self.width();
        let levels = self.levels as f64;
        rz.clear();
        rz.resize(dp, 0.0);
        let mut fields = [0u64; BLOCK];
        let mut done = 0;
        while done < dp {
            let take = (dp - done).min(BLOCK);
            r.read_block(w_lvl, &mut fields[..take]);
            for (j, &f) in fields[..take].iter().enumerate() {
                rz[done + j] = mn + f as f64 / levels * range;
            }
            done += take;
        }
        self.rotation.inverse_in_place(rz);
    }
}

impl VectorCodec for SureshHadamard {
    fn name(&self) -> String {
        format!("Hadamard(q={})", self.levels + 1)
    }

    fn dim(&self) -> usize {
        self.rotation.d
    }

    /// Sequential pre-pass: one-pass rotation into scratch, min/max
    /// header, and one bulk uniform per padded coordinate (the seed's
    /// draw order and count).
    fn encode_prepare(&mut self, x: &[f64], rng: &mut Rng) {
        self.rotation.forward_into(x, &mut self.rx);
        self.mn = self.rx.iter().cloned().fold(f64::INFINITY, f64::min);
        self.mx = self.rx.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        self.unis.resize(self.rx.len(), 0.0);
        rng.fill_uniform(&mut self.unis);
    }

    fn encode(&mut self, x: &[f64], rng: &mut Rng) -> Message {
        self.encode_prepare(x, rng);
        let dp = self.rotation.padded_dim();
        let mut w = BitWriter::with_capacity(dp * self.width() as usize + 128);
        self.encode_range(x, 0, dp, &mut w);
        let (bytes, bits) = w.finish();
        Message { bytes, bits }
    }

    /// Zero-realloc encode: same kernel, recycled scratch bytes.
    fn encode_into(&mut self, x: &[f64], rng: &mut Rng, out: &mut Message) {
        self.encode_prepare(x, rng);
        let dp = self.rotation.padded_dim();
        let mut w = BitWriter::reusing(std::mem::take(&mut out.bytes));
        self.encode_range(x, 0, dp, &mut w);
        let (bytes, bits) = w.finish();
        out.bytes = bytes;
        out.bits = bits;
    }

    /// The sharding domain is the padded rotated field count, not `d`.
    fn wire_fields(&self) -> usize {
        self.rotation.padded_dim()
    }

    /// Fused block encode kernel for *rotated field* indices
    /// `lo..lo + len` (of [`Self::wire_fields`]); the min/max header is
    /// emitted by the `lo == 0` chunk. Reads the rotated input and
    /// uniforms prepared by [`Self::encode_prepare`]; `x` is only
    /// shape-checked.
    fn encode_range(&self, x: &[f64], lo: usize, len: usize, w: &mut BitWriter) {
        const BLOCK: usize = 128;
        assert_eq!(x.len(), self.rotation.d);
        assert!(lo + len <= self.rotation.padded_dim());
        assert_eq!(
            self.rx.len(),
            self.rotation.padded_dim(),
            "encode_prepare must precede encode_range"
        );
        let (mn, mx) = (self.mn, self.mx);
        let range = (mx - mn).max(0.0);
        let w_lvl = self.width();
        let levels = self.levels as f64;
        let lmax = self.levels as u64;
        if lo == 0 {
            w.push_f64(mn);
            w.push_f64(mx);
        }
        let mut fields = [0u64; BLOCK];
        let mut done = 0;
        while done < len {
            let take = (len - done).min(BLOCK);
            let base = lo + done;
            for (j, f) in fields[..take].iter_mut().enumerate() {
                let v = self.rx[base + j];
                let scaled = if range > 0.0 {
                    (v - mn) / range * levels
                } else {
                    0.0
                };
                let low = scaled.floor();
                *f = (low as u64 + u64::from(self.unis[base + j] < scaled - low)).min(lmax);
            }
            w.push_block(&fields[..take], w_lvl);
            done += take;
        }
    }

    fn supports_encode_range(&self) -> bool {
        true
    }

    fn encode_chunk_align(&self) -> usize {
        byte_align_fields(self.width())
    }

    fn decode(&self, msg: &Message, _reference: &[f64]) -> Vec<f64> {
        let mut rz = Vec::new();
        self.dequant_rotate(msg, &mut rz);
        rz.truncate(self.rotation.d);
        rz
    }

    fn decode_into(&self, msg: &Message, _reference: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), self.rotation.d);
        let mut rz = Vec::new();
        self.dequant_rotate(msg, &mut rz);
        out.copy_from_slice(&rz[..self.rotation.d]);
    }

    /// Fused fold: dequantize + inverse-rotate once, accumulate the
    /// unpadded prefix (no decoded vector is handed to the caller; the
    /// padded scratch is a local allocation because the codec stays
    /// `Sync` for the chunk-sharded folds).
    fn decode_accumulate_into(&self, msg: &Message, _reference: &[f64], weight: f64, acc: &mut [f64]) {
        assert_eq!(acc.len(), self.rotation.d);
        let mut rz = Vec::new();
        self.dequant_rotate(msg, &mut rz);
        for (a, zi) in acc.iter_mut().zip(&rz[..self.rotation.d]) {
            *a += weight * zi;
        }
    }

    /// Range fold: the global rotation forces a full dequant + inverse
    /// per call, so this only trims the final accumulate to the chunk —
    /// correct under `fold_mean_chunked`, but no faster than the
    /// sequential fold. Bit-identical to decode + slice-accumulate.
    fn decode_accumulate_range(
        &self,
        msg: &Message,
        _reference: &[f64],
        weight: f64,
        lo: usize,
        acc: &mut [f64],
    ) {
        assert!(lo + acc.len() <= self.rotation.d);
        let mut rz = Vec::new();
        self.dequant_rotate(msg, &mut rz);
        for (a, zi) in acc.iter_mut().zip(&rz[lo..lo + acc.len()]) {
            *a += weight * zi;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbiased_through_rotation() {
        let d = 16;
        let mut shared = Rng::new(14);
        let mut c = SureshHadamard::new(d, 16, &mut shared);
        let x: Vec<f64> = (0..d).map(|i| 5.0 + (i as f64) * 0.1).collect();
        let mut rng = Rng::new(15);
        let trials = 40_000;
        let mut acc = vec![0.0; d];
        for _ in 0..trials {
            let msg = c.encode(&x, &mut rng);
            let z = c.decode(&msg, &[]);
            for (a, zi) in acc.iter_mut().zip(&z) {
                *a += zi;
            }
        }
        for (a, xi) in acc.iter().zip(&x) {
            let mean = a / trials as f64;
            assert!((mean - xi).abs() < 0.05, "{mean} vs {xi}");
        }
    }

    #[test]
    fn bit_cost() {
        let mut shared = Rng::new(16);
        let mut c = SureshHadamard::new(100, 8, &mut shared); // pads to 128
        let mut rng = Rng::new(17);
        let msg = c.encode(&vec![1.0; 100], &mut rng);
        assert_eq!(msg.bits, 128 + 128 * 3);
        assert_eq!(c.wire_fields(), 128);
    }
}
