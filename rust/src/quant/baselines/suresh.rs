//! Suresh et al. (ICML 2017) structured-rotation stochastic quantization —
//! the "Hadamard" baseline of the paper's Experiments 2–3.
//!
//! Scheme: rotate by `HD` (shared-random sign diagonal), then stochastic
//! uniform quantization of the rotated vector between its per-vector min
//! and max with `L` levels. Cost: `d·⌈log₂ L⌉` bits + two floats. Like
//! QSGD (and unlike the lattice scheme) the error scales with the input
//! *norm*, which is exactly the gap the paper exposes.

use crate::quant::bits::{width_for, BitReader, BitWriter};
use crate::quant::hadamard::Rotation;
use crate::quant::{Message, VectorCodec};
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct SureshHadamard {
    pub rotation: Rotation,
    pub levels: u32,
}

impl SureshHadamard {
    /// `q` quantization points per coordinate (q=8 ⇒ 3 bits/coord).
    pub fn new(d: usize, q: u32, shared: &mut Rng) -> Self {
        assert!(q >= 2);
        SureshHadamard {
            rotation: Rotation::new(d, shared),
            levels: q - 1,
        }
    }

    fn width(&self) -> u32 {
        width_for(self.levels as u64 + 1)
    }
}

impl VectorCodec for SureshHadamard {
    fn name(&self) -> String {
        format!("Hadamard(q={})", self.levels + 1)
    }

    fn dim(&self) -> usize {
        self.rotation.d
    }

    fn encode(&mut self, x: &[f64], rng: &mut Rng) -> Message {
        let rx = self.rotation.forward(x);
        let mn = rx.iter().cloned().fold(f64::INFINITY, f64::min);
        let mx = rx.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let range = (mx - mn).max(0.0);
        let w_lvl = self.width();
        let mut w = BitWriter::with_capacity(rx.len() * w_lvl as usize + 128);
        w.push_f64(mn);
        w.push_f64(mx);
        for &v in &rx {
            let scaled = if range > 0.0 {
                (v - mn) / range * self.levels as f64
            } else {
                0.0
            };
            let low = scaled.floor();
            let lvl =
                (low as u64 + if rng.next_f64() < scaled - low { 1 } else { 0 })
                    .min(self.levels as u64);
            w.push(lvl, w_lvl);
        }
        let (bytes, bits) = w.finish();
        Message { bytes, bits }
    }

    fn decode(&self, msg: &Message, _reference: &[f64]) -> Vec<f64> {
        let dp = self.rotation.padded_dim();
        let mut r = BitReader::new(&msg.bytes);
        let mn = r.read_f64();
        let mx = r.read_f64();
        let range = mx - mn;
        let w_lvl = self.width();
        let rz: Vec<f64> = (0..dp)
            .map(|_| mn + r.read(w_lvl) as f64 / self.levels as f64 * range)
            .collect();
        self.rotation.inverse(&rz)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbiased_through_rotation() {
        let d = 16;
        let mut shared = Rng::new(14);
        let mut c = SureshHadamard::new(d, 16, &mut shared);
        let x: Vec<f64> = (0..d).map(|i| 5.0 + (i as f64) * 0.1).collect();
        let mut rng = Rng::new(15);
        let trials = 40_000;
        let mut acc = vec![0.0; d];
        for _ in 0..trials {
            let msg = c.encode(&x, &mut rng);
            let z = c.decode(&msg, &[]);
            for (a, zi) in acc.iter_mut().zip(&z) {
                *a += zi;
            }
        }
        for (a, xi) in acc.iter().zip(&x) {
            let mean = a / trials as f64;
            assert!((mean - xi).abs() < 0.05, "{mean} vs {xi}");
        }
    }

    #[test]
    fn bit_cost() {
        let mut shared = Rng::new(16);
        let mut c = SureshHadamard::new(100, 8, &mut shared); // pads to 128
        let mut rng = Rng::new(17);
        let msg = c.encode(&vec![1.0; 100], &mut rng);
        assert_eq!(msg.bits, 128 + 128 * 3);
    }
}
