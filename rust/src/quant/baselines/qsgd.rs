//! QSGD (Alistarh et al., NeurIPS 2017) with the two normalizations used
//! by the paper's experiments:
//!
//! * **L2** — the original scheme: coordinates quantized stochastically
//!   onto `{0, 1/L, …, 1}·‖x‖₂` with a sign bit.
//! * **L∞** — the variant in the released QSGD implementation referenced
//!   by Experiment 1: normalize by the coordinate range `max(x) − min(x)`
//!   and quantize `(x − min)/range` (no sign bit; min/max shipped).
//!
//! Wire cost: `d·(⌈log₂(L+1)⌉ [+1 sign])` bits plus one or two 64-bit
//! floats of side information — exactly the overhead the paper notes.

use crate::quant::bits::{width_for, BitReader, BitWriter};
use crate::quant::{Message, VectorCodec};
use crate::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QsgdNorm {
    L2,
    Linf,
}

#[derive(Clone, Debug)]
pub struct Qsgd {
    pub d: usize,
    /// Number of non-zero quantization levels L (paper's `qlevel − 1`;
    /// q=8 ⇒ levels 0..=7 ⇒ 3 bits).
    pub levels: u32,
    pub norm: QsgdNorm,
}

impl Qsgd {
    pub fn new(d: usize, q: u32, norm: QsgdNorm) -> Self {
        assert!(q >= 2);
        Qsgd {
            d,
            levels: q - 1,
            norm,
        }
    }

    fn level_width(&self) -> u32 {
        width_for(self.levels as u64 + 1)
    }
}

impl VectorCodec for Qsgd {
    fn name(&self) -> String {
        match self.norm {
            QsgdNorm::L2 => format!("QSGD-L2(q={})", self.levels + 1),
            QsgdNorm::Linf => format!("QSGD-Linf(q={})", self.levels + 1),
        }
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn encode(&mut self, x: &[f64], rng: &mut Rng) -> Message {
        assert_eq!(x.len(), self.d);
        let w_lvl = self.level_width();
        match self.norm {
            QsgdNorm::L2 => {
                let norm = crate::linalg::norm2(x);
                let mut w = BitWriter::with_capacity(self.d * (w_lvl as usize + 1) + 64);
                w.push_f64(norm);
                for &v in x {
                    let sign = if v < 0.0 { 1u64 } else { 0u64 };
                    let scaled = if norm > 0.0 {
                        v.abs() / norm * self.levels as f64
                    } else {
                        0.0
                    };
                    let low = scaled.floor();
                    let lvl = low as u64
                        + if rng.next_f64() < scaled - low { 1 } else { 0 };
                    w.push(sign, 1);
                    w.push(lvl.min(self.levels as u64), w_lvl);
                }
                let (bytes, bits) = w.finish();
                Message { bytes, bits }
            }
            QsgdNorm::Linf => {
                let mn = x.iter().cloned().fold(f64::INFINITY, f64::min);
                let mx = x.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let range = (mx - mn).max(0.0);
                let mut w = BitWriter::with_capacity(self.d * w_lvl as usize + 128);
                w.push_f64(mn);
                w.push_f64(mx);
                for &v in x {
                    let scaled = if range > 0.0 {
                        (v - mn) / range * self.levels as f64
                    } else {
                        0.0
                    };
                    let low = scaled.floor();
                    let lvl = (low as u64
                        + if rng.next_f64() < scaled - low { 1 } else { 0 })
                    .min(self.levels as u64);
                    w.push(lvl, w_lvl);
                }
                let (bytes, bits) = w.finish();
                Message { bytes, bits }
            }
        }
    }

    fn decode(&self, msg: &Message, _reference: &[f64]) -> Vec<f64> {
        let mut r = BitReader::new(&msg.bytes);
        let w_lvl = self.level_width();
        match self.norm {
            QsgdNorm::L2 => {
                let norm = r.read_f64();
                (0..self.d)
                    .map(|_| {
                        let sign = if r.read(1) == 1 { -1.0 } else { 1.0 };
                        let lvl = r.read(w_lvl) as f64;
                        sign * norm * lvl / self.levels as f64
                    })
                    .collect()
            }
            QsgdNorm::Linf => {
                let mn = r.read_f64();
                let mx = r.read_f64();
                let range = mx - mn;
                (0..self.d)
                    .map(|_| mn + r.read(w_lvl) as f64 / self.levels as f64 * range)
                    .collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dist2, norm2};

    #[test]
    fn l2_unbiased() {
        let d = 8;
        let mut c = Qsgd::new(d, 8, QsgdNorm::L2);
        let x = vec![0.5, -1.0, 2.0, 0.0, -0.25, 3.0, -2.5, 1.25];
        let mut rng = Rng::new(9);
        let trials = 50_000;
        let mut acc = vec![0.0; d];
        for _ in 0..trials {
            let msg = c.encode(&x, &mut rng);
            let z = c.decode(&msg, &[]);
            for (a, zi) in acc.iter_mut().zip(&z) {
                *a += zi;
            }
        }
        let norm = norm2(&x);
        for (a, xi) in acc.iter().zip(&x) {
            let mean = a / trials as f64;
            let tol = 5.0 * norm / 7.0 / (trials as f64).sqrt() + 1e-9;
            assert!((mean - xi).abs() < tol, "{mean} vs {xi}");
        }
    }

    #[test]
    fn linf_unbiased() {
        let d = 6;
        let mut c = Qsgd::new(d, 16, QsgdNorm::Linf);
        let x = vec![10.0, 10.3, 9.8, 10.05, 10.21, 9.93]; // non-origin-centered
        let mut rng = Rng::new(10);
        let trials = 50_000;
        let mut acc = vec![0.0; d];
        for _ in 0..trials {
            let msg = c.encode(&x, &mut rng);
            let z = c.decode(&msg, &[]);
            for (a, zi) in acc.iter_mut().zip(&z) {
                *a += zi;
            }
        }
        for (a, xi) in acc.iter().zip(&x) {
            let mean = a / trials as f64;
            assert!((mean - xi).abs() < 0.005, "{mean} vs {xi}");
        }
    }

    #[test]
    fn bit_cost_formula() {
        let mut c = Qsgd::new(100, 8, QsgdNorm::L2);
        let mut rng = Rng::new(1);
        let msg = c.encode(&vec![1.0; 100], &mut rng);
        assert_eq!(msg.bits, 64 + 100 * (1 + 3));
        let mut c = Qsgd::new(100, 8, QsgdNorm::Linf);
        let msg = c.encode(&vec![1.0; 100], &mut rng);
        assert_eq!(msg.bits, 128 + 100 * 3);
    }

    #[test]
    fn zero_vector_roundtrip() {
        for norm in [QsgdNorm::L2, QsgdNorm::Linf] {
            let mut c = Qsgd::new(4, 8, norm);
            let mut rng = Rng::new(2);
            let msg = c.encode(&[0.0; 4], &mut rng);
            let z = c.decode(&msg, &[]);
            assert!(dist2(&z, &[0.0; 4]) < 1e-12);
        }
    }
}
