//! QSGD (Alistarh et al., NeurIPS 2017) with the two normalizations used
//! by the paper's experiments:
//!
//! * **L2** — the original scheme: coordinates quantized stochastically
//!   onto `{0, 1/L, …, 1}·‖x‖₂` with a sign bit.
//! * **L∞** — the variant in the released QSGD implementation referenced
//!   by Experiment 1: normalize by the coordinate range `max(x) − min(x)`
//!   and quantize `(x − min)/range` (no sign bit; min/max shipped).
//!
//! Wire cost: `d·(⌈log₂(L+1)⌉ [+1 sign])` bits plus one or two 64-bit
//! floats of side information — exactly the overhead the paper notes.
//!
//! §Perf: both normalizations ride the full fast-path surface (see
//! [`super`] §Perf) — the wire format is a byte-aligned float header
//! followed by `d` fixed-width fields (L2 packs sign and level into one
//! `1 + ⌈log₂(L+1)⌉`-bit field, LSB = sign, exactly the seed's
//! push(sign, 1) + push(level, w) stream), so encode is a
//! [`BitWriter::push_block`] kernel fed by bulk pre-drawn uniforms
//! ([`crate::rng::Rng::fill_uniform`] in [`VectorCodec::encode_prepare`],
//! stream-identical to the seed's per-coordinate draws) and every decode
//! entry point is one `decode_fold` block loop over
//! [`BitReader::read_block`]. Fixed-width fields make the stream
//! random-access: `decode_accumulate_range` seeks straight to a chunk and
//! `encode_range` shards across cores ([`crate::quant::encode_chunked`]),
//! all bit-identical to the seed scalar path (pinned in
//! `rust/tests/prop.rs`).

use crate::quant::bits::{byte_align_fields, width_for, BitReader, BitWriter};
use crate::quant::{Message, VectorCodec};
use crate::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QsgdNorm {
    L2,
    Linf,
}

#[derive(Clone, Debug)]
pub struct Qsgd {
    pub d: usize,
    /// Number of non-zero quantization levels L (paper's `qlevel − 1`;
    /// q=8 ⇒ levels 0..=7 ⇒ 3 bits).
    pub levels: u32,
    pub norm: QsgdNorm,
    /// Header floats captured by `encode_prepare` (L2: `[‖x‖₂, 0]`;
    /// L∞: `[min, max]`).
    hdr: [f64; 2],
    /// Pre-drawn stochastic-rounding uniforms, one per coordinate in
    /// coordinate order — the same stream the seed drew with one
    /// `next_f64` per coordinate.
    unis: Vec<f64>,
}

impl Qsgd {
    pub fn new(d: usize, q: u32, norm: QsgdNorm) -> Self {
        assert!(q >= 2);
        Qsgd {
            d,
            levels: q - 1,
            norm,
            hdr: [0.0; 2],
            unis: Vec::new(),
        }
    }

    fn level_width(&self) -> u32 {
        width_for(self.levels as u64 + 1)
    }

    /// Per-coordinate field width: L2 carries the sign in the field's
    /// LSB (`sign | level << 1` ≡ the seed's push(sign, 1) +
    /// push(level, w) in the LSB-first stream), L∞ the bare level.
    fn field_width(&self) -> u32 {
        match self.norm {
            QsgdNorm::L2 => self.level_width() + 1,
            QsgdNorm::Linf => self.level_width(),
        }
    }

    /// Header length in bits (whole bytes, so range chunks stay
    /// byte-alignable).
    fn header_bits(&self) -> u64 {
        match self.norm {
            QsgdNorm::L2 => 64,
            QsgdNorm::Linf => 128,
        }
    }

    /// The shared fused decode loop: the header is read, then fields for
    /// coordinates `lo..lo + len` are pulled through the word-granular
    /// block kernel and each reconstructed value handed to
    /// `emit(index, value)`. Every decode entry point is this loop with a
    /// different sink, so they are value-identical by construction (and
    /// expression-identical to the seed's scalar decode).
    fn decode_fold(&self, msg: &Message, lo: usize, len: usize, mut emit: impl FnMut(usize, f64)) {
        const BLOCK: usize = 128;
        let mut r = BitReader::new(&msg.bytes);
        let width = self.field_width();
        let levels = self.levels as f64;
        let mut fields = [0u64; BLOCK];
        match self.norm {
            QsgdNorm::L2 => {
                let norm = r.read_f64();
                r.seek(64 + lo as u64 * width as u64);
                let mut done = 0;
                while done < len {
                    let take = (len - done).min(BLOCK);
                    r.read_block(width, &mut fields[..take]);
                    for (j, &f) in fields[..take].iter().enumerate() {
                        let sign = if f & 1 == 1 { -1.0 } else { 1.0 };
                        let lvl = (f >> 1) as f64;
                        emit(lo + done + j, sign * norm * lvl / levels);
                    }
                    done += take;
                }
            }
            QsgdNorm::Linf => {
                let mn = r.read_f64();
                let mx = r.read_f64();
                let range = mx - mn;
                r.seek(128 + lo as u64 * width as u64);
                let mut done = 0;
                while done < len {
                    let take = (len - done).min(BLOCK);
                    r.read_block(width, &mut fields[..take]);
                    for (j, &f) in fields[..take].iter().enumerate() {
                        emit(lo + done + j, mn + f as f64 / levels * range);
                    }
                    done += take;
                }
            }
        }
    }
}

impl VectorCodec for Qsgd {
    fn name(&self) -> String {
        match self.norm {
            QsgdNorm::L2 => format!("QSGD-L2(q={})", self.levels + 1),
            QsgdNorm::Linf => format!("QSGD-Linf(q={})", self.levels + 1),
        }
    }

    fn dim(&self) -> usize {
        self.d
    }

    /// Sequential pre-pass: the normalization header over the whole
    /// input, plus one bulk uniform per coordinate (stream-identical to
    /// the seed's unconditional per-coordinate draw — including for the
    /// zero vector, which still consumed `d` draws).
    fn encode_prepare(&mut self, x: &[f64], rng: &mut Rng) {
        assert_eq!(x.len(), self.d);
        match self.norm {
            QsgdNorm::L2 => self.hdr = [crate::linalg::norm2(x), 0.0],
            QsgdNorm::Linf => {
                let mn = x.iter().cloned().fold(f64::INFINITY, f64::min);
                let mx = x.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                self.hdr = [mn, mx];
            }
        }
        self.unis.resize(self.d, 0.0);
        rng.fill_uniform(&mut self.unis);
    }

    fn encode(&mut self, x: &[f64], rng: &mut Rng) -> Message {
        let mut w = BitWriter::with_capacity(
            self.d * self.field_width() as usize + self.header_bits() as usize,
        );
        self.encode_prepare(x, rng);
        self.encode_range(x, 0, self.d, &mut w);
        let (bytes, bits) = w.finish();
        Message { bytes, bits }
    }

    /// Zero-realloc encode: same kernel, recycled scratch bytes.
    fn encode_into(&mut self, x: &[f64], rng: &mut Rng, out: &mut Message) {
        let mut w = BitWriter::reusing(std::mem::take(&mut out.bytes));
        self.encode_prepare(x, rng);
        self.encode_range(x, 0, self.d, &mut w);
        let (bytes, bits) = w.finish();
        out.bytes = bytes;
        out.bits = bits;
    }

    /// Fused block encode kernel for coordinates `lo..lo + len`
    /// (header emitted by the `lo == 0` chunk). Requires a preceding
    /// [`Self::encode_prepare`] for the same `x`.
    fn encode_range(&self, x: &[f64], lo: usize, len: usize, w: &mut BitWriter) {
        const BLOCK: usize = 128;
        assert_eq!(x.len(), self.d);
        assert!(lo + len <= self.d);
        assert_eq!(
            self.unis.len(),
            self.d,
            "encode_prepare must precede encode_range"
        );
        let width = self.field_width();
        let levels = self.levels as f64;
        let lmax = self.levels as u64;
        let mut fields = [0u64; BLOCK];
        if lo == 0 {
            w.push_f64(self.hdr[0]);
            if self.norm == QsgdNorm::Linf {
                w.push_f64(self.hdr[1]);
            }
        }
        match self.norm {
            QsgdNorm::L2 => {
                let norm = self.hdr[0];
                let mut done = 0;
                while done < len {
                    let take = (len - done).min(BLOCK);
                    let base = lo + done;
                    for (j, f) in fields[..take].iter_mut().enumerate() {
                        let v = x[base + j];
                        let sign = if v < 0.0 { 1u64 } else { 0u64 };
                        let scaled = if norm > 0.0 {
                            v.abs() / norm * levels
                        } else {
                            0.0
                        };
                        let low = scaled.floor();
                        let lvl =
                            low as u64 + u64::from(self.unis[base + j] < scaled - low);
                        *f = sign | (lvl.min(lmax) << 1);
                    }
                    w.push_block(&fields[..take], width);
                    done += take;
                }
            }
            QsgdNorm::Linf => {
                let (mn, mx) = (self.hdr[0], self.hdr[1]);
                let range = (mx - mn).max(0.0);
                let mut done = 0;
                while done < len {
                    let take = (len - done).min(BLOCK);
                    let base = lo + done;
                    for (j, f) in fields[..take].iter_mut().enumerate() {
                        let v = x[base + j];
                        let scaled = if range > 0.0 {
                            (v - mn) / range * levels
                        } else {
                            0.0
                        };
                        let low = scaled.floor();
                        *f = (low as u64 + u64::from(self.unis[base + j] < scaled - low))
                            .min(lmax);
                    }
                    w.push_block(&fields[..take], width);
                    done += take;
                }
            }
        }
    }

    fn supports_encode_range(&self) -> bool {
        true
    }

    fn encode_chunk_align(&self) -> usize {
        byte_align_fields(self.field_width())
    }

    fn decode(&self, msg: &Message, reference: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.d];
        self.decode_into(msg, reference, &mut out);
        out
    }

    fn decode_into(&self, msg: &Message, _reference: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), self.d);
        self.decode_fold(msg, 0, self.d, |idx, v| out[idx] = v);
    }

    /// Fused streaming-fold kernel: one pass bitstream → accumulator.
    fn decode_accumulate_into(&self, msg: &Message, _reference: &[f64], weight: f64, acc: &mut [f64]) {
        assert_eq!(acc.len(), self.d);
        self.decode_fold(msg, 0, self.d, |idx, v| acc[idx] += weight * v);
    }

    /// Chunk-sharded fold kernel: seeks past the header straight to
    /// coordinate `lo`'s bit offset (fixed-width fields ⇒ random access).
    fn decode_accumulate_range(
        &self,
        msg: &Message,
        _reference: &[f64],
        weight: f64,
        lo: usize,
        acc: &mut [f64],
    ) {
        assert!(lo + acc.len() <= self.d);
        self.decode_fold(msg, lo, acc.len(), |idx, v| acc[idx - lo] += weight * v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dist2, norm2};

    #[test]
    fn l2_unbiased() {
        let d = 8;
        let mut c = Qsgd::new(d, 8, QsgdNorm::L2);
        let x = vec![0.5, -1.0, 2.0, 0.0, -0.25, 3.0, -2.5, 1.25];
        let mut rng = Rng::new(9);
        let trials = 50_000;
        let mut acc = vec![0.0; d];
        for _ in 0..trials {
            let msg = c.encode(&x, &mut rng);
            let z = c.decode(&msg, &[]);
            for (a, zi) in acc.iter_mut().zip(&z) {
                *a += zi;
            }
        }
        let norm = norm2(&x);
        for (a, xi) in acc.iter().zip(&x) {
            let mean = a / trials as f64;
            let tol = 5.0 * norm / 7.0 / (trials as f64).sqrt() + 1e-9;
            assert!((mean - xi).abs() < tol, "{mean} vs {xi}");
        }
    }

    #[test]
    fn linf_unbiased() {
        let d = 6;
        let mut c = Qsgd::new(d, 16, QsgdNorm::Linf);
        let x = vec![10.0, 10.3, 9.8, 10.05, 10.21, 9.93]; // non-origin-centered
        let mut rng = Rng::new(10);
        let trials = 50_000;
        let mut acc = vec![0.0; d];
        for _ in 0..trials {
            let msg = c.encode(&x, &mut rng);
            let z = c.decode(&msg, &[]);
            for (a, zi) in acc.iter_mut().zip(&z) {
                *a += zi;
            }
        }
        for (a, xi) in acc.iter().zip(&x) {
            let mean = a / trials as f64;
            assert!((mean - xi).abs() < 0.005, "{mean} vs {xi}");
        }
    }

    #[test]
    fn bit_cost_formula() {
        let mut c = Qsgd::new(100, 8, QsgdNorm::L2);
        let mut rng = Rng::new(1);
        let msg = c.encode(&vec![1.0; 100], &mut rng);
        assert_eq!(msg.bits, 64 + 100 * (1 + 3));
        let mut c = Qsgd::new(100, 8, QsgdNorm::Linf);
        let msg = c.encode(&vec![1.0; 100], &mut rng);
        assert_eq!(msg.bits, 128 + 100 * 3);
    }

    #[test]
    fn zero_vector_roundtrip() {
        for norm in [QsgdNorm::L2, QsgdNorm::Linf] {
            let mut c = Qsgd::new(4, 8, norm);
            let mut rng = Rng::new(2);
            let msg = c.encode(&[0.0; 4], &mut rng);
            let z = c.decode(&msg, &[]);
            assert!(dist2(&z, &[0.0; 4]) < 1e-12);
        }
    }

    #[test]
    fn zero_vector_still_consumes_one_draw_per_coordinate() {
        // The seed's scalar loop evaluated `rng.next_f64()` even when the
        // norm was zero; the bulk prepare must keep that draw count so
        // downstream shared-randomness consumers see the same stream.
        for norm in [QsgdNorm::L2, QsgdNorm::Linf] {
            let d = 7;
            let mut c = Qsgd::new(d, 8, norm);
            let mut rng = Rng::new(3);
            let _ = c.encode(&vec![0.0; d], &mut rng);
            let mut expect = Rng::new(3);
            for _ in 0..d {
                expect.next_f64();
            }
            assert_eq!(rng.next_u64(), expect.next_u64());
        }
    }
}
