//! EF-SignSGD / 1-bit SGD with error feedback (Seide et al. 2014,
//! Karimireddy et al. 2019) — the 1-bit comparator of Experiment 7.
//!
//! Encoder state: the error memory `e`. Each step compresses `p = x + e`
//! to `sign(p)·‖p‖₁/d` (1 bit/coordinate + one float) and stores the
//! residual back into `e`. The decode side is stateless.

use crate::quant::bits::{BitReader, BitWriter};
use crate::quant::{Message, VectorCodec};
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct EfSignSgd {
    pub d: usize,
    /// Error-feedback memory (encoder side).
    pub error: Vec<f64>,
}

impl EfSignSgd {
    pub fn new(d: usize) -> Self {
        EfSignSgd {
            d,
            error: vec![0.0; d],
        }
    }

    pub fn reset(&mut self) {
        self.error.iter_mut().for_each(|e| *e = 0.0);
    }
}

impl VectorCodec for EfSignSgd {
    fn name(&self) -> String {
        "EF-SignSGD".to_string()
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn encode(&mut self, x: &[f64], _rng: &mut Rng) -> Message {
        assert_eq!(x.len(), self.d);
        let p: Vec<f64> = x.iter().zip(&self.error).map(|(a, e)| a + e).collect();
        let scale = crate::linalg::norm1(&p) / self.d as f64;
        let mut w = BitWriter::with_capacity(self.d + 64);
        w.push_f64(scale);
        for &v in &p {
            w.push(if v < 0.0 { 1 } else { 0 }, 1);
        }
        // Update error memory: e ← p − decode(msg).
        for (e, &v) in self.error.iter_mut().zip(&p) {
            let dec = if v < 0.0 { -scale } else { scale };
            *e = v - dec;
        }
        let (bytes, bits) = w.finish();
        Message { bytes, bits }
    }

    fn decode(&self, msg: &Message, _reference: &[f64]) -> Vec<f64> {
        let mut r = BitReader::new(&msg.bytes);
        let scale = r.read_f64();
        (0..self.d)
            .map(|_| if r.read(1) == 1 { -scale } else { scale })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_bit_per_coordinate() {
        let mut c = EfSignSgd::new(100);
        let mut rng = Rng::new(30);
        let msg = c.encode(&vec![1.0; 100], &mut rng);
        assert_eq!(msg.bits, 64 + 100);
    }

    #[test]
    fn error_feedback_accumulates_residual() {
        let mut c = EfSignSgd::new(2);
        let mut rng = Rng::new(31);
        let x = vec![1.0, 0.1];
        let msg = c.encode(&x, &mut rng);
        let z = c.decode(&msg, &[]);
        // residual stored:
        for i in 0..2 {
            assert!((c.error[i] - (x[i] - z[i])).abs() < 1e-12);
        }
        // Feeding zero next step flushes part of the error back out.
        let msg2 = c.encode(&[0.0, 0.0], &mut rng);
        let z2 = c.decode(&msg2, &[]);
        assert!(z2.iter().any(|v| *v != 0.0));
    }

    #[test]
    fn ef_mean_converges_to_signal() {
        // Over many steps of a constant signal, EF makes the *cumulative*
        // decoded sum track the cumulative input (the EF guarantee).
        let d = 4;
        let mut c = EfSignSgd::new(d);
        let mut rng = Rng::new(32);
        let x = vec![0.9, -0.4, 0.05, 0.0];
        let steps = 500;
        let mut acc = vec![0.0; d];
        for _ in 0..steps {
            let msg = c.encode(&x, &mut rng);
            let z = c.decode(&msg, &[]);
            for (a, zi) in acc.iter_mut().zip(&z) {
                *a += zi;
            }
        }
        for (a, xi) in acc.iter().zip(&x) {
            let mean = a / steps as f64;
            assert!((mean - xi).abs() < 0.05, "{mean} vs {xi}");
        }
    }
}
