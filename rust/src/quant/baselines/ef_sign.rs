//! EF-SignSGD / 1-bit SGD with error feedback (Seide et al. 2014,
//! Karimireddy et al. 2019) — the 1-bit comparator of Experiment 7.
//!
//! Encoder state: the error memory `e`. Each step compresses `p = x + e`
//! to `sign(p)·‖p‖₁/d` (1 bit/coordinate + one float) and stores the
//! residual back into `e`. The decode side is stateless.
//!
//! §Perf: a 64-bit scale header plus 1-bit sign fields — the full
//! fast-path surface (see [`super`] §Perf). [`VectorCodec::encode_prepare`]
//! is where the statefulness lives: it forms `p = x + e` into scratch,
//! computes the scale, and applies the error-feedback update, leaving
//! `encode_range` a pure `&self` sign-pack over the scratch
//! ([`BitWriter::push_block`], 64 signs per word store) that threads can
//! shard ([`crate::quant::encode_chunked`]). Every decode entry point is
//! one `decode_fold` block loop; `decode_accumulate_range` seeks straight
//! to its chunk. All bit-identical to the seed scalar path (pinned in
//! `rust/tests/prop.rs`).

use crate::quant::bits::{byte_align_fields, BitReader, BitWriter};
use crate::quant::{Message, VectorCodec};
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct EfSignSgd {
    pub d: usize,
    /// Error-feedback memory (encoder side).
    pub error: Vec<f64>,
    /// `x + e` scratch formed by `encode_prepare` (what the sign fields
    /// are read from).
    p: Vec<f64>,
    /// `‖p‖₁/d` header captured by `encode_prepare`.
    scale: f64,
}

impl EfSignSgd {
    pub fn new(d: usize) -> Self {
        EfSignSgd {
            d,
            error: vec![0.0; d],
            p: Vec::new(),
            scale: 0.0,
        }
    }

    pub fn reset(&mut self) {
        self.error.iter_mut().for_each(|e| *e = 0.0);
    }

    /// The shared fused decode loop (scale header, then 1-bit signs
    /// through the block kernel); every decode entry point is this loop
    /// with a different sink.
    fn decode_fold(&self, msg: &Message, lo: usize, len: usize, mut emit: impl FnMut(usize, f64)) {
        const BLOCK: usize = 128;
        let mut r = BitReader::new(&msg.bytes);
        let scale = r.read_f64();
        r.seek(64 + lo as u64);
        let mut fields = [0u64; BLOCK];
        let mut done = 0;
        while done < len {
            let take = (len - done).min(BLOCK);
            r.read_block(1, &mut fields[..take]);
            for (j, &f) in fields[..take].iter().enumerate() {
                emit(lo + done + j, if f == 1 { -scale } else { scale });
            }
            done += take;
        }
    }
}

impl VectorCodec for EfSignSgd {
    fn name(&self) -> String {
        "EF-SignSGD".to_string()
    }

    fn dim(&self) -> usize {
        self.d
    }

    /// Sequential pre-pass — and the codec's one stateful step: form
    /// `p = x + e`, compute the scale, update the error memory
    /// `e ← p − decode(msg)`. Call it exactly once per logical encode
    /// (`encode`/`encode_into` do; so does `encode_chunked`).
    fn encode_prepare(&mut self, x: &[f64], _rng: &mut Rng) {
        assert_eq!(x.len(), self.d);
        self.p.clear();
        self.p.extend(x.iter().zip(&self.error).map(|(a, e)| a + e));
        self.scale = crate::linalg::norm1(&self.p) / self.d as f64;
        let scale = self.scale;
        for (e, &v) in self.error.iter_mut().zip(&self.p) {
            let dec = if v < 0.0 { -scale } else { scale };
            *e = v - dec;
        }
    }

    fn encode(&mut self, x: &[f64], rng: &mut Rng) -> Message {
        self.encode_prepare(x, rng);
        let mut w = BitWriter::with_capacity(self.d + 64);
        self.encode_range(x, 0, self.d, &mut w);
        let (bytes, bits) = w.finish();
        Message { bytes, bits }
    }

    /// Zero-realloc encode: same kernel, recycled scratch bytes.
    fn encode_into(&mut self, x: &[f64], rng: &mut Rng, out: &mut Message) {
        self.encode_prepare(x, rng);
        let mut w = BitWriter::reusing(std::mem::take(&mut out.bytes));
        self.encode_range(x, 0, self.d, &mut w);
        let (bytes, bits) = w.finish();
        out.bytes = bytes;
        out.bits = bits;
    }

    /// Fused block sign-pack for coordinates `lo..lo + len` over the
    /// prepared `p = x + e` (header emitted by the `lo == 0` chunk).
    /// Requires a preceding [`Self::encode_prepare`] for the same `x`.
    fn encode_range(&self, x: &[f64], lo: usize, len: usize, w: &mut BitWriter) {
        const BLOCK: usize = 128;
        assert_eq!(x.len(), self.d);
        assert!(lo + len <= self.d);
        assert_eq!(
            self.p.len(),
            self.d,
            "encode_prepare must precede encode_range"
        );
        if lo == 0 {
            w.push_f64(self.scale);
        }
        let mut fields = [0u64; BLOCK];
        let mut done = 0;
        while done < len {
            let take = (len - done).min(BLOCK);
            let base = lo + done;
            for (j, f) in fields[..take].iter_mut().enumerate() {
                *f = u64::from(self.p[base + j] < 0.0);
            }
            w.push_block(&fields[..take], 1);
            done += take;
        }
    }

    fn supports_encode_range(&self) -> bool {
        true
    }

    fn encode_chunk_align(&self) -> usize {
        byte_align_fields(1)
    }

    fn decode(&self, msg: &Message, reference: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.d];
        self.decode_into(msg, reference, &mut out);
        out
    }

    fn decode_into(&self, msg: &Message, _reference: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), self.d);
        self.decode_fold(msg, 0, self.d, |idx, v| out[idx] = v);
    }

    /// Fused streaming-fold kernel: one pass bitstream → accumulator.
    fn decode_accumulate_into(&self, msg: &Message, _reference: &[f64], weight: f64, acc: &mut [f64]) {
        assert_eq!(acc.len(), self.d);
        self.decode_fold(msg, 0, self.d, |idx, v| acc[idx] += weight * v);
    }

    /// Chunk-sharded fold kernel: seeks past the header to the chunk's
    /// 1-bit field offset.
    fn decode_accumulate_range(
        &self,
        msg: &Message,
        _reference: &[f64],
        weight: f64,
        lo: usize,
        acc: &mut [f64],
    ) {
        assert!(lo + acc.len() <= self.d);
        self.decode_fold(msg, lo, acc.len(), |idx, v| acc[idx - lo] += weight * v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_bit_per_coordinate() {
        let mut c = EfSignSgd::new(100);
        let mut rng = Rng::new(30);
        let msg = c.encode(&vec![1.0; 100], &mut rng);
        assert_eq!(msg.bits, 64 + 100);
    }

    #[test]
    fn error_feedback_accumulates_residual() {
        let mut c = EfSignSgd::new(2);
        let mut rng = Rng::new(31);
        let x = vec![1.0, 0.1];
        let msg = c.encode(&x, &mut rng);
        let z = c.decode(&msg, &[]);
        // residual stored:
        for i in 0..2 {
            assert!((c.error[i] - (x[i] - z[i])).abs() < 1e-12);
        }
        // Feeding zero next step flushes part of the error back out.
        let msg2 = c.encode(&[0.0, 0.0], &mut rng);
        let z2 = c.decode(&msg2, &[]);
        assert!(z2.iter().any(|v| *v != 0.0));
    }

    #[test]
    fn ef_mean_converges_to_signal() {
        // Over many steps of a constant signal, EF makes the *cumulative*
        // decoded sum track the cumulative input (the EF guarantee).
        let d = 4;
        let mut c = EfSignSgd::new(d);
        let mut rng = Rng::new(32);
        let x = vec![0.9, -0.4, 0.05, 0.0];
        let steps = 500;
        let mut acc = vec![0.0; d];
        for _ in 0..steps {
            let msg = c.encode(&x, &mut rng);
            let z = c.decode(&msg, &[]);
            for (a, zi) in acc.iter_mut().zip(&z) {
                *a += zi;
            }
        }
        for (a, xi) in acc.iter().zip(&x) {
            let mean = a / steps as f64;
            assert!((mean - xi).abs() < 0.05, "{mean} vs {xi}");
        }
    }
}
