//! vQSGD cross-polytope quantizer with repetition (Gandikota et al. 2019)
//! — the sublinear-communication comparator of Experiment 4.
//!
//! A unit vector `v = x/‖x‖₂` lies in the ℓ₁ ball of radius `‖v‖₁`, i.e.
//! in the convex hull of the scaled cross-polytope vertices
//! `{±‖v‖₁ e_i}`. Sampling vertex `sign(v_i)·‖v‖₁·e_i` with probability
//! `|v_i|/‖v‖₁` is unbiased; each repetition costs `⌈log₂(2d)⌉` bits, and
//! `R` repetitions are averaged to divide the variance by `R`. Two floats
//! (`‖x‖₂`, `‖v‖₁`) of side information are shipped once.

use crate::quant::bits::{width_for, BitReader, BitWriter};
use crate::quant::{Message, VectorCodec};
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct VqsgdCrossPolytope {
    pub d: usize,
    /// Number of repetitions R.
    pub reps: u32,
}

impl VqsgdCrossPolytope {
    pub fn new(d: usize, reps: u32) -> Self {
        assert!(reps >= 1);
        VqsgdCrossPolytope { d, reps }
    }

    /// Repetitions that fit a budget of `bits` total (minus side floats).
    pub fn reps_for_bits(d: usize, bits: u64) -> u32 {
        let per = width_for(2 * d as u64) as u64;
        ((bits.saturating_sub(128)) / per).max(1) as u32
    }

    fn idx_width(&self) -> u32 {
        width_for(2 * self.d as u64)
    }

    /// CDF-sample the R repetitions and write the wire fields — the
    /// shared body of `encode`/`encode_into` (they differ only in writer
    /// scratch).
    fn encode_with(&mut self, x: &[f64], rng: &mut Rng, w: &mut BitWriter) {
        assert_eq!(x.len(), self.d);
        let norm2 = crate::linalg::norm2(x);
        if norm2 == 0.0 {
            w.push_f64(0.0);
            w.push_f64(0.0);
            for _ in 0..self.reps {
                w.push(0, self.idx_width());
            }
            return;
        }
        let v: Vec<f64> = x.iter().map(|a| a / norm2).collect();
        let norm1 = crate::linalg::norm1(&v);
        w.push_f64(norm2);
        w.push_f64(norm1);
        // CDF sampling per repetition.
        for _ in 0..self.reps {
            let mut target = rng.next_f64() * norm1;
            let mut pick = self.d - 1;
            for (i, vi) in v.iter().enumerate() {
                target -= vi.abs();
                if target <= 0.0 {
                    pick = i;
                    break;
                }
            }
            let signed_idx = (pick as u64) << 1 | u64::from(v[pick] < 0.0);
            w.push(signed_idx, self.idx_width());
        }
    }
}

impl VectorCodec for VqsgdCrossPolytope {
    fn name(&self) -> String {
        format!("vQSGD-cp(R={})", self.reps)
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn encode(&mut self, x: &[f64], rng: &mut Rng) -> Message {
        let mut w = BitWriter::with_capacity(self.reps as usize * self.idx_width() as usize + 128);
        self.encode_with(x, rng, &mut w);
        let (bytes, bits) = w.finish();
        Message { bytes, bits }
    }

    /// Zero-realloc (message-side) encode: same sampling, recycled
    /// scratch bytes.
    fn encode_into(&mut self, x: &[f64], rng: &mut Rng, out: &mut Message) {
        let mut w = BitWriter::reusing(std::mem::take(&mut out.bytes));
        self.encode_with(x, rng, &mut w);
        let (bytes, bits) = w.finish();
        out.bytes = bytes;
        out.bits = bits;
    }

    fn decode(&self, msg: &Message, reference: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.d];
        self.decode_into(msg, reference, &mut out);
        out
    }

    /// Zero-alloc decode into a caller buffer: replay the R vertex adds
    /// (identical add order, so identical values to `decode`).
    fn decode_into(&self, msg: &Message, _reference: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), self.d);
        let mut r = BitReader::new(&msg.bytes);
        let norm2 = r.read_f64();
        let norm1 = r.read_f64();
        out.fill(0.0);
        if norm2 == 0.0 {
            return;
        }
        let scale = norm2 * norm1 / self.reps as f64;
        for _ in 0..self.reps {
            let signed_idx = r.read(self.idx_width());
            let i = (signed_idx >> 1) as usize;
            // An honest encoder only emits vertex indices < d, but
            // `idx_width` bits can express larger values on hostile
            // payloads. Poison instead of panicking: the NaN fill is
            // caught by the service's float-hygiene screen, and honest
            // messages never take this branch.
            if i >= self.d {
                out.fill(f64::NAN);
                return;
            }
            let sgn = if signed_idx & 1 == 1 { -1.0 } else { 1.0 };
            out[i] += sgn * scale;
        }
    }

    // decode_accumulate_into stays on the allocating default: a vertex
    // index can repeat across repetitions, and bit-identity to
    // decode+axpy requires `weight · (a + b)`, not `weight·a + weight·b`
    // — the materialized decode is the only exact order.
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbiased() {
        let d = 8;
        let mut c = VqsgdCrossPolytope::new(d, 4);
        let x = vec![1.0, -2.0, 0.5, 0.0, 3.0, -0.1, 0.7, -1.3];
        let mut rng = Rng::new(20);
        let trials = 100_000;
        let mut acc = vec![0.0; d];
        for _ in 0..trials {
            let msg = c.encode(&x, &mut rng);
            let z = c.decode(&msg, &[]);
            for (a, zi) in acc.iter_mut().zip(&z) {
                *a += zi;
            }
        }
        for (a, xi) in acc.iter().zip(&x) {
            let mean = a / trials as f64;
            assert!((mean - xi).abs() < 0.05, "{mean} vs {xi}");
        }
    }

    #[test]
    fn bits_sublinear_in_d() {
        let d = 256;
        let mut c = VqsgdCrossPolytope::new(d, VqsgdCrossPolytope::reps_for_bits(d, 128 + 128));
        let mut rng = Rng::new(21);
        let msg = c.encode(&vec![1.0; d], &mut rng);
        // ⌈log2(512)⌉ = 9 bits per repetition; budget keeps it ≪ 32·d.
        assert!(msg.bits < 32 * d as u64 / 4);
    }

    #[test]
    fn variance_halves_with_double_reps() {
        let d = 32;
        let mut rng = Rng::new(22);
        let x: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let var = |reps: u32, rng: &mut Rng| {
            let mut c = VqsgdCrossPolytope::new(d, reps);
            let trials = 4000;
            let mut total = 0.0;
            for _ in 0..trials {
                let msg = c.encode(&x, rng);
                let z = c.decode(&msg, &[]);
                total += crate::linalg::dist2(&z, &x).powi(2);
            }
            total / trials as f64
        };
        let v1 = var(2, &mut rng);
        let v2 = var(4, &mut rng);
        assert!(v2 < v1 * 0.7, "v1={v1} v2={v2}");
    }
}
