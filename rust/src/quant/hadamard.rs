//! RLQSGD — cubic lattice + structured random rotation (Section 6).
//!
//! The rotation `HD` (normalized Walsh–Hadamard times a random ±1
//! diagonal) flattens any vector's coordinates so that
//! `‖HDx‖∞ = O(d^{-1/2}‖x‖₂ √log nd)` (Lemma 24), making the ℓ∞-optimal
//! cubic lattice near-optimal under ℓ₂ (Theorem 5). The diagonal is drawn
//! from shared randomness; `H` is fixed. Inputs whose dimension is not a
//! power of two are zero-padded (standard practice, also done in [36]).

use super::lattice::side_for_y;
use super::lq::LatticeQuantizer;
use super::{Message, VectorCodec};
use crate::rng::Rng;

/// Butterfly layers with stride < `FWHT_BLOCK` run to completion inside
/// one resident chunk before the next chunk is touched (§Perf): 4096
/// f64 = 32 KiB ≈ one L1d, so the low-stride layers — log₂(4096) of the
/// log₂(d) total — never leave cache, instead of streaming the whole
/// vector once per layer.
const FWHT_BLOCK: usize = 1 << 12;

/// One radix-2 butterfly layer at stride `h` (`x.len()` a multiple of 2h).
///
/// The per-group butterfly is [`crate::simd::butterfly2`]: AVX2 lanes
/// when dispatched, the seed's scalar loop otherwise — bit-identical
/// either way (lane-wise IEEE add/sub). Groups with `h < 4` fall into
/// the kernel's scalar tail; those low-stride layers are cache-resident
/// and cheap, so the lanes matter exactly where there is work.
fn radix2_layer(x: &mut [f64], h: usize) {
    for group in x.chunks_mut(2 * h) {
        let (lo, hi) = group.split_at_mut(h);
        crate::simd::butterfly2(lo, hi);
    }
}

/// Fused radix-4 pass covering strides `h` and `2h` in one sweep
/// (`x.len()` a multiple of 4h): both radix-2 stages happen in registers
/// — 4 loads + 4 stores where two radix-2 layers pay 8 of each — with
/// the identical add/sub associativity, so the result is bit-identical.
fn radix4_layer(x: &mut [f64], h: usize) {
    for group in x.chunks_mut(4 * h) {
        let (g01, g23) = group.split_at_mut(2 * h);
        let (g0, g1) = g01.split_at_mut(h);
        let (g2, g3) = g23.split_at_mut(h);
        crate::simd::butterfly4(g0, g1, g2, g3);
    }
}

/// Butterfly layers at strides `h0, 2·h0, …, h1` over one slice, paired
/// into radix-4 passes (a single radix-2 layer leads when the layer
/// count is odd).
fn layers(x: &mut [f64], h0: usize, h1: usize) {
    debug_assert!(h0.is_power_of_two() && h1.is_power_of_two() && h0 <= h1);
    let count = (h1 / h0).trailing_zeros() + 1;
    let mut h = h0;
    if count % 2 == 1 {
        radix2_layer(x, h);
        h *= 2;
    }
    while h < h1 {
        radix4_layer(x, h);
        h *= 4;
    }
}

/// Butterfly layers at strides `h0..=h1` (doubling), cache-blocked: the
/// strides that fit inside a [`FWHT_BLOCK`] chunk are finished per chunk
/// while it is L1-resident; only block-crossing strides stream the full
/// buffer (as fused radix-4 pairs). No-op when `h0 > h1`.
fn fwht_span(x: &mut [f64], mut h0: usize, h1: usize) {
    if h0 > h1 {
        return;
    }
    let block = FWHT_BLOCK.min(x.len());
    let in_block_hi = (block / 2).min(h1);
    if h0 <= in_block_hi {
        for chunk in x.chunks_mut(block) {
            layers(chunk, h0, in_block_hi);
        }
        h0 = in_block_hi * 2;
    }
    if h0 <= h1 {
        layers(x, h0, h1);
    }
}

/// The final butterfly layer (stride d/2) with `scale` fused into its
/// stores: `fl(fl(a±b)·scale)` is exactly what a separate post-pass over
/// the layer's output computes, so the fusion is bit-identical to
/// butterfly-then-normalize.
fn final_layer_scaled(x: &mut [f64], scale: f64) {
    let h = x.len() / 2;
    let (lo, hi) = x.split_at_mut(h);
    crate::simd::butterfly2_scaled(lo, hi, scale);
}

/// The final butterfly layer with a per-element diagonal fused into its
/// stores (the inverse rotation's `sign[i]·norm`). Bit-identical to
/// butterfly, then ·norm, then ·sign: the signs are exact and scaling by
/// a constant after the final rounding is the same operation either way.
fn final_layer_diag(x: &mut [f64], diag: &[f64]) {
    debug_assert_eq!(x.len(), diag.len());
    let h = x.len() / 2;
    let (lo, hi) = x.split_at_mut(h);
    let (dlo, dhi) = diag.split_at(h);
    crate::simd::butterfly2_diag(lo, hi, dlo, dhi);
}

/// In-place normalized fast Walsh–Hadamard transform.
/// `x.len()` must be a power of two. O(d log d).
///
/// §Perf: cache-blocked multi-radix (fused radix-4 passes, one leading
/// radix-2 layer when log₂ d is odd) with the 1/√d normalization folded
/// into the final butterfly layer's stores — one pass fewer over the
/// data than butterflies + normalize, and bit-identical to the plain
/// radix-2 two-pass form (kept as [`fwht_reference`] and pinned by the
/// parity tests below).
pub fn fwht(x: &mut [f64]) {
    let d = x.len();
    assert!(d.is_power_of_two(), "FWHT needs power-of-two length");
    if d == 1 {
        return; // zero layers, norm = 1 exactly
    }
    let norm = 1.0 / (d as f64).sqrt();
    fwht_span(x, 1, d / 4);
    final_layer_scaled(x, norm);
}

/// The seed's plain radix-2, two-pass (butterflies then a separate
/// normalization sweep) FWHT — kept as the parity and benchmark baseline
/// for the blocked multi-radix one-pass [`fwht`], which must match it
/// bit for bit.
pub fn fwht_reference(x: &mut [f64]) {
    let d = x.len();
    assert!(d.is_power_of_two(), "FWHT needs power-of-two length");
    let mut h = 1;
    while h < d {
        let stride = h * 2;
        let mut i = 0;
        while i < d {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += stride;
        }
        h = stride;
    }
    let norm = 1.0 / (d as f64).sqrt();
    for v in x.iter_mut() {
        *v *= norm;
    }
}

/// Next power of two ≥ n.
pub fn pad_dim(n: usize) -> usize {
    n.next_power_of_two()
}

/// The `HD` rotation with its shared-random sign diagonal.
///
/// §Perf: both directions are single-pass — the sign diagonal (and the
/// zero pad) is fused into the forward transform's first butterfly
/// layer, and the 1/√d normalization (plus, for the inverse, the sign
/// diagonal again) into the final butterfly layer's stores. Each fusion
/// commutes exactly with IEEE rounding (signs are exact; the final
/// layer's post-scale is the same multiply either way), so the fused
/// one-pass rotations are bit-identical to the legacy
/// load-multiply → [`fwht_reference`] → scale-sweep pipeline — pinned by
/// the parity tests below.
#[derive(Clone, Debug)]
pub struct Rotation {
    /// ±1 diagonal, length = padded dimension.
    pub sign: Vec<f64>,
    /// Original (unpadded) dimension.
    pub d: usize,
    /// 1/√(padded dim) — fused into the forward's final butterfly layer.
    norm: f64,
    /// `sign[i] · norm` — the inverse's fused output diagonal.
    inv_diag: Vec<f64>,
}

impl Rotation {
    /// Draw the diagonal from shared randomness.
    pub fn new(d: usize, shared: &mut Rng) -> Self {
        let dp = pad_dim(d);
        let sign: Vec<f64> = (0..dp).map(|_| shared.next_sign()).collect();
        let norm = 1.0 / (dp as f64).sqrt();
        let inv_diag = sign.iter().map(|s| s * norm).collect();
        Rotation {
            sign,
            d,
            norm,
            inv_diag,
        }
    }

    pub fn padded_dim(&self) -> usize {
        self.sign.len()
    }

    /// Forward rotation: zero-pad, multiply by D, apply H.
    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        let mut y = Vec::new();
        self.forward_into(x, &mut y);
        y
    }

    /// Forward rotation into a caller-owned scratch buffer (§Perf): the
    /// buffer is cleared and refilled to the padded length, so after its
    /// first use a round loop re-rotates with zero allocations. Values
    /// are identical to [`Self::forward`].
    ///
    /// Single pass: the first butterfly layer loads straight from `x`
    /// with the sign diagonal and the zero pad applied in registers; the
    /// final layer folds in the 1/√dp normalization.
    pub fn forward_into(&self, x: &[f64], out: &mut Vec<f64>) {
        assert_eq!(x.len(), self.d);
        let dp = self.padded_dim();
        out.clear();
        out.resize(dp, 0.0);
        let load = |i: usize| if i < self.d { x[i] * self.sign[i] } else { 0.0 };
        if dp == 1 {
            out[0] = load(0); // zero layers, norm = 1 exactly
            return;
        }
        if dp == 2 {
            // The first layer is also the final one: sign and norm both
            // fuse into the single butterfly.
            let (a, b) = (load(0), load(1));
            out[0] = (a + b) * self.norm;
            out[1] = (a - b) * self.norm;
            return;
        }
        for (t, pair) in out.chunks_mut(2).enumerate() {
            let a = load(2 * t);
            let b = load(2 * t + 1);
            pair[0] = a + b;
            pair[1] = a - b;
        }
        fwht_span(out, 2, dp / 4);
        final_layer_scaled(out, self.norm);
    }

    /// Inverse rotation: apply H (involution), multiply by D, truncate.
    pub fn inverse(&self, y: &[f64]) -> Vec<f64> {
        assert_eq!(y.len(), self.padded_dim());
        let mut z = y.to_vec();
        self.inverse_in_place(&mut z);
        z.truncate(self.d);
        z
    }

    /// In-place inverse rotation of a padded-length buffer: applies H
    /// then the sign diagonal. The caller reads the first `d` entries
    /// (the pad tail holds reconstruction residue, as in
    /// [`Self::inverse`] before its truncate).
    ///
    /// Single pass: the final butterfly layer's stores are multiplied by
    /// the precomputed `sign[i]/√dp` diagonal, replacing the legacy
    /// normalize sweep + sign sweep.
    pub fn inverse_in_place(&self, y: &mut [f64]) {
        assert_eq!(y.len(), self.padded_dim());
        let dp = y.len();
        if dp == 1 {
            y[0] *= self.inv_diag[0];
            return;
        }
        fwht_span(y, 1, dp / 4);
        final_layer_diag(y, &self.inv_diag);
    }
}

/// RLQSGD codec: rotate with `HD`, lattice-quantize in rotated space,
/// decode against the rotated reference, rotate back.
///
/// The rotated-space scratch buffers live behind a `RefCell` because the
/// decode paths take `&self`; the codec is still `Send` (one machine
/// thread owns it), which is all [`VectorCodec`] requires.
pub struct RotatedLatticeQuantizer {
    pub rotation: Rotation,
    pub inner: LatticeQuantizer,
    /// (rotated reference, rotated payload) — recycled by every `_into`
    /// call so the round loop allocates nothing after its first round.
    scratch: std::cell::RefCell<(Vec<f64>, Vec<f64>)>,
}

impl RotatedLatticeQuantizer {
    /// `y_rot` is the ℓ∞ distance bound *in rotated space* (the
    /// experiments maintain `y_R = slack · ‖HD(g₀−g₁)‖∞`, Section 9.1).
    pub fn from_y_rot(d: usize, q: u32, y_rot: f64, shared: &mut Rng) -> Self {
        let rotation = Rotation::new(d, shared);
        let dp = rotation.padded_dim();
        let s = side_for_y(y_rot.max(f64::MIN_POSITIVE), q);
        let inner = LatticeQuantizer::new(
            super::lattice::CubicLattice::random_offset(dp, s, shared),
            q,
        );
        RotatedLatticeQuantizer {
            rotation,
            inner,
            scratch: std::cell::RefCell::new((Vec::new(), Vec::new())),
        }
    }

    /// Message size: padded_d · ⌈log₂ q⌉ bits.
    pub fn message_bits(&self) -> u64 {
        self.inner.message_bits()
    }

    /// Encode returning the rotated input too (for y_R estimation).
    pub fn encode_with_rotated(&self, x: &[f64]) -> (Message, Vec<f64>) {
        let rx = self.rotation.forward(x);
        let (msg, _) = self.inner.encode_with_point(&rx);
        (msg, rx)
    }

    /// The shared scratch decode pipeline (rotate reference → lattice
    /// decode → inverse-rotate in place), handing the first `d` unrotated
    /// coordinates to `sink`. Both decode entry points are this pipeline
    /// with a different sink, so they are value-identical by
    /// construction.
    fn decode_to_scratch(&self, msg: &Message, reference: &[f64], sink: impl FnOnce(&[f64])) {
        let d = self.rotation.d;
        assert_eq!(reference.len(), d);
        let mut sc = self.scratch.borrow_mut();
        let (rref, rz) = &mut *sc;
        self.rotation.forward_into(reference, rref);
        rz.clear();
        rz.resize(self.rotation.padded_dim(), 0.0);
        self.inner.decode_into(msg, rref, rz);
        self.rotation.inverse_in_place(rz);
        sink(&rz[..d]);
    }
}

impl VectorCodec for RotatedLatticeQuantizer {
    fn name(&self) -> String {
        format!("RLQSGD(q={})", self.inner.q)
    }

    fn dim(&self) -> usize {
        self.rotation.d
    }

    fn encode(&mut self, x: &[f64], _rng: &mut Rng) -> Message {
        self.encode_with_rotated(x).0
    }

    fn decode(&self, msg: &Message, reference: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rotation.d];
        self.decode_into(msg, reference, &mut out);
        out
    }

    /// Zero-alloc encode through the scratch rotation buffer + the inner
    /// lattice's recycled bit writer (bit-identical to `encode`).
    fn encode_into(&mut self, x: &[f64], rng: &mut Rng, out: &mut Message) {
        let (rx, _) = self.scratch.get_mut();
        self.rotation.forward_into(x, rx);
        self.inner.encode_into(rx, rng, out);
    }

    /// Zero-alloc decode: the shared scratch pipeline (`decode_to_scratch`)
    /// with the unrotated coordinates copied out. Value-identical to
    /// `decode`.
    fn decode_into(&self, msg: &Message, reference: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), self.rotation.d);
        self.decode_to_scratch(msg, reference, |z| out.copy_from_slice(z));
    }

    /// Fused fold: same scratch pipeline, with the final unrotated
    /// coordinates accumulated instead of copied. (A single-pass bitstream
    /// fold is impossible here — the inverse rotation is global — but the
    /// accumulate still avoids materializing a decoded vector per packet.)
    fn decode_accumulate_into(&self, msg: &Message, reference: &[f64], weight: f64, acc: &mut [f64]) {
        assert_eq!(acc.len(), self.rotation.d);
        self.decode_to_scratch(msg, reference, |z| {
            for (a, zi) in acc.iter_mut().zip(z) {
                *a += weight * zi;
            }
        });
    }

    fn needs_reference(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dist2, norm2, norm_inf};

    #[test]
    fn fwht_involution() {
        let mut rng = Rng::new(2);
        let x: Vec<f64> = (0..128).map(|_| rng.next_gaussian()).collect();
        let mut y = x.clone();
        fwht(&mut y);
        fwht(&mut y);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn fwht_preserves_l2() {
        let mut rng = Rng::new(4);
        let x: Vec<f64> = (0..256).map(|_| rng.next_gaussian()).collect();
        let mut y = x.clone();
        fwht(&mut y);
        assert!((norm2(&x) - norm2(&y)).abs() < 1e-9);
    }

    #[test]
    fn fwht_matches_direct_hadamard_small() {
        // H_4 (normalized), direct definition H_{ij} = (-1)^{<i,j>}/sqrt(d).
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let mut y = x.clone();
        fwht(&mut y);
        let d = 4usize;
        for i in 0..d {
            let mut expect = 0.0;
            for (j, xj) in x.iter().enumerate() {
                let bits = (i & j).count_ones();
                let sgn = if bits % 2 == 0 { 1.0 } else { -1.0 };
                expect += sgn * xj;
            }
            expect /= (d as f64).sqrt();
            assert!((y[i] - expect).abs() < 1e-12, "{} vs {}", y[i], expect);
        }
    }

    #[test]
    fn blocked_multiradix_fwht_bit_identical_to_reference() {
        // Every size class: trivial (1, 2), odd/even log₂ d, one block,
        // exactly one block, and multi-block (crossing FWHT_BLOCK = 4096,
        // exercising the streamed radix-4 stage and the block-crossing
        // final layer).
        let mut rng = Rng::new(77);
        for d in [1usize, 2, 4, 8, 64, 128, 1024, 4096, 8192, 16384] {
            let x: Vec<f64> = (0..d).map(|_| rng.next_gaussian() * 3.0).collect();
            let mut fused = x.clone();
            fwht(&mut fused);
            let mut two_pass = x;
            fwht_reference(&mut two_pass);
            assert_eq!(fused, two_pass, "d={d}");
        }
    }

    #[test]
    fn fused_rotation_bit_identical_to_two_pass_reference() {
        // The one-pass rotations (sign fused into the first layer, norm —
        // and for the inverse, norm·sign — into the last) must match the
        // seed's pipeline: fill·sign → two-pass FWHT → scale sweeps.
        let mut rng = Rng::new(78);
        for d in [1usize, 2, 3, 5, 100, 1000, 5000] {
            let mut shared = Rng::new(d as u64 + 400);
            let rot = Rotation::new(d, &mut shared);
            let dp = rot.padded_dim();
            let x: Vec<f64> = (0..d).map(|_| rng.next_gaussian() * 2.0).collect();

            let mut expect = vec![0.0; dp];
            for i in 0..d {
                expect[i] = x[i] * rot.sign[i];
            }
            fwht_reference(&mut expect);
            assert_eq!(rot.forward(&x), expect, "forward d={d}");

            let y: Vec<f64> = (0..dp).map(|_| rng.next_gaussian()).collect();
            let mut inv_expect = y.clone();
            fwht_reference(&mut inv_expect);
            for (v, s) in inv_expect.iter_mut().zip(&rot.sign) {
                *v *= s;
            }
            let mut inv = y;
            rot.inverse_in_place(&mut inv);
            assert_eq!(inv, inv_expect, "inverse d={d}");
        }
    }

    #[test]
    fn rotation_roundtrip_with_padding() {
        let mut shared = Rng::new(5);
        let rot = Rotation::new(100, &mut shared); // pads to 128
        assert_eq!(rot.padded_dim(), 128);
        let mut rng = Rng::new(6);
        let x: Vec<f64> = (0..100).map(|_| rng.next_gaussian()).collect();
        let y = rot.forward(&x);
        let z = rot.inverse(&y);
        assert!(dist2(&x, &z) < 1e-9);
    }

    #[test]
    fn rotation_flattens_coordinates() {
        // Lemma 24: a spike vector gets spread to O(d^{-1/2}) coordinates.
        let d = 1024;
        let mut shared = Rng::new(9);
        let rot = Rotation::new(d, &mut shared);
        let mut x = vec![0.0; d];
        x[3] = 1.0;
        let y = rot.forward(&x);
        assert!(norm_inf(&y) <= 1.5 / (d as f64).sqrt() + 1e-12);
    }

    #[test]
    fn scratch_rotation_variants_match_allocating_paths() {
        let mut shared = Rng::new(20);
        let rot = Rotation::new(100, &mut shared); // pads to 128
        let mut rng = Rng::new(21);
        let x: Vec<f64> = (0..100).map(|_| rng.next_gaussian()).collect();
        let y = rot.forward(&x);
        let mut y2 = vec![5.0; 3]; // stale scratch, wrong length
        rot.forward_into(&x, &mut y2);
        assert_eq!(y, y2);
        let z = rot.inverse(&y);
        let mut z2 = y.clone();
        rot.inverse_in_place(&mut z2);
        assert_eq!(z, &z2[..100]);
    }

    #[test]
    fn rlq_into_and_fold_paths_match_allocating_paths() {
        let mut shared = Rng::new(30);
        let mut rng = Rng::new(31);
        for d in [16usize, 100] {
            let mut codec = RotatedLatticeQuantizer::from_y_rot(d, 16, 2.0, &mut shared);
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-3.0, 3.0)).collect();
            let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(-0.05, 0.05)).collect();
            let mut rng_a = rng.clone();
            let fresh = codec.encode(&x, &mut rng_a);
            let mut scratch_msg = crate::quant::Message {
                bytes: vec![0xAB; 3],
                bits: 24,
            };
            codec.encode_into(&x, &mut rng, &mut scratch_msg);
            assert_eq!(scratch_msg, fresh, "encode_into must be bit-identical");
            let z = codec.decode(&fresh, &xv);
            let mut z2 = vec![0.0; d];
            codec.decode_into(&fresh, &xv, &mut z2);
            assert_eq!(z, z2, "decode_into must be value-identical");
            // Fused fold ≡ decode + axpy with a stale accumulator.
            let stale: Vec<f64> = (0..d).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let w = 0.625;
            let mut expect = stale.clone();
            crate::linalg::axpy(&mut expect, w, &z);
            let mut acc = stale;
            codec.decode_accumulate_into(&fresh, &xv, w, &mut acc);
            assert_eq!(acc, expect, "fused fold must match decode + axpy");
        }
    }

    #[test]
    fn rlq_roundtrip_within_y() {
        let mut shared = Rng::new(12);
        let mut rng = Rng::new(13);
        let d = 100;
        let q = 16;
        for _ in 0..10 {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-3.0, 3.0)).collect();
            let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(-0.05, 0.05)).collect();
            // y in rotated space: measure actual rotated distance w/ slack.
            let rot_probe = Rotation::new(d, &mut shared.clone());
            let rdist = norm_inf(&crate::linalg::sub(
                &rot_probe.forward(&x),
                &rot_probe.forward(&xv),
            ));
            let mut codec =
                RotatedLatticeQuantizer::from_y_rot(d, q, (rdist * 1.5).max(1e-6), &mut shared);
            // Keep the rotation used in the codec consistent for the bound:
            let rx = codec.rotation.forward(&x);
            let rxv = codec.rotation.forward(&xv);
            let actual = norm_inf(&crate::linalg::sub(&rx, &rxv));
            let y_used = codec.inner.lattice.success_radius(q);
            if actual <= y_used {
                let msg = codec.encode(&x, &mut rng);
                let z = codec.decode(&msg, &xv);
                // Error bounded by s/2 in rotated ℓ∞, so ℓ2 error ≤ s/2·sqrt(dp).
                let s = codec.inner.lattice.s;
                let bound = s / 2.0 * (codec.rotation.padded_dim() as f64).sqrt() + 1e-9;
                assert!(dist2(&z, &x) <= bound, "{} > {}", dist2(&z, &x), bound);
            }
        }
    }
}
