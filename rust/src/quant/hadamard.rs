//! RLQSGD — cubic lattice + structured random rotation (Section 6).
//!
//! The rotation `HD` (normalized Walsh–Hadamard times a random ±1
//! diagonal) flattens any vector's coordinates so that
//! `‖HDx‖∞ = O(d^{-1/2}‖x‖₂ √log nd)` (Lemma 24), making the ℓ∞-optimal
//! cubic lattice near-optimal under ℓ₂ (Theorem 5). The diagonal is drawn
//! from shared randomness; `H` is fixed. Inputs whose dimension is not a
//! power of two are zero-padded (standard practice, also done in [36]).

use super::lattice::side_for_y;
use super::lq::LatticeQuantizer;
use super::{Message, VectorCodec};
use crate::rng::Rng;

/// In-place normalized fast Walsh–Hadamard transform.
/// `x.len()` must be a power of two. O(d log d).
pub fn fwht(x: &mut [f64]) {
    let d = x.len();
    assert!(d.is_power_of_two(), "FWHT needs power-of-two length");
    let mut h = 1;
    while h < d {
        let stride = h * 2;
        let mut i = 0;
        while i < d {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += stride;
        }
        h = stride;
    }
    let norm = 1.0 / (d as f64).sqrt();
    for v in x.iter_mut() {
        *v *= norm;
    }
}

/// Next power of two ≥ n.
pub fn pad_dim(n: usize) -> usize {
    n.next_power_of_two()
}

/// The `HD` rotation with its shared-random sign diagonal.
#[derive(Clone, Debug)]
pub struct Rotation {
    /// ±1 diagonal, length = padded dimension.
    pub sign: Vec<f64>,
    /// Original (unpadded) dimension.
    pub d: usize,
}

impl Rotation {
    /// Draw the diagonal from shared randomness.
    pub fn new(d: usize, shared: &mut Rng) -> Self {
        let dp = pad_dim(d);
        let sign = (0..dp).map(|_| shared.next_sign()).collect();
        Rotation { sign, d }
    }

    pub fn padded_dim(&self) -> usize {
        self.sign.len()
    }

    /// Forward rotation: zero-pad, multiply by D, apply H.
    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        let mut y = Vec::new();
        self.forward_into(x, &mut y);
        y
    }

    /// Forward rotation into a caller-owned scratch buffer (§Perf): the
    /// buffer is cleared and refilled to the padded length, so after its
    /// first use a round loop re-rotates with zero allocations. Values
    /// are identical to [`Self::forward`].
    pub fn forward_into(&self, x: &[f64], out: &mut Vec<f64>) {
        assert_eq!(x.len(), self.d);
        let dp = self.padded_dim();
        out.clear();
        out.resize(dp, 0.0);
        for i in 0..self.d {
            out[i] = x[i] * self.sign[i];
        }
        fwht(out);
    }

    /// Inverse rotation: apply H (involution), multiply by D, truncate.
    pub fn inverse(&self, y: &[f64]) -> Vec<f64> {
        assert_eq!(y.len(), self.padded_dim());
        let mut z = y.to_vec();
        self.inverse_in_place(&mut z);
        z.truncate(self.d);
        z
    }

    /// In-place inverse rotation of a padded-length buffer: applies H
    /// then the sign diagonal. The caller reads the first `d` entries
    /// (the pad tail holds reconstruction residue, as in
    /// [`Self::inverse`] before its truncate).
    pub fn inverse_in_place(&self, y: &mut [f64]) {
        assert_eq!(y.len(), self.padded_dim());
        fwht(y);
        for (yi, si) in y.iter_mut().zip(&self.sign) {
            *yi *= si;
        }
    }
}

/// RLQSGD codec: rotate with `HD`, lattice-quantize in rotated space,
/// decode against the rotated reference, rotate back.
///
/// The rotated-space scratch buffers live behind a `RefCell` because the
/// decode paths take `&self`; the codec is still `Send` (one machine
/// thread owns it), which is all [`VectorCodec`] requires.
pub struct RotatedLatticeQuantizer {
    pub rotation: Rotation,
    pub inner: LatticeQuantizer,
    /// (rotated reference, rotated payload) — recycled by every `_into`
    /// call so the round loop allocates nothing after its first round.
    scratch: std::cell::RefCell<(Vec<f64>, Vec<f64>)>,
}

impl RotatedLatticeQuantizer {
    /// `y_rot` is the ℓ∞ distance bound *in rotated space* (the
    /// experiments maintain `y_R = slack · ‖HD(g₀−g₁)‖∞`, Section 9.1).
    pub fn from_y_rot(d: usize, q: u32, y_rot: f64, shared: &mut Rng) -> Self {
        let rotation = Rotation::new(d, shared);
        let dp = rotation.padded_dim();
        let s = side_for_y(y_rot.max(f64::MIN_POSITIVE), q);
        let inner = LatticeQuantizer::new(
            super::lattice::CubicLattice::random_offset(dp, s, shared),
            q,
        );
        RotatedLatticeQuantizer {
            rotation,
            inner,
            scratch: std::cell::RefCell::new((Vec::new(), Vec::new())),
        }
    }

    /// Message size: padded_d · ⌈log₂ q⌉ bits.
    pub fn message_bits(&self) -> u64 {
        self.inner.message_bits()
    }

    /// Encode returning the rotated input too (for y_R estimation).
    pub fn encode_with_rotated(&self, x: &[f64]) -> (Message, Vec<f64>) {
        let rx = self.rotation.forward(x);
        let (msg, _) = self.inner.encode_with_point(&rx);
        (msg, rx)
    }

    /// The shared scratch decode pipeline (rotate reference → lattice
    /// decode → inverse-rotate in place), handing the first `d` unrotated
    /// coordinates to `sink`. Both decode entry points are this pipeline
    /// with a different sink, so they are value-identical by
    /// construction.
    fn decode_to_scratch(&self, msg: &Message, reference: &[f64], sink: impl FnOnce(&[f64])) {
        let d = self.rotation.d;
        assert_eq!(reference.len(), d);
        let mut sc = self.scratch.borrow_mut();
        let (rref, rz) = &mut *sc;
        self.rotation.forward_into(reference, rref);
        rz.clear();
        rz.resize(self.rotation.padded_dim(), 0.0);
        self.inner.decode_into(msg, rref, rz);
        self.rotation.inverse_in_place(rz);
        sink(&rz[..d]);
    }
}

impl VectorCodec for RotatedLatticeQuantizer {
    fn name(&self) -> String {
        format!("RLQSGD(q={})", self.inner.q)
    }

    fn dim(&self) -> usize {
        self.rotation.d
    }

    fn encode(&mut self, x: &[f64], _rng: &mut Rng) -> Message {
        self.encode_with_rotated(x).0
    }

    fn decode(&self, msg: &Message, reference: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rotation.d];
        self.decode_into(msg, reference, &mut out);
        out
    }

    /// Zero-alloc encode through the scratch rotation buffer + the inner
    /// lattice's recycled bit writer (bit-identical to `encode`).
    fn encode_into(&mut self, x: &[f64], rng: &mut Rng, out: &mut Message) {
        let (rx, _) = self.scratch.get_mut();
        self.rotation.forward_into(x, rx);
        self.inner.encode_into(rx, rng, out);
    }

    /// Zero-alloc decode: the shared scratch pipeline (`decode_to_scratch`)
    /// with the unrotated coordinates copied out. Value-identical to
    /// `decode`.
    fn decode_into(&self, msg: &Message, reference: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), self.rotation.d);
        self.decode_to_scratch(msg, reference, |z| out.copy_from_slice(z));
    }

    /// Fused fold: same scratch pipeline, with the final unrotated
    /// coordinates accumulated instead of copied. (A single-pass bitstream
    /// fold is impossible here — the inverse rotation is global — but the
    /// accumulate still avoids materializing a decoded vector per packet.)
    fn decode_accumulate_into(&self, msg: &Message, reference: &[f64], weight: f64, acc: &mut [f64]) {
        assert_eq!(acc.len(), self.rotation.d);
        self.decode_to_scratch(msg, reference, |z| {
            for (a, zi) in acc.iter_mut().zip(z) {
                *a += weight * zi;
            }
        });
    }

    fn needs_reference(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dist2, norm2, norm_inf};

    #[test]
    fn fwht_involution() {
        let mut rng = Rng::new(2);
        let x: Vec<f64> = (0..128).map(|_| rng.next_gaussian()).collect();
        let mut y = x.clone();
        fwht(&mut y);
        fwht(&mut y);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn fwht_preserves_l2() {
        let mut rng = Rng::new(4);
        let x: Vec<f64> = (0..256).map(|_| rng.next_gaussian()).collect();
        let mut y = x.clone();
        fwht(&mut y);
        assert!((norm2(&x) - norm2(&y)).abs() < 1e-9);
    }

    #[test]
    fn fwht_matches_direct_hadamard_small() {
        // H_4 (normalized), direct definition H_{ij} = (-1)^{<i,j>}/sqrt(d).
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let mut y = x.clone();
        fwht(&mut y);
        let d = 4usize;
        for i in 0..d {
            let mut expect = 0.0;
            for (j, xj) in x.iter().enumerate() {
                let bits = (i & j).count_ones();
                let sgn = if bits % 2 == 0 { 1.0 } else { -1.0 };
                expect += sgn * xj;
            }
            expect /= (d as f64).sqrt();
            assert!((y[i] - expect).abs() < 1e-12, "{} vs {}", y[i], expect);
        }
    }

    #[test]
    fn rotation_roundtrip_with_padding() {
        let mut shared = Rng::new(5);
        let rot = Rotation::new(100, &mut shared); // pads to 128
        assert_eq!(rot.padded_dim(), 128);
        let mut rng = Rng::new(6);
        let x: Vec<f64> = (0..100).map(|_| rng.next_gaussian()).collect();
        let y = rot.forward(&x);
        let z = rot.inverse(&y);
        assert!(dist2(&x, &z) < 1e-9);
    }

    #[test]
    fn rotation_flattens_coordinates() {
        // Lemma 24: a spike vector gets spread to O(d^{-1/2}) coordinates.
        let d = 1024;
        let mut shared = Rng::new(9);
        let rot = Rotation::new(d, &mut shared);
        let mut x = vec![0.0; d];
        x[3] = 1.0;
        let y = rot.forward(&x);
        assert!(norm_inf(&y) <= 1.5 / (d as f64).sqrt() + 1e-12);
    }

    #[test]
    fn scratch_rotation_variants_match_allocating_paths() {
        let mut shared = Rng::new(20);
        let rot = Rotation::new(100, &mut shared); // pads to 128
        let mut rng = Rng::new(21);
        let x: Vec<f64> = (0..100).map(|_| rng.next_gaussian()).collect();
        let y = rot.forward(&x);
        let mut y2 = vec![5.0; 3]; // stale scratch, wrong length
        rot.forward_into(&x, &mut y2);
        assert_eq!(y, y2);
        let z = rot.inverse(&y);
        let mut z2 = y.clone();
        rot.inverse_in_place(&mut z2);
        assert_eq!(z, &z2[..100]);
    }

    #[test]
    fn rlq_into_and_fold_paths_match_allocating_paths() {
        let mut shared = Rng::new(30);
        let mut rng = Rng::new(31);
        for d in [16usize, 100] {
            let mut codec = RotatedLatticeQuantizer::from_y_rot(d, 16, 2.0, &mut shared);
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-3.0, 3.0)).collect();
            let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(-0.05, 0.05)).collect();
            let mut rng_a = rng.clone();
            let fresh = codec.encode(&x, &mut rng_a);
            let mut scratch_msg = crate::quant::Message {
                bytes: vec![0xAB; 3],
                bits: 24,
            };
            codec.encode_into(&x, &mut rng, &mut scratch_msg);
            assert_eq!(scratch_msg, fresh, "encode_into must be bit-identical");
            let z = codec.decode(&fresh, &xv);
            let mut z2 = vec![0.0; d];
            codec.decode_into(&fresh, &xv, &mut z2);
            assert_eq!(z, z2, "decode_into must be value-identical");
            // Fused fold ≡ decode + axpy with a stale accumulator.
            let stale: Vec<f64> = (0..d).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let w = 0.625;
            let mut expect = stale.clone();
            crate::linalg::axpy(&mut expect, w, &z);
            let mut acc = stale;
            codec.decode_accumulate_into(&fresh, &xv, w, &mut acc);
            assert_eq!(acc, expect, "fused fold must match decode + axpy");
        }
    }

    #[test]
    fn rlq_roundtrip_within_y() {
        let mut shared = Rng::new(12);
        let mut rng = Rng::new(13);
        let d = 100;
        let q = 16;
        for _ in 0..10 {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-3.0, 3.0)).collect();
            let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(-0.05, 0.05)).collect();
            // y in rotated space: measure actual rotated distance w/ slack.
            let rot_probe = Rotation::new(d, &mut shared.clone());
            let rdist = norm_inf(&crate::linalg::sub(
                &rot_probe.forward(&x),
                &rot_probe.forward(&xv),
            ));
            let mut codec =
                RotatedLatticeQuantizer::from_y_rot(d, q, (rdist * 1.5).max(1e-6), &mut shared);
            // Keep the rotation used in the codec consistent for the bound:
            let rx = codec.rotation.forward(&x);
            let rxv = codec.rotation.forward(&xv);
            let actual = norm_inf(&crate::linalg::sub(&rx, &rxv));
            let y_used = codec.inner.lattice.success_radius(q);
            if actual <= y_used {
                let msg = codec.encode(&x, &mut rng);
                let z = codec.decode(&msg, &xv);
                // Error bounded by s/2 in rotated ℓ∞, so ℓ2 error ≤ s/2·sqrt(dp).
                let s = codec.inner.lattice.s;
                let bound = s / 2.0 * (codec.rotation.padded_dim() as f64).sqrt() + 1e-9;
                assert!(dist2(&z, &x) <= bound, "{} > {}", dist2(&z, &x), bound);
            }
        }
    }
}
