//! RLQSGD — cubic lattice + structured random rotation (Section 6).
//!
//! The rotation `HD` (normalized Walsh–Hadamard times a random ±1
//! diagonal) flattens any vector's coordinates so that
//! `‖HDx‖∞ = O(d^{-1/2}‖x‖₂ √log nd)` (Lemma 24), making the ℓ∞-optimal
//! cubic lattice near-optimal under ℓ₂ (Theorem 5). The diagonal is drawn
//! from shared randomness; `H` is fixed. Inputs whose dimension is not a
//! power of two are zero-padded (standard practice, also done in [36]).

use super::lattice::side_for_y;
use super::lq::LatticeQuantizer;
use super::{Message, VectorCodec};
use crate::rng::Rng;

/// In-place normalized fast Walsh–Hadamard transform.
/// `x.len()` must be a power of two. O(d log d).
pub fn fwht(x: &mut [f64]) {
    let d = x.len();
    assert!(d.is_power_of_two(), "FWHT needs power-of-two length");
    let mut h = 1;
    while h < d {
        let stride = h * 2;
        let mut i = 0;
        while i < d {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += stride;
        }
        h = stride;
    }
    let norm = 1.0 / (d as f64).sqrt();
    for v in x.iter_mut() {
        *v *= norm;
    }
}

/// Next power of two ≥ n.
pub fn pad_dim(n: usize) -> usize {
    n.next_power_of_two()
}

/// The `HD` rotation with its shared-random sign diagonal.
#[derive(Clone, Debug)]
pub struct Rotation {
    /// ±1 diagonal, length = padded dimension.
    pub sign: Vec<f64>,
    /// Original (unpadded) dimension.
    pub d: usize,
}

impl Rotation {
    /// Draw the diagonal from shared randomness.
    pub fn new(d: usize, shared: &mut Rng) -> Self {
        let dp = pad_dim(d);
        let sign = (0..dp).map(|_| shared.next_sign()).collect();
        Rotation { sign, d }
    }

    pub fn padded_dim(&self) -> usize {
        self.sign.len()
    }

    /// Forward rotation: zero-pad, multiply by D, apply H.
    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.d);
        let dp = self.padded_dim();
        let mut y = vec![0.0; dp];
        for i in 0..self.d {
            y[i] = x[i] * self.sign[i];
        }
        fwht(&mut y);
        y
    }

    /// Inverse rotation: apply H (involution), multiply by D, truncate.
    pub fn inverse(&self, y: &[f64]) -> Vec<f64> {
        assert_eq!(y.len(), self.padded_dim());
        let mut z = y.to_vec();
        fwht(&mut z);
        for (zi, si) in z.iter_mut().zip(&self.sign) {
            *zi *= si;
        }
        z.truncate(self.d);
        z
    }
}

/// RLQSGD codec: rotate with `HD`, lattice-quantize in rotated space,
/// decode against the rotated reference, rotate back.
pub struct RotatedLatticeQuantizer {
    pub rotation: Rotation,
    pub inner: LatticeQuantizer,
}

impl RotatedLatticeQuantizer {
    /// `y_rot` is the ℓ∞ distance bound *in rotated space* (the
    /// experiments maintain `y_R = slack · ‖HD(g₀−g₁)‖∞`, Section 9.1).
    pub fn from_y_rot(d: usize, q: u32, y_rot: f64, shared: &mut Rng) -> Self {
        let rotation = Rotation::new(d, shared);
        let dp = rotation.padded_dim();
        let s = side_for_y(y_rot.max(f64::MIN_POSITIVE), q);
        let inner = LatticeQuantizer::new(
            super::lattice::CubicLattice::random_offset(dp, s, shared),
            q,
        );
        RotatedLatticeQuantizer { rotation, inner }
    }

    /// Message size: padded_d · ⌈log₂ q⌉ bits.
    pub fn message_bits(&self) -> u64 {
        self.inner.message_bits()
    }

    /// Encode returning the rotated input too (for y_R estimation).
    pub fn encode_with_rotated(&self, x: &[f64]) -> (Message, Vec<f64>) {
        let rx = self.rotation.forward(x);
        let (msg, _) = self.inner.encode_with_point(&rx);
        (msg, rx)
    }
}

impl VectorCodec for RotatedLatticeQuantizer {
    fn name(&self) -> String {
        format!("RLQSGD(q={})", self.inner.q)
    }

    fn dim(&self) -> usize {
        self.rotation.d
    }

    fn encode(&mut self, x: &[f64], _rng: &mut Rng) -> Message {
        self.encode_with_rotated(x).0
    }

    fn decode(&self, msg: &Message, reference: &[f64]) -> Vec<f64> {
        let r_ref = self.rotation.forward(reference);
        let rz = self.inner.decode(msg, &r_ref);
        self.rotation.inverse(&rz)
    }

    fn needs_reference(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dist2, norm2, norm_inf};

    #[test]
    fn fwht_involution() {
        let mut rng = Rng::new(2);
        let x: Vec<f64> = (0..128).map(|_| rng.next_gaussian()).collect();
        let mut y = x.clone();
        fwht(&mut y);
        fwht(&mut y);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn fwht_preserves_l2() {
        let mut rng = Rng::new(4);
        let x: Vec<f64> = (0..256).map(|_| rng.next_gaussian()).collect();
        let mut y = x.clone();
        fwht(&mut y);
        assert!((norm2(&x) - norm2(&y)).abs() < 1e-9);
    }

    #[test]
    fn fwht_matches_direct_hadamard_small() {
        // H_4 (normalized), direct definition H_{ij} = (-1)^{<i,j>}/sqrt(d).
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let mut y = x.clone();
        fwht(&mut y);
        let d = 4usize;
        for i in 0..d {
            let mut expect = 0.0;
            for (j, xj) in x.iter().enumerate() {
                let bits = (i & j).count_ones();
                let sgn = if bits % 2 == 0 { 1.0 } else { -1.0 };
                expect += sgn * xj;
            }
            expect /= (d as f64).sqrt();
            assert!((y[i] - expect).abs() < 1e-12, "{} vs {}", y[i], expect);
        }
    }

    #[test]
    fn rotation_roundtrip_with_padding() {
        let mut shared = Rng::new(5);
        let rot = Rotation::new(100, &mut shared); // pads to 128
        assert_eq!(rot.padded_dim(), 128);
        let mut rng = Rng::new(6);
        let x: Vec<f64> = (0..100).map(|_| rng.next_gaussian()).collect();
        let y = rot.forward(&x);
        let z = rot.inverse(&y);
        assert!(dist2(&x, &z) < 1e-9);
    }

    #[test]
    fn rotation_flattens_coordinates() {
        // Lemma 24: a spike vector gets spread to O(d^{-1/2}) coordinates.
        let d = 1024;
        let mut shared = Rng::new(9);
        let rot = Rotation::new(d, &mut shared);
        let mut x = vec![0.0; d];
        x[3] = 1.0;
        let y = rot.forward(&x);
        assert!(norm_inf(&y) <= 1.5 / (d as f64).sqrt() + 1e-12);
    }

    #[test]
    fn rlq_roundtrip_within_y() {
        let mut shared = Rng::new(12);
        let mut rng = Rng::new(13);
        let d = 100;
        let q = 16;
        for _ in 0..10 {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-3.0, 3.0)).collect();
            let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(-0.05, 0.05)).collect();
            // y in rotated space: measure actual rotated distance w/ slack.
            let rot_probe = Rotation::new(d, &mut shared.clone());
            let rdist = norm_inf(&crate::linalg::sub(
                &rot_probe.forward(&x),
                &rot_probe.forward(&xv),
            ));
            let mut codec =
                RotatedLatticeQuantizer::from_y_rot(d, q, (rdist * 1.5).max(1e-6), &mut shared);
            // Keep the rotation used in the codec consistent for the bound:
            let rx = codec.rotation.forward(&x);
            let rxv = codec.rotation.forward(&xv);
            let actual = norm_inf(&crate::linalg::sub(&rx, &rxv));
            let y_used = codec.inner.lattice.success_radius(q);
            if actual <= y_used {
                let msg = codec.encode(&x, &mut rng);
                let z = codec.decode(&msg, &xv);
                // Error bounded by s/2 in rotated ℓ∞, so ℓ2 error ≤ s/2·sqrt(dp).
                let s = codec.inner.lattice.s;
                let bound = s / 2.0 * (codec.rotation.padded_dim() as f64).sqrt() + 1e-9;
                assert!(dist2(&z, &x) <= bound, "{} > {}", dist2(&z, &x), bound);
            }
        }
    }
}
