//! Error detection in quantization — RobustAgreement (Section 5, Alg 5).
//!
//! The paper augments the mod-q coloring with a *random coloring* such
//! that, when encoder and decoder are too far apart for proximity decoding,
//! the decoder detects this with high probability (the decoded color class
//! has no member near the decoder). It then replies `FAR` and the pair
//! retries with a squared precision parameter `r ← r²`, so the expected
//! bits stay `O(d log(q/ε · ‖x_u − x_v‖))` (Lemma 23).
//!
//! **Practical instantiation** (documented in DESIGN.md §2): the random
//! coloring's only role is to make wrong-point decodes *detectable*. We
//! realize exactly that semantics by shipping, alongside the mod-q colors,
//! a salted 32-bit hash of the encoded index vector. The decoder re-hashes
//! its decoded indices; a mismatch is the paper's "my color class has no
//! nearby point" event, with failure probability 2⁻³² per round (vs the
//! paper's `O(q^{-d})`). Detection bits per round are 32 = O(log n) for
//! every practical n, matching the `+ log n` term of Theorem 4.

use super::bits::{unpack, width_for, BitWriter};
use super::lattice::{side_for_y, CubicLattice};
use super::Message;
use crate::rng::{hash2, Rng};

/// Result of one robust encode→decode attempt.
#[derive(Clone, Debug, PartialEq)]
pub enum RobustOutcome {
    /// Decoded successfully (hash matched).
    Ok(Vec<f64>),
    /// Detected that the decoder is too far: retry with more bits.
    Far,
}

/// Pairwise robust agreement between an encoder holding `x_u` and a
/// decoder holding `x_v`.
///
/// Communication is simulated in-process but metered exactly:
/// `bits_sent_u → v` per round is `d·⌈log₂ q_r⌉ + 32` (colors + hash),
/// plus 1 bit for each `FAR` reply from v.
#[derive(Clone, Debug)]
pub struct RobustAgreement {
    pub d: usize,
    /// Initial quantization parameter q (precision doubles as q squares).
    pub q0: u32,
    /// Lattice side at the initial q (kept fixed; escalation only widens
    /// the color space, exactly like Alg 5 keeps ε and grows r).
    pub s: f64,
    /// Shared seed for the offset and the coloring salt.
    pub seed: u64,
    /// Cap on escalation rounds (q ≤ 2^31).
    pub max_rounds: u32,
}

/// Transcript of a robust agreement exchange.
#[derive(Clone, Debug)]
pub struct RobustTranscript {
    /// Decoded estimate (None if max_rounds exhausted — practically
    /// unreachable with sane parameters).
    pub estimate: Option<Vec<f64>>,
    /// Bits sent by the encoder across all rounds.
    pub bits_forward: u64,
    /// Bits sent by the decoder (FAR replies).
    pub bits_backward: u64,
    /// Number of rounds used (1 = first attempt succeeded).
    pub rounds: u32,
}

impl RobustAgreement {
    /// `y0` is the initial distance guess (ε·q ≈ y0 in paper terms).
    pub fn new(d: usize, q0: u32, y0: f64, seed: u64) -> Self {
        assert!(q0 >= 2);
        RobustAgreement {
            d,
            q0,
            s: side_for_y(y0.max(f64::MIN_POSITIVE), q0),
            seed,
            max_rounds: 5,
        }
    }

    fn lattice(&self) -> CubicLattice {
        let mut shared = Rng::new(hash2(self.seed, 0xD15A)); // shared offset
        CubicLattice::random_offset(self.d, self.s, &mut shared)
    }

    fn hash_indices(k: &[i64], salt: u64) -> u32 {
        let mut h = salt ^ 0x9E3779B97F4A7C15;
        for &ki in k {
            h = hash2(h, ki as u64);
        }
        (h & 0xFFFF_FFFF) as u32
    }

    /// One round at parameter `q`: returns (message, indices).
    pub fn encode_round(&self, x_u: &[f64], q: u32) -> (Message, Vec<i64>) {
        let lat = self.lattice();
        let mut k = vec![0i64; self.d];
        lat.nearest_index(x_u, &mut k);
        let width = width_for(q as u64);
        let colors: Vec<u64> = k
            .iter()
            .map(|&ki| CubicLattice::color_of(ki, q) as u64)
            .collect();
        let mut w = BitWriter::with_capacity(self.d * width as usize + 32);
        w.push_block(&colors, width);
        w.push(Self::hash_indices(&k, hash2(self.seed, q as u64)) as u64, 32);
        let (bytes, bits) = w.finish();
        (Message { bytes, bits }, k)
    }

    /// Decode one round at parameter `q` against `x_v`.
    pub fn decode_round(&self, msg: &Message, x_v: &[f64], q: u32) -> RobustOutcome {
        let lat = self.lattice();
        let width = width_for(q as u64);
        let all = unpack(&msg.bytes, width, self.d);
        // Re-read the trailing hash.
        let mut r = super::bits::BitReader::new(&msg.bytes);
        for _ in 0..self.d {
            r.read(width);
        }
        let sent_hash = r.read(32) as u32;
        let mut k = vec![0i64; self.d];
        // Reciprocals hoisted out of the per-coordinate loop (§Perf).
        let inv_sq = 1.0 / (lat.s * q as f64);
        let inv_q = 1.0 / q as f64;
        for i in 0..self.d {
            k[i] = CubicLattice::decode_index_folded(
                all[i] as u32,
                x_v[i],
                lat.offset[i],
                q,
                inv_sq,
                inv_q,
            );
        }
        if Self::hash_indices(&k, hash2(self.seed, q as u64)) == sent_hash {
            let mut z = vec![0.0; self.d];
            lat.point(&k, &mut z);
            RobustOutcome::Ok(z)
        } else {
            RobustOutcome::Far
        }
    }

    /// Run the full escalating protocol (Alg 5): q ← q² until success.
    pub fn run(&self, x_u: &[f64], x_v: &[f64]) -> RobustTranscript {
        assert_eq!(x_u.len(), self.d);
        assert_eq!(x_v.len(), self.d);
        let mut q = self.q0 as u64;
        let mut bits_forward = 0;
        let mut bits_backward = 0;
        for round in 1..=self.max_rounds {
            let q32 = q.min(1 << 30) as u32;
            let (msg, _k) = self.encode_round(x_u, q32);
            bits_forward += msg.bits;
            match self.decode_round(&msg, x_v, q32) {
                RobustOutcome::Ok(z) => {
                    return RobustTranscript {
                        estimate: Some(z),
                        bits_forward,
                        bits_backward,
                        rounds: round,
                    }
                }
                RobustOutcome::Far => {
                    bits_backward += 1; // the FAR reply
                    q = q.saturating_mul(q);
                }
            }
        }
        RobustTranscript {
            estimate: None,
            bits_forward,
            bits_backward,
            rounds: self.max_rounds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dist_inf;

    #[test]
    fn near_inputs_succeed_in_one_round() {
        let mut rng = Rng::new(21);
        let d = 64;
        let y = 1.0;
        let ra = RobustAgreement::new(d, 16, y, 777);
        for _ in 0..20 {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-50.0, 50.0)).collect();
            let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(-y, y)).collect();
            let t = ra.run(&x, &xv);
            assert_eq!(t.rounds, 1);
            let z = t.estimate.unwrap();
            assert!(dist_inf(&z, &x) <= ra.s / 2.0 + 1e-12);
            assert_eq!(t.bits_forward, 64 * 4 + 32);
        }
    }

    #[test]
    fn far_inputs_escalate_then_succeed() {
        let mut rng = Rng::new(22);
        let d = 32;
        let ra = RobustAgreement::new(d, 4, 0.5, 901);
        // Decoder 100x further than the estimate y=0.5 allows at q=4.
        let x: Vec<f64> = (0..d).map(|_| rng.uniform(-5.0, 5.0)).collect();
        let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(40.0, 50.0)).collect();
        let t = ra.run(&x, &xv);
        assert!(t.rounds > 1, "must escalate");
        assert!(t.bits_backward >= 1, "must have sent FAR");
        let z = t.estimate.expect("eventually succeeds");
        assert!(dist_inf(&z, &x) <= ra.s / 2.0 + 1e-12);
    }

    #[test]
    fn expected_bits_grow_with_log_distance() {
        // Lemma 23 shape: bits = O(d log(q/ε * dist)).
        let d = 16;
        let ra = RobustAgreement::new(d, 4, 0.25, 5);
        let x = vec![0.0; d];
        let mut bits_at = Vec::new();
        for scale in [0.1, 10.0, 1000.0] {
            let xv = vec![scale; d];
            let t = ra.run(&x, &xv);
            assert!(t.estimate.is_some());
            bits_at.push(t.bits_forward);
        }
        assert!(bits_at[0] < bits_at[1]);
        assert!(bits_at[1] <= bits_at[2]);
    }

    #[test]
    fn detection_is_sound_not_flaky() {
        // Within range, the hash never spuriously reports FAR (it is
        // computed over the decoded indices, which equal the encoded ones).
        let mut rng = Rng::new(23);
        let d = 48;
        let ra = RobustAgreement::new(d, 8, 2.0, 31337);
        for _ in 0..200 {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-10.0, 10.0)).collect();
            let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(-2.0, 2.0)).collect();
            let (msg, _) = ra.encode_round(&x, 8);
            assert!(matches!(
                ra.decode_round(&msg, &xv, 8),
                RobustOutcome::Ok(_)
            ));
        }
    }
}
